"""Coarse-grained block-wise pruning (value-level sparsity).

The weight matrix of a layer (im2col layout [K, N]: K input positions,
N filters) is partitioned into non-overlapping 1xα blocks along the
filter axis: block (k, g) covers weights at input position k in filters
g*α .. g*α+α-1 — "the weights at the same position in multiple filters".
α is fixed by the SRAM macro column count (α = 8 in DB-PIM).

Blocks are ranked by L2 norm and the lowest fraction is pruned. Because
a pruned block zeroes input position k for a whole α-filter group, the
sparse allocation network can skip fetching that input feature for the
group — this is the structured value-level sparsity the architecture
exploits.

Mirrored by ``rust/src/pruning/``.
"""

from __future__ import annotations

import numpy as np

#: DB-PIM pruning granularity (macro column count / FTA threshold).
ALPHA = 8


def block_l2(weights: np.ndarray, alpha: int = ALPHA) -> np.ndarray:
    """L2 norm of each 1xα block.

    Args:
      weights: [K, N] with N divisible by α.

    Returns:
      float64 array [K, N // α].
    """
    w = np.asarray(weights, dtype=np.float64)
    k, n = w.shape
    if n % alpha:
        raise ValueError(f"N={n} not divisible by alpha={alpha}")
    return np.sqrt((w.reshape(k, n // alpha, alpha) ** 2).sum(-1))


def prune_blocks(weights: np.ndarray, sparsity: float,
                 alpha: int = ALPHA) -> tuple[np.ndarray, np.ndarray]:
    """Prune the lowest-L2 fraction of blocks.

    Args:
      weights: [K, N] float or int weights.
      sparsity: fraction of blocks to prune, in [0, 1).
      alpha: block width along the filter axis.

    Returns:
      (pruned weights (same dtype), block mask [K, N // α] uint8 with
      1 = kept). Ties at the threshold are broken by block order
      (stable argsort), matching the rust mirror.
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity {sparsity} out of [0, 1)")
    w = np.asarray(weights)
    norms = block_l2(w, alpha)
    k, g = norms.shape
    mask = np.ones((k, g), dtype=np.uint8)
    n_prune = int(round(sparsity * k * g))
    if n_prune:
        order = np.argsort(norms.reshape(-1), kind="stable")
        mask.reshape(-1)[order[:n_prune]] = 0
    pruned = w * expand_mask(mask, alpha).astype(w.dtype)
    return pruned, mask


def expand_mask(block_mask: np.ndarray, alpha: int = ALPHA) -> np.ndarray:
    """Expand a [K, G] block mask to a per-weight [K, G*α] mask."""
    m = np.asarray(block_mask)
    return np.repeat(m, alpha, axis=1)


def value_sparsity(weights: np.ndarray) -> float:
    """Fraction of exactly-zero weights."""
    w = np.asarray(weights)
    return 1.0 - (np.count_nonzero(w) / w.size) if w.size else 0.0


def mask_sparsity(block_mask: np.ndarray) -> float:
    """Fraction of pruned blocks."""
    m = np.asarray(block_mask)
    return 1.0 - (np.count_nonzero(m) / m.size) if m.size else 0.0


def group_zero_column_fraction(acts: np.ndarray, group: int) -> float:
    """Fig. 3(b): fraction of all-zero bit columns in groups of N inputs.

    Activations are unsigned INT8 (post-ReLU). Inputs are grouped into
    consecutive runs of ``group`` values; a bit column (one of the 8 bit
    positions) is skippable when it is zero across the whole group.
    """
    a = np.asarray(acts).reshape(-1).astype(np.int64)
    if a.size == 0:
        return 0.0
    usable = (a.size // group) * group
    a = np.abs(a[:usable]).reshape(-1, group)
    bits = (a[..., None] >> np.arange(8)) & 1  # [G, group, 8]
    col_nonzero = bits.any(axis=1)  # [G, 8]
    return float(1.0 - col_nonzero.mean())
