"""Fixed-Threshold Approximation (FTA) — Algorithm 1 of the paper.

FTA imposes a uniform non-zero CSD digit count φ_th per *filter*: every
weight in the filter is re-projected to the nearest INT8 value whose CSD
representation has exactly φ_th non-zero digits. Because every surviving
weight then occupies exactly φ_th dyadic blocks, a filter maps onto a
fixed number of SRAM columns and the crossbar stays regular while the
zero blocks are physically removed.

The threshold is the mode of the filter's digit counts (over weights not
removed by coarse-grained pruning), clamped to [0, 2]:

    all φ == 0      → φ_th = 0      (all-zero filter)
    mode == 0       → φ_th = 1
    1 <= mode <= 2  → φ_th = mode
    mode > 2        → φ_th = 2

Mirrored bit-exactly by ``rust/src/fta/``.
"""

from __future__ import annotations

import functools

import numpy as np

from . import csd

INT8_MIN, INT8_MAX = -128, 127


@functools.lru_cache(maxsize=None)
def query_table(phi_th: int) -> np.ndarray:
    """T(φ_th): all INT8 values whose CSD has exactly φ_th non-zero digits.

    Sorted ascending. |T(0)| = 1 and |T(1)| = 15 (±2^0..2^6 plus -2^7;
    +128 is out of INT8 range); the five tables partition the 256 values.
    """
    if not 0 <= phi_th <= csd.MAX_PHI:
        raise ValueError(f"phi_th {phi_th} out of range")
    values = np.arange(INT8_MIN, INT8_MAX + 1, dtype=np.int64)
    counts = csd.phi(values)
    return values[counts == phi_th].astype(np.int64)


def nearest_in_table(values: np.ndarray, phi_th: int) -> np.ndarray:
    """Project each value to the closest element of T(φ_th).

    Ties resolve to the larger candidate (matching the paper's example
    where 0 projects to +1 under φ_th = 1); the rust mirror uses the same
    rule.
    """
    table = query_table(phi_th)
    v = np.asarray(values, dtype=np.int64)
    # searchsorted gives the insertion point; candidates are at idx-1, idx.
    idx = np.searchsorted(table, v)
    lo = np.clip(idx - 1, 0, len(table) - 1)
    hi = np.clip(idx, 0, len(table) - 1)
    dist_lo = np.abs(v - table[lo])
    dist_hi = np.abs(table[hi] - v)
    # Strict '<' keeps hi on ties => prefer the larger value.
    return np.where(dist_lo < dist_hi, table[lo], table[hi])


def filter_threshold(phis: np.ndarray, mask: np.ndarray) -> int:
    """Compute φ_th for one filter from its digit counts and prune mask.

    ``phis``: int array, non-zero digit count per weight.
    ``mask``: same shape; 0 marks weights removed by coarse pruning
    (excluded from the mode).
    """
    phis = np.asarray(phis).reshape(-1)
    mask = np.asarray(mask).reshape(-1)
    kept = phis[mask != 0]
    if kept.size == 0 or not np.any(phis):
        return 0
    counts = np.bincount(kept, minlength=csd.MAX_PHI + 1)
    # Mode; ties resolve to the smaller φ (np.argmax picks first max),
    # which biases toward sparsity. The rust mirror matches.
    mode = int(np.argmax(counts))
    if mode == 0:
        return 1
    return min(mode, 2)


def fta_filter(weights: np.ndarray, mask: np.ndarray) -> tuple[np.ndarray, int]:
    """Apply FTA to one filter (Alg. 1 body for a single i).

    Masked (coarse-pruned) weights stay exactly zero; every other weight
    — including naturally-zero unpruned weights — is re-projected into
    T(φ_th).

    Returns (approximated weights int64, φ_th).
    """
    w = np.asarray(weights, dtype=np.int64)
    m = np.asarray(mask) != 0
    phis = csd.phi(w) * m  # pruned weights contribute φ=0 and are excluded
    th = filter_threshold(csd.phi(w), m)
    if th == 0:
        return np.zeros_like(w), 0
    approx = nearest_in_table(w, th)
    return np.where(m, approx, 0), th


def fta_layer(weights: np.ndarray, mask: np.ndarray | None = None
              ) -> tuple[np.ndarray, np.ndarray]:
    """Apply FTA to a layer's weight matrix.

    Args:
      weights: int array [K, N] (im2col layout — column n is filter n).
      mask: optional [K, N] 0/1 array from coarse-grained pruning
        (1 = kept). Defaults to all-ones.

    Returns:
      (approximated weights [K, N] int64, thresholds [N] int64).
    """
    w = np.asarray(weights, dtype=np.int64)
    if w.ndim != 2:
        raise ValueError("fta_layer expects [K, N]")
    m = np.ones_like(w) if mask is None else np.asarray(mask, dtype=np.int64)
    if m.shape != w.shape:
        raise ValueError("mask shape mismatch")
    out = np.zeros_like(w)
    ths = np.zeros(w.shape[1], dtype=np.int64)
    for n in range(w.shape[1]):
        out[:, n], ths[n] = fta_filter(w[:, n], m[:, n])
    return out, ths


def bit_sparsity(weights: np.ndarray) -> float:
    """Fraction of zero CSD digits — the paper's bit-level sparsity."""
    return 1.0 - csd.nonzero_bit_fraction(weights, "csd")


def guaranteed_sparsity(thresholds: np.ndarray) -> float:
    """Minimum bit-level sparsity guaranteed by FTA thresholds.

    φ_th = 2 guarantees ≥ 75% (2 of 8 digit positions), φ_th = 1 ≥ 87.5%.
    The paper standardizes reporting at the 75% floor.
    """
    th = np.asarray(thresholds, dtype=np.float64)
    if th.size == 0:
        return 1.0
    return float(1.0 - th.mean() / csd.NUM_DIGITS)
