"""Canonical Signed Digit (CSD) encoding and dyadic-block decomposition.

CSD (Reitwiesner 1960) represents an integer with digits in {-1, 0, 1}
such that (1) the number of non-zero digits is minimal, (2) no two
adjacent digits are both non-zero, and (3) the representation is unique.
This is exactly the non-adjacent form (NAF).

DB-PIM partitions the 8 CSD digit positions of an INT8 value into four
*dyadic blocks* (bit pairs): DB#k covers positions (2k+1, 2k). Property
(2) guarantees each block holds at most one non-zero digit, so a block is
either the Zero pattern `00` or a Complementary pattern (one signed digit
at the even or odd position). A Comp. pattern maps onto the Q/Q-bar
cross-coupled pair of a single 6T SRAM cell.

All functions here are pure numpy (build-time only) and are mirrored
bit-exactly by ``rust/src/csd/``.
"""

from __future__ import annotations

import numpy as np

#: Number of CSD digit positions used for INT8 ([-128, 127] never needs
#: a digit above position 7 in NAF).
NUM_DIGITS = 8

#: Number of dyadic blocks per INT8 value.
NUM_BLOCKS = NUM_DIGITS // 2

#: Maximum possible non-zero digit count for an INT8 value (one per block).
MAX_PHI = NUM_BLOCKS


def to_csd(value: int) -> np.ndarray:
    """Encode a single integer in [-128, 127] as 8 NAF/CSD digits.

    Returns an int8 array ``d`` of shape (8,), LSB first, with
    ``value == sum(d[i] * 2**i)`` and ``d[i] in {-1, 0, 1}``.
    """
    if not -128 <= value <= 127:
        raise ValueError(f"value {value} out of INT8 range")
    x = int(value)
    digits = np.zeros(NUM_DIGITS, dtype=np.int8)
    i = 0
    while x != 0:
        if x & 1:
            # 2 - (x mod 4): +1 when x % 4 == 1, -1 when x % 4 == 3.
            d = 2 - (x & 3)
            x -= d
            digits[i] = d
        i += 1
        x >>= 1
    return digits


def to_csd_array(values: np.ndarray) -> np.ndarray:
    """Vectorized CSD encoding.

    Args:
      values: integer array, each element in [-128, 127].

    Returns:
      int8 array of shape ``values.shape + (8,)``, digits LSB first.
    """
    v = np.asarray(values)
    if v.size and (v.min() < -128 or v.max() > 127):
        raise ValueError("values out of INT8 range")
    x = v.astype(np.int64)
    out = np.zeros(v.shape + (NUM_DIGITS,), dtype=np.int8)
    for i in range(NUM_DIGITS):
        odd = (x & 1).astype(bool)
        d = np.where(odd, 2 - (x & 3), 0)
        x = (x - d) >> 1
        out[..., i] = d.astype(np.int8)
    assert not np.any(x), "residual after 8 CSD digits (value out of range?)"
    return out


def from_csd(digits: np.ndarray) -> np.ndarray:
    """Decode CSD digits (last axis, LSB first) back to integers."""
    d = np.asarray(digits, dtype=np.int64)
    weights = 1 << np.arange(d.shape[-1], dtype=np.int64)
    return np.tensordot(d, weights, axes=([-1], [0]))


def phi(values: np.ndarray) -> np.ndarray:
    """Non-zero CSD digit count per element (the paper's φ), in 0..4."""
    return np.count_nonzero(to_csd_array(values), axis=-1).astype(np.int32)


def is_nonadjacent(digits: np.ndarray) -> np.ndarray:
    """Check the NAF property: no two adjacent non-zero digits."""
    d = np.asarray(digits) != 0
    adj = d[..., :-1] & d[..., 1:]
    return ~np.any(adj, axis=-1)


def dyadic_blocks(values: np.ndarray) -> np.ndarray:
    """Decompose values into dyadic-block coefficients.

    Block k covers CSD positions (2k, 2k+1); its coefficient is
    ``d[2k] + 2 * d[2k+1]`` in {-2, -1, 0, 1, 2}, so

        value == sum_k coeff[k] << (2 * k).

    Returns int8 array of shape ``values.shape + (4,)``.
    """
    d = to_csd_array(values).astype(np.int8)
    even = d[..., 0::2]
    odd = d[..., 1::2]
    return (even + 2 * odd).astype(np.int8)


def from_dyadic_blocks(coeffs: np.ndarray) -> np.ndarray:
    """Inverse of :func:`dyadic_blocks`."""
    c = np.asarray(coeffs, dtype=np.int64)
    weights = 1 << (2 * np.arange(c.shape[-1], dtype=np.int64))
    return np.tensordot(c, weights, axes=([-1], [0]))


def block_metadata(value: int) -> list[dict]:
    """Per-value Comp. pattern metadata, as stored in the DB-PIM meta RF.

    Returns a list (one entry per non-zero dyadic block) of dicts with:
      ``index``  — block index 0..3 (the paper's 2-bit DB index),
      ``sign``   — 1 for a negative digit, 0 for positive,
      ``odd``    — True when the digit sits at the odd position of the
                   block (pattern ``10``/``T0``); this is the Q bit, and
                   Q-bar is its complement (pattern ``01``/``0T``).
    """
    coeffs = dyadic_blocks(np.asarray(value)).reshape(-1)
    meta = []
    for k, c in enumerate(coeffs):
        c = int(c)
        if c == 0:
            continue
        meta.append({
            "index": k,
            "sign": 1 if c < 0 else 0,
            "odd": abs(c) == 2,
        })
    return meta


def digit_planes(weight: np.ndarray) -> np.ndarray:
    """Dyadic digit planes for a weight matrix.

    Args:
      weight: int array of shape [K, N] with INT8 values.

    Returns:
      int8 array of shape [4, K, N] — plane ``d`` holds the dyadic-block
      coefficient for block ``d``, so
      ``weight == sum_d planes[d] << (2 * d)``. This is the layout the
      Pallas kernel (L1) consumes; the rust compiler produces the packed
      SRAM image from the same decomposition.
    """
    blocks = dyadic_blocks(weight)  # [K, N, 4]
    return np.moveaxis(blocks, -1, 0).astype(np.int8)


def nonzero_bit_fraction(values: np.ndarray, encoding: str = "csd") -> float:
    """Fraction of non-zero bits/digits over all 8-bit positions.

    ``encoding`` is ``"csd"`` (signed digits) or ``"binary"`` (two's
    complement bits). Used by the Fig. 3(a) analysis.
    """
    v = np.asarray(values)
    if encoding == "csd":
        nz = np.count_nonzero(to_csd_array(v))
    elif encoding == "binary":
        bits = (v.astype(np.int64) & 0xFF).astype(np.uint8)
        nz = int(np.unpackbits(bits[..., None], axis=-1).sum())
    else:
        raise ValueError(f"unknown encoding {encoding!r}")
    return nz / (v.size * NUM_DIGITS) if v.size else 0.0
