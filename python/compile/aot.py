"""AOT export: lower L2 graphs to HLO *text* + dump binary weight packs.

Interchange format is HLO text, NOT serialized HloModuleProto — jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  mininet.hlo.txt          golden MiniNet forward, FTA weights baked in
                           (input: int8 [B, C, H, W]; output: int32
                           logits in a 1-tuple)
  mininet_ref.hlo.txt      same graph via the jnp oracle (A/B check)
  tile_matmul.hlo.txt      golden dyadic tile matmul (x, planes) -> acc
  mininet_manifest.json    layer table: shapes, strides, requant muls,
                           FTA thresholds, class count, file offsets
  mininet_weights.bin      int8 [K, N] row-major weight matrices, concat
  mininet_masks.bin        u8 block masks [K, N/α] row-major, concat
  mininet_input.bin        fixed int8 input batch (B=2)
  mininet_golden.bin       int32 golden logits for that batch

Python runs once at build time; the rust binary is self-contained
afterwards. `make artifacts` is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # requant uses exact int64

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import pruning

TILE_M, TILE_K, TILE_N = 64, 128, 64
BATCH = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    `print_large_constants=True` is essential: the default printer elides
    big literals as `{...}`, which the 0.5.1 HLO text parser then
    silently mis-reads — baked weights would execute as garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)


def export_mininet(out_dir: str, seed: int = 0, value_sparsity: float = 0.6) -> None:
    spec = model_lib.MiniNetSpec()
    params = model_lib.synthesize_weights(spec, seed=seed,
                                          value_sparsity=value_sparsity)

    # --- golden HLO graphs -------------------------------------------------
    x_spec = jax.ShapeDtypeStruct((BATCH, spec.input_ch, spec.input_hw,
                                   spec.input_hw), jnp.int8)
    for fname, use_kernel in (("mininet.hlo.txt", True),
                              ("mininet_ref.hlo.txt", False)):
        fn = model_lib.make_golden_fn(params, spec, use_kernel=use_kernel)
        text = to_hlo_text(jax.jit(fn).lower(x_spec))
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)

    tile_fn = model_lib.make_tile_matmul_fn(TILE_M, TILE_K, TILE_N)
    tile_text = to_hlo_text(jax.jit(tile_fn).lower(
        jax.ShapeDtypeStruct((TILE_M, TILE_K), jnp.int8),
        jax.ShapeDtypeStruct((4, TILE_K, TILE_N), jnp.int8)))
    with open(os.path.join(out_dir, "tile_matmul.hlo.txt"), "w") as f:
        f.write(tile_text)

    # --- binary weight pack + manifest ------------------------------------
    weights = bytearray()
    masks = bytearray()
    layers = []
    order = [c.name for c in spec.convs] + ["fc"]
    for name in order:
        p = params[name]
        w = np.asarray(p["w"], dtype=np.int8)  # [K, N]
        m = np.asarray(p["mask"], dtype=np.uint8)  # [K, N/α]
        c = p["spec"]
        layers.append({
            "name": name,
            "kind": "conv" if c is not None else "fc",
            "k": int(w.shape[0]), "n": int(w.shape[1]),
            "weight_offset": len(weights), "mask_offset": len(masks),
            "requant_mul": int(p["mul"]),
            "thresholds": [int(t) for t in np.asarray(p["th"]).reshape(-1)],
            "conv": None if c is None else {
                "out_ch": c.out_ch, "in_ch": c.in_ch, "kernel": c.kernel,
                "stride": c.stride, "pad": c.pad, "pool": bool(c.pool),
            },
        })
        weights += w.tobytes()
        masks += m.tobytes()

    # --- fixed verification batch ------------------------------------------
    rng = np.random.default_rng(1234)
    x = rng.integers(0, 96, size=(BATCH, spec.input_ch, spec.input_hw,
                                  spec.input_hw), dtype=np.int8)
    golden = np.asarray(model_lib.forward(params, jnp.asarray(x), spec,
                                          use_kernel=False), dtype=np.int32)
    kernel_out = np.asarray(model_lib.forward(params, jnp.asarray(x), spec,
                                              use_kernel=True), dtype=np.int32)
    assert np.array_equal(golden, kernel_out), \
        "Pallas kernel path diverged from the jnp oracle"

    manifest = {
        "version": 1,
        "alpha": pruning.ALPHA,
        "input": {"batch": BATCH, "ch": spec.input_ch, "hw": spec.input_hw},
        "num_classes": spec.num_classes,
        "value_sparsity": value_sparsity,
        "seed": seed,
        "layers": layers,
        "files": {
            "weights": "mininet_weights.bin",
            "masks": "mininet_masks.bin",
            "input": "mininet_input.bin",
            "golden": "mininet_golden.bin",
            "hlo": "mininet.hlo.txt",
            "tile_hlo": "tile_matmul.hlo.txt",
        },
        "tile": {"m": TILE_M, "k": TILE_K, "n": TILE_N},
    }
    with open(os.path.join(out_dir, "mininet_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    for fname, blob in (("mininet_weights.bin", bytes(weights)),
                        ("mininet_masks.bin", bytes(masks)),
                        ("mininet_input.bin", x.tobytes()),
                        ("mininet_golden.bin", golden.tobytes())):
        with open(os.path.join(out_dir, fname), "wb") as f:
            f.write(blob)
    print(f"exported {len(layers)} layers, {len(weights)} weight bytes, "
          f"golden logits {golden.shape} -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--value-sparsity", type=float, default=0.6)
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    export_mininet(out_dir, seed=args.seed, value_sparsity=args.value_sparsity)


if __name__ == "__main__":
    main()
