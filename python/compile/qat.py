"""FTA-aware Quantization-Aware Training (QAT).

Implements the paper's training recipe (Sec. III / VI-A):

* INT8 symmetric fake-quantization of weights and activations with
  **dynamic min-max ranges smoothed by an exponential moving average**
  (EMA) — no precomputed global ranges, no trainable range parameters.
* **Straight-through estimator** (STE) gradients through the quantizer
  and through the FTA projection.
* The **FTA projection is applied inside the training loop** (each
  optimization step here; the paper says each epoch) so the optimizer
  sees the accuracy impact of the fixed-threshold constraint.
* Coarse-grained block-pruned weights are pinned to zero throughout
  fine-tuning.

Everything is pure JAX + a hand-rolled AdamW (optax is not available in
the build image). Build-time only — never on the rust request path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import csd, fta, pruning

INT8_MAX = 127.0


# --------------------------------------------------------------------------
# Fake quantization with STE
# --------------------------------------------------------------------------

@jax.custom_vjp
def ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def quantize_symmetric(x, scale):
    """Fake-quantize to INT8 with STE: x -> round(x / s).clip * s."""
    q = ste_round(x / scale)
    q = jnp.clip(q, -128.0, INT8_MAX)
    return q * scale


def amax_scale(x) -> jnp.ndarray:
    """Symmetric min-max scale: amax / 127 (ε-guarded)."""
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / INT8_MAX


@dataclasses.dataclass
class EmaRange:
    """EMA-smoothed absolute-max range tracker for activations."""
    decay: float = 0.99

    def init(self) -> jnp.ndarray:
        return jnp.array(0.0, dtype=jnp.float32)

    def update(self, state, x):
        amax = jnp.max(jnp.abs(x))
        new = jnp.where(state == 0.0, amax, self.decay * state + (1 - self.decay) * amax)
        return new

    def scale(self, state) -> jnp.ndarray:
        return jnp.maximum(state, 1e-8) / INT8_MAX


# --------------------------------------------------------------------------
# FTA projection inside the loop (non-differentiable; applied to the
# *quantized integer* weights, with STE back to the float master copy)
# --------------------------------------------------------------------------

def fta_project_int(w_int: np.ndarray, mask: np.ndarray | None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Project an integer [K, N] weight matrix to FTA-compliant values.

    Pure numpy (runs on host between jitted steps, like the paper's
    per-epoch application). Returns (projected ints, thresholds [N]).
    """
    return fta.fta_layer(w_int, mask)


def apply_fta_to_params(params: dict, masks: dict, alpha: int = pruning.ALPHA,
                        enable: bool = True) -> tuple[dict, dict]:
    """Project every conv/dense kernel in ``params`` to FTA-compliant
    fake-quantized values; biases are untouched.

    ``masks`` maps parameter name -> block mask ([K, G] uint8) or None.
    Returns (new params, thresholds per layer).
    """
    new = dict(params)
    thresholds = {}
    for name, w in params.items():
        if not name.endswith("w"):
            continue
        wk = np.asarray(w)
        k2 = wk.reshape(-1, wk.shape[-1])  # [K, N] im2col layout
        scale = float(np.maximum(np.abs(k2).max(), 1e-8) / INT8_MAX)
        w_int = np.clip(np.round(k2 / scale), -128, 127).astype(np.int64)
        bmask = masks.get(name)
        wmask = None if bmask is None else pruning.expand_mask(bmask, alpha)
        if enable:
            w_fta, th = fta_project_int(w_int, wmask)
        else:
            w_fta = w_int if wmask is None else w_int * wmask
            th = csd.phi(w_fta).max(axis=0) if w_fta.size else np.zeros(0)
        thresholds[name] = th
        new[name] = jnp.asarray((w_fta * scale).reshape(wk.shape),
                                dtype=jnp.float32)
    return new, thresholds


# --------------------------------------------------------------------------
# Hand-rolled AdamW
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4

    def init(self, params):
        zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
        return {"m": zeros(params), "v": zeros(params), "t": jnp.array(0, jnp.int32)}

    def update(self, grads, state, params, lr_scale=1.0):
        t = state["t"] + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
        vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
        lr = self.lr * lr_scale
        new_params = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + self.eps)
                                        + self.weight_decay * p),
            params, mhat, vhat)
        return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total_steps, base=1.0, floor=1e-4, warmup=0.02):
    """Cosine annealing with linear warmup, as a multiplier of base lr."""
    warm_steps = jnp.maximum(1, jnp.asarray(total_steps * warmup, jnp.float32))
    warm = step / warm_steps
    progress = jnp.clip((step - warm_steps) / jnp.maximum(1.0, total_steps - warm_steps), 0.0, 1.0)
    cos = floor + (base - floor) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warm_steps, base * warm, cos)


# --------------------------------------------------------------------------
# Masked-gradient helper: pinned zeros stay zero through fine-tuning
# --------------------------------------------------------------------------

def apply_weight_masks(params: dict, masks: dict, alpha: int = pruning.ALPHA) -> dict:
    out = dict(params)
    for name, bmask in masks.items():
        if bmask is None or name not in params:
            continue
        w = params[name]
        k2 = pruning.expand_mask(np.asarray(bmask), alpha).astype(np.float32)
        out[name] = w * jnp.asarray(k2.reshape((-1,) + (w.shape[-1],)).reshape(w.shape))
    return out


def build_masks(params: dict, sparsity: float, alpha: int = pruning.ALPHA) -> dict:
    """Coarse-grained block-wise pruning masks for every kernel param."""
    masks = {}
    for name, w in params.items():
        if not name.endswith("w"):
            continue
        k2 = np.asarray(w).reshape(-1, w.shape[-1])
        if k2.shape[1] % alpha or sparsity <= 0.0:
            masks[name] = None
            continue
        _, bmask = pruning.prune_blocks(k2, sparsity, alpha)
        masks[name] = bmask
    return masks
