"""Pure-jnp oracles for the DB-PIM compute path.

These are the correctness references that the Pallas kernels (L1), the
exported HLO graphs (L2), and — through the exported golden artifacts —
the rust cycle-accurate simulator (L3) are all validated against.

All arithmetic is exact integer math (INT8 operands, INT32 accumulation,
INT64 requantization), so every layer of the stack can be compared
bit-exactly. The requantization scheme is the fixed-point multiplier
form shared with ``rust/src/quant/``:

    out = clamp( (acc * mul + (1 << (shift-1))) >> shift , -128, 127)

with ``mul`` an i32 and ``shift = 16`` (rounds half toward +inf — the
same rule on both sides).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

REQUANT_SHIFT = 16


def int8_matmul(x, w):
    """Exact INT8 x INT8 -> INT32 matmul. x: [M, K] int8-valued, w: [K, N]."""
    return jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def dyadic_matmul(x, planes):
    """Reference for the dyadic-block (CSD digit-plane) matmul.

    planes: [4, K, N] int8, coefficient of dyadic block d in {-2..2};
    result == int8_matmul(x, sum_d planes[d] << 2d).
    """
    acc = jnp.zeros((x.shape[0], planes.shape[2]), jnp.int32)
    for d in range(planes.shape[0]):
        part = jnp.dot(x.astype(jnp.int32), planes[d].astype(jnp.int32),
                       preferred_element_type=jnp.int32)
        acc = acc + (part << (2 * d))
    return acc


def bitserial_matmul(x, w):
    """Reference for the input-bit-serial dataflow of digital SRAM-PIM.

    Inputs are processed one bit-plane at a time (the macro broadcasts
    one input bit column per cycle); bit 7 of a signed INT8 input has
    weight -2^7. result == int8_matmul(x, w).
    """
    xi = x.astype(jnp.int32)
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for b in range(8):
        bit = (xi >> b) & 1
        sign = -1 if b == 7 else 1
        part = jnp.dot(bit, w.astype(jnp.int32),
                       preferred_element_type=jnp.int32)
        acc = acc + sign * (part << b)
    return acc


def requant_mul_shift(scale_ratio: float) -> int:
    """Fixed-point multiplier for a float requant ratio (shift = 16)."""
    mul = int(round(scale_ratio * (1 << REQUANT_SHIFT)))
    if not 0 <= mul < 2 ** 31:
        raise ValueError(f"requant ratio {scale_ratio} out of range")
    return mul


def requantize(acc, mul: int, shift: int = REQUANT_SHIFT):
    """INT32 accumulator -> INT8 output, exact fixed-point semantics."""
    wide = acc.astype(jnp.int64) * jnp.int64(mul)
    rounded = (wide + (jnp.int64(1) << (shift - 1))) >> shift
    return jnp.clip(rounded, -128, 127).astype(jnp.int32)


def im2col(x, kh: int, kw: int, stride: int = 1, pad: int = 0):
    """Unfold NCHW activations into matmul rows.

    x: [N, C, H, W] -> [N * OH * OW, C * kh * kw]; column order is
    (c, kh, kw) row-major, matching ``rust/src/tensor/``.
    """
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, :, i:i + stride * oh:stride, j:j + stride * ow:stride]
            cols.append(patch)  # [N, C, OH, OW]
    stack = jnp.stack(cols, axis=2)  # [N, C, KH*KW, OH, OW]
    stack = stack.transpose(0, 3, 4, 1, 2)  # [N, OH, OW, C, KHKW]
    return stack.reshape(n * oh * ow, c * kh * kw), (n, oh, ow)


def conv2d_int8(x, w, stride: int = 1, pad: int = 0):
    """Exact INT8 conv via im2col. x: [N,C,H,W], w: [O,C,KH,KW] -> int32
    [N,O,OH,OW]."""
    o, c, kh, kw = w.shape
    cols, (n, oh, ow) = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(o, c * kh * kw).T  # [CKK, O]
    out = int8_matmul(cols, wmat)  # [N*OH*OW, O]
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


def relu(x):
    return jnp.maximum(x, 0)


def maxpool2x2(x):
    """2x2/2 max pool on [N, C, H, W] integers."""
    n, c, h, w = x.shape
    xr = x.reshape(n, c, h // 2, 2, w // 2, 2)
    return xr.max(axis=(3, 5))


def avgpool_global(x):
    """Global average pool with floor division (integer semantics)."""
    n, c, h, w = x.shape
    s = x.astype(jnp.int32).sum(axis=(2, 3))
    return s // (h * w)


def numpy_int8_matmul(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Host-side exact reference (used by pytest without tracing)."""
    return x.astype(np.int64) @ w.astype(np.int64)
