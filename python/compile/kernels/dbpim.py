"""Layer-1 Pallas kernels: the DB-PIM macro compute hot-spot.

Hardware adaptation (paper 28nm SRAM macro -> TPU-style tiling): the
macro's 16-compartment x 16-DBMU grid with Tk2 = 16 sequential rows
becomes a Pallas BlockSpec tile — the (M, N) output tile lives in VMEM
(the macro's accumulator registers), the K dimension is the grid's inner
loop (the macro's compartment/row traversal), and the four dyadic-block
digit planes play the role of the Comp.-pattern columns: the weight
tensor is stored *decomposed* (planes[d] in {-2..2}) and the result is
reassembled by the CSD adder-tree semantics ``sum_d (x @ P_d) << 2d``.
The bit-serial kernel models the macro's input dataflow (one input bit
column per cycle, IPU-style zero-column skipping is a runtime decision
and lives in the rust simulator).

Kernels are lowered with ``interpret=True``: real-TPU Pallas emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; interpret mode
lowers to plain HLO ops with identical numerics (see DESIGN.md §8 for
the VMEM/MXU analysis used in place of TPU wallclock).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile geometry. Chosen so one (TM, TN) int32 accumulator tile +
# one (TM, TK) int8 input tile + four (TK, TN) int8 digit planes stay
# well under VMEM (~0.3 MiB at these sizes; see DESIGN.md §8).
TILE_M = 64
TILE_N = 64
TILE_K = 128

NUM_PLANES = 4
NUM_BITS = 8


def _pick(tile: int, dim: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``tile``."""
    t = min(tile, dim)
    while dim % t:
        t -= 1
    return t


def _dyadic_kernel(x_ref, p_ref, o_ref):
    """One grid step: accumulate the four shifted plane matmuls.

    x_ref: [TM, TK] int8 input tile (one compartment-group of rows).
    p_ref: [4, TK, TN] int8 dyadic digit planes (the Comp.-pattern
           contents of the macro columns for this K-slice).
    o_ref: [TM, TN] int32 accumulator tile (PPU accumulator registers).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    acc = o_ref[...]
    # CSD adder tree: each dyadic block contributes its partial product
    # shifted by 2*d. Unrolled — four MXU-shaped matmuls per step.
    for d in range(NUM_PLANES):
        part = jnp.dot(x, p_ref[d].astype(jnp.int32),
                       preferred_element_type=jnp.int32)
        acc = acc + (part << (2 * d))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def dyadic_matmul(x, planes, *, tm=TILE_M, tn=TILE_N, tk=TILE_K):
    """DB-PIM dyadic-block matmul.

    Args:
      x: [M, K] int8 inputs.
      planes: [4, K, N] int8 dyadic-block coefficient planes; the logical
        weight is ``sum_d planes[d] << 2d``.

    Returns:
      [M, N] int32 — bit-exact vs ``ref.int8_matmul(x, w)``.
    """
    m, k = x.shape
    _, k2, n = planes.shape
    assert k == k2, (k, k2)
    tm, tn, tk = _pick(tm, m), _pick(tn, n), _pick(tk, k)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _dyadic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((NUM_PLANES, tk, tn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x, planes)


def _bitserial_kernel(xb_ref, w_ref, o_ref):
    """One grid step of the input-bit-serial dataflow.

    xb_ref: [8, TM, TK] int8 input bit planes (bit b of every input).
    w_ref:  [TK, TN] int8 weights.
    o_ref:  [TM, TN] int32 accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.int32)
    acc = o_ref[...]
    # Bit-serial: the macro broadcasts one input bit column per cycle;
    # shift&add in the PPU. Bit 7 carries the two's-complement sign.
    for b in range(NUM_BITS):
        part = jnp.dot(xb_ref[b].astype(jnp.int32), w,
                       preferred_element_type=jnp.int32)
        signed = jnp.where(b == NUM_BITS - 1, -part, part)
        acc = acc + (signed << b)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def bitserial_matmul(x, w, *, tm=TILE_M, tn=TILE_N, tk=TILE_K):
    """Digital-PIM bit-serial matmul (dense baseline dataflow).

    x: [M, K] int8, w: [K, N] int8 -> [M, N] int32, bit-exact vs
    ``ref.int8_matmul``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    xi = x.astype(jnp.int32)
    bits = jnp.stack([(xi >> b) & 1 for b in range(NUM_BITS)]).astype(jnp.int8)
    tm, tn, tk = _pick(tm, m), _pick(tn, n), _pick(tk, k)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _bitserial_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((NUM_BITS, tm, tk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(bits, w)


def vmem_bytes(tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K) -> int:
    """Static VMEM footprint estimate for one dyadic grid step."""
    x = tm * tk            # int8 input tile
    p = NUM_PLANES * tk * tn  # int8 planes
    o = 4 * tm * tn        # int32 accumulator
    return x + p + o
