"""Layer-2 JAX model: quantized CNN forward built on the L1 kernels.

The network here ("MiniNet") is the end-to-end verification workload: a
small INT8 CNN whose weights go through the full DB-PIM pipeline (coarse
block pruning -> FTA projection -> dyadic-block decomposition). Its
forward pass calls the Pallas dyadic kernel for every conv/FC layer, so
the AOT-lowered HLO exercises the exact compute the rust simulator
models; the rust e2e example compares the simulator's integer outputs
against this graph bit-for-bit.

All layer arithmetic is integer-exact (see kernels/ref.py for the shared
requantization semantics).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dbpim, ref
from . import csd, fta, pruning


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One conv layer: INT8 weights [O, C, KH, KW], stride/pad, requant."""
    name: str
    out_ch: int
    in_ch: int
    kernel: int
    stride: int = 1
    pad: int = 1
    pool: bool = False  # 2x2 max pool after ReLU


@dataclasses.dataclass(frozen=True)
class MiniNetSpec:
    """The e2e verification CNN (channels are multiples of α = 8)."""
    input_hw: int = 16
    input_ch: int = 8
    num_classes: int = 16
    convs: tuple = (
        ConvSpec("conv1", 16, 8, 3, pool=True),
        ConvSpec("conv2", 32, 16, 3, pool=True),
        ConvSpec("conv3", 32, 32, 3),
    )

    @property
    def fc_in(self) -> int:
        hw = self.input_hw
        for c in self.convs:
            hw = hw // c.stride
            if c.pool:
                hw //= 2
        return self.convs[-1].out_ch * hw * hw


def synthesize_weights(spec: MiniNetSpec, seed: int = 0,
                       value_sparsity: float = 0.6,
                       apply_fta: bool = True) -> dict:
    """Generate FTA-compliant INT8 weights + requant multipliers.

    Weights are drawn from a clipped Gaussian (trained-CNN-like
    distribution), block-pruned at ``value_sparsity``, then FTA-projected
    — the exact offline pipeline the rust compiler consumes.

    Returns a dict: name -> {"w": int8 [O,C,KH,KW] or [K,N] for fc,
    "mask": block mask, "th": per-filter φ_th, "mul": requant
    multiplier}.
    """
    rng = np.random.default_rng(seed)
    params = {}
    for c in spec.convs:
        k = c.in_ch * c.kernel * c.kernel
        w = np.clip(rng.normal(0.0, 24.0, size=(k, c.out_ch)), -127, 127)
        w = np.round(w).astype(np.int64)
        pruned, mask = pruning.prune_blocks(w, value_sparsity)
        if apply_fta:
            wq, th = fta.fta_layer(pruned, pruning.expand_mask(mask))
        else:
            wq, th = pruned, csd.phi(pruned).max(axis=0)
        # Requant multiplier keeps activations in INT8 range: scale by
        # ~1/(sqrt(K) * sigma) in fixed point.
        mul = ref.requant_mul_shift(1.0 / (np.sqrt(k) * 24.0 * 0.25))
        params[c.name] = {
            "w": wq.reshape(k, c.out_ch).astype(np.int8),
            "mask": mask, "th": th.astype(np.int8), "mul": mul,
            "spec": c,
        }
    # FC layer; num_classes may not be a multiple of α — pad filters up.
    kfc = spec.fc_in
    ncls = spec.num_classes
    npad = ((ncls + pruning.ALPHA - 1) // pruning.ALPHA) * pruning.ALPHA
    w = np.round(np.clip(rng.normal(0.0, 24.0, size=(kfc, npad)), -127, 127)).astype(np.int64)
    pruned, mask = pruning.prune_blocks(w, value_sparsity)
    if apply_fta:
        wq, th = fta.fta_layer(pruned, pruning.expand_mask(mask))
    else:
        wq, th = pruned, csd.phi(pruned).max(axis=0)
    params["fc"] = {
        "w": wq.astype(np.int8), "mask": mask, "th": th.astype(np.int8),
        "mul": ref.requant_mul_shift(1.0 / (np.sqrt(kfc) * 24.0 * 0.25)),
        "spec": None, "classes": ncls,
    }
    return params


def _conv_layer(x, w_planes, mul, c: ConvSpec, use_kernel: bool):
    """INT8 conv -> requant -> ReLU (-> pool) with exact integer math."""
    cols, (n, oh, ow) = ref.im2col(x, c.kernel, c.kernel, c.stride, c.pad)
    if use_kernel:
        acc = dbpim.dyadic_matmul(cols.astype(jnp.int8), w_planes)
    else:
        w = sum((w_planes[d].astype(jnp.int32) << (2 * d)) for d in range(4))
        acc = ref.int8_matmul(cols, w)
    out = ref.requantize(acc, mul)
    out = ref.relu(out)
    out = out.reshape(n, oh, ow, -1).transpose(0, 3, 1, 2)
    if c.pool:
        out = ref.maxpool2x2(out)
    return out


def forward(params: dict, x, spec: MiniNetSpec, use_kernel: bool = True):
    """MiniNet forward: x int8 [N, C, H, W] -> int32 logits [N, classes].

    ``use_kernel=True`` routes every matmul through the L1 Pallas dyadic
    kernel; ``False`` uses the jnp oracle (for A/B testing the lowering).
    """
    h = x
    for c in spec.convs:
        p = params[c.name]
        planes = jnp.asarray(csd.digit_planes(np.asarray(p["w"], dtype=np.int64)))
        h = _conv_layer(h, planes, p["mul"], c, use_kernel)
    n = h.shape[0]
    flat = h.transpose(0, 2, 3, 1).reshape(n, -1)  # match rust (HWC) layout
    pfc = params["fc"]
    planes = jnp.asarray(csd.digit_planes(np.asarray(pfc["w"], dtype=np.int64)))
    if use_kernel:
        acc = dbpim.dyadic_matmul(flat.astype(jnp.int8), planes)
    else:
        w = sum((planes[d].astype(jnp.int32) << (2 * d)) for d in range(4))
        acc = ref.int8_matmul(flat, w)
    return acc[:, :pfc["classes"]]


def make_golden_fn(params: dict, spec: MiniNetSpec, use_kernel: bool = True):
    """Close over weights so the AOT graph takes only the activation.

    The exported HLO then has the FTA weights baked in as constants —
    the rust side feeds an input batch and compares raw logits.
    """
    def fn(x):
        return (forward(params, x, spec, use_kernel),)
    return fn


def make_tile_matmul_fn(m: int, k: int, n: int):
    """Golden tile graph: (x int8 [m,k], planes int8 [4,k,n]) -> int32.

    Used by the rust runtime to verify individual simulator tiles via
    PJRT without re-deriving weights.
    """
    def fn(x, planes):
        return (dbpim.dyadic_matmul(x, planes),)
    return fn
