"""Ensure the build-time package root (python/) is importable in tests."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
