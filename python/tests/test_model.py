"""L2 model tests: MiniNet forward, kernel-vs-oracle equality, pipeline."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import csd, fta, model as model_lib, pruning
from compile.kernels import ref


@pytest.fixture(scope="module")
def spec():
    return model_lib.MiniNetSpec()


@pytest.fixture(scope="module")
def params(spec):
    return model_lib.synthesize_weights(spec, seed=0, value_sparsity=0.6)


@pytest.fixture(scope="module")
def batch(spec):
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.integers(0, 96, size=(2, spec.input_ch,
                                                 spec.input_hw,
                                                 spec.input_hw)), jnp.int8)


def test_fc_in_dimension(spec):
    # 16x16 -> pool -> 8x8 -> pool -> 4x4, 32 channels
    assert spec.fc_in == 32 * 4 * 4


def test_weights_are_fta_compliant(params):
    for name, p in params.items():
        w = np.asarray(p["w"], dtype=np.int64)
        th = np.asarray(p["th"])
        mask = pruning.expand_mask(np.asarray(p["mask"]))
        for n in range(w.shape[1]):
            kept = w[mask[:, n] != 0, n]
            if th[n] == 0:
                np.testing.assert_array_equal(w[:, n], 0)
            else:
                np.testing.assert_array_equal(
                    csd.phi(kept), np.full(len(kept), th[n]),
                    err_msg=f"{name} filter {n}")


def test_weights_respect_value_sparsity(params):
    for name, p in params.items():
        assert pruning.mask_sparsity(np.asarray(p["mask"])) == pytest.approx(0.6, abs=0.02), name


def test_forward_shapes(params, spec, batch):
    logits = model_lib.forward(params, batch, spec, use_kernel=False)
    assert logits.shape == (2, spec.num_classes)
    assert logits.dtype == jnp.int32


def test_kernel_path_bit_exact_vs_oracle(params, spec, batch):
    """The single most important L2 check: Pallas == jnp oracle."""
    a = np.asarray(model_lib.forward(params, batch, spec, use_kernel=True))
    b = np.asarray(model_lib.forward(params, batch, spec, use_kernel=False))
    np.testing.assert_array_equal(a, b)


def test_forward_deterministic(params, spec, batch):
    a = np.asarray(model_lib.forward(params, batch, spec, use_kernel=False))
    b = np.asarray(model_lib.forward(params, batch, spec, use_kernel=False))
    np.testing.assert_array_equal(a, b)


def test_im2col_matches_direct_conv():
    """conv2d_int8 (im2col path) equals lax-style dense conv."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-64, 64, (1, 4, 8, 8)), jnp.int8)
    w = jnp.asarray(rng.integers(-64, 64, (8, 4, 3, 3)), jnp.int8)
    out = ref.conv2d_int8(x, w, stride=1, pad=1)
    # brute-force conv
    xp = np.pad(np.asarray(x, np.int64), ((0, 0), (0, 0), (1, 1), (1, 1)))
    wn = np.asarray(w, np.int64)
    expect = np.zeros((1, 8, 8, 8), np.int64)
    for o in range(8):
        for i in range(8):
            for j in range(8):
                patch = xp[0, :, i:i + 3, j:j + 3]
                expect[0, o, i, j] = (patch * wn[o]).sum()
    np.testing.assert_array_equal(np.asarray(out), expect)


def test_maxpool_and_avgpool():
    x = jnp.arange(16, dtype=jnp.int32).reshape(1, 1, 4, 4)
    pooled = ref.maxpool2x2(x)
    np.testing.assert_array_equal(np.asarray(pooled)[0, 0], [[5, 7], [13, 15]])
    avg = ref.avgpool_global(x)
    assert int(avg[0, 0]) == (15 * 16 // 2) // 16


def test_mininet_without_fta_differs(spec, batch):
    """FTA projection changes weights, so logits differ from the
    unprojected model (sanity that FTA actually ran)."""
    p_fta = model_lib.synthesize_weights(spec, seed=0, apply_fta=True)
    p_raw = model_lib.synthesize_weights(spec, seed=0, apply_fta=False)
    a = np.asarray(model_lib.forward(p_fta, batch, spec, use_kernel=False))
    b = np.asarray(model_lib.forward(p_raw, batch, spec, use_kernel=False))
    assert not np.array_equal(a, b)


def test_fta_approximation_error_small(spec):
    """FTA projection moves each weight by a bounded distance (projection
    onto the φ_th set is the nearest point)."""
    p_fta = model_lib.synthesize_weights(spec, seed=0, apply_fta=True)
    p_raw = model_lib.synthesize_weights(spec, seed=0, apply_fta=False)
    for name in p_fta:
        a = np.asarray(p_fta[name]["w"], np.int64)
        b = np.asarray(p_raw[name]["w"], np.int64)
        err = np.abs(a - b)
        assert err.max() <= 22  # worst-case gap to T(1) within INT8
        assert np.mean(err) < 4.0
