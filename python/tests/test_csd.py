"""CSD codec tests: exhaustive over INT8 plus hypothesis sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import csd

ALL_INT8 = np.arange(-128, 128, dtype=np.int64)


def test_roundtrip_exhaustive():
    digits = csd.to_csd_array(ALL_INT8)
    back = csd.from_csd(digits)
    np.testing.assert_array_equal(back, ALL_INT8)


def test_digits_are_ternary():
    digits = csd.to_csd_array(ALL_INT8)
    assert set(np.unique(digits)) <= {-1, 0, 1}


def test_nonadjacent_property_exhaustive():
    """CSD property 2: no two adjacent non-zero digits."""
    digits = csd.to_csd_array(ALL_INT8)
    assert bool(np.all(csd.is_nonadjacent(digits)))


def test_minimality_vs_binary():
    """CSD property 1: never more non-zero digits than plain binary."""
    digits = csd.to_csd_array(ALL_INT8)
    csd_nz = np.count_nonzero(digits, axis=-1)
    for v, nz in zip(ALL_INT8, csd_nz):
        bin_nz = bin(int(v) & 0xFF).count("1")
        assert nz <= max(bin_nz, 1) + 1  # loose sanity
    # average reduction ~33% claimed by the paper for random data
    assert csd_nz.mean() < 3.0


def test_scalar_matches_vector():
    for v in (-128, -67, -1, 0, 1, 67, 85, 127):
        np.testing.assert_array_equal(csd.to_csd(v),
                                      csd.to_csd_array(np.asarray(v)))


def test_paper_example_67():
    """Tab. I: 67 = 0100_0101-bar-at-0 -> digits at 6 (+), 2 (+), 0 (-)."""
    d = csd.to_csd(67)
    assert int(csd.from_csd(d)) == 67
    nz = {i: int(d[i]) for i in range(8) if d[i]}
    assert nz == {0: -1, 2: 1, 6: 1}


def test_paper_example_neg67():
    d = csd.to_csd(-67)
    nz = {i: int(d[i]) for i in range(8) if d[i]}
    assert nz == {0: 1, 2: -1, 6: -1}


def test_phi_range():
    phis = csd.phi(ALL_INT8)
    assert phis.min() == 0 and phis.max() == csd.MAX_PHI
    assert phis[128] == 0  # value 0
    assert int(csd.phi(np.asarray(85))) == 4  # alternating 01010101


def test_dyadic_block_at_most_one_digit():
    """Each dyadic block is Zero or Comp. pattern — never two digits."""
    digits = csd.to_csd_array(ALL_INT8).reshape(256, 4, 2)
    per_block = np.count_nonzero(digits, axis=-1)
    assert per_block.max() <= 1


def test_dyadic_roundtrip_exhaustive():
    coeffs = csd.dyadic_blocks(ALL_INT8)
    assert set(np.unique(coeffs)) <= {-2, -1, 0, 1, 2}
    np.testing.assert_array_equal(csd.from_dyadic_blocks(coeffs), ALL_INT8)


def test_digit_planes_reconstruct():
    rng = np.random.default_rng(0)
    w = rng.integers(-128, 128, size=(24, 16), dtype=np.int64)
    planes = csd.digit_planes(w)
    assert planes.shape == (4, 24, 16)
    recon = sum(planes[d].astype(np.int64) << (2 * d) for d in range(4))
    np.testing.assert_array_equal(recon, w)


def test_block_metadata_paper_example():
    """f(0) = -64 (CSD 0T00_0000): one Comp. block at index 3, negative."""
    meta = csd.block_metadata(-64)
    assert meta == [{"index": 3, "sign": 1, "odd": False}]
    # value 2 = block 0, odd position within block, positive
    assert csd.block_metadata(2) == [{"index": 0, "sign": 0, "odd": True}]


def test_metadata_count_equals_phi():
    for v in ALL_INT8:
        assert len(csd.block_metadata(int(v))) == int(csd.phi(np.asarray(v)))


def test_nonzero_bit_fraction_csd_leq_binary_on_average():
    rng = np.random.default_rng(1)
    w = rng.integers(-128, 128, size=4096, dtype=np.int64)
    f_csd = csd.nonzero_bit_fraction(w, "csd")
    f_bin = csd.nonzero_bit_fraction(w, "binary")
    assert f_csd < f_bin
    # Reitwiesner's asymptotic density is 1/3 non-zero digits.
    assert abs(f_csd - 1 / 3) < 0.03


@given(st.lists(st.integers(min_value=-128, max_value=127), min_size=1,
                max_size=256))
@settings(max_examples=200, deadline=None)
def test_roundtrip_hypothesis(values):
    arr = np.asarray(values, dtype=np.int64)
    np.testing.assert_array_equal(csd.from_csd(csd.to_csd_array(arr)), arr)
    np.testing.assert_array_equal(
        csd.from_dyadic_blocks(csd.dyadic_blocks(arr)), arr)


@given(st.integers(min_value=-128, max_value=127))
@settings(max_examples=256, deadline=None)
def test_out_of_range_guard(v):
    csd.to_csd(v)  # never raises in range
    with pytest.raises(ValueError):
        csd.to_csd(200)
    with pytest.raises(ValueError):
        csd.to_csd(-200)
