"""Coarse-grained block-wise pruning tests."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import pruning


def test_block_l2_shape_and_values():
    w = np.asarray([[3.0] * 8 + [4.0] * 8])
    norms = pruning.block_l2(w, alpha=8)
    assert norms.shape == (1, 2)
    np.testing.assert_allclose(norms[0], [np.sqrt(9 * 8), np.sqrt(16 * 8)])


def test_block_l2_rejects_misaligned():
    with pytest.raises(ValueError):
        pruning.block_l2(np.zeros((4, 12)), alpha=8)


def test_prune_exact_fraction():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(32, 64))
    _, mask = pruning.prune_blocks(w, 0.5)
    assert mask.shape == (32, 8)
    assert mask.sum() == mask.size // 2


def test_prune_removes_lowest_norm_blocks():
    w = np.zeros((2, 16))
    w[0, :8] = 10.0   # strong block
    w[0, 8:] = 0.1    # weak block
    w[1, :8] = 5.0
    w[1, 8:] = 0.2
    pruned, mask = pruning.prune_blocks(w, 0.5)
    np.testing.assert_array_equal(mask, [[1, 0], [1, 0]])
    assert np.all(pruned[:, 8:] == 0)
    assert np.all(pruned[:, :8] == w[:, :8])


def test_zero_sparsity_keeps_everything():
    w = np.ones((8, 8))
    pruned, mask = pruning.prune_blocks(w, 0.0)
    np.testing.assert_array_equal(pruned, w)
    assert mask.all()


def test_expand_mask():
    m = np.asarray([[1, 0], [0, 1]], dtype=np.uint8)
    e = pruning.expand_mask(m, alpha=4)
    assert e.shape == (2, 8)
    np.testing.assert_array_equal(e[0], [1] * 4 + [0] * 4)
    np.testing.assert_array_equal(e[1], [0] * 4 + [1] * 4)


def test_value_sparsity_metric():
    w = np.asarray([0, 0, 1, 2])
    assert pruning.value_sparsity(w) == pytest.approx(0.5)
    assert pruning.mask_sparsity(np.asarray([1, 0, 0, 0])) == pytest.approx(0.75)


def test_group_zero_column_all_zero():
    assert pruning.group_zero_column_fraction(np.zeros(64, int), 8) == 1.0


def test_group_zero_column_dense_ones():
    # 0xFF in every input -> no zero columns.
    acts = np.full(64, 127, dtype=np.int64)
    frac = pruning.group_zero_column_fraction(acts, 8)
    assert frac == pytest.approx(1 / 8)  # bit 7 of 127 is 0


def test_group_zero_column_monotone_in_group_size():
    """Fig. 3(b) trend: larger groups -> fewer skippable columns."""
    rng = np.random.default_rng(2)
    # ReLU-like activations: ~50% zeros, small magnitudes
    acts = rng.integers(0, 32, size=4096)
    acts[rng.random(4096) < 0.5] = 0
    f1 = pruning.group_zero_column_fraction(acts, 1)
    f8 = pruning.group_zero_column_fraction(acts, 8)
    f16 = pruning.group_zero_column_fraction(acts, 16)
    assert f1 >= f8 >= f16
    assert f8 > 0.2  # grouped sparsity remains substantial


@given(st.integers(min_value=1, max_value=8).map(lambda g: 8 * g),
       st.floats(min_value=0.0, max_value=0.9))
@settings(max_examples=60, deadline=None)
def test_prune_fraction_hypothesis(n, sparsity):
    rng = np.random.default_rng(n)
    w = rng.normal(size=(16, n))
    pruned, mask = pruning.prune_blocks(w, sparsity)
    expect = int(round(sparsity * mask.size))
    assert int((mask == 0).sum()) == expect
    # every pruned block is fully zero in the weights
    zero_blocks = pruning.expand_mask(mask) == 0
    assert np.all(pruned[zero_blocks] == 0)
