"""L1 Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

The dyadic kernel must be *bit-exact* against exact INT8 matmul for all
shapes/dtypes the compiler can emit; hypothesis sweeps the shape space.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile import csd
from compile.kernels import dbpim, ref


def _random_case(rng, m, k, n):
    x = rng.integers(-128, 128, size=(m, k), dtype=np.int64)
    w = rng.integers(-128, 128, size=(k, n), dtype=np.int64)
    planes = csd.digit_planes(w)
    return (jnp.asarray(x, jnp.int8), jnp.asarray(w, jnp.int8),
            jnp.asarray(planes, jnp.int8))


def test_dyadic_matmul_exact_default_tiles():
    rng = np.random.default_rng(0)
    x, w, planes = _random_case(rng, 64, 128, 64)
    out = dbpim.dyadic_matmul(x, planes)
    expect = ref.int8_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_dyadic_matmul_non_divisible_tiles():
    """Shapes that don't divide the default tiles still work (tile
    shrinks to a divisor)."""
    rng = np.random.default_rng(1)
    x, w, planes = _random_case(rng, 6, 36, 10)
    out = dbpim.dyadic_matmul(x, planes)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.int8_matmul(x, w)))


def test_dyadic_ref_matches_int8_matmul():
    rng = np.random.default_rng(2)
    x, w, planes = _random_case(rng, 16, 64, 24)
    np.testing.assert_array_equal(
        np.asarray(ref.dyadic_matmul(x, planes)),
        np.asarray(ref.int8_matmul(x, w)))


def test_bitserial_matmul_exact():
    rng = np.random.default_rng(3)
    x, w, _ = _random_case(rng, 32, 64, 16)
    out = dbpim.bitserial_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.int8_matmul(x, w)))


def test_bitserial_ref_matches():
    rng = np.random.default_rng(4)
    x, w, _ = _random_case(rng, 8, 40, 8)
    np.testing.assert_array_equal(np.asarray(ref.bitserial_matmul(x, w)),
                                  np.asarray(ref.int8_matmul(x, w)))


def test_extreme_values():
    """Worst-case magnitudes: -128 everywhere must not overflow int32."""
    m, k, n = 8, 256, 8
    x = jnp.full((m, k), -128, jnp.int8)
    w = np.full((k, n), -128, np.int64)
    planes = jnp.asarray(csd.digit_planes(w), jnp.int8)
    out = dbpim.dyadic_matmul(x, planes)
    expect = np.full((m, n), 128 * 128 * k, np.int32)
    np.testing.assert_array_equal(np.asarray(out), expect)


def test_sparse_planes_zero_rows():
    """All-zero weights -> all-zero output (Zero-pattern-only filters)."""
    x = jnp.asarray(np.random.default_rng(5).integers(-128, 128, (16, 32)),
                    jnp.int8)
    planes = jnp.zeros((4, 32, 8), jnp.int8)
    out = dbpim.dyadic_matmul(x, planes)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((16, 8)))


def test_vmem_estimate_within_budget():
    """DESIGN.md §8: default tile footprint stays far below ~16 MiB."""
    assert dbpim.vmem_bytes() < 1 << 20


def test_requantize_matches_fixed_point():
    rng = np.random.default_rng(6)
    acc = rng.integers(-(1 << 20), 1 << 20, size=(64,), dtype=np.int64)
    mul = ref.requant_mul_shift(0.00317)
    out = np.asarray(ref.requantize(jnp.asarray(acc, jnp.int32), mul))
    # independent host-side computation of the same fixed-point rule
    wide = acc.astype(np.int64) * mul
    expect = np.clip((wide + (1 << 15)) >> 16, -128, 127)
    np.testing.assert_array_equal(out, expect)


def test_requantize_rounding_rule_half_toward_plus_inf():
    mul = 1 << 15  # ratio 0.5 at shift 16
    acc = jnp.asarray([1, -1, 3, -3], jnp.int32)
    out = np.asarray(ref.requantize(acc, mul))
    # 0.5 -> 1, -0.5 -> 0, 1.5 -> 2, -1.5 -> -1
    np.testing.assert_array_equal(out, [1, 0, 2, -1])


@given(st.integers(1, 5), st.integers(1, 6), st.integers(1, 5),
       st.integers(0, 2 ** 32 - 1))
@settings(max_examples=25, deadline=None)
def test_dyadic_matmul_hypothesis(mi, ki, ni, seed):
    """Shape sweep: m in 1..80, k in 1..96, n in 1..80 (random strides)."""
    rng = np.random.default_rng(seed)
    m, k, n = mi * 16, ki * 16, ni * 16
    x, w, planes = _random_case(rng, m, k, n)
    out = dbpim.dyadic_matmul(x, planes)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.int8_matmul(x, w)))


@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 2 ** 32 - 1))
@settings(max_examples=15, deadline=None)
def test_bitserial_matmul_hypothesis(mi, ki, ni, seed):
    rng = np.random.default_rng(seed)
    m, k, n = mi * 8, ki * 16, ni * 8
    x, w, _ = _random_case(rng, m, k, n)
    out = dbpim.bitserial_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.int8_matmul(x, w)))


@given(st.sampled_from([(1, 1, 1), (3, 7, 5), (2, 9, 4), (5, 3, 11)]),
       st.integers(0, 2 ** 31))
@settings(max_examples=12, deadline=None)
def test_dyadic_matmul_awkward_shapes(shape, seed):
    """Non-power-of-two shapes exercise the tile-shrink path."""
    rng = np.random.default_rng(seed)
    x, w, planes = _random_case(rng, *shape)
    out = dbpim.dyadic_matmul(x, planes)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.int8_matmul(x, w)))
