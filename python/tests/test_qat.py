"""QAT machinery tests: STE, EMA ranges, AdamW, FTA-in-the-loop."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import csd, pruning, qat


def test_ste_round_forward():
    x = jnp.asarray([0.4, 0.6, -1.5, 2.5])
    np.testing.assert_array_equal(np.asarray(qat.ste_round(x)),
                                  np.round(np.asarray(x)))


def test_ste_round_gradient_is_identity():
    g = jax.grad(lambda x: qat.ste_round(3.0 * x))(1.234)
    assert g == pytest.approx(3.0)


def test_quantize_symmetric_range():
    x = jnp.linspace(-2.0, 2.0, 101)
    s = qat.amax_scale(x)
    q = qat.quantize_symmetric(x, s)
    levels = np.unique(np.round(np.asarray(q) / float(s)))
    assert levels.min() >= -128 and levels.max() <= 127


def test_quantize_gradient_flows():
    def loss(x):
        return jnp.sum(qat.quantize_symmetric(x, qat.amax_scale(x)) ** 2)
    g = jax.grad(loss)(jnp.asarray([0.5, -0.25]))
    assert np.all(np.isfinite(np.asarray(g)))
    assert not np.allclose(np.asarray(g), 0.0)


def test_ema_range_tracker():
    ema = qat.EmaRange(decay=0.9)
    s = ema.init()
    s = ema.update(s, jnp.asarray([1.0, -2.0]))  # first update seeds
    assert float(s) == pytest.approx(2.0)
    s = ema.update(s, jnp.asarray([4.0]))
    assert float(s) == pytest.approx(0.9 * 2.0 + 0.1 * 4.0)
    assert float(ema.scale(s)) == pytest.approx(float(s) / 127.0)


def test_adamw_reduces_quadratic():
    opt = qat.AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)
        params, state = opt.update(grads, state, params)
    assert np.abs(np.asarray(params["w"])).max() < 1e-2


def test_cosine_lr_schedule():
    total = 1000
    start = float(qat.cosine_lr(0.0, total))
    mid = float(qat.cosine_lr(total / 2, total))
    end = float(qat.cosine_lr(float(total), total))
    assert start < 0.1          # warmup begins low
    assert 0.3 < mid < 0.8      # mid-cosine
    assert end == pytest.approx(1e-4, abs=1e-3)


def test_build_masks_and_apply():
    rng = np.random.default_rng(0)
    params = {"conv.w": jnp.asarray(rng.normal(size=(3, 3, 8, 16)),
                                    jnp.float32)}
    masks = qat.build_masks(params, sparsity=0.5)
    bmask = masks["conv.w"]
    assert bmask is not None and bmask.shape == (72, 2)
    assert (bmask == 0).sum() == bmask.size // 2
    masked = qat.apply_weight_masks(params, masks)
    w = np.asarray(masked["conv.w"]).reshape(72, 16)
    expanded = pruning.expand_mask(np.asarray(bmask))
    assert np.all(w[expanded == 0] == 0)


def test_apply_fta_to_params_projects_kernels():
    rng = np.random.default_rng(1)
    params = {"conv.w": jnp.asarray(rng.normal(size=(3, 3, 8, 16)),
                                    jnp.float32),
              "conv.b": jnp.zeros(16)}
    masks = qat.build_masks(params, sparsity=0.5)
    new, ths = qat.apply_fta_to_params(params, masks)
    assert "conv.w" in ths and "conv.b" not in ths
    # quantize the projected weights back and verify φ uniformity
    w = np.asarray(new["conv.w"]).reshape(72, 16)
    scale = np.abs(np.asarray(params["conv.w"]).reshape(72, 16)).max() / 127.0
    w_int = np.round(w / scale).astype(np.int64)
    mask = pruning.expand_mask(np.asarray(masks["conv.w"]))
    for n in range(16):
        th = int(ths["conv.w"][n])
        kept = w_int[mask[:, n] != 0, n]
        if th > 0:
            counts = csd.phi(kept)
            np.testing.assert_array_equal(counts, np.full(len(kept), th))
    # bias untouched
    np.testing.assert_array_equal(np.asarray(new["conv.b"]), 0.0)


def test_fta_disable_passthrough():
    rng = np.random.default_rng(2)
    params = {"fc.w": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)}
    new, _ = qat.apply_fta_to_params(params, {"fc.w": None}, enable=False)
    # disabled: only fake-quantization, values stay on the int grid
    w = np.asarray(new["fc.w"])
    scale = np.abs(np.asarray(params["fc.w"])).max() / 127.0
    np.testing.assert_allclose(w / scale, np.round(w / scale), atol=1e-4)
