"""AOT export regression tests.

The most important one guards the constant-elision bug: XLA's default
HLO printer abbreviates large literals as `{...}`, which the 0.5.1 HLO
text parser on the rust side silently mis-reads — baked weights would
execute as garbage. `to_hlo_text` must print full constants.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model as model_lib


@pytest.fixture(scope="module")
def small_graph_text():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-64, 64, size=(64, 8), dtype=np.int8))

    def fn(x):
        return (jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32),
                        preferred_element_type=jnp.int32),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 64), jnp.int8))
    return aot.to_hlo_text(lowered)


def test_hlo_text_has_no_elided_constants(small_graph_text):
    assert "..." not in small_graph_text, \
        "large constants were elided — the rust HLO parser would misread them"


def test_hlo_text_is_tuple_rooted(small_graph_text):
    # the rust loader unwraps a 1-tuple (lowered with return_tuple=True)
    assert "ROOT" in small_graph_text
    assert "tuple(" in small_graph_text


def test_exported_artifacts_consistent(tmp_path):
    """Full export to a temp dir: manifest offsets must index the packs,
    and the golden logits must be reproducible from the exported input."""
    aot.export_mininet(str(tmp_path), seed=3, value_sparsity=0.5)
    import json
    manifest = json.loads((tmp_path / "mininet_manifest.json").read_text())
    weights = (tmp_path / "mininet_weights.bin").read_bytes()
    masks = (tmp_path / "mininet_masks.bin").read_bytes()
    total_w = 0
    total_m = 0
    for layer in manifest["layers"]:
        assert layer["weight_offset"] == total_w
        assert layer["mask_offset"] == total_m
        total_w += layer["k"] * layer["n"]
        total_m += layer["k"] * (layer["n"] // manifest["alpha"])
        assert len(layer["thresholds"]) == layer["n"]
        assert all(0 <= t <= 2 for t in layer["thresholds"])
    assert len(weights) == total_w
    assert len(masks) == total_m

    # recompute golden from the exported input + weights
    spec = model_lib.MiniNetSpec()
    params = model_lib.synthesize_weights(spec, seed=3, value_sparsity=0.5)
    x = np.frombuffer((tmp_path / "mininet_input.bin").read_bytes(),
                      dtype=np.int8).reshape(manifest["input"]["batch"],
                                             manifest["input"]["ch"],
                                             manifest["input"]["hw"],
                                             manifest["input"]["hw"])
    golden = np.frombuffer((tmp_path / "mininet_golden.bin").read_bytes(),
                           dtype=np.int32)
    logits = np.asarray(model_lib.forward(params, jnp.asarray(x), spec,
                                          use_kernel=False))
    np.testing.assert_array_equal(logits.reshape(-1), golden)


def test_export_weights_match_synthesis(tmp_path):
    """The exported weight pack is exactly the synthesized pipeline
    output (same seed ⇒ same bytes)."""
    aot.export_mininet(str(tmp_path), seed=0, value_sparsity=0.6)
    spec = model_lib.MiniNetSpec()
    params = model_lib.synthesize_weights(spec, seed=0, value_sparsity=0.6)
    blob = (tmp_path / "mininet_weights.bin").read_bytes()
    offset = 0
    order = [c.name for c in spec.convs] + ["fc"]
    for name in order:
        w = np.asarray(params[name]["w"], dtype=np.int8).tobytes()
        assert blob[offset:offset + len(w)] == w, f"layer {name} differs"
        offset += len(w)
