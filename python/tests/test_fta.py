"""FTA (Alg. 1) tests, including the paper's worked example."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import csd, fta


def test_query_tables_partition_int8():
    sizes = {p: len(fta.query_table(p)) for p in range(5)}
    assert sizes[0] == 1  # {0}
    assert sum(sizes.values()) == 256
    union = np.concatenate([fta.query_table(p) for p in range(5)])
    assert len(np.unique(union)) == 256


def test_query_table_phi_exact():
    for p in range(5):
        t = fta.query_table(p)
        np.testing.assert_array_equal(csd.phi(t), np.full(len(t), p))


def test_query_table_1_is_signed_powers_of_two():
    t = set(int(v) for v in fta.query_table(1))
    expect = {s * 2 ** k for s in (1, -1) for k in range(8)}
    expect = {v for v in expect if -128 <= v <= 127}
    assert t == expect


def test_nearest_tie_prefers_larger():
    # 0 is equidistant from -1 and +1 in T(1); paper's example projects
    # the unpruned natural zero to +1.
    assert int(fta.nearest_in_table(np.asarray(0), 1)) == 1


def test_paper_worked_example():
    """Sec. IV-C: f0 = {-63,0,64,0,0,-8,13}, mask = {1,0,1,1,0,1,1}."""
    f0 = np.asarray([-63, 0, 64, 0, 0, -8, 13])
    mask = np.asarray([1, 0, 1, 1, 0, 1, 1])
    phis = csd.phi(f0)
    np.testing.assert_array_equal(phis, [2, 0, 1, 0, 0, 1, 3])
    assert fta.filter_threshold(phis, mask) == 1
    out, th = fta.fta_filter(f0, mask)
    assert th == 1
    np.testing.assert_array_equal(out, [-64, 0, 64, 1, 0, -8, 16])


def test_threshold_rules():
    # all-zero filter
    assert fta.filter_threshold(np.zeros(8, int), np.ones(8, int)) == 0
    # mode 0 with some non-zero -> 1
    assert fta.filter_threshold(np.asarray([0, 0, 0, 1]), np.ones(4, int)) == 1
    # mode in {1, 2} -> mode
    assert fta.filter_threshold(np.asarray([1, 1, 2, 3]), np.ones(4, int)) == 1
    assert fta.filter_threshold(np.asarray([2, 2, 1, 3]), np.ones(4, int)) == 2
    # mode > 2 -> clamp to 2
    assert fta.filter_threshold(np.asarray([3, 3, 4, 1]), np.ones(4, int)) == 2
    # fully masked filter -> 0
    assert fta.filter_threshold(np.asarray([1, 2, 3]), np.zeros(3, int)) == 0


def test_fta_layer_every_kept_weight_has_threshold_digits():
    rng = np.random.default_rng(7)
    w = rng.integers(-128, 128, size=(64, 16), dtype=np.int64)
    mask = (rng.random((64, 16)) > 0.3).astype(np.int64)
    out, ths = fta.fta_layer(w, mask)
    for n in range(w.shape[1]):
        th = int(ths[n])
        col = out[:, n]
        kept = col[mask[:, n] != 0]
        if th == 0:
            np.testing.assert_array_equal(col, 0)
        else:
            np.testing.assert_array_equal(csd.phi(kept),
                                          np.full(len(kept), th))
        # pruned weights stay exactly zero
        np.testing.assert_array_equal(col[mask[:, n] == 0], 0)


def test_fta_projection_idempotent():
    rng = np.random.default_rng(3)
    w = rng.integers(-128, 128, size=(32, 8), dtype=np.int64)
    once, th1 = fta.fta_layer(w)
    twice, th2 = fta.fta_layer(once)
    np.testing.assert_array_equal(once, twice)
    np.testing.assert_array_equal(th1, th2)


def test_thresholds_bounded_by_two():
    rng = np.random.default_rng(11)
    w = rng.integers(-128, 128, size=(128, 24), dtype=np.int64)
    _, ths = fta.fta_layer(w)
    assert ths.max() <= 2 and ths.min() >= 0


def test_guaranteed_sparsity():
    assert fta.guaranteed_sparsity(np.asarray([2, 2, 2])) == pytest.approx(0.75)
    assert fta.guaranteed_sparsity(np.asarray([1, 1])) == pytest.approx(0.875)
    assert fta.guaranteed_sparsity(np.asarray([0, 1, 2, 2, 1, 0])) == \
        pytest.approx(1 - (6 / 6) / 8)


def test_bit_sparsity_increases_after_fta():
    rng = np.random.default_rng(5)
    w = rng.integers(-128, 128, size=(256, 32), dtype=np.int64)
    before = fta.bit_sparsity(w)
    out, _ = fta.fta_layer(w)
    after = fta.bit_sparsity(out)
    assert after > before
    assert after >= 0.75  # FTA guarantee with φ_th <= 2


@given(st.integers(min_value=-128, max_value=127),
       st.integers(min_value=1, max_value=2))
@settings(max_examples=300, deadline=None)
def test_projection_error_bounded(v, th):
    """The projection picks the *closest* element — no table element is
    nearer than the chosen one."""
    chosen = int(fta.nearest_in_table(np.asarray(v), th))
    table = fta.query_table(th)
    best = int(np.min(np.abs(table - v)))
    assert abs(chosen - v) == best
