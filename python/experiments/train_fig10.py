"""Fig. 10 reproduction: hybrid-grained vs coarse-grained pruning accuracy.

Substitution (DESIGN.md §3): the paper trains five CIFAR-100 CNNs for
500 epochs; this harness runs the *identical pipeline* — pretrain →
coarse block pruning → fine-tune with masks (+ FTA-aware QAT for the
hybrid arm) → final FTA quantization → evaluate the projected INT8
model — on a synthetic 16-class image task with a scaled-down CNN. The
paper's claim is relative: at matched total sparsity, hybrid (value +
bit) pruning loses less accuracy than pushing coarse value pruning
alone. That mechanism is scale-independent and is what we measure.

Sparsity accounting follows the paper: FTA with φ_th ≤ 2 guarantees a
75% bit-sparsity floor, so hybrid total = 1 − (1 − v) · (1 − 0.75) for
value sparsity v; e.g. v=0.6 ⇒ 90% compound.

Usage: python -m experiments.train_fig10 --out ../artifacts/fig10_accuracy.json
"""

from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import pruning, qat

NUM_CLASSES = 16
IMG = 16
CH = 3


# --------------------------------------------------------------------------
# Synthetic dataset: smooth class prototypes + jitter + noise
# --------------------------------------------------------------------------

def make_dataset(n_train=4096, n_test=1024, seed=0):
    rng = np.random.default_rng(seed)
    # low-frequency class prototypes
    freq = rng.normal(size=(NUM_CLASSES, CH, 3, 3))
    protos = np.zeros((NUM_CLASSES, IMG, IMG, CH), np.float32)
    yy, xx = np.mgrid[0:IMG, 0:IMG] / IMG
    for c in range(NUM_CLASSES):
        for ch in range(CH):
            acc = np.zeros((IMG, IMG))
            for i in range(3):
                for j in range(3):
                    acc += freq[c, ch, i, j] * np.sin(
                        2 * np.pi * ((i + 1) * yy + (j + 1) * xx)
                        + c * 0.7 + ch)
            protos[c, :, :, ch] = acc
    protos /= np.abs(protos).max(axis=(1, 2, 3), keepdims=True)

    def sample(n):
        labels = rng.integers(0, NUM_CLASSES, n)
        imgs = protos[labels].copy()
        # random cyclic shifts (translation jitter)
        for i in range(n):
            sx, sy = rng.integers(0, 4, 2)
            imgs[i] = np.roll(imgs[i], (sx, sy), axis=(0, 1))
        imgs *= rng.uniform(0.6, 1.4, size=(n, 1, 1, 1)).astype(np.float32)
        imgs += rng.normal(0, 0.35, imgs.shape).astype(np.float32)
        return imgs.astype(np.float32), labels.astype(np.int32)

    return sample(n_train), sample(n_test)


# --------------------------------------------------------------------------
# Small CNN (pure jax, params dict; kernels HWIO so qat helpers apply)
# --------------------------------------------------------------------------

def init_params(seed=0):
    rng = np.random.default_rng(seed)

    def he(shape):
        fan_in = int(np.prod(shape[:-1]))
        return jnp.asarray(rng.normal(0, np.sqrt(2 / fan_in), shape),
                           jnp.float32)

    return {
        "c1.w": he((3, 3, CH, 16)), "c1.b": jnp.zeros(16),
        "c2.w": he((3, 3, 16, 32)), "c2.b": jnp.zeros(32),
        "c3.w": he((3, 3, 32, 32)), "c3.b": jnp.zeros(32),
        "fc.w": he((4 * 4 * 32, NUM_CLASSES)), "fc.b": jnp.zeros(NUM_CLASSES),
    }


def _conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def forward(params, x, quant: bool):
    """Forward pass; ``quant`` enables INT8 fake-quantization (QAT) of
    weights and activations with dynamic min-max ranges (STE grads)."""
    def q(w):
        return qat.quantize_symmetric(w, qat.amax_scale(w)) if quant else w

    def qa(a):
        return qat.quantize_symmetric(a, qat.amax_scale(a)) if quant else a

    h = qa(x)
    h = jax.nn.relu(_conv(h, q(params["c1.w"]), params["c1.b"]))
    h = _pool(qa(h))
    h = jax.nn.relu(_conv(h, q(params["c2.w"]), params["c2.b"]))
    h = _pool(qa(h))
    h = jax.nn.relu(_conv(h, q(params["c3.w"]), params["c3.b"]))
    h = qa(h).reshape(h.shape[0], -1)
    return h @ q(params["fc.w"]) + params["fc.b"]


def loss_fn(params, x, y, quant):
    logits = forward(params, x, quant)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


@functools.partial(jax.jit, static_argnames=("quant", "opt"))
def train_step(params, opt_state, x, y, lr_scale, quant, opt):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, quant)
    params, opt_state = opt.update(grads, opt_state, params, lr_scale)
    return params, opt_state, loss


@functools.partial(jax.jit, static_argnames=("quant",))
def eval_batch(params, x, y, quant):
    logits = forward(params, x, quant)
    return jnp.sum(jnp.argmax(logits, -1) == y)


def accuracy(params, data, quant):
    x, y = data
    correct = 0
    for i in range(0, len(x), 256):
        correct += int(eval_batch(params, jnp.asarray(x[i:i + 256]),
                                  jnp.asarray(y[i:i + 256]), quant))
    return correct / len(x)


def run_training(params, masks, train, steps, opt, *, quant, fta_every=0,
                 seed=0, batch=128):
    """Fine-tune with pinned masks; optionally FTA-project periodically."""
    x, y = train
    rng = np.random.default_rng(seed)
    opt_state = opt.init(params)
    for step in range(steps):
        idx = rng.integers(0, len(x), batch)
        lr_scale = qat.cosine_lr(float(step), steps)
        params, opt_state, _ = train_step(
            params, opt_state, jnp.asarray(x[idx]), jnp.asarray(y[idx]),
            lr_scale, quant, opt)
        params = qat.apply_weight_masks(params, masks)
        if fta_every and (step + 1) % fta_every == 0:
            params, _ = qat.apply_fta_to_params(params, masks)
    return params


def experiment(steps=400, seed=0):
    train, test = make_dataset(seed=seed)
    opt = qat.AdamW(lr=1e-3)

    # ---- pretrain dense (float) -------------------------------------------
    params = init_params(seed)
    params = run_training(params, {}, train, steps, opt, quant=False,
                          seed=seed)
    dense_acc = accuracy(params, test, quant=False)
    results = {"dense_acc": dense_acc, "points": []}
    print(f"dense float acc: {dense_acc:.3f}")

    # ---- INT8 QAT baseline (0 sparsity) -----------------------------------
    qat_params = run_training(dict(params), {}, train, steps // 2, opt,
                              quant=True, seed=seed + 1)
    base_acc = accuracy(qat_params, test, quant=True)
    results["int8_acc"] = base_acc
    print(f"int8 dense acc: {base_acc:.3f}")

    # hybrid arm: value sparsity v + FTA (75% floor) => total 1-(1-v)/4
    hybrid_points = [(0.0, 0.75), (0.2, 0.80), (0.4, 0.85), (0.6, 0.90),
                     (0.7, 0.925)]
    # coarse arm: pure value sparsity at the same totals
    coarse_points = [0.75, 0.80, 0.85, 0.90, 0.925]

    for v, total in hybrid_points:
        p = dict(qat_params)
        masks = qat.build_masks(p, v)
        p = qat.apply_weight_masks(p, masks)
        p = run_training(p, masks, train, steps, opt, quant=True,
                         fta_every=max(1, steps // 8), seed=seed + 2)
        p, _ = qat.apply_fta_to_params(p, masks)  # final FTA quantization
        acc = accuracy(p, test, quant=True)
        results["points"].append({"method": "hybrid", "value_sparsity": v,
                                  "total_sparsity": total, "acc": acc})
        print(f"hybrid v={v:.2f} total={total:.3f}: {acc:.3f}")

    for s in coarse_points:
        p = dict(qat_params)
        masks = qat.build_masks(p, s)
        p = qat.apply_weight_masks(p, masks)
        p = run_training(p, masks, train, steps, opt, quant=True,
                         seed=seed + 3)
        acc = accuracy(p, test, quant=True)
        results["points"].append({"method": "coarse", "value_sparsity": s,
                                  "total_sparsity": s, "acc": acc})
        print(f"coarse s={s:.3f}: {acc:.3f}")

    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/fig10_accuracy.json")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    t0 = time.time()
    results = experiment(steps=args.steps, seed=args.seed)
    results["wall_seconds"] = time.time() - t0
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out} in {results['wall_seconds']:.0f}s")


if __name__ == "__main__":
    main()
