#!/usr/bin/env python3
"""Perf-regression gate over BENCH_*.json medians.

Compares the medians of selected sim_hotpath cases in a fresh bench run
against a committed baseline JSON and fails (exit 1) when any watched
case regresses by more than the allowed fraction. Used by the CI
perf-smoke job after `DBPIM_BENCH_FAST=1 cargo bench --bench
sim_hotpath` (see .github/workflows/ci.yml).

Usage:
    check_bench_regression.py MEASURED BASELINE
        [--max-regression 0.25]
        [--cases row_loop_ipu_on e2e_resnet18_hybrid]
        [--update]

Behaviour:
  * missing baseline file           -> warn + exit 0 (bootstrap runs)
  * watched case missing either side -> fail (the bench was renamed or
    dropped without updating the gate)
  * --update rewrites the baseline from the measured file instead of
    comparing (for refreshing the committed numbers from a CI artifact)

The committed baseline records *upper bounds* for the watched medians
on a CI-class host; refresh it from a real CI run's artifact whenever
the hot paths change deliberately (see EXPERIMENTS.md §Perf).
"""

import argparse
import json
import shutil
import sys

DEFAULT_CASES = [
    "row_loop_ipu_on",
    "e2e_resnet18_hybrid",
    "pool_nested_sweep",
    "pool_spawn_overhead",
    "arena_reuse_row_loop",
    "sim_cached_sweep",
    "dense_eff_prefix",
    "serve_throughput",
    "kernel_backend_scan",
    "kernel_backend_gemm",
    "requant_relu_arena",
    "serve_loop_saturation",
    "shard_sweep",
    "fault_campaign",
    "explore_sweep",
]


def load_medians(path):
    with open(path) as f:
        doc = json.load(f)
    return {s["name"]: float(s["median_ns"]) for s in doc["samples"]}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("measured", help="fresh BENCH_sim_hotpath.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="allowed fractional slowdown vs baseline (default 0.25 = 25%%)",
    )
    ap.add_argument("--cases", nargs="+", default=DEFAULT_CASES)
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the measured file instead of comparing",
    )
    args = ap.parse_args()

    if args.update:
        shutil.copyfile(args.measured, args.baseline)
        print(f"baseline refreshed from {args.measured} -> {args.baseline}")
        return 0

    try:
        base = load_medians(args.baseline)
    except FileNotFoundError:
        print(f"WARNING: no baseline at {args.baseline} — skipping perf gate")
        return 0
    measured = load_medians(args.measured)

    failed = False
    for case in args.cases:
        if case not in measured:
            print(f"FAIL: case '{case}' missing from {args.measured}")
            failed = True
            continue
        if case not in base:
            print(f"FAIL: case '{case}' missing from baseline {args.baseline}")
            failed = True
            continue
        got, want = measured[case], base[case]
        ratio = got / want if want > 0 else float("inf")
        limit = 1.0 + args.max_regression
        verdict = "FAIL" if ratio > limit else "ok"
        print(
            f"{verdict}: {case}: median {got / 1e6:.2f} ms vs baseline "
            f"{want / 1e6:.2f} ms ({ratio:.2f}x, limit {limit:.2f}x)"
        )
        failed |= ratio > limit
    if failed:
        print(
            "perf regression gate failed; if the slowdown is intentional, "
            "refresh the baseline with --update from a CI artifact"
        )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
