//! Quickstart: sparsify a layer with the hybrid-grained pipeline, map it
//! onto DB-PIM, simulate it against the dense digital-PIM baseline, and
//! print the speedup / energy / utilization numbers.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dbpim::arch::ArchConfig;
use dbpim::compiler::{compile_layer, prepare_layer, SparsityConfig};
use dbpim::models::synthesize_weights;
use dbpim::quant;
use dbpim::sim::Machine;
use dbpim::tensor::MatI8;

fn main() {
    // One conv-sized matmul: M output pixels, K unfolded inputs, N filters.
    let (m, k, n) = (256, 1152, 128);
    println!("layer: [{m} x {k}] @ [{k} x {n}] INT8\n");

    // --- offline pipeline: coarse 60% block pruning + FTA projection ---
    let raw = synthesize_weights(42, k, n);
    let sparsity = SparsityConfig::hybrid(0.6);

    let mut results = Vec::new();
    for arch in [ArchConfig::db_pim(), ArchConfig::dense_baseline()] {
        let prep = prepare_layer(
            "quickstart",
            m,
            k,
            n,
            raw.clone(),
            sparsity,
            &arch,
            quant::requant_mul(0.005),
            true,
            None,
        );
        if arch.weight_bit_sparsity {
            let ths = &prep.thresholds;
            let th1 = ths.iter().filter(|&&t| t == 1).count();
            let th2 = ths.iter().filter(|&&t| t == 2).count();
            println!(
                "FTA thresholds: {} filters φ=1, {} filters φ=2, {} empty",
                th1,
                th2,
                n - th1 - th2
            );
            println!("value sparsity: {:.1}% of α-blocks pruned", 100.0 * prep.mask.sparsity());
        }
        let layer = compile_layer(prep, &arch);
        println!(
            "{:16} {} macro assignments, {} weight tiles, {} instructions",
            arch.name,
            layer.assignments.len(),
            layer.tiles.len(),
            layer.instrs.len()
        );

        // --- simulate with ReLU-like input activations ---
        let acts = dbpim::models::synthesize_activations(7, m * k);
        let x = MatI8::from_vec(m, k, acts);
        let machine = Machine::new(arch.clone());
        let (stats, _) = machine.run_pim_layer(&layer, Some(&x), false);
        let energy_uj = stats.events.energy_pj(&machine.energy) / 1e6;
        let u_act = stats.events.u_act(arch.macro_columns * arch.compartments);
        println!(
            "{:16} {} cycles  ({:.1} µs @ {:.0} MHz)   {:.2} µJ   U_act {:.1}%\n",
            arch.name,
            stats.elapsed,
            stats.elapsed as f64 * arch.clock_ns() / 1e3,
            arch.freq_mhz,
            energy_uj,
            100.0 * u_act
        );
        results.push((stats.elapsed, energy_uj));
    }

    let speedup = results[1].0 as f64 / results[0].0 as f64;
    let saving = 1.0 - results[0].1 / results[1].1;
    println!("DB-PIM speedup over dense PIM baseline: {speedup:.2}x");
    println!("energy saving: {:.1}%", 100.0 * saving);
    assert!(speedup > 3.0, "expected a clear win on a 90%-sparsity layer");
}
