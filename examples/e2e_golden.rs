//! End-to-end validation driver (the repository's headline experiment).
//!
//! Proves all three layers compose on a real workload:
//!
//! 1. `make artifacts` (build time, python): the MiniNet CNN is
//!    block-pruned + FTA-projected, its forward pass — running every
//!    matmul through the **Pallas dyadic kernel** — is AOT-lowered to
//!    HLO text, and the exact INT8 weights are exported.
//! 2. This binary loads the weight pack, compiles the network onto the
//!    DB-PIM macro grid, and runs inference **in the cycle-accurate
//!    simulator** (functional mode) on the fixed input batch.
//! 3. It then executes the golden HLO **through PJRT** and compares all
//!    logits bit-for-bit, for DB-PIM, the dense baseline, and every
//!    ablation architecture.
//!
//! Reported: logits equality, cycles, µJ, speedup, utilization — the
//! paper's headline metrics on this workload. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_golden
//! ```

use dbpim::arch::ArchConfig;
use dbpim::models;
use dbpim::runtime;
use dbpim::sim::pipeline::run_mininet;

fn main() {
    let dir = models::default_artifacts_dir();
    let net = models::load_mininet(&dir).unwrap_or_else(|e| {
        eprintln!("cannot load artifacts ({e:#}); run `make artifacts` first");
        std::process::exit(1);
    });
    println!(
        "MiniNet: {} PIM layers, batch {}, input {}x{}x{}, {} classes",
        net.layers.len(),
        net.batch,
        net.input_ch,
        net.input_hw,
        net.input_hw,
        net.num_classes
    );

    // --- 1. golden HLO through PJRT (the python/jax/pallas layers) ---
    let pjrt_logits = runtime::run_golden_mininet(&net).expect("PJRT execution failed");
    assert_eq!(
        pjrt_logits, net.golden,
        "PJRT-executed golden HLO diverges from the exported oracle logits"
    );
    println!("PJRT golden HLO  == exported oracle: BIT-EXACT");

    // --- 2. cycle-accurate simulation across all architectures ---
    let archs = [
        ArchConfig::dense_baseline(),
        ArchConfig::value_only(),
        ArchConfig::weights_only(),
        ArchConfig::bit_only(),
        ArchConfig::db_pim(),
    ];
    let mut baseline_cycles = 0u64;
    let mut baseline_energy = 0f64;
    println!("\n{:16} {:>10} {:>10} {:>9} {:>8} {:>8}", "architecture", "cycles", "time µs", "µJ", "speedup", "U_act");
    for arch in archs {
        let run = run_mininet(&net, &arch).expect("simulation failed");
        assert_eq!(
            run.logits, pjrt_logits,
            "{}: simulator logits diverge from PJRT",
            arch.name
        );
        let cycles = run.total_cycles();
        let energy = run.energy_uj();
        if arch.name == "dense-baseline" {
            baseline_cycles = cycles;
            baseline_energy = energy;
        }
        let u = run.totals.u_act(arch.macro_columns * arch.compartments);
        println!(
            "{:16} {:>10} {:>10.2} {:>9.3} {:>7}x {:>7.1}%",
            arch.name,
            cycles,
            run.time_us(),
            energy,
            if baseline_cycles > 0 {
                format!("{:.2}", baseline_cycles as f64 / cycles as f64)
            } else {
                "-".to_string()
            },
            100.0 * u,
        );
    }
    // recompute against the captured baseline (last row printed "-" for
    // rows before baseline was known, so print the summary explicitly)
    let d = run_mininet(&net, &ArchConfig::db_pim()).unwrap();
    println!(
        "\nALL ARCHITECTURES BIT-EXACT vs golden HLO via PJRT ✓\n\
         DB-PIM vs dense baseline: {:.2}x speedup, {:.1}% energy saving",
        baseline_cycles as f64 / d.total_cycles() as f64,
        100.0 * (1.0 - d.energy_uj() / baseline_energy)
    );
}
