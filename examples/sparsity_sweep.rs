//! Sparsity sweep: the Fig. 11 experiment as a runnable example —
//! speedup and energy saving vs the dense baseline as value-level
//! sparsity sweeps 0–70% on top of FTA bit-level sparsity, for any zoo
//! network.
//!
//! ```bash
//! cargo run --release --example sparsity_sweep [network]
//! ```

use dbpim::arch::ArchConfig;
use dbpim::compiler::SparsityConfig;
use dbpim::models;
use dbpim::sim;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let net = models::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown network {name}");
        std::process::exit(2);
    });
    println!("network: {name} ({} PIM MACs)", net.pim_macs());

    let base = sim::simulate_network(
        &net,
        SparsityConfig::dense(),
        &ArchConfig::dense_baseline(),
        42,
    );
    println!(
        "dense baseline: {} cycles ({:.3} ms), {:.1} µJ\n",
        base.pim_cycles(),
        base.pim_time_ms(),
        base.energy_uj()
    );

    println!(
        "{:>8} {:>8} {:>10} {:>9} {:>9} {:>8}",
        "value", "total", "cycles", "speedup", "energy", "U_act"
    );
    let mut last = 0.0;
    for v in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7] {
        let total = 1.0 - (1.0 - v) * 0.25; // FTA guarantees the 75% floor
        let r = sim::simulate_network(
            &net,
            SparsityConfig::hybrid(v),
            &ArchConfig::weights_only(),
            42,
        );
        let speedup = r.pim_speedup_vs(&base);
        let saving = 1.0 - r.energy_uj() / base.energy_uj();
        println!(
            "{:>7.0}% {:>7.1}% {:>10} {:>8.2}x {:>8.1}% {:>7.1}%",
            100.0 * v,
            100.0 * total,
            r.pim_cycles(),
            speedup,
            100.0 * saving,
            100.0 * r.u_act(),
        );
        assert!(speedup >= last * 0.98, "speedup should rise with sparsity");
        last = speedup;
    }
}
