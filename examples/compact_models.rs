//! Compact-model study (the paper's Fig. 12/13 motivation): end-to-end
//! inference of MobileNetV2 and EfficientNet-B0 on DB-PIM, showing how
//! depthwise convolutions and element-wise ops cap the achievable
//! speedup even when std/pw-conv layers accelerate ~8×.
//!
//! ```bash
//! cargo run --release --example compact_models
//! ```

use dbpim::arch::ArchConfig;
use dbpim::compiler::SparsityConfig;
use dbpim::models;
use dbpim::sim::{self, OpCategory};

fn main() {
    for name in ["mobilenet_v2", "efficientnet_b0"] {
        let net = models::by_name(name).unwrap();
        let base = sim::simulate_network(
            &net,
            SparsityConfig::dense(),
            &ArchConfig::dense_baseline(),
            42,
        );
        let r = sim::simulate_network(&net, SparsityConfig::hybrid(0.6), &ArchConfig::db_pim(), 42);

        println!("== {name} ==");
        println!(
            "  PIM-layer speedup : {:.2}x   end-to-end speedup: {:.2}x",
            r.pim_speedup_vs(&base),
            r.speedup_vs(&base)
        );
        println!("  execution-time breakdown on DB-PIM (Fig. 13):");
        for (cat, share) in r.category_breakdown() {
            let label = match cat {
                OpCategory::PimConvFc => "pw/std-conv + FC",
                OpCategory::DwConv => "dw-conv",
                OpCategory::Mul => "mul (SE etc.)",
                OpCategory::Etc => "pool/ReLU/resadd",
            };
            println!("    {label:18} {:5.1}%", 100.0 * share);
        }
        // Amdahl check: the non-PIM share must be a visible fraction —
        // that is the paper's explanation for compact models' limits.
        let non_pim: f64 = r
            .category_breakdown()
            .iter()
            .filter(|(c, _)| *c != OpCategory::PimConvFc)
            .map(|(_, s)| s)
            .sum();
        println!("  non-acceleratable share: {:.1}%\n", 100.0 * non_pim);
        assert!(non_pim > 0.15, "compact models should be SIMD-bound");
        assert!(
            r.speedup_vs(&base) < r.pim_speedup_vs(&base),
            "end-to-end speedup must trail the PIM-only speedup"
        );
    }
}
