//! PJRT runtime: load the AOT-compiled HLO text artifacts and execute
//! them on the CPU PJRT client (`xla` crate).
//!
//! This is the bridge from the build-time python/JAX/Pallas layers into
//! the rust request path: `make artifacts` lowers the golden graphs to
//! HLO *text* (jax ≥ 0.5 serialized protos use 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids), and
//! this module compiles + runs them for bit-exact verification of the
//! simulator. Python never runs at request time.

use std::path::Path;

use anyhow::{anyhow, Context};

/// PJRT CPU client wrapper.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> crate::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
        Ok(Executable { exe })
    }
}

/// An INT8 tensor argument for an executable.
pub fn literal_i8(data: &[i8], dims: &[usize]) -> crate::Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape mismatch");
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, dims, bytes)
        .map_err(|e| anyhow!("creating i8 literal: {e:?}"))
}

impl Executable {
    /// Execute with literal arguments; the golden graphs return a
    /// 1-tuple (lowered with `return_tuple=True`), unwrap it and read
    /// the INT32 payload.
    pub fn run_i32(&self, args: &[xla::Literal]) -> crate::Result<Vec<i32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec<i32>: {e:?}"))
    }
}

/// Convenience: run the golden MiniNet HLO on its fixed input batch.
pub fn run_golden_mininet(net: &crate::models::MiniNet) -> crate::Result<Vec<i32>> {
    let rt = Runtime::cpu()?;
    let exe = rt.load_hlo_text(&net.hlo_path).context("loading golden mininet HLO")?;
    let x = literal_i8(
        &net.input,
        &[net.batch, net.input_ch, net.input_hw, net.input_hw],
    )?;
    exe.run_i32(&[x])
}

/// Convenience: run the golden tile-matmul HLO: (x [M,K] i8,
/// planes [4,K,N] i8) -> [M,N] i32.
pub fn run_golden_tile(
    net: &crate::models::MiniNet,
    x: &[i8],
    m: usize,
    k: usize,
    planes: &[i8],
    n: usize,
) -> crate::Result<Vec<i32>> {
    let rt = Runtime::cpu()?;
    let exe = rt.load_hlo_text(&net.tile_hlo_path).context("loading tile HLO")?;
    let xl = literal_i8(x, &[m, k])?;
    let pl = literal_i8(planes, &[4, k, n])?;
    exe.run_i32(&[xl, pl])
}
