//! PJRT runtime façade.
//!
//! The real implementation ([`pjrt`]) loads the AOT-compiled HLO text
//! artifacts and executes them on the CPU PJRT client via the `xla`
//! crate — which is not in the offline vendored registry. It is gated
//! behind the `pjrt` cargo feature (see rust/Cargo.toml for how to
//! enable it); the default build compiles [`stub`], which keeps the
//! same API surface (so the CLI, example and integration tests all
//! compile) but returns descriptive errors from every execution entry
//! point. Shape validation (`literal_i8`) works in both builds.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;
