//! Stub runtime for builds without the `pjrt` feature (the `xla` crate
//! is not in the offline registry). Mirrors the pjrt.rs API so callers
//! compile unchanged; execution entry points return errors, and the
//! integration tests skip gracefully because the HLO artifacts they
//! need are produced by the same toolchain that provides PJRT.

use std::path::Path;

use anyhow::{anyhow, ensure};

const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` feature \
     (the xla crate is not in the offline registry; see rust/Cargo.toml)";

/// Shape-checked literal stand-in (never executed).
pub struct Literal {
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
}

/// PJRT CPU client wrapper (stub).
pub struct Runtime {
    _private: (),
}

/// A compiled executable (stub).
pub struct Executable {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> crate::Result<Self> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> crate::Result<Executable> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl Executable {
    pub fn run_i32(&self, _args: &[Literal]) -> crate::Result<Vec<i32>> {
        Err(anyhow!(UNAVAILABLE))
    }
}

/// An INT8 tensor argument for an executable. The shape check matches
/// the real implementation so validation tests run in both builds.
pub fn literal_i8(data: &[i8], dims: &[usize]) -> crate::Result<Literal> {
    let n: usize = dims.iter().product();
    ensure!(n == data.len(), "literal shape mismatch");
    Ok(Literal { dims: dims.to_vec(), bytes: data.iter().map(|&b| b as u8).collect() })
}

/// Convenience: run the golden MiniNet HLO on its fixed input batch.
pub fn run_golden_mininet(_net: &crate::models::MiniNet) -> crate::Result<Vec<i32>> {
    Err(anyhow!(UNAVAILABLE))
}

/// Convenience: run the golden tile-matmul HLO.
pub fn run_golden_tile(
    _net: &crate::models::MiniNet,
    _x: &[i8],
    _m: usize,
    _k: usize,
    _planes: &[i8],
    _n: usize,
) -> crate::Result<Vec<i32>> {
    Err(anyhow!(UNAVAILABLE))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(Runtime::cpu().is_err());
    }

    #[test]
    fn stub_literal_validates_shape() {
        assert!(literal_i8(&[1, 2, 3], &[2, 2]).is_err());
        let l = literal_i8(&[1, -1, 2, -2], &[2, 2]).unwrap();
        assert_eq!(l.dims, vec![2, 2]);
        assert_eq!(l.bytes, vec![1, 255, 2, 254]);
    }
}
