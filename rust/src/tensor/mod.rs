//! Minimal integer tensor types + im2col + exact conv references.
//!
//! Layouts match `python/compile/kernels/ref.py` exactly: activations
//! are NCHW, im2col rows are ordered (n, oh, ow) with columns ordered
//! (c, kh, kw) row-major, and the flatten before an FC layer is HWC —
//! so every integer the simulator produces can be compared bit-for-bit
//! with the golden jnp graphs.

/// A dense NCHW INT8 activation tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorI8 {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<i8>,
}

impl TensorI8 {
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> Self {
        Self { n, c, h, w, data: vec![0; n * c * h * w] }
    }

    pub fn from_vec(n: usize, c: usize, h: usize, w: usize, data: Vec<i8>) -> Self {
        assert_eq!(data.len(), n * c * h * w, "shape/data mismatch");
        Self { n, c, h, w, data }
    }

    #[inline]
    pub fn idx(&self, n: usize, c: usize, y: usize, x: usize) -> usize {
        ((n * self.c + c) * self.h + y) * self.w + x
    }

    #[inline]
    pub fn get(&self, n: usize, c: usize, y: usize, x: usize) -> i8 {
        self.data[self.idx(n, c, y, x)]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// HWC flatten per batch element (matches the jnp golden graph's
    /// `transpose(0,2,3,1).reshape(n, -1)` before the FC layer).
    pub fn flatten_hwc(&self) -> MatI8 {
        let cols = self.c * self.h * self.w;
        let mut out = vec![0i8; self.n * cols];
        for n in 0..self.n {
            let mut j = 0;
            for y in 0..self.h {
                for x in 0..self.w {
                    for c in 0..self.c {
                        out[n * cols + j] = self.get(n, c, y, x);
                        j += 1;
                    }
                }
            }
        }
        MatI8 { rows: self.n, cols, data: out }
    }
}

/// Row-major INT8 matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i8>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i8) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Row-major INT32 matrix (accumulators).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn add(&mut self, r: usize, c: usize, v: i32) {
        self.data[r * self.cols + c] += v;
    }
}

/// Geometry of one conv (im2col) problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }
}

/// im2col: NCHW -> [N*OH*OW, C*KH*KW], column order (c, kh, kw).
pub fn im2col(x: &TensorI8, g: ConvGeom) -> (MatI8, usize, usize) {
    let (oh, ow) = g.out_hw(x.h, x.w);
    let cols = x.c * g.kh * g.kw;
    let mut out = vec![0i8; x.n * oh * ow * cols];
    let mut row = 0;
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                let base = row * cols;
                let mut j = 0;
                for c in 0..x.c {
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            let iy = oy * g.stride + ky;
                            let ix = ox * g.stride + kx;
                            let v = if iy < g.pad
                                || ix < g.pad
                                || iy - g.pad >= x.h
                                || ix - g.pad >= x.w
                            {
                                0
                            } else {
                                x.get(n, c, iy - g.pad, ix - g.pad)
                            };
                            out[base + j] = v;
                            j += 1;
                        }
                    }
                }
                row += 1;
            }
        }
    }
    (MatI8 { rows: x.n * oh * ow, cols, data: out }, oh, ow)
}

/// Exact INT8 matmul reference: [M, K] x [K, N] -> [M, N] i32.
pub fn matmul_i8(x: &MatI8, w: &MatI8) -> MatI32 {
    assert_eq!(x.cols, w.rows, "K mismatch");
    let mut out = MatI32::zeros(x.rows, w.cols);
    for m in 0..x.rows {
        let xrow = x.row(m);
        let orow = &mut out.data[m * w.cols..(m + 1) * w.cols];
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let wrow = w.row(k);
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv as i32;
            }
        }
    }
    out
}

/// Reshape a matmul output [N*OH*OW, O] back to NCHW.
pub fn cols2im(out: &[i8], n: usize, oh: usize, ow: usize, o: usize) -> TensorI8 {
    assert_eq!(out.len(), n * oh * ow * o);
    let mut t = TensorI8::zeros(n, o, oh, ow);
    for nn in 0..n {
        for y in 0..oh {
            for x in 0..ow {
                let row = (nn * oh + y) * ow + x;
                for c in 0..o {
                    let idx = t.idx(nn, c, y, x);
                    t.data[idx] = out[row * o + c];
                }
            }
        }
    }
    t
}

/// 2x2/2 max pool.
pub fn maxpool2x2(x: &TensorI8) -> TensorI8 {
    let mut out = TensorI8::zeros(x.n, x.c, x.h / 2, x.w / 2);
    for n in 0..x.n {
        for c in 0..x.c {
            for y in 0..x.h / 2 {
                for xx in 0..x.w / 2 {
                    let m = x
                        .get(n, c, 2 * y, 2 * xx)
                        .max(x.get(n, c, 2 * y, 2 * xx + 1))
                        .max(x.get(n, c, 2 * y + 1, 2 * xx))
                        .max(x.get(n, c, 2 * y + 1, 2 * xx + 1));
                    let idx = out.idx(n, c, y, xx);
                    out.data[idx] = m;
                }
            }
        }
    }
    out
}

/// ReLU in place.
pub fn relu_i8(xs: &mut [i8]) {
    for v in xs {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// Exact depthwise conv (runs on the SIMD core, not the PIM array).
/// x: [N, C, H, W], w: per-channel [C, KH*KW] i8 -> i32 accumulators.
pub fn dwconv_i8(x: &TensorI8, w: &MatI8, g: ConvGeom) -> Vec<i32> {
    assert_eq!(w.rows, x.c);
    assert_eq!(w.cols, g.kh * g.kw);
    let (oh, ow) = g.out_hw(x.h, x.w);
    let mut out = vec![0i32; x.n * x.c * oh * ow];
    let mut i = 0;
    for n in 0..x.n {
        for c in 0..x.c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i32;
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            let iy = oy * g.stride + ky;
                            let ix = ox * g.stride + kx;
                            if iy >= g.pad && ix >= g.pad && iy - g.pad < x.h && ix - g.pad < x.w {
                                acc += x.get(n, c, iy - g.pad, ix - g.pad) as i32
                                    * w.get(c, ky * g.kw + kx) as i32;
                            }
                        }
                    }
                    out[i] = acc;
                    i += 1;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{check_cases, Rng};

    fn rand_tensor(rng: &mut Rng, n: usize, c: usize, h: usize, w: usize) -> TensorI8 {
        let data = (0..n * c * h * w).map(|_| rng.int8()).collect();
        TensorI8::from_vec(n, c, h, w, data)
    }

    #[test]
    fn im2col_identity_1x1() {
        let mut rng = Rng::new(1);
        let x = rand_tensor(&mut rng, 1, 3, 4, 4);
        let (cols, oh, ow) = im2col(&x, ConvGeom { kh: 1, kw: 1, stride: 1, pad: 0 });
        assert_eq!((oh, ow), (4, 4));
        assert_eq!(cols.rows, 16);
        assert_eq!(cols.cols, 3);
        // row (y, x) must equal the channel vector at that pixel
        for y in 0..4 {
            for x2 in 0..4 {
                for c in 0..3 {
                    assert_eq!(cols.get(y * 4 + x2, c), x.get(0, c, y, x2));
                }
            }
        }
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        let mut rng = Rng::new(2);
        let x = rand_tensor(&mut rng, 2, 4, 6, 6);
        let g = ConvGeom { kh: 3, kw: 3, stride: 1, pad: 1 };
        let o = 5;
        let wdata: Vec<i8> = (0..4 * 9 * o).map(|_| rng.int8()).collect();
        // weight as [K=36, N=o], column n = filter n, rows ordered (c,kh,kw)
        let wmat = MatI8::from_vec(36, o, {
            let mut m = vec![0i8; 36 * o];
            for n in 0..o {
                for k in 0..36 {
                    m[k * o + n] = wdata[n * 36 + k];
                }
            }
            m
        });
        let (cols, oh, ow) = im2col(&x, g);
        let got = matmul_i8(&cols, &wmat);
        // direct conv
        for n in 0..2 {
            for f in 0..o {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0i32;
                        for c in 0..4 {
                            for ky in 0..3 {
                                for kx in 0..3 {
                                    let iy = oy as i32 + ky as i32 - 1;
                                    let ix = ox as i32 + kx as i32 - 1;
                                    if iy >= 0 && ix >= 0 && iy < 6 && ix < 6 {
                                        acc += x.get(n, c, iy as usize, ix as usize) as i32
                                            * wdata[f * 36 + (c * 3 + ky) * 3 + kx] as i32;
                                    }
                                }
                            }
                        }
                        let row = (n * oh + oy) * ow + ox;
                        assert_eq!(got.get(row, f), acc, "n{n} f{f} {oy},{ox}");
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_property_vs_naive() {
        check_cases(16, |rng| {
            let (m, k, n) = (
                1 + rng.below(8) as usize,
                1 + rng.below(16) as usize,
                1 + rng.below(8) as usize,
            );
            let x = MatI8::from_vec(m, k, (0..m * k).map(|_| rng.int8()).collect());
            let w = MatI8::from_vec(k, n, (0..k * n).map(|_| rng.int8()).collect());
            let got = matmul_i8(&x, &w);
            for mm in 0..m {
                for nn in 0..n {
                    let want: i32 =
                        (0..k).map(|kk| x.get(mm, kk) as i32 * w.get(kk, nn) as i32).sum();
                    if got.get(mm, nn) != want {
                        return Err(format!("({mm},{nn}): {} != {want}", got.get(mm, nn)));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn maxpool_basic() {
        let x = TensorI8::from_vec(1, 1, 2, 2, vec![1, -5, 3, 2]);
        let p = maxpool2x2(&x);
        assert_eq!(p.data, vec![3]);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut xs = vec![-3i8, 0, 7];
        relu_i8(&mut xs);
        assert_eq!(xs, vec![0, 0, 7]);
    }

    #[test]
    fn flatten_hwc_order() {
        // c=2, h=1, w=2 -> order (y0x0c0, y0x0c1, y0x1c0, y0x1c1)
        let x = TensorI8::from_vec(1, 2, 1, 2, vec![1, 2, 3, 4]); // c0: [1,2], c1: [3,4]
        let f = x.flatten_hwc();
        assert_eq!(f.data, vec![1, 3, 2, 4]);
    }

    #[test]
    fn cols2im_roundtrip() {
        let mut rng = Rng::new(4);
        let x = rand_tensor(&mut rng, 2, 3, 4, 4);
        // 1x1 conv with identity-ish weight (delta on channel) reconstructs
        let (cols, oh, ow) = im2col(&x, ConvGeom { kh: 1, kw: 1, stride: 1, pad: 0 });
        let flat: Vec<i8> = cols.data.clone();
        let t = cols2im(&flat, 2, oh, ow, 3);
        assert_eq!(t, x);
    }

    #[test]
    fn dwconv_matches_naive_3x3() {
        let mut rng = Rng::new(5);
        let x = rand_tensor(&mut rng, 1, 2, 4, 4);
        let w = MatI8::from_vec(2, 9, (0..18).map(|_| rng.int8()).collect());
        let g = ConvGeom { kh: 3, kw: 3, stride: 1, pad: 1 };
        let out = dwconv_i8(&x, &w, g);
        // spot check center position channel 1
        let (oy, ox, c) = (2usize, 1usize, 1usize);
        let mut acc = 0i32;
        for ky in 0..3 {
            for kx in 0..3 {
                let iy = oy + ky;
                let ix = ox + kx;
                if iy >= 1 && ix >= 1 && iy - 1 < 4 && ix - 1 < 4 {
                    acc += x.get(0, c, iy - 1, ix - 1) as i32 * w.get(c, ky * 3 + kx) as i32;
                }
            }
        }
        assert_eq!(out[(c * 4 + oy) * 4 + ox], acc);
    }
}
