//! Energy model: per-event energies + accounting.
//!
//! Substitution for the paper's post-layout power analysis (DESIGN.md
//! §3/§7): a 28 nm-class per-event energy table whose *relative*
//! magnitudes follow the ADC-less digital SRAM-PIM macro of Yan et al.
//! [20] (the macro the paper extends) and SRAM-compiler buffer
//! estimates. Both machines (DB-PIM and the dense baseline) share this
//! table, so the reported energy *ratios* depend only on the event
//! counts produced by the cycle-accurate simulation — which is exactly
//! the quantity the paper's Fig. 11/12 claims are about.

/// Per-event energies in picojoules.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyTable {
    /// One macro row-step bit-cycle *per active DBMU column*: 16
    /// compartments × (2 bitwise ANDs in the LPU) + the column's share
    /// of the CSD adder tree. ≈ 0.056 pJ/cell-op × 16.
    pub macro_col_cycle_pj: f64,
    /// Fixed per-macro-cycle overhead (wordline drivers, controllers).
    pub macro_cycle_base_pj: f64,
    /// Input buffer read, per 128-bit access.
    pub input_buf_read_pj: f64,
    /// Output buffer write, per 32-bit partial sum.
    pub output_buf_write_pj: f64,
    /// Output buffer read (accumulator reload), per 32-bit word.
    pub output_buf_read_pj: f64,
    /// Metadata RF read (signs + indices for one row-step).
    pub meta_rf_read_pj: f64,
    /// Mask RF read (one α-block mask word).
    pub mask_rf_read_pj: f64,
    /// Sparse-allocation-network switch: one extracted input feature.
    pub alloc_switch_pj: f64,
    /// IPU zero-column detection for one 16-input group.
    pub ipu_detect_pj: f64,
    /// One SIMD lane-op (8-bit ALU op).
    pub simd_lane_op_pj: f64,
    /// Writing one weight bit (cell) during tile load.
    pub weight_write_pj: f64,
    /// Re-deriving + comparing one ABFT checksum word at tile load
    /// (cell-fault model on, DESIGN.md §13).
    pub abft_check_pj: f64,
    /// Instruction fetch + decode.
    pub instr_pj: f64,
    /// Static leakage per core per cycle.
    pub leakage_core_cycle_pj: f64,
}

impl EnergyTable {
    /// The default 28 nm-class table.
    pub fn default28nm() -> Self {
        Self {
            macro_col_cycle_pj: 0.90,
            macro_cycle_base_pj: 3.6,
            input_buf_read_pj: 5.2,
            output_buf_write_pj: 6.0,
            output_buf_read_pj: 4.8,
            meta_rf_read_pj: 0.8,
            mask_rf_read_pj: 0.6,
            alloc_switch_pj: 0.35,
            ipu_detect_pj: 0.6,
            simd_lane_op_pj: 1.1,
            weight_write_pj: 0.05,
            abft_check_pj: 0.7,
            instr_pj: 0.4,
            leakage_core_cycle_pj: 0.9,
        }
    }
}

/// Raw event counts accumulated by the simulator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EventCounts {
    /// Σ over macro bit-cycles of the number of active DBMU columns.
    pub macro_col_cycles: u64,
    /// Macro bit-cycles (row-step × input-bit iterations).
    pub macro_cycles: u64,
    /// 128-bit input buffer reads.
    pub input_buf_reads: u64,
    /// 32-bit output buffer writes.
    pub output_buf_writes: u64,
    /// 32-bit output buffer reads.
    pub output_buf_reads: u64,
    /// Metadata RF reads.
    pub meta_rf_reads: u64,
    /// Mask RF reads.
    pub mask_rf_reads: u64,
    /// Allocation-network extractions.
    pub alloc_switches: u64,
    /// IPU group detections.
    pub ipu_detects: u64,
    /// SIMD lane-ops.
    pub simd_lane_ops: u64,
    /// Weight cell writes.
    pub weight_writes: u64,
    /// ABFT checksum words verified at tile load (cell-fault model).
    pub abft_checks: u64,
    /// Instructions executed.
    pub instrs: u64,
    /// Total elapsed cycles × active cores (for leakage).
    pub core_cycles: u64,
    // ---- non-energy bookkeeping ----
    /// ABFT checksum mismatches raised (typed corruption detections;
    /// counted per verification, so every tile load of a corrupted
    /// assignment raises its mismatches again).
    pub fault_detections: u64,
    /// Total elapsed cycles (makespan).
    pub elapsed_cycles: u64,
    /// Σ active columns over compute cycles (U_act numerator; the
    /// denominator is macro_cycles × macro_columns).
    pub active_col_cycles: u64,
    /// MAC operations actually performed.
    pub macs: u64,
}

/// `events += &delta` — the deterministic merge the barrier scheduler
/// uses to fold per-core counts (in ascending core order; all fields
/// are u64 sums, so the merge is exact regardless of execution order).
impl std::ops::AddAssign<&EventCounts> for EventCounts {
    fn add_assign(&mut self, other: &EventCounts) {
        self.add(other);
    }
}

impl std::ops::AddAssign for EventCounts {
    fn add_assign(&mut self, other: EventCounts) {
        self.add(&other);
    }
}

impl EventCounts {
    pub fn add(&mut self, other: &EventCounts) {
        self.macro_col_cycles += other.macro_col_cycles;
        self.macro_cycles += other.macro_cycles;
        self.input_buf_reads += other.input_buf_reads;
        self.output_buf_writes += other.output_buf_writes;
        self.output_buf_reads += other.output_buf_reads;
        self.meta_rf_reads += other.meta_rf_reads;
        self.mask_rf_reads += other.mask_rf_reads;
        self.alloc_switches += other.alloc_switches;
        self.ipu_detects += other.ipu_detects;
        self.simd_lane_ops += other.simd_lane_ops;
        self.weight_writes += other.weight_writes;
        self.abft_checks += other.abft_checks;
        self.instrs += other.instrs;
        self.core_cycles += other.core_cycles;
        self.fault_detections += other.fault_detections;
        self.elapsed_cycles += other.elapsed_cycles;
        self.active_col_cycles += other.active_col_cycles;
        self.macs += other.macs;
    }

    /// Total energy in picojoules under `table`.
    pub fn energy_pj(&self, table: &EnergyTable) -> f64 {
        self.macro_col_cycles as f64 * table.macro_col_cycle_pj
            + self.macro_cycles as f64 * table.macro_cycle_base_pj
            + self.input_buf_reads as f64 * table.input_buf_read_pj
            + self.output_buf_writes as f64 * table.output_buf_write_pj
            + self.output_buf_reads as f64 * table.output_buf_read_pj
            + self.meta_rf_reads as f64 * table.meta_rf_read_pj
            + self.mask_rf_reads as f64 * table.mask_rf_read_pj
            + self.alloc_switches as f64 * table.alloc_switch_pj
            + self.ipu_detects as f64 * table.ipu_detect_pj
            + self.simd_lane_ops as f64 * table.simd_lane_op_pj
            + self.weight_writes as f64 * table.weight_write_pj
            + self.abft_checks as f64 * table.abft_check_pj
            + self.instrs as f64 * table.instr_pj
            + self.core_cycles as f64 * table.leakage_core_cycle_pj
    }

    /// Per-component energy breakdown (label, pJ) for reports.
    pub fn energy_breakdown(&self, t: &EnergyTable) -> Vec<(&'static str, f64)> {
        vec![
            ("macro_array", self.macro_col_cycles as f64 * t.macro_col_cycle_pj
                + self.macro_cycles as f64 * t.macro_cycle_base_pj),
            ("input_buffer", self.input_buf_reads as f64 * t.input_buf_read_pj),
            ("output_buffer", self.output_buf_writes as f64 * t.output_buf_write_pj
                + self.output_buf_reads as f64 * t.output_buf_read_pj),
            ("metadata_rf", self.meta_rf_reads as f64 * t.meta_rf_read_pj
                + self.mask_rf_reads as f64 * t.mask_rf_read_pj),
            ("alloc_network", self.alloc_switches as f64 * t.alloc_switch_pj),
            ("ipu", self.ipu_detects as f64 * t.ipu_detect_pj),
            ("simd_core", self.simd_lane_ops as f64 * t.simd_lane_op_pj),
            ("weight_load", self.weight_writes as f64 * t.weight_write_pj),
            ("abft", self.abft_checks as f64 * t.abft_check_pj),
            ("control", self.instrs as f64 * t.instr_pj),
            ("leakage", self.core_cycles as f64 * t.leakage_core_cycle_pj),
        ]
    }

    /// Actual utilization U_act (Eq. 2): effective compute cells over
    /// total compute cells engaged per macro cycle.
    pub fn u_act(&self, macro_columns: usize) -> f64 {
        if self.macro_cycles == 0 {
            return 0.0;
        }
        self.active_col_cycles as f64 / (self.macro_cycles * macro_columns as u64) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_is_linear_in_events() {
        let t = EnergyTable::default28nm();
        let mut a = EventCounts::default();
        a.macro_cycles = 10;
        a.macro_col_cycles = 100;
        let mut b = a.clone();
        b.add(&a);
        assert!((b.energy_pj(&t) - 2.0 * a.energy_pj(&t)).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let t = EnergyTable::default28nm();
        let mut e = EventCounts::default();
        e.macro_cycles = 7;
        e.macro_col_cycles = 93;
        e.input_buf_reads = 11;
        e.output_buf_writes = 13;
        e.output_buf_reads = 3;
        e.meta_rf_reads = 17;
        e.mask_rf_reads = 19;
        e.alloc_switches = 23;
        e.ipu_detects = 29;
        e.simd_lane_ops = 31;
        e.weight_writes = 37;
        e.abft_checks = 47;
        e.instrs = 41;
        e.core_cycles = 43;
        let total: f64 = e.energy_breakdown(&t).iter().map(|(_, v)| v).sum();
        assert!((total - e.energy_pj(&t)).abs() < 1e-9);
    }

    #[test]
    fn add_assign_matches_add() {
        let mut a = EventCounts::default();
        a.macro_cycles = 3;
        a.macs = 7;
        a.instrs = 11;
        let mut by_add = a.clone();
        by_add.add(&a);
        let mut by_ref = a.clone();
        by_ref += &a;
        let mut by_val = a.clone();
        by_val += a.clone();
        assert_eq!(by_add, by_ref);
        assert_eq!(by_add, by_val);
        assert_eq!(by_add.macro_cycles, 6);
        assert_eq!(by_add.macs, 14);
    }

    #[test]
    fn u_act_bounds() {
        let mut e = EventCounts::default();
        assert_eq!(e.u_act(16), 0.0);
        e.macro_cycles = 10;
        e.active_col_cycles = 160;
        assert!((e.u_act(16) - 1.0).abs() < 1e-12);
        e.active_col_cycles = 80;
        assert!((e.u_act(16) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn macro_energy_dominates_buffers_at_scale() {
        // sanity on table magnitudes: with 16 active columns the macro
        // cycle costs more than one buffer access, as in digital PIM.
        let t = EnergyTable::default28nm();
        let per_cycle = 16.0 * t.macro_col_cycle_pj + t.macro_cycle_base_pj;
        assert!(per_cycle > t.input_buf_read_pj);
        assert!(per_cycle > t.output_buf_write_pj);
    }
}
