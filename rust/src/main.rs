//! `dbpim` — DB-PIM leader CLI.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline registry):
//!
//! ```text
//! dbpim verify             run MiniNet on the simulator + golden HLO via
//!                          PJRT and compare logits bit-for-bit
//! dbpim simulate <net>     simulate one network (--arch, --value-sparsity,
//!                          --engine sequential|parallel)
//! dbpim fig3|fig11|fig12|fig13|table2|table3
//!                          regenerate a paper figure/table (prints the
//!                          rows + writes artifacts/<exp>.json)
//! dbpim serve --replay <trace.json> [--batch N]
//!                          replay a multi-tenant traffic trace through
//!                          the batched serving frontend (admission-order
//!                          results, p50/p99 latency, req/s)
//! dbpim serve --open-loop [--spec <openloop.json>] [--rate R]
//!             [--requests N] [--arrival poisson|bursty] [--deadline-ms D]
//!             [--queue-cap Q] [--chips C] [--scheme tp|pp|hybrid]
//!             [--batch B] [--seed S] [--rate-sweep]
//!                          run the open-loop continuous-batching serve
//!                          loop on a virtual clock: seeded arrivals,
//!                          bounded admission queue with shedding, EDF
//!                          deadlines, retries/timeouts; `--rate-sweep`
//!                          sweeps offered load to saturation. Fault
//!                          injection: `DBPIM_FAULT_SEED=N` (or a
//!                          "faults" object in the spec file) — see
//!                          DESIGN.md §11
//! dbpim shard-sweep        speedup-vs-chips table (1/4/16 chips, tensor
//!                          vs pipeline parallel) per zoo model, with the
//!                          interconnect charge broken out
//! dbpim fault-campaign [--models a,b] [--ber 1e-5,1e-4] [--repair
//!                      none|spares|both] [--seed S] [--fault-seed S]
//!                      [--check]
//!                          sweep the macro-level cell-fault model
//!                          (DESIGN.md §13): per (model, BER, repair)
//!                          cell report spare-repair coverage, the
//!                          detected/undetected output-error split vs
//!                          the fault-free reference, and the ABFT
//!                          latency/energy overhead. `--check` exits
//!                          nonzero unless repair is effective and no
//!                          corruption goes undetected (the CI smoke
//!                          gate); `--fault-seed` defaults to
//!                          `DBPIM_CELL_FAULT_SEED`, then `--seed`
//! dbpim explore [--models a,b] [--seed S] [--check]
//!                          design-space explorer (DESIGN.md §14):
//!                          sweep each model (transformers expand over
//!                          two sequence lengths) across arch variants
//!                          (cores, macro count, tile shape, CSD
//!                          on/off) and fleet points, then mark the
//!                          speedup-vs-energy Pareto frontier per
//!                          model. `--check` exits nonzero unless
//!                          every model's frontier is non-empty and
//!                          non-dominated (the CI smoke gate)
//! dbpim info               architecture summary + effective topology
//!                          (pool, fleet, kernel backend, cache shards)
//! ```
//!
//! `--workers N` (any subcommand) sizes the shared worker pool; the
//! `DBPIM_WORKERS` env var is consulted when the flag is absent, and
//! `default_workers()` otherwise. Results never depend on the count.
//!
//! `--chips N --scheme tp|pp|hybrid` (on `simulate` and `serve`) runs
//! the workload on a sharded multi-chip fleet through
//! `coordinator::sharding` (DESIGN.md §12): tensor parallelism splits
//! each layer's filters across chips, pipeline parallelism maps layer
//! ranges to stages, and a deterministic interconnect cost model
//! charges the communication. `--chips 1` is bit-identical to the
//! single-chip path under every scheme.
//!
//! `--kernel auto|scalar|swar|wide` (any subcommand) forces the kernel
//! backend policy; the `DBPIM_KERNEL` env var is consulted when the
//! flag is absent, and per-shape auto selection otherwise
//! (sim::backend). Results never depend on the choice — every backend
//! is bit-identical to the scalar oracle.
//!
//! The CLI is all user input: `unwrap`/`expect` are linted out — parse
//! failures print usage and exit with a code, they never panic.
#![warn(clippy::unwrap_used, clippy::expect_used)]

use dbpim::arch::ArchConfig;
use dbpim::benchlib::{f2, pct, print_table};
use dbpim::compiler::{CompileCache, SparsityConfig};
use dbpim::coordinator::arrivals::ArrivalProcess;
use dbpim::coordinator::experiments as exp;
use dbpim::coordinator::faults::FaultSpec;
use dbpim::coordinator::serve;
use dbpim::coordinator::serve_loop::OpenLoopSpec;
use dbpim::coordinator::sharding::{self, ShardSpec};
use dbpim::json;
use dbpim::models;
use dbpim::sim;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // Global flag, valid on every subcommand: size the worker pool
    // before anything initializes it.
    if let Some(i) = args.iter().position(|a| a == "--workers") {
        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
            Some(n) if n >= 1 => {
                dbpim::coordinator::pool::configure_workers(n);
                args.drain(i..=i + 1);
            }
            _ => {
                eprintln!("--workers expects a positive integer");
                std::process::exit(2);
            }
        }
    }
    // Global flag: force the kernel-backend policy before the first
    // compile resolves it.
    if let Some(i) = args.iter().position(|a| a == "--kernel") {
        match args.get(i + 1).map(String::as_str).and_then(dbpim::sim::backend::KernelPolicy::parse)
        {
            Some(p) => {
                dbpim::sim::backend::configure_kernel(p);
                args.drain(i..=i + 1);
            }
            None => {
                eprintln!("--kernel expects auto|scalar|swar|wide");
                std::process::exit(2);
            }
        }
    }
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "verify" => cmd_verify(),
        "simulate" => cmd_simulate(&args[1..]),
        "fig3" => cmd_fig3(),
        "fig11" => cmd_fig11(),
        "fig12" => cmd_fig12(),
        "fig13" => cmd_fig13(),
        "table2" => cmd_table2(),
        "table3" => cmd_table3(),
        "energy" => cmd_energy(&args[1..]),
        "trace" => cmd_trace(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "shard-sweep" => cmd_shard_sweep(),
        "fault-campaign" => cmd_fault_campaign(&args[1..]),
        "explore" => cmd_explore(&args[1..]),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: dbpim <verify|simulate|energy|trace|serve|shard-sweep|fault-campaign|explore|fig3|fig11|fig12|fig13|table2|table3|info> [--workers N] [--kernel auto|scalar|swar|wide]"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Parse an optional integer flag with a lower bound. `Ok(None)` when
/// absent; `Err(exit_code)` (after printing usage) when malformed.
fn usize_flag(args: &[String], name: &str, min: usize) -> Result<Option<usize>, i32> {
    match flag_value(args, name) {
        None => Ok(None),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= min => Ok(Some(n)),
            _ => {
                eprintln!("{name} expects an integer >= {min}");
                Err(2)
            }
        },
    }
}

/// Parse the shared `--chips N --scheme tp|pp|hybrid` fleet flags.
/// `Ok(None)` when both are absent (plain single-chip run); `--scheme`
/// alone implies `--chips 1`, `--chips` alone implies tensor parallel.
fn shard_flags(args: &[String]) -> Result<Option<ShardSpec>, i32> {
    let chips = usize_flag(args, "--chips", 1)?;
    let scheme = flag_value(args, "--scheme");
    if chips.is_none() && scheme.is_none() {
        return Ok(None);
    }
    let name = scheme.unwrap_or_else(|| "tp".to_string());
    match ShardSpec::parse(chips.unwrap_or(1), &name) {
        Some(spec) => Ok(Some(spec)),
        None => {
            eprintln!("--scheme expects tp|pp|hybrid");
            Err(2)
        }
    }
}

fn write_report(name: &str, value: &json::Value) {
    let dir = models::default_artifacts_dir();
    let path = dir.join(format!("{name}.json"));
    if std::fs::create_dir_all(&dir).is_ok() && std::fs::write(&path, json::to_string(value)).is_ok()
    {
        println!("wrote {path:?}");
    }
}

fn cmd_verify() -> i32 {
    let dir = models::default_artifacts_dir();
    let net = match models::load_mininet(&dir) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("error loading artifacts: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };
    println!(
        "MiniNet: {} layers, batch {}, {} classes",
        net.layers.len(),
        net.batch,
        net.num_classes
    );

    // 1. simulator (DB-PIM + baseline)
    let (run_d, run_b) = match (
        sim::pipeline::run_mininet(&net, &ArchConfig::db_pim()),
        sim::pipeline::run_mininet(&net, &ArchConfig::dense_baseline()),
    ) {
        (Ok(d), Ok(b)) => (d, b),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("simulation failed: {e:#}");
            return 1;
        }
    };
    let sim_ok = run_d.matches_golden(&net) && run_b.matches_golden(&net);
    println!("simulator vs exported golden: {}", if sim_ok { "BIT-EXACT" } else { "MISMATCH" });

    // 2. golden HLO through PJRT
    match dbpim::runtime::run_golden_mininet(&net) {
        Ok(logits) => {
            let pjrt_ok = logits == net.golden && logits == run_d.logits;
            println!(
                "PJRT golden HLO vs simulator: {}",
                if pjrt_ok { "BIT-EXACT" } else { "MISMATCH" }
            );
            if !pjrt_ok {
                return 1;
            }
        }
        Err(e) => {
            eprintln!("PJRT execution failed: {e:#}");
            return 1;
        }
    }

    println!(
        "DB-PIM: {} cycles, {:.2} µs, {:.3} µJ | baseline: {} cycles ⇒ speedup {:.2}×, energy saving {}",
        run_d.total_cycles(),
        run_d.time_us(),
        run_d.energy_uj(),
        run_b.total_cycles(),
        run_b.total_cycles() as f64 / run_d.total_cycles() as f64,
        pct(1.0 - run_d.energy_uj() / run_b.energy_uj()),
    );
    if sim_ok {
        0
    } else {
        1
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let name = args.first().map(String::as_str).unwrap_or("resnet18");
    let Some(net) = models::by_name(name) else {
        eprintln!(
            "unknown network {name} (try: alexnet vgg19 resnet18 mobilenet_v2 efficientnet_b0 bert_base gpt_micro tiny_transformer)"
        );
        return 2;
    };
    let mut arch = match flag_value(args, "--arch") {
        None => ArchConfig::db_pim(),
        Some(name) => match ArchConfig::by_name(&name) {
            Some(a) => a,
            None => {
                eprintln!(
                    "unknown arch {name} (try: db-pim baseline bit-only value-only weights-only dac24)"
                );
                return 2;
            }
        },
    };
    // DBPIM_CELL_FAULT_SEED turns on the stock cell-fault mix
    // (DESIGN.md §13) for plain simulations; sharded fleets derive
    // per-chip defect patterns from it.
    if let Some(f) = dbpim::arch::CellFaultSpec::from_env() {
        arch.cell_faults = f;
    }
    let v = flag_value(args, "--value-sparsity").and_then(|s| s.parse().ok()).unwrap_or(0.6);
    let sp = if args.iter().any(|a| a == "--no-fta") {
        SparsityConfig { value_sparsity: v, fta: false }
    } else {
        SparsityConfig::hybrid(v)
    };
    let engine = match flag_value(args, "--engine").as_deref() {
        None => sim::Engine::Parallel,
        Some(s) => match sim::Engine::parse(s) {
            Some(e) => e,
            None => {
                eprintln!("unknown engine {s} (sequential|parallel)");
                return 2;
            }
        },
    };
    let shard = match shard_flags(args) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let t0 = std::time::Instant::now();
    if let Some(fleet) = shard {
        let (compile, simc) = (CompileCache::new(), sim::SimCache::new());
        let r = sharding::simulate_sharded(&net, sp, &arch, 42, fleet, engine, &compile, &simc);
        println!(
            "{name} on {} x{} chips ({}, {engine:?} engine): {} cycles ({:.3} ms), interconnect {} cycles / {} bytes",
            arch.name,
            fleet.chips,
            fleet.scheme.name(),
            r.fleet_cycles(),
            r.report.time_ms(),
            r.interconnect_cycles,
            r.interconnect_bytes,
        );
        if r.pipeline_interval_cycles != r.fleet_cycles() {
            println!("  steady-state interval: {} cycles/inference", r.pipeline_interval_cycles);
        }
        for (c, cyc) in r.chip_cycles.iter().enumerate() {
            println!("  chip {c}: {cyc} busy cycles");
        }
        println!("simulated in {:?} host time", t0.elapsed());
        return 0;
    }
    let r = sim::simulate_network_with_engine(&net, sp, &arch, 42, engine);
    println!(
        "{name} on {} ({engine:?} engine): {} cycles ({:.3} ms @ {:.0} MHz), PIM-only {:.3} ms, {:.1} µJ, U_act {}",
        arch.name,
        r.total_cycles(),
        r.time_ms(),
        arch.freq_mhz,
        r.pim_time_ms(),
        r.energy_uj(),
        pct(r.u_act()),
    );
    println!("simulated in {:?} host time", t0.elapsed());
    for (cat, share) in r.category_breakdown() {
        println!("  {:?}: {}", cat, pct(share));
    }
    0
}

fn cmd_fig3() -> i32 {
    let (bits, cols) = exp::fig3(42);
    print_table(
        "Fig. 3(a) — zero-bit proportion in weights (CSD)",
        &["network", "original", "60% value-pruned", "hybrid (ours)"],
        &bits
            .iter()
            .map(|r| vec![r.network.clone(), pct(r.original), pct(r.value_pruned), pct(r.hybrid)])
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 3(b) — all-zero input bit columns by group size",
        &["network", "N=1", "N=8", "N=16"],
        &cols
            .iter()
            .map(|r| vec![r.network.clone(), pct(r.group1), pct(r.group8), pct(r.group16)])
            .collect::<Vec<_>>(),
    );
    write_report("fig3", &exp::fig3_json(&bits, &cols));
    0
}

fn cmd_fig11() -> i32 {
    let (rows, stats) = exp::fig11_with_stats(42);
    print_table(
        "Fig. 11 — speedup & energy saving vs dense PIM (weight sparsity only)",
        &["network", "total sparsity", "speedup", "energy saving"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    pct(r.total_sparsity),
                    format!("{}x", f2(r.speedup)),
                    pct(r.energy_saving),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("fig11", &exp::fig11_json(&rows));
    0
}

fn cmd_fig12() -> i32 {
    let (rows, stats) = exp::fig12_with_stats(42);
    print_table(
        "Fig. 12 — end-to-end breakdown by sparsity approach",
        &["network", "approach", "speedup", "normalized energy"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    r.approach.to_string(),
                    format!("{}x", f2(r.speedup)),
                    f2(r.energy_norm),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("fig12", &exp::fig12_json(&rows));
    0
}

fn cmd_fig13() -> i32 {
    let rows = exp::fig13(42);
    print_table(
        "Fig. 13 — execution-time breakdown",
        &["network", "pw/std-conv+FC", "dw-conv", "mul", "etc"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    pct(r.pw_std_conv_fc),
                    pct(r.dw_conv),
                    pct(r.mul),
                    pct(r.etc),
                ]
            })
            .collect::<Vec<_>>(),
    );
    write_report("fig13", &exp::fig13_json(&rows));
    0
}

fn cmd_table2() -> i32 {
    let (t, stats) = exp::table2_with_stats(42);
    println!("Table II — this work:");
    println!("  macros: {}  PIM capacity: {} KB", t.total_macros, t.pim_kb);
    println!(
        "  peak: {:.2} TOPS | {:.1} GOPS/macro (φ=1), {:.1} (φ=2), {:.1} (dense mapping)",
        t.peak_tops_phi1,
        t.peak_gops_per_macro_phi1,
        t.peak_gops_per_macro_phi2,
        t.dense_gops_per_macro
    );
    print_table(
        "Measured actual utilization U_act (hybrid, 60% value + FTA)",
        &["network", "U_act"],
        &t.u_act.iter().map(|(n, u)| vec![n.clone(), pct(*u)]).collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("table2", &exp::table2_json(&t));
    0
}

fn cmd_table3() -> i32 {
    let (rows, stats) = exp::table3_with_stats(42);
    print_table(
        "Table III — on-chip execution time, std/pw-conv + FC only (ms)",
        &["network", "DAC'24", "bit-level", "hybrid", "hybrid speedup vs DAC'24"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    f2(r.dac24_ms),
                    f2(r.bit_level_ms),
                    f2(r.hybrid_ms),
                    format!("{}x", f2(r.dac24_ms / r.hybrid_ms)),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("table3", &exp::table3_json(&rows));
    0
}

/// Per-component energy breakdown of a simulated run (Fig. 12-style
/// energy accounting, per hardware block).
fn cmd_energy(args: &[String]) -> i32 {
    let name = args.first().map(String::as_str).unwrap_or("resnet18");
    let Some(net) = models::by_name(name) else {
        eprintln!("unknown network {name}");
        return 2;
    };
    let table = dbpim::energy::EnergyTable::default28nm();
    for arch in [ArchConfig::db_pim(), ArchConfig::dense_baseline()] {
        let sp = if arch.weight_bit_sparsity {
            SparsityConfig::hybrid(0.6)
        } else {
            SparsityConfig::dense()
        };
        let r = sim::simulate_network(&net, sp, &arch, 42);
        let breakdown = r.totals.energy_breakdown(&table);
        let total: f64 = breakdown.iter().map(|(_, v)| v).sum();
        println!("\n{name} on {} — total {:.1} µJ", arch.name, total / 1e6);
        for (label, pj) in breakdown {
            println!("  {label:14} {:>9.2} µJ  ({})", pj / 1e6, pct(pj / total));
        }
    }
    0
}

/// Dump a Chrome/Perfetto trace of one simulated inference.
fn cmd_trace(args: &[String]) -> i32 {
    let name = args.first().map(String::as_str).unwrap_or("mobilenet_v2");
    let Some(net) = models::by_name(name) else {
        eprintln!("unknown network {name}");
        return 2;
    };
    let out = flag_value(args, "--out").unwrap_or_else(|| format!("{name}_trace.json"));
    let r = sim::simulate_network(&net, SparsityConfig::hybrid(0.6), &ArchConfig::db_pim(), 42);
    let text = dbpim::sim::trace::chrome_trace(&r);
    match std::fs::write(&out, &text) {
        Ok(()) => {
            println!("wrote {out} ({} bytes) — open in ui.perfetto.dev", text.len());
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

/// Replay a traffic trace through the batched multi-tenant serving
/// frontend: admission-ordered results, p50/p99 simulated latency and
/// host-side throughput (DESIGN.md §9).
fn cmd_serve(args: &[String]) -> i32 {
    if args.iter().any(|a| a == "--open-loop") {
        return cmd_serve_open_loop(args);
    }
    let Some(path) = flag_value(args, "--replay") else {
        eprintln!(
            "usage: dbpim serve --replay <trace.json> [--batch N] [--workers N] [--chips N --scheme tp|pp|hybrid]\n       dbpim serve --open-loop [--spec <openloop.json>] [--rate R] [--requests N] [--rate-sweep]"
        );
        return 2;
    };
    let batch = match flag_value(args, "--batch") {
        None => 8,
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("--batch expects a positive integer");
                return 2;
            }
        },
    };
    let fleet = match shard_flags(args) {
        Ok(s) => s,
        Err(code) => return code,
    };
    let spec = match serve::ServeSpec::load(&path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error loading trace: {e}");
            return 1;
        }
    };
    let run = match fleet {
        Some(f) => spec.run_fleet(batch, f),
        None => spec.run(batch),
    };
    let (results, stats) = match run {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve error: {e}");
            return 1;
        }
    };
    if let Some(f) = fleet {
        let scheme = f.scheme.name();
        println!("fleet: {} chip(s), scheme {scheme} (latencies include interconnect)", f.chips);
    }
    // per-model latency aggregation (admission order preserved per row)
    let mut agg: Vec<(String, usize, f64)> = Vec::new();
    for (r, lat) in results.iter().zip(&stats.latencies_ms) {
        match agg.iter_mut().find(|a| a.0 == r.network) {
            Some(a) => {
                a.1 += 1;
                a.2 += lat;
            }
            None => agg.push((r.network.clone(), 1, *lat)),
        }
    }
    print_table(
        "Serve replay — per-model simulated latency",
        &["model", "requests", "mean latency (ms)"],
        &agg.iter()
            .map(|(n, c, t)| vec![n.clone(), c.to_string(), f2(t / *c as f64)])
            .collect::<Vec<_>>(),
    );
    println!(
        "{} requests in {} batches (max batch {}): p50 {} ms / p99 {} ms simulated latency",
        stats.requests,
        stats.batches,
        stats.max_batch,
        f2(stats.p50_ms),
        f2(stats.p99_ms)
    );
    println!("host: {:?} wall, {:.1} req/s", stats.wall, stats.req_per_s);
    println!("compile cache: {}", stats.cache.compile.summary());
    println!("sim cache: {}", stats.cache.sim.summary());
    0
}

/// Open-loop serving: seeded arrival process on a virtual clock,
/// bounded admission queue with shedding, EDF deadlines, continuous
/// batching, deterministic fault injection (DESIGN.md §11).
fn cmd_serve_open_loop(args: &[String]) -> i32 {
    let mut spec = match flag_value(args, "--spec") {
        Some(path) => match OpenLoopSpec::load(&path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error loading open-loop spec: {e}");
                return 1;
            }
        },
        None => {
            // Stock workload: the two zoo models the replay example
            // serves, under the default loop parameters.
            let tpl = |model: &str, seed: u64| serve::ServeRequest {
                model: model.into(),
                arch: "db-pim".into(),
                sparsity: SparsityConfig::hybrid(0.6),
                seed,
            };
            OpenLoopSpec {
                models: vec!["resnet18".into(), "mobilenet_v2".into()],
                workload: vec![tpl("resnet18", 1), tpl("mobilenet_v2", 1)],
                arrivals: ArrivalProcess::Poisson { rate_rps: 500.0 },
                requests: 64,
                queue_cap: 64,
                deadline_ms: 50.0,
                timeout_ms: 200.0,
                max_batch: 8,
                chips: 2,
                scheme: None,
                max_retries: 3,
                backoff_ms: 1.0,
                seed: 42,
                faults: FaultSpec::off(),
                trace_events: false,
            }
        }
    };
    // CLI overrides on top of the spec (file or stock).
    if let Some(kind) = flag_value(args, "--arrival") {
        let rate = spec.arrivals.nominal_rps().max(1.0);
        spec.arrivals = match kind.as_str() {
            "poisson" => ArrivalProcess::Poisson { rate_rps: rate },
            "bursty" => ArrivalProcess::Bursty {
                base_rps: rate / 2.0,
                burst_rps: 2.0 * rate,
                mean_phase_ms: 25.0,
            },
            _ => {
                eprintln!("--arrival expects poisson|bursty");
                return 2;
            }
        };
    }
    if let Some(s) = flag_value(args, "--rate") {
        match s.parse::<f64>() {
            Ok(r) if r.is_finite() && r > 0.0 => {
                let nominal = spec.arrivals.nominal_rps();
                spec.arrivals = if nominal > 0.0 {
                    spec.arrivals.scaled(r / nominal)
                } else {
                    ArrivalProcess::Poisson { rate_rps: r }
                };
            }
            _ => {
                eprintln!("--rate expects a positive number (requests/second)");
                return 2;
            }
        }
    }
    // `--requests 0` is a valid (empty) run; the others must be >= 1.
    match usize_flag(args, "--requests", 0) {
        Err(code) => return code,
        Ok(Some(n)) => spec.requests = n,
        Ok(None) => {}
    }
    for (flag, slot) in [
        ("--queue-cap", &mut spec.queue_cap),
        ("--chips", &mut spec.chips),
        ("--batch", &mut spec.max_batch),
    ] {
        match usize_flag(args, flag, 1) {
            Err(code) => return code,
            Ok(Some(n)) => *slot = n,
            Ok(None) => {}
        }
    }
    // `--scheme` gangs the chips into one sharded logical server
    // (DESIGN.md §12) instead of independent replicas.
    if let Some(name) = flag_value(args, "--scheme") {
        match ShardSpec::parse(spec.chips.max(1), &name) {
            Some(s) => spec.scheme = Some(s.scheme),
            None => {
                eprintln!("--scheme expects tp|pp|hybrid");
                return 2;
            }
        }
    }
    if let Some(s) = flag_value(args, "--deadline-ms") {
        match s.parse::<f64>() {
            Ok(d) if d.is_finite() && d > 0.0 => {
                spec.deadline_ms = d;
                spec.timeout_ms = spec.timeout_ms.max(d);
            }
            _ => {
                eprintln!("--deadline-ms expects a positive number");
                return 2;
            }
        }
    }
    if let Some(s) = flag_value(args, "--seed") {
        match s.parse::<u64>() {
            Ok(n) => spec.seed = n,
            Err(_) => {
                eprintln!("--seed expects a non-negative integer");
                return 2;
            }
        }
    }
    // DBPIM_FAULT_SEED turns on the stock fault mix (CI fault leg).
    if let Some(f) = FaultSpec::from_env() {
        spec.faults = f;
    }

    if args.iter().any(|a| a == "--rate-sweep") {
        const FACTORS: [f64; 6] = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0];
        let sweep = match spec.rate_sweep(&FACTORS) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve error: {e}");
                return 1;
            }
        };
        print_table(
            "Open-loop rate sweep — goodput & SLO vs offered load",
            &[
                "load x", "offered rps", "goodput rps", "SLO", "done", "shed", "failed",
                "timeout", "retries", "p99 ms",
            ],
            &sweep
                .iter()
                .map(|(f, s)| {
                    vec![
                        f2(*f),
                        f2(s.offered_rps),
                        f2(s.goodput_rps),
                        pct(s.slo_attainment),
                        s.done.to_string(),
                        s.shed.to_string(),
                        s.failed.to_string(),
                        s.timed_out.to_string(),
                        s.retries.to_string(),
                        f2(s.p99_ms),
                    ]
                })
                .collect::<Vec<_>>(),
        );
        return 0;
    }

    let (_, stats) = match spec.run() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve error: {e}");
            return 1;
        }
    };
    println!(
        "open-loop serve: {} arrivals ({} at {} rps nominal), {} chip(s) x {} lanes, queue cap {}, deadline {} ms",
        stats.offered,
        spec.arrivals.name(),
        f2(stats.offered_rps),
        spec.chips,
        spec.max_batch,
        spec.queue_cap,
        f2(spec.deadline_ms),
    );
    if let Some(scheme) = spec.scheme {
        let name = scheme.name();
        println!("sharded fleet: 1 logical server of {} {name} shards", spec.chips);
    }
    if spec.faults.enabled() {
        println!(
            "faults on (seed {}): transient {} / spike {} at {}x / outages ~{} ms every ~{} ms",
            spec.faults.seed,
            pct(spec.faults.transient_rate),
            pct(spec.faults.spike_rate),
            f2(spec.faults.spike_factor),
            f2(spec.faults.down_duration_ms),
            f2(spec.faults.down_mean_ms),
        );
    }
    println!(
        "outcomes: {} done ({} in SLO) / {} shed / {} failed / {} timed out; {} retries, {} batches, peak queue {}",
        stats.done,
        stats.deadline_met,
        stats.shed,
        stats.failed,
        stats.timed_out,
        stats.retries,
        stats.batches,
        stats.peak_queue,
    );
    println!(
        "goodput {} rps, SLO attainment {}, latency p50 {} / p99 {} ms, makespan {} ms virtual",
        f2(stats.goodput_rps),
        pct(stats.slo_attainment),
        f2(stats.p50_ms),
        f2(stats.p99_ms),
        f2(stats.makespan_ms),
    );
    println!("host: {:?} wall", stats.wall);
    println!("compile cache: {}", stats.cache.compile.summary());
    println!("sim cache: {}", stats.cache.sim.summary());
    0
}

/// Speedup-vs-chips × scheme table over the zoo (DESIGN.md §12):
/// merged fleet cycles, the interconnect charge, and throughput speedup
/// against the memoized single-chip baseline.
fn cmd_shard_sweep() -> i32 {
    let (rows, stats) = exp::shard_sweep_with_stats(42);
    print_table(
        "Shard sweep — fleet cycles & speedup vs single chip",
        &["network", "scheme", "chips", "fleet cycles", "interconnect", "speedup"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    r.scheme.to_string(),
                    r.chips.to_string(),
                    r.fleet_cycles.to_string(),
                    r.interconnect_cycles.to_string(),
                    format!("{}x", f2(r.speedup)),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("shard_sweep", &exp::shard_sweep_json(&rows));
    0
}

/// Macro-level cell-fault campaign (DESIGN.md §13): BER × model ×
/// repair-strategy sweep reporting repair coverage, the
/// detected/undetected output-error split vs the fault-free reference,
/// and the ABFT verification overhead.
fn cmd_fault_campaign(args: &[String]) -> i32 {
    let models_arg = flag_value(args, "--models").unwrap_or_else(|| "resnet18".into());
    let nets: Vec<String> =
        models_arg.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    if nets.is_empty() {
        eprintln!("--models expects a comma-separated list of network names");
        return 2;
    }
    for n in &nets {
        if models::by_name(n).is_none() {
            eprintln!(
                "unknown network {n} (try: alexnet vgg19 resnet18 mobilenet_v2 efficientnet_b0 mininet tiny small)"
            );
            return 2;
        }
    }
    let bers: Vec<f64> = match flag_value(args, "--ber") {
        None => vec![1e-5, 1e-4, 1e-3],
        Some(s) => {
            let mut v = Vec::new();
            for tok in s.split(',') {
                match tok.trim().parse::<f64>() {
                    Ok(b) if b.is_finite() && (0.0..=1.0).contains(&b) => v.push(b),
                    _ => {
                        eprintln!("--ber expects comma-separated rates in [0, 1]");
                        return 2;
                    }
                }
            }
            v
        }
    };
    let repairs: Vec<&'static str> = match flag_value(args, "--repair").as_deref() {
        None | Some("both") => vec!["none", "spares"],
        Some("none") => vec!["none"],
        Some("spares") => vec!["spares"],
        Some(other) => {
            eprintln!("--repair expects none|spares|both, got {other}");
            return 2;
        }
    };
    let seed = match flag_value(args, "--seed") {
        None => 42,
        Some(s) => match s.parse::<u64>() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("--seed expects a non-negative integer");
                return 2;
            }
        },
    };
    let fault_seed = match flag_value(args, "--fault-seed") {
        Some(s) => match s.parse::<u64>() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("--fault-seed expects a non-negative integer");
                return 2;
            }
        },
        None => dbpim::arch::CellFaultSpec::from_env().map(|f| f.seed).unwrap_or(seed),
    };
    let (rows, stats) = exp::fault_campaign_with_stats(&nets, &bers, &repairs, seed, fault_seed);
    print_table(
        "Fault campaign — spare repair & ABFT detection per (model, BER, repair)",
        &[
            "network", "BER", "repair", "stuck", "repaired", "coverage", "injected", "detections",
            "bad layers", "undetected", "cycle ovh", "energy ovh",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    format!("{:.0e}", r.ber),
                    r.repair.to_string(),
                    r.stuck_columns.to_string(),
                    r.repaired_columns.to_string(),
                    pct(r.repair_coverage()),
                    r.injected_cells.to_string(),
                    r.detections.to_string(),
                    format!("{}/{}", r.corrupted_layers, r.pim_layers),
                    r.undetected_layers.to_string(),
                    pct(r.cycle_overhead),
                    pct(r.energy_overhead),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("fault_campaign", &exp::fault_campaign_json(&rows));
    if args.iter().any(|a| a == "--check") {
        let mut ok = true;
        for r in rows.iter().filter(|r| r.repair == "spares") {
            if r.stuck_columns > 0 && r.repaired_columns == 0 {
                eprintln!(
                    "check failed: {} @ BER {:.0e}: {} stuck columns, none repaired",
                    r.network, r.ber, r.stuck_columns
                );
                ok = false;
            }
            if r.undetected_layers > 0 {
                eprintln!(
                    "check failed: {} @ BER {:.0e}: {} corrupted layer(s) escaped ABFT detection",
                    r.network, r.ber, r.undetected_layers
                );
                ok = false;
            }
        }
        if !rows.iter().any(|r| r.repair == "spares") {
            eprintln!("check failed: no `spares` rows in the sweep (pass --repair spares|both)");
            ok = false;
        }
        if !ok {
            return 1;
        }
        println!("fault-campaign check: repair active, no silent corruption");
    }
    0
}

/// Design-space explorer (DESIGN.md §14): model × seq-len × arch
/// variant × fleet sweep with a per-model speedup-vs-energy Pareto
/// frontier.
fn cmd_explore(args: &[String]) -> i32 {
    let models_arg =
        flag_value(args, "--models").unwrap_or_else(|| "tiny_transformer,gpt_micro".into());
    let nets: Vec<String> =
        models_arg.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    if nets.is_empty() {
        eprintln!("--models expects a comma-separated list of model names");
        return 2;
    }
    for n in &nets {
        if models::by_name(n).is_none() {
            eprintln!(
                "unknown model {n} (try: bert_base gpt_micro tiny_transformer alexnet vgg19 resnet18 mobilenet_v2 efficientnet_b0)"
            );
            return 2;
        }
    }
    let seed = match flag_value(args, "--seed") {
        None => 42,
        Some(s) => match s.parse::<u64>() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("--seed expects a non-negative integer");
                return 2;
            }
        },
    };
    let (rows, stats) = exp::explore_with_stats(&nets, seed);
    print_table(
        "Design-space exploration — speedup vs energy per (model, seq, arch, fleet)",
        &["model", "network", "seq", "arch", "chips", "scheme", "cycles", "speedup", "energy uJ", "pareto"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.model.clone(),
                    r.network.clone(),
                    r.seq_len.to_string(),
                    r.arch.to_string(),
                    r.chips.to_string(),
                    r.scheme.to_string(),
                    r.cycles.to_string(),
                    format!("{}x", f2(r.speedup)),
                    f2(r.energy_uj),
                    if r.on_frontier { "*".into() } else { String::new() },
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    write_report("explore", &exp::explore_json(&rows));
    if args.iter().any(|a| a == "--check") {
        let mut ok = true;
        for n in &nets {
            let frontier: Vec<&exp::ExploreRow> =
                rows.iter().filter(|r| &r.model == n && r.on_frontier).collect();
            if frontier.is_empty() {
                eprintln!("check failed: {n}: empty Pareto frontier");
                ok = false;
                continue;
            }
            for f in frontier {
                let dominated = rows.iter().any(|o| {
                    o.model == f.model
                        && o.speedup >= f.speedup
                        && o.energy_uj <= f.energy_uj
                        && (o.speedup > f.speedup || o.energy_uj < f.energy_uj)
                });
                if dominated {
                    eprintln!(
                        "check failed: {n}: frontier row {} ({}, {} chips) is dominated",
                        f.network, f.arch, f.chips
                    );
                    ok = false;
                }
            }
        }
        if !ok {
            return 1;
        }
        println!("explore check: every model has a non-empty, non-dominated frontier");
    }
    0
}

fn cmd_info() -> i32 {
    for arch in [
        ArchConfig::db_pim(),
        ArchConfig::dense_baseline(),
        ArchConfig::bit_only(),
        ArchConfig::value_only(),
        ArchConfig::dac24(),
    ] {
        println!(
            "{:16} cores={} macros={} Tk={} cols={} bits={} {}{}{}simd={}",
            arch.name,
            arch.n_cores,
            arch.total_macros(),
            arch.k_slots(),
            arch.macro_columns,
            arch.input_bits,
            if arch.weight_bit_sparsity { "wbit " } else { "" },
            if arch.value_sparsity { "value " } else { "" },
            if arch.input_skipping { "ipu " } else { "" },
            arch.has_simd,
        );
    }
    println!(
        "worker pool: {} threads (set with --workers N or DBPIM_WORKERS)",
        dbpim::coordinator::pool::effective_workers()
    );
    let fleet = sharding::env_shard().unwrap_or_else(ShardSpec::single);
    let (tp, pp) = fleet.factors();
    println!(
        "fleet: {} chip(s), scheme {} (tp {tp} x pp {pp}; set with --chips/--scheme or DBPIM_CHIPS/DBPIM_SCHEME)",
        fleet.chips,
        fleet.scheme.name()
    );
    println!(
        "kernel policy: {} (set with --kernel or DBPIM_KERNEL; avx2 {})",
        dbpim::sim::backend::effective_policy().describe(),
        if dbpim::sim::backend::avx2_available() { "available" } else { "unavailable" }
    );
    println!(
        "caches: compile {} shards, sim {} shards",
        CompileCache::shard_count(),
        sim::SimCache::shard_count()
    );
    let a = ArchConfig::db_pim();
    match dbpim::arch::CellFaultSpec::from_env() {
        Some(f) => println!(
            "cell faults: ON via DBPIM_CELL_FAULT_SEED (seed {}, BER {:.0e} stuck0 / {:.0e} stuck1 / {:.0e} transient)",
            f.seed, f.ber_stuck0, f.ber_stuck1, f.ber_transient
        ),
        None => println!(
            "cell faults: off (enable with DBPIM_CELL_FAULT_SEED=N or `dbpim fault-campaign`)"
        ),
    }
    println!(
        "  repair budget: {} spare columns/macro, {} spare macro(s)/core; degrade policy {}",
        a.spare_columns_per_macro,
        a.spare_macros_per_core,
        a.fault_degrade.name()
    );
    0
}
