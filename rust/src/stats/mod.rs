//! Sparsity statistics — the Fig. 3 analyses and Table II metrics.
//!
//! * Fig. 3(a): proportion of zero bits in weights for the original
//!   model ("Ori."), the 60% value-pruned model ("Val."), and the
//!   hybrid-grained model ("Our") — measured here over synthesized
//!   trained-like weights for each of the five networks.
//! * Fig. 3(b): proportion of block-wise all-zero input bit columns for
//!   group sizes N = 1, 8, 16.

use crate::arch::ArchConfig;
use crate::csd;
use crate::fta;
use crate::models::{self, Network};
use crate::pruning;

/// One Fig. 3(a) row.
#[derive(Debug, Clone, PartialEq)]
pub struct ZeroBitStats {
    pub network: String,
    /// Zero-bit fraction of the original INT8 weights (CSD encoding).
    pub original: f64,
    /// After 60% coarse block pruning.
    pub value_pruned: f64,
    /// After hybrid pruning (60% value + FTA).
    pub hybrid: f64,
}

/// Compute Fig. 3(a) for one network over synthesized weights.
pub fn zero_bit_stats(net: &Network, value_sparsity: f64, seed: u64) -> ZeroBitStats {
    let arch = ArchConfig::db_pim();
    let mut ori_nz = 0u64;
    let mut ori_total = 0u64;
    let mut val_nz = 0u64;
    let mut hyb_nz = 0u64;
    for (idx, layer) in net.layers.iter().enumerate() {
        let Some((_, k, n_logical)) = layer.kind.matmul_dims() else { continue };
        let raw = models::synthesize_weights(seed ^ (idx as u64) << 8, k, n_logical);
        ori_nz += raw.iter().map(|&w| csd::phi(w) as u64).sum::<u64>();
        ori_total += (raw.len() * csd::NUM_DIGITS) as u64;

        let n = crate::util::round_up(n_logical, arch.alpha);
        let mut padded = vec![0i8; k * n];
        for row in 0..k {
            padded[row * n..row * n + n_logical]
                .copy_from_slice(&raw[row * n_logical..(row + 1) * n_logical]);
        }
        let mask = pruning::prune_blocks(&mut padded, k, n, value_sparsity, arch.alpha);
        // only count the logical (non-padding) columns
        let count_nz = |w: &[i8]| -> u64 {
            let mut nz = 0;
            for row in 0..k {
                for col in 0..n_logical {
                    nz += csd::phi(w[row * n + col]) as u64;
                }
            }
            nz
        };
        val_nz += count_nz(&padded);
        let expand = mask.expand();
        let (projected, _) = fta::fta_layer(&padded, k, n, Some(&expand));
        hyb_nz += count_nz(&projected);
    }
    let t = ori_total as f64;
    ZeroBitStats {
        network: net.name.clone(),
        original: 1.0 - ori_nz as f64 / t,
        value_pruned: 1.0 - val_nz as f64 / t,
        hybrid: 1.0 - hyb_nz as f64 / t,
    }
}

/// One Fig. 3(b) row: all-zero-column fraction per group size.
#[derive(Debug, Clone, PartialEq)]
pub struct ZeroColumnStats {
    pub network: String,
    pub group1: f64,
    pub group8: f64,
    pub group16: f64,
}

/// Compute Fig. 3(b) over ReLU-like synthesized activations sized by
/// the network's total activation volume.
pub fn zero_column_stats(net: &Network, seed: u64) -> ZeroColumnStats {
    // total activation elements across PIM layer inputs (capped)
    let elems: usize = net
        .layers
        .iter()
        .filter_map(|l| l.kind.matmul_dims())
        .map(|(m, k, _)| (m * k).min(1 << 18))
        .sum::<usize>()
        .min(1 << 22);
    let acts = models::synthesize_activations(seed, elems.max(1024));
    ZeroColumnStats {
        network: net.name.clone(),
        group1: pruning::group_zero_column_fraction(&acts, 1),
        group8: pruning::group_zero_column_fraction(&acts, 8),
        group16: pruning::group_zero_column_fraction(&acts, 16),
    }
}

/// Table II-style architectural throughput analysis (theoretical peak,
/// dataset-independent — "governed exclusively by architectural
/// characteristics").
#[derive(Debug, Clone, PartialEq)]
pub struct PeakThroughput {
    /// Peak GOPS per macro at 8b/8b (1 MAC = 2 OPs).
    pub gops_per_macro: f64,
    /// Whole-chip peak TOPS.
    pub tops: f64,
    /// Filters processed concurrently per macro at the given φ.
    pub filters_per_macro: usize,
}

/// Peak throughput under a uniform FTA threshold φ (1 or 2), or the
/// dense mapping when `phi == None`.
pub fn peak_throughput(arch: &ArchConfig, phi: Option<u8>) -> PeakThroughput {
    let filters = match phi {
        Some(p) => arch.macro_columns / p.max(1) as usize,
        None => arch.dense_filters_per_macro(),
    };
    // One full K-pass over the macro: compartments×rows MACs per filter
    // in rows × input_bits cycles (bit-serial inputs, dense input bits).
    let macs = (arch.k_slots() * filters) as f64;
    let cycles = (arch.rows_per_compartment * arch.input_bits) as f64;
    let macs_per_cycle = macs / cycles;
    let gops = 2.0 * macs_per_cycle * arch.freq_mhz * 1e6 / 1e9;
    PeakThroughput {
        gops_per_macro: gops,
        tops: gops * arch.total_macros() as f64 / 1e3,
        filters_per_macro: filters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_ordering_ori_lt_val_lt_hybrid() {
        let net = models::resnet18();
        // shrink: prefix for test speed
        let prefix = Network {
            name: "r18-prefix".into(),
            input_hw: net.input_hw,
            input_ch: net.input_ch,
            layers: net.layers[..6].to_vec(),
        };
        let s = zero_bit_stats(&prefix, 0.6, 1);
        assert!(s.original < s.value_pruned, "{s:?}");
        assert!(s.value_pruned < s.hybrid, "{s:?}");
        // paper: Val. > 80% zero bits, hybrid higher still
        assert!(s.value_pruned > 0.75, "{s:?}");
        assert!(s.hybrid > 0.85, "{s:?}");
    }

    #[test]
    fn fig3b_monotone_in_group() {
        let s = zero_column_stats(&models::alexnet(), 2);
        assert!(s.group1 >= s.group8);
        assert!(s.group8 >= s.group16);
        assert!(s.group16 > 0.2, "grouped sparsity collapsed: {s:?}");
    }

    #[test]
    fn peak_throughput_matches_paper_ratios() {
        let arch = ArchConfig::db_pim();
        let dense = peak_throughput(&arch, None);
        let th1 = peak_throughput(&arch, Some(1));
        let th2 = peak_throughput(&arch, Some(2));
        assert_eq!(dense.filters_per_macro, 2);
        assert_eq!(th1.filters_per_macro, 16); // paper: 16 filters at φ=1
        assert_eq!(th2.filters_per_macro, 8); // paper: 8 filters at φ=2
        assert!((th1.gops_per_macro / dense.gops_per_macro - 8.0).abs() < 1e-9);
        assert!((th2.gops_per_macro / dense.gops_per_macro - 4.0).abs() < 1e-9);
        // whole chip in the paper's ballpark (2.48 TOPS reported)
        assert!(th1.tops > 1.0 && th1.tops < 10.0, "{}", th1.tops);
    }
}
