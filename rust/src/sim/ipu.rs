//! Input pre-processing unit (IPU): block-wise zero bit-column
//! detection and skipping (Fig. 8 ①).
//!
//! The macro receives 16 input features per row-step (one per
//! compartment) and processes them bit-serially. The IPU ORs the 16
//! values; any bit position where the OR is zero is an all-zero column
//! whose bit-cycle can be skipped (the input-selection MUXes compact
//! the non-zero columns). With skipping disabled every row-step costs
//! the full `input_bits` cycles.

use super::occupancy;

/// OR-reduce a group of INT8 inputs to its column-occupancy byte
/// (word-wise fold; see sim::occupancy for the packed variant the
/// engines use).
#[inline]
pub fn column_occupancy(inputs: &[i8]) -> u8 {
    occupancy::or_fold_bytes(occupancy::i8_as_u8(inputs))
}

/// Number of bit-serial cycles needed for one 16-input row-step.
#[inline]
pub fn effective_bit_cycles(inputs: &[i8], input_bits: usize, skipping: bool) -> u32 {
    if skipping {
        u32::from(column_occupancy(inputs).count_ones())
    } else {
        input_bits as u32
    }
}

/// Fraction of skippable (all-zero) columns over a stream of groups —
/// the Fig. 3(b) statistic as measured by the IPU itself.
///
/// `count_zeros` runs over the full 8-bit occupancy byte, so the
/// `8 - input_bits` always-zero high bits must be discounted — but only
/// when they really are zero: negative (or otherwise wide) activations
/// set high bits, leaving fewer than `8 - input_bits` zero bits, and
/// the unchecked subtraction used to wrap around u64. Saturate instead:
/// such a group simply has no skippable columns beyond its occupancy.
pub fn skippable_fraction(acts: &[i8], group: usize, input_bits: usize) -> f64 {
    if acts.len() < group || group == 0 {
        return 0.0;
    }
    let high_overhead = 8u64.saturating_sub(input_bits as u64);
    let mut zero = 0u64;
    let mut total = 0u64;
    for chunk in acts.chunks(group) {
        let occ = column_occupancy(chunk);
        zero += u64::from(occ.count_zeros()).saturating_sub(high_overhead);
        total += input_bits as u64;
    }
    zero as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_or_semantics() {
        assert_eq!(column_occupancy(&[0, 0, 0]), 0);
        assert_eq!(column_occupancy(&[1, 2, 4]), 7);
        assert_eq!(column_occupancy(&[0x7F]), 0x7F);
        // negative values contribute their two's-complement bits
        assert_eq!(column_occupancy(&[-128]), 0x80);
    }

    #[test]
    fn effective_cycles_skipping() {
        assert_eq!(effective_bit_cycles(&[0; 16], 8, true), 0);
        assert_eq!(effective_bit_cycles(&[0; 16], 8, false), 8);
        assert_eq!(effective_bit_cycles(&[1, 2], 8, true), 2);
        assert_eq!(effective_bit_cycles(&[127; 16], 8, true), 7);
        assert_eq!(effective_bit_cycles(&[-1], 8, true), 8);
    }

    #[test]
    fn skipping_never_exceeds_full_cost() {
        let mut rng = crate::util::Rng::new(2);
        for _ in 0..100 {
            let group: Vec<i8> = (0..16).map(|_| rng.int8()).collect();
            assert!(effective_bit_cycles(&group, 8, true) <= 8);
        }
    }

    #[test]
    fn skippable_fraction_no_underflow_on_narrow_input_bits() {
        // Regression: with input_bits < 8, a group whose occupancy has
        // fewer than (8 - input_bits) zero bits (e.g. any negative
        // activation sets bit 7) used to wrap `count_zeros() - (8 -
        // input_bits)` around u64, exploding the fraction.
        let acts = [-1i8; 32]; // occ = 0xFF -> count_zeros() = 0
        let f = skippable_fraction(&acts, 16, 4);
        assert_eq!(f, 0.0, "wrapped underflow leaked into the fraction: {f}");
        // mixed stream: one clean group (low nibble only), one group
        // with sign bits; only the clean group contributes.
        let mut acts = vec![0i8; 16];
        acts[0] = 0x03; // occ 0b0000_0011 -> 2 zero low-nibble columns
        acts.extend_from_slice(&[-128i8; 16]); // occ 0b1000_0000
        let f = skippable_fraction(&acts, 16, 4);
        // group 1: 6 zero bits total, minus 4 high = 2 skippable of 4;
        // group 2: count_zeros = 7 (only bit 7 occupied), minus 4 high
        // -> 3 skippable of 4.
        assert!((f - (2.0 + 3.0) / 8.0).abs() < 1e-12, "fraction {f}");
        // fraction stays within [0, 1] for arbitrary signed streams
        let mut rng = crate::util::Rng::new(9);
        for bits in 1..=8 {
            let acts: Vec<i8> = (0..256).map(|_| rng.int8()).collect();
            let f = skippable_fraction(&acts, 16, bits);
            assert!((0.0..=1.0).contains(&f), "bits {bits} fraction {f}");
        }
    }

    #[test]
    fn skippable_fraction_matches_pruning_mirror() {
        // must agree with pruning::group_zero_column_fraction on
        // non-negative activations (the mirror uses unsigned_abs).
        let acts = crate::models::synthesize_activations(11, 2048);
        let a = skippable_fraction(&acts, 16, 8);
        let b = crate::pruning::group_zero_column_fraction(&acts, 16);
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
}
