//! Pluggable kernel backends with runtime SIMD dispatch and per-shape
//! routine selection.
//!
//! The three hot routines of the simulation path — the step-major IPU
//! occupancy scan, the dense gathered-weight micro-GEMM, and the
//! SIMD-core requant/ReLU post-op — live behind the [`KernelBackend`]
//! trait with three implementations:
//!
//! * [`ScalarRef`] — first-principles scalar loops, frozen as the
//!   bit-exact oracle. Never auto-selected; force it with
//!   `--kernel scalar` / `DBPIM_KERNEL=scalar` to pin the contract
//!   end-to-end (CI runs the whole test suite that way).
//! * [`Swar64`] — the word-packed routines from [`super::kernels`]
//!   (8 occupancy rows per `u64` with SWAR lane popcounts, 4-wide
//!   unrolled GEMM): the previous hot path, now a first-class backend
//!   and the default.
//! * [`Wide`] — AVX2 via `std::arch` on x86_64, gated by a one-time
//!   `is_x86_feature_detected!("avx2")` check; on other targets (or
//!   hosts without AVX2) it degrades to the portable word-chunked
//!   routines, so selecting it is always safe.
//!
//! **Oracle rule.** Every backend is bit-identical to [`ScalarRef`]
//! for every input: popcounts are exact, and all accumulations are
//! exact integer adds folded in the same per-element order, so a
//! backend can only change wall-clock — never a result bit. This is
//! property-tested across random shapes, engines and worker counts
//! (`tests/prop_invariants.rs::prop_kernel_backends_bit_identical`),
//! which is what keeps the DESIGN.md §8 determinism contract intact.
//!
//! **Selection.** `compiler::program::codegen` calls [`select_kernel`]
//! with the layer's [`KernelShape`] (M × widest filter block × tallest
//! tile) and records the answer in `Program::kernel`. The policy
//! resolves once per process (`--kernel` CLI flag > `DBPIM_KERNEL` env
//! > auto), and auto selection is memoized per log2 shape class —
//! optionally seeded by a one-shot calibration micro-run when
//! `DBPIM_KERNEL_CALIBRATE=1` — so every compile of the same geometry
//! (fresh or `CompileCache`d) picks the same routine. By the oracle
//! rule the choice is *excluded* from `CompileKey`/`SimKey`: it cannot
//! change results, so cached artifacts stay valid under any policy.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::compiler::KernelShape;
use crate::quant;

use super::kernels::{self, TileScan};
use super::occupancy::OccupancyTable;

/// Which kernel routine a compiled `Program` runs under.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// First-principles scalar oracle (never auto-selected).
    Scalar,
    /// Word-packed SWAR scan + 4-wide unrolled GEMM (the pre-backend
    /// hot path; `Default` so decoded/flattened programs behave as
    /// before this field existed).
    #[default]
    Swar,
    /// AVX2 with runtime detection; portable chunked fallback.
    Wide,
}

impl BackendKind {
    /// Every compiled-in kind, oracle first.
    pub const ALL: [BackendKind; 3] = [BackendKind::Scalar, BackendKind::Swar, BackendKind::Wide];

    /// CLI/env spelling of this kind.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Swar => "swar",
            BackendKind::Wide => "wide",
        }
    }

    /// Parse the CLI/env spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(BackendKind::Scalar),
            "swar" => Some(BackendKind::Swar),
            "wide" => Some(BackendKind::Wide),
            _ => None,
        }
    }
}

/// Process-wide routine-selection policy
/// (`DBPIM_KERNEL=auto|scalar|swar|wide`, `--kernel` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPolicy {
    /// Pick per shape class: static heuristic, or a one-shot
    /// calibration micro-run when `DBPIM_KERNEL_CALIBRATE=1`.
    Auto,
    /// Always use the given backend — full selector bypass.
    Force(BackendKind),
}

impl KernelPolicy {
    /// Parse the CLI/env spelling (`auto` or a backend name).
    pub fn parse(s: &str) -> Option<Self> {
        if s == "auto" {
            return Some(KernelPolicy::Auto);
        }
        BackendKind::parse(s).map(KernelPolicy::Force)
    }

    /// CLI/env spelling of this policy (for `dbpim info`).
    pub fn describe(self) -> &'static str {
        match self {
            KernelPolicy::Auto => "auto",
            KernelPolicy::Force(k) => k.name(),
        }
    }
}

static CONFIGURED: OnceLock<KernelPolicy> = OnceLock::new();
static RESOLVED: OnceLock<KernelPolicy> = OnceLock::new();

/// Set the policy from the CLI (`--kernel`). Mirrors
/// `pool::configure_workers`: must run before the first compile
/// resolves the policy; later calls are ignored.
pub fn configure_kernel(p: KernelPolicy) {
    let _ = CONFIGURED.set(p);
}

/// The process-wide policy: `--kernel` override > `DBPIM_KERNEL` env >
/// auto. Resolved once and constant for the process lifetime, so
/// repeated compiles of one layer always select the same routine
/// (`cached_artifact_equals_fresh_compile` depends on this).
pub fn effective_policy() -> KernelPolicy {
    *RESOLVED.get_or_init(|| {
        if let Some(&p) = CONFIGURED.get() {
            return p;
        }
        match std::env::var("DBPIM_KERNEL") {
            Ok(v) => KernelPolicy::parse(v.trim()).unwrap_or_else(|| {
                eprintln!(
                    "warning: unknown DBPIM_KERNEL={v:?} (want auto|scalar|swar|wide); using auto"
                );
                KernelPolicy::Auto
            }),
            Err(_) => KernelPolicy::Auto,
        }
    })
}

/// One-time runtime AVX2 detection (x86_64 only).
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

/// Non-x86_64 targets have no AVX2; [`Wide`] uses its portable path.
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

/// The three hot routines of the simulation path. Contract: every
/// implementation is bit-identical to [`ScalarRef`] on every input
/// (the oracle rule, module docs) — implementations may only differ in
/// wall-clock.
pub trait KernelBackend: Sync + std::fmt::Debug {
    /// The tag recorded in `Program::kernel` for this backend.
    fn kind(&self) -> BackendKind;

    /// Step-major occupancy scan of one tile; same contract as
    /// [`kernels::scan_tile_occupancy_into`] (every output field of
    /// `scan` is rewritten, `lane_scratch` is cleared/resized inside —
    /// backends that don't need lane accumulators leave it empty).
    fn scan_tile_occupancy_into(
        &self,
        scan: &mut TileScan,
        table: &OccupancyTable,
        tile: u32,
        base_step: usize,
        step_eff: &[u64],
        lane_scratch: &mut Vec<u64>,
    );

    /// Dense `i32 += i8×i8` row accumulate; same contract as
    /// [`kernels::gemm_accumulate`].
    fn gemm_accumulate(&self, out: &mut [i32], gathered: &[u8], wblock: &[i8]);

    /// Requantize + optional ReLU `acc` into the caller-provided `out`
    /// (same length; arena-recycled in the hot path).
    fn requant_relu_into(&self, out: &mut [i8], acc: &[i32], mul: i32, relu: bool);
}

/// Requantize one accumulator (the shared scalar core of every
/// backend's post-op; exactness lives in [`quant::requantize`]).
#[inline]
fn requant1(a: i32, mul: i32, relu: bool) -> i8 {
    let q = quant::requantize(a, mul);
    if relu && q < 0 {
        0
    } else {
        q
    }
}

/// Word-chunked requant/ReLU (4 accumulators per iteration) shared by
/// the fast backends. The requantize core is a widening i64 multiply +
/// 64-bit arithmetic shift; AVX2 has no 64-bit arithmetic right shift
/// (that is AVX-512) and the op is memory-bound, so chunked scalar is
/// the fast form on every target — bit-identical to the oracle by
/// construction (same [`requant1`] per element).
fn requant_relu_chunked(out: &mut [i8], acc: &[i32], mul: i32, relu: bool) {
    assert_eq!(out.len(), acc.len());
    let main = acc.len() - acc.len() % 4;
    let (a4, a_tail) = acc.split_at(main);
    let (o4, o_tail) = out.split_at_mut(main);
    for (o, a) in o4.chunks_exact_mut(4).zip(a4.chunks_exact(4)) {
        o[0] = requant1(a[0], mul, relu);
        o[1] = requant1(a[1], mul, relu);
        o[2] = requant1(a[2], mul, relu);
        o[3] = requant1(a[3], mul, relu);
    }
    for (o, &a) in o_tail.iter_mut().zip(a_tail) {
        *o = requant1(a, mul, relu);
    }
}

/// The bit-exact oracle: per-(step, row) byte walk, plain double-loop
/// GEMM (zero activations included — adding 0 is exact), per-element
/// requantize. Deliberately free of batching so the fast backends are
/// tested against independent first-principles code, not against a
/// refactoring of themselves.
#[derive(Debug)]
pub struct ScalarRef;

impl KernelBackend for ScalarRef {
    fn kind(&self) -> BackendKind {
        BackendKind::Scalar
    }

    fn scan_tile_occupancy_into(
        &self,
        scan: &mut TileScan,
        table: &OccupancyTable,
        tile: u32,
        base_step: usize,
        step_eff: &[u64],
        _lane_scratch: &mut Vec<u64>,
    ) {
        let m_total = table.m_rows();
        debug_assert!(base_step + step_eff.len() <= table.steps());
        scan.tile = tile;
        scan.row_cycles.clear();
        scan.row_cycles.resize(m_total, 0);
        let mut eff_total = 0u64;
        for (s, &eff) in step_eff.iter().enumerate() {
            let occ_row = table.step_row(base_step + s);
            for (rc, &b) in scan.row_cycles.iter_mut().zip(occ_row) {
                let beff = u64::from(b.count_ones());
                *rc += beff;
                eff_total += eff * beff;
            }
        }
        scan.eff_total = eff_total;
    }

    fn gemm_accumulate(&self, out: &mut [i32], gathered: &[u8], wblock: &[i8]) {
        let nf = out.len();
        debug_assert_eq!(wblock.len(), gathered.len() * nf);
        for (ri, &g) in gathered.iter().enumerate() {
            let xv = g as i8 as i32;
            for (fi, o) in out.iter_mut().enumerate() {
                *o += xv * wblock[ri * nf + fi] as i32;
            }
        }
    }

    fn requant_relu_into(&self, out: &mut [i8], acc: &[i32], mul: i32, relu: bool) {
        super::simd::requant_relu_into(out, acc, mul, relu);
    }
}

/// The word-packed SWAR backend: delegates to the [`super::kernels`]
/// routines (the pre-backend hot path) plus the chunked requant.
#[derive(Debug)]
pub struct Swar64;

impl KernelBackend for Swar64 {
    fn kind(&self) -> BackendKind {
        BackendKind::Swar
    }

    fn scan_tile_occupancy_into(
        &self,
        scan: &mut TileScan,
        table: &OccupancyTable,
        tile: u32,
        base_step: usize,
        step_eff: &[u64],
        lane_scratch: &mut Vec<u64>,
    ) {
        kernels::scan_tile_occupancy_into(scan, table, tile, base_step, step_eff, lane_scratch);
    }

    fn gemm_accumulate(&self, out: &mut [i32], gathered: &[u8], wblock: &[i8]) {
        kernels::gemm_accumulate(out, gathered, wblock);
    }

    fn requant_relu_into(&self, out: &mut [i8], acc: &[i32], mul: i32, relu: bool) {
        requant_relu_chunked(out, acc, mul, relu);
    }
}

/// The AVX2 backend: 32 occupancy bytes per vector op in the scan
/// (nibble-LUT `pshufb` popcount), 8 filters per vector op in the GEMM
/// (`_mm256_mullo_epi32` — exact, |xv·w| ≤ 127·128 fits i32 with room
/// to spare). Dispatches at runtime ([`avx2_available`]); without AVX2
/// it runs the portable word-chunked routines, so `Wide` is valid on
/// every host.
#[derive(Debug)]
pub struct Wide;

impl KernelBackend for Wide {
    fn kind(&self) -> BackendKind {
        BackendKind::Wide
    }

    fn scan_tile_occupancy_into(
        &self,
        scan: &mut TileScan,
        table: &OccupancyTable,
        tile: u32,
        base_step: usize,
        step_eff: &[u64],
        lane_scratch: &mut Vec<u64>,
    ) {
        #[cfg(target_arch = "x86_64")]
        if avx2_available() {
            // SAFETY: AVX2 availability verified at runtime above.
            unsafe {
                avx2::scan_tile_occupancy_into(
                    scan,
                    table,
                    tile,
                    base_step,
                    step_eff,
                    lane_scratch,
                )
            };
            return;
        }
        kernels::scan_tile_occupancy_into(scan, table, tile, base_step, step_eff, lane_scratch);
    }

    fn gemm_accumulate(&self, out: &mut [i32], gathered: &[u8], wblock: &[i8]) {
        #[cfg(target_arch = "x86_64")]
        if avx2_available() {
            // SAFETY: AVX2 availability verified at runtime above.
            unsafe { avx2::gemm_accumulate(out, gathered, wblock) };
            return;
        }
        kernels::gemm_accumulate(out, gathered, wblock);
    }

    fn requant_relu_into(&self, out: &mut [i8], acc: &[i32], mul: i32, relu: bool) {
        requant_relu_chunked(out, acc, mul, relu);
    }
}

/// The compiled-in backend singletons (trait objects are `Sync`
/// zero-sized statics — dispatch is one vtable indirection per tile or
/// chunk, amortized over the whole batched routine).
pub static SCALAR_REF: ScalarRef = ScalarRef;
pub static SWAR64: Swar64 = Swar64;
pub static WIDE: Wide = Wide;

/// The backend implementing `kind` (total — every tag resolves).
pub fn backend_for(kind: BackendKind) -> &'static dyn KernelBackend {
    match kind {
        BackendKind::Scalar => &SCALAR_REF,
        BackendKind::Swar => &SWAR64,
        BackendKind::Wide => &WIDE,
    }
}

/// Every compiled-in backend, oracle first (the property tests iterate
/// this).
pub fn all_backends() -> [&'static dyn KernelBackend; 3] {
    [&SCALAR_REF, &SWAR64, &WIDE]
}

/// Pick the routine for one layer shape under the process policy;
/// called by `compiler::program::codegen`, recorded in
/// `Program::kernel`.
pub fn select_kernel(shape: KernelShape) -> BackendKind {
    select_with_policy(effective_policy(), shape)
}

/// Policy-explicit selection (unit-testable without process globals).
/// `Force(k)` bypasses the selector entirely; `Auto` consults the
/// memoized per-shape-class choice.
pub fn select_with_policy(policy: KernelPolicy, shape: KernelShape) -> BackendKind {
    match policy {
        KernelPolicy::Force(k) => k,
        KernelPolicy::Auto => auto_select(shape),
    }
}

/// log2 buckets of the geometry fields: near-identical sweep layers
/// share one class (and therefore one memoized selection).
fn shape_class(shape: KernelShape) -> (u32, u32, u32) {
    let b = |v: usize| (v.max(1) as u64).ilog2();
    (b(shape.m), b(shape.max_filters), b(shape.max_tile_rows))
}

/// Auto selection, memoized per shape class for the process lifetime.
/// The memo is what makes selection a pure function of the shape class
/// within a process: a `CompileCache` hit and a fresh compile of the
/// same layer see the same choice even when calibration timing is
/// noisy.
fn auto_select(shape: KernelShape) -> BackendKind {
    static MEMO: OnceLock<Mutex<HashMap<(u32, u32, u32), BackendKind>>> = OnceLock::new();
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let class = shape_class(shape);
    let mut memo = memo.lock().unwrap();
    if let Some(&k) = memo.get(&class) {
        return k;
    }
    let k = if calibrate_enabled() { calibrate(shape) } else { heuristic(shape) };
    memo.insert(class, k);
    k
}

/// Static heuristic: AVX2 pays off when the GEMM rows are wide enough
/// to fill 8 i32 lanes or the scan covers ≥ 32 input rows (one full
/// vector of occupancy bytes); the SWAR word path wins on skinnier
/// shapes. The oracle is never auto-picked.
fn heuristic(shape: KernelShape) -> BackendKind {
    if avx2_available() && (shape.max_filters >= 8 || shape.m >= 32) {
        BackendKind::Wide
    } else {
        BackendKind::Swar
    }
}

fn calibrate_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("DBPIM_KERNEL_CALIBRATE").as_deref() == Ok("1"))
}

/// One-shot calibration: time the fast candidates on a synthetic GEMM
/// of this shape class and keep the faster. Runs once per shape class
/// per process (memoized by [`auto_select`]), so its cost amortizes
/// across a sweep. The outcome is timing-dependent across *processes*,
/// which is fine by the oracle rule: it can only move wall-clock, and
/// within a process the memo keeps it consistent.
fn calibrate(shape: KernelShape) -> BackendKind {
    let nf = shape.max_filters.clamp(1, 512);
    let rows = shape.max_tile_rows.clamp(1, 1024);
    let mut rng = crate::util::Rng::new(0xCA11_B8A7E);
    let gathered: Vec<u8> = (0..rows).map(|_| rng.int8() as u8).collect();
    let wblock: Vec<i8> = (0..rows * nf).map(|_| rng.int8()).collect();
    let mut out = vec![0i32; nf];
    let mut best = (BackendKind::Swar, u128::MAX);
    for kind in [BackendKind::Swar, BackendKind::Wide] {
        let b = backend_for(kind);
        let start = std::time::Instant::now();
        for _ in 0..8 {
            out.fill(0);
            b.gemm_accumulate(&mut out, &gathered, &wblock);
            std::hint::black_box(&mut out);
        }
        let dt = start.elapsed().as_nanos();
        if dt < best.1 {
            best = (kind, dt);
        }
    }
    best.0
}

/// AVX2 routines. Bit-identity argument, per routine:
///
/// * scan — the per-byte popcount (nibble-LUT `pshufb`) is exact, the
///   byte-lane accumulators live in the same `u64` little-endian lane
///   layout the SWAR path uses (x86_64 is little-endian, so vector
///   byte lanes coincide with the `to_le_bytes` lanes `flush_lanes`
///   drains), and the flush cadence is the same 31-step bound.
/// * gemm — `i8` weights widen to `i32` before an exact
///   `_mm256_mullo_epi32` and `_mm256_add_epi32`; per output column
///   the adds fold in the same kept-rows-ascending order as scalar.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use crate::sim::kernels::{flush_lanes, lane_popcount, TileScan, LANE_FLUSH_STEPS};
    use crate::sim::occupancy::OccupancyTable;

    /// Step-major occupancy scan, 32 occupancy bytes per vector op.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scan_tile_occupancy_into(
        scan: &mut TileScan,
        table: &OccupancyTable,
        tile: u32,
        base_step: usize,
        step_eff: &[u64],
        lane_scratch: &mut Vec<u64>,
    ) {
        let m_total = table.m_rows();
        debug_assert!(base_step + step_eff.len() <= table.steps());
        scan.tile = tile;
        scan.row_cycles.clear();
        scan.row_cycles.resize(m_total, 0);
        let row_cycles = &mut scan.row_cycles;
        let words = m_total / 8;
        lane_scratch.clear();
        lane_scratch.resize(words, 0);
        // 4 u64 lanes = one 256-bit in-memory byte-lane accumulator
        let vec_words = words - words % 4;
        // nibble popcount LUT for pshufb (both 128-bit halves)
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3,
            2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let shift4 = _mm_cvtsi32_si128(4);
        let mut eff_total = 0u64;
        let mut pending = 0u32;
        for (s, &eff) in step_eff.iter().enumerate() {
            let occ_row = table.step_row(base_step + s);
            let (word_bytes, tail) = occ_row.split_at(words * 8);
            for g in 0..vec_words / 4 {
                let v = _mm256_loadu_si256(word_bytes.as_ptr().add(g * 32) as *const __m256i);
                let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(v, low_mask));
                let hi = _mm256_shuffle_epi8(
                    lut,
                    _mm256_and_si256(_mm256_srl_epi16(v, shift4), low_mask),
                );
                let pc = _mm256_add_epi8(lo, hi);
                let accp = lane_scratch.as_mut_ptr().add(g * 4) as *mut __m256i;
                let lanes = _mm256_loadu_si256(accp);
                _mm256_storeu_si256(accp, _mm256_add_epi8(lanes, pc));
                // per-step total popcount of the 32 bytes, for the
                // eff-weighted column-cycle sum
                let sums = _mm256_sad_epu8(pc, zero);
                let mut q = [0u64; 4];
                _mm256_storeu_si256(q.as_mut_ptr() as *mut __m256i, sums);
                eff_total += eff * (q[0] + q[1] + q[2] + q[3]);
            }
            // remainder words (< 4) via the SWAR word path
            for (lanes, chunk) in lane_scratch[vec_words..]
                .iter_mut()
                .zip(word_bytes[vec_words * 8..].chunks_exact(8))
            {
                let word = u64::from_le_bytes(chunk.try_into().unwrap());
                *lanes += lane_popcount(word);
                eff_total += eff * u64::from(word.count_ones());
            }
            // tail rows (m_total % 8) byte-wise
            for (rc, &b) in row_cycles[words * 8..].iter_mut().zip(tail) {
                let beff = u64::from(b.count_ones());
                *rc += beff;
                eff_total += eff * beff;
            }
            pending += 1;
            if pending == LANE_FLUSH_STEPS {
                flush_lanes(lane_scratch, row_cycles);
                pending = 0;
            }
        }
        if pending > 0 {
            flush_lanes(lane_scratch, row_cycles);
        }
        scan.eff_total = eff_total;
    }

    /// Dense row accumulate, 8 filter columns per vector op.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_accumulate(out: &mut [i32], gathered: &[u8], wblock: &[i8]) {
        let nf = out.len();
        debug_assert_eq!(wblock.len(), gathered.len() * nf);
        let main = nf - nf % 8;
        for (ri, &g) in gathered.iter().enumerate() {
            let xv = g as i8 as i32;
            if xv == 0 {
                continue;
            }
            let wrow = &wblock[ri * nf..(ri + 1) * nf];
            let xb = _mm256_set1_epi32(xv);
            let mut fi = 0;
            while fi < main {
                let w8 =
                    _mm256_cvtepi8_epi32(_mm_loadl_epi64(wrow.as_ptr().add(fi) as *const __m128i));
                let op = out.as_mut_ptr().add(fi) as *mut __m256i;
                let o = _mm256_loadu_si256(op);
                _mm256_storeu_si256(op, _mm256_add_epi32(o, _mm256_mullo_epi32(w8, xb)));
                fi += 8;
            }
            for (o, &w) in out[main..].iter_mut().zip(&wrow[main..]) {
                *o += xv * w as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::MatI8;
    use crate::util::{ceil_div, Rng};

    fn shape(m: usize, nf: usize, rows: usize) -> KernelShape {
        KernelShape { m, max_filters: nf, max_tile_rows: rows }
    }

    #[test]
    fn names_parse_and_dispatch_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
            assert_eq!(backend_for(k).kind(), k);
            assert_eq!(KernelPolicy::parse(k.name()), Some(KernelPolicy::Force(k)));
        }
        assert_eq!(BackendKind::parse("bogus"), None);
        assert_eq!(KernelPolicy::parse("auto"), Some(KernelPolicy::Auto));
        assert_eq!(KernelPolicy::parse(""), None);
        assert_eq!(BackendKind::default(), BackendKind::Swar);
    }

    /// ISSUE 6 satellite pin: a forced policy (`--kernel scalar` /
    /// `DBPIM_KERNEL=scalar`) bypasses the selector entirely — every
    /// shape gets the forced backend, including shapes the heuristic
    /// would route elsewhere.
    #[test]
    fn forced_policy_bypasses_selector() {
        for s in [shape(1, 1, 1), shape(256, 128, 1024), shape(64, 8, 64)] {
            assert_eq!(
                select_with_policy(KernelPolicy::Force(BackendKind::Scalar), s),
                BackendKind::Scalar
            );
            for k in BackendKind::ALL {
                assert_eq!(select_with_policy(KernelPolicy::Force(k), s), k);
            }
        }
    }

    #[test]
    fn auto_never_selects_the_scalar_oracle() {
        for m in [1usize, 4, 32, 256] {
            for nf in [1usize, 2, 8, 48] {
                for rows in [1usize, 64, 1024] {
                    let k = select_with_policy(KernelPolicy::Auto, shape(m, nf, rows));
                    assert_ne!(k, BackendKind::Scalar, "auto picked the oracle at {m}x{nf}x{rows}");
                }
            }
        }
    }

    #[test]
    fn gemm_backends_match_scalar_oracle() {
        let mut rng = Rng::new(91);
        for _ in 0..40 {
            let kept = rng.below(80) as usize;
            let nf = 1 + rng.below(40) as usize;
            let gathered: Vec<u8> = (0..kept)
                .map(|_| if rng.below(3) == 0 { 0 } else { rng.int8() as u8 })
                .collect();
            let wblock: Vec<i8> = (0..kept * nf).map(|_| rng.int8()).collect();
            // non-zero starting accumulators: backends must add on top
            let base: Vec<i32> = (0..nf).map(|_| rng.int8() as i32 * 1000).collect();
            let mut want = base.clone();
            SCALAR_REF.gemm_accumulate(&mut want, &gathered, &wblock);
            for b in all_backends() {
                let mut got = base.clone();
                b.gemm_accumulate(&mut got, &gathered, &wblock);
                assert_eq!(got, want, "{:?} kept {kept} nf {nf}", b.kind());
            }
        }
    }

    #[test]
    fn scan_backends_match_scalar_oracle() {
        let mut rng = Rng::new(92);
        for _ in 0..20 {
            let m_total = 1 + rng.below(70) as usize;
            let k = 8 + rng.below(300) as usize;
            let comp = 16;
            let x = MatI8::from_vec(
                m_total,
                k,
                (0..m_total * k)
                    .map(|_| if rng.below(2) == 0 { 0 } else { rng.int8() })
                    .collect(),
            );
            let kept: Vec<u32> = (0..k as u32).filter(|_| rng.below(4) > 0).collect();
            if kept.is_empty() {
                continue;
            }
            let table = OccupancyTable::build(0, &x, &kept, comp, m_total, true, false);
            let steps = ceil_div(kept.len(), comp);
            let step_eff: Vec<u64> = (0..steps).map(|_| rng.below(512)).collect();
            let mut want = TileScan::empty();
            let mut scratch = Vec::new();
            SCALAR_REF.scan_tile_occupancy_into(&mut want, &table, 3, 0, &step_eff, &mut scratch);
            for b in all_backends() {
                let mut got = TileScan::empty();
                let mut scratch = Vec::new();
                b.scan_tile_occupancy_into(&mut got, &table, 3, 0, &step_eff, &mut scratch);
                assert_eq!(got.tile, want.tile, "{:?}", b.kind());
                assert_eq!(got.row_cycles, want.row_cycles, "{:?}", b.kind());
                assert_eq!(got.eff_total, want.eff_total, "{:?}", b.kind());
            }
        }
    }

    #[test]
    fn requant_backends_match_scalar_on_edge_values() {
        let acc = vec![100_000, -100_000, 0, 6553, i32::MAX, i32::MIN, -1, 1, 65_536];
        let mul = quant::requant_mul(0.01);
        for relu in [false, true] {
            let mut want = vec![0i8; acc.len()];
            SCALAR_REF.requant_relu_into(&mut want, &acc, mul, relu);
            for b in all_backends() {
                let mut got = vec![0i8; acc.len()];
                b.requant_relu_into(&mut got, &acc, mul, relu);
                assert_eq!(got, want, "{:?} relu {relu}", b.kind());
            }
        }
    }
}
