//! Batched hot-loop kernels for the per-core executor.
//!
//! The two remaining inner loops of the row-loop simulation path are
//! rewritten here as cache-friendly batched kernels (PR: step-major
//! batched kernels):
//!
//! * [`scan_tile_occupancy`] — the IPU timing walk, inverted from
//!   row-major to step-major over the [`OccupancyTable`]'s step-major
//!   `occ` storage. Each step's occupancy bytes for all M input rows
//!   are contiguous, so the kernel processes 8 rows at a time as one
//!   `u64` word: a single `count_ones` per word feeds the
//!   `active_col_cycles` total while a SWAR per-byte popcount
//!   ([`lane_popcount`]) accumulates the per-row cycle counts in
//!   word-parallel lanes — ~8× fewer loads and popcounts than the
//!   scalar byte walk, bit-identical totals (popcounts are exact and
//!   integer addition is order-free).
//! * [`gemm_accumulate`] — the functional accumulate, turned from a
//!   scatter (`acc[col_of[f]] += xv * w[f]`, one indirect gather per
//!   MAC) into a dense `i32 += i8×i8` micro-GEMM over the assignment's
//!   compile-time gathered weight block (`Assignment::wblock`) and the
//!   core's dense per-assignment accumulator block, 4-wide unrolled
//!   over contiguous memory.
//!
//! Both kernels are property-tested bit-identical to scalar
//! first-principles references (unit tests below and
//! tests/prop_invariants.rs).
//!
//! These routines are the engine of the `Swar64` backend (and the
//! portable fallback of `Wide`) in [`super::backend`]; the executor
//! reaches them through the [`super::backend::KernelBackend`] trait.

use super::occupancy::OccupancyTable;

/// Per-byte popcount in SWAR lanes: each byte of the result holds the
/// popcount of the corresponding input byte (0..=8), computed for all
/// 8 lanes at once with no per-byte loads.
#[inline]
pub fn lane_popcount(mut v: u64) -> u64 {
    v -= (v >> 1) & 0x5555_5555_5555_5555;
    v = (v & 0x3333_3333_3333_3333) + ((v >> 2) & 0x3333_3333_3333_3333);
    (v + (v >> 4)) & 0x0F0F_0F0F_0F0F_0F0F
}

/// Result of scanning one tile's occupancy over all M input rows.
///
/// Cached single-slot per executor (tiles of one assignment are walked
/// chunk by chunk, `m_base` ascending from 0, before the next tile
/// starts — codegen invariant, tests/prop_invariants.rs).
#[derive(Debug, Clone)]
pub struct TileScan {
    /// Tile id this scan belongs to (executor cache key).
    pub tile: u32,
    /// Per input row m: Σ_steps B_eff(m, step) — the row's bit-serial
    /// cycle count under IPU skipping.
    pub row_cycles: Vec<u64>,
    /// Σ_rows Σ_steps `step_eff[step] * B_eff(row, step)` — the tile's
    /// whole contribution to `active_col_cycles`, accounted once on the
    /// tile's first Compute chunk.
    pub eff_total: u64,
}

impl TileScan {
    /// An unbuilt scan (the arena's recycling seed): tile sentinel no
    /// real tile id can match, empty cycle vector.
    pub fn empty() -> Self {
        TileScan { tile: u32::MAX, row_cycles: Vec::new(), eff_total: 0 }
    }

    /// Poison the executor cache key before the scan enters the arena
    /// free list (a recycled scan must never falsely match a tile id
    /// of a different layer).
    pub(crate) fn retire(&mut self) {
        self.tile = u32::MAX;
    }
}

/// Lane accumulators flush to 64-bit counters before a byte lane can
/// saturate: 31 steps × max popcount 8 = 248 < 256. Shared with the
/// AVX2 scan in `sim::backend`, whose 32-byte lanes have the same
/// saturation bound.
pub(crate) const LANE_FLUSH_STEPS: u32 = 31;

/// Step-major occupancy scan of one tile: for global steps
/// `base_step .. base_step + step_eff.len()`, fold every input row's
/// occupancy popcount into per-row cycle counts and the eff-weighted
/// column-cycle total. Bit-identical to the scalar per-(row, step)
/// byte walk.
pub fn scan_tile_occupancy(
    table: &OccupancyTable,
    tile: u32,
    base_step: usize,
    step_eff: &[u64],
) -> TileScan {
    let mut scan = TileScan::empty();
    let mut lane_scratch = Vec::new();
    scan_tile_occupancy_into(&mut scan, table, tile, base_step, step_eff, &mut lane_scratch);
    scan
}

/// Reset-and-fill form of [`scan_tile_occupancy`]: rewrites `scan` in
/// place (reusing its `row_cycles` capacity) and runs the SWAR lane
/// accumulators in caller-provided scratch, so an arena-recycled scan
/// makes the per-tile walk allocation-free after warm-up. Bit-identical
/// to the allocating form — every output field is rewritten.
pub fn scan_tile_occupancy_into(
    scan: &mut TileScan,
    table: &OccupancyTable,
    tile: u32,
    base_step: usize,
    step_eff: &[u64],
    lane_scratch: &mut Vec<u64>,
) {
    let m_total = table.m_rows();
    debug_assert!(base_step + step_eff.len() <= table.steps());
    scan.tile = tile;
    scan.row_cycles.clear();
    scan.row_cycles.resize(m_total, 0);
    let row_cycles = &mut scan.row_cycles;
    let words = m_total / 8;
    lane_scratch.clear();
    lane_scratch.resize(words, 0);
    let mut eff_total = 0u64;
    let mut pending = 0u32;
    for (s, &eff) in step_eff.iter().enumerate() {
        let occ_row = table.step_row(base_step + s);
        let (word_bytes, tail) = occ_row.split_at(words * 8);
        for (lanes, chunk) in lane_scratch.iter_mut().zip(word_bytes.chunks_exact(8)) {
            let word = u64::from_le_bytes(chunk.try_into().unwrap());
            *lanes += lane_popcount(word);
            eff_total += eff * u64::from(word.count_ones());
        }
        for (rc, &b) in row_cycles[words * 8..].iter_mut().zip(tail) {
            let beff = u64::from(b.count_ones());
            *rc += beff;
            eff_total += eff * beff;
        }
        pending += 1;
        if pending == LANE_FLUSH_STEPS {
            flush_lanes(lane_scratch, row_cycles);
            pending = 0;
        }
    }
    if pending > 0 {
        flush_lanes(lane_scratch, row_cycles);
    }
    scan.eff_total = eff_total;
}

/// Drain the byte-lane accumulators into the 64-bit per-row counters.
/// `pub(crate)`: the AVX2 scan in `sim::backend` accumulates into the
/// same little-endian `u64` byte-lane layout and drains through here.
pub(crate) fn flush_lanes(lane_acc: &mut [u64], row_cycles: &mut [u64]) {
    for (w, lanes) in lane_acc.iter_mut().enumerate() {
        if *lanes != 0 {
            for (i, b) in lanes.to_le_bytes().into_iter().enumerate() {
                row_cycles[w * 8 + i] += u64::from(b);
            }
            *lanes = 0;
        }
    }
}

/// Dense `i32 += i8×i8` row accumulate: for each gathered activation
/// byte (raw bit pattern of the kept input value) accumulate
/// `out[f] += xv * wrow[f]` over the assignment's contiguous gathered
/// weight block (`wblock[ri * out.len() + fi]`), 4-wide unrolled.
/// Zero activations are skipped (ReLU-sparse inputs).
///
/// Bit-identical to the legacy scatter loop: same per-column addition
/// order (kept rows ascending), exact integer arithmetic.
pub fn gemm_accumulate(out: &mut [i32], gathered: &[u8], wblock: &[i8]) {
    let nf = out.len();
    debug_assert_eq!(wblock.len(), gathered.len() * nf);
    let main = nf - (nf % 4);
    for (ri, &g) in gathered.iter().enumerate() {
        let xv = g as i8 as i32;
        if xv == 0 {
            continue;
        }
        let wrow = &wblock[ri * nf..(ri + 1) * nf];
        let (out4, out_tail) = out.split_at_mut(main);
        let (w4, w_tail) = wrow.split_at(main);
        for (o, w) in out4.chunks_exact_mut(4).zip(w4.chunks_exact(4)) {
            o[0] += xv * w[0] as i32;
            o[1] += xv * w[1] as i32;
            o[2] += xv * w[2] as i32;
            o[3] += xv * w[3] as i32;
        }
        for (o, &w) in out_tail.iter_mut().zip(w_tail) {
            *o += xv * w as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::MatI8;
    use crate::util::{ceil_div, Rng};

    #[test]
    fn lane_popcount_matches_per_byte_count_ones() {
        let mut rng = Rng::new(17);
        for _ in 0..500 {
            let v = rng.next_u64();
            let lanes = lane_popcount(v).to_le_bytes();
            for (i, b) in v.to_le_bytes().into_iter().enumerate() {
                assert_eq!(u32::from(lanes[i]), b.count_ones(), "word {v:#x} byte {i}");
            }
        }
        assert_eq!(lane_popcount(0), 0);
        assert_eq!(lane_popcount(u64::MAX), 0x0808_0808_0808_0808);
    }

    #[test]
    fn scan_matches_scalar_reference() {
        let mut rng = Rng::new(23);
        for case in 0..30 {
            let m_total = 1 + rng.below(40) as usize;
            let k = 16 + rng.below(400) as usize;
            let comp = 16;
            let x = MatI8::from_vec(
                m_total,
                k,
                (0..m_total * k)
                    .map(|_| if rng.below(2) == 0 { 0 } else { rng.int8() })
                    .collect(),
            );
            let kept: Vec<u32> = (0..k as u32).filter(|_| rng.below(3) > 0).collect();
            if kept.is_empty() {
                continue;
            }
            let table = OccupancyTable::build(0, &x, &kept, comp, m_total, true, false);
            let total_steps = ceil_div(kept.len(), comp);
            // random step window (a "tile") with varied eff weights
            let base_step = rng.below(total_steps as u64) as usize;
            let steps = 1 + rng.below((total_steps - base_step) as u64) as usize;
            let step_eff: Vec<u64> = (0..steps).map(|_| rng.below(2048)).collect();

            let scan = scan_tile_occupancy(&table, 7, base_step, &step_eff);
            assert_eq!(scan.tile, 7);
            let mut eff_ref = 0u64;
            for m in 0..m_total {
                let mut rc = 0u64;
                for (s, &eff) in step_eff.iter().enumerate() {
                    let start = (base_step + s) * comp;
                    let lanes = (kept.len() - start).min(comp);
                    let or = kept[start..start + lanes]
                        .iter()
                        .fold(0u8, |o, &kk| o | (x.get(m, kk as usize) as u8));
                    let beff = u64::from(or.count_ones());
                    rc += beff;
                    eff_ref += eff * beff;
                }
                assert_eq!(scan.row_cycles[m], rc, "case {case} row {m}");
            }
            assert_eq!(scan.eff_total, eff_ref, "case {case}");
        }
    }

    #[test]
    fn scan_lane_flush_survives_many_steps() {
        // >31 steps of all-ones occupancy: every lane would saturate a
        // byte without the periodic flush (40 steps × 8 = 320 > 255).
        let m_total = 9; // one full word + one tail row
        let comp = 1; // one kept row per step
        let k = 40;
        let x = MatI8::from_vec(m_total, k, vec![-1i8; m_total * k]);
        let kept: Vec<u32> = (0..k as u32).collect();
        let table = OccupancyTable::build(0, &x, &kept, comp, m_total, true, false);
        let step_eff = vec![1u64; k];
        let scan = scan_tile_occupancy(&table, 0, 0, &step_eff);
        for m in 0..m_total {
            assert_eq!(scan.row_cycles[m], 8 * k as u64);
        }
        assert_eq!(scan.eff_total, (m_total * 8 * k) as u64);
    }

    #[test]
    fn gemm_matches_scalar_scatter_reference() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let kept = rng.below(60) as usize;
            let nf = 1 + rng.below(20) as usize;
            let gathered: Vec<u8> = (0..kept)
                .map(|_| if rng.below(2) == 0 { 0 } else { rng.int8() as u8 })
                .collect();
            let wblock: Vec<i8> = (0..kept * nf).map(|_| rng.int8()).collect();
            let mut out = vec![0i32; nf];
            gemm_accumulate(&mut out, &gathered, &wblock);
            let mut want = vec![0i32; nf];
            for (ri, &g) in gathered.iter().enumerate() {
                let xv = g as i8 as i32;
                for (fi, w) in want.iter_mut().enumerate() {
                    *w += xv * wblock[ri * nf + fi] as i32;
                }
            }
            assert_eq!(out, want, "kept {kept} nf {nf}");
        }
    }

    #[test]
    fn gemm_accumulates_on_top_of_existing_values() {
        let mut out = vec![10i32, -3, 7];
        gemm_accumulate(&mut out, &[2, 0, 0xFF], &[1, 2, 3, 9, 9, 9, 1, 1, 1]);
        // row 0: xv=2 → +2,+4,+6 ; row 1 skipped ; row 2: xv=-1 → -1 each
        assert_eq!(out, vec![10 + 2 - 1, -3 + 4 - 1, 7 + 6 - 1]);
    }
}
