//! SIMD-core cost model + functional post-ops.
//!
//! The SIMD core (Sec. V-A / VII) executes everything the PIM array
//! does not: depthwise conv, pooling, ReLU, requantization, residual
//! adds, element-wise multiplies. It is identical in every
//! configuration, so compact models' dw-conv/elementwise time is an
//! Amdahl floor on end-to-end speedup — the Fig. 13 effect.

use crate::arch::ArchConfig;
use crate::isa::SimdOp;
use crate::quant;
use crate::tensor::{self, MatI32, TensorI8};

/// Lane-ops performed for `elems` elements of the given op.
pub fn lane_ops(op: SimdOp, elems: u64) -> u64 {
    match op {
        // 2×2 max pool: 3 compares per output = 3/4 per input element
        SimdOp::MaxPool => elems * 3 / 4,
        // requant: multiply + shift + clamp ≈ 2 lane-ops
        SimdOp::Requant => elems * 2,
        // one lane-op per element (dw-conv `elems` is its MAC count)
        _ => elems,
    }
}

/// Cycles to execute the op over `elems` elements.
pub fn simd_cycles(op: SimdOp, elems: u64, arch: &ArchConfig) -> u64 {
    crate::util::ceil_div(lane_ops(op, elems) as usize, arch.simd_lanes) as u64
}

/// Functional: requantize + optional ReLU an accumulator matrix into i8.
pub fn requant_relu(acc: &MatI32, mul: i32, relu: bool) -> Vec<i8> {
    acc.data
        .iter()
        .map(|&a| {
            let q = quant::requantize(a, mul);
            if relu && q < 0 {
                0
            } else {
                q
            }
        })
        .collect()
}

/// Functional 2×2 max pool (thin wrapper for pipeline symmetry).
pub fn maxpool(x: &TensorI8) -> TensorI8 {
    tensor::maxpool2x2(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_divide_by_lanes() {
        let arch = ArchConfig::db_pim();
        assert_eq!(simd_cycles(SimdOp::Relu, 64, &arch), 1);
        assert_eq!(simd_cycles(SimdOp::Relu, 65, &arch), 2);
        assert_eq!(simd_cycles(SimdOp::Requant, 64, &arch), 2);
    }

    #[test]
    fn requant_relu_clamps() {
        let acc = MatI32 { rows: 1, cols: 4, data: vec![100_000, -100_000, 0, 6553] };
        let mul = quant::requant_mul(0.01);
        let out = requant_relu(&acc, mul, true);
        assert_eq!(out[0], 127); // clamped high
        assert_eq!(out[1], 0); // relu'd
        assert_eq!(out[2], 0);
        assert!(out[3] > 0);
        let out_norelu = requant_relu(&acc, mul, false);
        assert_eq!(out_norelu[1], -128);
    }

    #[test]
    fn dwconv_lane_ops_equal_macs() {
        assert_eq!(lane_ops(SimdOp::DwConv, 12345), 12345);
    }
}
