//! SIMD-core cost model + functional post-ops.
//!
//! The SIMD core (Sec. V-A / VII) executes everything the PIM array
//! does not: depthwise conv, pooling, ReLU, requantization, residual
//! adds, element-wise multiplies. It is identical in every
//! configuration, so compact models' dw-conv/elementwise time is an
//! Amdahl floor on end-to-end speedup — the Fig. 13 effect.

use crate::arch::ArchConfig;
use crate::isa::SimdOp;
use crate::quant;
use crate::tensor::{self, MatI32, TensorI8};

/// Lane-ops performed for `elems` elements of the given op.
pub fn lane_ops(op: SimdOp, elems: u64) -> u64 {
    match op {
        // 2×2 max pool: 3 compares per output = 3/4 per input element
        SimdOp::MaxPool => elems * 3 / 4,
        // requant: multiply + shift + clamp ≈ 2 lane-ops
        SimdOp::Requant => elems * 2,
        // one lane-op per element (dw-conv `elems` is its MAC count)
        _ => elems,
    }
}

/// Cycles to execute the op over `elems` elements. The ceil-div stays
/// in `u64` the whole way: the old `lane_ops(..) as usize` narrowing
/// silently truncated huge elem counts on 32-bit targets before
/// dividing.
pub fn simd_cycles(op: SimdOp, elems: u64, arch: &ArchConfig) -> u64 {
    let lanes = (arch.simd_lanes as u64).max(1);
    lane_ops(op, elems).div_ceil(lanes)
}

/// Functional: requantize + optional ReLU a raw accumulator slice into
/// a caller-provided `i8` buffer (arena-recycled in the pipeline hot
/// path — the old signature allocated a fresh `Vec<i8>` per layer).
/// This per-element scalar loop is the `ScalarRef` oracle routine; the
/// chunked fast backends in [`super::backend`] are tested bit-identical
/// to it.
pub fn requant_relu_into(out: &mut [i8], acc: &[i32], mul: i32, relu: bool) {
    assert_eq!(out.len(), acc.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        let q = quant::requantize(a, mul);
        *o = if relu && q < 0 { 0 } else { q };
    }
}

/// Allocating convenience wrapper over [`requant_relu_into`].
pub fn requant_relu(acc: &MatI32, mul: i32, relu: bool) -> Vec<i8> {
    let mut out = vec![0i8; acc.data.len()];
    requant_relu_into(&mut out, &acc.data, mul, relu);
    out
}

/// Functional 2×2 max pool (thin wrapper for pipeline symmetry).
pub fn maxpool(x: &TensorI8) -> TensorI8 {
    tensor::maxpool2x2(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_divide_by_lanes() {
        let arch = ArchConfig::db_pim();
        assert_eq!(simd_cycles(SimdOp::Relu, 64, &arch), 1);
        assert_eq!(simd_cycles(SimdOp::Relu, 65, &arch), 2);
        assert_eq!(simd_cycles(SimdOp::Requant, 64, &arch), 2);
    }

    #[test]
    fn requant_relu_clamps() {
        let acc = MatI32 { rows: 1, cols: 4, data: vec![100_000, -100_000, 0, 6553] };
        let mul = quant::requant_mul(0.01);
        let out = requant_relu(&acc, mul, true);
        assert_eq!(out[0], 127); // clamped high
        assert_eq!(out[1], 0); // relu'd
        assert_eq!(out[2], 0);
        assert!(out[3] > 0);
        let out_norelu = requant_relu(&acc, mul, false);
        assert_eq!(out_norelu[1], -128);
    }

    #[test]
    fn dwconv_lane_ops_equal_macs() {
        assert_eq!(lane_ops(SimdOp::DwConv, 12345), 12345);
    }

    #[test]
    fn cycles_survive_huge_elem_counts_without_narrowing() {
        let arch = ArchConfig::db_pim();
        assert_eq!(arch.simd_lanes, 64);
        // > u32::MAX lane-ops: the old `as usize` narrowing truncated
        // this on 32-bit targets before the ceil-div.
        let elems = (1u64 << 40) + 1;
        assert_eq!(simd_cycles(SimdOp::Relu, elems, &arch), (1u64 << 34) + 1);
        // exact multiple: no remainder cycle
        assert_eq!(simd_cycles(SimdOp::Relu, 1u64 << 40, &arch), 1u64 << 34);
        assert_eq!(simd_cycles(SimdOp::Relu, 0, &arch), 0);
    }

    #[test]
    fn requant_relu_into_reuses_arena_buffers() {
        use crate::sim::arena;
        let acc =
            MatI32 { rows: 4, cols: 8, data: (0..32).map(|i| i * 1000 - 16_000).collect() };
        let mul = quant::requant_mul(0.01);
        let want = requant_relu(&acc, mul, true);
        // warm-up take/give seeds the thread-local free list
        let out = arena::take_i8(acc.data.len());
        arena::give_i8(out);
        arena::reset_stats();
        for _ in 0..5 {
            let mut out = arena::take_i8(acc.data.len());
            requant_relu_into(&mut out, &acc.data, mul, true);
            assert_eq!(out, want);
            arena::give_i8(out);
        }
        let s = arena::stats();
        assert_eq!(s.misses, 0, "steady-state requant still allocating: {s:?}");
        assert!(s.hits >= 5);
    }
}
