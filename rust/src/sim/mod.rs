//! The DB-PIM cycle-accurate simulator.
//!
//! * [`machine`] — the machine façade (arch + energy + engine choice).
//! * [`core_exec`] — per-core segment executor (clock, events,
//!   accumulator slice, occupancy cache).
//! * [`engine`] — barrier scheduler over segmented programs; spawns
//!   phase segments into the shared worker pool, bit-identical to the
//!   legacy flat-stream interpreter it also hosts.
//! * [`occupancy`] — word-packed bit-plane occupancy precompute for the
//!   IPU inner loop (step-major storage).
//! * [`kernels`] — batched hot-loop kernels: the step-major word-batched
//!   occupancy scan and the dense gathered-weight micro-GEMM accumulate.
//! * [`backend`] — pluggable kernel backends behind those routines:
//!   `ScalarRef` bit-exact oracle, `Swar64` word path, AVX2 `Wide` with
//!   runtime dispatch; plus the per-shape routine selector whose choice
//!   is recorded in each compiled `Program`
//!   (`DBPIM_KERNEL=auto|scalar|swar|wide`, `--kernel`).
//! * [`arena`] — thread-local scratch arenas recycling the hot-path
//!   working set (occupancy tables, tile scans, accumulator blocks), so
//!   steady-state simulation is allocation-free.
//! * [`simcache`] — sweep-wide memo of per-layer simulation results,
//!   keyed like the CompileCache; repeated sweep cells skip simulation
//!   entirely. [`simulate_batch`] is the serving frontend's entry
//!   point on top: a whole batch of requests against one
//!   (network, sparsity, arch) combination, flattened into one
//!   (request × layer) pool fan-out (DESIGN.md §9).
//! * [`ipu`] — input zero-column detection (bit-level input sparsity).
//! * [`dbmu`] — bit-level DBMU reference datapath (validation).
//! * [`simd`] — SIMD-core cost model and functional post-ops.
//! * [`pipeline`] — functional end-to-end MiniNet execution (bit-exact
//!   against the golden HLO).
//!
//! The *dense digital PIM baseline* of Sec. VI-A is not a separate
//! simulator: it is this machine with every sparsity flag disabled
//! (`ArchConfig::dense_baseline()`), exactly like the paper obtained it
//! by "removing all sparsity support".

pub mod arena;
pub mod backend;
pub mod core_exec;
pub mod dbmu;
pub mod engine;
pub mod ipu;
pub mod kernels;
pub mod machine;
pub mod occupancy;
pub mod pipeline;
pub mod simcache;
pub mod simd;
pub mod trace;

pub use engine::Engine;
pub use machine::{LayerStats, Machine, OpCategory};
pub use simcache::SimCache;

use std::sync::Arc;

use crate::arch::ArchConfig;
use crate::compiler::cache::CompileCache;
use crate::compiler::{self, SparsityConfig};
use crate::energy::{EnergyTable, EventCounts};
use crate::isa::SimdOp;
use crate::models::{LayerKind, Network};
use crate::tensor::MatI8;

/// Whole-network simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Architecture the run used. Shared with the machine that produced
    /// the report (`Arc`): cloning a report, or assembling many reports
    /// from one batch, bumps a refcount instead of deep-copying the
    /// config.
    pub arch: Arc<ArchConfig>,
    pub network: String,
    pub sparsity: SparsityConfig,
    pub layers: Vec<LayerStats>,
    pub totals: EventCounts,
}

impl SimReport {
    /// Makespan over all layers (sequential layer execution).
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.elapsed).sum()
    }

    /// Cycles spent in PIM layers only (std/pw-conv + FC) — the scope
    /// of Fig. 11 and Tab. III.
    pub fn pim_cycles(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| l.category == OpCategory::PimConvFc)
            .map(|l| l.elapsed)
            .sum()
    }

    /// Wall-clock milliseconds at the configured frequency.
    pub fn time_ms(&self) -> f64 {
        self.total_cycles() as f64 * self.arch.clock_ns() / 1e6
    }

    pub fn pim_time_ms(&self) -> f64 {
        self.pim_cycles() as f64 * self.arch.clock_ns() / 1e6
    }

    /// Makespan in integer virtual nanoseconds — the serve loop's
    /// service-time currency (`coordinator::clock`). At least 1 ns so a
    /// degenerate zero-cycle report still advances virtual time.
    pub fn time_ns(&self) -> u64 {
        let ns = (self.total_cycles() as f64 * self.arch.clock_ns()).round();
        if ns >= 1.0 { ns as u64 } else { 1 }
    }

    /// Total energy in microjoules.
    pub fn energy_uj(&self) -> f64 {
        let table = EnergyTable::default28nm();
        self.totals.energy_pj(&table) / 1e6
    }

    /// Actual utilization U_act (Eq. 2) over the run.
    pub fn u_act(&self) -> f64 {
        let cells_per_cycle = self.arch.macro_columns * self.arch.compartments;
        self.totals.u_act(cells_per_cycle)
    }

    /// Cycle share per Fig. 13 category, normalized to 1.0.
    pub fn category_breakdown(&self) -> Vec<(OpCategory, f64)> {
        let total = self.total_cycles().max(1) as f64;
        let mut acc: Vec<(OpCategory, u64)> = vec![
            (OpCategory::PimConvFc, 0),
            (OpCategory::DwConv, 0),
            (OpCategory::Mul, 0),
            (OpCategory::Etc, 0),
        ];
        for l in &self.layers {
            for entry in acc.iter_mut() {
                if entry.0 == l.category {
                    entry.1 += l.elapsed;
                }
            }
        }
        acc.into_iter().map(|(c, v)| (c, v as f64 / total)).collect()
    }

    /// End-to-end speedup of `self` relative to `other` (same network).
    pub fn speedup_vs(&self, other: &SimReport) -> f64 {
        other.total_cycles() as f64 / self.total_cycles().max(1) as f64
    }

    /// PIM-only speedup (Fig. 11 scope).
    pub fn pim_speedup_vs(&self, other: &SimReport) -> f64 {
        other.pim_cycles() as f64 / self.pim_cycles().max(1) as f64
    }

    /// Normalized energy of `self` vs `other` (lower is better).
    pub fn energy_ratio_vs(&self, other: &SimReport) -> f64 {
        self.energy_uj() / other.energy_uj().max(1e-12)
    }
}

/// Perf-mode simulation of a zoo network: weights synthesized +
/// sparsified per `sparsity`, activations synthesized with ReLU-like
/// statistics (DESIGN.md §3), exact event/cycle accounting.
///
/// Layers are independent jobs in perf mode (weights and activations
/// are synthesized per layer index), so compile + simulate spawns into
/// the shared `coordinator::pool` — nesting under a sweep driver's
/// fan-out and over each layer's per-segment fan-out; per-layer stats
/// merge back in layer order and are bit-identical to the sequential
/// walk.
pub fn simulate_network(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
) -> SimReport {
    simulate_network_with_engine(net, sparsity, arch, seed, Engine::Parallel)
}

/// One PIM layer's perf-mode job: compile (through the sweep's
/// [`CompileCache`] when one is provided), synthesize activations when
/// the IPU needs them, simulate. When a [`SimCache`] is provided the
/// whole job is memoized — a hit skips compilation, activation
/// synthesis and simulation entirely. Deterministic per (seed, idx) —
/// both caches only memoize, they never change the result (DESIGN.md
/// §8).
fn simulate_pim_layer(
    net: &Network,
    idx: usize,
    sparsity: SparsityConfig,
    machine: &Machine,
    seed: u64,
    cache: Option<&CompileCache>,
    sim_cache: Option<&SimCache>,
) -> LayerStats {
    let arch = &machine.arch;
    let compute = || {
        let clayer: std::sync::Arc<compiler::CompiledLayer> = match cache {
            Some(cache) => {
                cache.get_or_compile(net, idx, sparsity, arch, seed).expect("not a PIM layer")
            }
            None => std::sync::Arc::new(
                compiler::compile_network_layer(net, idx, sparsity, arch, seed)
                    .expect("not a PIM layer"),
            ),
        };
        let x = arch.input_skipping.then(|| {
            let m = clayer.prep.m.max(1);
            MatI8::from_vec(
                m,
                clayer.prep.k,
                crate::models::synthesize_activations(
                    seed ^ ((idx as u64) << 20),
                    m * clayer.prep.k,
                ),
            )
        });
        let (stats, _) = machine.run_pim_layer(&clayer, x.as_ref(), false);
        (stats, None)
    };
    match sim_cache {
        Some(sc) => {
            sc.get_or_run(net, idx, sparsity, arch, seed, false, compute)
                .expect("not a PIM layer")
                .0
        }
        None => compute().0,
    }
}

/// [`simulate_network`] with an explicit engine: `Engine::Parallel`
/// fans out across layers *and* lets each layer fan its core segments
/// into the same pool (nested scopes compose without oversubscription);
/// `Engine::Sequential` is the fully serial walk. Both produce
/// identical reports.
pub fn simulate_network_with_engine(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
    engine: Engine,
) -> SimReport {
    simulate_network_impl(net, sparsity, arch, seed, engine, None, None)
}

/// [`simulate_network_with_engine`] compiling through a sweep-wide
/// [`CompileCache`]: identical `(arch knobs, layer, sparsity, seed)`
/// combinations across calls compile once and share the `Arc`'d
/// artifact. The report is bit-identical to the uncached path.
pub fn simulate_network_cached(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
    engine: Engine,
    cache: &CompileCache,
) -> SimReport {
    simulate_network_impl(net, sparsity, arch, seed, engine, Some(cache), None)
}

/// [`simulate_network_cached`] additionally memoizing whole per-layer
/// simulation results through a sweep-wide [`SimCache`]: a repeated
/// `(arch knobs, layer, sparsity, seed)` combination skips compilation
/// *and* simulation, returning the memoized [`LayerStats`]. The report
/// is bit-identical to the uncached path (DESIGN.md §8; pinned by
/// `prop_simcache_is_bit_identical_and_hits`).
pub fn simulate_network_memo(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
    engine: Engine,
    cache: &CompileCache,
    sim_cache: &SimCache,
) -> SimReport {
    simulate_network_impl(net, sparsity, arch, seed, engine, Some(cache), Some(sim_cache))
}

#[allow(clippy::too_many_arguments)]
fn simulate_network_impl(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
    engine: Engine,
    cache: Option<&CompileCache>,
    sim_cache: Option<&SimCache>,
) -> SimReport {
    simulate_batch_impl(net, sparsity, arch, std::slice::from_ref(&seed), engine, cache, sim_cache)
        .pop()
        .expect("one report per request")
}

/// Batched serving entry point: one request per entry of `seeds`, all
/// against the same `(net, sparsity, arch)` combination, sharing one
/// [`Machine`], the per-worker scratch arenas and — through the caches —
/// one compiled artifact and one memoized layer result per distinct
/// key across the whole batch. The flattened (request × layer) job list
/// fans into the worker pool together, so heterogeneous request
/// runtimes load-balance better than a per-request fan-out would.
///
/// Reports come back in `seeds` order, each bit-identical to the
/// corresponding serial [`simulate_network_with_engine`] call: batch
/// boundaries, worker count and steal order never leak into results
/// (DESIGN.md §8/§9; pinned by `prop_serve_batched_bit_identical`).
pub fn simulate_batch(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seeds: &[u64],
    engine: Engine,
    cache: &CompileCache,
    sim_cache: &SimCache,
) -> Vec<SimReport> {
    simulate_batch_impl(net, sparsity, arch, seeds, engine, Some(cache), Some(sim_cache))
}

/// Indices of the PIM (std/pw-conv + FC) layers of `net`.
pub(crate) fn pim_indices(net: &Network) -> Vec<usize> {
    (0..net.layers.len()).filter(|&i| net.layers[i].kind.matmul_dims().is_some()).collect()
}

#[allow(clippy::too_many_arguments)]
fn simulate_batch_impl(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seeds: &[u64],
    engine: Engine,
    cache: Option<&CompileCache>,
    sim_cache: Option<&SimCache>,
) -> Vec<SimReport> {
    // The per-layer machines inherit the outer engine: with
    // Engine::Parallel each layer's core segments spawn into the same
    // shared pool its own job runs on (nested scopes execute or steal —
    // no oversubscription), and Engine::Sequential is the fully serial
    // walk. Reports are bit-identical either way.
    let machine = Machine::with_engine(arch.clone(), engine);
    let pim_idx = pim_indices(net);
    let cells: Vec<(u64, usize)> =
        seeds.iter().flat_map(|&seed| pim_idx.iter().map(move |&idx| (seed, idx))).collect();
    let stats: Vec<LayerStats> = {
        let machine = &machine;
        match engine {
            Engine::Parallel => {
                let jobs: Vec<_> = cells
                    .iter()
                    .map(|&(seed, idx)| {
                        move || {
                            simulate_pim_layer(net, idx, sparsity, machine, seed, cache, sim_cache)
                        }
                    })
                    .collect();
                crate::coordinator::pool::run_jobs(jobs)
            }
            Engine::Sequential => cells
                .iter()
                .map(|&(seed, idx)| {
                    simulate_pim_layer(net, idx, sparsity, machine, seed, cache, sim_cache)
                })
                .collect(),
        }
    };
    let mut stats = stats.into_iter();
    seeds
        .iter()
        .map(|_| {
            let mut slots: Vec<Option<LayerStats>> = (0..net.layers.len()).map(|_| None).collect();
            for &idx in &pim_idx {
                slots[idx] = Some(stats.next().expect("per-layer job missing"));
            }
            assemble_report(net, sparsity, &machine, slots)
        })
        .collect()
}

/// Assemble one request's report from its per-PIM-layer stat slots; the
/// SIMD layers are costed inline (deterministic, data-independent and
/// cheap), and totals merge in layer order.
fn assemble_report(
    net: &Network,
    sparsity: SparsityConfig,
    machine: &Machine,
    mut pim_stats: Vec<Option<LayerStats>>,
) -> SimReport {
    let mut layers = Vec::new();
    let mut totals = EventCounts::default();
    for (idx, layer) in net.layers.iter().enumerate() {
        if layer.kind.matmul_dims().is_some() {
            let stats = pim_stats[idx].take().expect("compiled layer missing");
            totals.add(&stats.events);
            layers.push(stats);
        } else if let Some(s) = simd_layer_stats(machine, layer) {
            totals.add(&s.events);
            layers.push(s);
        }
    }

    SimReport {
        arch: Arc::clone(&machine.arch),
        network: net.name.clone(),
        sparsity,
        layers,
        totals,
    }
}

/// Cost one standalone SIMD layer on `machine`'s SIMD core. Returns
/// `None` for PIM layers (they go through the compiler) and for archs
/// without the SIMD core (`dac24`). Deterministic and data-independent;
/// shared by report assembly and the multi-chip sharding layer
/// (`coordinator::sharding`), which must cost SIMD layers exactly once
/// per fleet to stay bit-identical to the single-chip report.
pub(crate) fn simd_layer_stats(
    machine: &Machine,
    layer: &crate::models::Layer,
) -> Option<LayerStats> {
    if !machine.arch.has_simd {
        return None;
    }
    Some(match layer.kind {
        LayerKind::Conv { .. }
        | LayerKind::Fc { .. }
        | LayerKind::Attention { .. }
        | LayerKind::Mlp { .. } => return None,
        LayerKind::DwConv { .. } => {
            machine.run_simd_layer(&layer.name, SimdOp::DwConv, layer.kind.macs())
        }
        LayerKind::Pool { elems } => {
            machine.run_simd_layer(&layer.name, SimdOp::MaxPool, elems as u64)
        }
        LayerKind::Act { elems } => machine.run_simd_layer(&layer.name, SimdOp::Relu, elems as u64),
        LayerKind::ResAdd { elems } => {
            machine.run_simd_layer(&layer.name, SimdOp::ResAdd, elems as u64)
        }
        LayerKind::Mul { elems } => machine.run_simd_layer(&layer.name, SimdOp::Mul, elems as u64),
        // LayerNorm has no dedicated SIMD opcode in the ISA; its
        // element-wise normalize/scale pass is costed like a Mul over
        // the same element count.
        LayerKind::LayerNorm { elems } => {
            machine.run_simd_layer(&layer.name, SimdOp::Mul, elems as u64)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::models::fixtures::small_net;

    #[test]
    fn vgg_speedup_shape_holds() {
        // Scaled-down sanity: a small synthetic net reproduces the
        // "hybrid beats baseline by >3x" shape quickly.
        let net = small_net();
        let hybrid = simulate_network(
            &net,
            SparsityConfig::hybrid(0.6),
            &ArchConfig::db_pim(),
            1,
        );
        let base = simulate_network(
            &net,
            SparsityConfig::hybrid(0.6),
            &ArchConfig::dense_baseline(),
            1,
        );
        let s = hybrid.pim_speedup_vs(&base);
        assert!(s > 2.5, "speedup {s}"); // tiny layers are overhead-bound
        let e = hybrid.energy_ratio_vs(&base);
        assert!(e < 0.5, "energy ratio {e}");
    }

    #[test]
    fn report_breakdown_sums_to_one() {
        let net = models::mobilenet_v2();
        // shrink: simulate only a prefix to keep the test fast
        let prefix = models::Network {
            name: "mnv2-prefix".into(),
            input_hw: net.input_hw,
            input_ch: net.input_ch,
            layers: net.layers[..12].to_vec(),
        };
        let r = simulate_network(
            &prefix,
            SparsityConfig::hybrid(0.6),
            &ArchConfig::db_pim(),
            2,
        );
        let total: f64 = r.category_breakdown().iter().map(|(_, v)| v).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(r.total_cycles() > 0);
        assert!(r.u_act() > 0.0);
    }

    #[test]
    fn simulate_network_engines_agree() {
        let net = small_net();
        let sp = SparsityConfig::hybrid(0.5);
        let arch = ArchConfig::db_pim();
        let p = simulate_network_with_engine(&net, sp, &arch, 4, Engine::Parallel);
        let s = simulate_network_with_engine(&net, sp, &arch, 4, Engine::Sequential);
        assert_eq!(p.totals, s.totals);
        assert_eq!(p.layers.len(), s.layers.len());
        for (a, b) in p.layers.iter().zip(&s.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.events, b.events);
            assert_eq!(a.core_cycles, b.core_cycles);
            assert_eq!(a.elapsed, b.elapsed);
        }
    }

    #[test]
    fn simulate_batch_matches_per_request_reports() {
        let net = small_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.5);
        let cc = CompileCache::new();
        let sc = SimCache::new();
        let seeds = [3u64, 9, 3, 11];
        let batch = simulate_batch(&net, sp, &arch, &seeds, Engine::Parallel, &cc, &sc);
        assert_eq!(batch.len(), seeds.len());
        for (&seed, got) in seeds.iter().zip(&batch) {
            let want = simulate_network_with_engine(&net, sp, &arch, seed, Engine::Sequential);
            assert_eq!(got.totals, want.totals, "seed {seed}");
            assert_eq!(got.layers.len(), want.layers.len());
            for (a, b) in got.layers.iter().zip(&want.layers) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.events, b.events);
                assert_eq!(a.core_cycles, b.core_cycles);
                assert_eq!(a.elapsed, b.elapsed);
            }
        }
        // 4 requests × 2 PIM layers reach the sim cache; the repeated
        // seed's layers are hits (hit/miss counts are deterministic for
        // any schedule — racing duplicates count as dup_computes)
        let s = sc.stats();
        assert_eq!(s.lookups(), 8);
        assert_eq!(s.misses, 6);
        assert_eq!(s.hits, 2);
    }

    #[test]
    fn dac24_skips_simd_layers() {
        let net = small_net();
        let r = simulate_network(&net, SparsityConfig::hybrid(0.0), &ArchConfig::dac24(), 3);
        assert!(r.layers.iter().all(|l| l.category == OpCategory::PimConvFc));
    }
}
