//! Sweep-wide simulation cache.
//!
//! Mirrors `compiler::cache::CompileCache` one level up the stack: the
//! experiment drivers sweep grids in which whole *cells* repeat — most
//! prominently fig11's dense baseline, identical at all four sparsity
//! points of each network. The CompileCache already deduplicates their
//! compiles, but the simulator still re-ran every repeated layer.
//! [`SimCache`] memoizes the full per-layer simulation result
//! ([`LayerStats`], plus the functional accumulators when present), so
//! repeated cells skip compilation *and* simulation entirely.
//!
//! **Key contract.** Perf-mode layer simulation is a pure function of
//! the compiled artifact and the synthesized activations (DESIGN.md
//! §3). The compile key (`compiler::cache::CompileKey`) already pins
//! every input of both: all arch knobs the executor reads are compile
//! knobs (`n_cores`, `compartments`, `macros_per_core`,
//! `tile_load_cycles`, `input_bits`, `macro_columns`, the sparsity
//! feature flags), and activation synthesis is seeded by
//! `(seed, layer_idx, m, k)`, all in the key. Engine choice and worker
//! count are excluded *by the determinism contract* (§8): they cannot
//! change a single bit of the result. The `Program::kernel` backend
//! tag is excluded for the same reason — every kernel backend is
//! bit-identical to the `ScalarRef` oracle (sim::backend), so the
//! choice affects only wall-clock. The only sim-side extension is
//! the `functional` flag (accumulators computed or not).
//!
//! Sharded + counted exactly like the CompileCache; a racing duplicate
//! simulation of one key is harmless (results are bit-identical, first
//! insert wins) and keeps long simulations from serializing the shard.

use std::collections::hash_map::{DefaultHasher, Entry};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::ArchConfig;
use crate::compiler::cache::CompileKey;
use crate::compiler::{CacheStats, SparsityConfig};
use crate::models::Network;
use crate::tensor::MatI32;

use super::machine::LayerStats;

/// Everything that determines one layer's simulation result: the
/// compile key (see module docs) plus the functional flag.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct SimKey {
    compile: CompileKey,
    functional: bool,
}

/// One memoized layer result.
#[derive(Debug)]
struct SimEntry {
    stats: LayerStats,
    /// Functional accumulators (None for perf-mode entries).
    acc: Option<MatI32>,
}

/// Shard count: enough to keep 16 sweep workers from colliding.
const SHARDS: usize = 16;

type Shard = Mutex<HashMap<SimKey, Arc<SimEntry>>>;

/// Content-keyed, mutex-sharded memo of per-layer simulation results,
/// shared across the jobs of one experiment sweep (`SweepCtx`).
#[derive(Debug)]
pub struct SimCache {
    shards: Vec<Shard>,
    hits: AtomicU64,
    misses: AtomicU64,
    dup_computes: AtomicU64,
}

impl Default for SimCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SimCache {
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            dup_computes: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &SimKey) -> &Shard {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// Fetch (or compute via `run`) the simulation result of the PIM
    /// layer at `idx` of `net`. Returns `None` for non-PIM layers
    /// without invoking `run`. `run` executes *outside* the shard lock
    /// (a racing duplicate is bit-identical; the first insert wins).
    ///
    /// Accounting mirrors `CompileCache::get_or_compile`: the lookup
    /// whose insert lands first is the key's one miss, every other
    /// lookup is a hit, and a duplicate `run` that lost the insert is
    /// tallied in [`CacheStats::dup_computes`] — so hit/miss counts are
    /// identical for any worker count or steal order.
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_run(
        &self,
        net: &Network,
        idx: usize,
        sparsity: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
        functional: bool,
        run: impl FnOnce() -> (LayerStats, Option<MatI32>),
    ) -> Option<(LayerStats, Option<MatI32>)> {
        net.layers[idx].kind.matmul_dims()?;
        let key = CompileKey::new(net, idx, sparsity, arch, seed);
        Some(self.get_or_run_keyed(key, functional, run))
    }

    /// Fetch (or compute via `run`) a layer result under an explicit
    /// compile key. The sharding layer uses this with per-chip keys
    /// (`CompileKey::sharded`) to memoize chip-local simulations;
    /// accounting and locking behave exactly as in
    /// [`SimCache::get_or_run`].
    pub(crate) fn get_or_run_keyed(
        &self,
        compile: CompileKey,
        functional: bool,
        run: impl FnOnce() -> (LayerStats, Option<MatI32>),
    ) -> (LayerStats, Option<MatI32>) {
        let key = SimKey { compile, functional };
        let shard = self.shard(&key);
        if let Some(hit) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (hit.stats.clone(), hit.acc.clone());
        }
        let (stats, acc) = run();
        let fresh = Arc::new(SimEntry { stats, acc });
        let mut map = shard.lock().unwrap();
        let entry = match map.entry(key) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.dup_computes.fetch_add(1, Ordering::Relaxed);
                Arc::clone(e.get())
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Arc::clone(v.insert(fresh))
            }
        };
        (entry.stats.clone(), entry.acc.clone())
    }

    /// Mutex shard count (fixed; surfaced by `dbpim info`).
    pub fn shard_count() -> usize {
        SHARDS
    }

    /// Snapshot of the hit/miss counters (a miss = the one simulation
    /// per key whose insert won; see `get_or_run`).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            dup_computes: self.dup_computes.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fixtures::tiny_net;
    use crate::sim::{self, Engine};

    fn layer_result(net: &Network, idx: usize, seed: u64) -> (LayerStats, Option<MatI32>) {
        // a real (tiny) simulation as the closure payload
        let arch = ArchConfig::db_pim();
        let clayer = crate::compiler::compile_network_layer(
            net,
            idx,
            SparsityConfig::hybrid(0.5),
            &arch,
            seed,
        )
        .unwrap();
        let m = clayer.prep.m.max(1);
        let x = crate::tensor::MatI8::from_vec(
            m,
            clayer.prep.k,
            crate::models::synthesize_activations(seed, m * clayer.prep.k),
        );
        let machine = sim::Machine::with_engine(arch, Engine::Sequential);
        let (stats, acc) = machine.run_pim_layer(&clayer, Some(&x), false);
        (stats, acc)
    }

    #[test]
    fn second_lookup_hits_without_running() {
        let cache = SimCache::new();
        let net = tiny_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.5);
        let a = cache
            .get_or_run(&net, 0, sp, &arch, 7, false, || layer_result(&net, 0, 7))
            .unwrap();
        let b = cache
            .get_or_run(&net, 0, sp, &arch, 7, false, || {
                panic!("hit must not re-run the simulation")
            })
            .unwrap();
        assert_eq!(a.0.events, b.0.events);
        assert_eq!(a.0.core_cycles, b.0.core_cycles);
        assert_eq!(a.0.elapsed, b.0.elapsed);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, dup_computes: 0 });
    }

    #[test]
    fn distinct_keys_miss() {
        let cache = SimCache::new();
        let net = tiny_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.5);
        let run = || layer_result(&net, 0, 7);
        cache.get_or_run(&net, 0, sp, &arch, 7, false, run).unwrap();
        // seed, sparsity, arch knob, layer idx, functional: all distinct
        cache.get_or_run(&net, 0, sp, &arch, 8, false, || layer_result(&net, 0, 8)).unwrap();
        cache
            .get_or_run(&net, 0, SparsityConfig::hybrid(0.6), &arch, 7, false, || {
                layer_result(&net, 0, 7)
            })
            .unwrap();
        cache
            .get_or_run(&net, 0, sp, &ArchConfig::dense_baseline(), 7, false, || {
                layer_result(&net, 0, 7)
            })
            .unwrap();
        cache.get_or_run(&net, 2, sp, &arch, 7, false, || layer_result(&net, 2, 7)).unwrap();
        cache.get_or_run(&net, 0, sp, &arch, 7, true, || layer_result(&net, 0, 7)).unwrap();
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 6, dup_computes: 0 });
    }

    #[test]
    fn non_pim_layers_return_none_without_counting() {
        let cache = SimCache::new();
        let net = tiny_net();
        let r = cache.get_or_run(
            &net,
            1,
            SparsityConfig::dense(),
            &ArchConfig::db_pim(),
            1,
            false,
            || panic!("non-PIM layer must not run"),
        );
        assert!(r.is_none());
        assert_eq!(cache.stats().lookups(), 0);
    }
}
