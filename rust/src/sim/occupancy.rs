//! Bit-plane occupancy precompute for the IPU inner loop.
//!
//! The hot path of the row-loop simulation is the per-(row, step)
//! column-occupancy OR: the IPU ORs the 16 gathered input bytes of a
//! compartment step and counts the surviving bit columns (ipu.rs).
//! Instead of re-gathering and OR-folding byte-by-byte for every
//! (tile, row, step), [`OccupancyTable`] gathers each im2col row's kept
//! activations once per (layer, assignment), packs the 8 bit-planes
//! into `u64` words (8 activation bytes per word, little-endian), and
//! reduces every step with a word-wise OR + horizontal fold.
//!
//! The occupancy bytes are stored **step-major** (`occ[step][m]`): all
//! M rows of one step are contiguous, which is what lets
//! `sim::kernels::scan_tile_occupancy` walk a tile's occupancy 8 input
//! rows at a time as `u64` words instead of byte-at-a-time.
//!
//! Occupancy bytes are bit-identical to the scalar fold — `u64` OR over
//! packed bytes distributes over the per-byte OR — so the engines built
//! on this table stay exactly equivalent to the legacy interpreter.

use crate::tensor::MatI8;
use crate::util::ceil_div;

/// Reinterpret an `i8` slice as raw bytes (identical layout; the IPU
/// treats activations as unsigned bit patterns).
#[inline]
pub fn i8_as_u8(xs: &[i8]) -> &[u8] {
    // SAFETY: i8 and u8 have the same size, alignment and validity.
    unsafe { std::slice::from_raw_parts(xs.as_ptr().cast(), xs.len()) }
}

/// OR-fold a byte slice word-wise: 8 bytes per `u64` OR, then a
/// horizontal fold of the surviving word. Equivalent to
/// `bytes.iter().fold(0, |o, &b| o | b)`.
#[inline]
pub fn or_fold_bytes(bytes: &[u8]) -> u8 {
    let mut acc = 0u64;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        acc |= u64::from_le_bytes(c.try_into().unwrap());
    }
    let mut tail = 0u8;
    for &b in chunks.remainder() {
        tail |= b;
    }
    acc |= acc >> 32;
    acc |= acc >> 16;
    acc |= acc >> 8;
    tail | (acc as u8)
}

/// Word-packed gathered activations + per-step occupancy bytes for one
/// assignment (all M rows), built once per (layer, assignment).
#[derive(Debug, Clone)]
pub struct OccupancyTable {
    /// Assignment index this table was built for (executor cache key).
    pub assignment: usize,
    kept_len: usize,
    /// Row stride in bytes (kept_len rounded up to a whole u64 word).
    stride: usize,
    /// Gathered rows, m-major: `bytes[m * stride + i] = x[m][kept[i]]`
    /// as its raw bit pattern, zero-padded to the stride. Empty when
    /// built without `keep_gathered` (perf-only IPU runs read nothing
    /// but `occ`, so the full M × kept matrix would be dead weight).
    bytes: Vec<u8>,
    /// Compartment steps over the kept rows; 0 when built without
    /// occupancy (functional-only use).
    steps: usize,
    /// Input rows gathered (the layer's M).
    m_total: usize,
    /// Per-(global step, m) occupancy byte, step-major:
    /// `occ[step * m_total + m]` — all M rows of a step contiguous for
    /// the word-batched kernel walk.
    occ: Vec<u8>,
    /// Gather scratch row used when the gathered rows are NOT retained
    /// (perf-only builds). Kept in the struct so recycled tables
    /// (`sim::arena`) reuse its capacity instead of reallocating per
    /// build.
    scratch: Vec<u8>,
}

impl OccupancyTable {
    /// An unbuilt table (the arena's recycling seed): no rows, no
    /// steps, and an assignment sentinel no real build can match.
    /// [`build_into`](Self::build_into) turns it into a live table.
    pub fn empty() -> Self {
        Self {
            assignment: usize::MAX,
            kept_len: 0,
            stride: 0,
            bytes: Vec::new(),
            steps: 0,
            m_total: 0,
            occ: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Gather + pack all `m_total` rows of `x` for `kept`. `with_occ`
    /// precomputes the per-step occupancy bytes (IPU enabled);
    /// `keep_gathered` retains the gathered rows (functional runs need
    /// the values, perf-only runs don't — and perf-only builds skip the
    /// per-row scratch entirely). `comp` is the compartment count
    /// (lanes per step).
    pub fn build(
        assignment: usize,
        x: &MatI8,
        kept: &[u32],
        comp: usize,
        m_total: usize,
        with_occ: bool,
        keep_gathered: bool,
    ) -> Self {
        let mut t = Self::empty();
        t.build_into(assignment, x, kept, comp, m_total, with_occ, keep_gathered);
        t
    }

    /// Reset-and-fill form of [`build`](Self::build): rebuilds `self`
    /// in place for new inputs, reusing its buffer capacities. After
    /// warm-up an arena-recycled table makes this allocation-free —
    /// the result is bit-identical to a fresh `build` (every byte of
    /// every buffer is rewritten or zero-filled below).
    #[allow(clippy::too_many_arguments)]
    pub fn build_into(
        &mut self,
        assignment: usize,
        x: &MatI8,
        kept: &[u32],
        comp: usize,
        m_total: usize,
        with_occ: bool,
        keep_gathered: bool,
    ) {
        let kept_len = kept.len();
        let stride = ceil_div(kept_len.max(1), 8) * 8;
        let steps = if with_occ { ceil_div(kept_len, comp) } else { 0 };
        self.assignment = assignment;
        self.kept_len = kept_len;
        self.stride = stride;
        self.steps = steps;
        self.m_total = m_total;
        self.bytes.clear();
        self.bytes.resize(if keep_gathered { m_total * stride } else { 0 }, 0);
        self.occ.clear();
        self.occ.resize(m_total * steps, 0);
        // the scratch row only backs the gather when the gathered rows
        // are NOT retained; sizing it otherwise would be dead weight
        self.scratch.clear();
        self.scratch.resize(if keep_gathered { 0 } else { stride }, 0);
        for m in 0..m_total {
            let xrow = i8_as_u8(x.row(m));
            let row: &mut [u8] = if keep_gathered {
                &mut self.bytes[m * stride..m * stride + kept_len]
            } else {
                &mut self.scratch[..kept_len]
            };
            for (dst, &k) in row.iter_mut().zip(kept) {
                *dst = xrow[k as usize];
            }
            let row = &row[..];
            for s in 0..steps {
                let start = s * comp;
                let lanes = (kept_len - start).min(comp);
                self.occ[s * m_total + m] = or_fold_bytes(&row[start..start + lanes]);
            }
        }
    }

    /// Internal buffer capacities — arena growth accounting: a
    /// `build_into` that changes any of these reallocated (capacities
    /// never shrink), which the executor reports via
    /// `arena::note_growth` so the zero-miss assertions stay honest.
    pub(crate) fn buf_capacities(&self) -> (usize, usize, usize) {
        (self.bytes.capacity(), self.occ.capacity(), self.scratch.capacity())
    }

    /// Poison the executor cache key before the table enters the arena
    /// free list, so a recycled table can never falsely match a new
    /// layer's assignment index (defense in depth — executors rebuild
    /// every table they take anyway).
    pub(crate) fn retire(&mut self) {
        self.assignment = usize::MAX;
        self.steps = 0;
        self.m_total = 0;
        self.kept_len = 0;
    }

    /// Whether the gathered rows were retained.
    #[inline]
    pub fn has_gathered(&self) -> bool {
        !self.bytes.is_empty()
    }

    /// Gathered kept activations of row `m` (raw bit patterns). Only
    /// valid when built with `keep_gathered`.
    #[inline]
    pub fn gathered_row(&self, m: usize) -> &[u8] {
        &self.bytes[m * self.stride..m * self.stride + self.kept_len]
    }

    /// Occupancy byte of `(row m, global step)` — the OR of the step's
    /// lanes. Only valid when built `with_occ`.
    #[inline]
    pub fn step_occ(&self, m: usize, step: usize) -> u8 {
        self.occ[step * self.m_total + m]
    }

    /// All M occupancy bytes of one global step (the contiguous lane of
    /// the step-major walk). Only valid when built `with_occ`.
    #[inline]
    pub fn step_row(&self, step: usize) -> &[u8] {
        &self.occ[step * self.m_total..(step + 1) * self.m_total]
    }

    /// Whether per-step occupancy bytes were precomputed.
    #[inline]
    pub fn has_occ(&self) -> bool {
        self.steps > 0
    }

    /// Global compartment steps covered (0 without occupancy).
    #[inline]
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Input rows gathered.
    #[inline]
    pub fn m_rows(&self) -> usize {
        self.m_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn or_fold_matches_scalar_fold() {
        let mut rng = Rng::new(31);
        for len in 0..40usize {
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let scalar = bytes.iter().fold(0u8, |o, &b| o | b);
            assert_eq!(or_fold_bytes(&bytes), scalar, "len {len}");
        }
    }

    #[test]
    fn i8_view_matches_bit_patterns() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let group: Vec<i8> = (0..16).map(|_| rng.int8()).collect();
            // same fold as the scalar IPU definition over `v as u8`
            let scalar = group.iter().fold(0u8, |o, &v| o | (v as u8));
            assert_eq!(or_fold_bytes(i8_as_u8(&group)), scalar);
        }
        assert_eq!(i8_as_u8(&[-128, -1, 0, 1]), &[0x80, 0xFF, 0, 1]);
    }

    #[test]
    fn table_matches_direct_gather_and_fold() {
        let mut rng = Rng::new(91);
        for _ in 0..20 {
            let m_total = 1 + rng.below(6) as usize;
            let k = 20 + rng.below(200) as usize;
            let comp = 16;
            let x = MatI8::from_vec(m_total, k, (0..m_total * k).map(|_| rng.int8()).collect());
            // a random strictly-ascending kept subset
            let kept: Vec<u32> =
                (0..k as u32).filter(|_| rng.below(3) > 0).collect();
            if kept.is_empty() {
                continue;
            }
            let t = OccupancyTable::build(0, &x, &kept, comp, m_total, true, true);
            assert!(t.has_occ() && t.has_gathered());
            assert_eq!(t.m_rows(), m_total);
            assert_eq!(t.steps(), crate::util::ceil_div(kept.len(), comp));
            // occ-only build (perf mode) agrees and drops the bytes
            let t_occ = OccupancyTable::build(0, &x, &kept, comp, m_total, true, false);
            assert!(!t_occ.has_gathered());
            for m in 0..m_total {
                for s in 0..crate::util::ceil_div(kept.len(), comp) {
                    assert_eq!(t_occ.step_occ(m, s), t.step_occ(m, s));
                }
            }
            for m in 0..m_total {
                let gathered: Vec<u8> =
                    kept.iter().map(|&kk| x.get(m, kk as usize) as u8).collect();
                assert_eq!(t.gathered_row(m), &gathered[..]);
                let steps = crate::util::ceil_div(kept.len(), comp);
                for s in 0..steps {
                    let start = s * comp;
                    let lanes = (kept.len() - start).min(comp);
                    let want = gathered[start..start + lanes]
                        .iter()
                        .fold(0u8, |o, &b| o | b);
                    assert_eq!(t.step_occ(m, s), want, "m {m} step {s}");
                    // the step-major lane exposes the same byte
                    assert_eq!(t.step_row(s)[m], want, "m {m} step {s}");
                }
            }
        }
    }

    #[test]
    fn build_into_reuse_is_bit_identical_to_fresh_build() {
        // rebuild one table object across random inputs (the arena's
        // reuse pattern) and compare every observable against a fresh
        // build — no byte of a previous build may survive
        let mut rng = Rng::new(77);
        let mut reused = OccupancyTable::empty();
        for case in 0..25usize {
            let m_total = 1 + rng.below(10) as usize;
            let k = 8 + rng.below(120) as usize;
            let comp = [4usize, 8, 16][rng.below(3) as usize];
            let x = MatI8::from_vec(m_total, k, (0..m_total * k).map(|_| rng.int8()).collect());
            let kept: Vec<u32> = (0..k as u32).filter(|_| rng.below(3) > 0).collect();
            let with_occ = rng.below(4) > 0;
            let keep_gathered = rng.below(2) == 0;
            let fresh =
                OccupancyTable::build(case, &x, &kept, comp, m_total, with_occ, keep_gathered);
            reused.build_into(case, &x, &kept, comp, m_total, with_occ, keep_gathered);
            assert_eq!(reused.assignment, fresh.assignment);
            assert_eq!(reused.steps(), fresh.steps(), "case {case}");
            assert_eq!(reused.m_rows(), fresh.m_rows());
            assert_eq!(reused.has_gathered(), fresh.has_gathered());
            for m in 0..m_total {
                if fresh.has_gathered() {
                    assert_eq!(reused.gathered_row(m), fresh.gathered_row(m), "case {case}");
                }
                for s in 0..fresh.steps() {
                    assert_eq!(reused.step_occ(m, s), fresh.step_occ(m, s), "case {case}");
                }
            }
        }
    }

    #[test]
    fn table_without_occ_still_gathers() {
        let x = MatI8::from_vec(2, 4, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let t = OccupancyTable::build(3, &x, &[0, 2, 3], 16, 2, false, true);
        assert!(!t.has_occ());
        assert!(t.has_gathered());
        assert_eq!(t.assignment, 3);
        assert_eq!(t.gathered_row(0), &[1, 3, 4]);
        assert_eq!(t.gathered_row(1), &[5, 7, 8]);
    }

    #[test]
    fn empty_kept_set_builds_degenerate_table() {
        let x = MatI8::from_vec(3, 5, vec![1; 15]);
        let t = OccupancyTable::build(0, &x, &[], 16, 3, true, true);
        assert!(!t.has_occ(), "no kept rows ⇒ no steps");
        assert_eq!(t.steps(), 0);
        assert_eq!(t.m_rows(), 3);
        for m in 0..3 {
            assert!(t.gathered_row(m).is_empty());
        }
        // perf-mode build of the same degenerate case
        let t2 = OccupancyTable::build(0, &x, &[], 16, 3, true, false);
        assert!(!t2.has_gathered() && !t2.has_occ());
    }

    #[test]
    fn non_word_aligned_strides_pad_with_zeros() {
        // kept_len % 8 != 0 exercises the stride padding on every row
        let mut rng = Rng::new(3);
        for kept_len in [1usize, 3, 7, 9, 13, 15, 17, 23] {
            let k = 32;
            let m_total = 4;
            let x = MatI8::from_vec(
                m_total,
                k,
                (0..m_total * k).map(|_| rng.int8()).collect(),
            );
            let kept: Vec<u32> = (0..kept_len as u32).collect();
            let t = OccupancyTable::build(1, &x, &kept, 4, m_total, true, true);
            assert_eq!(t.steps(), crate::util::ceil_div(kept_len, 4));
            for m in 0..m_total {
                assert_eq!(t.gathered_row(m).len(), kept_len);
                for s in 0..t.steps() {
                    let start = s * 4;
                    let lanes = (kept_len - start).min(4);
                    let want = (start..start + lanes)
                        .fold(0u8, |o, i| o | (x.get(m, i) as u8));
                    assert_eq!(t.step_occ(m, s), want, "kept {kept_len} m {m} s {s}");
                }
            }
        }
    }

    #[test]
    fn single_row_m_total_table() {
        // m_total == 1: the step-major lanes are one byte wide
        let x = MatI8::from_vec(1, 6, vec![0, 0x11, 0, 0x22, 0, 0x44]);
        let t = OccupancyTable::build(0, &x, &[1, 3, 5], 2, 1, true, true);
        assert_eq!(t.m_rows(), 1);
        assert_eq!(t.steps(), 2);
        assert_eq!(t.step_occ(0, 0), 0x11 | 0x22);
        assert_eq!(t.step_occ(0, 1), 0x44);
        assert_eq!(t.step_row(0), &[0x33]);
        assert_eq!(t.step_row(1), &[0x44]);
        assert_eq!(t.gathered_row(0), &[0x11, 0x22, 0x44]);
    }
}
