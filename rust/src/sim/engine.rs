//! Barrier scheduler: executes a compiled layer's segmented [`Program`]
//! phase by phase.
//!
//! Within a phase, cores share no mutable state (each [`CoreExecutor`]
//! owns its clock, events, occupancy cache and accumulator slice), so
//! [`Engine::Parallel`] spawns the phase's segments into the shared
//! `coordinator::pool` — composing with the layer- and sweep-level
//! fan-outs above it, since nested pool scopes execute or steal instead
//! of spawning threads — while [`Engine::Sequential`] runs them inline;
//! both merge results in
//! ascending core order and are bit-identical — same cycles, same
//! [`EventCounts`], same functional accumulators — to each other and to
//! the legacy flat-stream interpreter ([`run_layer_interp`]), which is
//! retained as the equivalence baseline (tests/prop_invariants.rs).

use crate::compiler::{Barrier, CompiledLayer};
use crate::energy::EventCounts;
use crate::isa::{Instr, Segment};
use crate::tensor::{MatI8, MatI32};

use super::core_exec::{CoreAcc, CoreExecutor};
use super::machine::{LayerStats, Machine, OpCategory};
use super::simd;

/// How a layer's segmented program is driven.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Segments run inline on the calling thread (core order).
    Sequential,
    /// Segments of a phase fan out over worker threads.
    Parallel,
}

impl Engine {
    /// Parse a CLI/env spelling ("sequential" | "parallel").
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "sequential" | "seq" => Some(Engine::Sequential),
            "parallel" | "par" => Some(Engine::Parallel),
            _ => None,
        }
    }
}

/// Result of draining one segment on one core.
struct SegmentOut {
    core: usize,
    clock: u64,
    events: EventCounts,
    acc: Option<CoreAcc>,
}

fn run_segment(
    machine: &Machine,
    layer: &CompiledLayer,
    x: Option<&MatI8>,
    seg: &Segment,
    functional: bool,
    m_total: usize,
) -> SegmentOut {
    let mut ex =
        CoreExecutor::new(&machine.arch, layer, x, seg.core as usize, functional, m_total);
    for instr in &seg.instrs {
        ex.exec(instr);
    }
    // take the outputs (the executor's Drop returns its cached
    // table/scan to the thread arena)
    SegmentOut {
        core: seg.core as usize,
        clock: ex.clock,
        events: std::mem::take(&mut ex.events),
        acc: ex.acc.take(),
    }
}

fn validate_inputs(machine: &Machine, layer: &CompiledLayer, x: Option<&MatI8>, functional: bool) {
    if functional || machine.arch.input_skipping {
        let x = x.expect("input matrix required for functional/IPU simulation");
        let m_total = layer.prep.m.max(1);
        assert_eq!(x.rows, m_total, "input rows != layer M");
        assert_eq!(x.cols, layer.prep.k, "input cols != layer K");
    }
}

fn finish(
    machine: &Machine,
    layer: &CompiledLayer,
    mut events: EventCounts,
    clocks: Vec<u64>,
    acc: Option<MatI32>,
) -> (LayerStats, Option<MatI32>) {
    let elapsed = clocks.iter().copied().max().unwrap_or(0);
    events.elapsed_cycles = elapsed;
    events.core_cycles = elapsed * machine.arch.n_cores as u64;
    let stats = LayerStats {
        name: layer.prep.name.clone(),
        category: OpCategory::PimConvFc,
        events,
        core_cycles: clocks,
        elapsed,
    };
    (stats, acc)
}

/// Apply a phase-closing barrier to the core clocks + shared events.
fn apply_barrier(barrier: Barrier, clocks: &mut [u64], events: &mut EventCounts, machine: &Machine) {
    match barrier {
        Barrier::Sync => {
            events.instrs += 1;
            let max = clocks.iter().copied().max().unwrap_or(0);
            clocks.iter_mut().for_each(|c| *c = max);
        }
        Barrier::Simd { op, elems } => {
            events.instrs += 1;
            let c = simd::simd_cycles(op, elems as u64, &machine.arch);
            events.simd_lane_ops += simd::lane_ops(op, elems as u64);
            let max = clocks.iter().copied().max().unwrap_or(0);
            clocks.iter_mut().for_each(|c2| *c2 = max + c);
        }
        Barrier::End => events.instrs += 1,
        Barrier::Open => {}
    }
}

/// Execute a compiled layer's segmented program under `engine`.
pub fn run_layer(
    machine: &Machine,
    layer: &CompiledLayer,
    x: Option<&MatI8>,
    functional: bool,
    engine: Engine,
) -> (LayerStats, Option<MatI32>) {
    validate_inputs(machine, layer, x, functional);
    let arch = &machine.arch;
    let m_total = layer.prep.m.max(1);
    let mut events = EventCounts::default();
    let mut clocks = vec![0u64; arch.n_cores];
    let mut acc = functional.then(|| MatI32::zeros(m_total, layer.prep.n));

    for phase in &layer.program.phases {
        let outs: Vec<SegmentOut> = if engine == Engine::Parallel && phase.segments.len() > 1 {
            let jobs: Vec<_> = phase
                .segments
                .iter()
                .map(|seg| move || run_segment(machine, layer, x, seg, functional, m_total))
                .collect();
            crate::coordinator::pool::run_jobs(jobs)
        } else {
            phase
                .segments
                .iter()
                .map(|seg| run_segment(machine, layer, x, seg, functional, m_total))
                .collect()
        };
        // Deterministic merge: ascending core order (segment order).
        // Merged CoreAccs recycle their block storage to the arena.
        for out in outs {
            clocks[out.core] += out.clock;
            events += &out.events;
            if let Some(ca) = out.acc {
                if let Some(acc) = acc.as_mut() {
                    ca.merge_into(acc);
                }
                ca.recycle();
            }
        }
        apply_barrier(phase.barrier, &mut clocks, &mut events, machine);
    }
    finish(machine, layer, events, clocks, acc)
}

/// Legacy single-thread interpreter: walks the flat instruction stream
/// in its original interleaved order, dispatching per-core instructions
/// to per-core executors. Kept as the ground-truth baseline the
/// segmented engines are property-tested against.
pub fn run_layer_interp(
    machine: &Machine,
    layer: &CompiledLayer,
    x: Option<&MatI8>,
    functional: bool,
) -> (LayerStats, Option<MatI32>) {
    validate_inputs(machine, layer, x, functional);
    let arch = &machine.arch;
    let m_total = layer.prep.m.max(1);
    let mut execs: Vec<CoreExecutor> = (0..arch.n_cores)
        .map(|c| CoreExecutor::new(arch, layer, x, c, functional, m_total))
        .collect();
    let mut clocks = vec![0u64; arch.n_cores];
    let mut events = EventCounts::default(); // barrier-level events
    for instr in &layer.instrs {
        match *instr {
            Instr::Sync => apply_barrier(Barrier::Sync, &mut clocks, &mut events, machine),
            Instr::EndLayer => apply_barrier(Barrier::End, &mut clocks, &mut events, machine),
            Instr::Simd { op, elems } => {
                apply_barrier(Barrier::Simd { op, elems }, &mut clocks, &mut events, machine)
            }
            Instr::LoadTile { core, .. } | Instr::Compute { core, .. } | Instr::Store { core, .. } => {
                let ex = &mut execs[core as usize];
                let before = ex.clock;
                ex.exec(instr);
                clocks[core as usize] += ex.clock - before;
            }
        }
    }
    let mut acc = functional.then(|| MatI32::zeros(m_total, layer.prep.n));
    for mut ex in execs {
        events += &ex.events;
        if let Some(ca) = ex.acc.take() {
            if let Some(acc) = acc.as_mut() {
                ca.merge_into(acc);
            }
            ca.recycle();
        }
    }
    finish(machine, layer, events, clocks, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::compiler::{compile_layer, prepare_layer, SparsityConfig};
    use crate::models::synthesize_weights;
    use crate::quant;

    fn build(arch: &ArchConfig, seed: u64) -> (CompiledLayer, MatI8) {
        let (m, k, n) = (10, 160, 24);
        let w = synthesize_weights(seed, k, n);
        let prep = prepare_layer(
            "t", m, k, n, w,
            SparsityConfig::hybrid(0.5),
            arch,
            quant::requant_mul(0.01),
            true,
            None,
        );
        let layer = compile_layer(prep, arch);
        let x = MatI8::from_vec(
            m,
            k,
            crate::models::synthesize_activations(seed ^ 3, m * k),
        );
        (layer, x)
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("sequential"), Some(Engine::Sequential));
        assert_eq!(Engine::parse("par"), Some(Engine::Parallel));
        assert_eq!(Engine::parse("turbo"), None);
    }

    #[test]
    fn engines_and_interp_agree_functionally() {
        let arch = ArchConfig::db_pim();
        let (layer, x) = build(&arch, 17);
        let m = Machine::new(arch);
        let (s_int, a_int) = run_layer_interp(&m, &layer, Some(&x), true);
        let (s_seq, a_seq) = run_layer(&m, &layer, Some(&x), true, Engine::Sequential);
        let (s_par, a_par) = run_layer(&m, &layer, Some(&x), true, Engine::Parallel);
        assert_eq!(s_int.events, s_seq.events);
        assert_eq!(s_int.events, s_par.events);
        assert_eq!(s_int.core_cycles, s_seq.core_cycles);
        assert_eq!(s_int.core_cycles, s_par.core_cycles);
        assert_eq!(s_int.elapsed, s_par.elapsed);
        assert_eq!(a_int, a_seq);
        assert_eq!(a_int, a_par);
    }

    #[test]
    fn single_core_arch_runs_inline() {
        let arch = ArchConfig { n_cores: 1, ..ArchConfig::db_pim() };
        let (layer, x) = build(&arch, 5);
        let m = Machine::new(arch);
        let (s_par, _) = run_layer(&m, &layer, Some(&x), false, Engine::Parallel);
        let (s_int, _) = run_layer_interp(&m, &layer, Some(&x), false);
        assert_eq!(s_par.events, s_int.events);
        assert_eq!(s_par.elapsed, s_int.elapsed);
    }
}
