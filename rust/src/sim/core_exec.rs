//! Per-core segment executor.
//!
//! A [`CoreExecutor`] owns everything one PIM core touches while
//! executing a barrier-free instruction segment: its clock, its event
//! counters, its slice of the functional accumulators ([`CoreAcc`] —
//! the filter columns of the core's assignments, disjoint across cores
//! by construction of the packing), and a cached [`OccupancyTable`] for
//! the assignment currently resident. Because no shared state is
//! mutated between barriers, segments of one phase can execute on
//! worker threads and merge deterministically (sim::engine).
//!
//! The timing/event semantics are an exact port of the original
//! single-thread interpreter loop (machine.rs pre-refactor, DESIGN.md
//! §6): every engine built on this executor is bit-identical to it.

use crate::arch::ArchConfig;
use crate::compiler::{Assignment, CompiledLayer, PreparedLayer, Tile};
use crate::energy::EventCounts;
use crate::isa::Instr;
use crate::tensor::{MatI8, MatI32};
use crate::util::ceil_div;

use super::occupancy::OccupancyTable;

/// Functional accumulator slice owned by one core: the filter columns
/// of the core's assignments, stored densely as [M, owned_filters].
#[derive(Debug, Clone)]
pub struct CoreAcc {
    /// Owned global filter columns, ascending.
    pub filters: Vec<usize>,
    /// Global filter column -> local column (usize::MAX = not owned).
    col_of: Vec<usize>,
    /// m_total × filters.len() accumulators, m-major.
    pub data: Vec<i32>,
    m_total: usize,
}

impl CoreAcc {
    pub fn new(layer: &CompiledLayer, core: usize, m_total: usize) -> Self {
        let mut filters: Vec<usize> = layer
            .assignments
            .iter()
            .filter(|a| a.core == core)
            .flat_map(|a| a.filters.iter().copied())
            .collect();
        filters.sort_unstable();
        filters.dedup();
        let mut col_of = vec![usize::MAX; layer.prep.n];
        for (i, &f) in filters.iter().enumerate() {
            col_of[f] = i;
        }
        let data = vec![0i32; m_total * filters.len()];
        Self { filters, col_of, data, m_total }
    }

    /// Fold this core's columns into the shared [M, N] accumulator.
    /// Columns are disjoint across cores, so the merge order cannot
    /// change the result.
    pub fn merge_into(&self, acc: &mut MatI32) {
        let w = self.filters.len();
        for m in 0..self.m_total {
            let row = &self.data[m * w..(m + 1) * w];
            let acc_row = &mut acc.data[m * acc.cols..(m + 1) * acc.cols];
            for (i, &f) in self.filters.iter().enumerate() {
                acc_row[f] += row[i];
            }
        }
    }
}

/// Execution state of one PIM core over one layer.
#[derive(Debug)]
pub struct CoreExecutor<'a> {
    arch: &'a ArchConfig,
    layer: &'a CompiledLayer,
    x: Option<&'a MatI8>,
    pub core: usize,
    m_total: usize,
    /// Clock advance accumulated by this executor (cycles).
    pub clock: u64,
    pub events: EventCounts,
    /// Functional accumulators (None in perf-only mode).
    pub acc: Option<CoreAcc>,
    /// Cached gather/occupancy table for the resident assignment.
    table: Option<OccupancyTable>,
}

impl<'a> CoreExecutor<'a> {
    pub fn new(
        arch: &'a ArchConfig,
        layer: &'a CompiledLayer,
        x: Option<&'a MatI8>,
        core: usize,
        functional: bool,
        m_total: usize,
    ) -> Self {
        let acc = functional.then(|| CoreAcc::new(layer, core, m_total));
        Self { arch, layer, x, core, m_total, clock: 0, events: EventCounts::default(), acc, table: None }
    }

    /// Execute one per-core instruction. Barriers are handled by the
    /// scheduler and must never reach a segment executor.
    pub fn exec(&mut self, instr: &Instr) {
        self.events.instrs += 1;
        let arch = self.arch;
        let layer = self.layer;
        match *instr {
            Instr::LoadTile { tile, .. } => {
                let t = &layer.tiles[tile as usize];
                let a = &layer.assignments[t.assignment];
                // every cell of the tile written once, in all Tm
                // macro replicas
                let cells = t.rows() * a.active_cols() * arch.macros_per_core;
                self.events.weight_writes += cells as u64;
                self.clock += arch.tile_load_cycles;
                // mask RF consulted once per tile to build the
                // gather list (value sparsity only)
                if arch.value_sparsity {
                    self.events.mask_rf_reads += t.rows() as u64;
                }
            }
            Instr::Compute { tile, m_base, m_count, .. } => {
                let cycles = self.compute_chunk(tile as usize, m_base as usize, m_count as usize);
                self.clock += cycles;
            }
            Instr::Store { tile, m_count, .. } => {
                let t = &layer.tiles[tile as usize];
                let a = &layer.assignments[t.assignment];
                let words = m_count as u64 * a.filters.len() as u64;
                self.events.output_buf_writes += words;
                if t.row_start > 0 {
                    // partial-sum reload for non-first K tiles
                    self.events.output_buf_reads += words;
                }
                // store drains through the PPU: 1 cycle per Tm-batch
                self.clock += ceil_div(words as usize, arch.macros_per_core) as u64;
            }
            Instr::Simd { .. } | Instr::Sync | Instr::EndLayer => {
                unreachable!("barrier instruction inside a segment: {instr:?}")
            }
        }
    }

    /// (Re)build the gather/occupancy table when the resident
    /// assignment changes. Tiles of one assignment are contiguous in
    /// every core's stream, so a single-slot cache never thrashes.
    fn ensure_table(&mut self, assignment: usize) {
        if self.table.as_ref().map(|t| t.assignment) == Some(assignment) {
            return;
        }
        let x = self.x.expect("input required");
        let a = &self.layer.assignments[assignment];
        self.table = Some(OccupancyTable::build(
            assignment,
            x,
            &a.kept_rows,
            self.arch.compartments,
            self.m_total,
            self.arch.input_skipping,
            // perf-only IPU runs read nothing but the occ bytes
            self.acc.is_some(),
        ));
    }

    /// Process one Compute chunk (≤ Tm input rows on this core).
    /// Returns the core-clock advance (max over the chunk's rows).
    fn compute_chunk(&mut self, tile_idx: usize, m_base: usize, m_count: usize) -> u64 {
        let arch = self.arch;
        let layer = self.layer;
        let t = &layer.tiles[tile_idx];
        let a = &layer.assignments[t.assignment];
        let prep = &layer.prep;
        let comp = arch.compartments;
        let rows = t.rows();
        let steps = ceil_div(rows, comp);
        let demand = a.active_cols() as u64;
        let functional = self.acc.is_some();

        // Fast analytic path: timing is data-independent without IPU
        // skipping, so one row's cost is every row's cost.
        if !arch.input_skipping && !functional {
            let bits = arch.input_bits as u64;
            let cycles_per_row = steps as u64 * bits;
            let full_steps = rows / comp;
            let tail = rows % comp;
            // effective cells per bit-cycle (U_act numerator)
            let eff_cells: u64 = if arch.weight_bit_sparsity {
                (full_steps as u64 * comp as u64 + tail as u64) * demand
            } else {
                // dense: effective = non-zero weight bits actually stored
                dense_effective_cells(t, a, prep)
            };
            let mc = m_count as u64;
            self.events.macro_cycles += cycles_per_row * mc;
            self.events.macro_col_cycles += cycles_per_row * mc * arch.macro_columns as u64;
            self.events.active_col_cycles += eff_cells * bits * mc;
            self.events.input_buf_reads += steps as u64 * mc;
            if arch.value_sparsity {
                self.events.alloc_switches += rows as u64 * mc;
            }
            if arch.weight_bit_sparsity {
                self.events.meta_rf_reads += steps as u64 * mc;
            }
            self.events.macs += rows as u64 * a.filters.len() as u64 * mc;
            return cycles_per_row;
        }

        // Row-loop path: per-assignment occupancy precompute replaces
        // the per-(tile, row, step) gather + byte-wise OR fold.
        self.ensure_table(t.assignment);
        let x = self.x;
        let Self { table, acc, events, .. } = self;
        let table = table.as_ref().expect("table just built");
        let mut acc = acc.as_mut();

        let kept = &a.kept_rows[t.row_start..t.row_end];
        // Global step base when tile rows align with compartment steps
        // (always true for k_slots-sized tiles); otherwise fall back to
        // an on-the-fly fold over the gathered row.
        let base_step = (arch.input_skipping && t.row_start % comp == 0 && table.has_occ())
            .then(|| t.row_start / comp);
        // Per-step effective cells are row-independent; hoist them.
        let step_eff: Vec<u64> = if arch.input_skipping {
            (0..steps)
                .map(|s| {
                    let lanes = (rows - s * comp).min(comp);
                    if arch.weight_bit_sparsity {
                        demand * lanes as u64
                    } else {
                        dense_step_effective_cells(t, a, prep, comp, s, lanes)
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        let row_eff: u64 = if arch.input_skipping {
            0
        } else if arch.weight_bit_sparsity {
            demand * rows as u64
        } else {
            dense_effective_cells(t, a, prep)
        };

        let mut worst = 0u64;
        // Accumulate per-chunk event totals locally; fold into `events`
        // once (hot-path: avoids 6 counter writes per row-step).
        let mut tot_cycles = 0u64;
        let mut tot_eff = 0u64;
        for mi in 0..m_count {
            let m = m_base + mi;
            let mut row_cycles = 0u64;
            if arch.input_skipping {
                // IPU: the precomputed occupancy byte per (row, step)
                // is the OR of the step's 16 gathered inputs.
                for (s, &eff) in step_eff.iter().enumerate() {
                    let occ = match base_step {
                        Some(b) => table.step_occ(m, b + s),
                        None => {
                            // unaligned tile (never emitted by the
                            // compiler): fold straight off the input
                            let lanes = (rows - s * comp).min(comp);
                            let group = &kept[s * comp..s * comp + lanes];
                            let xrow =
                                super::occupancy::i8_as_u8(x.expect("input required").row(m));
                            group.iter().fold(0u8, |o, &k| o | xrow[k as usize])
                        }
                    };
                    let beff = u64::from(occ.count_ones());
                    row_cycles += beff;
                    tot_eff += eff * beff;
                }
            } else {
                // timing is data-independent: full bit-serial cost
                let bits = arch.input_bits as u64;
                row_cycles = steps as u64 * bits;
                tot_eff += row_eff * bits;
            }
            tot_cycles += row_cycles;
            worst = worst.max(row_cycles);

            // functional accumulate (fast dot-product path; the DBMU
            // bit-level path in dbmu.rs is cross-checked in tests)
            if let Some(acc) = acc.as_deref_mut() {
                let w = acc.filters.len();
                let gathered = &table.gathered_row(m)[t.row_start..t.row_end];
                let (col_of, acc_row) = (&acc.col_of, &mut acc.data[m * w..(m + 1) * w]);
                for (ri, &k) in kept.iter().enumerate() {
                    let xv = gathered[ri] as i8 as i32;
                    if xv == 0 {
                        continue;
                    }
                    let wrow = prep.weights.row(k as usize);
                    for &f in &a.filters {
                        acc_row[col_of[f]] += xv * wrow[f] as i32;
                    }
                }
            }
        }
        let mc = m_count as u64;
        events.macro_cycles += tot_cycles;
        events.macro_col_cycles += tot_cycles * arch.macro_columns as u64;
        events.active_col_cycles += tot_eff;
        events.input_buf_reads += steps as u64 * mc;
        if arch.input_skipping {
            events.ipu_detects += steps as u64 * mc;
        }
        if arch.weight_bit_sparsity {
            events.meta_rf_reads += steps as u64 * mc;
        }
        if arch.value_sparsity {
            events.alloc_switches += rows as u64 * mc;
        }
        events.macs += rows as u64 * a.filters.len() as u64 * mc;
        worst
    }
}

/// Effective (non-zero-bit) cells for a whole dense tile, summed over
/// row-steps — the U_act numerator per bit-cycle.
fn dense_effective_cells(t: &Tile, a: &Assignment, prep: &PreparedLayer) -> u64 {
    let mut cells = 0u64;
    for &k in &a.kept_rows[t.row_start..t.row_end] {
        for &f in &a.filters {
            cells += (prep.weights.get(k as usize, f) as u8).count_ones() as u64;
        }
    }
    cells
}

/// Same, restricted to the lanes of one row-step.
fn dense_step_effective_cells(
    t: &Tile,
    a: &Assignment,
    prep: &PreparedLayer,
    comp: usize,
    step: usize,
    lanes: usize,
) -> u64 {
    let base = t.row_start + step * comp;
    let mut cells = 0u64;
    for &k in &a.kept_rows[base..base + lanes] {
        for &f in &a.filters {
            cells += (prep.weights.get(k as usize, f) as u8).count_ones() as u64;
        }
    }
    cells
}
