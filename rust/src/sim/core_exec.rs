//! Per-core segment executor.
//!
//! A [`CoreExecutor`] owns everything one PIM core touches while
//! executing a barrier-free instruction segment: its clock, its event
//! counters, its slice of the functional accumulators ([`CoreAcc`] —
//! one dense block per assignment scheduled on the core, filter columns
//! disjoint across cores by construction of the packing), a cached
//! [`OccupancyTable`] for the assignment currently resident, and a
//! cached [`TileScan`] for the tile currently being walked. Because no
//! shared state is mutated between barriers, segments of one phase can
//! execute on worker threads and merge deterministically (sim::engine).
//!
//! The hot loops are reached through the layer's selected
//! [`KernelBackend`] (`Program::kernel`, resolved once at
//! construction): IPU timing is a step-major batched occupancy scan
//! computed once per tile (Compute chunks then read back per-row cycle
//! counts), and the functional accumulate is a dense i8×i8 micro-GEMM
//! over the assignment's compile-time gathered weight block. Every
//! backend is bit-identical to the `ScalarRef` oracle
//! (sim::backend docs), and the timing/event semantics remain an exact
//! port of the original single-thread interpreter loop (machine.rs
//! pre-refactor, DESIGN.md §6): every engine built on this executor is
//! bit-identical to it.

use crate::arch::ArchConfig;
use crate::compiler::{Assignment, CompiledLayer, Tile};
use crate::energy::EventCounts;
use crate::isa::Instr;
use crate::tensor::{MatI8, MatI32};
use crate::util::ceil_div;

use super::arena;
use super::backend::{self, KernelBackend};
use super::kernels::TileScan;
use super::occupancy::OccupancyTable;

/// Dense functional accumulator block of one assignment:
/// `data[m * filters.len() + fi]` accumulates input row m against the
/// assignment's fi-th filter — the contiguous GEMM target of
/// [`KernelBackend::gemm_accumulate`].
#[derive(Debug, Clone)]
pub struct AccBlock {
    /// Assignment index in the layer (executor lookup key).
    pub assignment: usize,
    /// Global filter columns, in the assignment's slot order.
    pub filters: Vec<usize>,
    /// m_total × filters.len() accumulators, m-major.
    pub data: Vec<i32>,
}

/// Functional accumulator slice owned by one core: one dense block per
/// assignment scheduled on the core. Every filter is packed into
/// exactly one assignment (compiler invariant), so blocks — and cores —
/// cover disjoint output columns and merge exactly in any order.
#[derive(Debug, Clone)]
pub struct CoreAcc {
    blocks: Vec<AccBlock>,
    /// assignment index → position in `blocks` (`u32::MAX` = not on
    /// this core), precomputed at construction so `block_mut` is one
    /// indexed load per Compute chunk instead of a linear scan.
    block_index: Vec<u32>,
    m_total: usize,
}

impl CoreAcc {
    pub fn new(layer: &CompiledLayer, core: usize, m_total: usize) -> Self {
        let mut block_index = vec![u32::MAX; layer.assignments.len()];
        let mut blocks = Vec::new();
        for (ai, a) in layer.assignments.iter().enumerate() {
            if a.core != core {
                continue;
            }
            block_index[ai] = blocks.len() as u32;
            blocks.push(AccBlock {
                assignment: ai,
                filters: a.filters.clone(),
                // block storage recycles through the thread arena
                // (returned by `recycle` after the engine's merge)
                data: arena::take_i32(m_total * a.filters.len()),
            });
        }
        Self { blocks, block_index, m_total }
    }

    /// The dense blocks owned by this core (ascending assignment index).
    pub fn blocks(&self) -> &[AccBlock] {
        &self.blocks
    }

    /// The dense block of `assignment` (must be scheduled on this core).
    fn block_mut(&mut self, assignment: usize) -> &mut AccBlock {
        let i = self.block_index[assignment];
        assert!(i != u32::MAX, "assignment not owned by this core");
        &mut self.blocks[i as usize]
    }

    /// Fold this core's blocks into the shared [M, N] accumulator.
    /// Filter columns are disjoint across blocks and across cores, so
    /// the merge order cannot change the result.
    pub fn merge_into(&self, acc: &mut MatI32) {
        for b in &self.blocks {
            let w = b.filters.len();
            for m in 0..self.m_total {
                let row = &b.data[m * w..(m + 1) * w];
                let acc_row = &mut acc.data[m * acc.cols..(m + 1) * acc.cols];
                for (i, &f) in b.filters.iter().enumerate() {
                    acc_row[f] += row[i];
                }
            }
        }
    }

    /// Return the block storage to the thread arena (called by the
    /// engines after the merge; optional — dropping is also correct).
    pub fn recycle(self) {
        for b in self.blocks {
            arena::give_i32(b.data);
        }
    }
}

/// Execution state of one PIM core over one layer.
#[derive(Debug)]
pub struct CoreExecutor<'a> {
    arch: &'a ArchConfig,
    layer: &'a CompiledLayer,
    x: Option<&'a MatI8>,
    pub core: usize,
    m_total: usize,
    /// Clock advance accumulated by this executor (cycles).
    pub clock: u64,
    pub events: EventCounts,
    /// Functional accumulators (None in perf-only mode).
    pub acc: Option<CoreAcc>,
    /// Cached gather/occupancy table for the resident assignment.
    table: Option<OccupancyTable>,
    /// Cached step-major occupancy scan for the tile being walked.
    scan: Option<TileScan>,
    /// Kernel routines for this layer (`Program::kernel`), resolved to
    /// a backend once per executor.
    backend: &'static dyn KernelBackend,
}

impl<'a> CoreExecutor<'a> {
    pub fn new(
        arch: &'a ArchConfig,
        layer: &'a CompiledLayer,
        x: Option<&'a MatI8>,
        core: usize,
        functional: bool,
        m_total: usize,
    ) -> Self {
        let acc = functional.then(|| CoreAcc::new(layer, core, m_total));
        Self {
            arch,
            layer,
            x,
            core,
            m_total,
            clock: 0,
            events: EventCounts::default(),
            acc,
            table: None,
            scan: None,
            backend: backend::backend_for(layer.program.kernel),
        }
    }

    /// Execute one per-core instruction. Barriers are handled by the
    /// scheduler and must never reach a segment executor.
    pub fn exec(&mut self, instr: &Instr) {
        self.events.instrs += 1;
        let arch = self.arch;
        let layer = self.layer;
        match *instr {
            Instr::LoadTile { tile, .. } => {
                let t = &layer.tiles[tile as usize];
                let a = &layer.assignments[t.assignment];
                // every cell of the tile written once, in all Tm
                // macro replicas
                let cells = t.rows() * a.active_cols() * arch.macros_per_core;
                self.events.weight_writes += cells as u64;
                self.clock += arch.tile_load_cycles;
                // mask RF consulted once per tile to build the
                // gather list (value sparsity only)
                if arch.value_sparsity {
                    self.events.mask_rf_reads += t.rows() as u64;
                }
                if let Some(lf) = layer.faults.as_ref() {
                    // ABFT verification of the freshly loaded block:
                    // nf × NUM_BLOCKS checksum words re-derived and
                    // compared, one macro-column batch per cycle
                    // (DESIGN.md §13). Charged per LoadTile — a pure
                    // function of the instruction, so bit-identical
                    // under every engine and worker count.
                    let words = (a.filters.len() * crate::csd::NUM_BLOCKS) as u64;
                    self.events.abft_checks += words;
                    self.clock += ceil_div(words as usize, arch.macro_columns) as u64;
                    if let Some(af) = lf.by_assignment[t.assignment].as_ref() {
                        for r in &af.replicas {
                            self.events.fault_detections += r.detections;
                            if lf.policy == crate::arch::DegradePolicy::Recompute {
                                // scalar-oracle recompute of the
                                // flagged filters over this tile's rows
                                self.clock += r.detected_filters * t.rows() as u64;
                            }
                        }
                    }
                }
            }
            Instr::Compute { tile, m_base, m_count, .. } => {
                let cycles = self.compute_chunk(tile as usize, m_base as usize, m_count as usize);
                self.clock += cycles;
            }
            Instr::Store { tile, m_count, .. } => {
                let t = &layer.tiles[tile as usize];
                let a = &layer.assignments[t.assignment];
                let words = m_count as u64 * a.filters.len() as u64;
                self.events.output_buf_writes += words;
                if t.row_start > 0 {
                    // partial-sum reload for non-first K tiles
                    self.events.output_buf_reads += words;
                }
                // store drains through the PPU: 1 cycle per Tm-batch
                self.clock += ceil_div(words as usize, arch.macros_per_core) as u64;
            }
            Instr::Simd { .. } | Instr::Sync | Instr::EndLayer => {
                unreachable!("barrier instruction inside a segment: {instr:?}")
            }
        }
    }

    /// (Re)build the gather/occupancy table when the resident
    /// assignment changes. Tiles of one assignment are contiguous in
    /// every core's stream, so a single-slot cache never thrashes. The
    /// table object (and its buffers) recycles through the thread
    /// arena: taken on first use, rebuilt in place per assignment,
    /// given back when the executor drops.
    fn ensure_table(&mut self, assignment: usize) {
        if self.table.as_ref().map(|t| t.assignment) == Some(assignment) {
            return;
        }
        let x = self.x.expect("input required");
        let a = &self.layer.assignments[assignment];
        let mut table = self.table.take().unwrap_or_else(arena::take_table);
        let caps = table.buf_capacities();
        table.build_into(
            assignment,
            x,
            &a.kept_rows,
            self.arch.compartments,
            self.m_total,
            self.arch.input_skipping,
            // perf-only IPU runs read nothing but the occ bytes
            self.acc.is_some(),
        );
        if table.buf_capacities() != caps {
            // the recycled table reallocated: report it so the
            // zero-miss steady-state assertions can't be fooled
            arena::note_growth();
        }
        self.table = Some(table);
    }

    /// (Re)run the step-major occupancy scan when the walked tile
    /// changes. A tile's Compute chunks are contiguous and ascend from
    /// `m_base = 0` (codegen invariant), so a single-slot cache never
    /// thrashes and the whole-tile scan is computed exactly once. The
    /// scan object and both scratch vectors (per-step eff weights,
    /// SWAR lane accumulators) recycle through the thread arena, so
    /// the per-tile walk is allocation-free after warm-up.
    fn ensure_scan(&mut self, tile_idx: usize) {
        let arch = self.arch;
        let layer = self.layer;
        let t = &layer.tiles[tile_idx];
        if self.scan.as_ref().map(|s| s.tile) == Some(t.id) {
            return;
        }
        let a = &layer.assignments[t.assignment];
        let comp = arch.compartments;
        // The compiler only emits step-aligned tiles (k_slots is a
        // multiple of the compartment count); the on-the-fly gather
        // fallback this used to guard is unreachable.
        debug_assert_eq!(t.row_start % comp, 0, "compiler emitted a step-unaligned tile");
        let base_step = t.row_start / comp;
        let rows = t.rows();
        let steps = ceil_div(rows, comp);
        let demand = a.active_cols() as u64;
        // Per-step effective cells are row-independent; computed once
        // per tile (the scan folds them into the eff-weighted total).
        let mut step_eff = arena::take_u64(steps);
        for (s, eff) in step_eff.iter_mut().enumerate() {
            let lanes = (rows - s * comp).min(comp);
            *eff = if arch.weight_bit_sparsity {
                demand * lanes as u64
            } else {
                dense_step_effective_cells(t, a, comp, s, lanes)
            };
        }
        let table = self.table.as_ref().expect("occupancy table built before scan");
        debug_assert!(table.has_occ());
        let mut scan = self.scan.take().unwrap_or_else(arena::take_scan);
        // request the lane scratch at its real size (m_total/8 words)
        // so growth shows up as an arena miss instead of hiding inside
        // the kernel's resize
        let mut lanes_buf = arena::take_u64(table.m_rows() / 8);
        let cap = scan.row_cycles.capacity();
        self.backend.scan_tile_occupancy_into(
            &mut scan,
            table,
            t.id,
            base_step,
            &step_eff,
            &mut lanes_buf,
        );
        if scan.row_cycles.capacity() != cap {
            arena::note_growth();
        }
        arena::give_u64(lanes_buf);
        arena::give_u64(step_eff);
        self.scan = Some(scan);
    }

    /// Process one Compute chunk (≤ Tm input rows on this core).
    /// Returns the core-clock advance (max over the chunk's rows).
    fn compute_chunk(&mut self, tile_idx: usize, m_base: usize, m_count: usize) -> u64 {
        let arch = self.arch;
        let layer = self.layer;
        let t = &layer.tiles[tile_idx];
        let a = &layer.assignments[t.assignment];
        let comp = arch.compartments;
        let rows = t.rows();
        let steps = ceil_div(rows, comp);
        let demand = a.active_cols() as u64;
        let functional = self.acc.is_some();

        // Fast analytic path: timing is data-independent without IPU
        // skipping, so one row's cost is every row's cost.
        if !arch.input_skipping && !functional {
            let bits = arch.input_bits as u64;
            let cycles_per_row = steps as u64 * bits;
            let full_steps = rows / comp;
            let tail = rows % comp;
            // effective cells per bit-cycle (U_act numerator)
            let eff_cells: u64 = if arch.weight_bit_sparsity {
                (full_steps as u64 * comp as u64 + tail as u64) * demand
            } else {
                // dense: effective = non-zero weight bits actually stored
                dense_effective_cells(t, a)
            };
            let mc = m_count as u64;
            self.events.macro_cycles += cycles_per_row * mc;
            self.events.macro_col_cycles += cycles_per_row * mc * arch.macro_columns as u64;
            self.events.active_col_cycles += eff_cells * bits * mc;
            self.events.input_buf_reads += steps as u64 * mc;
            if arch.value_sparsity {
                self.events.alloc_switches += rows as u64 * mc;
            }
            if arch.weight_bit_sparsity {
                self.events.meta_rf_reads += steps as u64 * mc;
            }
            self.events.macs += rows as u64 * a.filters.len() as u64 * mc;
            return cycles_per_row;
        }

        // Row-loop path. IPU timing reads back the tile's cached
        // step-major occupancy scan (sim::kernels); the per-assignment
        // table + per-tile scan replace the per-(tile, row, step)
        // gather + byte-wise OR fold.
        self.ensure_table(t.assignment);
        if arch.input_skipping {
            self.ensure_scan(tile_idx);
        }
        let backend = self.backend;
        let Self { table, scan, acc, events, .. } = self;

        let mut worst = 0u64;
        let mut tot_cycles = 0u64;
        let mut tot_eff = 0u64;
        if arch.input_skipping {
            let scan = scan.as_ref().expect("scan built for IPU timing");
            for &rc in &scan.row_cycles[m_base..m_base + m_count] {
                tot_cycles += rc;
                worst = worst.max(rc);
            }
            // the scan's eff-weighted total covers the whole tile; the
            // chunks of a tile partition [0, M) exactly once, so it is
            // accounted on the first chunk (bit-identical layer totals)
            if m_base == 0 && m_count > 0 {
                tot_eff = scan.eff_total;
            }
        } else if m_count > 0 {
            // timing is data-independent: full bit-serial cost per row
            let bits = arch.input_bits as u64;
            let row_cycles = steps as u64 * bits;
            let row_eff: u64 = if arch.weight_bit_sparsity {
                demand * rows as u64
            } else {
                dense_effective_cells(t, a)
            };
            worst = row_cycles;
            tot_cycles = row_cycles * m_count as u64;
            tot_eff = row_eff * bits * m_count as u64;
        }

        // functional accumulate: dense micro-GEMM of the gathered
        // activations against the assignment's gathered weight block
        // (the DBMU bit-level path in dbmu.rs is cross-checked in tests)
        if let Some(acc) = acc.as_mut() {
            let table = table.as_ref().expect("table built");
            let block = acc.block_mut(t.assignment);
            let nf = block.filters.len();
            debug_assert_eq!(a.wblock.len(), a.kept_rows.len() * nf);
            let faulty = layer.faults.is_some();
            let mut wtile = &a.wblock[t.row_start * nf..t.row_end * nf];
            for mi in 0..m_count {
                let m = m_base + mi;
                if faulty {
                    // replica macro `mi` serves row m (m ≡ mi mod Tm;
                    // codegen's Compute chunks are Tm-aligned), so it
                    // reads that replica's effective resident block
                    wtile = &layer.effective_wblock(t.assignment, mi)
                        [t.row_start * nf..t.row_end * nf];
                }
                let gathered = &table.gathered_row(m)[t.row_start..t.row_end];
                backend.gemm_accumulate(&mut block.data[m * nf..(m + 1) * nf], gathered, wtile);
            }
        }

        let mc = m_count as u64;
        events.macro_cycles += tot_cycles;
        events.macro_col_cycles += tot_cycles * arch.macro_columns as u64;
        events.active_col_cycles += tot_eff;
        events.input_buf_reads += steps as u64 * mc;
        if arch.input_skipping {
            events.ipu_detects += steps as u64 * mc;
        }
        if arch.weight_bit_sparsity {
            events.meta_rf_reads += steps as u64 * mc;
        }
        if arch.value_sparsity {
            events.alloc_switches += rows as u64 * mc;
        }
        events.macs += rows as u64 * a.filters.len() as u64 * mc;
        worst
    }
}

/// Release the executor's cached table/scan back to the thread arena.
/// (`acc` is moved out by the engines before the drop and recycled
/// after their merge.)
impl Drop for CoreExecutor<'_> {
    fn drop(&mut self) {
        if let Some(table) = self.table.take() {
            arena::give_table(table);
        }
        if let Some(scan) = self.scan.take() {
            arena::give_scan(scan);
        }
    }
}

/// Effective (non-zero-bit) cells for a whole dense tile, summed over
/// row-steps — the U_act numerator per bit-cycle. O(1): a subtraction
/// of the assignment's compile-time bit-cell prefix sums
/// ([`Assignment::bit_cell_prefix`]) instead of the O(rows × filters)
/// popcount walk this used to perform per tile at sim time.
fn dense_effective_cells(t: &Tile, a: &Assignment) -> u64 {
    a.bit_cell_prefix[t.row_end] - a.bit_cell_prefix[t.row_start]
}

/// Same, restricted to the lanes of one row-step — also one prefix
/// subtraction.
fn dense_step_effective_cells(t: &Tile, a: &Assignment, comp: usize, step: usize, lanes: usize) -> u64 {
    let base = t.row_start + step * comp;
    a.bit_cell_prefix[base + lanes] - a.bit_cell_prefix[base]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_layer, prepare_layer, SparsityConfig};
    use crate::models::synthesize_weights;
    use crate::quant;

    fn compiled(arch: &ArchConfig, seed: u64) -> CompiledLayer {
        let (m, k, n) = (6, 320, 48);
        let w = synthesize_weights(seed, k, n);
        let prep = prepare_layer(
            "t", m, k, n, w,
            SparsityConfig::hybrid(0.5),
            arch,
            quant::requant_mul(0.01),
            true,
            None,
        );
        compile_layer(prep, arch)
    }

    #[test]
    fn core_acc_blocks_cover_disjoint_filter_columns() {
        let arch = ArchConfig::db_pim();
        let layer = compiled(&arch, 41);
        let m_total = layer.prep.m;
        let mut seen = vec![false; layer.prep.n];
        for core in 0..arch.n_cores {
            let acc = CoreAcc::new(&layer, core, m_total);
            for b in acc.blocks() {
                assert_eq!(layer.assignments[b.assignment].core, core);
                assert_eq!(b.data.len(), m_total * b.filters.len());
                for &f in &b.filters {
                    assert!(!seen[f], "filter {f} owned by two blocks");
                    seen[f] = true;
                }
            }
        }
    }

    #[test]
    fn merge_into_is_order_independent_and_exact() {
        let arch = ArchConfig::db_pim();
        let layer = compiled(&arch, 42);
        let m_total = layer.prep.m;
        // fill each block with a value derived from its assignment so
        // the merged matrix is predictable
        let mut accs: Vec<CoreAcc> = (0..arch.n_cores)
            .map(|c| CoreAcc::new(&layer, c, m_total))
            .collect();
        for acc in &mut accs {
            for b in &mut acc.blocks {
                let ai = b.assignment as i32;
                for v in &mut b.data {
                    *v = ai + 1;
                }
            }
        }
        let mut fwd = MatI32::zeros(m_total, layer.prep.n);
        for acc in &accs {
            acc.merge_into(&mut fwd);
        }
        let mut rev = MatI32::zeros(m_total, layer.prep.n);
        for acc in accs.iter().rev() {
            acc.merge_into(&mut rev);
        }
        assert_eq!(fwd, rev, "merge must be order independent");
        // every assigned filter column got exactly its block's value
        for (ai, a) in layer.assignments.iter().enumerate() {
            for &f in &a.filters {
                for m in 0..m_total {
                    assert_eq!(fwd.get(m, f), ai as i32 + 1, "m {m} filter {f}");
                }
            }
        }
    }

    #[test]
    fn dense_effective_cells_match_direct_popcount_walk() {
        // the O(1) prefix subtractions must equal the original
        // O(rows × filters) popcount walk, per tile and per step
        for arch in [ArchConfig::dense_baseline(), ArchConfig::db_pim()] {
            let layer = compiled(&arch, 44);
            let prep = &layer.prep;
            let comp = arch.compartments;
            for t in &layer.tiles {
                let a = &layer.assignments[t.assignment];
                let mut want = 0u64;
                for &k in &a.kept_rows[t.row_start..t.row_end] {
                    for &f in &a.filters {
                        want += u64::from((prep.weights.get(k as usize, f) as u8).count_ones());
                    }
                }
                assert_eq!(dense_effective_cells(t, a), want, "tile {}", t.id);
                let rows = t.rows();
                let steps = ceil_div(rows, comp);
                let mut sum = 0u64;
                for s in 0..steps {
                    let lanes = (rows - s * comp).min(comp);
                    sum += dense_step_effective_cells(t, a, comp, s, lanes);
                }
                assert_eq!(sum, want, "step sums must partition tile {}", t.id);
            }
        }
    }

    #[test]
    fn merge_into_of_fresh_acc_is_zero() {
        let arch = ArchConfig::db_pim();
        let layer = compiled(&arch, 43);
        let mut acc = MatI32::zeros(layer.prep.m, layer.prep.n);
        for core in 0..arch.n_cores {
            CoreAcc::new(&layer, core, layer.prep.m).merge_into(&mut acc);
        }
        assert!(acc.data.iter().all(|&v| v == 0));
    }
}
