//! Execution trace export — Chrome/Perfetto `trace_event` JSON so a
//! simulated run's per-layer timeline (per core, per category) can be
//! inspected visually. One complete span per layer per busy core, plus
//! a counter track for cumulative energy.
//!
//! Trace encode/decode is a user-facing I/O path — `unwrap`/`expect`
//! are linted out of the non-test code.
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::fmt::Write as _;

use crate::sim::{LayerStats, OpCategory, SimReport};

/// Render a Chrome-tracing JSON document for a simulation report.
/// Timestamps are simulated nanoseconds (cycles × clock period).
pub fn chrome_trace(report: &SimReport) -> String {
    let ns_per_cycle = report.arch.clock_ns();
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut t_cursor = 0.0f64; // layer start (layers run back-to-back)
    let mut energy_pj = 0.0f64;
    let table = crate::energy::EnergyTable::default28nm();
    for layer in &report.layers {
        let dur = layer.elapsed as f64 * ns_per_cycle / 1e3; // µs
        let ts = t_cursor;
        emit_span(&mut out, &mut first, layer, ts, dur);
        energy_pj += layer.events.energy_pj(&table);
        emit_counter(&mut out, &mut first, ts + dur, energy_pj);
        t_cursor += dur;
    }
    out.push_str("]}");
    out
}

fn tid_for(cat: OpCategory) -> u32 {
    match cat {
        OpCategory::PimConvFc => 0,
        OpCategory::DwConv => 1,
        OpCategory::Mul => 2,
        OpCategory::Etc => 3,
    }
}

fn emit_span(out: &mut String, first: &mut bool, layer: &LayerStats, ts: f64, dur: f64) {
    if !*first {
        out.push(',');
    }
    *first = false;
    let cat = match layer.category {
        OpCategory::PimConvFc => "pim",
        OpCategory::DwConv => "dwconv",
        OpCategory::Mul => "mul",
        OpCategory::Etc => "etc",
    };
    let _ = write!(
        out,
        "{{\"name\":{name:?},\"cat\":\"{cat}\",\"ph\":\"X\",\"ts\":{ts:.3},\"dur\":{dur:.3},\"pid\":1,\"tid\":{tid},\"args\":{{\"cycles\":{cycles},\"macs\":{macs}}}}}",
        name = layer.name,
        tid = tid_for(layer.category),
        cycles = layer.elapsed,
        macs = layer.events.macs,
    );
}

fn emit_counter(out: &mut String, first: &mut bool, ts: f64, energy_pj: f64) {
    if !*first {
        out.push(',');
    }
    *first = false;
    let _ = write!(
        out,
        "{{\"name\":\"energy_uj\",\"ph\":\"C\",\"ts\":{ts:.3},\"pid\":1,\"args\":{{\"uJ\":{:.4}}}}}",
        energy_pj / 1e6
    );
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::compiler::SparsityConfig;
    use crate::models;

    fn tiny_report() -> SimReport {
        let net = models::Network {
            name: "t".into(),
            input_hw: 8,
            input_ch: 8,
            layers: vec![
                models::Layer {
                    name: "c".into(),
                    kind: models::LayerKind::Conv {
                        in_ch: 8,
                        out_ch: 16,
                        kernel: 3,
                        stride: 1,
                        pad: 1,
                        in_hw: 8,
                    },
                },
                models::Layer { name: "relu".into(), kind: models::LayerKind::Act { elems: 1024 } },
            ],
        };
        crate::sim::simulate_network(&net, SparsityConfig::hybrid(0.5), &ArchConfig::db_pim(), 1)
    }

    #[test]
    fn trace_is_valid_json_with_all_layers() {
        let r = tiny_report();
        let text = chrome_trace(&r);
        let v = crate::json::parse(&text).expect("trace must parse as JSON");
        let events = v.req("traceEvents").as_arr().unwrap();
        // one span + one counter per layer
        assert_eq!(events.len(), 2 * r.layers.len());
        let span = &events[0];
        assert_eq!(span.req("ph").as_str(), Some("X"));
        assert!(span.req("dur").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn spans_are_contiguous() {
        let r = tiny_report();
        let v = crate::json::parse(&chrome_trace(&r)).unwrap();
        let events = v.req("traceEvents").as_arr().unwrap();
        let spans: Vec<_> = events.iter().filter(|e| e.req("ph").as_str() == Some("X")).collect();
        let mut expect_ts = 0.0;
        for s in spans {
            let ts = s.req("ts").as_f64().unwrap();
            assert!((ts - expect_ts).abs() < 1e-6, "gap at {ts}");
            expect_ts = ts + s.req("dur").as_f64().unwrap();
        }
    }
}
