//! Thread-local scratch arenas for the simulation hot path.
//!
//! Steady-state simulation used to re-allocate its working set for
//! every layer of every sweep cell: the per-assignment
//! [`OccupancyTable`], the per-tile [`TileScan`] and its `step_eff` /
//! SWAR-lane scratch, and the dense `CoreAcc` accumulator blocks. This
//! module recycles all of them through per-thread free lists, so after
//! warm-up the row loop performs zero heap allocations across layers,
//! cells and sweeps (ISSUE 4; pinned by `steady_state_…` below and the
//! `arena_reuse_row_loop` bench assertion).
//!
//! **Ownership.** The arena is a plain `thread_local!`, which makes it
//! *per pool worker* for `coordinator::pool` threads (each worker owns
//! its free lists for its whole lifetime; `pool::worker_loop` retires
//! them on shutdown so private test pools release their memory) and
//! automatically provides the standalone fallback for sequential runs,
//! tests and bench main threads — no pool required. Buffers taken and
//! given on different threads simply migrate between thread arenas;
//! free lists are bounded ([`MAX_POOLED`]) so migration can only cost
//! reuse rate, never unbounded memory. The zero-alloc guarantee is
//! therefore scoped: it holds for same-thread take/give cycles — the
//! sequential engine, and the perf-mode row loop under any engine
//! (tables/scans/scratch live and die inside one `run_segment` on one
//! worker). Functional runs under `Engine::Parallel` recycle `CoreAcc`
//! blocks on the *merging* thread, so those blocks migrate owner-ward
//! and worker takes may keep allocating — bounded churn, accepted
//! (functional mode is the verification path, not the sweep hot path).
//!
//! **Determinism.** Recycling is invisible to results by construction:
//! every `take_*` is followed by a full reset-and-fill
//! (`OccupancyTable::build_into`, `kernels::scan_tile_occupancy_into`,
//! zero-filled `take_u64`/`take_i32`), and `give_*` poisons the
//! executor cache keys (`retire`) as defense in depth, so no byte of a
//! recycled buffer survives into the next use. The bit-identical
//! engine contract (DESIGN.md §8) is unchanged; enforced by
//! `tests/prop_invariants.rs::prop_arena_recycled_executors_bit_identical`.
//!
//! **Stats.** A take served from the free list (with sufficient
//! capacity) counts a *hit*; a take that had to allocate counts a
//! *miss*. [`stats`]/[`reset_stats`] read and clear the current
//! thread's counters — the allocation-freeness assertions are
//! "zero misses after warm-up" on a single-threaded (sequential-
//! engine) run, where the thread arena sees every take.

use std::cell::RefCell;

use super::kernels::TileScan;
use super::occupancy::OccupancyTable;

/// Per-thread hit/miss counters (see module docs for semantics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Takes served from the free list.
    pub hits: u64,
    /// Takes that had to allocate (empty list or insufficient capacity).
    pub misses: u64,
}

/// Free-list bound per kind: large enough for the peak concurrent
/// demand of any real layer (one table/scan per live executor, a few
/// u64 scratches, one i32 block per assignment of a functional phase),
/// small enough that a thread can never retain unbounded buffers.
const MAX_POOLED: usize = 64;

#[derive(Default)]
struct Arena {
    tables: Vec<OccupancyTable>,
    scans: Vec<TileScan>,
    u64s: Vec<Vec<u64>>,
    i32s: Vec<Vec<i32>>,
    i8s: Vec<Vec<i8>>,
    stats: ArenaStats,
}

thread_local! {
    static ARENA: RefCell<Arena> = RefCell::new(Arena::default());
}

/// Pop a buffer whose capacity already covers `len` (hit), else
/// allocate (miss). The result is always zero-filled to `len`.
/// Best-fit (smallest adequate capacity): taking the tightest buffer
/// keeps larger ones available for larger requests, so a repeated
/// request multiset (the steady-state sweep pattern) is served with
/// zero misses regardless of arrival order.
fn take_vec<T: Clone + Default>(
    pool: &mut Vec<Vec<T>>,
    stats: &mut ArenaStats,
    len: usize,
) -> Vec<T> {
    let mut best: Option<(usize, usize)> = None;
    for (i, b) in pool.iter().enumerate() {
        let cap = b.capacity();
        let tighter = match best {
            None => true,
            Some((_, c)) => cap < c,
        };
        if cap >= len && tighter {
            best = Some((i, cap));
        }
    }
    if let Some((i, _)) = best {
        stats.hits += 1;
        let mut v = pool.swap_remove(i);
        v.clear();
        v.resize(len, T::default());
        v
    } else {
        stats.misses += 1;
        vec![T::default(); len]
    }
}

fn give_vec<T>(pool: &mut Vec<Vec<T>>, v: Vec<T>) {
    if pool.len() < MAX_POOLED {
        pool.push(v);
    }
}

/// Take a recycled [`OccupancyTable`] (or a fresh empty one). The
/// caller must `build_into` it before reading anything.
pub fn take_table() -> OccupancyTable {
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        match a.tables.pop() {
            Some(t) => {
                a.stats.hits += 1;
                t
            }
            None => {
                a.stats.misses += 1;
                OccupancyTable::empty()
            }
        }
    })
}

/// Return a table to the current thread's free list.
pub fn give_table(mut t: OccupancyTable) {
    t.retire();
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        if a.tables.len() < MAX_POOLED {
            a.tables.push(t);
        }
    });
}

/// Take a recycled [`TileScan`] (or a fresh empty one). The caller
/// must rebuild it (`scan_tile_occupancy_into`) before reading it.
pub fn take_scan() -> TileScan {
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        match a.scans.pop() {
            Some(s) => {
                a.stats.hits += 1;
                s
            }
            None => {
                a.stats.misses += 1;
                TileScan::empty()
            }
        }
    })
}

/// Return a scan to the current thread's free list.
pub fn give_scan(mut s: TileScan) {
    s.retire();
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        if a.scans.len() < MAX_POOLED {
            a.scans.push(s);
        }
    });
}

/// Take a zero-filled `Vec<u64>` of `len` (step_eff / SWAR-lane
/// scratch).
pub fn take_u64(len: usize) -> Vec<u64> {
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        take_vec(&mut a.u64s, &mut a.stats, len)
    })
}

/// Return a u64 buffer to the current thread's free list.
pub fn give_u64(v: Vec<u64>) {
    ARENA.with(|a| give_vec(&mut a.borrow_mut().u64s, v));
}

/// Take a zero-filled `Vec<i32>` of `len` (CoreAcc block storage).
pub fn take_i32(len: usize) -> Vec<i32> {
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        take_vec(&mut a.i32s, &mut a.stats, len)
    })
}

/// Return an i32 buffer to the current thread's free list.
pub fn give_i32(v: Vec<i32>) {
    ARENA.with(|a| give_vec(&mut a.borrow_mut().i32s, v));
}

/// Take a zero-filled `Vec<i8>` of `len` (requant/ReLU output buffers).
pub fn take_i8(len: usize) -> Vec<i8> {
    ARENA.with(|a| {
        let a = &mut *a.borrow_mut();
        take_vec(&mut a.i8s, &mut a.stats, len)
    })
}

/// Return an i8 buffer to the current thread's free list.
pub fn give_i8(v: Vec<i8>) {
    ARENA.with(|a| give_vec(&mut a.borrow_mut().i8s, v));
}

/// Record that a recycled object had to *grow* its internal buffers
/// after a pooled take (tables/scans are popped without a capacity
/// check — the needed sizes are only known at build time). Counted as
/// a miss: the take did not avoid an allocation, and the zero-miss
/// assertions must see it.
pub fn note_growth() {
    ARENA.with(|a| a.borrow_mut().stats.misses += 1);
}

/// Snapshot of the current thread's hit/miss counters.
pub fn stats() -> ArenaStats {
    ARENA.with(|a| a.borrow().stats)
}

/// Clear the current thread's hit/miss counters (the free lists stay —
/// that is the point: measure steady-state reuse after warm-up).
pub fn reset_stats() {
    ARENA.with(|a| a.borrow_mut().stats = ArenaStats::default());
}

/// Drop the current thread's free lists and counters. Called by pool
/// workers on shutdown so private test pools release their retained
/// buffers with their threads.
pub fn retire_thread() {
    ARENA.with(|a| *a.borrow_mut() = Arena::default());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::compiler::{compile_layer, prepare_layer, SparsityConfig};
    use crate::models::{synthesize_activations, synthesize_weights};
    use crate::quant;
    use crate::sim::{Engine, Machine};
    use crate::tensor::MatI8;

    #[test]
    fn take_give_roundtrip_reuses_capacity() {
        retire_thread();
        let v = take_u64(16);
        assert_eq!(v, vec![0u64; 16]);
        assert_eq!(stats(), ArenaStats { hits: 0, misses: 1 });
        give_u64(v);
        let v2 = take_u64(10);
        assert_eq!(v2.len(), 10);
        assert!(v2.capacity() >= 16, "recycled capacity lost");
        assert_eq!(stats(), ArenaStats { hits: 1, misses: 1 });
        // a bigger request than any pooled capacity is a miss
        give_u64(v2);
        let v3 = take_u64(1000);
        assert_eq!(v3, vec![0u64; 1000]);
        assert_eq!(stats().misses, 2);
        retire_thread();
    }

    #[test]
    fn recycled_buffers_come_back_zeroed() {
        retire_thread();
        let mut v = take_i32(8);
        v.iter_mut().for_each(|x| *x = -7);
        give_i32(v);
        assert_eq!(take_i32(8), vec![0i32; 8]);
        let mut v = take_i8(8);
        v.iter_mut().for_each(|x| *x = -7);
        give_i8(v);
        assert_eq!(take_i8(8), vec![0i8; 8]);
        retire_thread();
    }

    #[test]
    fn recycled_table_and_scan_are_poisoned() {
        let x = MatI8::from_vec(2, 8, vec![1i8; 16]);
        let t = OccupancyTable::build(3, &x, &[0, 2], 16, 2, true, true);
        give_table(t);
        let t = take_table();
        assert_eq!(t.assignment, usize::MAX, "stale assignment key survived recycling");
        give_table(t);
        let mut s = TileScan::empty();
        s.tile = 5;
        give_scan(s);
        assert_eq!(take_scan().tile, u32::MAX, "stale tile key survived recycling");
    }

    #[test]
    fn free_lists_are_bounded() {
        retire_thread();
        for _ in 0..(MAX_POOLED + 10) {
            give_u64(Vec::new());
        }
        ARENA.with(|a| assert_eq!(a.borrow().u64s.len(), MAX_POOLED));
        retire_thread();
    }

    /// ISSUE 4 acceptance: after the first (warm-up) layer of a
    /// repeated-cell run, the row loop takes every scratch buffer from
    /// the arena — zero misses — while staying bit-identical.
    #[test]
    fn steady_state_repeated_cell_run_has_zero_arena_misses() {
        let arch = ArchConfig::db_pim();
        let (m, k, n) = (12, 320, 48);
        let w = synthesize_weights(9, k, n);
        let prep = prepare_layer(
            "arena",
            m,
            k,
            n,
            w,
            SparsityConfig::hybrid(0.5),
            &arch,
            quant::requant_mul(0.01),
            true,
            None,
        );
        let layer = compile_layer(prep, &arch);
        let x = MatI8::from_vec(m, k, synthesize_activations(3, m * k));
        // sequential engine: every executor of every phase runs on this
        // thread, so this thread's arena sees every take/give
        let machine = Machine::with_engine(arch, Engine::Sequential);
        let (want, want_acc) = machine.run_pim_layer(&layer, Some(&x), true);
        reset_stats();
        for _ in 0..3 {
            let (got, got_acc) = machine.run_pim_layer(&layer, Some(&x), true);
            assert_eq!(got.events, want.events);
            assert_eq!(got.core_cycles, want.core_cycles);
            assert_eq!(got_acc, want_acc);
        }
        let s = stats();
        assert_eq!(s.misses, 0, "steady-state row loop still allocating: {s:?}");
        assert!(s.hits > 0, "arena saw no takes at all");
    }
}
