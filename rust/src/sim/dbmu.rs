//! Dyadic Block Multiply Unit (DBMU) micro-model (Fig. 8 ②/③).
//!
//! A DBMU's 6T cell stores one Comp.-pattern block as the cross-coupled
//! pair (Q, Q̄); the LPU computes the two bitwise ANDs `IN & Q` and
//! `IN & Q̄` per input bit, and the CSD-based adder tree recombines the
//! partial products with the block's sign/index metadata:
//!
//! ```text
//! partial(bit b) = (IN_b & Q) << 1 | (IN_b & Q̄)   // = IN_b << odd
//! value contribution = ± partial << (2*index + b)
//! ```
//!
//! This module is the bit-level reference the fast functional path in
//! `machine.rs` is validated against (`row_mac` computes one
//! input×weight product purely through stored blocks + metadata).

use crate::csd::{comp_blocks, CompBlock};

/// Packed image of one macro column-set for a tile: for each stored row
/// and each filter, its Comp blocks (≤ φ_th entries each).
#[derive(Debug, Clone)]
pub struct TileImage {
    /// `blocks[row][filter_slot]` — Comp blocks of that weight.
    pub blocks: Vec<Vec<Vec<CompBlock>>>,
}

impl TileImage {
    /// Build from the weight matrix for the given rows × filters.
    pub fn pack(weights: &crate::tensor::MatI8, rows: &[u32], filters: &[usize]) -> Self {
        let blocks = rows
            .iter()
            .map(|&r| {
                filters
                    .iter()
                    .map(|&f| comp_blocks(weights.get(r as usize, f)))
                    .collect()
            })
            .collect();
        Self { blocks }
    }

    /// Total SRAM cells occupied (one per Comp block).
    pub fn cells(&self) -> usize {
        self.blocks.iter().flatten().map(|b| b.len()).sum()
    }
}

/// Multiply one INT8 input against one stored weight *through the DBMU
/// datapath*: bit-serial input, per-block AND pairs, CSD adder tree.
/// Bit 7 of the two's-complement input carries negative weight.
pub fn dbmu_multiply(input: i8, blocks: &[CompBlock]) -> i32 {
    let in_bits = input as u8;
    let mut acc = 0i64;
    for b in 0..8 {
        let in_b = ((in_bits >> b) & 1) as i64;
        if in_b == 0 {
            continue;
        }
        let bit_sign = if b == 7 { -1i64 } else { 1i64 };
        for blk in blocks {
            // LPU: two ANDs against Q / Q̄ — exactly one is the stored
            // digit position (odd/even within the dyadic block).
            let q = blk.odd as i64; // Q bit
            let qbar = 1 - q;
            let partial = ((in_b & q) << 1) | (in_b & qbar); // IN << odd
            let shifted = partial << (2 * blk.index as i64 + b as i64);
            let signed = if blk.sign { -shifted } else { shifted };
            acc += bit_sign * signed;
        }
    }
    acc as i32
}

/// One full row-step MAC through the DBMU path: 16 compartment inputs
/// against their stored rows, accumulated per filter.
pub fn row_step_mac(
    inputs: &[i8],
    image: &TileImage,
    row_base: usize,
    acc: &mut [i32],
) {
    for (lane, &input) in inputs.iter().enumerate() {
        let row = row_base + lane;
        if row >= image.blocks.len() || input == 0 {
            continue;
        }
        for (slot, blocks) in image.blocks[row].iter().enumerate() {
            acc[slot] += dbmu_multiply(input, blocks);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::MatI8;
    use crate::util::check_cases;

    #[test]
    fn dbmu_multiply_equals_integer_multiply_exhaustive_weights() {
        // all weights × a spread of inputs
        for w in i8::MIN..=i8::MAX {
            let blocks = comp_blocks(w);
            for &i in &[-128i8, -77, -1, 0, 1, 3, 64, 127] {
                assert_eq!(
                    dbmu_multiply(i, &blocks),
                    i as i32 * w as i32,
                    "i={i} w={w}"
                );
            }
        }
    }

    #[test]
    fn dbmu_multiply_random_property() {
        check_cases(64, |rng| {
            let i = rng.int8();
            let w = rng.int8();
            let got = dbmu_multiply(i, &comp_blocks(w));
            if got != i as i32 * w as i32 {
                return Err(format!("{i}*{w}: got {got}"));
            }
            Ok(())
        });
    }

    #[test]
    fn row_step_matches_dot_product() {
        let mut rng = crate::util::Rng::new(8);
        let k = 16;
        let n = 4;
        let w = MatI8::from_vec(k, n, (0..k * n).map(|_| rng.int8()).collect());
        let rows: Vec<u32> = (0..k as u32).collect();
        let filters: Vec<usize> = (0..n).collect();
        let image = TileImage::pack(&w, &rows, &filters);
        let inputs: Vec<i8> = (0..16).map(|_| rng.int8()).collect();
        let mut acc = vec![0i32; n];
        row_step_mac(&inputs, &image, 0, &mut acc);
        for f in 0..n {
            let want: i32 = (0..k).map(|r| inputs[r] as i32 * w.get(r, f) as i32).sum();
            assert_eq!(acc[f], want, "filter {f}");
        }
    }

    #[test]
    fn tile_image_cell_count_is_phi_sum() {
        let mut rng = crate::util::Rng::new(9);
        let w = MatI8::from_vec(8, 3, (0..24).map(|_| rng.int8()).collect());
        let rows: Vec<u32> = (0..8).collect();
        let image = TileImage::pack(&w, &rows, &[0, 1, 2]);
        let phi_sum: usize = w.data.iter().map(|&v| crate::csd::phi(v) as usize).sum();
        assert_eq!(image.cells(), phi_sum);
    }
}
