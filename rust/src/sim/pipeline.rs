//! Functional end-to-end MiniNet execution on the simulated machine.
//!
//! Runs the python-exported model layer by layer through the compiler +
//! cycle-accurate machine with `functional = true`, applying the exact
//! integer post-ops (requant → ReLU → pool) of the golden graph. The
//! resulting logits must equal `mininet_golden.bin` bit-for-bit — and,
//! through the PJRT runtime, the output of executing the golden HLO.

use anyhow::Context;

use crate::arch::ArchConfig;
use crate::compiler::{self, SparsityConfig};
use crate::energy::EventCounts;
use crate::isa::SimdOp;
use crate::models::MiniNet;
use crate::sim::machine::{LayerStats, Machine};
use crate::sim::{arena, backend, simd};
use crate::tensor::{self, TensorI8};

/// Result of a functional MiniNet run.
#[derive(Debug, Clone)]
pub struct MiniNetRun {
    /// Raw INT32 logits, [batch, num_classes] row-major.
    pub logits: Vec<i32>,
    pub layers: Vec<LayerStats>,
    pub totals: EventCounts,
    pub arch: ArchConfig,
}

impl MiniNetRun {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.elapsed).sum()
    }

    pub fn time_us(&self) -> f64 {
        self.total_cycles() as f64 * self.arch.clock_ns() / 1e3
    }

    pub fn energy_uj(&self) -> f64 {
        self.totals.energy_pj(&crate::energy::EnergyTable::default28nm()) / 1e6
    }

    /// Bit-exact comparison against the loaded golden logits.
    pub fn matches_golden(&self, net: &MiniNet) -> bool {
        self.logits == net.golden
    }
}

/// Execute MiniNet functionally on `arch`.
pub fn run_mininet(net: &MiniNet, arch: &ArchConfig) -> crate::Result<MiniNetRun> {
    let machine = Machine::new(arch.clone());
    let mut layers = Vec::new();
    let mut totals = EventCounts::default();
    let mut x = TensorI8::from_vec(
        net.batch,
        net.input_ch,
        net.input_hw,
        net.input_hw,
        net.input.clone(),
    );
    let mut logits: Option<Vec<i32>> = None;

    for (li, l) in net.layers.iter().enumerate() {
        let is_fc = l.conv.is_none();
        let mut prep = compiler::prepare_from_mininet(l, net.batch, !is_fc);
        if let Some(info) = &l.conv {
            // conv: im2col the current activation
            let (cols, oh, ow) = tensor::im2col(&x, info.geom);
            prep.m = cols.rows;
            let compiled = compiler::compile_layer(prep, arch);
            let (stats, acc) = machine.run_pim_layer(&compiled, Some(&cols), true);
            totals.add(&stats.events);
            layers.push(stats);
            let acc = acc.context("functional run returned no accumulators")?;
            // SIMD: requant + ReLU through the layer's selected kernel
            // backend, into an arena-recycled buffer (no per-layer
            // `Vec<i8>` allocation)
            let mut out = arena::take_i8(acc.data.len());
            backend::backend_for(compiled.program.kernel).requant_relu_into(
                &mut out,
                &acc.data,
                l.requant_mul,
                true,
            );
            let s = machine.run_simd_layer(
                &format!("{}_requant", l.name),
                SimdOp::Requant,
                acc.data.len() as u64,
            );
            totals.add(&s.events);
            layers.push(s);
            let mut t = tensor::cols2im(&out, net.batch, oh, ow, info.out_ch);
            arena::give_i8(out);
            if info.pool {
                let s = machine.run_simd_layer(
                    &format!("{}_pool", l.name),
                    SimdOp::MaxPool,
                    t.len() as u64,
                );
                totals.add(&s.events);
                layers.push(s);
                t = simd::maxpool(&t);
            }
            x = t;
        } else {
            // FC: HWC flatten, raw INT32 logits (no requant — matches
            // the golden graph)
            let flat = x.flatten_hwc();
            assert_eq!(flat.cols, l.k, "fc features mismatch at layer {li}");
            prep.m = flat.rows;
            let compiled = compiler::compile_layer(prep, arch);
            let (stats, acc) = machine.run_pim_layer(&compiled, Some(&flat), true);
            totals.add(&stats.events);
            layers.push(stats);
            let acc = acc.context("functional run returned no accumulators")?;
            let mut out = Vec::with_capacity(net.batch * net.num_classes);
            for b in 0..net.batch {
                for c in 0..net.num_classes {
                    out.push(acc.get(b, c));
                }
            }
            logits = Some(out);
        }
    }

    Ok(MiniNetRun {
        logits: logits.context("manifest has no FC layer")?,
        layers,
        totals,
        arch: arch.clone(),
    })
}

/// Dense-baseline sparsity config used when re-sparsifying is needed
/// (MiniNet weights are already sparsified; this is for documentation
/// symmetry with `simulate_network`).
pub fn mininet_sparsity() -> SparsityConfig {
    SparsityConfig::hybrid(0.6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{load_mininet, mininet::default_artifacts_dir};

    fn net() -> Option<MiniNet> {
        load_mininet(&default_artifacts_dir()).ok()
    }

    #[test]
    fn dbpim_run_matches_golden_bit_exact() {
        let Some(net) = net() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let run = run_mininet(&net, &ArchConfig::db_pim()).unwrap();
        assert_eq!(run.logits, net.golden, "DB-PIM logits diverge from golden HLO");
    }

    #[test]
    fn baseline_run_matches_golden_bit_exact() {
        let Some(net) = net() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let run = run_mininet(&net, &ArchConfig::dense_baseline()).unwrap();
        assert_eq!(run.logits, net.golden, "baseline logits diverge from golden HLO");
    }

    #[test]
    fn all_ablation_archs_agree_functionally() {
        let Some(net) = net() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let archs = [
            ArchConfig::db_pim(),
            ArchConfig::bit_only(),
            ArchConfig::value_only(),
            ArchConfig::weights_only(),
        ];
        let golden = &net.golden;
        for arch in archs {
            let run = run_mininet(&net, &arch).unwrap();
            assert_eq!(&run.logits, golden, "{} functional divergence", run.arch.name);
        }
    }

    #[test]
    fn dbpim_faster_and_cheaper_than_baseline_e2e() {
        let Some(net) = net() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let d = run_mininet(&net, &ArchConfig::db_pim()).unwrap();
        let b = run_mininet(&net, &ArchConfig::dense_baseline()).unwrap();
        let speedup = b.total_cycles() as f64 / d.total_cycles() as f64;
        assert!(speedup > 2.0, "e2e speedup {speedup}");
        assert!(d.energy_uj() < b.energy_uj());
    }
}
