//! The cycle-accurate machine model: top controller executing the
//! compiled instruction streams over the PIM cores, sparse allocation
//! network, IPUs and SIMD core, with full event/energy accounting.
//!
//! Timing model (DESIGN.md §6). One macro bit-cycle = all 16
//! compartments perform their DBMU ANDs + the PPUs reduce one input bit
//! column. Per input row (one im2col row m) and weight tile:
//!
//! ```text
//! steps   = ceil(tile_rows / compartments)
//! cycles  = Σ_steps B_eff(step)        # B_eff = IPU-surviving columns
//! ```
//!
//! The Tm macros of a core hold identical weights and process Tm
//! different m rows concurrently (pipelined); a Compute instruction
//! advances the core clock by the *max* of its rows' cycle counts while
//! energy accrues for every row. Cores run independently; Sync aligns
//! them; layer makespan = max core clock.

use crate::arch::ArchConfig;
use crate::compiler::{Assignment, CompiledLayer, Tile};
use crate::energy::{EnergyTable, EventCounts};
use crate::isa::{Instr, SimdOp};
use crate::tensor::{MatI8, MatI32};

use super::simd;

/// Per-layer simulation result.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    /// Operation category for the Fig. 13 breakdown.
    pub category: OpCategory,
    pub events: EventCounts,
    /// Busy cycles per core.
    pub core_cycles: Vec<u64>,
    /// Layer makespan in cycles.
    pub elapsed: u64,
}

/// Fig. 13 execution-time categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpCategory {
    /// std/pw conv + FC (PIM).
    PimConvFc,
    /// Depthwise conv (SIMD).
    DwConv,
    /// Element-wise multiplies (SIMD).
    Mul,
    /// Everything else: pool, ReLU, residual add (SIMD).
    Etc,
}

/// The machine: an architecture + energy table.
#[derive(Debug, Clone)]
pub struct Machine {
    pub arch: ArchConfig,
    pub energy: EnergyTable,
}

impl Machine {
    pub fn new(arch: ArchConfig) -> Self {
        Self { arch, energy: EnergyTable::default28nm() }
    }

    /// Execute one compiled PIM layer.
    ///
    /// * `x` — the im2col input matrix [M, K]; required in functional
    ///   mode and whenever IPU skipping is on (data-dependent timing).
    /// * `functional` — also compute the exact INT32 accumulators.
    ///
    /// Returns stats and (in functional mode) the [M, N] accumulators.
    pub fn run_pim_layer(
        &self,
        layer: &CompiledLayer,
        x: Option<&MatI8>,
        functional: bool,
    ) -> (LayerStats, Option<MatI32>) {
        let arch = &self.arch;
        let prep = &layer.prep;
        let m_total = prep.m.max(1);
        if functional || arch.input_skipping {
            let x = x.expect("input matrix required for functional/IPU simulation");
            assert_eq!(x.rows, m_total, "input rows != layer M");
            assert_eq!(x.cols, prep.k, "input cols != layer K");
        }

        let mut events = EventCounts::default();
        let mut clocks = vec![0u64; arch.n_cores];
        let mut acc = functional.then(|| MatI32::zeros(m_total, prep.n));
        // per-assignment gathered input row buffer (reused)
        let mut gathered: Vec<i8> = Vec::new();

        for instr in &layer.instrs {
            events.instrs += 1;
            match *instr {
                Instr::LoadTile { core, tile } => {
                    let t = &layer.tiles[tile as usize];
                    let a = &layer.assignments[t.assignment];
                    // every cell of the tile written once, in all Tm
                    // macro replicas
                    let cells = t.rows() * a.active_cols() * arch.macros_per_core;
                    events.weight_writes += cells as u64;
                    clocks[core as usize] += arch.tile_load_cycles;
                    // mask RF consulted once per tile to build the
                    // gather list (value sparsity only)
                    if arch.value_sparsity {
                        events.mask_rf_reads += t.rows() as u64;
                    }
                }
                Instr::Compute { core, tile, m_base, m_count } => {
                    let t = &layer.tiles[tile as usize];
                    let a = &layer.assignments[t.assignment];
                    let chunk_cycles = self.compute_chunk(
                        t,
                        a,
                        prep,
                        x,
                        m_base as usize,
                        m_count as usize,
                        &mut events,
                        acc.as_mut(),
                        &mut gathered,
                    );
                    clocks[core as usize] += chunk_cycles;
                }
                Instr::Store { core, tile, m_count, .. } => {
                    let t = &layer.tiles[tile as usize];
                    let a = &layer.assignments[t.assignment];
                    let words = m_count as u64 * a.filters.len() as u64;
                    events.output_buf_writes += words;
                    if t.row_start > 0 {
                        // partial-sum reload for non-first K tiles
                        events.output_buf_reads += words;
                    }
                    // store drains through the PPU: 1 cycle per Tm-batch
                    clocks[core as usize] +=
                        crate::util::ceil_div(words as usize, arch.macros_per_core) as u64;
                }
                Instr::Simd { op, elems } => {
                    let c = simd::simd_cycles(op, elems as u64, arch);
                    events.simd_lane_ops += simd::lane_ops(op, elems as u64);
                    let max = clocks.iter().copied().max().unwrap_or(0);
                    clocks.iter_mut().for_each(|c2| *c2 = max + c);
                }
                Instr::Sync => {
                    let max = clocks.iter().copied().max().unwrap_or(0);
                    clocks.iter_mut().for_each(|c| *c = max);
                }
                Instr::EndLayer => {}
            }
        }

        let elapsed = clocks.iter().copied().max().unwrap_or(0);
        events.elapsed_cycles = elapsed;
        events.core_cycles = elapsed * arch.n_cores as u64;
        let stats = LayerStats {
            name: prep.name.clone(),
            category: OpCategory::PimConvFc,
            events,
            core_cycles: clocks,
            elapsed,
        };
        (stats, acc)
    }

    /// Process one Compute chunk (≤ Tm input rows on one core).
    /// Returns the core-clock advance (max over the chunk's rows).
    #[allow(clippy::too_many_arguments)]
    fn compute_chunk(
        &self,
        t: &Tile,
        a: &Assignment,
        prep: &crate::compiler::PreparedLayer,
        x: Option<&MatI8>,
        m_base: usize,
        m_count: usize,
        events: &mut EventCounts,
        mut acc: Option<&mut MatI32>,
        gathered: &mut Vec<i8>,
    ) -> u64 {
        let arch = &self.arch;
        let comp = arch.compartments;
        let rows = t.rows();
        let steps = crate::util::ceil_div(rows, comp);
        let demand = a.active_cols() as u64;
        let functional = acc.is_some();

        // Fast analytic path: timing is data-independent without IPU
        // skipping, so one row's cost is every row's cost.
        if !arch.input_skipping && !functional {
            let bits = arch.input_bits as u64;
            let cycles_per_row = steps as u64 * bits;
            let full_steps = rows / comp;
            let tail = rows % comp;
            // effective cells per bit-cycle (U_act numerator)
            let eff_cells: u64 = if arch.weight_bit_sparsity {
                (full_steps as u64 * comp as u64 + tail as u64) * demand / 1
            } else {
                // dense: effective = non-zero weight bits actually stored
                self.dense_effective_cells(t, a, prep)
            };
            let mc = m_count as u64;
            events.macro_cycles += cycles_per_row * mc;
            events.macro_col_cycles += cycles_per_row * mc * arch.macro_columns as u64;
            events.active_col_cycles += eff_cells * bits * mc;
            events.input_buf_reads += steps as u64 * mc;
            if arch.value_sparsity {
                events.alloc_switches += rows as u64 * mc;
            }
            if arch.weight_bit_sparsity {
                events.meta_rf_reads += steps as u64 * mc;
            }
            events.macs += rows as u64 * a.filters.len() as u64 * mc;
            return cycles_per_row;
        }

        let x = x.expect("input required");
        let kept = &a.kept_rows[t.row_start..t.row_end];
        let functional_run = acc.is_some();
        let mut worst = 0u64;
        // Accumulate per-chunk event totals locally; fold into `events`
        // once (hot-path: avoids 6 counter writes per row-step).
        let mut tot_cycles = 0u64;
        let mut tot_eff = 0u64;
        for mi in 0..m_count {
            let m = m_base + mi;
            let xrow = x.row(m);
            let mut row_cycles = 0u64;
            if arch.input_skipping {
                // IPU: OR-reduce each 16-input group straight off the
                // gathered stream; no materialized buffer needed unless
                // we also accumulate functionally.
                if functional_run {
                    gathered.clear();
                    gathered.extend(kept.iter().map(|&k| xrow[k as usize]));
                }
                for s in 0..steps {
                    let lanes = (rows - s * comp).min(comp);
                    let group = &kept[s * comp..s * comp + lanes];
                    let occ = group
                        .iter()
                        .fold(0u8, |o, &k| o | (xrow[k as usize] as u8));
                    let beff = u64::from(occ.count_ones());
                    row_cycles += beff;
                    let eff = if arch.weight_bit_sparsity {
                        demand * lanes as u64
                    } else {
                        self.dense_step_effective_cells(t, a, prep, s, lanes)
                    };
                    tot_eff += eff * beff;
                }
            } else {
                // timing is data-independent: full bit-serial cost
                let bits = arch.input_bits as u64;
                row_cycles = steps as u64 * bits;
                if functional_run {
                    gathered.clear();
                    gathered.extend(kept.iter().map(|&k| xrow[k as usize]));
                }
                let eff = if arch.weight_bit_sparsity {
                    demand * rows as u64
                } else {
                    self.dense_effective_cells(t, a, prep)
                };
                tot_eff += eff * bits;
            }
            tot_cycles += row_cycles;
            worst = worst.max(row_cycles);

            // functional accumulate (fast dot-product path; the DBMU
            // bit-level path in dbmu.rs is cross-checked in tests)
            if let Some(acc) = acc.as_deref_mut() {
                let acc_cols = acc.cols;
                let acc_row = &mut acc.data[m * acc_cols..(m + 1) * acc_cols];
                for (ri, &k) in kept.iter().enumerate() {
                    let xv = gathered[ri] as i32;
                    if xv == 0 {
                        continue;
                    }
                    let wrow = prep.weights.row(k as usize);
                    for &f in &a.filters {
                        acc_row[f] += xv * wrow[f] as i32;
                    }
                }
            }
        }
        let mc = m_count as u64;
        events.macro_cycles += tot_cycles;
        events.macro_col_cycles += tot_cycles * arch.macro_columns as u64;
        events.active_col_cycles += tot_eff;
        events.input_buf_reads += steps as u64 * mc;
        if arch.input_skipping {
            events.ipu_detects += steps as u64 * mc;
        }
        if arch.weight_bit_sparsity {
            events.meta_rf_reads += steps as u64 * mc;
        }
        if arch.value_sparsity {
            events.alloc_switches += rows as u64 * mc;
        }
        events.macs += rows as u64 * a.filters.len() as u64 * mc;
        worst
    }

    /// Effective (non-zero-bit) cells for a whole dense tile, summed
    /// over row-steps — the U_act numerator per bit-cycle.
    fn dense_effective_cells(
        &self,
        t: &Tile,
        a: &Assignment,
        prep: &crate::compiler::PreparedLayer,
    ) -> u64 {
        let mut cells = 0u64;
        for &k in &a.kept_rows[t.row_start..t.row_end] {
            for &f in &a.filters {
                cells += (prep.weights.get(k as usize, f) as u8).count_ones() as u64;
            }
        }
        cells
    }

    /// Same, restricted to the lanes of one row-step.
    fn dense_step_effective_cells(
        &self,
        t: &Tile,
        a: &Assignment,
        prep: &crate::compiler::PreparedLayer,
        step: usize,
        lanes: usize,
    ) -> u64 {
        let comp = self.arch.compartments;
        let base = t.row_start + step * comp;
        let mut cells = 0u64;
        for &k in &a.kept_rows[base..base + lanes] {
            for &f in &a.filters {
                cells += (prep.weights.get(k as usize, f) as u8).count_ones() as u64;
            }
        }
        cells
    }

    /// Simulate one standalone SIMD layer (dw-conv, pool, ...).
    pub fn run_simd_layer(&self, name: &str, op: SimdOp, elems: u64) -> LayerStats {
        let cycles = simd::simd_cycles(op, elems, &self.arch);
        let mut events = EventCounts::default();
        events.simd_lane_ops = simd::lane_ops(op, elems);
        events.instrs = 1;
        events.elapsed_cycles = cycles;
        events.core_cycles = cycles; // SIMD core only
        let category = match op {
            SimdOp::DwConv => OpCategory::DwConv,
            SimdOp::Mul => OpCategory::Mul,
            _ => OpCategory::Etc,
        };
        LayerStats {
            name: name.to_string(),
            category,
            events,
            core_cycles: vec![0; self.arch.n_cores],
            elapsed: cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_layer, prepare_layer, SparsityConfig};
    use crate::models::synthesize_weights;
    use crate::quant;
    use crate::tensor::{matmul_i8, MatI8};
    use crate::util::Rng;

    fn build(
        m: usize,
        k: usize,
        n: usize,
        sp: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
    ) -> (CompiledLayer, MatI8) {
        let w = synthesize_weights(seed, k, n);
        let prep = prepare_layer("t", m, k, n, w, sp, arch, quant::requant_mul(0.01), true, None);
        let layer = compile_layer(prep, arch);
        let mut rng = Rng::new(seed ^ 55);
        let x = MatI8::from_vec(
            m,
            k,
            (0..m * k)
                .map(|_| if rng.f64() < 0.5 { 0 } else { rng.range_i64(0, 63) as i8 })
                .collect(),
        );
        (layer, x)
    }

    #[test]
    fn functional_matches_reference_matmul_dbpim() {
        let arch = ArchConfig::db_pim();
        let (layer, x) = build(12, 96, 16, SparsityConfig::hybrid(0.5), &arch, 1);
        let machine = Machine::new(arch);
        let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
        let want = matmul_i8(&x, &layer.prep.weights);
        assert_eq!(acc.unwrap(), want);
    }

    #[test]
    fn functional_matches_reference_matmul_baseline() {
        let arch = ArchConfig::dense_baseline();
        // baseline runs the same sparsified model, mapped densely
        let (layer, x) = build(6, 64, 16, SparsityConfig::hybrid(0.5), &arch, 2);
        let machine = Machine::new(arch);
        let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
        let want = matmul_i8(&x, &layer.prep.weights);
        assert_eq!(acc.unwrap(), want);
    }

    #[test]
    fn dbpim_is_faster_than_baseline_on_same_layer() {
        let sp = SparsityConfig::hybrid(0.6);
        let arch_d = ArchConfig::db_pim();
        let arch_b = ArchConfig::dense_baseline();
        let (ld, x) = build(32, 256, 64, sp, &arch_d, 3);
        let (lb, _) = build(32, 256, 64, sp, &arch_b, 3);
        let (sd, _) = Machine::new(arch_d).run_pim_layer(&ld, Some(&x), false);
        let (sb, _) = Machine::new(arch_b).run_pim_layer(&lb, None, false);
        let speedup = sb.elapsed as f64 / sd.elapsed as f64;
        assert!(speedup > 3.0, "speedup {speedup}");
    }

    #[test]
    fn analytic_path_matches_row_loop_when_no_skipping() {
        // weights-only arch has IPU off; force the loop path via
        // functional mode and compare timing events to the fast path.
        let arch = ArchConfig::weights_only();
        let (layer, x) = build(8, 128, 16, SparsityConfig::hybrid(0.4), &arch, 4);
        let machine = Machine::new(arch);
        let (fast, _) = machine.run_pim_layer(&layer, Some(&x), false);
        let (slow, _) = machine.run_pim_layer(&layer, Some(&x), true);
        assert_eq!(fast.elapsed, slow.elapsed);
        assert_eq!(fast.events.macro_cycles, slow.events.macro_cycles);
        assert_eq!(fast.events.macro_col_cycles, slow.events.macro_col_cycles);
        assert_eq!(fast.events.active_col_cycles, slow.events.active_col_cycles);
        assert_eq!(fast.events.input_buf_reads, slow.events.input_buf_reads);
        assert_eq!(fast.events.macs, slow.events.macs);
        assert_eq!(fast.events.alloc_switches, slow.events.alloc_switches);
    }

    #[test]
    fn input_skipping_reduces_cycles() {
        let sp = SparsityConfig::hybrid(0.0);
        let arch_on = ArchConfig::bit_only();
        let arch_off = ArchConfig::weights_only();
        let (l_on, x) = build(16, 128, 16, sp, &arch_on, 5);
        let (l_off, _) = build(16, 128, 16, sp, &arch_off, 5);
        let (s_on, _) = Machine::new(arch_on).run_pim_layer(&l_on, Some(&x), false);
        let (s_off, _) = Machine::new(arch_off).run_pim_layer(&l_off, Some(&x), false);
        assert!(
            s_on.elapsed < s_off.elapsed,
            "IPU on {} vs off {}",
            s_on.elapsed,
            s_off.elapsed
        );
    }

    #[test]
    fn utilization_dbpim_beats_dense() {
        let sp = SparsityConfig::hybrid(0.0);
        let arch_d = ArchConfig::weights_only();
        let arch_b = ArchConfig::dense_baseline();
        let (ld, _) = build(8, 256, 64, sp, &arch_d, 6);
        let (lb, _) = build(8, 256, 64, sp, &arch_b, 6);
        let (sd, _) = Machine::new(arch_d.clone()).run_pim_layer(&ld, None, false);
        let (sb, _) = Machine::new(arch_b.clone()).run_pim_layer(&lb, None, false);
        let cells_d = arch_d.macro_columns * arch_d.compartments;
        let ud = sd.events.active_col_cycles as f64
            / (sd.events.macro_cycles * cells_d as u64) as f64;
        let ub = sb.events.active_col_cycles as f64
            / (sb.events.macro_cycles * cells_d as u64) as f64;
        assert!(ud > 0.5, "dbpim U_act {ud}");
        assert!(ub < 0.55, "dense U_act {ub}");
        assert!(ud > 1.5 * ub, "dbpim {ud} vs dense {ub}");
    }

    #[test]
    fn energy_dbpim_lower_than_baseline() {
        let sp = SparsityConfig::hybrid(0.6);
        let arch_d = ArchConfig::db_pim();
        let arch_b = ArchConfig::dense_baseline();
        let (ld, x) = build(16, 256, 32, sp, &arch_d, 7);
        let (lb, _) = build(16, 256, 32, sp, &arch_b, 7);
        let md = Machine::new(arch_d);
        let mb = Machine::new(arch_b);
        let (sd, _) = md.run_pim_layer(&ld, Some(&x), false);
        let (sb, _) = mb.run_pim_layer(&lb, None, false);
        let ed = sd.events.energy_pj(&md.energy);
        let eb = sb.events.energy_pj(&mb.energy);
        assert!(ed < 0.5 * eb, "energy {ed} vs {eb}");
    }

    #[test]
    fn simd_layer_costs_scale_with_elems() {
        let m = Machine::new(ArchConfig::db_pim());
        let a = m.run_simd_layer("dw", SimdOp::DwConv, 1000);
        let b = m.run_simd_layer("dw", SimdOp::DwConv, 2000);
        assert!(b.elapsed >= 2 * a.elapsed - 1);
        assert_eq!(a.category, OpCategory::DwConv);
    }
}
