//! The cycle-accurate machine model: top controller executing the
//! compiled programs over the PIM cores, sparse allocation network,
//! IPUs and SIMD core, with full event/energy accounting.
//!
//! Timing model (DESIGN.md §6). One macro bit-cycle = all 16
//! compartments perform their DBMU ANDs + the PPUs reduce one input bit
//! column. Per input row (one im2col row m) and weight tile:
//!
//! ```text
//! steps   = ceil(tile_rows / compartments)
//! cycles  = Σ_steps B_eff(step)        # B_eff = IPU-surviving columns
//! ```
//!
//! The Tm macros of a core hold identical weights and process Tm
//! different m rows concurrently (pipelined); a Compute instruction
//! advances the core clock by the *max* of its rows' cycle counts while
//! energy accrues for every row. Cores run independently; Sync aligns
//! them; layer makespan = max core clock.
//!
//! This file is the thin façade over the execution stack (DESIGN.md
//! §8): the per-core work lives in [`super::core_exec::CoreExecutor`],
//! the barrier scheduling + parallel fan-out in [`super::engine`], and
//! `Machine::run_pim_layer` dispatches on the machine's configured
//! [`Engine`] so every existing call site keeps working unchanged.

use std::sync::Arc;

use crate::arch::ArchConfig;
use crate::compiler::CompiledLayer;
use crate::energy::{EnergyTable, EventCounts};
use crate::isa::SimdOp;
use crate::tensor::{MatI8, MatI32};

use super::engine::{self, Engine};
use super::simd;

/// Per-layer simulation result.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    /// Operation category for the Fig. 13 breakdown.
    pub category: OpCategory,
    pub events: EventCounts,
    /// Busy cycles per core.
    pub core_cycles: Vec<u64>,
    /// Layer makespan in cycles.
    pub elapsed: u64,
}

/// Fig. 13 execution-time categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpCategory {
    /// std/pw conv + FC (PIM).
    PimConvFc,
    /// Depthwise conv (SIMD).
    DwConv,
    /// Element-wise multiplies (SIMD).
    Mul,
    /// Everything else: pool, ReLU, residual add (SIMD).
    Etc,
}

/// The machine: an architecture + energy table + execution engine.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Shared architecture description. `Arc` so the per-batch machine
    /// and every report it assembles alias one config instead of
    /// deep-cloning it per layer/report on the sweep hot path (deref
    /// coercion keeps `&machine.arch` usable wherever `&ArchConfig` is
    /// expected).
    pub arch: Arc<ArchConfig>,
    pub energy: EnergyTable,
    /// How segmented programs are driven (default: parallel; results
    /// are bit-identical either way).
    pub engine: Engine,
}

impl Machine {
    pub fn new(arch: impl Into<Arc<ArchConfig>>) -> Self {
        Self::with_engine(arch, Engine::Parallel)
    }

    pub fn with_engine(arch: impl Into<Arc<ArchConfig>>, engine: Engine) -> Self {
        Self { arch: arch.into(), energy: EnergyTable::default28nm(), engine }
    }

    /// Execute one compiled PIM layer.
    ///
    /// * `x` — the im2col input matrix [M, K]; required in functional
    ///   mode and whenever IPU skipping is on (data-dependent timing).
    /// * `functional` — also compute the exact INT32 accumulators.
    ///
    /// Returns stats and (in functional mode) the [M, N] accumulators.
    /// Compat shim over the segmented engines: dispatches the layer's
    /// per-core program on `self.engine`.
    pub fn run_pim_layer(
        &self,
        layer: &CompiledLayer,
        x: Option<&MatI8>,
        functional: bool,
    ) -> (LayerStats, Option<MatI32>) {
        engine::run_layer(self, layer, x, functional, self.engine)
    }

    /// Legacy flat-stream interpreter (single thread, original
    /// interleaved instruction order). The segmented engines are
    /// property-tested bit-identical against this baseline.
    pub fn run_pim_layer_interp(
        &self,
        layer: &CompiledLayer,
        x: Option<&MatI8>,
        functional: bool,
    ) -> (LayerStats, Option<MatI32>) {
        engine::run_layer_interp(self, layer, x, functional)
    }

    /// Simulate one standalone SIMD layer (dw-conv, pool, ...).
    pub fn run_simd_layer(&self, name: &str, op: SimdOp, elems: u64) -> LayerStats {
        let cycles = simd::simd_cycles(op, elems, &self.arch);
        let events = EventCounts {
            simd_lane_ops: simd::lane_ops(op, elems),
            instrs: 1,
            elapsed_cycles: cycles,
            core_cycles: cycles, // SIMD core only
            ..EventCounts::default()
        };
        let category = match op {
            SimdOp::DwConv => OpCategory::DwConv,
            SimdOp::Mul => OpCategory::Mul,
            _ => OpCategory::Etc,
        };
        LayerStats {
            name: name.to_string(),
            category,
            events,
            core_cycles: vec![0; self.arch.n_cores],
            elapsed: cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_layer, prepare_layer, SparsityConfig};
    use crate::models::synthesize_weights;
    use crate::quant;
    use crate::tensor::{matmul_i8, MatI8};
    use crate::util::Rng;

    fn build(
        m: usize,
        k: usize,
        n: usize,
        sp: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
    ) -> (CompiledLayer, MatI8) {
        let w = synthesize_weights(seed, k, n);
        let prep = prepare_layer("t", m, k, n, w, sp, arch, quant::requant_mul(0.01), true, None);
        let layer = compile_layer(prep, arch);
        let mut rng = Rng::new(seed ^ 55);
        let x = MatI8::from_vec(
            m,
            k,
            (0..m * k)
                .map(|_| if rng.f64() < 0.5 { 0 } else { rng.range_i64(0, 63) as i8 })
                .collect(),
        );
        (layer, x)
    }

    #[test]
    fn functional_matches_reference_matmul_dbpim() {
        let arch = ArchConfig::db_pim();
        let (layer, x) = build(12, 96, 16, SparsityConfig::hybrid(0.5), &arch, 1);
        let machine = Machine::new(arch);
        let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
        let want = matmul_i8(&x, &layer.prep.weights);
        assert_eq!(acc.unwrap(), want);
    }

    #[test]
    fn functional_matches_reference_matmul_baseline() {
        let arch = ArchConfig::dense_baseline();
        // baseline runs the same sparsified model, mapped densely
        let (layer, x) = build(6, 64, 16, SparsityConfig::hybrid(0.5), &arch, 2);
        let machine = Machine::new(arch);
        let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
        let want = matmul_i8(&x, &layer.prep.weights);
        assert_eq!(acc.unwrap(), want);
    }

    #[test]
    fn dbpim_is_faster_than_baseline_on_same_layer() {
        let sp = SparsityConfig::hybrid(0.6);
        let arch_d = ArchConfig::db_pim();
        let arch_b = ArchConfig::dense_baseline();
        let (ld, x) = build(32, 256, 64, sp, &arch_d, 3);
        let (lb, _) = build(32, 256, 64, sp, &arch_b, 3);
        let (sd, _) = Machine::new(arch_d).run_pim_layer(&ld, Some(&x), false);
        let (sb, _) = Machine::new(arch_b).run_pim_layer(&lb, None, false);
        let speedup = sb.elapsed as f64 / sd.elapsed as f64;
        assert!(speedup > 3.0, "speedup {speedup}");
    }

    #[test]
    fn analytic_path_matches_row_loop_when_no_skipping() {
        // weights-only arch has IPU off; force the loop path via
        // functional mode and compare timing events to the fast path.
        let arch = ArchConfig::weights_only();
        let (layer, x) = build(8, 128, 16, SparsityConfig::hybrid(0.4), &arch, 4);
        let machine = Machine::new(arch);
        let (fast, _) = machine.run_pim_layer(&layer, Some(&x), false);
        let (slow, _) = machine.run_pim_layer(&layer, Some(&x), true);
        assert_eq!(fast.elapsed, slow.elapsed);
        assert_eq!(fast.events.macro_cycles, slow.events.macro_cycles);
        assert_eq!(fast.events.macro_col_cycles, slow.events.macro_col_cycles);
        assert_eq!(fast.events.active_col_cycles, slow.events.active_col_cycles);
        assert_eq!(fast.events.input_buf_reads, slow.events.input_buf_reads);
        assert_eq!(fast.events.macs, slow.events.macs);
        assert_eq!(fast.events.alloc_switches, slow.events.alloc_switches);
    }

    #[test]
    fn input_skipping_reduces_cycles() {
        let sp = SparsityConfig::hybrid(0.0);
        let arch_on = ArchConfig::bit_only();
        let arch_off = ArchConfig::weights_only();
        let (l_on, x) = build(16, 128, 16, sp, &arch_on, 5);
        let (l_off, _) = build(16, 128, 16, sp, &arch_off, 5);
        let (s_on, _) = Machine::new(arch_on).run_pim_layer(&l_on, Some(&x), false);
        let (s_off, _) = Machine::new(arch_off).run_pim_layer(&l_off, Some(&x), false);
        assert!(
            s_on.elapsed < s_off.elapsed,
            "IPU on {} vs off {}",
            s_on.elapsed,
            s_off.elapsed
        );
    }

    #[test]
    fn utilization_dbpim_beats_dense() {
        let sp = SparsityConfig::hybrid(0.0);
        let arch_d = ArchConfig::weights_only();
        let arch_b = ArchConfig::dense_baseline();
        let (ld, _) = build(8, 256, 64, sp, &arch_d, 6);
        let (lb, _) = build(8, 256, 64, sp, &arch_b, 6);
        let (sd, _) = Machine::new(arch_d.clone()).run_pim_layer(&ld, None, false);
        let (sb, _) = Machine::new(arch_b.clone()).run_pim_layer(&lb, None, false);
        let cells_d = arch_d.macro_columns * arch_d.compartments;
        let ud = sd.events.active_col_cycles as f64
            / (sd.events.macro_cycles * cells_d as u64) as f64;
        let ub = sb.events.active_col_cycles as f64
            / (sb.events.macro_cycles * cells_d as u64) as f64;
        assert!(ud > 0.5, "dbpim U_act {ud}");
        assert!(ub < 0.55, "dense U_act {ub}");
        assert!(ud > 1.5 * ub, "dbpim {ud} vs dense {ub}");
    }

    #[test]
    fn energy_dbpim_lower_than_baseline() {
        let sp = SparsityConfig::hybrid(0.6);
        let arch_d = ArchConfig::db_pim();
        let arch_b = ArchConfig::dense_baseline();
        let (ld, x) = build(16, 256, 32, sp, &arch_d, 7);
        let (lb, _) = build(16, 256, 32, sp, &arch_b, 7);
        let md = Machine::new(arch_d);
        let mb = Machine::new(arch_b);
        let (sd, _) = md.run_pim_layer(&ld, Some(&x), false);
        let (sb, _) = mb.run_pim_layer(&lb, None, false);
        let ed = sd.events.energy_pj(&md.energy);
        let eb = sb.events.energy_pj(&mb.energy);
        assert!(ed < 0.5 * eb, "energy {ed} vs {eb}");
    }

    #[test]
    fn simd_layer_costs_scale_with_elems() {
        let m = Machine::new(ArchConfig::db_pim());
        let a = m.run_simd_layer("dw", SimdOp::DwConv, 1000);
        let b = m.run_simd_layer("dw", SimdOp::DwConv, 2000);
        assert!(b.elapsed >= 2 * a.elapsed - 1);
        assert_eq!(a.category, OpCategory::DwConv);
    }

    #[test]
    fn engine_choice_is_bit_identical() {
        let sp = SparsityConfig::hybrid(0.5);
        let arch = ArchConfig::db_pim();
        let (layer, x) = build(20, 320, 48, sp, &arch, 8);
        let seq = Machine::with_engine(arch.clone(), Engine::Sequential);
        let par = Machine::with_engine(arch, Engine::Parallel);
        let (ss, accs) = seq.run_pim_layer(&layer, Some(&x), true);
        let (sp2, accp) = par.run_pim_layer(&layer, Some(&x), true);
        let (si, acci) = par.run_pim_layer_interp(&layer, Some(&x), true);
        assert_eq!(ss.events, sp2.events);
        assert_eq!(ss.events, si.events);
        assert_eq!(ss.core_cycles, sp2.core_cycles);
        assert_eq!(ss.core_cycles, si.core_cycles);
        assert_eq!(accs, accp);
        assert_eq!(accs, acci);
    }
}
