//! Minimal JSON parser + writer (the vendored registry has no serde).
//! Supports the full JSON grammar minus exotic escapes; used for the
//! artifact manifest and experiment reports.
//!
//! This module sits on user-input paths (spec files, traces), so
//! `unwrap`/`expect` are linted out — fallible lookups go through
//! [`Value::try_req`] and friends.
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience: `obj["a"]["b"]` style access, panicking with a clear
    /// message. Only for trusted build outputs and tests — anything
    /// reachable from user input must use [`Self::try_req`].
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key {key:?}"))
    }

    /// Non-panicking required lookup for untrusted documents: a missing
    /// key (or a non-object receiver) is a descriptive `Err`.
    pub fn try_req(&self, key: &str) -> Result<&Value, String> {
        self.get(key).ok_or_else(|| format!("missing JSON key {key:?}"))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(format!("bad object at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(format!("bad array at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("bad \\u escape")?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or("bad hex digit")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // re-decode UTF-8 multibyte sequences
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("bad number bytes at {start}"))?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("bad number {s:?} at byte {start}"))
    }
}

/// Serialize a value as compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for ch in s.chars() {
                match ch {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Value::Str(k.clone()), out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

/// Builder helpers for report generation.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn str_(s: &str) -> Value {
    Value::Str(s.to_string())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(parse("-3.5").unwrap().as_f64(), Some(-3.5));
        assert_eq!(parse("\"hi\"").unwrap().as_str(), Some("hi"));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let arr = v.req("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].req("b").as_str(), Some("c"));
        assert_eq!(v.req("d"), &Value::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""line\nquote\"uA""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nquote\"uA"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"alpha":8,"layers":[{"k":72,"n":16}],"name":"mininet","ok":true}"#;
        let v = parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn parses_real_manifest() {
        // shape of the aot.py manifest
        let text = r#"{
          "version": 1, "alpha": 8,
          "input": {"batch": 2, "ch": 8, "hw": 16},
          "layers": [{"name": "conv1", "kind": "conv", "k": 72, "n": 16,
                      "weight_offset": 0, "mask_offset": 0,
                      "requant_mul": 1286, "thresholds": [2, 1],
                      "conv": {"out_ch": 16, "in_ch": 8, "kernel": 3,
                               "stride": 1, "pad": 1, "pool": true}}]
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req("alpha").as_usize(), Some(8));
        let layer = &v.req("layers").as_arr().unwrap()[0];
        assert_eq!(layer.req("conv").req("pool").as_bool(), Some(true));
        assert_eq!(layer.req("thresholds").as_arr().unwrap()[0].as_i64(), Some(2));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"φ≤2\"").unwrap();
        assert_eq!(v.as_str(), Some("φ≤2"));
    }
}
