//! Coarse-grained block-wise pruning (value-level sparsity) — mirror of
//! `python/compile/pruning.py`.
//!
//! A layer's [K, N] im2col weight matrix is partitioned into 1×α blocks
//! along the filter axis (α = 8, the macro column / FTA budget); blocks
//! are ranked by L2 norm and the lowest `sparsity` fraction is pruned.
//! A pruned block zeroes input position k for a whole α-filter group, so
//! the sparse allocation network can skip that input feature entirely.

/// DB-PIM pruning granularity.
pub const ALPHA: usize = 8;

/// Block keep-mask for a [K, N] layer: `mask[k * groups + g]` is true
/// when block (k, g) survives; `groups = N / α`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockMask {
    pub k: usize,
    pub groups: usize,
    pub alpha: usize,
    pub keep: Vec<bool>,
}

impl BlockMask {
    pub fn all_kept(k: usize, n: usize, alpha: usize) -> Self {
        assert_eq!(n % alpha, 0, "N={n} not divisible by alpha={alpha}");
        Self { k, groups: n / alpha, alpha, keep: vec![true; k * n / alpha] }
    }

    #[inline]
    pub fn kept(&self, k: usize, group: usize) -> bool {
        self.keep[k * self.groups + group]
    }

    /// Per-weight keep mask of shape [K, N] (row-major).
    pub fn expand(&self) -> Vec<bool> {
        let n = self.groups * self.alpha;
        let mut out = vec![false; self.k * n];
        for k in 0..self.k {
            for g in 0..self.groups {
                if self.kept(k, g) {
                    for a in 0..self.alpha {
                        out[k * n + g * self.alpha + a] = true;
                    }
                }
            }
        }
        out
    }

    /// Fraction of pruned blocks.
    pub fn sparsity(&self) -> f64 {
        let pruned = self.keep.iter().filter(|&&m| !m).count();
        pruned as f64 / self.keep.len() as f64
    }

    /// Number of kept rows (k positions) for one filter group — the
    /// effective K the allocation network streams to that group.
    pub fn kept_rows(&self, group: usize) -> usize {
        (0..self.k).filter(|&k| self.kept(k, group)).count()
    }

    /// Raw u8 encoding (1 = keep), matching the python export layout.
    pub fn from_bytes(k: usize, groups: usize, alpha: usize, bytes: &[u8]) -> Self {
        assert_eq!(bytes.len(), k * groups);
        Self { k, groups, alpha, keep: bytes.iter().map(|&b| b != 0).collect() }
    }
}

/// L2 norm of each 1×α block of a [K, N] matrix (row-major i8 weights).
pub fn block_l2(weights: &[i8], k: usize, n: usize, alpha: usize) -> Vec<f64> {
    assert_eq!(weights.len(), k * n);
    assert_eq!(n % alpha, 0, "N={n} not divisible by alpha={alpha}");
    let groups = n / alpha;
    let mut norms = vec![0f64; k * groups];
    for row in 0..k {
        for g in 0..groups {
            let mut acc = 0f64;
            for a in 0..alpha {
                let w = weights[row * n + g * alpha + a] as f64;
                acc += w * w;
            }
            norms[row * groups + g] = acc.sqrt();
        }
    }
    norms
}

/// Prune the lowest-L2 `sparsity` fraction of blocks in place.
/// Ties break by block order (stable sort), matching numpy's stable
/// argsort in the python mirror.
pub fn prune_blocks(
    weights: &mut [i8],
    k: usize,
    n: usize,
    sparsity: f64,
    alpha: usize,
) -> BlockMask {
    assert!((0.0..1.0).contains(&sparsity), "sparsity {sparsity}");
    let norms = block_l2(weights, k, n, alpha);
    let groups = n / alpha;
    let mut mask = BlockMask::all_kept(k, n, alpha);
    let n_prune = (sparsity * (k * groups) as f64).round() as usize;
    if n_prune > 0 {
        // Selection instead of a full sort (perf §Perf): we only need
        // the n_prune smallest blocks; (norm, index) ordering matches
        // numpy's stable argsort tie-break in the python mirror.
        let mut order: Vec<usize> = (0..norms.len()).collect();
        let cmp = |&a: &usize, &b: &usize| {
            norms[a].partial_cmp(&norms[b]).unwrap().then(a.cmp(&b))
        };
        if n_prune < order.len() {
            order.select_nth_unstable_by(n_prune, cmp);
        }
        for &idx in order.iter().take(n_prune) {
            mask.keep[idx] = false;
            let (row, g) = (idx / groups, idx % groups);
            for a in 0..alpha {
                weights[row * n + g * alpha + a] = 0;
            }
        }
    }
    mask
}

/// N:M structured pruning (transformer FFN config, DESIGN.md §14):
/// within every group of `m` consecutive input rows of one filter
/// column, keep the `keep` largest-magnitude weights and zero the rest
/// (stable tie-break: the earlier row wins, so the result is
/// deterministic). `weights` is the [K, N] row-major synthesized
/// matrix; a trailing group shorter than `m` is kept proportionally
/// (only rows beyond the `keep` largest are zeroed). No-op when
/// `keep >= m`.
pub fn prune_n_of_m(weights: &mut [i8], k: usize, n: usize, keep: usize, m: usize) {
    assert_eq!(weights.len(), k * n, "weights must be K×N row-major");
    if m == 0 || keep >= m {
        return;
    }
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    for col in 0..n {
        let mut g0 = 0usize;
        while g0 < k {
            let glen = m.min(k - g0);
            if glen > keep {
                idx.clear();
                idx.extend(0..glen);
                // |i8| via i16: |-128| overflows in i8
                idx.sort_by_key(|&i| {
                    (std::cmp::Reverse((weights[(g0 + i) * n + col] as i16).abs()), i)
                });
                for &i in &idx[keep..] {
                    weights[(g0 + i) * n + col] = 0;
                }
            }
            g0 += glen;
        }
    }
}

/// Fraction of exactly-zero weights.
pub fn value_sparsity(weights: &[i8]) -> f64 {
    if weights.is_empty() {
        return 0.0;
    }
    let zeros = weights.iter().filter(|&&w| w == 0).count();
    zeros as f64 / weights.len() as f64
}

/// Fig. 3(b): fraction of all-zero bit columns across groups of `group`
/// consecutive activations (the IPU's skippable columns).
pub fn group_zero_column_fraction(acts: &[i8], group: usize) -> f64 {
    if acts.is_empty() || acts.len() < group {
        return 0.0;
    }
    let usable = (acts.len() / group) * group;
    let mut zero_cols = 0usize;
    let mut total_cols = 0usize;
    for chunk in acts[..usable].chunks_exact(group) {
        let or: u8 = chunk.iter().fold(0u8, |acc, &v| acc | (v.unsigned_abs()));
        zero_cols += or.count_zeros() as usize;
        total_cols += 8;
    }
    zero_cols as f64 / total_cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_cases;

    #[test]
    fn block_l2_values() {
        // one row: [3;8] then [4;8]
        let mut w = vec![3i8; 8];
        w.extend(vec![4i8; 8]);
        let norms = block_l2(&w, 1, 16, 8);
        assert!((norms[0] - (9.0f64 * 8.0).sqrt()).abs() < 1e-12);
        assert!((norms[1] - (16.0f64 * 8.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn prunes_exact_fraction_and_lowest_norm() {
        let mut w = vec![0i8; 2 * 16];
        for a in 0..8 {
            w[a] = 10; // row0 g0: strong
            w[8 + a] = 1; // row0 g1: weak
            w[16 + a] = 5; // row1 g0
            w[24 + a] = 2; // row1 g1
        }
        let mask = prune_blocks(&mut w, 2, 16, 0.5, 8);
        assert!(mask.kept(0, 0) && mask.kept(1, 0));
        assert!(!mask.kept(0, 1) && !mask.kept(1, 1));
        assert!((mask.sparsity() - 0.5).abs() < 1e-12);
        assert!(w[8..16].iter().all(|&v| v == 0));
        assert!(w[24..32].iter().all(|&v| v == 0));
        assert!(w[..8].iter().all(|&v| v == 10));
    }

    #[test]
    fn zero_sparsity_keeps_everything() {
        let mut w = vec![1i8; 32];
        let mask = prune_blocks(&mut w, 2, 16, 0.0, 8);
        assert!(mask.keep.iter().all(|&m| m));
        assert!(w.iter().all(|&v| v == 1));
    }

    #[test]
    fn expand_mask_layout() {
        let mut mask = BlockMask::all_kept(2, 8, 4);
        mask.keep = vec![true, false, false, true];
        let e = mask.expand();
        assert_eq!(e[..8], [true, true, true, true, false, false, false, false]);
        assert_eq!(e[8..], [false, false, false, false, true, true, true, true]);
    }

    #[test]
    fn kept_rows_counts() {
        let mut mask = BlockMask::all_kept(3, 8, 8);
        mask.keep = vec![true, false, true];
        assert_eq!(mask.kept_rows(0), 2);
    }

    #[test]
    fn group_zero_columns_extremes() {
        assert_eq!(group_zero_column_fraction(&vec![0i8; 64], 8), 1.0);
        // 127 = 0111_1111: only bit 7 is a zero column
        let f = group_zero_column_fraction(&vec![127i8; 64], 8);
        assert!((f - 1.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn group_zero_columns_monotone_in_group_size() {
        let mut rng = crate::util::Rng::new(1);
        let acts: Vec<i8> = (0..4096)
            .map(|_| if rng.f64() < 0.5 { 0 } else { rng.range_i64(0, 31) as i8 })
            .collect();
        let f1 = group_zero_column_fraction(&acts, 1);
        let f8 = group_zero_column_fraction(&acts, 8);
        let f16 = group_zero_column_fraction(&acts, 16);
        assert!(f1 >= f8 && f8 >= f16, "{f1} {f8} {f16}");
        assert!(f8 > 0.2);
    }

    #[test]
    fn n_of_m_keeps_largest_per_group() {
        // one column, K = 8, 2:4 — groups [9,1,5,3] and [2,2,8,7]
        let mut w = vec![9i8, 1, 5, 3, 2, 2, 8, 7];
        prune_n_of_m(&mut w, 8, 1, 2, 4);
        assert_eq!(w, vec![9, 0, 5, 0, 0, 0, 8, 7]);
        // ties keep the earlier row; negative magnitudes count
        let mut t = vec![-4i8, 4, 4, 1];
        prune_n_of_m(&mut t, 4, 1, 2, 4);
        assert_eq!(t, vec![-4, 4, 0, 0]);
        // keep >= m is a no-op
        let mut u = vec![1i8, 2, 3, 4];
        prune_n_of_m(&mut u, 4, 1, 4, 4);
        assert_eq!(u, vec![1, 2, 3, 4]);
    }

    #[test]
    fn n_of_m_property_deterministic_and_bounded() {
        check_cases(24, |rng| {
            let k = 4 + rng.below(40) as usize;
            let n = 1 + rng.below(12) as usize;
            let m = 2 + rng.below(6) as usize;
            let keep = 1 + rng.below(m as u64 - 1) as usize;
            let orig: Vec<i8> = (0..k * n).map(|_| rng.int8()).collect();
            let mut a = orig.clone();
            prune_n_of_m(&mut a, k, n, keep, m);
            // deterministic
            let mut b = orig.clone();
            prune_n_of_m(&mut b, k, n, keep, m);
            if a != b {
                return Err("non-deterministic".into());
            }
            // idempotent
            let mut c = a.clone();
            prune_n_of_m(&mut c, k, n, keep, m);
            if c != a {
                return Err("not idempotent".into());
            }
            // at most `keep` nonzeros per full group, per column
            for col in 0..n {
                let mut g0 = 0usize;
                while g0 < k {
                    let glen = m.min(k - g0);
                    let nz =
                        (0..glen).filter(|&i| a[(g0 + i) * n + col] != 0).count();
                    let cap = keep.min(glen);
                    if glen > keep && nz > cap {
                        return Err(format!("group at {g0} col {col}: {nz} > {cap}"));
                    }
                    g0 += glen;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prune_fraction_property() {
        check_cases(24, |rng| {
            let k = 4 + rng.below(12) as usize;
            let groups = 1 + rng.below(6) as usize;
            let n = groups * ALPHA;
            let sparsity = rng.f64() * 0.9;
            let mut w: Vec<i8> = (0..k * n).map(|_| rng.int8()).collect();
            let mask = prune_blocks(&mut w, k, n, sparsity, ALPHA);
            let expect = (sparsity * (k * groups) as f64).round() as usize;
            let pruned = mask.keep.iter().filter(|&&m| !m).count();
            if pruned != expect {
                return Err(format!("pruned {pruned} != {expect}"));
            }
            // pruned blocks are fully zero
            for kk in 0..k {
                for g in 0..groups {
                    if !mask.kept(kk, g) {
                        for a in 0..ALPHA {
                            if w[kk * n + g * ALPHA + a] != 0 {
                                return Err("pruned block not zeroed".into());
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
