//! Sweep-wide compilation cache.
//!
//! The experiment drivers (coordinator::experiments) sweep
//! (network × sparsity × architecture) grids in which many points share
//! identical `(arch knobs, layer shape, sparsity config, seed)`
//! combinations — most prominently the dense baseline a whole figure is
//! normalized against, which the pre-cache drivers recompiled from
//! scratch at every sweep point. [`CompileCache`] is a content-keyed
//! memo of compiled layers: the key hashes every input that reaches the
//! prepare → pack → tile → schedule → codegen pipeline, so a hit is
//! guaranteed to be the byte-identical artifact (compilation is
//! deterministic per key — DESIGN.md §3).
//!
//! The cache is owned by a sweep's `SweepCtx` and shared by reference
//! across the sweep's pool jobs; it is mutex-sharded so jobs resolving
//! different layers don't serialize on one lock. Compilation happens
//! *outside* the shard lock: two racing
//! jobs may compile the same key once each, which is harmless (the
//! artifacts are identical; the first insert wins) and keeps a long
//! compile from blocking every other job mapped to the shard.

use std::collections::hash_map::{DefaultHasher, Entry};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::{ArchConfig, DegradePolicy, SchedulePolicy};
use crate::models::Network;

use super::{compile_network_layer, CompiledLayer, SparsityConfig};

/// Everything that determines a compiled layer. Arch fields that only
/// affect *simulation* of the artifact (clock frequency, SIMD lane
/// count, buffer capacities) are deliberately excluded; every knob the
/// compiler pipeline reads is included.
///
/// Crate-visible because `sim::simcache::SimCache` reuses it verbatim
/// as the compile half of its own key: perf-mode simulation is a pure
/// function of the compiled artifact plus inputs this key already pins
/// (activation synthesis is seeded by `(seed, layer_idx, m, k)`, and
/// every arch knob the executor reads is a compile knob).
///
/// The kernel-backend tag codegen records in `Program::kernel` is NOT
/// part of the key: every backend is bit-identical to the scalar
/// oracle (sim::backend), so the tag cannot change any result, and
/// selection is process-consistent (policy resolved once, auto choice
/// memoized per shape class) — a cache hit and a fresh compile of the
/// same key always carry the same tag
/// (`cached_artifact_equals_fresh_compile` below).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub(crate) struct CompileKey {
    network: String,
    layer_idx: usize,
    /// The layer's actual matmul shape and conv geometry, so two
    /// networks that merely share a name (e.g. programmatically built
    /// variants) can never alias each other's artifacts.
    m: usize,
    k: usize,
    n: usize,
    /// (kernel, stride, pad, in_hw) for conv layers, zeros for FC.
    conv_geom: (usize, usize, usize, usize),
    seed: u64,
    /// `SparsityConfig::value_sparsity` as raw bits (f64 is not `Hash`).
    value_sparsity_bits: u64,
    fta: bool,
    n_cores: usize,
    macros_per_core: usize,
    compartments: usize,
    rows_per_compartment: usize,
    macro_columns: usize,
    input_bits: usize,
    alpha: usize,
    tile_load_cycles: u64,
    weight_bit_sparsity: bool,
    value_sparsity: bool,
    input_skipping: bool,
    merge_groups: bool,
    schedule: SchedulePolicy,
    /// Shard scope (coordinator::sharding): `(1, 0)` for the ordinary
    /// single-chip artifact; `(chips, chip)` for a chip-local
    /// re-lowering of the layer under tensor parallelism. Per-chip
    /// artifacts hold assignment *subsets*, so they must never alias
    /// the full artifact or each other.
    chips: usize,
    chip: usize,
    /// Cell-fault model bits (`CellFaultSpec::key_bits`): all zeros
    /// when the spec is off — a disabled fault subsystem never
    /// perturbs keys, so goldens and pinned cache counts stay
    /// bit-identical to a build without it — and the exact rates+seed
    /// otherwise, so faulty artifacts key on their spec.
    cell_faults: [u64; 4],
    /// Spare budget + degrade policy; compile-inert without faults, so
    /// normalized to zero/default when the spec is off.
    spare_columns: usize,
    spare_macros: usize,
    degrade: DegradePolicy,
}

impl CompileKey {
    pub(crate) fn new(
        net: &Network,
        idx: usize,
        sp: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
    ) -> Self {
        let kind = &net.layers[idx].kind;
        let (m, k, n) = kind.matmul_dims().expect("PIM layer");
        // Exhaustive on purpose: a new PIM-shaped `LayerKind` must
        // decide here whether it carries spatial geometry that the
        // cache key has to discriminate on.
        let conv_geom = match *kind {
            crate::models::LayerKind::Conv { kernel, stride, pad, in_hw, .. } => {
                (kernel, stride, pad, in_hw)
            }
            crate::models::LayerKind::Fc { .. }
            | crate::models::LayerKind::Attention { .. }
            | crate::models::LayerKind::Mlp { .. }
            | crate::models::LayerKind::DwConv { .. }
            | crate::models::LayerKind::Pool { .. }
            | crate::models::LayerKind::Act { .. }
            | crate::models::LayerKind::ResAdd { .. }
            | crate::models::LayerKind::Mul { .. }
            | crate::models::LayerKind::LayerNorm { .. } => (0, 0, 0, 0),
        };
        Self {
            network: net.name.clone(),
            layer_idx: idx,
            m,
            k,
            n,
            conv_geom,
            seed,
            value_sparsity_bits: sp.value_sparsity.to_bits(),
            fta: sp.fta,
            n_cores: arch.n_cores,
            macros_per_core: arch.macros_per_core,
            compartments: arch.compartments,
            rows_per_compartment: arch.rows_per_compartment,
            macro_columns: arch.macro_columns,
            input_bits: arch.input_bits,
            alpha: arch.alpha,
            tile_load_cycles: arch.tile_load_cycles,
            weight_bit_sparsity: arch.weight_bit_sparsity,
            value_sparsity: arch.value_sparsity,
            input_skipping: arch.input_skipping,
            merge_groups: arch.merge_groups,
            schedule: arch.schedule,
            chips: 1,
            chip: 0,
            cell_faults: arch.cell_faults.key_bits(),
            spare_columns: if arch.cell_faults.enabled() { arch.spare_columns_per_macro } else { 0 },
            spare_macros: if arch.cell_faults.enabled() { arch.spare_macros_per_core } else { 0 },
            degrade: if arch.cell_faults.enabled() {
                arch.fault_degrade
            } else {
                DegradePolicy::default()
            },
        }
    }

    /// The same key re-scoped to one chip of a `chips`-wide
    /// tensor-parallel fleet (coordinator::sharding).
    pub(crate) fn sharded(mut self, chips: usize, chip: usize) -> Self {
        self.chips = chips;
        self.chip = chip;
        self
    }
}

/// Shard count: enough to keep 16 sweep workers from colliding.
const SHARDS: usize = 16;

type Shard = Mutex<HashMap<CompileKey, Arc<CompiledLayer>>>;

/// Content-keyed, mutex-sharded memo of compiled layers, shared across
/// the jobs of one experiment sweep (`Arc<CompileCache>`).
#[derive(Debug)]
pub struct CompileCache {
    shards: Vec<Shard>,
    hits: AtomicU64,
    misses: AtomicU64,
    dup_computes: AtomicU64,
}

impl Default for CompileCache {
    fn default() -> Self {
        Self::new()
    }
}

impl CompileCache {
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            dup_computes: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &CompileKey) -> &Shard {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// Compile (or fetch) the PIM layer at `idx` of `net`. Returns
    /// `None` for non-PIM layers, mirroring [`compile_network_layer`].
    ///
    /// **Accounting is schedule-independent:** exactly one lookup per
    /// key — the one whose insert lands first — counts as the miss;
    /// every other lookup of that key counts as a hit, including a
    /// racing duplicate compile that lost the insert (the wasted work
    /// is tallied separately in [`CacheStats::dup_computes`]). So
    /// `hits`/`misses` are identical for any worker count or steal
    /// order, which lets tests pin them exactly.
    pub fn get_or_compile(
        &self,
        net: &Network,
        idx: usize,
        sparsity: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
    ) -> Option<Arc<CompiledLayer>> {
        net.layers[idx].kind.matmul_dims()?;
        let key = CompileKey::new(net, idx, sparsity, arch, seed);
        Some(self.get_or_insert_with(key, || {
            compile_network_layer(net, idx, sparsity, arch, seed).expect("PIM layer")
        }))
    }

    /// Fetch (or build via `build`) the artifact under an explicit key.
    /// The sharding layer uses this to memoize chip-local re-lowered
    /// artifacts under per-chip keys (`CompileKey::sharded`); the
    /// accounting contract matches [`CompileCache::get_or_compile`].
    /// `build` runs *outside* the shard lock: a racing duplicate build
    /// of the same key is deterministic, so whichever insert lands
    /// first is authoritative and the loser's artifact is dropped.
    pub(crate) fn get_or_insert_with(
        &self,
        key: CompileKey,
        build: impl FnOnce() -> CompiledLayer,
    ) -> Arc<CompiledLayer> {
        let shard = self.shard(&key);
        if let Some(hit) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        let compiled = Arc::new(build());
        let mut map = shard.lock().unwrap();
        match map.entry(key) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.dup_computes.fetch_add(1, Ordering::Relaxed);
                Arc::clone(e.get())
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Arc::clone(v.insert(compiled))
            }
        }
    }

    /// Mutex shard count (fixed; surfaced by `dbpim info`).
    pub fn shard_count() -> usize {
        SHARDS
    }

    /// Snapshot of the hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            dup_computes: self.dup_computes.load(Ordering::Relaxed),
        }
    }
}

/// Hit/miss counters of one sweep. A miss is the one lookup per key
/// that inserted the authoritative entry, so `hits` and `misses` are
/// deterministic for any worker count and steal order; only
/// `dup_computes` (computations that lost an insert race — wasted but
/// harmless work) depends on scheduling.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Computations whose insert lost a race (already counted as hits;
    /// excluded from `lookups`). Schedule-dependent — exclude from
    /// determinism comparisons.
    pub dup_computes: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// One-line driver-summary form: "3 hits / 5 misses (37.5% hit rate)",
    /// plus the racing-duplicate tally when one occurred.
    pub fn summary(&self) -> String {
        let base = format!(
            "{} hits / {} misses ({:.1}% hit rate)",
            self.hits,
            self.misses,
            100.0 * self.hit_rate()
        );
        if self.dup_computes == 0 {
            base
        } else {
            format!("{base}, {} duplicate computes", self.dup_computes)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fixtures::tiny_net;
    use crate::models::{Layer, LayerKind};

    #[test]
    fn second_lookup_hits_and_shares_the_artifact() {
        let cache = CompileCache::new();
        let net = tiny_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.5);
        let a = cache.get_or_compile(&net, 0, sp, &arch, 7).unwrap();
        let b = cache.get_or_compile(&net, 0, sp, &arch, 7).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit must return the shared artifact");
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, dup_computes: 0 });
    }

    #[test]
    fn distinct_keys_miss() {
        let cache = CompileCache::new();
        let net = tiny_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.5);
        cache.get_or_compile(&net, 0, sp, &arch, 7).unwrap();
        // different seed, sparsity, arch knob, layer: all distinct keys
        cache.get_or_compile(&net, 0, sp, &arch, 8).unwrap();
        cache.get_or_compile(&net, 0, SparsityConfig::hybrid(0.6), &arch, 7).unwrap();
        cache.get_or_compile(&net, 0, sp, &ArchConfig::dense_baseline(), 7).unwrap();
        cache.get_or_compile(&net, 2, sp, &arch, 7).unwrap();
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 5, dup_computes: 0 });
    }

    #[test]
    fn same_name_different_shape_does_not_alias() {
        // two networks sharing a name must never share artifacts: the
        // key carries the layer's actual shape, not just (name, idx)
        let cache = CompileCache::new();
        let a = tiny_net();
        let mut b = tiny_net();
        b.layers[2] = Layer {
            name: "fc".into(),
            kind: LayerKind::Fc { in_features: 256, out_features: 24 },
        };
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::dense();
        let ca = cache.get_or_compile(&a, 2, sp, &arch, 1).unwrap();
        let cb = cache.get_or_compile(&b, 2, sp, &arch, 1).unwrap();
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 2, dup_computes: 0 });
        assert_eq!(ca.prep.n, 8);
        assert_eq!(cb.prep.n, 24);
    }

    #[test]
    fn sharded_keys_do_not_alias_the_full_artifact() {
        let cache = CompileCache::new();
        let net = tiny_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.5);
        let full = cache.get_or_compile(&net, 0, sp, &arch, 7).unwrap();
        let key = CompileKey::new(&net, 0, sp, &arch, 7).sharded(2, 0);
        let derived = cache.get_or_insert_with(key.clone(), || {
            crate::compiler::compile_assignment_subset(&full, &[0], &arch)
        });
        assert!(!Arc::ptr_eq(&full, &derived), "per-chip key must not alias the full artifact");
        assert_eq!(derived.assignments.len(), 1);
        let again = cache.get_or_insert_with(key, || panic!("hit must not rebuild"));
        assert!(Arc::ptr_eq(&derived, &again));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 2, dup_computes: 0 });
    }

    #[test]
    fn fault_spec_scopes_keys_only_when_enabled() {
        let cache = CompileCache::new();
        let net = tiny_net();
        let sp = SparsityConfig::hybrid(0.5);
        let base = ArchConfig::db_pim();
        // off spec: spare/degrade knobs are compile-inert and must not
        // perturb the key (the second lookup is a hit)
        let mut respared = base.clone();
        respared.spare_columns_per_macro += 3;
        respared.fault_degrade = DegradePolicy::Mask;
        cache.get_or_compile(&net, 0, sp, &base, 7).unwrap();
        cache.get_or_compile(&net, 0, sp, &respared, 7).unwrap();
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, dup_computes: 0 });
        // enabled specs key on rates + seed + spare budget
        let mut faulty = base.clone();
        faulty.cell_faults = crate::arch::CellFaultSpec::default_with_seed(3);
        cache.get_or_compile(&net, 0, sp, &faulty, 7).unwrap();
        let mut reseeded = faulty.clone();
        reseeded.cell_faults.seed = 4;
        cache.get_or_compile(&net, 0, sp, &reseeded, 7).unwrap();
        let mut unspared = faulty.clone();
        unspared.spare_columns_per_macro = 0;
        cache.get_or_compile(&net, 0, sp, &unspared, 7).unwrap();
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 4, dup_computes: 0 });
    }

    #[test]
    fn cached_artifact_equals_fresh_compile() {
        let cache = CompileCache::new();
        let net = tiny_net();
        let arch = ArchConfig::db_pim();
        let sp = SparsityConfig::hybrid(0.4);
        let cached = cache.get_or_compile(&net, 2, sp, &arch, 3).unwrap();
        let fresh = compile_network_layer(&net, 2, sp, &arch, 3).unwrap();
        assert_eq!(cached.assignments, fresh.assignments);
        assert_eq!(cached.tiles, fresh.tiles);
        assert_eq!(cached.instrs, fresh.instrs);
        assert_eq!(cached.program, fresh.program);
    }

    #[test]
    fn non_pim_layers_return_none_without_counting() {
        let cache = CompileCache::new();
        let net = tiny_net();
        assert!(cache
            .get_or_compile(&net, 1, SparsityConfig::dense(), &ArchConfig::db_pim(), 1)
            .is_none());
        assert_eq!(cache.stats().lookups(), 0);
    }

    #[test]
    fn stats_formatting() {
        let s = CacheStats { hits: 3, misses: 5, dup_computes: 0 };
        assert_eq!(s.lookups(), 8);
        assert!((s.hit_rate() - 0.375).abs() < 1e-12);
        assert_eq!(s.summary(), "3 hits / 5 misses (37.5% hit rate)");
        let d = CacheStats { hits: 3, misses: 5, dup_computes: 2 };
        assert_eq!(d.lookups(), 8, "dup computes are already counted as hits");
        assert_eq!(d.summary(), "3 hits / 5 misses (37.5% hit rate), 2 duplicate computes");
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }
}
