//! The offline compiler: maps pruned+FTA networks onto the DB-PIM macro
//! grid (Fig. 9's multi-level loop nest) and emits the instruction
//! streams the top controller executes.
//!
//! Pipeline per PIM layer:
//! 1. **prepare** — pad N to the α granularity, apply coarse block
//!    pruning + FTA projection (or pass dense weights through for
//!    baseline configs).
//! 2. **pack** — form filter α-groups, compute each group's column
//!    demand (Σ φ_th under the DBMU mapping, 8 bits/filter under the
//!    dense mapping), and assign groups to macros.
//! 3. **tile** — split each assignment's kept K rows into
//!    Tk1×Tk2-sized weight tiles (the allocation network's gather means
//!    only *kept* rows occupy slots).
//! 4. **schedule** — balance assignments across the 8 cores (greedy
//!    longest-first, equivalent in makespan to the paper's N-K-M loop
//!    order for uniform groups).
//! 5. **codegen** — emit the segmented per-core [`Program`] (one
//!    barrier-free `Segment` per core, closed by Sync/EndLayer); the
//!    flat LoadTile/Compute/Store/Sync stream is its flattening.
//!
//! The whole pipeline is deterministic per
//! `(arch knobs, layer, sparsity, seed)`; [`cache::CompileCache`]
//! memoizes it sweep-wide so the experiment drivers compile each
//! distinct combination once instead of once per sweep point.

pub mod cache;
pub mod packing;
pub mod program;

use crate::arch::ArchConfig;
use crate::fta;
use crate::isa::Instr;
use crate::models::{LayerKind, MiniNetLayer, Network};
use crate::pruning::{self, BlockMask};
use crate::quant;
use crate::tensor::{ConvGeom, MatI8};
use crate::util::round_up;

pub use cache::{CacheStats, CompileCache};
pub use packing::{
    Assignment, AssignmentFaults, KernelShape, LayerFaults, MacroRepair, RepairPlan, RepairReport,
    ReplicaFault, Tile,
};
pub use program::{Barrier, Phase, Program};

/// Execution attributes of a conv layer (geometry + fused post-ops).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvExec {
    pub in_ch: usize,
    pub out_ch: usize,
    pub geom: ConvGeom,
    pub in_hw: usize,
    /// 2×2 max pool after ReLU.
    pub pool: bool,
}

/// A layer after sparsification, ready for packing.
#[derive(Debug, Clone)]
pub struct PreparedLayer {
    pub name: String,
    /// Output rows of the im2col matmul for batch 1 (batch scales M).
    pub m: usize,
    pub k: usize,
    /// N padded up to a multiple of α.
    pub n: usize,
    /// Logical (unpadded) filter count.
    pub n_logical: usize,
    /// [K, N] row-major INT8 weights after prune + FTA.
    pub weights: MatI8,
    pub mask: BlockMask,
    /// Per-filter φ_th (0 ⇒ filter entirely skipped).
    pub thresholds: Vec<u8>,
    pub requant_mul: i32,
    pub relu: bool,
    pub conv: Option<ConvExec>,
}

/// A fully compiled PIM layer.
#[derive(Debug, Clone)]
pub struct CompiledLayer {
    pub prep: PreparedLayer,
    pub assignments: Vec<Assignment>,
    pub tiles: Vec<Tile>,
    /// Flat instruction stream (the segmented program's flattening;
    /// kept for the instruction-buffer accounting and legacy interp).
    pub instrs: Vec<Instr>,
    /// Segmented per-core program executed by the engines.
    pub program: Program,
    /// Compile-side cell-fault state (repair report, per-replica
    /// corrupted/degraded resident blocks, ABFT detections). `None`
    /// when the arch's fault model is off — the zero-BER pipeline is
    /// bit-identical to a build without the subsystem (DESIGN.md §13).
    pub faults: Option<LayerFaults>,
}

impl CompiledLayer {
    /// The resident weight block replica `slot` of assignment `ai`
    /// actually reads at run time: the clean compile-time gather
    /// unless the fault pass recorded a corrupted (or policy-degraded)
    /// copy for that replica macro.
    pub fn effective_wblock(&self, ai: usize, slot: usize) -> &[i8] {
        if let Some(lf) = &self.faults {
            if let Some(af) = &lf.by_assignment[ai] {
                if let Some(r) = af.replicas.iter().find(|r| r.slot == slot) {
                    if let Some(w) = &r.wblock {
                        return w;
                    }
                }
            }
        }
        &self.assignments[ai].wblock
    }
}

/// Sparsification settings for the offline pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparsityConfig {
    /// Coarse block-pruning fraction (0.0 disables).
    pub value_sparsity: f64,
    /// Apply FTA (bit-level weight sparsity).
    pub fta: bool,
}

impl SparsityConfig {
    pub fn dense() -> Self {
        Self { value_sparsity: 0.0, fta: false }
    }

    pub fn hybrid(value_sparsity: f64) -> Self {
        Self { value_sparsity, fta: true }
    }
}

/// Prepare one layer from raw weights: pad, prune, project.
///
/// When the *architecture* lacks a sparsity feature the data is still
/// sparsified identically (same model everywhere, as in the paper's
/// baseline comparison) — the mapping just cannot exploit it:
/// `weight_bit_sparsity = false` stores 8 bit-columns per filter, and
/// `value_sparsity = false` keeps pruned rows resident.
#[allow(clippy::too_many_arguments)]
pub fn prepare_layer(
    name: &str,
    m: usize,
    k: usize,
    n_logical: usize,
    raw_weights: Vec<i8>, // [K, n_logical] row-major
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    requant_mul: i32,
    relu: bool,
    conv: Option<ConvExec>,
) -> PreparedLayer {
    assert_eq!(raw_weights.len(), k * n_logical);
    let n = round_up(n_logical, arch.alpha);
    // pad filters with zero columns
    let mut w = vec![0i8; k * n];
    for row in 0..k {
        w[row * n..row * n + n_logical]
            .copy_from_slice(&raw_weights[row * n_logical..(row + 1) * n_logical]);
    }
    // coarse block pruning
    let mask = if sparsity.value_sparsity > 0.0 {
        pruning::prune_blocks(&mut w, k, n, sparsity.value_sparsity, arch.alpha)
    } else {
        BlockMask::all_kept(k, n, arch.alpha)
    };
    // FTA projection
    let (w, thresholds) = if sparsity.fta {
        let expand = mask.expand();
        fta::fta_layer(&w, k, n, Some(&expand))
    } else {
        // dense mapping: every (non-padded) filter occupies the full 8
        // bit columns; record φ_th = 8 bits sentinel via threshold 0
        // handled in packing (dense path ignores thresholds).
        let ths = (0..n)
            .map(|col| (0..k).map(|row| crate::csd::phi(w[row * n + col])).max().unwrap_or(0))
            .collect();
        (w, ths)
    };
    PreparedLayer {
        name: name.to_string(),
        m,
        k,
        n,
        n_logical,
        weights: MatI8::from_vec(k, n, w),
        mask,
        thresholds,
        requant_mul,
        relu,
        conv,
    }
}

/// Prepare a layer directly from the python-exported MiniNet artifact
/// (weights are already pruned + FTA-projected — no re-sparsification).
pub fn prepare_from_mininet(l: &MiniNetLayer, batch: usize, relu: bool) -> PreparedLayer {
    let conv = l.conv.map(|c| ConvExec {
        in_ch: c.in_ch,
        out_ch: c.out_ch,
        geom: c.geom,
        in_hw: 0, // filled by the functional runner per activation
        pool: c.pool,
    });
    let m = match &l.conv {
        Some(_) => 0, // conv M depends on activation spatial dims at run time
        None => batch,
    };
    PreparedLayer {
        name: l.name.clone(),
        m,
        k: l.k,
        n: l.n,
        n_logical: l.n,
        weights: MatI8::from_vec(l.k, l.n, l.weights.clone()),
        mask: l.mask.clone(),
        thresholds: l.thresholds.clone(),
        requant_mul: l.requant_mul,
        relu,
        conv,
    }
}

/// Compile a prepared layer: pack, tile, schedule, codegen.
pub fn compile_layer(prep: PreparedLayer, arch: &ArchConfig) -> CompiledLayer {
    let (assignments, tiles) = packing::pack_layer(&prep, arch);
    let program = program::codegen(&prep, &assignments, &tiles, arch);
    let instrs = program.to_instrs();
    let faults = packing::apply_cell_faults(&assignments, &program.abft, arch);
    CompiledLayer { prep, assignments, tiles, instrs, program, faults }
}

/// Re-lower an already-compiled layer onto a subset of its assignments
/// (tensor-parallel sharding, coordinator::sharding): clone the
/// prepared layer, keep the selected assignments (ascending index, so
/// the chip-local stream order is a subsequence of the original), then
/// re-run the schedule → tile → codegen tail of the pipeline for the
/// subset. Per-instruction event semantics depend only on
/// (tile, assignment, arch, input), so the chips' physical event totals
/// partition the single-chip run's exactly (DESIGN.md §12).
pub fn compile_assignment_subset(
    full: &CompiledLayer,
    subset: &[usize],
    arch: &ArchConfig,
) -> CompiledLayer {
    debug_assert!(subset.windows(2).all(|w| w[0] < w[1]), "subset must ascend");
    let prep = full.prep.clone();
    let mut assignments: Vec<Assignment> =
        subset.iter().map(|&i| full.assignments[i].clone()).collect();
    packing::schedule_cores(&mut assignments, arch);
    let tiles = packing::tile_assignments(&assignments, arch.k_slots());
    let program = program::codegen(&prep, &assignments, &tiles, arch);
    let instrs = program.to_instrs();
    // per-chip fault state: `arch` here is the chip-local config, so a
    // sharded fleet's defect patterns are chip-independent
    // (CellFaultSpec::for_chip)
    let faults = packing::apply_cell_faults(&assignments, &program.abft, arch);
    CompiledLayer { prep, assignments, tiles, instrs, program, faults }
}

/// Sparsify + compile the PIM layer at index `idx` of a zoo network
/// (None for non-PIM layers). Deterministic per (seed, idx), so layer
/// jobs can fan out across workers in any order.
pub fn compile_network_layer(
    net: &Network,
    idx: usize,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
) -> Option<CompiledLayer> {
    let layer = &net.layers[idx];
    let (m, k, n) = layer.kind.matmul_dims()?;
    let mut raw = crate::models::synthesize_weights(seed ^ (idx as u64) << 8, k, n);
    // Per-layer sparsity configs (transformer workloads, DESIGN.md
    // §14): both refine a *sparse* run and are no-ops when the run is
    // dense, so dense-baseline reference runs stay truly dense. They
    // are pure functions of (net.name, idx), which the cache keys
    // already pin, so no CompileKey extension is needed.
    let mut sparsity = sparsity;
    if sparsity.value_sparsity > 0.0 {
        if let LayerKind::Attention { head_sparsity_pct: Some(pct), .. } = layer.kind {
            sparsity.value_sparsity = f64::from(pct.min(99)) / 100.0;
        }
        if let LayerKind::Mlp { nm: Some((keep, group)), .. } = layer.kind {
            crate::pruning::prune_n_of_m(&mut raw, k, n, keep as usize, group as usize);
        }
    }
    let conv = match layer.kind {
        LayerKind::Conv { in_ch, out_ch, kernel, stride, pad, in_hw } => Some(ConvExec {
            in_ch,
            out_ch,
            geom: ConvGeom { kh: kernel, kw: kernel, stride, pad },
            in_hw,
            pool: false,
        }),
        // GEMM-shaped kinds with no spatial reassembly
        LayerKind::Fc { .. } | LayerKind::Attention { .. } | LayerKind::Mlp { .. } => None,
        // non-PIM kinds already returned via matmul_dims()? above;
        // listed so new kinds must be classified here explicitly
        LayerKind::DwConv { .. }
        | LayerKind::Pool { .. }
        | LayerKind::Act { .. }
        | LayerKind::ResAdd { .. }
        | LayerKind::Mul { .. }
        | LayerKind::LayerNorm { .. } => None,
    };
    let mul = quant::requant_mul(1.0 / (k as f64).sqrt() / 6.0);
    let prep = prepare_layer(&layer.name, m, k, n, raw, sparsity, arch, mul, true, conv);
    Some(compile_layer(prep, arch))
}

/// Sparsify + compile every PIM layer of a zoo network (perf-mode
/// simulation; weights synthesized per layer).
pub fn compile_network(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
) -> Vec<(usize, CompiledLayer)> {
    (0..net.layers.len())
        .filter_map(|idx| compile_network_layer(net, idx, sparsity, arch, seed).map(|c| (idx, c)))
        .collect()
}

/// Effective K after value pruning, per α-group, averaged (diagnostics).
pub fn mean_kept_rows(prep: &PreparedLayer) -> f64 {
    let groups = prep.mask.groups;
    let total: usize = (0..groups).map(|g| prep.mask.kept_rows(g)).sum();
    total as f64 / groups as f64
}

/// Instruction-buffer footprint of a layer in bytes.
pub fn instr_bytes(layer: &CompiledLayer) -> usize {
    layer.instrs.len() * crate::isa::INSTR_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn small_prep(sparsity: SparsityConfig, arch: &ArchConfig) -> PreparedLayer {
        let (m, k, n) = (8, 64, 24);
        let w = models::synthesize_weights(7, k, n);
        prepare_layer("t", m, k, n, w, sparsity, arch, quant::requant_mul(0.01), true, None)
    }

    #[test]
    fn prepare_pads_filters_to_alpha() {
        let arch = ArchConfig::db_pim();
        let p = small_prep(SparsityConfig::hybrid(0.5), &arch);
        assert_eq!(p.n, 24); // already multiple of 8
        let p2 = {
            let w = models::synthesize_weights(7, 64, 20);
            prepare_layer("t", 8, 64, 20, w, SparsityConfig::dense(), &arch,
                          quant::requant_mul(0.01), true, None)
        };
        assert_eq!(p2.n, 24);
        assert_eq!(p2.n_logical, 20);
        // padded columns are zero
        for row in 0..64 {
            for col in 20..24 {
                assert_eq!(p2.weights.get(row, col), 0);
            }
        }
    }

    #[test]
    fn prepare_hybrid_weights_are_fta_compliant() {
        let arch = ArchConfig::db_pim();
        let p = small_prep(SparsityConfig::hybrid(0.5), &arch);
        let expand = p.mask.expand();
        for col in 0..p.n {
            let th = p.thresholds[col];
            for row in 0..p.k {
                let w = p.weights.get(row, col);
                if !expand[row * p.n + col] {
                    assert_eq!(w, 0);
                } else if th > 0 {
                    assert_eq!(crate::csd::phi(w), th);
                }
            }
        }
        assert!(p.mask.sparsity() > 0.45);
    }

    #[test]
    fn compile_emits_instructions_ending_with_sync_end() {
        let arch = ArchConfig::db_pim();
        let c = compile_layer(small_prep(SparsityConfig::hybrid(0.5), &arch), &arch);
        assert!(!c.tiles.is_empty());
        let n = c.instrs.len();
        assert_eq!(c.instrs[n - 2], Instr::Sync);
        assert_eq!(c.instrs[n - 1], Instr::EndLayer);
        // every tile gets exactly one LoadTile
        let loads = c.instrs.iter().filter(|i| matches!(i, Instr::LoadTile { .. })).count();
        assert_eq!(loads, c.tiles.len());
    }

    #[test]
    fn compile_network_covers_all_pim_layers() {
        let arch = ArchConfig::db_pim();
        let net = models::resnet18();
        let compiled = compile_network(&net, SparsityConfig::hybrid(0.6), &arch, 1);
        let pim_count = net.layers.iter().filter(|l| l.kind.is_pim()).count();
        assert_eq!(compiled.len(), pim_count);
    }

    #[test]
    fn instr_stream_roundtrips_through_isa() {
        let arch = ArchConfig::db_pim();
        let c = compile_layer(small_prep(SparsityConfig::hybrid(0.6), &arch), &arch);
        let bytes = crate::isa::encode_stream(&c.instrs);
        assert_eq!(crate::isa::decode_stream(&bytes), Some(c.instrs.clone()));
        assert_eq!(instr_bytes(&c), bytes.len());
    }
}
