//! Segmented per-core programs.
//!
//! The flat per-layer instruction stream the original codegen emitted
//! interleaves the 8 cores' work; the hardware, however, runs cores
//! independently between barriers (machine.rs header, DESIGN.md §4).
//! `Program` makes that structure explicit: a sequence of [`Phase`]s,
//! each holding one barrier-free [`Segment`] per active core plus the
//! barrier that closes the phase. The parallel engine (sim::engine)
//! fans a phase's segments out over worker threads and applies the
//! barrier once all of them have drained — bit-identical to walking the
//! flat stream on one thread, because instructions of different cores
//! never touch shared state between barriers.
//!
//! `Program::from_instrs` / `Program::to_instrs` convert between the
//! two representations; `from_instrs(to_instrs(p)) == p` always holds
//! (the flat order within a phase is normalized to ascending core id).

use crate::arch::ArchConfig;
use crate::isa::{Instr, Segment, SimdOp};

use super::packing::{self, Assignment, Tile};
use super::PreparedLayer;

/// The synchronization event that closes a [`Phase`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Barrier {
    /// All cores wait for the slowest (`Instr::Sync`).
    Sync,
    /// All cores wait, then the SIMD core runs `op` over `elems`
    /// (`Instr::Simd`).
    Simd { op: SimdOp, elems: u32 },
    /// End of the layer's stream (`Instr::EndLayer`).
    End,
    /// No barrier instruction: the phase simply ends (trailing
    /// instructions of a stream that is not barrier-terminated).
    Open,
}

impl Barrier {
    /// The instruction this barrier round-trips to (None for `Open`).
    pub fn instr(self) -> Option<Instr> {
        match self {
            Barrier::Sync => Some(Instr::Sync),
            Barrier::Simd { op, elems } => Some(Instr::Simd { op, elems }),
            Barrier::End => Some(Instr::EndLayer),
            Barrier::Open => None,
        }
    }
}

/// One barrier-delimited phase: per-core segments (ascending core id,
/// idle cores omitted) plus the closing barrier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Phase {
    pub segments: Vec<Segment>,
    pub barrier: Barrier,
}

impl Phase {
    /// Instructions in this phase, barrier included.
    pub fn instr_count(&self) -> usize {
        let body: usize = self.segments.iter().map(|s| s.instrs.len()).sum();
        body + usize::from(self.barrier.instr().is_some())
    }
}

/// A compiled layer's full segmented program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program {
    /// Core count the program was partitioned for.
    pub n_cores: usize,
    pub phases: Vec<Phase>,
    /// Kernel routine the simulator runs this program under, selected
    /// at codegen time from the layer's [`packing::KernelShape`]
    /// (`sim::backend::select_kernel`). Sim-side metadata only: every
    /// backend is bit-identical to the `ScalarRef` oracle, so the tag
    /// is *excluded* from `CompileKey`/`SimKey` and is not carried by
    /// the flat/byte encodings (`from_instrs`/`decode` restore the
    /// default).
    pub kernel: crate::sim::backend::BackendKind,
    /// ABFT column checksums of every assignment's *clean* resident
    /// weight block (`arch::faultmap::dyadic_checksums` layout,
    /// `abft[assignment][filter · NUM_BLOCKS + block]`), recorded only
    /// when the arch's cell-fault model is on (DESIGN.md §13). Empty
    /// otherwise — and, like the kernel tag, not carried by the
    /// flat/byte encodings (`from_instrs`/`decode` restore empty), so
    /// the zero-BER roundtrips are bit-identical to a build without
    /// the fault subsystem.
    pub abft: Vec<Vec<u64>>,
}

impl Program {
    /// Partition a flat instruction stream into per-core segments split
    /// at `Sync`/`Simd`/`EndLayer` barriers.
    ///
    /// Panics if an instruction names a core `>= n_cores` (compiler
    /// streams are constructed in-range; untrusted bytes go through
    /// [`Program::decode`], which rejects them instead).
    pub fn from_instrs(instrs: &[Instr], n_cores: usize) -> Program {
        let mut phases = Vec::new();
        let mut pending: Vec<Vec<Instr>> = vec![Vec::new(); n_cores];
        for &instr in instrs {
            match instr {
                Instr::Sync => close_phase(&mut pending, Barrier::Sync, &mut phases),
                Instr::EndLayer => close_phase(&mut pending, Barrier::End, &mut phases),
                Instr::Simd { op, elems } => {
                    close_phase(&mut pending, Barrier::Simd { op, elems }, &mut phases)
                }
                Instr::LoadTile { core, .. }
                | Instr::Compute { core, .. }
                | Instr::Store { core, .. } => pending[core as usize].push(instr),
            }
        }
        if pending.iter().any(|v| !v.is_empty()) {
            close_phase(&mut pending, Barrier::Open, &mut phases);
        }
        Program { n_cores, phases, kernel: Default::default(), abft: Vec::new() }
    }

    /// Flatten back to an instruction stream (segments in ascending
    /// core order within each phase, then the barrier instruction).
    pub fn to_instrs(&self) -> Vec<Instr> {
        let mut out = Vec::with_capacity(self.instr_count());
        for p in &self.phases {
            for s in &p.segments {
                out.extend_from_slice(&s.instrs);
            }
            if let Some(i) = p.barrier.instr() {
                out.push(i);
            }
        }
        out
    }

    /// Total instructions, barriers included.
    pub fn instr_count(&self) -> usize {
        self.phases.iter().map(Phase::instr_count).sum()
    }

    /// Encode to the instruction-buffer byte format (flat framing).
    pub fn encode(&self) -> Vec<u8> {
        crate::isa::encode_stream(&self.to_instrs())
    }

    /// Decode from the instruction-buffer byte format. Rejects streams
    /// naming a core outside `0..n_cores` (corrupted/foreign buffers).
    pub fn decode(bytes: &[u8], n_cores: usize) -> Option<Program> {
        let instrs = crate::isa::decode_stream(bytes)?;
        let in_range = instrs.iter().all(|i| match *i {
            Instr::LoadTile { core, .. }
            | Instr::Compute { core, .. }
            | Instr::Store { core, .. } => (core as usize) < n_cores,
            _ => true,
        });
        in_range.then(|| Program::from_instrs(&instrs, n_cores))
    }
}

fn close_phase(pending: &mut [Vec<Instr>], barrier: Barrier, phases: &mut Vec<Phase>) {
    let segments = pending
        .iter_mut()
        .enumerate()
        .filter(|(_, v)| !v.is_empty())
        .map(|(core, v)| Segment { core: core as u8, instrs: std::mem::take(v) })
        .collect();
    phases.push(Phase { segments, barrier });
}

/// Emit the per-layer segmented program (N-K-M loop order per core,
/// Fig. 9): every tile contributes LoadTile → Compute×chunks → Store to
/// its core's segment; one Sync aligns the cores, then EndLayer.
pub fn codegen(
    prep: &PreparedLayer,
    assignments: &[Assignment],
    tiles: &[Tile],
    arch: &ArchConfig,
) -> Program {
    let m_total = prep.m.max(1);
    let m_chunk = arch.macros_per_core as u32; // Tm rows in flight per core
    let mut per_core: Vec<Vec<Instr>> = vec![Vec::new(); arch.n_cores];
    for (core, tile_ids) in packing::tiles_by_core(assignments, tiles, arch.n_cores)
        .into_iter()
        .enumerate()
    {
        let stream = &mut per_core[core];
        for ti in tile_ids {
            let tile = &tiles[ti];
            stream.push(Instr::LoadTile { core: core as u8, tile: tile.id });
            let mut m = 0u32;
            while (m as usize) < m_total {
                let count = (m_total as u32 - m).min(m_chunk) as u16;
                stream.push(Instr::Compute {
                    core: core as u8,
                    tile: tile.id,
                    m_base: m,
                    m_count: count,
                });
                m += count as u32;
            }
            stream.push(Instr::Store {
                core: core as u8,
                tile: tile.id,
                m_base: 0,
                m_count: m_total.min(u16::MAX as usize) as u16,
            });
        }
    }
    let segments = per_core
        .into_iter()
        .enumerate()
        .filter(|(_, v)| !v.is_empty())
        .map(|(core, instrs)| Segment { core: core as u8, instrs })
        .collect();
    Program {
        n_cores: arch.n_cores,
        phases: vec![
            Phase { segments, barrier: Barrier::Sync },
            Phase { segments: Vec::new(), barrier: Barrier::End },
        ],
        kernel: crate::sim::backend::select_kernel(packing::kernel_shape(
            prep,
            assignments,
            tiles,
        )),
        abft: if arch.cell_faults.enabled() {
            assignments
                .iter()
                .map(|a| crate::arch::faultmap::dyadic_checksums(&a.wblock, a.filters.len()))
                .collect()
        } else {
            Vec::new()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_layer, prepare_layer, SparsityConfig};
    use crate::models::synthesize_weights;
    use crate::quant;

    fn compiled(sparsity: SparsityConfig, arch: &ArchConfig) -> crate::compiler::CompiledLayer {
        let (m, k, n) = (12, 192, 32);
        let w = synthesize_weights(9, k, n);
        let prep =
            prepare_layer("t", m, k, n, w, sparsity, arch, quant::requant_mul(0.01), true, None);
        compile_layer(prep, arch)
    }

    #[test]
    fn program_flat_roundtrip() {
        let arch = ArchConfig::db_pim();
        let c = compiled(SparsityConfig::hybrid(0.5), &arch);
        let flat = c.program.to_instrs();
        assert_eq!(flat, c.instrs, "CompiledLayer.instrs is the flattened program");
        let mut back = Program::from_instrs(&flat, arch.n_cores);
        // the kernel tag is sim-side metadata the flat stream does not
        // carry (Program docs) — normalize before the structural compare
        back.kernel = c.program.kernel;
        assert_eq!(back, c.program);
    }

    #[test]
    fn program_encode_decode_roundtrip() {
        let arch = ArchConfig::db_pim();
        let c = compiled(SparsityConfig::hybrid(0.6), &arch);
        let bytes = c.program.encode();
        // decode restores the default kernel tag (bytes don't carry it)
        let back = Program::decode(&bytes, arch.n_cores).map(|mut p| {
            p.kernel = c.program.kernel;
            p
        });
        assert_eq!(back, Some(c.program.clone()));
    }

    #[test]
    fn decode_rejects_out_of_range_core() {
        let bytes = crate::isa::encode_stream(&[
            Instr::LoadTile { core: 9, tile: 0 },
            Instr::Sync,
            Instr::EndLayer,
        ]);
        assert_eq!(Program::decode(&bytes, 8), None);
        assert!(Program::decode(&bytes, 10).is_some());
    }

    #[test]
    fn segments_are_per_core_and_barrier_free() {
        let arch = ArchConfig::db_pim();
        let c = compiled(SparsityConfig::hybrid(0.3), &arch);
        for phase in &c.program.phases {
            let mut last_core = None;
            for seg in &phase.segments {
                assert!(last_core < Some(seg.core), "segments not ascending by core");
                last_core = Some(seg.core);
                assert!(!seg.instrs.is_empty());
                for i in &seg.instrs {
                    let core = match *i {
                        Instr::LoadTile { core, .. }
                        | Instr::Compute { core, .. }
                        | Instr::Store { core, .. } => core,
                        _ => panic!("barrier inside segment: {i:?}"),
                    };
                    assert_eq!(core, seg.core, "instruction on foreign core");
                }
            }
        }
    }

    #[test]
    fn codegen_ends_with_sync_then_end() {
        let arch = ArchConfig::db_pim();
        let c = compiled(SparsityConfig::dense(), &arch);
        let n = c.program.phases.len();
        assert_eq!(c.program.phases[n - 2].barrier, Barrier::Sync);
        assert_eq!(c.program.phases[n - 1].barrier, Barrier::End);
        assert!(c.program.phases[n - 1].segments.is_empty());
    }

    #[test]
    fn open_barrier_preserves_trailing_instrs() {
        let flat = vec![
            Instr::LoadTile { core: 0, tile: 0 },
            Instr::Sync,
            Instr::LoadTile { core: 1, tile: 1 },
        ];
        let p = Program::from_instrs(&flat, 2);
        assert_eq!(p.phases.len(), 2);
        assert_eq!(p.phases[1].barrier, Barrier::Open);
        assert_eq!(p.to_instrs(), flat);
        assert_eq!(p.instr_count(), 3);
    }

    #[test]
    fn instr_count_matches_flat_length() {
        let arch = ArchConfig::db_pim();
        for sp in [SparsityConfig::dense(), SparsityConfig::hybrid(0.7)] {
            let c = compiled(sp, &arch);
            assert_eq!(c.program.instr_count(), c.instrs.len());
        }
    }
}
