//! Filter-group packing and weight tiling.
//!
//! Under the DBMU mapping (weight_bit_sparsity), each kept weight of
//! filter n occupies exactly φ_th(n) SRAM columns (its Comp.-pattern
//! blocks); an α-group of filters therefore demands Σ φ_th ≤ α·2 = 16
//! columns and fills one macro. Groups whose filters are all zero
//! (φ_th = 0 across the group, or fully pruned) are skipped outright.
//!
//! Under the dense mapping each filter occupies `input_bits` = 8 bit
//! columns, so a 16-column macro holds 2 filters — the conventional
//! digital-PIM arrangement the paper compares against.
//!
//! One macro sees ONE input stream, so all filters in an assignment
//! must share the same coarse-pruning mask — i.e. belong to the same
//! α-group (the allocation-network switch is per core, per group).

use crate::arch::{faultmap, ArchConfig, CellFault, CellFaultSpec, DegradePolicy, FaultMap};
use crate::csd;
use crate::util::ceil_div;

use super::PreparedLayer;

/// A set of filters resident together in one macro (replicated across
/// the Tm macros of the owning core for M-parallelism).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    /// α-group index this assignment draws filters from.
    pub group: usize,
    /// Filter (column) indices, ascending.
    pub filters: Vec<usize>,
    /// Columns occupied per filter (φ_th or 8).
    pub cols_per_filter: Vec<u8>,
    /// K rows actually stored (gathered by the allocation network when
    /// value sparsity is enabled; 0..K otherwise).
    pub kept_rows: Vec<u32>,
    /// Core this assignment is scheduled on.
    pub core: usize,
    /// Compile-time gathered weight block: `[kept_rows × filters]`
    /// row-major i8, `wblock[ri * filters.len() + fi] =
    /// weights[kept_rows[ri]][filters[fi]]` — the dense, contiguous
    /// GEMM operand of the simulator's functional accumulate
    /// (sim::kernels::gemm_accumulate). Filled once per layer after
    /// merging/scheduling settles the filter set.
    pub wblock: Vec<i8>,
    /// Prefix sums of per-kept-row weight-bit popcounts:
    /// `bit_cell_prefix[ri]` = Σ over kept rows `< ri`, over the
    /// assignment's filters, of `popcount(weight as u8)` (length
    /// `kept_rows.len() + 1`, `bit_cell_prefix[0] == 0`). Turns the
    /// simulator's dense effective-cell accounting for any kept-row
    /// range — whole tiles and single compartment steps alike — into
    /// one O(1) prefix subtraction instead of an O(rows × filters)
    /// popcount walk at sim time. Filled with `wblock`.
    pub bit_cell_prefix: Vec<u64>,
}

impl Assignment {
    /// Total macro columns in use.
    pub fn active_cols(&self) -> usize {
        self.cols_per_filter.iter().map(|&c| c as usize).sum()
    }
}

/// One weight tile: a Tk1×Tk2 slice of an assignment's kept rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tile {
    pub id: u32,
    /// Index into the layer's assignment list.
    pub assignment: usize,
    /// Range into `kept_rows` covered by this tile.
    pub row_start: usize,
    pub row_end: usize,
}

impl Tile {
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Pack a prepared layer into assignments and tiles and schedule them
/// across cores (greedy longest-processing-time balancing).
pub fn pack_layer(prep: &PreparedLayer, arch: &ArchConfig) -> (Vec<Assignment>, Vec<Tile>) {
    let mut assignments = Vec::new();
    let groups = prep.mask.groups;
    for g in 0..groups {
        let filters: Vec<usize> = (g * arch.alpha..(g + 1) * arch.alpha).collect();
        // kept K rows for this group
        let kept_rows: Vec<u32> = if arch.value_sparsity {
            (0..prep.k).filter(|&k| prep.mask.kept(k, g)).map(|k| k as u32).collect()
        } else {
            (0..prep.k as u32).collect()
        };
        if kept_rows.is_empty() {
            continue; // group fully pruned
        }
        if arch.weight_bit_sparsity {
            // Each filter needs φ_th columns; drop φ_th = 0 filters.
            // With FTA (φ_th ≤ 2, α = 8) a whole group always fits one
            // macro; without FTA (ablation runs) per-filter demand can
            // reach 4 columns, so chunk filters to the column budget.
            let live: Vec<usize> =
                filters.iter().copied().filter(|&n| prep.thresholds[n] > 0).collect();
            if live.is_empty() {
                continue;
            }
            let mut chunk: Vec<usize> = Vec::new();
            let mut cols: Vec<u8> = Vec::new();
            let mut demand = 0usize;
            for &f in &live {
                let c = prep.thresholds[f].min(crate::csd::MAX_PHI) as usize;
                if demand + c > arch.macro_columns && !chunk.is_empty() {
                    assignments.push(Assignment {
                        group: g,
                        filters: std::mem::take(&mut chunk),
                        cols_per_filter: std::mem::take(&mut cols),
                        kept_rows: kept_rows.clone(),
                        core: 0,
                        wblock: Vec::new(),
                        bit_cell_prefix: Vec::new(),
                    });
                    demand = 0;
                }
                chunk.push(f);
                cols.push(c as u8);
                demand += c;
            }
            assignments.push(Assignment {
                group: g,
                filters: chunk,
                cols_per_filter: cols,
                kept_rows,
                core: 0,
                wblock: Vec::new(),
                bit_cell_prefix: Vec::new(),
            });
        } else {
            // dense mapping: pairs of filters, 8 bit-columns each
            let per_macro = arch.dense_filters_per_macro();
            for chunk in filters.chunks(per_macro) {
                assignments.push(Assignment {
                    group: g,
                    filters: chunk.to_vec(),
                    cols_per_filter: vec![arch.input_bits as u8; chunk.len()],
                    kept_rows: kept_rows.clone(),
                    core: 0,
                    wblock: Vec::new(),
                    bit_cell_prefix: Vec::new(),
                });
            }
        }
    }

    // Merge assignments that can share a macro: combined column demand
    // within budget AND identical input streams (same kept-row gather —
    // one macro broadcasts a single input stream to all compartments).
    // This is how the paper reaches "up to 16 filters per macro with
    // φ_th = 1": low-threshold groups double up whenever their masks
    // agree (always true without value sparsity).
    if arch.weight_bit_sparsity && arch.merge_groups {
        merge_compatible(&mut assignments, arch.macro_columns);
    }

    schedule_cores(&mut assignments, arch);

    // Gather each assignment's dense weight block now that merging and
    // scheduling have settled the filter sets (the simulator's
    // functional accumulate runs a contiguous micro-GEMM over it
    // instead of an indirect gather per MAC). Perf-only runs never read
    // it; the cost is one extra ~K×N i8 copy of the layer's weights,
    // accepted so the block is compile-time state shared by every
    // executor and cache consumer. The bit-cell prefix sums ride along:
    // one popcount pass here replaces every sim-time dense
    // effective-cell walk with a prefix subtraction.
    for a in &mut assignments {
        a.wblock = gather_weight_block(prep, &a.kept_rows, &a.filters);
        a.bit_cell_prefix = bit_cell_prefix(&a.wblock, a.filters.len());
    }

    // K tiling: Tk1 × Tk2 row slots per macro.
    let tiles = tile_assignments(&assignments, arch.k_slots());
    (assignments, tiles)
}

/// Spread assignments over the cores under the arch's scheduling
/// policy. Shared by [`pack_layer`] and the multi-chip sharding layer,
/// which re-schedules a chip-local assignment subset with the same
/// policy (coordinator::sharding).
pub(crate) fn schedule_cores(assignments: &mut [Assignment], arch: &ArchConfig) {
    match arch.schedule {
        crate::arch::SchedulePolicy::Lpt => schedule(assignments, arch.n_cores),
        crate::arch::SchedulePolicy::RoundRobin => {
            for (i, a) in assignments.iter_mut().enumerate() {
                a.core = i % arch.n_cores;
            }
        }
    }
}

/// K tiling: split each assignment's kept rows into `slots`-row tiles
/// (Tk1 × Tk2 row slots per macro), ids ascending in assignment order.
/// Shared by [`pack_layer`] and the sharding layer's chip-local
/// re-tiling.
pub(crate) fn tile_assignments(assignments: &[Assignment], slots: usize) -> Vec<Tile> {
    let mut tiles = Vec::new();
    let mut id = 0u32;
    for (ai, a) in assignments.iter().enumerate() {
        let n_tiles = ceil_div(a.kept_rows.len(), slots);
        for t in 0..n_tiles {
            let row_start = t * slots;
            let row_end = ((t + 1) * slots).min(a.kept_rows.len());
            tiles.push(Tile { id, assignment: ai, row_start, row_end });
            id += 1;
        }
    }
    tiles
}

/// Shape class of one compiled layer's kernel workload, summarized for
/// routine selection (`sim::backend::select_kernel`): the M dimension
/// every scan and GEMM runs over, the widest filter block any
/// assignment feeds the micro-GEMM, and the tallest tile row count any
/// occupancy scan walks. The selector buckets the fields by log2, so
/// near-identical sweep layers share one memoized routine choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelShape {
    /// Input rows (M): scan rows per step, GEMM calls per tile chunk.
    pub m: usize,
    /// Widest `Assignment::filters` — the GEMM's inner output width.
    pub max_filters: usize,
    /// Tallest `Tile::rows()` — the scan's step-window upper bound.
    pub max_tile_rows: usize,
}

/// The [`KernelShape`] of a packed layer (0 fields for empty layers —
/// the selector treats those as the smallest bucket).
pub fn kernel_shape(
    prep: &PreparedLayer,
    assignments: &[Assignment],
    tiles: &[Tile],
) -> KernelShape {
    KernelShape {
        m: prep.m,
        max_filters: assignments.iter().map(|a| a.filters.len()).max().unwrap_or(0),
        max_tile_rows: tiles.iter().map(Tile::rows).max().unwrap_or(0),
    }
}

/// Gather the `[kept × filters]` row-major dense weight block of one
/// assignment from the prepared layer's [K, N] matrix.
pub fn gather_weight_block(prep: &PreparedLayer, kept: &[u32], filters: &[usize]) -> Vec<i8> {
    let mut w = Vec::with_capacity(kept.len() * filters.len());
    for &k in kept {
        let row = prep.weights.row(k as usize);
        for &f in filters {
            w.push(row[f]);
        }
    }
    w
}

/// Prefix sums of per-kept-row weight-bit popcounts over a gathered
/// `[rows × nf]` weight block (see [`Assignment::bit_cell_prefix`]).
/// Popcounts are taken over the i8 bit patterns (`w as u8`), matching
/// the simulator's stored-cell accounting for the dense mapping.
pub fn bit_cell_prefix(wblock: &[i8], nf: usize) -> Vec<u64> {
    let rows = if nf == 0 { 0 } else { wblock.len() / nf };
    let mut prefix = Vec::with_capacity(rows + 1);
    let mut acc = 0u64;
    prefix.push(acc);
    for row in wblock.chunks_exact(nf.max(1)).take(rows) {
        for &w in row {
            acc += u64::from((w as u8).count_ones());
        }
        prefix.push(acc);
    }
    prefix
}

/// First-fit-decreasing merge of column-compatible assignments.
fn merge_compatible(assignments: &mut Vec<Assignment>, budget: usize) {
    let mut order: Vec<usize> = (0..assignments.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(assignments[i].active_cols()));
    let mut merged: Vec<Assignment> = Vec::with_capacity(assignments.len());
    for idx in order {
        let a = &assignments[idx];
        if let Some(host) = merged.iter_mut().find(|h| {
            h.active_cols() + a.active_cols() <= budget && h.kept_rows == a.kept_rows
        }) {
            host.filters.extend_from_slice(&a.filters);
            host.cols_per_filter.extend_from_slice(&a.cols_per_filter);
        } else {
            merged.push(a.clone());
        }
    }
    *assignments = merged;
}

/// Greedy LPT schedule: heaviest assignment (by kept rows × columns) to
/// the least-loaded core. Deterministic.
fn schedule(assignments: &mut [Assignment], n_cores: usize) {
    let mut order: Vec<usize> = (0..assignments.len()).collect();
    let cost = |a: &Assignment| (a.kept_rows.len() * a.active_cols()) as u64;
    order.sort_by_key(|&i| std::cmp::Reverse((cost(&assignments[i]), i)));
    let mut load = vec![0u64; n_cores];
    for idx in order {
        let core = (0..n_cores).min_by_key(|&c| (load[c], c)).unwrap();
        assignments[idx].core = core;
        load[core] += cost(&assignments[idx]);
    }
}

/// Tile indices grouped per core, preserving global tile order — the
/// walk order of the segmented codegen when emitting per-core streams.
/// Tiles of one assignment stay contiguous (they are generated that
/// way), which lets the executor cache one occupancy table per
/// assignment at a time.
pub fn tiles_by_core(
    assignments: &[Assignment],
    tiles: &[Tile],
    n_cores: usize,
) -> Vec<Vec<usize>> {
    let mut by_core: Vec<Vec<usize>> = vec![Vec::new(); n_cores];
    for (ti, t) in tiles.iter().enumerate() {
        by_core[assignments[t.assignment].core].push(ti);
    }
    by_core
}

// ---------------------------------------------------------------------
// Compile-time repair + fault application (DESIGN.md §13)
// ---------------------------------------------------------------------

/// Column repair of one physical macro chosen for a replica slot:
/// where each logical column actually lives after the repair pass
/// steered it away from stuck cells.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MacroRepair {
    /// Physical macro backing this replica slot (may be a spare).
    pub phys_macro: usize,
    /// Logical column → physical column (len `macro_columns`).
    pub col_map: Vec<u16>,
    /// Logical columns left on stuck physical columns because the
    /// spare budget ran out; ascending.
    pub stuck_logical: Vec<u16>,
}

/// Repaired physical placement of the whole macro grid: one
/// [`MacroRepair`] per (core, replica slot).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RepairPlan {
    /// `slots[core][slot]`, `n_cores × macros_per_core`.
    pub slots: Vec<Vec<MacroRepair>>,
    pub report: RepairReport,
}

/// Aggregate outcome of the repair pass over the whole grid.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RepairReport {
    /// Stuck physical columns among the primary (non-spare) columns of
    /// the macros actually used.
    pub stuck_columns: u64,
    /// Logical columns steered off a stuck physical column onto a
    /// clean one (spare-column repair).
    pub repaired_columns: u64,
    /// Logical columns that still sit on a stuck physical column
    /// (spares exhausted; runtime corruption + ABFT must catch them).
    pub unrepairable_columns: u64,
    /// Replica slots served by a spare macro instead of a primary.
    pub spared_macros: u64,
}

/// Compile-time repair: for every (core, replica slot), pick the
/// physical macro (primary or spare) with the fewest unmappable
/// columns, then map the `macro_columns` logical columns onto its
/// clean physical columns in ascending order, spilling into the spare
/// columns as needed. Stuck cells are *known* at compile time
/// (post-manufacturing test); transient upsets are not, so they stay
/// invisible here and only ABFT detection sees them. Pure in
/// `(arch.cell_faults, arch geometry)` — schedule/layer independent —
/// and `None` when the fault model is off.
pub fn plan_repair(arch: &ArchConfig) -> Option<RepairPlan> {
    if !arch.cell_faults.enabled() {
        return None;
    }
    let fm = FaultMap::new(arch.cell_faults);
    let comps = arch.compartments;
    let rows = arch.rows_per_compartment;
    let phys_cols = arch.macro_columns + arch.spare_columns_per_macro;
    let phys_macros = arch.macros_per_core + arch.spare_macros_per_core;
    let mut report = RepairReport::default();
    let mut slots = Vec::with_capacity(arch.n_cores);
    for core in 0..arch.n_cores {
        // stuck-column scan of every candidate macro of the core
        let stuck: Vec<Vec<bool>> = (0..phys_macros)
            .map(|pm| (0..phys_cols).map(|pc| fm.column_stuck(core, pm, pc, comps, rows)).collect())
            .collect();
        // deficit: logical columns a macro cannot host on clean cells
        let deficit = |pm: usize| {
            let clean = stuck[pm].iter().filter(|&&s| !s).count();
            arch.macro_columns.saturating_sub(clean)
        };
        let mut order: Vec<usize> = (0..phys_macros).collect();
        order.sort_by_key(|&pm| (deficit(pm), stuck[pm].iter().filter(|&&s| s).count(), pm));
        let mut chosen: Vec<usize> = order[..arch.macros_per_core].to_vec();
        chosen.sort_unstable(); // replica slots keep ascending physical order
        let mut core_slots = Vec::with_capacity(arch.macros_per_core);
        for &pm in &chosen {
            if pm >= arch.macros_per_core {
                report.spared_macros += 1;
            }
            let primary_stuck =
                stuck[pm][..arch.macro_columns].iter().filter(|&&s| s).count() as u64;
            report.stuck_columns += primary_stuck;
            let mut col_map = Vec::with_capacity(arch.macro_columns);
            let mut stuck_logical = Vec::new();
            let mut clean_iter = (0..phys_cols).filter(|&pc| !stuck[pm][pc]);
            let mut stuck_iter = (0..phys_cols).filter(|&pc| stuck[pm][pc]);
            for lc in 0..arch.macro_columns {
                match clean_iter.next() {
                    Some(pc) => col_map.push(pc as u16),
                    None => {
                        // spares exhausted: park the remaining logical
                        // columns on stuck cells, lowest index first
                        let pc = stuck_iter.next().expect("phys_cols >= macro_columns");
                        col_map.push(pc as u16);
                        stuck_logical.push(lc as u16);
                    }
                }
            }
            report.repaired_columns += primary_stuck.saturating_sub(stuck_logical.len() as u64);
            report.unrepairable_columns += stuck_logical.len() as u64;
            core_slots.push(MacroRepair { phys_macro: pm, col_map, stuck_logical });
        }
        slots.push(core_slots);
    }
    Some(RepairPlan { slots, report })
}

/// Residual fault state of one resident replica macro of one
/// assignment after repair.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaFault {
    /// Replica slot (serves input rows `m ≡ slot (mod Tm)` — codegen's
    /// Compute chunks are Tm-aligned).
    pub slot: usize,
    /// Faulty cells that landed on occupied resident weight slots and
    /// changed the stored value.
    pub injected: u64,
    /// Mismatched `(filter, dyadic block)` ABFT checksum pairs.
    pub detections: u64,
    /// Distinct filters among the mismatches (Recompute charge unit).
    pub detected_filters: u64,
    /// Effective resident block under the layer's degrade policy;
    /// `None` ⇒ the clean block (Recompute restores it bit-exactly).
    pub wblock: Option<Vec<i8>>,
}

/// Residual fault state of one assignment (only replicas whose
/// resident block was actually corrupted are listed).
#[derive(Debug, Clone, PartialEq)]
pub struct AssignmentFaults {
    pub replicas: Vec<ReplicaFault>,
}

/// Compile-side outcome of the whole fault pass for one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerFaults {
    pub spec: CellFaultSpec,
    pub policy: DegradePolicy,
    pub report: RepairReport,
    /// Total corrupted resident cells over assignments × replicas.
    pub injected: u64,
    /// Total ABFT `(filter, block)` mismatches; the runtime raises this
    /// many detection events per full verification of the layer.
    pub detections: u64,
    /// Indexed by assignment; `None` ⇒ clean in every replica.
    pub by_assignment: Vec<Option<AssignmentFaults>>,
}

/// Apply the arch's cell-fault model to a packed layer: plan the
/// repair, map every assignment's resident cells to physical cells
/// through it, corrupt the weights that landed on residual faulty
/// cells ([`faultmap::corrupt_weight`]), verify the recorded ABFT
/// checksums (`abft`, from `Program::abft`) against each corrupted
/// block, and materialize the effective per-replica blocks under the
/// degrade policy. `None` when the fault model is off — the zero-BER
/// pipeline never allocates a byte here.
pub fn apply_cell_faults(
    assignments: &[Assignment],
    abft: &[Vec<u64>],
    arch: &ArchConfig,
) -> Option<LayerFaults> {
    let plan = plan_repair(arch)?;
    let fm = FaultMap::new(arch.cell_faults);
    let comps = arch.compartments;
    let slots_k = arch.k_slots();
    let phys_cols = arch.macro_columns + arch.spare_columns_per_macro;
    let phys_macros = arch.macros_per_core + arch.spare_macros_per_core;
    // Per-(core, phys macro) cell-verdict grid, indexed pc·k_slots + rt
    // where rt = kept-row index mod k_slots ⇔ (compartment, SRAM row):
    // one hash pass here makes the per-assignment walk hash-free.
    let grid: Vec<Vec<Option<CellFault>>> = (0..arch.n_cores * phys_macros)
        .map(|cm| {
            let (core, pm) = (cm / phys_macros, cm % phys_macros);
            (0..phys_cols * slots_k)
                .map(|i| {
                    let (pc, rt) = (i / slots_k, i % slots_k);
                    fm.cell(core, pm, rt % comps, rt / comps, pc)
                })
                .collect()
        })
        .collect();
    let policy = arch.fault_degrade;
    let mut injected_total = 0u64;
    let mut detections_total = 0u64;
    let mut by_assignment = Vec::with_capacity(assignments.len());
    for (ai, a) in assignments.iter().enumerate() {
        let nf = a.filters.len();
        let clean_sums = &abft[ai];
        // logical column start of each filter slot
        let mut col_starts = Vec::with_capacity(nf);
        let mut start = 0usize;
        for &c in &a.cols_per_filter {
            col_starts.push(start);
            start += c as usize;
        }
        let mut replicas = Vec::new();
        for slot in 0..arch.macros_per_core {
            let mr = &plan.slots[a.core][slot];
            let cells = &grid[a.core * phys_macros + mr.phys_macro];
            let mut wblock = a.wblock.clone();
            let mut injected = 0u64;
            for (fi, &cs) in col_starts.iter().enumerate() {
                for jj in 0..a.cols_per_filter[fi] as usize {
                    let pc = mr.col_map[cs + jj] as usize;
                    let col_cells = &cells[pc * slots_k..(pc + 1) * slots_k];
                    for r in 0..a.kept_rows.len() {
                        if let Some(kind) = col_cells[r % slots_k] {
                            let w = wblock[r * nf + fi];
                            let c = faultmap::corrupt_weight(w, jj, arch.weight_bit_sparsity, kind);
                            if c != w {
                                wblock[r * nf + fi] = c;
                                injected += 1;
                            }
                        }
                    }
                }
            }
            if wblock == a.wblock {
                continue; // clean replica
            }
            // honest ABFT verification: re-derive the corrupted block's
            // checksums and compare against the recorded clean sums
            let bad_sums = faultmap::dyadic_checksums(&wblock, nf);
            let mut flagged = vec![false; nf * csd::NUM_BLOCKS];
            let mut detections = 0u64;
            for (i, (b, c)) in bad_sums.iter().zip(clean_sums.iter()).enumerate() {
                if b != c {
                    flagged[i] = true;
                    detections += 1;
                }
            }
            let filter_hit = |f: usize| (0..csd::NUM_BLOCKS).any(|k| flagged[f * csd::NUM_BLOCKS + k]);
            let detected_filters = (0..nf).filter(|&f| filter_hit(f)).count() as u64;
            injected_total += injected;
            detections_total += detections;
            let eff = match policy {
                DegradePolicy::Fail => Some(wblock),
                DegradePolicy::Recompute => None,
                DegradePolicy::Mask => {
                    // zero the flagged dyadic-block contributions of
                    // every flagged filter, row by row
                    let mut m = wblock;
                    for r in 0..a.kept_rows.len() {
                        for f in (0..nf).filter(|&f| filter_hit(f)) {
                            let coeffs = csd::dyadic_blocks(m[r * nf + f]);
                            let mut v = 0i32;
                            for (k, &c) in coeffs.iter().enumerate() {
                                if !flagged[f * csd::NUM_BLOCKS + k] {
                                    v += (c as i32) << (2 * k);
                                }
                            }
                            m[r * nf + f] = v.clamp(-128, 127) as i8;
                        }
                    }
                    Some(m)
                }
            };
            replicas.push(ReplicaFault { slot, injected, detections, detected_filters, wblock: eff });
        }
        by_assignment.push((!replicas.is_empty()).then_some(AssignmentFaults { replicas }));
    }
    Some(LayerFaults {
        spec: arch.cell_faults,
        policy,
        report: plan.report,
        injected: injected_total,
        detections: detections_total,
        by_assignment,
    })
}

/// U_act upper bound from the packing alone (column occupancy).
pub fn packing_utilization(assignments: &[Assignment], arch: &ArchConfig) -> f64 {
    if assignments.is_empty() {
        return 0.0;
    }
    let used: usize = assignments.iter().map(|a| a.active_cols()).sum();
    used as f64 / (assignments.len() * arch.macro_columns) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{prepare_layer, SparsityConfig};
    use crate::models::synthesize_weights;
    use crate::quant;

    fn prep(k: usize, n: usize, sparsity: SparsityConfig, arch: &ArchConfig) -> PreparedLayer {
        let w = synthesize_weights(3, k, n);
        prepare_layer("t", 4, k, n, w, sparsity, arch, quant::requant_mul(0.01), true, None)
    }

    #[test]
    fn dbpim_packs_one_group_per_assignment() {
        let arch = ArchConfig::db_pim();
        let p = prep(128, 32, SparsityConfig::hybrid(0.0), &arch);
        let (asg, tiles) = pack_layer(&p, &arch);
        assert!(asg.len() <= 4); // 32 filters / α=8 (fewer after merging)
        let mut seen = std::collections::HashSet::new();
        for a in &asg {
            assert!(a.active_cols() <= arch.macro_columns);
            assert!(!a.filters.is_empty());
            for &f in &a.filters {
                assert!(seen.insert(f), "filter {f} packed twice");
            }
        }
        assert!(!tiles.is_empty());
    }

    #[test]
    fn dense_packs_two_filters_per_assignment() {
        let arch = ArchConfig::dense_baseline();
        let p = prep(64, 16, SparsityConfig::dense(), &arch);
        let (asg, _) = pack_layer(&p, &arch);
        assert_eq!(asg.len(), 8); // 16 filters / 2
        for a in &asg {
            assert_eq!(a.filters.len(), 2);
            assert_eq!(a.active_cols(), 16);
        }
    }

    #[test]
    fn value_sparsity_shrinks_kept_rows() {
        let arch = ArchConfig::db_pim();
        let p = prep(256, 16, SparsityConfig::hybrid(0.6), &arch);
        let (asg, _) = pack_layer(&p, &arch);
        for a in &asg {
            assert!(a.kept_rows.len() < 256, "rows {}", a.kept_rows.len());
            // kept rows are exactly the unpruned ones for the group
            for &r in &a.kept_rows {
                assert!(p.mask.kept(r as usize, a.group));
            }
        }
        // baseline arch ignores the mask
        let arch_b = ArchConfig::dense_baseline();
        let (asg_b, _) = pack_layer(&p, &arch_b);
        for a in &asg_b {
            assert_eq!(a.kept_rows.len(), 256);
        }
    }

    #[test]
    fn tiles_cover_all_kept_rows_exactly() {
        let arch = ArchConfig::db_pim();
        let p = prep(1000, 24, SparsityConfig::hybrid(0.3), &arch);
        let (asg, tiles) = pack_layer(&p, &arch);
        for (ai, a) in asg.iter().enumerate() {
            let mut covered = 0;
            for t in tiles.iter().filter(|t| t.assignment == ai) {
                assert!(t.rows() <= arch.k_slots());
                covered += t.rows();
            }
            assert_eq!(covered, a.kept_rows.len());
        }
    }

    #[test]
    fn schedule_balances_cores() {
        let arch = ArchConfig::db_pim();
        let p = prep(512, 128, SparsityConfig::hybrid(0.5), &arch);
        let (asg, _) = pack_layer(&p, &arch);
        let mut loads = vec![0u64; arch.n_cores];
        for a in &asg {
            loads[a.core] += (a.kept_rows.len() * a.active_cols()) as u64;
        }
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(min > 0.0, "idle core with 16 groups");
        assert!(max / min.max(1.0) < 2.0, "imbalance {loads:?}");
    }

    #[test]
    fn tiles_by_core_partitions_all_tiles_in_order() {
        let arch = ArchConfig::db_pim();
        let p = prep(512, 64, SparsityConfig::hybrid(0.4), &arch);
        let (asg, tiles) = pack_layer(&p, &arch);
        let by_core = tiles_by_core(&asg, &tiles, arch.n_cores);
        let mut seen = vec![false; tiles.len()];
        for (core, tis) in by_core.iter().enumerate() {
            // global tile order preserved within a core
            assert!(tis.windows(2).all(|w| w[0] < w[1]));
            for &ti in tis {
                assert_eq!(asg[tiles[ti].assignment].core, core);
                assert!(!seen[ti], "tile {ti} in two cores");
                seen[ti] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "tile missing from partition");
    }

    #[test]
    fn wblock_gathers_kept_rows_by_filter_slot() {
        for arch in [ArchConfig::db_pim(), ArchConfig::dense_baseline()] {
            let p = prep(300, 32, SparsityConfig::hybrid(0.5), &arch);
            let (asg, _) = pack_layer(&p, &arch);
            for a in &asg {
                let nf = a.filters.len();
                assert_eq!(a.wblock.len(), a.kept_rows.len() * nf);
                for (ri, &k) in a.kept_rows.iter().enumerate() {
                    for (fi, &f) in a.filters.iter().enumerate() {
                        assert_eq!(
                            a.wblock[ri * nf + fi],
                            p.weights.get(k as usize, f),
                            "row {k} filter {f} on {}",
                            arch.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bit_cell_prefix_matches_direct_popcount_walk() {
        for arch in [ArchConfig::db_pim(), ArchConfig::dense_baseline()] {
            let p = prep(300, 32, SparsityConfig::hybrid(0.5), &arch);
            let (asg, _) = pack_layer(&p, &arch);
            for a in &asg {
                assert_eq!(a.bit_cell_prefix.len(), a.kept_rows.len() + 1);
                assert_eq!(a.bit_cell_prefix[0], 0);
                // every prefix entry equals the direct popcount walk
                // over the prepared weights (not just wblock)
                let mut acc = 0u64;
                for (ri, &k) in a.kept_rows.iter().enumerate() {
                    for &f in &a.filters {
                        acc += u64::from((p.weights.get(k as usize, f) as u8).count_ones());
                    }
                    assert_eq!(a.bit_cell_prefix[ri + 1], acc, "row {ri} on {}", arch.name);
                }
                // prefix is non-decreasing
                assert!(a.bit_cell_prefix.windows(2).all(|w| w[0] <= w[1]));
            }
        }
    }

    #[test]
    fn kernel_shape_summarizes_packing_geometry() {
        let arch = ArchConfig::db_pim();
        let p = prep(512, 64, SparsityConfig::hybrid(0.4), &arch);
        let (asg, tiles) = pack_layer(&p, &arch);
        let s = kernel_shape(&p, &asg, &tiles);
        assert_eq!(s.m, p.m);
        assert_eq!(s.max_filters, asg.iter().map(|a| a.filters.len()).max().unwrap());
        assert_eq!(s.max_tile_rows, tiles.iter().map(Tile::rows).max().unwrap());
        assert!(s.max_tile_rows <= arch.k_slots());
        // empty packing → zeroed shape (smallest selector bucket)
        let e = kernel_shape(&p, &[], &[]);
        assert_eq!((e.max_filters, e.max_tile_rows), (0, 0));
    }

    #[test]
    fn utilization_higher_for_dbpim_than_unused_columns() {
        let arch = ArchConfig::db_pim();
        let p = prep(128, 64, SparsityConfig::hybrid(0.0), &arch);
        let (asg, _) = pack_layer(&p, &arch);
        let u = packing_utilization(&asg, &arch);
        assert!(u > 0.5, "packing utilization {u}");
    }

    fn faulty_arch(ber: f64, seed: u64) -> ArchConfig {
        ArchConfig { cell_faults: CellFaultSpec::uniform(ber, seed), ..ArchConfig::db_pim() }
    }

    #[test]
    fn plan_repair_off_spec_is_none() {
        assert!(plan_repair(&ArchConfig::db_pim()).is_none());
        let asg: Vec<Assignment> = Vec::new();
        assert!(apply_cell_faults(&asg, &[], &ArchConfig::db_pim()).is_none());
    }

    #[test]
    fn plan_repair_avoids_stuck_columns_within_budget() {
        // a BER high enough to guarantee stuck columns, low enough
        // that the spare budget usually covers them
        let arch = faulty_arch(2e-4, 21);
        let fm = FaultMap::new(arch.cell_faults);
        let plan = plan_repair(&arch).unwrap();
        assert_eq!(plan.slots.len(), arch.n_cores);
        let phys_cols = arch.macro_columns + arch.spare_columns_per_macro;
        let phys_macros = arch.macros_per_core + arch.spare_macros_per_core;
        for (core, slots) in plan.slots.iter().enumerate() {
            assert_eq!(slots.len(), arch.macros_per_core);
            for mr in slots {
                assert!(mr.phys_macro < phys_macros, "macro beyond spare budget");
                assert_eq!(mr.col_map.len(), arch.macro_columns);
                // col_map is injective and within the physical budget
                let mut seen = vec![false; phys_cols];
                for (lc, &pc) in mr.col_map.iter().enumerate() {
                    let pc = pc as usize;
                    assert!(pc < phys_cols, "column beyond spare budget");
                    assert!(!seen[pc], "physical column mapped twice");
                    seen[pc] = true;
                    let stuck = fm.column_stuck(
                        core,
                        mr.phys_macro,
                        pc,
                        arch.compartments,
                        arch.rows_per_compartment,
                    );
                    // a mapped column is stuck only if the plan says so
                    assert_eq!(stuck, mr.stuck_logical.contains(&(lc as u16)));
                }
            }
        }
        assert!(plan.report.repaired_columns > 0, "BER 2e-4 must repair something");
        assert_eq!(plan.report.unrepairable_columns, 0, "spares must cover BER 2e-4");
        // the plan is pure: replanning yields the identical placement
        assert_eq!(plan, plan_repair(&arch).unwrap());
    }

    #[test]
    fn zero_spares_keep_identity_mapping_when_clean() {
        // with no spare budget a fault-free macro maps identically
        let mut arch = faulty_arch(0.0, 3);
        arch.cell_faults.ber_transient = 1e-4; // enabled, but no stuck cells
        arch.spare_columns_per_macro = 0;
        arch.spare_macros_per_core = 0;
        let plan = plan_repair(&arch).unwrap();
        for (slot, mr) in plan.slots[0].iter().enumerate() {
            assert_eq!(mr.phys_macro, slot);
            let identity: Vec<u16> = (0..arch.macro_columns as u16).collect();
            assert_eq!(mr.col_map, identity);
            assert!(mr.stuck_logical.is_empty());
        }
        assert_eq!(plan.report, RepairReport::default());
    }

    #[test]
    fn apply_cell_faults_detects_every_corruption() {
        // transient-heavy spec: repair can't help, ABFT must see all
        let mut arch = faulty_arch(0.0, 17);
        arch.cell_faults.ber_transient = 5e-3;
        arch.fault_degrade = DegradePolicy::Fail;
        let p = prep(512, 32, SparsityConfig::hybrid(0.4), &arch);
        let (asg, _) = pack_layer(&p, &arch);
        let abft: Vec<Vec<u64>> = asg
            .iter()
            .map(|a| faultmap::dyadic_checksums(&a.wblock, a.filters.len()))
            .collect();
        let lf = apply_cell_faults(&asg, &abft, &arch).unwrap();
        assert!(lf.injected > 0, "5e-3 transient BER must corrupt something");
        assert!(lf.detections > 0);
        for af in lf.by_assignment.iter().flatten() {
            for r in &af.replicas {
                assert!(r.slot < arch.macros_per_core);
                assert!(r.injected > 0);
                assert!(r.detections > 0, "corrupted replica escaped ABFT");
                assert!(r.detected_filters > 0);
                // policy Fail keeps the corrupted block
                assert!(r.wblock.is_some());
            }
        }
    }

    #[test]
    fn recompute_policy_restores_clean_blocks() {
        let mut arch = faulty_arch(1e-3, 29);
        arch.fault_degrade = DegradePolicy::Recompute;
        let p = prep(512, 32, SparsityConfig::hybrid(0.4), &arch);
        let (asg, _) = pack_layer(&p, &arch);
        let abft: Vec<Vec<u64>> = asg
            .iter()
            .map(|a| faultmap::dyadic_checksums(&a.wblock, a.filters.len()))
            .collect();
        let lf = apply_cell_faults(&asg, &abft, &arch).unwrap();
        for af in lf.by_assignment.iter().flatten() {
            for r in &af.replicas {
                assert!(r.wblock.is_none(), "Recompute must restore the clean block");
            }
        }
    }
}
