//! Experiment drivers — one per paper table/figure (DESIGN.md §5).
//! Shared by the `dbpim` CLI (`dbpim fig11` …) and the bench targets in
//! `rust/benches/`, so the same code regenerates every reported row.
//!
//! Every driver is a declarative [`SweepSpec`]: a list of axis cells
//! (e.g. network × sparsity point), a job function mapping one cell to
//! one row, and an optional merge over the collected rows. One generic
//! executor ([`SweepSpec::run`]) owns the sweep-wide [`CompileCache`]
//! and its hit/miss counters, fans the cells out over the shared
//! `coordinator::pool`, and returns rows in axis order — bit-identical
//! for any worker count, steal order, `DBPIM_ENGINE` choice, or
//! `DBPIM_KERNEL` backend selection (the sim::backend oracle rule).
//!
//! Parallelism nests: a sweep cell's simulation fans out across layers,
//! and each layer across core segments, all into the same pool (nested
//! scopes execute or steal child jobs instead of spawning threads — no
//! oversubscription, no "one level at a time" restriction). Set
//! `DBPIM_ENGINE=sequential` to force every level serial for A/B
//! timing; rows are bit-identical either way.
//!
//! Combinations repeated across sweep points — e.g. fig11's dense
//! baseline, identical at all four sparsity points — compile once via
//! the shared `CompileCache` and simulate once via the shared
//! `SimCache` (repeated cells skip simulation entirely); the
//! `*_with_stats` variants surface both hit/miss counters for the
//! driver summaries.

use crate::arch::{ArchConfig, CellFaultSpec, DegradePolicy};
use crate::compiler::{packing, CacheStats, CompileCache, SparsityConfig};
use crate::json::{arr, num, obj, str_, Value};
use crate::models::{self, Network};
use crate::sim::{self, Engine, Machine, OpCategory, SimCache, SimReport};
use crate::stats;
use crate::tensor::MatI8;

use super::pool;
use super::sharding::{self, ShardReport, ShardSpec};

/// `DBPIM_ENGINE` override (spelling per `Engine::parse`); shared with
/// the serving frontend (`coordinator::serve`).
pub(crate) fn env_engine() -> Option<Engine> {
    std::env::var("DBPIM_ENGINE").ok().and_then(|s| Engine::parse(&s))
}

/// Hit/miss counters of one sweep's two memo layers: compiles
/// deduplicated by the [`CompileCache`], whole per-layer simulations
/// deduplicated by the [`SimCache`]. Printed by the CLI drivers as the
/// sweep summary lines.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepStats {
    pub compile: CacheStats,
    pub sim: CacheStats,
}

/// Per-sweep shared context handed to every job: the sweep-wide compile
/// and simulation caches, and the engine the sweep's simulations run
/// under.
pub struct SweepCtx {
    /// Content-keyed compile memo shared by all cells of the sweep.
    pub cache: CompileCache,
    /// Content-keyed per-layer simulation memo shared by all cells —
    /// repeated cells (e.g. a figure's dense baseline) skip simulation
    /// entirely.
    pub sim: SimCache,
    engine: Engine,
    /// `DBPIM_CHIPS`/`DBPIM_SCHEME` fleet override: when set, every
    /// cell simulation routes through the sharding layer (CI's
    /// `chips=1` golden-equivalence leg relies on the `chips == 1`
    /// delegation being bit-identical).
    shard: Option<ShardSpec>,
}

impl SweepCtx {
    fn new() -> Self {
        SweepCtx {
            cache: CompileCache::new(),
            sim: SimCache::new(),
            engine: env_engine().unwrap_or(Engine::Parallel),
            shard: sharding::env_shard(),
        }
    }

    /// Simulate one sweep cell: compiles through the sweep's compile
    /// cache, memoizes per-layer results in the sweep's sim cache, and
    /// (by default) nests layer- and segment-level jobs into the same
    /// worker pool the sweep itself fans out on.
    pub fn simulate(
        &self,
        net: &Network,
        sp: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
    ) -> SimReport {
        match self.shard {
            Some(spec) => self.simulate_fleet(net, sp, arch, seed, spec).report,
            None => {
                sim::simulate_network_memo(net, sp, arch, seed, self.engine, &self.cache, &self.sim)
            }
        }
    }

    /// Simulate one cell on an explicit chip fleet (the `shard-sweep`
    /// driver's entry point); shares the sweep's caches and engine.
    pub fn simulate_fleet(
        &self,
        net: &Network,
        sp: SparsityConfig,
        arch: &ArchConfig,
        seed: u64,
        spec: ShardSpec,
    ) -> ShardReport {
        sharding::simulate_sharded(net, sp, arch, seed, spec, self.engine, &self.cache, &self.sim)
    }

    fn stats(&self) -> SweepStats {
        SweepStats { compile: self.cache.stats(), sim: self.sim.stats() }
    }
}

/// A declarative experiment sweep: `axes` cells, each mapped to one row
/// by `job`. The executor owns the [`SweepCtx`] (cache + engine) and
/// the fan-out; drivers only declare *what* to compute.
pub struct SweepSpec<A, F> {
    pub axes: Vec<A>,
    pub job: F,
}

impl<A, F> SweepSpec<A, F> {
    /// Fan the cells over the shared pool; rows come back in axis
    /// order regardless of worker count or steal order.
    pub fn run<R>(self) -> (Vec<R>, SweepStats)
    where
        A: Send,
        R: Send,
        F: Fn(A, &SweepCtx) -> R + Sync,
    {
        let SweepSpec { axes, job } = self;
        let ctx = SweepCtx::new();
        let (job_ref, ctx_ref) = (&job, &ctx);
        let rows = pool::scope(move |s| {
            for cell in axes {
                s.spawn(move || job_ref(cell, ctx_ref));
            }
        });
        (rows, ctx.stats())
    }

    /// [`run`](Self::run), then fold the rows with `merge`.
    pub fn run_merged<R, Out>(self, merge: impl FnOnce(Vec<R>) -> Out) -> (Out, SweepStats)
    where
        A: Send,
        R: Send,
        F: Fn(A, &SweepCtx) -> R + Sync,
    {
        let (rows, stats) = self.run();
        (merge(rows), stats)
    }
}

/// Fig. 11 row: weight-sparsity-only speedup + energy vs dense baseline.
#[derive(Debug, Clone)]
pub struct Fig11Row {
    pub network: String,
    /// Compound weight sparsity (75–90%).
    pub total_sparsity: f64,
    pub value_sparsity: f64,
    pub speedup: f64,
    /// Energy saving fraction vs baseline (positive is better).
    pub energy_saving: f64,
}

/// Fig. 11: VGG19 / ResNet18 / MobileNetV2 at 75–90% weight sparsity;
/// IPU disabled (paper: "disable dynamic skipping of input columns"),
/// conv/FC layers only.
pub fn fig11(seed: u64) -> Vec<Fig11Row> {
    fig11_with_stats(seed).0
}

/// [`fig11`] plus the sweep's cache counters. The dense baseline is
/// identical across the four sparsity points of each network, so 3 of
/// its 4 simulations per (network, layer) are sim-cache hits — a
/// 37.5% sim hit rate by construction — and those hits skip
/// compilation entirely (the compile cache sees exactly one lookup
/// per sim computation, i.e. the sim misses plus any racing
/// duplicates).
pub fn fig11_with_stats(seed: u64) -> (Vec<Fig11Row>, SweepStats) {
    let nets = ["vgg19", "resnet18", "mobilenet_v2"];
    // value sparsity v + FTA (75% floor) ⇒ total = 1 - (1-v)/4
    let points = [(0.0, 0.75), (0.2, 0.80), (0.4, 0.85), (0.6, 0.90)];
    let arch = ArchConfig::weights_only();
    let base_arch = ArchConfig::dense_baseline();
    let axes: Vec<(&str, f64, f64)> = nets
        .iter()
        .flat_map(|&name| points.iter().map(move |&(v, total)| (name, v, total)))
        .collect();
    SweepSpec {
        axes,
        job: |(name, v, total): (&str, f64, f64), ctx: &SweepCtx| {
            let net = models::by_name(name).unwrap();
            let r = ctx.simulate(&net, SparsityConfig::hybrid(v), &arch, seed);
            let b = ctx.simulate(&net, SparsityConfig::dense(), &base_arch, seed);
            Fig11Row {
                network: name.to_string(),
                total_sparsity: total,
                value_sparsity: v,
                speedup: pim_speedup(&r, &b),
                energy_saving: 1.0 - pim_energy_ratio(&r, &b),
            }
        },
    }
    .run()
}

fn pim_speedup(r: &SimReport, b: &SimReport) -> f64 {
    b.pim_cycles() as f64 / r.pim_cycles().max(1) as f64
}

fn pim_energy_ratio(r: &SimReport, b: &SimReport) -> f64 {
    // PIM-scope energy: totals are dominated by PIM layers in these
    // runs (conv-only accounting uses full totals of PIM-layer events).
    let table = crate::energy::EnergyTable::default28nm();
    let re: f64 = r
        .layers
        .iter()
        .filter(|l| l.category == OpCategory::PimConvFc)
        .map(|l| l.events.energy_pj(&table))
        .sum();
    let be: f64 = b
        .layers
        .iter()
        .filter(|l| l.category == OpCategory::PimConvFc)
        .map(|l| l.events.energy_pj(&table))
        .sum();
    re / be.max(1e-12)
}

/// Fig. 12 row: end-to-end breakdown by sparsity approach.
#[derive(Debug, Clone)]
pub struct Fig12Row {
    pub network: String,
    pub approach: &'static str,
    pub speedup: f64,
    /// Energy normalized to the dense baseline (lower is better).
    pub energy_norm: f64,
}

/// Fig. 12: bit-level / value-level / hybrid vs dense baseline,
/// end-to-end (SIMD ops included) on all five networks.
pub fn fig12(seed: u64) -> Vec<Fig12Row> {
    fig12_with_stats(seed).0
}

/// [`fig12`] plus the sweep's cache counters.
pub fn fig12_with_stats(seed: u64) -> (Vec<Fig12Row>, SweepStats) {
    let configs: Vec<(&'static str, ArchConfig, SparsityConfig)> = vec![
        ("bit", ArchConfig::bit_only(), SparsityConfig { value_sparsity: 0.0, fta: true }),
        ("value", ArchConfig::value_only(), SparsityConfig { value_sparsity: 0.6, fta: false }),
        ("hybrid", ArchConfig::db_pim(), SparsityConfig::hybrid(0.6)),
    ];
    let base_arch = ArchConfig::dense_baseline();
    SweepSpec {
        axes: models::zoo(),
        job: |net: Network, ctx: &SweepCtx| {
            let base = ctx.simulate(&net, SparsityConfig::dense(), &base_arch, seed);
            configs
                .iter()
                .map(|cfg| {
                    let r = ctx.simulate(&net, cfg.2, &cfg.1, seed);
                    Fig12Row {
                        network: net.name.clone(),
                        approach: cfg.0,
                        speedup: r.speedup_vs(&base),
                        energy_norm: r.energy_ratio_vs(&base),
                    }
                })
                .collect::<Vec<Fig12Row>>()
        },
    }
    .run_merged(|nested| nested.into_iter().flatten().collect())
}

/// Fig. 13 row: execution-time share per op category.
#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub network: String,
    pub pw_std_conv_fc: f64,
    pub dw_conv: f64,
    pub mul: f64,
    pub etc: f64,
}

/// Fig. 13: MobileNetV2 + EfficientNetB0 op-time breakdown on DB-PIM.
pub fn fig13(seed: u64) -> Vec<Fig13Row> {
    let arch = ArchConfig::db_pim();
    let (rows, _) = SweepSpec {
        axes: vec!["mobilenet_v2", "efficientnet_b0"],
        job: |name: &'static str, ctx: &SweepCtx| {
            let net = models::by_name(name).unwrap();
            let r = ctx.simulate(&net, SparsityConfig::hybrid(0.6), &arch, seed);
            let mut row = Fig13Row {
                network: name.to_string(),
                pw_std_conv_fc: 0.0,
                dw_conv: 0.0,
                mul: 0.0,
                etc: 0.0,
            };
            for (cat, share) in r.category_breakdown() {
                match cat {
                    OpCategory::PimConvFc => row.pw_std_conv_fc = share,
                    OpCategory::DwConv => row.dw_conv = share,
                    OpCategory::Mul => row.mul = share,
                    OpCategory::Etc => row.etc = share,
                }
            }
            row
        },
    }
    .run();
    rows
}

/// Table II row for "this work": measured U_act per network + peak
/// throughput analysis.
#[derive(Debug, Clone)]
pub struct Table2 {
    pub u_act: Vec<(String, f64)>,
    pub peak_tops_phi1: f64,
    pub peak_gops_per_macro_phi1: f64,
    pub peak_gops_per_macro_phi2: f64,
    pub dense_gops_per_macro: f64,
    pub total_macros: usize,
    pub pim_kb: usize,
}

/// Table II: measured utilization + architectural peak throughput.
pub fn table2(seed: u64) -> Table2 {
    table2_with_stats(seed).0
}

/// [`table2`] plus the sweep's cache counters.
pub fn table2_with_stats(seed: u64) -> (Table2, SweepStats) {
    let arch = ArchConfig::db_pim();
    SweepSpec {
        axes: models::zoo(),
        job: |net: Network, ctx: &SweepCtx| {
            let r = ctx.simulate(&net, SparsityConfig::hybrid(0.6), &arch, seed);
            (net.name.clone(), r.u_act())
        },
    }
    .run_merged(|u_act| {
        let p1 = stats::peak_throughput(&arch, Some(1));
        let p2 = stats::peak_throughput(&arch, Some(2));
        let pd = stats::peak_throughput(&arch, None);
        Table2 {
            u_act,
            peak_tops_phi1: p1.tops,
            peak_gops_per_macro_phi1: p1.gops_per_macro,
            peak_gops_per_macro_phi2: p2.gops_per_macro,
            dense_gops_per_macro: pd.gops_per_macro,
            total_macros: arch.total_macros(),
            pim_kb: arch.pim_capacity_kb(),
        }
    })
}

/// Table III row: on-chip execution time (std/pw-conv + FC only).
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub network: String,
    pub dac24_ms: f64,
    pub bit_level_ms: f64,
    pub hybrid_ms: f64,
}

/// Table III: DAC'24 config vs this work's bit-level and hybrid modes.
pub fn table3(seed: u64) -> Vec<Table3Row> {
    table3_with_stats(seed).0
}

/// [`table3`] plus the sweep's cache counters.
pub fn table3_with_stats(seed: u64) -> (Vec<Table3Row>, SweepStats) {
    let bitsp = SparsityConfig { value_sparsity: 0.0, fta: true };
    SweepSpec {
        axes: models::zoo(),
        job: |net: Network, ctx: &SweepCtx| {
            let dac = ctx.simulate(&net, bitsp, &ArchConfig::dac24(), seed);
            let bit = ctx.simulate(&net, bitsp, &ArchConfig::bit_only(), seed);
            let hyb = ctx.simulate(&net, SparsityConfig::hybrid(0.6), &ArchConfig::db_pim(), seed);
            Table3Row {
                network: net.name.clone(),
                dac24_ms: dac.pim_time_ms(),
                bit_level_ms: bit.pim_time_ms(),
                hybrid_ms: hyb.pim_time_ms(),
            }
        },
    }
    .run()
}

/// `dbpim shard-sweep` row: one (network, scheme, chip count) cell.
#[derive(Debug, Clone)]
pub struct ShardSweepRow {
    pub network: String,
    pub scheme: &'static str,
    pub chips: usize,
    /// End-to-end fleet latency (cycles, interconnect included).
    pub fleet_cycles: u64,
    pub interconnect_cycles: u64,
    /// Single-chip cycles / fleet throughput cycles (pipeline interval
    /// when pipelining, fleet latency otherwise). 1.0 at `chips == 1`
    /// by the delegation contract.
    pub speedup: f64,
}

/// Speedup-vs-chips × scheme table: resnet18 + mobilenet_v2 on fleets
/// of 1/4/16 chips under tensor and pipeline parallelism (hybrid is
/// reachable via `dbpim simulate --chips N --scheme hybrid`).
pub fn shard_sweep(seed: u64) -> Vec<ShardSweepRow> {
    shard_sweep_with_stats(seed).0
}

/// [`shard_sweep`] plus the sweep's cache counters. Every cell's
/// single-chip baseline is the same memoized `chips=1` run (the
/// delegation shares identity cache keys with plain runs), so the
/// sweep simulates each network once per distinct (scheme, chips)
/// cell plus once for the baseline.
pub fn shard_sweep_with_stats(seed: u64) -> (Vec<ShardSweepRow>, SweepStats) {
    let arch = ArchConfig::db_pim();
    let nets = ["resnet18", "mobilenet_v2"];
    let schemes = ["tp", "pp"];
    let chips = [1usize, 4, 16];
    let axes: Vec<(&'static str, &'static str, usize)> = nets
        .iter()
        .flat_map(|&n| schemes.iter().flat_map(move |&s| chips.iter().map(move |&c| (n, s, c))))
        .collect();
    SweepSpec {
        axes,
        job: |(name, scheme, chips): (&'static str, &'static str, usize), ctx: &SweepCtx| {
            let net = models::by_name(name).unwrap();
            let sp = SparsityConfig::hybrid(0.6);
            let spec = ShardSpec::parse(chips, scheme).expect("static scheme tags");
            let base = ctx.simulate_fleet(&net, sp, &arch, seed, ShardSpec::single());
            let r = ctx.simulate_fleet(&net, sp, &arch, seed, spec);
            ShardSweepRow {
                network: name.to_string(),
                scheme,
                chips,
                fleet_cycles: r.fleet_cycles(),
                interconnect_cycles: r.interconnect_cycles,
                speedup: base.fleet_cycles() as f64 / r.throughput_cycles().max(1) as f64,
            }
        },
    }
    .run()
}

/// `dbpim fault-campaign` row: one (network, BER, repair strategy)
/// cell of the macro-level cell-fault campaign (DESIGN.md §13).
#[derive(Debug, Clone)]
pub struct FaultCampaignRow {
    pub network: String,
    /// Uniform bit-error rate on all three fault axes
    /// (stuck-0 / stuck-1 / transient).
    pub ber: f64,
    /// Repair strategy: `"none"` (spare budget zeroed) or `"spares"`
    /// (the preset spare-column + spare-macro budget).
    pub repair: &'static str,
    /// Stuck primary columns in the fault map (whole grid; the repair
    /// plan is a pure function of the arch, shared by every layer).
    pub stuck_columns: u64,
    /// Stuck columns steered onto clean spares at compile time.
    pub repaired_columns: u64,
    /// Stuck columns left in service (spares exhausted).
    pub unrepairable_columns: u64,
    /// Replica slots served by a spare macro instead of a primary.
    pub spared_macros: u64,
    /// Corrupted resident weight cells over all PIM layers
    /// (post-repair; replicas included).
    pub injected_cells: u64,
    /// ABFT `(filter, dyadic block)` checksum mismatches over all PIM
    /// layers.
    pub detections: u64,
    pub pim_layers: usize,
    /// PIM layers whose functional output differs from the fault-free
    /// reference.
    pub corrupted_layers: usize,
    /// Corrupted layers flagged by at least one ABFT detection.
    pub detected_layers: usize,
    /// Corrupted layers with zero detections — silent data corruption.
    /// The acceptance gate: 0 under `repair = spares` at BER ≤ 1e-4.
    pub undetected_layers: usize,
    /// Fleet latency overhead vs the fault-free run (fraction ≥ 0:
    /// ABFT verification cycles + any degrade-policy recompute).
    pub cycle_overhead: f64,
    /// Energy overhead vs the fault-free run (fraction; ABFT checks).
    pub energy_overhead: f64,
}

impl FaultCampaignRow {
    /// `repaired / stuck` (1.0 when nothing is stuck).
    pub fn repair_coverage(&self) -> f64 {
        if self.stuck_columns == 0 {
            1.0
        } else {
            self.repaired_columns as f64 / self.stuck_columns as f64
        }
    }
}

/// The default campaign grid (the EXPERIMENTS.md artifact): resnet18
/// across three BER decades, with and without spare repair.
pub fn fault_campaign(seed: u64) -> Vec<FaultCampaignRow> {
    let nets = vec!["resnet18".to_string()];
    fault_campaign_with_stats(&nets, &[1e-5, 1e-4, 1e-3], &["none", "spares"], seed, seed).0
}

/// The fault-injection campaign: for every (network, BER, repair
/// strategy) cell, build a faulty arch (uniform BER, degrade policy
/// `fail` so corruption reaches the outputs and the ABFT verdicts are
/// observable), then report the compile-time repair outcome, the
/// detected/undetected output-error split vs the fault-free functional
/// reference, and the latency/energy overhead of verification.
///
/// `seed` drives weights/activations; `fault_seed` drives the defect
/// pattern (the CLI's `--fault-seed` / `DBPIM_CELL_FAULT_SEED`). Rows
/// are bit-identical for any worker count or engine: fault decisions
/// are pure hashes and both simulations flow through the shared
/// deterministic caches.
pub fn fault_campaign_with_stats(
    nets: &[String],
    bers: &[f64],
    repairs: &[&'static str],
    seed: u64,
    fault_seed: u64,
) -> (Vec<FaultCampaignRow>, SweepStats) {
    let axes: Vec<(String, f64, &'static str)> = nets
        .iter()
        .flat_map(|n| {
            bers.iter().flat_map(move |&b| repairs.iter().map(move |&r| (n.clone(), b, r)))
        })
        .collect();
    SweepSpec {
        axes,
        job: move |(name, ber, repair): (String, f64, &'static str), ctx: &SweepCtx| {
            let net = models::by_name(&name).expect("campaign model");
            let sp = SparsityConfig::hybrid(0.6);
            let clean_arch = ArchConfig::db_pim();
            let mut arch = ArchConfig::db_pim();
            arch.cell_faults = CellFaultSpec::uniform(ber, fault_seed);
            arch.fault_degrade = DegradePolicy::Fail;
            if repair == "none" {
                arch.spare_columns_per_macro = 0;
                arch.spare_macros_per_core = 0;
            }
            let rep = packing::plan_repair(&arch).map(|p| p.report).unwrap_or_default();
            let clean = ctx.simulate(&net, sp, &clean_arch, seed);
            let faulty = ctx.simulate(&net, sp, &arch, seed);
            let clean_m = Machine::new(clean_arch.clone());
            let fault_m = Machine::new(arch.clone());
            let (mut injected, mut detections) = (0u64, 0u64);
            let (mut corrupted, mut detected, mut undetected) = (0usize, 0usize, 0usize);
            let pim = sim::pim_indices(&net);
            for &idx in &pim {
                let cl =
                    ctx.cache.get_or_compile(&net, idx, sp, &clean_arch, seed).expect("PIM layer");
                let fl = ctx.cache.get_or_compile(&net, idx, sp, &arch, seed).expect("PIM layer");
                let m = cl.prep.m.max(1);
                let x = MatI8::from_vec(
                    m,
                    cl.prep.k,
                    models::synthesize_activations(seed ^ ((idx as u64) << 20), m * cl.prep.k),
                );
                let (_, reference) = clean_m.run_pim_layer(&cl, Some(&x), true);
                let (_, out) = fault_m.run_pim_layer(&fl, Some(&x), true);
                let (li, ld) =
                    fl.faults.as_ref().map(|f| (f.injected, f.detections)).unwrap_or((0, 0));
                injected += li;
                detections += ld;
                if out != reference {
                    corrupted += 1;
                    if ld > 0 {
                        detected += 1;
                    } else {
                        undetected += 1;
                    }
                }
            }
            let table = crate::energy::EnergyTable::default28nm();
            FaultCampaignRow {
                network: name,
                ber,
                repair,
                stuck_columns: rep.stuck_columns,
                repaired_columns: rep.repaired_columns,
                unrepairable_columns: rep.unrepairable_columns,
                spared_macros: rep.spared_macros,
                injected_cells: injected,
                detections,
                pim_layers: pim.len(),
                corrupted_layers: corrupted,
                detected_layers: detected,
                undetected_layers: undetected,
                cycle_overhead: faulty.total_cycles() as f64
                    / clean.total_cycles().max(1) as f64
                    - 1.0,
                energy_overhead: faulty.totals.energy_pj(&table)
                    / clean.totals.energy_pj(&table).max(1e-12)
                    - 1.0,
            }
        },
    }
    .run()
}

/// `dbpim explore` row: one (model instance, arch variant, fleet)
/// cell of the design-space sweep (DESIGN.md §14). `speedup` is
/// end-to-end cycles of the per-model dense baseline (dense arch,
/// dense sparsity, one chip) over this cell's fleet cycles;
/// `energy_uj` is the cell's merged-report energy. `on_frontier`
/// marks the speedup-vs-energy Pareto frontier *within the rows of
/// the same base model* (max speedup, min energy).
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreRow {
    /// Base model name as registered (`bert_base`, `resnet18`, ...).
    pub model: String,
    /// Concrete instance simulated (`bert_base_s128`, ...).
    pub network: String,
    /// Sequence length of the instance; 0 for CNNs (no seq axis).
    pub seq_len: usize,
    /// Arch variant label (`ArchConfig::name`).
    pub arch: &'static str,
    pub chips: usize,
    pub scheme: &'static str,
    /// End-to-end fleet latency (cycles, interconnect included).
    pub cycles: u64,
    pub speedup: f64,
    pub energy_uj: f64,
    pub on_frontier: bool,
}

/// The curated arch variants the explorer sweeps: the paper preset
/// plus one step along each hardware axis ISSUE 10 names — core
/// count, macro count, tile shape (same 256-row K budget, taller ×
/// narrower), and the CSD bit-level path switched off. Every varied
/// field is part of `CompileKey`, so variants never alias in the
/// sweep caches.
fn explore_archs() -> Vec<ArchConfig> {
    let base = ArchConfig::db_pim();
    vec![
        base.clone(),
        ArchConfig { name: "cores-x2", n_cores: base.n_cores * 2, ..base.clone() },
        ArchConfig {
            name: "macros-x2",
            macros_per_core: base.macros_per_core * 2,
            ..base.clone()
        },
        ArchConfig {
            name: "tile-tall",
            compartments: base.compartments / 2,
            rows_per_compartment: base.rows_per_compartment * 2,
            ..base.clone()
        },
        ArchConfig { name: "no-csd", weight_bit_sparsity: false, ..base },
    ]
}

/// Pareto frontier over (speedup, energy) points: `.0` is maximized,
/// `.1` minimized. `mask[i]` is true iff no other point is at least
/// as good on both axes and strictly better on one; exact float
/// comparisons, so duplicated points stay on the frontier together
/// and the mask is bit-stable across runs.
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<bool> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(s, e))| {
            !points.iter().enumerate().any(|(j, &(sj, ej))| {
                j != i && sj >= s && ej <= e && (sj > s || ej < e)
            })
        })
        .collect()
}

/// The default explorer grid (the EXPERIMENTS.md artifact): the two
/// cheap transformer fixtures over their seq-len, arch-variant, and
/// fleet axes. `bert_base` (or any zoo CNN) is reachable via
/// `dbpim explore --models ...`.
pub fn explore(seed: u64) -> Vec<ExploreRow> {
    let names = vec!["tiny_transformer".to_string(), "gpt_micro".to_string()];
    explore_with_stats(&names, seed).0
}

/// The design-space explorer: every model in `model_names` (base name;
/// transformers expand to two seq-len instances — half the default and
/// the default — CNNs to one instance) crossed with the
/// [`explore_archs`] variants and the fleet points (1 chip, 4-chip
/// TP). Each cell simulates through the shared sweep caches — the
/// per-model dense baseline is memoized once per instance — and the
/// rows come back in axis order with `on_frontier` marked per base
/// model. Bit-identical for any worker count, steal order, or engine.
pub fn explore_with_stats(model_names: &[String], seed: u64) -> (Vec<ExploreRow>, SweepStats) {
    let archs = explore_archs();
    let fleets: [(usize, &'static str); 2] = [(1, "single"), (4, "tp")];
    type Cell = (String, Network, usize, ArchConfig, usize, &'static str);
    let mut axes: Vec<Cell> = Vec::new();
    for name in model_names {
        let instances: Vec<(Network, usize)> = match models::default_seq_len(name) {
            Some(d) => {
                let mut seqs = vec![(d / 2).max(2), d];
                seqs.dedup();
                seqs.iter()
                    .map(|&s| {
                        (models::transformer_seq(name, s).expect("transformer model"), s)
                    })
                    .collect()
            }
            None => vec![(models::by_name(name).expect("explore model"), 0)],
        };
        for (net, s) in instances {
            for a in &archs {
                for &(chips, scheme) in &fleets {
                    axes.push((name.clone(), net.clone(), s, a.clone(), chips, scheme));
                }
            }
        }
    }
    let (mut rows, st) = SweepSpec {
        axes,
        job: move |(model, net, seq_len, arch, chips, scheme): Cell, ctx: &SweepCtx| {
            let sp = SparsityConfig::hybrid(0.6);
            let base =
                ctx.simulate(&net, SparsityConfig::dense(), &ArchConfig::dense_baseline(), seed);
            let spec = if chips <= 1 {
                ShardSpec::single()
            } else {
                ShardSpec::parse(chips, scheme).expect("static scheme tags")
            };
            let rep = ctx.simulate_fleet(&net, sp, &arch, seed, spec);
            let cycles = rep.fleet_cycles();
            ExploreRow {
                model,
                network: net.name.clone(),
                seq_len,
                arch: arch.name,
                chips,
                scheme,
                cycles,
                speedup: base.total_cycles() as f64 / cycles.max(1) as f64,
                energy_uj: rep.report.energy_uj(),
                on_frontier: false,
            }
        },
    }
    .run();
    mark_frontiers(&mut rows);
    (rows, st)
}

/// Set `on_frontier` per base model over the collected rows (pure
/// post-pass; row order is already fixed by the sweep executor).
fn mark_frontiers(rows: &mut [ExploreRow]) {
    let mut seen: Vec<String> = Vec::new();
    for r in rows.iter() {
        if !seen.contains(&r.model) {
            seen.push(r.model.clone());
        }
    }
    for m in seen {
        let idx: Vec<usize> =
            rows.iter().enumerate().filter(|(_, r)| r.model == m).map(|(i, _)| i).collect();
        let pts: Vec<(f64, f64)> =
            idx.iter().map(|&i| (rows[i].speedup, rows[i].energy_uj)).collect();
        let mask = pareto_frontier(&pts);
        for (k, &i) in idx.iter().enumerate() {
            rows[i].on_frontier = mask[k];
        }
    }
}

/// Fig. 3 data (both panels) for all five networks.
pub fn fig3(seed: u64) -> (Vec<stats::ZeroBitStats>, Vec<stats::ZeroColumnStats>) {
    let (panels, _) = SweepSpec {
        axes: models::zoo(),
        job: |net: Network, _ctx: &SweepCtx| {
            (stats::zero_bit_stats(&net, 0.6, seed), stats::zero_column_stats(&net, seed))
        },
    }
    .run();
    panels.into_iter().unzip()
}

// ---------------------------------------------------------------------------
// JSON report serialization (for EXPERIMENTS.md regeneration)
// ---------------------------------------------------------------------------

pub fn fig3_json(bits: &[stats::ZeroBitStats], cols: &[stats::ZeroColumnStats]) -> Value {
    obj(vec![
        (
            "zero_bits",
            arr(bits
                .iter()
                .map(|r| {
                    obj(vec![
                        ("network", str_(&r.network)),
                        ("original", num(r.original)),
                        ("value_pruned", num(r.value_pruned)),
                        ("hybrid", num(r.hybrid)),
                    ])
                })
                .collect()),
        ),
        (
            "zero_columns",
            arr(cols
                .iter()
                .map(|r| {
                    obj(vec![
                        ("network", str_(&r.network)),
                        ("group1", num(r.group1)),
                        ("group8", num(r.group8)),
                        ("group16", num(r.group16)),
                    ])
                })
                .collect()),
        ),
    ])
}

pub fn table2_json(t: &Table2) -> Value {
    obj(vec![
        (
            "u_act",
            arr(t.u_act
                .iter()
                .map(|(n, u)| obj(vec![("network", str_(n)), ("u_act", num(*u))]))
                .collect()),
        ),
        ("peak_tops_phi1", num(t.peak_tops_phi1)),
        ("peak_gops_per_macro_phi1", num(t.peak_gops_per_macro_phi1)),
        ("peak_gops_per_macro_phi2", num(t.peak_gops_per_macro_phi2)),
        ("dense_gops_per_macro", num(t.dense_gops_per_macro)),
        ("total_macros", num(t.total_macros as f64)),
        ("pim_kb", num(t.pim_kb as f64)),
    ])
}

pub fn fig11_json(rows: &[Fig11Row]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("network", str_(&r.network)),
                ("total_sparsity", num(r.total_sparsity)),
                ("value_sparsity", num(r.value_sparsity)),
                ("speedup", num(r.speedup)),
                ("energy_saving", num(r.energy_saving)),
            ])
        })
        .collect())
}

pub fn fig12_json(rows: &[Fig12Row]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("network", str_(&r.network)),
                ("approach", str_(r.approach)),
                ("speedup", num(r.speedup)),
                ("energy_norm", num(r.energy_norm)),
            ])
        })
        .collect())
}

pub fn fig13_json(rows: &[Fig13Row]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("network", str_(&r.network)),
                ("pw_std_conv_fc", num(r.pw_std_conv_fc)),
                ("dw_conv", num(r.dw_conv)),
                ("mul", num(r.mul)),
                ("etc", num(r.etc)),
            ])
        })
        .collect())
}

pub fn shard_sweep_json(rows: &[ShardSweepRow]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("network", str_(&r.network)),
                ("scheme", str_(r.scheme)),
                ("chips", num(r.chips as f64)),
                ("fleet_cycles", num(r.fleet_cycles as f64)),
                ("interconnect_cycles", num(r.interconnect_cycles as f64)),
                ("speedup", num(r.speedup)),
            ])
        })
        .collect())
}

pub fn fault_campaign_json(rows: &[FaultCampaignRow]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("network", str_(&r.network)),
                ("ber", num(r.ber)),
                ("repair", str_(r.repair)),
                ("stuck_columns", num(r.stuck_columns as f64)),
                ("repaired_columns", num(r.repaired_columns as f64)),
                ("unrepairable_columns", num(r.unrepairable_columns as f64)),
                ("spared_macros", num(r.spared_macros as f64)),
                ("repair_coverage", num(r.repair_coverage())),
                ("injected_cells", num(r.injected_cells as f64)),
                ("detections", num(r.detections as f64)),
                ("pim_layers", num(r.pim_layers as f64)),
                ("corrupted_layers", num(r.corrupted_layers as f64)),
                ("detected_layers", num(r.detected_layers as f64)),
                ("undetected_layers", num(r.undetected_layers as f64)),
                ("cycle_overhead", num(r.cycle_overhead)),
                ("energy_overhead", num(r.energy_overhead)),
            ])
        })
        .collect())
}

pub fn explore_json(rows: &[ExploreRow]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("model", str_(&r.model)),
                ("network", str_(&r.network)),
                ("seq_len", num(r.seq_len as f64)),
                ("arch", str_(r.arch)),
                ("chips", num(r.chips as f64)),
                ("scheme", str_(r.scheme)),
                ("cycles", num(r.cycles as f64)),
                ("speedup", num(r.speedup)),
                ("energy_uj", num(r.energy_uj)),
                ("on_frontier", Value::Bool(r.on_frontier)),
            ])
        })
        .collect())
}

pub fn table3_json(rows: &[Table3Row]) -> Value {
    arr(rows
        .iter()
        .map(|r| {
            obj(vec![
                ("network", str_(&r.network)),
                ("dac24_ms", num(r.dac24_ms)),
                ("bit_level_ms", num(r.bit_level_ms)),
                ("hybrid_ms", num(r.hybrid_ms)),
            ])
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: full-zoo experiment tests live in rust/tests/; here we only
    // check the cheapest invariants so `cargo test` stays fast.

    #[test]
    fn fig13_shares_sum_to_one() {
        let rows = fig13(3);
        assert_eq!(rows.len(), 2);
        for r in rows {
            let sum = r.pw_std_conv_fc + r.dw_conv + r.mul + r.etc;
            assert!((sum - 1.0).abs() < 1e-9, "{r:?}");
            assert!(r.dw_conv > 0.1, "dw-conv share too small: {r:?}");
        }
    }

    #[test]
    fn table2_peaks() {
        let t = table2(1);
        assert_eq!(t.total_macros, 32);
        assert_eq!(t.pim_kb, 16);
        assert!(t.peak_gops_per_macro_phi1 > t.peak_gops_per_macro_phi2);
        assert!(t.peak_gops_per_macro_phi2 > t.dense_gops_per_macro);
        for (name, u) in &t.u_act {
            assert!(*u > 0.4, "{name} U_act {u}");
        }
    }

    #[test]
    fn pareto_frontier_marks_non_dominated() {
        // speedup maximized, energy minimized; duplicates co-survive
        let pts = [(2.0, 5.0), (1.0, 9.0), (3.0, 4.0), (3.0, 4.0), (2.5, 6.0)];
        assert_eq!(pareto_frontier(&pts), vec![false, false, true, true, false]);
        // a point better on one axis, worse on the other, is kept
        let pts = [(1.0, 1.0), (2.0, 2.0)];
        assert_eq!(pareto_frontier(&pts), vec![true, true]);
        assert!(pareto_frontier(&[]).is_empty());
    }

    #[test]
    fn explore_tiny_has_nonempty_valid_frontier() {
        let names = vec!["tiny_transformer".to_string()];
        let (rows, stats) = explore_with_stats(&names, 7);
        // 2 seq-len instances × 5 arch variants × 2 fleet points
        assert_eq!(rows.len(), 20);
        assert!(rows.iter().any(|r| r.on_frontier), "empty frontier");
        for r in &rows {
            assert!(r.cycles > 0 && r.speedup > 0.0 && r.energy_uj > 0.0, "{r:?}");
        }
        // every frontier row is non-dominated within its base model
        for r in rows.iter().filter(|r| r.on_frontier) {
            assert!(
                !rows.iter().any(|o| o.model == r.model
                    && o.speedup >= r.speedup
                    && o.energy_uj <= r.energy_uj
                    && (o.speedup > r.speedup || o.energy_uj < r.energy_uj)),
                "dominated frontier row {r:?}"
            );
        }
        // the shared dense baseline memoizes: one sim per instance's
        // baseline, not one per cell
        assert!(stats.sim.hits > 0, "{stats:?}");
    }

    #[test]
    fn fault_campaign_on_tiny_net_detects_everything() {
        // one cheap cell on the tiny fixture: coverage of the whole
        // campaign path (repair plan, dual compile, functional diff,
        // overhead math) without touching the zoo.
        let nets = vec!["tiny".to_string()];
        let (rows, _) = fault_campaign_with_stats(&nets, &[2e-3], &["none", "spares"], 5, 5);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.pim_layers, 2);
            assert!(r.injected_cells > 0, "BER 2e-3 must corrupt something: {r:?}");
            assert_eq!(r.undetected_layers, 0, "silent corruption: {r:?}");
            assert_eq!(r.corrupted_layers, r.detected_layers, "{r:?}");
            assert!(r.cycle_overhead > 0.0, "ABFT verification is not free: {r:?}");
            assert!(r.energy_overhead > 0.0, "{r:?}");
            assert!(r.repair_coverage() >= 0.0 && r.repair_coverage() <= 1.0);
        }
        // without spares nothing can be repaired; with them repair may
        // only improve (at this BER most columns carry a stuck cell, so
        // coverage is partial — the low-BER regime is pinned in the
        // integration goldens)
        assert_eq!(rows[0].repaired_columns, 0, "{rows:?}");
        assert!(rows[1].repaired_columns >= rows[0].repaired_columns, "{rows:?}");
    }

    #[test]
    fn sweep_executor_preserves_axis_order_and_counts_cache() {
        let net = crate::models::fixtures::tiny_net();
        let arch = ArchConfig::db_pim();
        let (rows, stats) = SweepSpec {
            axes: vec![0u64, 1, 2, 0],
            job: |seed: u64, ctx: &SweepCtx| {
                let r = ctx.simulate(&net, SparsityConfig::hybrid(0.5), &arch, seed);
                (seed, r.total_cycles())
            },
        }
        .run();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows.iter().map(|r| r.0).collect::<Vec<_>>(), vec![0, 1, 2, 0]);
        // identical cells must produce bit-identical rows
        assert_eq!(rows[0].1, rows[3].1);
        // 4 cells × 2 PIM layers reach the sim cache over 6 unique
        // keys; hit/miss counts are deterministic for any schedule
        // (racing duplicate computations count as dup_computes, and a
        // duplicated sim run re-drives the compile cache), and a
        // sim-cache hit skips compilation entirely, so the compile
        // cache sees exactly one lookup per sim computation.
        assert_eq!(stats.sim.lookups(), 8);
        assert_eq!(stats.sim.misses, 6, "{stats:?}");
        assert_eq!(stats.sim.hits, 2, "{stats:?}");
        assert_eq!(
            stats.compile.lookups(),
            stats.sim.misses + stats.sim.dup_computes,
            "{stats:?}"
        );
        assert_eq!(stats.compile.misses, 6, "{stats:?}");
    }
}
