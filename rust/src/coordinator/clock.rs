//! Virtual time for the open-loop serve loop (DESIGN.md §11).
//!
//! The serve loop is a deterministic discrete-event simulation: arrival
//! times, service completions, retries, timeouts and chip outages all
//! live on one **virtual clock**, measured in integer nanoseconds, that
//! only advances when the loop pops the next event. Nothing in the loop
//! ever reads host time, so an entire serve run — outcomes, stats and
//! event order — is a pure function of the spec and its seeds, and is
//! replayable bit-exactly on any host and for any worker count (the
//! worker pool only parallelizes the simulations *inside* one event,
//! which are themselves schedule-independent by the §8 contract).
//!
//! [`EventQueue`] is the matching deterministic priority queue: events
//! pop in `(time, push-sequence)` order, so simultaneous events resolve
//! in the order they were scheduled — a total order independent of heap
//! internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual-time instant/duration in nanoseconds.
pub type VirtualNs = u64;

/// Convert a millisecond quantity (the spec/CLI currency) to virtual
/// nanoseconds, saturating at 0 below and at ~292 years above so
/// malformed specs cannot overflow the clock.
pub fn ms_to_ns(ms: f64) -> VirtualNs {
    let ns = (ms * 1e6).round();
    if ns.is_nan() || ns <= 0.0 {
        return 0;
    }
    if ns >= 9.2e18 {
        return 9_200_000_000_000_000_000;
    }
    ns as VirtualNs
}

/// Virtual nanoseconds back to milliseconds (for reports).
pub fn ns_to_ms(ns: VirtualNs) -> f64 {
    ns as f64 / 1e6
}

/// The monotone virtual clock. Advancing backwards is a logic error in
/// the event loop (events pop in time order), so it panics loudly
/// instead of silently reordering history.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: VirtualNs,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Current virtual time.
    pub fn now(&self) -> VirtualNs {
        self.now
    }

    /// Advance to `t` (monotone; equal time is fine — simultaneous
    /// events share an instant).
    pub fn advance_to(&mut self, t: VirtualNs) {
        assert!(t >= self.now, "virtual clock moved backwards: {} -> {}", self.now, t);
        self.now = t;
    }
}

/// One scheduled event: ordered by `(time, seq)` — `seq` is the push
/// sequence number, so ties break deterministically in schedule order.
struct Entry<E> {
    time: VirtualNs,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Deterministic event queue: min-heap on `(time, push-sequence)`.
/// The payload type needs no ordering of its own.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue::default()
    }

    /// Schedule `event` at virtual time `time`.
    pub fn push(&mut self, time: VirtualNs, event: E) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time, seq, event });
    }

    /// Pop the earliest event (ties in push order).
    pub fn pop(&mut self) -> Option<(VirtualNs, E)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    /// Time of the earliest pending event, without popping it. The
    /// serve loop uses this to drain every event of the current instant
    /// before forming batches, so simultaneous arrivals batch together
    /// instead of dispatching one by one.
    pub fn peek_time(&self) -> Option<VirtualNs> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a1");
        q.push(20, "b");
        q.push(10, "a2");
        q.push(10, "a3");
        assert_eq!(q.peek_time(), Some(10));
        let order: Vec<(VirtualNs, &str)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(q.peek_time(), None);
        assert_eq!(order, vec![(10, "a1"), (10, "a2"), (10, "a3"), (20, "b"), (30, "c")]);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn clock_is_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(5);
        c.advance_to(5); // same instant is fine
        c.advance_to(9);
        assert_eq!(c.now(), 9);
    }

    #[test]
    #[should_panic(expected = "moved backwards")]
    fn clock_rejects_backwards_time() {
        let mut c = VirtualClock::new();
        c.advance_to(10);
        c.advance_to(9);
    }

    #[test]
    fn ms_ns_conversion_is_safe_on_garbage() {
        assert_eq!(ms_to_ns(1.0), 1_000_000);
        assert_eq!(ms_to_ns(0.0), 0);
        assert_eq!(ms_to_ns(-3.0), 0);
        assert_eq!(ms_to_ns(f64::NAN), 0);
        assert!(ms_to_ns(f64::INFINITY) > 0);
        assert!((ns_to_ms(2_500_000) - 2.5).abs() < 1e-12);
    }
}
