//! Multi-chip sharding: tensor/pipeline parallelism over fleets of
//! DB-PIM chips with a deterministic interconnect cost model
//! (DESIGN.md §12).
//!
//! The sharding layer sits between the compiler and the simulator. A
//! [`ShardSpec`] names a fleet — `chips` identical chips (each a full
//! `ArchConfig` machine) — and a [`ShardScheme`]:
//!
//! * **Tensor parallel** (`tp`): every PIM layer's filter assignments
//!   are partitioned across chips (LPT by assignment cost, respecting
//!   each chip's weight-storage capacity `pim_capacity_kb`), each chip
//!   re-lowers and simulates its subset, and the per-layer results
//!   merge deterministically. Layer latency = max over chips; an
//!   all-gather of the output activations is charged per layer.
//! * **Pipeline parallel** (`pp`): whole layers map to pipeline stages
//!   (contiguous, placement by a linear-partition DP balancing
//!   per-stage cycle estimates); chip-boundary activations are charged
//!   per stage crossing. Latency = sum over stages + transfers;
//!   steady-state throughput is set by the slowest stage
//!   ([`ShardReport::pipeline_interval_cycles`]).
//! * **Hybrid** (`tp × pp`): tensor-parallel groups inside pipeline
//!   stages; both charge kinds apply.
//!
//! **Determinism contract** (extends DESIGN.md §8): `chips == 1` under
//! any scheme delegates to the single-chip path and is bit-identical
//! to it — same `SimReport`, same goldens. For `chips > 1` the merge
//! is order-fixed (chip-major, layer order), per-chip simulations are
//! pure functions of the chip-local compiled subset, and interconnect
//! charges are closed-form in (bytes, hops) — so results are
//! bit-identical for any worker count or steal order. Physical event
//! totals are *conserved*: the merged totals equal the single-chip
//! totals exactly, once the per-chip barrier bookkeeping (2 extra
//! `instrs` per extra chip per layer) is corrected and the
//! fleet-dependent timing projections (`elapsed_cycles`,
//! `core_cycles`) are set aside — pinned by `prop_sharding`.
//!
//! Communication appears in the merged report as one synthetic
//! `interconnect` pseudo-layer (category `Etc`, pure latency, zero
//! physical events) so every downstream consumer of
//! `SimReport::total_cycles`/`time_ns` — the serve frontends, traces,
//! sweep tables — naturally sees fleet latency including transfers.
//!
//! Cache contract: chip-local artifacts and simulations are memoized
//! in the same `CompileCache`/`SimCache` as single-chip runs, under
//! keys extended with the shard scope (`CompileKey::sharded`), so
//! sharded and unsharded cells of one sweep never alias and the
//! pipeline scheme (which simulates plain single-chip layers) shares
//! entries with plain runs.

// Panic-hardening (DESIGN.md §13, extended by ISSUE 10): sharding sits
// on the serve path, so stray unwraps are lint-visible. The few
// remaining `expect`s are structural invariants with per-site
// justifications.
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::sync::Arc;

use crate::arch::ArchConfig;
use crate::compiler::cache::{CompileCache, CompileKey};
use crate::compiler::{compile_assignment_subset, Assignment, SparsityConfig};
use crate::energy::EventCounts;
use crate::models::{LayerKind, Network};
use crate::sim::{self, Engine, LayerStats, Machine, OpCategory, SimCache, SimReport};
use crate::tensor::MatI8;

use super::pool;

/// How a fleet of chips divides the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardScheme {
    /// Split every PIM layer's assignments across all chips.
    TensorParallel,
    /// Map contiguous layer ranges to pipeline stages, one per chip.
    PipelineParallel,
    /// `tp`-way tensor groups inside `pp` pipeline stages
    /// (`chips == tp * pp`).
    Hybrid { tp: usize, pp: usize },
}

impl ShardScheme {
    /// CLI/JSON tag (`--scheme tp|pp|hybrid`).
    pub fn name(&self) -> &'static str {
        match self {
            ShardScheme::TensorParallel => "tp",
            ShardScheme::PipelineParallel => "pp",
            ShardScheme::Hybrid { .. } => "hybrid",
        }
    }
}

/// A fleet: `chips` identical chips under one scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub chips: usize,
    pub scheme: ShardScheme,
}

impl ShardSpec {
    /// The degenerate single-chip fleet (delegates to the plain path).
    pub fn single() -> Self {
        ShardSpec { chips: 1, scheme: ShardScheme::TensorParallel }
    }

    /// Build a spec from the CLI surface: a chip count and a scheme
    /// tag. `hybrid` factors `chips` into `tp × pp` with `pp` the
    /// largest divisor ≤ √chips (4 → 2×2, 16 → 4×4, 6 → 3×2), so the
    /// pipeline depth never exceeds the tensor width. Returns `None`
    /// for an unknown tag or `chips == 0`.
    pub fn parse(chips: usize, scheme: &str) -> Option<Self> {
        if chips == 0 {
            return None;
        }
        let scheme = match scheme {
            "tp" | "tensor" => ShardScheme::TensorParallel,
            "pp" | "pipeline" => ShardScheme::PipelineParallel,
            "hybrid" => {
                let mut pp = (chips as f64).sqrt().floor() as usize;
                while pp > 1 && chips % pp != 0 {
                    pp -= 1;
                }
                let pp = pp.max(1);
                ShardScheme::Hybrid { tp: chips / pp, pp }
            }
            _ => return None,
        };
        Some(ShardSpec { chips, scheme })
    }

    /// `(tensor width, pipeline depth)`; `tp * pp == chips`.
    pub fn factors(&self) -> (usize, usize) {
        match self.scheme {
            ShardScheme::TensorParallel => (self.chips, 1),
            ShardScheme::PipelineParallel => (1, self.chips),
            ShardScheme::Hybrid { tp, pp } => (tp, pp),
        }
    }
}

/// Read a fleet spec from the environment (`DBPIM_CHIPS`,
/// `DBPIM_SCHEME`; scheme defaults to `tp`). Lets CI route the whole
/// experiment surface through the sharded path — the `chips=1`
/// golden-equivalence leg — without touching every driver's signature.
pub fn env_shard() -> Option<ShardSpec> {
    let chips = std::env::var("DBPIM_CHIPS").ok()?.trim().parse::<usize>().ok()?;
    let scheme = std::env::var("DBPIM_SCHEME").unwrap_or_else(|_| "tp".into());
    ShardSpec::parse(chips, scheme.trim())
}

/// A sharded run: the merged fleet-level report plus the fleet
/// decomposition.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub spec: ShardSpec,
    /// Merged report. Layer stats are fleet-level (TP layers carry the
    /// max-over-chips latency and the summed physical events); when
    /// communication was charged, one trailing `interconnect`
    /// pseudo-layer holds it, so `total_cycles`/`time_ns` are fleet
    /// latency including transfers.
    pub report: SimReport,
    /// Busy cycles per chip (chip index = stage * tp + rank).
    pub chip_cycles: Vec<u64>,
    /// Total interconnect cycles charged (all-gathers + stage
    /// boundaries).
    pub interconnect_cycles: u64,
    /// Total bytes moved across chip boundaries.
    pub interconnect_bytes: u64,
    /// Steady-state initiation interval: the slowest pipeline stage
    /// including its outgoing transfer. Equals fleet latency when
    /// `pp == 1` (no pipelining).
    pub pipeline_interval_cycles: u64,
    /// ABFT corruption detections per chip (chip index = stage * tp +
    /// rank; all zeros when the cell-fault model is off). Each fleet
    /// member draws its own defect pattern (`CellFaultSpec::for_chip`),
    /// so a degraded chip shows up here in fleet summaries.
    pub chip_fault_detections: Vec<u64>,
}

impl ShardReport {
    /// End-to-end fleet latency for one inference (cycles), including
    /// interconnect charges.
    pub fn fleet_cycles(&self) -> u64 {
        self.report.total_cycles()
    }

    /// Cycles per inference at steady state: the pipeline interval
    /// when pipelining, else the fleet latency.
    pub fn throughput_cycles(&self) -> u64 {
        let (_, pp) = self.spec.factors();
        if pp > 1 {
            self.pipeline_interval_cycles
        } else {
            self.fleet_cycles()
        }
    }
}

/// Fleet-independent projection of an event total: zero the two
/// timing fields (`elapsed_cycles`, `core_cycles`) that by design
/// depend on how work spreads over chips. Everything else — the
/// physical work: MACs, cycles of macro activity, buffer traffic,
/// (corrected) instruction count — must be conserved exactly by any
/// sharding; `prop_sharding` pins that.
pub fn physical_projection(e: &EventCounts) -> EventCounts {
    let mut p = e.clone();
    p.elapsed_cycles = 0;
    p.core_cycles = 0;
    p
}

/// Weight-storage footprint of one assignment on a chip, in bytes:
/// `kept_rows × active bit-columns` cells, one bit each.
pub fn assignment_footprint_bytes(a: &Assignment) -> u64 {
    ((a.kept_rows.len() * a.active_cols()) as u64).div_ceil(8)
}

/// Partition a layer's assignments across `chips` chips: LPT order by
/// simulation cost (`kept_rows × active_cols`, index as tiebreak),
/// each assignment to the least-loaded chip whose weight capacity
/// (`pim_capacity_kb`) still fits it — falling back to the
/// least-loaded chip outright when none fits (capacity is a placement
/// preference, not a hard wall; the guaranteed-fit condition is pinned
/// by `prop_sharding::tp_placement_respects_capacity`). Returned
/// per-chip index lists are ascending; concatenated they are a
/// permutation of `0..assignments.len()`.
pub fn partition_assignments(
    assignments: &[Assignment],
    arch: &ArchConfig,
    chips: usize,
) -> Vec<Vec<usize>> {
    let chips = chips.max(1);
    let cap = (arch.pim_capacity_kb() as u64) * 1024;
    let mut order: Vec<(u64, usize)> = assignments
        .iter()
        .enumerate()
        .map(|(i, a)| ((a.kept_rows.len() * a.active_cols()) as u64, i))
        .collect();
    order.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); chips];
    let mut load = vec![0u64; chips];
    let mut foot = vec![0u64; chips];
    for (cost, idx) in order {
        let fp = assignment_footprint_bytes(&assignments[idx]);
        let fits = (0..chips).filter(|&c| foot[c] + fp <= cap).min_by_key(|&c| (load[c], c));
        // `chips >= 1` always (ShardSpec::parse rejects 0), so the
        // capacity-blind fallback has a least-loaded chip; `unwrap_or`
        // keeps the path panic-free regardless.
        let c = fits
            .unwrap_or_else(|| (0..chips).min_by_key(|&c| (load[c], c)).unwrap_or(0));
        parts[c].push(idx);
        load[c] += cost.max(1);
        foot[c] += fp;
    }
    for p in &mut parts {
        p.sort_unstable();
    }
    parts
}

/// Output-activation volume of a layer in bytes (i8 activations) —
/// what an all-gather (TP) or a stage boundary (PP) moves.
fn layer_output_bytes(kind: &LayerKind) -> u64 {
    // Infallible: every GEMM-lowered kind answers through
    // `matmul_dims`, so a new PIM-shaped variant can never hit a
    // panic here (ISSUE 10 hardening; previously `expect("PIM layer")`
    // on a `Conv | Fc` match).
    if let Some((m, _, n)) = kind.matmul_dims() {
        return (m * n) as u64;
    }
    match *kind {
        LayerKind::DwConv { ch, kernel, stride, pad, in_hw } => {
            let out_hw = (in_hw + 2 * pad - kernel) / stride + 1;
            (ch * out_hw * out_hw) as u64
        }
        LayerKind::Pool { elems }
        | LayerKind::Act { elems }
        | LayerKind::ResAdd { elems }
        | LayerKind::Mul { elems }
        | LayerKind::LayerNorm { elems } => elems as u64,
        // GEMM-lowered kinds returned above; listed so the match stays
        // exhaustive (and panic-free) when variants are added.
        LayerKind::Conv { .. }
        | LayerKind::Fc { .. }
        | LayerKind::Attention { .. }
        | LayerKind::Mlp { .. } => 0,
    }
}

/// Ring all-gather charge for one TP layer: `c` participating chips
/// each hold `bytes / c` of the output and receive the rest over
/// `c - 1` hops. Zero when one chip holds everything.
fn all_gather_cycles(arch: &ArchConfig, bytes: u64, c: usize) -> u64 {
    if c <= 1 {
        return 0;
    }
    arch.link_transfer_cycles(bytes - bytes / c as u64, c as u64 - 1)
}

/// Contiguous linear partition of `weights` into at most `stages`
/// ranges minimizing the maximum range sum (classic DP; earliest cut
/// wins ties, so placement is deterministic). Every range is
/// non-empty; returns `min(stages, len)` ranges covering `0..len`.
fn partition_stages(weights: &[u64], stages: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let s = stages.clamp(1, n);
    let mut pre = vec![0u64; n + 1];
    for (i, &w) in weights.iter().enumerate() {
        pre[i + 1] = pre[i] + w;
    }
    let sum = |a: usize, b: usize| pre[b] - pre[a];
    let mut dp = vec![vec![u64::MAX; n + 1]; s + 1];
    let mut cut = vec![vec![0usize; n + 1]; s + 1];
    dp[0][0] = 0;
    for k in 1..=s {
        for i in k..=n {
            for j in (k - 1)..i {
                if dp[k - 1][j] == u64::MAX {
                    continue;
                }
                let cand = dp[k - 1][j].max(sum(j, i));
                if cand < dp[k][i] {
                    dp[k][i] = cand;
                    cut[k][i] = j;
                }
            }
        }
    }
    let mut bounds = Vec::with_capacity(s);
    let mut i = n;
    for k in (1..=s).rev() {
        let j = cut[k][i];
        bounds.push((j, i));
        i = j;
    }
    bounds.reverse();
    bounds
}

/// One fleet-level layer after the TP merge, plus what the scheduler
/// needs to place and charge it.
struct MergedLayer {
    stats: LayerStats,
    /// Per-tensor-rank busy cycles (len == tp; SIMD layers run on rank
    /// 0 only).
    rank_elapsed: Vec<u64>,
    /// Per-tensor-rank ABFT detections (len == tp; zero for SIMD
    /// layers and when the cell-fault model is off).
    rank_detections: Vec<u64>,
    /// All-gather charge for this layer (TP layers with ≥ 2
    /// participating chips; else 0).
    comm_cycles: u64,
    comm_bytes: u64,
    /// Net layer this came from (for stage-boundary volumes).
    net_idx: usize,
}

/// Simulate `net` on a fleet. `chips == 1` (any scheme) delegates to
/// [`sim::simulate_network_memo`] and is bit-identical to it; sharded
/// runs fan per-chip × per-layer jobs into the worker pool and merge
/// in fixed chip-major order. Both caches memoize chip-local work
/// under shard-scoped keys (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn simulate_sharded(
    net: &Network,
    sparsity: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
    spec: ShardSpec,
    engine: Engine,
    cache: &CompileCache,
    sim_cache: &SimCache,
) -> ShardReport {
    let (tp, pp) = spec.factors();
    if spec.chips <= 1 {
        let report = sim::simulate_network_memo(net, sparsity, arch, seed, engine, cache, sim_cache);
        let total = report.total_cycles();
        let detections = report.totals.fault_detections;
        return ShardReport {
            spec,
            report,
            chip_cycles: vec![total],
            interconnect_cycles: 0,
            interconnect_bytes: 0,
            pipeline_interval_cycles: total,
            chip_fault_detections: vec![detections],
        };
    }
    debug_assert_eq!(tp * pp, spec.chips, "scheme factors must cover the fleet");

    let machine = Machine::with_engine(arch.clone(), engine);
    let merged = if tp > 1 {
        merge_tensor_parallel(net, sparsity, &machine, seed, tp, cache, sim_cache)
    } else {
        // Pure pipeline: per-layer results are plain single-chip runs,
        // memoized under the identity keys (shared with unsharded
        // sweeps of the same cell).
        let report = sim::simulate_network_memo(net, sparsity, arch, seed, engine, cache, sim_cache);
        let kinds = present_layer_indices(net, arch);
        debug_assert_eq!(kinds.len(), report.layers.len());
        report
            .layers
            .into_iter()
            .zip(kinds)
            .map(|(stats, net_idx)| MergedLayer {
                rank_elapsed: vec![stats.elapsed],
                rank_detections: vec![stats.events.fault_detections],
                comm_cycles: 0,
                comm_bytes: 0,
                net_idx,
                stats,
            })
            .collect()
    };

    // --- pipeline placement + interconnect charges ------------------
    let weights: Vec<u64> = merged.iter().map(|l| l.stats.elapsed + l.comm_cycles).collect();
    let stages = partition_stages(&weights, pp);
    let mut comm_cycles: u64 = merged.iter().map(|l| l.comm_cycles).sum();
    let mut comm_bytes: u64 = merged.iter().map(|l| l.comm_bytes).sum();
    let mut interval: u64 = 0;
    let mut chip_cycles = vec![0u64; spec.chips];
    let mut chip_fault_detections = vec![0u64; spec.chips];
    for (s, &(a, b)) in stages.iter().enumerate() {
        let stage_sum: u64 = weights[a..b].iter().sum();
        let boundary = if s + 1 < stages.len() {
            let out = layer_output_bytes(&net.layers[merged[b - 1].net_idx].kind);
            comm_bytes += out;
            arch.link_transfer_cycles(out, 1)
        } else {
            0
        };
        comm_cycles += boundary;
        interval = interval.max(stage_sum + boundary);
        for l in &merged[a..b] {
            for (r, &e) in l.rank_elapsed.iter().enumerate() {
                chip_cycles[s * tp + r] += e;
            }
            for (r, &d) in l.rank_detections.iter().enumerate() {
                chip_fault_detections[s * tp + r] += d;
            }
        }
    }

    // --- assemble the merged report ---------------------------------
    let mut layers: Vec<LayerStats> = Vec::with_capacity(merged.len() + 1);
    let mut totals = EventCounts::default();
    for l in merged {
        totals.add(&l.stats.events);
        layers.push(l.stats);
    }
    if comm_cycles > 0 {
        let stats = interconnect_layer(arch, comm_cycles);
        totals.add(&stats.events);
        layers.push(stats);
    }
    let report = SimReport {
        arch: Arc::clone(&machine.arch),
        network: net.name.clone(),
        sparsity,
        layers,
        totals,
    };
    let interval = if pp > 1 { interval } else { report.total_cycles() };
    ShardReport {
        spec,
        report,
        chip_cycles,
        interconnect_cycles: comm_cycles,
        interconnect_bytes: comm_bytes,
        pipeline_interval_cycles: interval,
        chip_fault_detections,
    }
}

/// The synthetic communication pseudo-layer: pure latency, category
/// `Etc`, zero physical events — `physical_projection` of its events
/// is all-zero by construction.
fn interconnect_layer(arch: &ArchConfig, cycles: u64) -> LayerStats {
    LayerStats {
        name: "interconnect".into(),
        category: OpCategory::Etc,
        events: EventCounts { elapsed_cycles: cycles, ..EventCounts::default() },
        core_cycles: vec![0; arch.n_cores],
        elapsed: cycles,
    }
}

/// Indices of the net layers that appear in a report under `arch`
/// (PIM always; SIMD layers only when the chip has the SIMD core).
fn present_layer_indices(net: &Network, arch: &ArchConfig) -> Vec<usize> {
    net.layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.kind.matmul_dims().is_some() || arch.has_simd)
        .map(|(i, _)| i)
        .collect()
}

/// Tensor-parallel body: fan (PIM layer × chip) jobs into the pool,
/// then merge per layer in fixed chip order. SIMD layers are costed
/// exactly once (they are not split), identical to the single-chip
/// report.
// expect(): the two expects below consume exactly one chunk / one
// merged entry per `pim_indices` element — a structural zip whose
// lengths are equal by construction.
#[allow(clippy::expect_used)]
fn merge_tensor_parallel(
    net: &Network,
    sparsity: SparsityConfig,
    machine: &Machine,
    seed: u64,
    tp: usize,
    cache: &CompileCache,
    sim_cache: &SimCache,
) -> Vec<MergedLayer> {
    let arch = &machine.arch;
    let pim_idx = sim::pim_indices(net);
    let cells: Vec<(usize, usize)> =
        pim_idx.iter().flat_map(|&idx| (0..tp).map(move |chip| (idx, chip))).collect();
    let chip_stats: Vec<Option<LayerStats>> = {
        let run = |&(idx, chip): &(usize, usize)| {
            simulate_chip_layer(net, idx, sparsity, machine, seed, tp, chip, cache, sim_cache)
        };
        match machine.engine {
            Engine::Parallel => {
                let jobs: Vec<_> = cells.iter().map(|cell| move || run(cell)).collect();
                pool::run_jobs(jobs)
            }
            Engine::Sequential => cells.iter().map(run).collect(),
        }
    };

    let mut per_layer = chip_stats.chunks(tp);
    let mut pim_merged = pim_idx
        .iter()
        .map(|&idx| {
            let chips = per_layer.next().expect("one chunk per PIM layer");
            merge_pim_layer(net, idx, arch, chips, tp)
        })
        .collect::<Vec<_>>()
        .into_iter();
    // Interleave with the once-costed SIMD layers, in net order.
    let mut merged = Vec::new();
    for (net_idx, layer) in net.layers.iter().enumerate() {
        if layer.kind.matmul_dims().is_some() {
            merged.push(pim_merged.next().expect("merged PIM layer"));
        } else if let Some(stats) = sim::simd_layer_stats(machine, layer) {
            let mut rank_elapsed = vec![0u64; tp];
            rank_elapsed[0] = stats.elapsed;
            merged.push(MergedLayer {
                rank_elapsed,
                rank_detections: vec![0u64; tp],
                comm_cycles: 0,
                comm_bytes: 0,
                net_idx,
                stats,
            });
        }
    }
    merged
}

/// Merge one PIM layer's per-chip stats: physical events sum, the
/// per-chip barrier bookkeeping (Sync + End = 2 `instrs` per program)
/// is corrected so the merged count equals the single-chip count
/// exactly, latency is the slowest chip, and per-core busy cycles
/// concatenate in chip order. The all-gather is charged over the
/// chips that actually hold filters.
fn merge_pim_layer(
    net: &Network,
    idx: usize,
    arch: &ArchConfig,
    chips: &[Option<LayerStats>],
    tp: usize,
) -> MergedLayer {
    let present: Vec<&LayerStats> = chips.iter().flatten().collect();
    debug_assert!(!present.is_empty(), "chip 0 always simulates");
    let mut events = EventCounts::default();
    let mut core_cycles = Vec::with_capacity(present.len() * arch.n_cores);
    let mut elapsed = 0u64;
    let mut rank_elapsed = vec![0u64; tp];
    let mut rank_detections = vec![0u64; tp];
    let mut busy = 0usize; // chips with actual filter work
    for (chip, slot) in chips.iter().enumerate() {
        if let Some(s) = slot {
            events.add(&s.events);
            core_cycles.extend_from_slice(&s.core_cycles);
            elapsed = elapsed.max(s.elapsed);
            rank_elapsed[chip] = s.elapsed;
            rank_detections[chip] = s.events.fault_detections;
            if s.elapsed > 0 || s.events.weight_writes > 0 {
                busy += 1;
            }
        }
    }
    // Each extra chip-local program re-runs the Sync + End barriers.
    events.instrs -= 2 * (present.len() as u64 - 1);
    events.elapsed_cycles = elapsed;
    let (comm_cycles, comm_bytes) = if busy >= 2 {
        let bytes = layer_output_bytes(&net.layers[idx].kind);
        (all_gather_cycles(arch, bytes, busy), bytes - bytes / busy as u64)
    } else {
        (0, 0)
    };
    MergedLayer {
        stats: LayerStats {
            name: net.layers[idx].name.clone(),
            category: OpCategory::PimConvFc,
            events,
            core_cycles,
            elapsed,
        },
        rank_elapsed,
        rank_detections,
        comm_cycles,
        comm_bytes,
        net_idx: idx,
    }
}

/// One (layer, chip) job: partition the full layer's assignments,
/// re-lower this chip's subset (memoized under the shard-scoped
/// compile key), and simulate it (memoized under the matching sim
/// key). Chips that received no assignments return `None` — except
/// chip 0, which always simulates (possibly an empty program) so a
/// layer with no assignments still contributes its barrier
/// bookkeeping exactly like the single-chip run.
// expect(): callers only pass indices from `sim::pim_indices`, for
// which `get_or_compile` returns `Some` by definition.
#[allow(clippy::too_many_arguments, clippy::expect_used)]
fn simulate_chip_layer(
    net: &Network,
    idx: usize,
    sparsity: SparsityConfig,
    machine: &Machine,
    seed: u64,
    tp: usize,
    chip: usize,
    cache: &CompileCache,
    sim_cache: &SimCache,
) -> Option<LayerStats> {
    let arch = &machine.arch;
    let full = cache.get_or_compile(net, idx, sparsity, arch, seed).expect("PIM layer");
    let mine = partition_assignments(&full.assignments, arch, tp).swap_remove(chip);
    if mine.is_empty() && chip != 0 {
        return None;
    }
    // Per-chip defect patterns: each fleet member re-lowers its subset
    // under its own fault spec (`CellFaultSpec::for_chip`), whose key
    // bits land in the chip-scoped compile key. The full-layer artifact
    // stays under the root spec — packing ignores fault state, so every
    // chip partitions the identical assignment list.
    let chip_arch: ArchConfig;
    let sub_arch: &ArchConfig = if arch.cell_faults.enabled() && tp > 1 {
        chip_arch =
            ArchConfig { cell_faults: arch.cell_faults.for_chip(chip), ..(**arch).clone() };
        &chip_arch
    } else {
        &**arch
    };
    let key = CompileKey::new(net, idx, sparsity, sub_arch, seed).sharded(tp, chip);
    let (stats, _) = sim_cache.get_or_run_keyed(key.clone(), false, || {
        let sub =
            cache.get_or_insert_with(key, || compile_assignment_subset(&full, &mine, sub_arch));
        let x = arch.input_skipping.then(|| {
            let m = sub.prep.m.max(1);
            MatI8::from_vec(
                m,
                sub.prep.k,
                crate::models::synthesize_activations(
                    seed ^ ((idx as u64) << 20),
                    m * sub.prep.k,
                ),
            )
        });
        let (stats, _) = machine.run_pim_layer(&sub, x.as_ref(), false);
        (stats, None)
    });
    Some(stats)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn parse_covers_every_scheme_and_factors_hybrid() {
        assert_eq!(ShardSpec::parse(4, "tp").unwrap().scheme, ShardScheme::TensorParallel);
        assert_eq!(ShardSpec::parse(4, "pp").unwrap().scheme, ShardScheme::PipelineParallel);
        let hybrid = |chips| ShardSpec::parse(chips, "hybrid").unwrap().scheme;
        assert_eq!(hybrid(4), ShardScheme::Hybrid { tp: 2, pp: 2 });
        assert_eq!(hybrid(16), ShardScheme::Hybrid { tp: 4, pp: 4 });
        assert_eq!(hybrid(6), ShardScheme::Hybrid { tp: 3, pp: 2 });
        assert_eq!(hybrid(1), ShardScheme::Hybrid { tp: 1, pp: 1 });
        assert!(ShardSpec::parse(0, "tp").is_none());
        assert!(ShardSpec::parse(4, "??").is_none());
    }

    #[test]
    fn stage_partition_balances_and_covers() {
        let w = [10u64, 1, 1, 1, 10, 1, 1, 1];
        let st = partition_stages(&w, 3);
        assert_eq!(st.len(), 3);
        assert_eq!(st.first().unwrap().0, 0);
        assert_eq!(st.last().unwrap().1, w.len());
        for win in st.windows(2) {
            assert_eq!(win[0].1, win[1].0, "stages must be contiguous");
            assert!(win[0].0 < win[0].1, "stages must be non-empty");
        }
        let worst = st.iter().map(|&(a, b)| w[a..b].iter().sum::<u64>()).max().unwrap();
        assert!(worst <= 13, "DP should balance the two heavy layers, got {worst}");
        // more stages than layers: one layer each
        assert_eq!(partition_stages(&[5, 5], 8).len(), 2);
        assert!(partition_stages(&[], 4).is_empty());
    }

    #[test]
    fn all_gather_is_zero_for_one_chip_and_grows_with_chips() {
        let arch = ArchConfig::db_pim();
        assert_eq!(all_gather_cycles(&arch, 1 << 20, 1), 0);
        let c2 = all_gather_cycles(&arch, 1 << 20, 2);
        let c4 = all_gather_cycles(&arch, 1 << 20, 4);
        assert!(c2 > 0);
        assert!(c4 > c2, "more hops + larger remote share: {c4} vs {c2}");
    }
}
