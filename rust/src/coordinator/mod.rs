//! The coordinator: schedules simulation/verification jobs across
//! worker threads, runs the paper's experiments end-to-end, and emits
//! JSON reports.
//!
//! (The offline image has no tokio; the event loop is std threads with
//! scoped fork-join, which matches the workload — batch experiment
//! sweeps, not request serving.)

pub mod experiments;

use std::sync::Mutex;

/// Run `jobs` across up to `workers` threads, preserving output order.
pub fn run_parallel<T: Send, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    let queue: Mutex<Vec<(usize, F)>> = Mutex::new(jobs.into_iter().enumerate().rev().collect());
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    let workers = workers.clamp(1, n.max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((idx, f)) => {
                        let out = f();
                        results.lock().unwrap()[idx] = Some(out);
                    }
                    None => break,
                }
            });
        }
    });
    results.into_inner().unwrap().into_iter().map(|r| r.expect("job panicked")).collect()
}

/// Default worker count (leave headroom for the OS).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..32usize).map(|i| Box::new(move || i * i) as _).collect();
        let out = run_parallel(jobs, 4);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_single_worker() {
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> =
            (0..3u32).map(|i| Box::new(move || i + 1) as _).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3]);
    }
}
