//! The coordinator: the persistent work-stealing worker pool that every
//! parallel layer of the stack schedules into ([`pool`]), the
//! declarative experiment drivers ([`experiments`]) that regenerate the
//! paper's figures/tables on top of it, and the batched multi-tenant
//! serving frontend ([`serve`]) that replays request traffic over the
//! same pool and caches. The open-loop regime lives in [`serve_loop`]
//! (continuous batching on a virtual clock — [`clock`] — under seeded
//! arrivals — [`arrivals`] — with deterministic fault injection —
//! [`faults`]; DESIGN.md §11).
//!
//! Multi-chip fleets live in [`sharding`]: tensor/pipeline-parallel
//! partitioning of a network over several chips with a deterministic
//! interconnect cost model, merged back into ordinary `SimReport`s
//! (DESIGN.md §12).
//!
//! (The offline image has no tokio/rayon; [`pool`] is std threads with
//! a global injector + per-worker deques. Nested `scope()`s execute or
//! steal child jobs instead of spawning threads, so sweep × chip ×
//! layer × segment parallelism composes without oversubscription —
//! DESIGN.md §5/§8.)

pub mod arrivals;
pub mod clock;
pub mod experiments;
pub mod faults;
pub mod pool;
pub mod serve;
pub mod serve_loop;
pub mod sharding;

/// Default worker count (leave headroom for the OS).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 16)
}
