//! Open-loop continuous-batching serve loop with fault injection
//! (DESIGN.md §11).
//!
//! PR 5's [`super::serve`] replays a *closed-loop* trace: it plans all
//! batches up front and reports latency as if requests waited for each
//! other. This module is the open-loop counterpart — a long-running
//! deterministic admission loop driven by a seeded arrival process
//! ([`super::arrivals`]) on a virtual clock ([`super::clock`]), hardened
//! with an explicit fault model ([`super::faults`]):
//!
//! * **Bounded admission queue with load shedding** — when the queue is
//!   full, arriving (or retrying) requests get a typed
//!   [`Outcome::Shed`], never a panic and never unbounded memory.
//! * **Deadlines and EDF batch formation** — every request carries a
//!   relative deadline; the batcher always serves the earliest-deadline
//!   queued request next and fills the rest of the batch with
//!   compatible (same [`BatchKey`]) requests in deadline order.
//! * **Continuous batching** — chips are `max_batch`-lane servers;
//!   whenever lanes free up (a member finishes) the batcher immediately
//!   re-forms a batch from whatever is queued *now*, instead of waiting
//!   for the slowest member of a pre-planned batch. All events at one
//!   virtual instant are drained before batch formation, so
//!   simultaneous arrivals/completions batch together.
//! * **Faults, retries, timeouts** — transient attempt failures and
//!   latency spikes (per-attempt, hash-seeded) and whole-chip down
//!   intervals (per-chip seeded streams) are injected deterministically;
//!   the loop answers with bounded retries under full exponential
//!   backoff + deterministic jitter, per-request timeouts, and typed
//!   terminal outcomes ([`Outcome::Failed`] / [`Outcome::TimedOut`]).
//!
//! The loop itself is single-threaded discrete-event simulation; the
//! worker pool only parallelizes the `sim::simulate_batch` calls inside
//! one event, which are bit-identical for any worker count (DESIGN.md
//! §8). Hence an entire open-loop run — per-request outcomes, stats,
//! and the event log — is a pure function of the spec, replayable
//! bit-exactly anywhere (pinned by
//! `prop_open_loop_deterministic_across_worker_counts`).

use std::time::{Duration, Instant};

use crate::arch::ArchConfig;
use crate::compiler::SparsityConfig;
use crate::json::{self, arr, num, obj, str_, Value};
use crate::models::Registry;
use crate::sim;
use crate::util::{self, Rng};

use super::arrivals::ArrivalProcess;
use super::clock::{ms_to_ns, ns_to_ms, EventQueue, VirtualClock, VirtualNs};
use super::experiments::SweepStats;
use super::faults::{FaultInjector, FaultSpec};
use super::serve::{percentile, BatchKey, ServeCtx, ServeRequest};
use super::sharding::{self, ShardScheme, ShardSpec};

/// Terminal outcome of one open-loop request. Every request gets
/// exactly one; nothing in the loop panics on overload or faults.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed. `latency_ns` is virtual sojourn time (arrival →
    /// completion); `deadline_met` is the SLO bit.
    Done { latency_ns: VirtualNs, attempts: u32, deadline_met: bool },
    /// Rejected because the admission queue was full (at arrival:
    /// `attempts == 0`; on a retry re-entry: the attempts so far).
    Shed { attempts: u32 },
    /// Exceeded its per-request timeout before completing.
    TimedOut { attempts: u32 },
    /// Exhausted the retry budget on injected failures
    /// (`attempts == max_retries + 1`).
    Failed { attempts: u32 },
}

/// One request's identity plus its terminal outcome, in admission-id
/// order. `PartialEq`/`Eq` so replays can be compared wholesale.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestOutcome {
    /// Arrival index (also the fault-decision key).
    pub id: usize,
    pub model: String,
    pub arrival_ns: VirtualNs,
    pub outcome: Outcome,
}

/// A replayable open-loop serving workload: deployment + workload
/// templates + arrival process + loop/fault parameters. Entirely
/// seed-determined — same spec, same run.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenLoopSpec {
    /// Deployed model set (zoo names for [`OpenLoopSpec::run`]).
    pub models: Vec<String>,
    /// Request templates; each arrival is assigned one template by a
    /// seeded hash of its index.
    pub workload: Vec<ServeRequest>,
    pub arrivals: ArrivalProcess,
    /// Number of arrivals to draw from the process.
    pub requests: usize,
    /// Admission-queue bound; arrivals beyond it are shed.
    pub queue_cap: usize,
    /// Relative SLO deadline per request (ms of virtual time).
    pub deadline_ms: f64,
    /// Hard per-request timeout (ms of virtual time, >= deadline).
    pub timeout_ms: f64,
    /// Lanes per chip — the continuous batcher's batch-size cap.
    pub max_batch: usize,
    /// Number of `max_batch`-lane chips. With `scheme` unset these are
    /// independent replicas; with `scheme` set they gang into ONE
    /// logical `max_batch`-lane server of `chips` shards.
    pub chips: usize,
    /// Multi-chip sharding scheme (DESIGN.md §12). `None` (default)
    /// keeps the replica fleet semantics. `Some(scheme)` reinterprets
    /// `chips` as the shard width of a single logical server whose
    /// per-request service time comes from
    /// [`sharding::simulate_sharded`] — interconnect included via the
    /// merged report's pseudo-layer.
    pub scheme: Option<ShardScheme>,
    /// Retry budget per request (total attempts = max_retries + 1).
    pub max_retries: u32,
    /// Base backoff (ms); attempt `n` backs off
    /// `backoff_ms * 2^(n-1) * jitter`, jitter in [1, 2).
    pub backoff_ms: f64,
    /// Root seed: arrival times and template assignment.
    pub seed: u64,
    pub faults: FaultSpec,
    /// Record a human-readable event log in `LoopStats::events`
    /// (replay debugging and the event-order property test).
    pub trace_events: bool,
}

/// Summary of one open-loop run. Every field except `wall` (host time)
/// and `cache.{compile,sim}.dup_computes` (benign scheduling races,
/// DESIGN.md §8) is deterministic in the spec.
#[derive(Debug, Clone)]
pub struct LoopStats {
    /// Total arrivals drawn (= spec.requests).
    pub offered: usize,
    /// Arrivals that entered the queue (offered - shed-at-admission).
    pub admitted: usize,
    pub done: usize,
    pub shed: usize,
    pub failed: usize,
    pub timed_out: usize,
    /// Completions that met their deadline (the SLO numerator).
    pub deadline_met: usize,
    /// Retry attempts scheduled (backoff re-entries).
    pub retries: u64,
    /// Batches dispatched (continuous batching re-forms these live).
    pub batches: usize,
    pub peak_queue: usize,
    /// Offered load (nominal arrival rate, requests/s).
    pub offered_rps: f64,
    /// Deadline-met completions per virtual second.
    pub goodput_rps: f64,
    /// deadline_met / offered, in [0, 1] (0 for an empty run).
    pub slo_attainment: f64,
    /// Virtual time of the last terminal outcome (ms).
    pub makespan_ms: f64,
    /// Virtual sojourn latency of completed requests (ms).
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Host wall-clock of the run (not deterministic).
    pub wall: Duration,
    pub cache: SweepStats,
    /// Event log (empty unless `trace_events`): one line per event in
    /// deterministic virtual-time order.
    pub events: Vec<String>,
}

/// Seeded template assignment for arrival `i` — a one-shot hash stream,
/// independent of every other arrival.
fn pick_template(seed: u64, i: usize, n: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    Rng::new(seed ^ 0x5EED_7E3A_11AD_0001 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .below(n as u64) as usize
}

impl OpenLoopSpec {
    /// Reject every invalid parameter and workload index in one error
    /// (same all-indices policy as `ServeSpec`).
    pub fn validate(&self) -> Result<(), String> {
        self.arrivals.validate()?;
        self.faults.validate()?;
        let mut errs: Vec<String> = Vec::new();
        if self.requests > 0 && self.workload.is_empty() {
            errs.push("open-loop spec: empty workload with requests > 0".to_string());
        }
        let pos = |v: f64| v.is_finite() && v > 0.0;
        if !pos(self.deadline_ms) {
            errs.push(format!(
                "open-loop spec: deadline_ms must be finite and > 0, got {}",
                self.deadline_ms
            ));
        }
        if !pos(self.timeout_ms) {
            errs.push(format!(
                "open-loop spec: timeout_ms must be finite and > 0, got {}",
                self.timeout_ms
            ));
        }
        if !pos(self.backoff_ms) {
            errs.push(format!(
                "open-loop spec: backoff_ms must be finite and > 0, got {}",
                self.backoff_ms
            ));
        }
        if self.chips == 0 {
            errs.push("open-loop spec: chips must be >= 1".to_string());
        }
        if self.queue_cap == 0 {
            errs.push("open-loop spec: queue_cap must be >= 1".to_string());
        }
        if self.max_batch == 0 {
            errs.push("open-loop spec: max_batch must be >= 1".to_string());
        }
        for (i, r) in self.workload.iter().enumerate() {
            if !self.models.iter().any(|m| m == &r.model) {
                errs.push(format!("workload {i}: model {:?} is not in \"models\"", r.model));
            }
            if ArchConfig::by_name(&r.arch).is_none() {
                errs.push(format!("workload {i}: unknown arch preset {:?}", r.arch));
            }
            if !(0.0..1.0).contains(&r.sparsity.value_sparsity) {
                errs.push(format!("workload {i}: value sparsity must be in [0.0, 1.0)"));
            }
        }
        if errs.is_empty() { Ok(()) } else { Err(errs.join("; ")) }
    }

    /// Parse an open-loop spec. Required: `models`, `workload`,
    /// `arrivals`. Everything else defaults to the stock loop
    /// parameters (see field docs).
    pub fn from_json(v: &Value) -> Result<OpenLoopSpec, String> {
        let models = v
            .get("models")
            .and_then(Value::as_arr)
            .ok_or_else(|| "open-loop spec: missing \"models\" array".to_string())?
            .iter()
            .enumerate()
            .map(|(i, m)| {
                m.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| format!("open-loop spec: models[{i}] must be a string"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        let raw = v
            .get("workload")
            .and_then(Value::as_arr)
            .ok_or_else(|| "open-loop spec: missing \"workload\" array".to_string())?;
        let mut workload = Vec::with_capacity(raw.len());
        let mut errs: Vec<String> = Vec::new();
        for (i, r) in raw.iter().enumerate() {
            match ServeRequest::from_json(i, r) {
                Ok(t) => workload.push(t),
                Err(e) => errs.push(format!("workload {e}")),
            }
        }
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        let arrivals = ArrivalProcess::from_json(
            v.get("arrivals")
                .ok_or_else(|| "open-loop spec: missing \"arrivals\" object".to_string())?,
        )?;
        let faults = match v.get("faults") {
            None => FaultSpec::off(),
            Some(f) => FaultSpec::from_json(f)?,
        };
        let u = |key: &str, dflt: usize| -> Result<usize, String> {
            match v.get(key) {
                None => Ok(dflt),
                Some(x) => x.as_usize().ok_or_else(|| {
                    format!("open-loop spec: \"{key}\" must be a non-negative integer")
                }),
            }
        };
        let f = |key: &str, dflt: f64| -> Result<f64, String> {
            match v.get(key) {
                None => Ok(dflt),
                Some(x) => {
                    x.as_f64().ok_or_else(|| format!("open-loop spec: \"{key}\" must be a number"))
                }
            }
        };
        let deadline_ms = f("deadline_ms", 50.0)?;
        let chips = u("chips", 2)?;
        let scheme = match v.get("scheme") {
            None => None,
            Some(s) => {
                let name = s
                    .as_str()
                    .ok_or_else(|| "open-loop spec: \"scheme\" must be a string".to_string())?;
                let parsed = ShardSpec::parse(chips.max(1), name)
                    .ok_or_else(|| format!("open-loop spec: unknown scheme {name:?}"))?;
                Some(parsed.scheme)
            }
        };
        let spec = OpenLoopSpec {
            models,
            workload,
            arrivals,
            requests: u("requests", 32)?,
            queue_cap: u("queue_cap", 64)?,
            deadline_ms,
            timeout_ms: f("timeout_ms", 4.0 * deadline_ms)?,
            max_batch: u("max_batch", 8)?,
            chips,
            scheme,
            max_retries: u32::try_from(u("max_retries", 3)?)
                .map_err(|_| "open-loop spec: \"max_retries\" too large".to_string())?,
            backoff_ms: f("backoff_ms", 1.0)?,
            seed: u("seed", 42)? as u64,
            faults,
            trace_events: false,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("models", arr(self.models.iter().map(|m| str_(m)).collect())),
            ("workload", arr(self.workload.iter().map(ServeRequest::to_json).collect())),
            ("arrivals", self.arrivals.to_json()),
            ("requests", num(self.requests as f64)),
            ("queue_cap", num(self.queue_cap as f64)),
            ("deadline_ms", num(self.deadline_ms)),
            ("timeout_ms", num(self.timeout_ms)),
            ("max_batch", num(self.max_batch as f64)),
            ("chips", num(self.chips as f64)),
            ("max_retries", num(self.max_retries as f64)),
            ("backoff_ms", num(self.backoff_ms)),
            ("seed", num(self.seed as f64)),
            ("faults", self.faults.to_json()),
        ];
        if let Some(scheme) = self.scheme {
            fields.push(("scheme", str_(scheme.name())));
        }
        obj(fields)
    }

    /// Load a spec from a JSON file; every error names the file.
    pub fn load(path: &str) -> Result<OpenLoopSpec, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let v = json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
        OpenLoopSpec::from_json(&v).map_err(|e| format!("{path}: {e}"))
    }

    /// Run with a fresh [`ServeCtx`] over the spec's model list (zoo
    /// lookup). See [`OpenLoopSpec::run_with`].
    pub fn run(&self) -> Result<(Vec<RequestOutcome>, LoopStats), String> {
        let ctx = ServeCtx::new(Registry::from_names(&self.models)?);
        self.run_with(&ctx)
    }

    /// Run the open-loop serve loop through an existing serving
    /// context. Deterministic in the spec for any worker count.
    pub fn run_with(&self, ctx: &ServeCtx) -> Result<(Vec<RequestOutcome>, LoopStats), String> {
        self.validate()?;
        let mut errs: Vec<String> = Vec::new();
        for (i, r) in self.workload.iter().enumerate() {
            if ctx.registry.get(&r.model).is_none() {
                errs.push(format!("workload {i}: model {:?} is not deployed", r.model));
            }
        }
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        Ok(Runner::new(self, ctx).run())
    }

    /// Sweep offered load by scaling the arrival process by each factor
    /// and re-running the loop over one shared context (warm caches —
    /// exactly how a long-lived deployment would see the sweep).
    pub fn rate_sweep_with(
        &self,
        ctx: &ServeCtx,
        factors: &[f64],
    ) -> Result<Vec<(f64, LoopStats)>, String> {
        let mut out = Vec::with_capacity(factors.len());
        for &factor in factors {
            let mut point = self.clone();
            point.arrivals = self.arrivals.scaled(factor);
            let (_, stats) = point.run_with(ctx)?;
            out.push((factor, stats));
        }
        Ok(out)
    }

    /// [`OpenLoopSpec::rate_sweep_with`] over a fresh context.
    pub fn rate_sweep(&self, factors: &[f64]) -> Result<Vec<(f64, LoopStats)>, String> {
        let ctx = ServeCtx::new(Registry::from_names(&self.models)?);
        self.rate_sweep_with(&ctx, factors)
    }
}

/// Request lifecycle. `Pending` → (`Queued` ⇄ `InFlight` ⇄
/// `BackingOff`) → `Terminal`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RState {
    Pending,
    Queued,
    InFlight,
    BackingOff,
    Terminal,
}

struct Req {
    template: usize,
    arrival_ns: VirtualNs,
    deadline_at: VirtualNs,
    timeout_at: VirtualNs,
    attempts: u32,
    state: RState,
}

/// One simulated chip: a `max_batch`-lane server that can be down.
/// `epoch` invalidates in-flight completions across an outage.
struct Chip {
    down: bool,
    epoch: u64,
    busy: usize,
    inflight: Vec<usize>,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive(usize),
    Finish { chip: usize, epoch: u64, req: usize, attempt: u32, ok: bool },
    Timeout(usize),
    Retry(usize),
    ChipDown { chip: usize, up_at: VirtualNs },
    ChipUp(usize),
}

struct Runner<'a> {
    spec: &'a OpenLoopSpec,
    ctx: &'a ServeCtx,
    keys: Vec<BatchKey>,
    clock: VirtualClock,
    events: EventQueue<Ev>,
    reqs: Vec<Req>,
    /// Admission queue (request ids); EDF selection scans it, so order
    /// here is arrival order and does not matter for results.
    queue: Vec<usize>,
    chips: Vec<Chip>,
    inj: FaultInjector,
    outcomes: Vec<Option<Outcome>>,
    done_count: usize,
    admitted: usize,
    retries: u64,
    batches: usize,
    peak_queue: usize,
    log: Vec<String>,
}

impl<'a> Runner<'a> {
    fn new(spec: &'a OpenLoopSpec, ctx: &'a ServeCtx) -> Runner<'a> {
        let arrivals = spec.arrivals.times(spec.requests, spec.seed);
        let deadline = ms_to_ns(spec.deadline_ms).max(1);
        let timeout = ms_to_ns(spec.timeout_ms).max(1);
        let mut events = EventQueue::new();
        let mut reqs = Vec::with_capacity(spec.requests);
        for (i, &t) in arrivals.iter().enumerate() {
            reqs.push(Req {
                template: pick_template(spec.seed, i, spec.workload.len()),
                arrival_ns: t,
                deadline_at: t.saturating_add(deadline),
                timeout_at: t.saturating_add(timeout),
                attempts: 0,
                state: RState::Pending,
            });
            events.push(t, Ev::Arrive(i));
        }
        // A sharded fleet is ONE logical server: faults and outages hit
        // the whole gang at once, not per-shard replicas.
        let servers = if spec.scheme.is_some() { 1 } else { spec.chips };
        let mut inj = FaultInjector::new(spec.faults, servers);
        let chips = (0..servers)
            .map(|c| {
                if let Some((down_at, up_at)) = inj.next_down_window(c, 0) {
                    events.push(down_at, Ev::ChipDown { chip: c, up_at });
                }
                Chip { down: false, epoch: 0, busy: 0, inflight: Vec::new() }
            })
            .collect();
        Runner {
            spec,
            ctx,
            keys: spec.workload.iter().map(BatchKey::of).collect(),
            clock: VirtualClock::new(),
            events,
            outcomes: vec![None; spec.requests],
            reqs,
            queue: Vec::new(),
            chips,
            inj,
            done_count: 0,
            admitted: 0,
            retries: 0,
            batches: 0,
            peak_queue: 0,
            log: Vec::new(),
        }
    }

    fn trace(&mut self, msg: impl FnOnce() -> String) {
        if self.spec.trace_events {
            let line = format!("t={}ns {}", self.clock.now(), msg());
            self.log.push(line);
        }
    }

    fn run(mut self) -> (Vec<RequestOutcome>, LoopStats) {
        let t_host = Instant::now();
        while self.done_count < self.spec.requests {
            let Some((t, ev)) = self.events.pop() else { break };
            self.clock.advance_to(t);
            self.handle(ev);
            // Drain every event of this instant before forming batches:
            // simultaneous arrivals/completions batch together instead
            // of dispatching one by one. Handlers only schedule strictly
            // future events, so this inner drain terminates.
            while self.events.peek_time() == Some(self.clock.now()) {
                let (_, ev) = self.events.pop().expect("peeked event");
                self.handle(ev);
            }
            self.try_dispatch();
        }
        self.finish_run(t_host.elapsed())
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Arrive(i) => self.on_arrive(i),
            Ev::Finish { chip, epoch, req, attempt, ok } => {
                self.on_finish(chip, epoch, req, attempt, ok)
            }
            Ev::Timeout(i) => self.on_timeout(i),
            Ev::Retry(i) => self.on_retry(i),
            Ev::ChipDown { chip, up_at } => self.on_chip_down(chip, up_at),
            Ev::ChipUp(chip) => self.on_chip_up(chip),
        }
    }

    fn finish_req(&mut self, i: usize, outcome: Outcome) {
        debug_assert!(self.outcomes[i].is_none(), "request {i} finished twice");
        self.outcomes[i] = Some(outcome);
        self.reqs[i].state = RState::Terminal;
        self.done_count += 1;
    }

    fn enqueue(&mut self, i: usize) {
        self.reqs[i].state = RState::Queued;
        self.queue.push(i);
        self.peak_queue = self.peak_queue.max(self.queue.len());
    }

    fn on_arrive(&mut self, i: usize) {
        if self.queue.len() >= self.spec.queue_cap {
            self.trace(|| format!("shed r{i} at admission (queue full)"));
            self.finish_req(i, Outcome::Shed { attempts: 0 });
            return;
        }
        self.admitted += 1;
        self.enqueue(i);
        let timeout_at = self.reqs[i].timeout_at;
        self.events.push(timeout_at, Ev::Timeout(i));
        self.trace(|| format!("admit r{i}"));
    }

    fn on_timeout(&mut self, i: usize) {
        if self.reqs[i].state == RState::Terminal {
            return;
        }
        if self.reqs[i].state == RState::Queued {
            self.queue.retain(|&r| r != i);
        }
        // In-flight lanes free when their (now stale for this request)
        // Finish event lands; backing-off retries no-op on Terminal.
        let attempts = self.reqs[i].attempts;
        self.trace(|| format!("timeout r{i} (after {attempts} attempts)"));
        self.finish_req(i, Outcome::TimedOut { attempts });
    }

    fn on_retry(&mut self, i: usize) {
        if self.reqs[i].state == RState::Terminal {
            return;
        }
        if self.queue.len() >= self.spec.queue_cap {
            let attempts = self.reqs[i].attempts;
            self.trace(|| format!("shed r{i} on retry (queue full)"));
            self.finish_req(i, Outcome::Shed { attempts });
            return;
        }
        self.enqueue(i);
        self.trace(|| format!("requeue r{i} for retry"));
    }

    fn on_finish(&mut self, chip: usize, epoch: u64, req: usize, attempt: u32, ok: bool) {
        if self.chips[chip].epoch != epoch {
            // The chip went down after dispatch; its lanes were already
            // reset and the attempt already failed over to retry.
            return;
        }
        self.chips[chip].busy -= 1;
        self.chips[chip].inflight.retain(|&r| r != req);
        if self.reqs[req].state == RState::Terminal {
            return; // timed out while in flight — lane freed, that's all
        }
        if ok {
            let now = self.clock.now();
            let latency_ns = now - self.reqs[req].arrival_ns;
            let deadline_met = now <= self.reqs[req].deadline_at;
            self.trace(|| format!("done r{req} (attempt {attempt}, slo_met={deadline_met})"));
            self.finish_req(req, Outcome::Done { latency_ns, attempts: attempt, deadline_met });
        } else {
            self.trace(|| format!("fault r{req} (attempt {attempt} failed transiently)"));
            self.fail_attempt(req);
        }
    }

    /// One attempt of `req` failed (transient fault or chip outage):
    /// either exhaust the retry budget into a typed [`Outcome::Failed`]
    /// or schedule a backoff retry.
    fn fail_attempt(&mut self, req: usize) {
        if self.reqs[req].state == RState::Terminal {
            return;
        }
        let attempt = self.reqs[req].attempts;
        if attempt > self.spec.max_retries {
            self.trace(|| format!("fail r{req} (retry budget exhausted after {attempt} attempts)"));
            self.finish_req(req, Outcome::Failed { attempts: attempt });
            return;
        }
        self.retries += 1;
        self.reqs[req].state = RState::BackingOff;
        // Full exponential backoff with deterministic jitter in [1, 2).
        let exp = 2f64.powi(attempt.saturating_sub(1).min(16) as i32);
        let jitter = self.inj.backoff_jitter(req as u64, attempt as u64);
        let backoff = ms_to_ns(self.spec.backoff_ms * exp * jitter).max(1);
        let at = self.clock.now().saturating_add(backoff);
        self.events.push(at, Ev::Retry(req));
        self.trace(|| format!("backoff r{req} (attempt {attempt} failed)"));
    }

    fn on_chip_down(&mut self, chip: usize, up_at: VirtualNs) {
        self.chips[chip].down = true;
        self.chips[chip].epoch += 1;
        self.chips[chip].busy = 0;
        let inflight = std::mem::take(&mut self.chips[chip].inflight);
        self.trace(|| format!("chip {chip} down ({} in flight)", inflight.len()));
        for r in inflight {
            self.fail_attempt(r);
        }
        let at = up_at.max(self.clock.now().saturating_add(1));
        self.events.push(at, Ev::ChipUp(chip));
    }

    fn on_chip_up(&mut self, chip: usize) {
        self.chips[chip].down = false;
        self.trace(|| format!("chip {chip} up"));
        let now = self.clock.now();
        if let Some((down_at, up_at)) = self.inj.next_down_window(chip, now) {
            self.events.push(down_at, Ev::ChipDown { chip, up_at });
        }
    }

    /// Continuous EDF batch formation: while there is a queued request
    /// and an up chip with free lanes, serve the earliest-deadline
    /// request and fill the batch with compatible queued requests in
    /// deadline order.
    fn try_dispatch(&mut self) {
        let max_batch = self.spec.max_batch.max(1);
        loop {
            if self.queue.is_empty() {
                return;
            }
            let Some(c) = self.chips.iter().position(|ch| !ch.down && ch.busy < max_batch)
            else {
                return;
            };
            let free = max_batch - self.chips[c].busy;
            let &head = self
                .queue
                .iter()
                .min_by_key(|&&r| (self.reqs[r].deadline_at, r))
                .expect("queue checked non-empty");
            let key = self.keys[self.reqs[head].template].clone();
            let mut members: Vec<usize> = self
                .queue
                .iter()
                .copied()
                .filter(|&r| self.keys[self.reqs[r].template] == key)
                .collect();
            members.sort_by_key(|&r| (self.reqs[r].deadline_at, r));
            members.truncate(free);
            self.queue.retain(|r| !members.contains(r));
            self.dispatch(c, &key, &members);
        }
    }

    fn dispatch(&mut self, c: usize, key: &BatchKey, members: &[usize]) {
        let net = self.ctx.registry.get(&key.model).expect("validated at admission");
        let arch = ArchConfig::by_name(&key.arch).expect("validated at admission");
        let sp = SparsityConfig { value_sparsity: f64::from_bits(key.value_bits), fta: key.fta };
        // All members share the key, hence the seed (it is a compile
        // input — DESIGN.md §9). Replica fleets simulate one report per
        // member; a sharded fleet runs the gang once and every member
        // sees the same merged service time (interconnect included via
        // the merged report's pseudo-layer).
        let times_ns: Vec<u64> = match self.spec.scheme {
            Some(scheme) => {
                let shard = ShardSpec { chips: self.spec.chips, scheme };
                let rep = sharding::simulate_sharded(
                    &net,
                    sp,
                    &arch,
                    key.seed,
                    shard,
                    self.ctx.engine,
                    &self.ctx.compile,
                    &self.ctx.sim,
                )
                .report;
                vec![rep.time_ns(); members.len()]
            }
            None => {
                let seeds: Vec<u64> = members.iter().map(|_| key.seed).collect();
                sim::simulate_batch(
                    &net,
                    sp,
                    &arch,
                    &seeds,
                    self.ctx.engine,
                    &self.ctx.compile,
                    &self.ctx.sim,
                )
                .iter()
                .map(sim::SimReport::time_ns)
                .collect()
            }
        };
        self.batches += 1;
        let now = self.clock.now();
        let epoch = self.chips[c].epoch;
        for (&r, &t_ns) in members.iter().zip(&times_ns) {
            self.reqs[r].attempts += 1;
            let attempt = self.reqs[r].attempts;
            let ok = !self.inj.attempt_fails(r as u64, attempt as u64);
            let factor = self.inj.latency_factor(r as u64, attempt as u64);
            let svc = ((t_ns as f64) * factor).round().max(1.0) as VirtualNs;
            self.reqs[r].state = RState::InFlight;
            self.chips[c].busy += 1;
            self.chips[c].inflight.push(r);
            self.events
                .push(now.saturating_add(svc), Ev::Finish { chip: c, epoch, req: r, attempt, ok });
        }
        let n = members.len();
        self.trace(|| format!("dispatch batch of {n} on chip {c} ({}@{})", key.model, key.arch));
    }

    fn finish_run(self, wall: Duration) -> (Vec<RequestOutcome>, LoopStats) {
        let outcomes: Vec<RequestOutcome> = self
            .outcomes
            .iter()
            .enumerate()
            .map(|(i, o)| RequestOutcome {
                id: i,
                model: self.spec.workload[self.reqs[i].template].model.clone(),
                arrival_ns: self.reqs[i].arrival_ns,
                outcome: o.expect("event loop drained with open requests"),
            })
            .collect();
        let (mut done, mut shed, mut failed, mut timed_out, mut met) = (0, 0, 0, 0, 0);
        let mut lat: Vec<f64> = Vec::new();
        for o in &outcomes {
            match o.outcome {
                Outcome::Done { latency_ns, deadline_met, .. } => {
                    done += 1;
                    if deadline_met {
                        met += 1;
                    }
                    lat.push(ns_to_ms(latency_ns));
                }
                Outcome::Shed { .. } => shed += 1,
                Outcome::TimedOut { .. } => timed_out += 1,
                Outcome::Failed { .. } => failed += 1,
            }
        }
        let mut sorted = lat.clone();
        sorted.sort_by(f64::total_cmp);
        let offered = outcomes.len();
        let makespan_ms = ns_to_ms(self.clock.now());
        let makespan_s = makespan_ms / 1e3;
        let stats = LoopStats {
            offered,
            admitted: self.admitted,
            done,
            shed,
            failed,
            timed_out,
            deadline_met: met,
            retries: self.retries,
            batches: self.batches,
            peak_queue: self.peak_queue,
            offered_rps: self.spec.arrivals.nominal_rps(),
            goodput_rps: if makespan_s > 0.0 { met as f64 / makespan_s } else { 0.0 },
            slo_attainment: if offered > 0 { met as f64 / offered as f64 } else { 0.0 },
            makespan_ms,
            mean_ms: util::mean(&lat),
            p50_ms: percentile(&sorted, 50.0),
            p99_ms: percentile(&sorted, 99.0),
            wall,
            cache: SweepStats { compile: self.ctx.compile.stats(), sim: self.ctx.sim.stats() },
            events: self.log,
        };
        (outcomes, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fixtures::{small_net, tiny_net};

    fn tpl(model: &str, seed: u64) -> ServeRequest {
        ServeRequest {
            model: model.into(),
            arch: "db-pim".into(),
            sparsity: SparsityConfig::hybrid(0.5),
            seed,
        }
    }

    fn fixture_ctx() -> ServeCtx {
        ServeCtx::new(Registry::from_networks(vec![small_net(), tiny_net()]))
    }

    fn base_spec() -> OpenLoopSpec {
        OpenLoopSpec {
            models: vec!["small".into(), "tiny".into()],
            workload: vec![tpl("small", 1), tpl("tiny", 2)],
            arrivals: ArrivalProcess::Poisson { rate_rps: 2000.0 },
            requests: 24,
            queue_cap: 64,
            deadline_ms: 1e6,
            timeout_ms: 4e6,
            max_batch: 4,
            chips: 2,
            scheme: None,
            max_retries: 3,
            backoff_ms: 0.5,
            seed: 42,
            faults: FaultSpec::off(),
            trace_events: false,
        }
    }

    #[test]
    fn healthy_run_completes_every_request() {
        let (outcomes, stats) = base_spec().run_with(&fixture_ctx()).unwrap();
        assert_eq!(outcomes.len(), 24);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id, i);
            match o.outcome {
                Outcome::Done { latency_ns, attempts, deadline_met } => {
                    assert!(latency_ns > 0);
                    assert_eq!(attempts, 1);
                    assert!(deadline_met);
                }
                other => panic!("request {i} not served: {other:?}"),
            }
        }
        assert_eq!(stats.done, 24);
        assert_eq!(stats.admitted, 24);
        assert_eq!(stats.shed + stats.failed + stats.timed_out, 0);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.slo_attainment, 1.0);
        assert!(stats.mean_ms > 0.0 && stats.p99_ms >= stats.p50_ms);
        assert!(stats.makespan_ms > 0.0 && stats.goodput_rps > 0.0);
        assert!(stats.batches >= 1 && stats.batches <= 24);
    }

    #[test]
    fn zero_requests_yield_well_defined_zero_stats() {
        let mut spec = base_spec();
        spec.requests = 0;
        spec.workload.clear(); // even an empty workload is fine at 0 requests
        let (outcomes, stats) = spec.run_with(&fixture_ctx()).unwrap();
        assert!(outcomes.is_empty());
        assert_eq!(stats.offered, 0);
        assert_eq!(stats.done + stats.shed + stats.failed + stats.timed_out, 0);
        // all ratios are well-defined zeros, not NaN
        assert_eq!(stats.slo_attainment, 0.0);
        assert_eq!(stats.goodput_rps, 0.0);
        assert_eq!(stats.mean_ms, 0.0);
        assert_eq!(stats.p50_ms, 0.0);
        assert_eq!(stats.p99_ms, 0.0);
        assert_eq!(stats.makespan_ms, 0.0);
    }

    #[test]
    fn saturation_sheds_with_typed_outcomes_and_no_panics() {
        let mut spec = base_spec();
        spec.arrivals = ArrivalProcess::Poisson { rate_rps: 1e9 }; // far past saturation
        spec.requests = 64;
        spec.queue_cap = 4;
        spec.chips = 1;
        spec.max_batch = 2;
        spec.deadline_ms = 0.05;
        spec.timeout_ms = 0.2;
        let (outcomes, stats) = spec.run_with(&fixture_ctx()).unwrap();
        assert_eq!(outcomes.len(), 64);
        assert_eq!(stats.done + stats.shed + stats.failed + stats.timed_out, 64);
        assert!(stats.shed > 0, "overload must shed: {stats:?}");
        assert!(stats.peak_queue <= 4, "queue bound violated: {}", stats.peak_queue);
        assert!(stats.slo_attainment < 1.0);
    }

    #[test]
    fn continuous_batching_reforms_batches_as_lanes_free() {
        let mut spec = base_spec();
        // 8 simultaneous compatible arrivals onto one 4-lane chip:
        // one batch of 4 now, one batch of 4 re-formed at completion.
        spec.workload = vec![tpl("small", 1)];
        spec.models = vec!["small".into()];
        spec.arrivals = ArrivalProcess::Trace { times_ms: vec![0.0; 8] };
        spec.requests = 8;
        spec.chips = 1;
        spec.max_batch = 4;
        spec.queue_cap = 16;
        let (outcomes, stats) = spec.run_with(&fixture_ctx()).unwrap();
        assert!(outcomes.iter().all(|o| matches!(o.outcome, Outcome::Done { .. })));
        assert_eq!(stats.done, 8);
        assert_eq!(stats.batches, 2, "continuous batcher should form 2 batches of 4");
        assert!(stats.peak_queue >= 4);
    }

    #[test]
    fn serve_loop_fault_exhaustion_yields_typed_failures() {
        let mut spec = base_spec();
        spec.requests = 6;
        spec.max_retries = 2;
        spec.faults = FaultSpec { seed: 9, transient_rate: 1.0, ..FaultSpec::off() };
        let ctx = fixture_ctx();
        let (outcomes, stats) = spec.run_with(&ctx).unwrap();
        for o in &outcomes {
            assert_eq!(
                o.outcome,
                Outcome::Failed { attempts: 3 },
                "every attempt faults, budget is 2 retries"
            );
        }
        assert_eq!(stats.failed, 6);
        assert_eq!(stats.done, 0);
        assert_eq!(stats.retries, 12, "6 requests x 2 retries each");
        // the context (pool, caches) is not poisoned: a healthy run
        // through the same ctx still completes
        let mut healthy = base_spec();
        healthy.requests = 4;
        let (_, s2) = healthy.run_with(&ctx).unwrap();
        assert_eq!(s2.done, 4);
    }

    #[test]
    fn sharded_fleet_serves_as_one_logical_server() {
        let mut spec = base_spec();
        spec.workload = vec![tpl("small", 1)];
        spec.models = vec!["small".into()];
        spec.requests = 8;
        spec.chips = 2;
        spec.scheme = Some(ShardScheme::TensorParallel);
        let (outcomes, stats) = spec.run_with(&fixture_ctx()).unwrap();
        assert!(outcomes.iter().all(|o| matches!(o.outcome, Outcome::Done { .. })));
        assert_eq!(stats.done, 8);
        // the two chips are shards of one server, not two replicas —
        // the run must replay bit-exactly like any other spec
        let (o2, _) = spec.run_with(&fixture_ctx()).unwrap();
        assert_eq!(outcomes, o2);
    }

    #[test]
    fn serve_loop_replays_bit_exactly() {
        let mut spec = base_spec();
        spec.requests = 16;
        spec.deadline_ms = 1.0;
        spec.timeout_ms = 4.0;
        spec.faults = FaultSpec::default_with_seed(5);
        spec.trace_events = true;
        let (o1, s1) = spec.run_with(&fixture_ctx()).unwrap();
        let (o2, s2) = spec.run_with(&fixture_ctx()).unwrap();
        assert_eq!(o1, o2, "outcomes must replay bit-exactly");
        assert_eq!(s1.events, s2.events, "event order must replay bit-exactly");
        assert_eq!(
            (s1.done, s1.shed, s1.failed, s1.timed_out, s1.retries, s1.batches, s1.peak_queue),
            (s2.done, s2.shed, s2.failed, s2.timed_out, s2.retries, s2.batches, s2.peak_queue)
        );
        assert_eq!(s1.makespan_ms, s2.makespan_ms);
        assert!(!s1.events.is_empty());
    }

    #[test]
    fn rate_sweep_degrades_gracefully() {
        let mut spec = base_spec();
        spec.requests = 32;
        spec.queue_cap = 8;
        spec.chips = 1;
        spec.max_batch = 2;
        spec.deadline_ms = 0.05;
        spec.timeout_ms = 0.2;
        spec.arrivals = ArrivalProcess::Poisson { rate_rps: 1e4 };
        let ctx = fixture_ctx();
        let sweep = spec.rate_sweep_with(&ctx, &[1.0, 1e4]).unwrap();
        assert_eq!(sweep.len(), 2);
        for (_, s) in &sweep {
            assert_eq!(s.done + s.shed + s.failed + s.timed_out, 32, "no lost requests");
        }
        // past saturation the load is shed, never panicked on
        let (f_hi, hi) = &sweep[1];
        assert_eq!(*f_hi, 1e4);
        assert!(hi.shed > 0, "saturated point must shed: {hi:?}");
        assert!(hi.offered_rps > sweep[0].1.offered_rps);
    }

    #[test]
    fn validate_reports_all_bad_indices_and_load_names_file() {
        let mut spec = base_spec();
        spec.workload = vec![
            tpl("ghost", 1),                  // not in models
            tpl("small", 2),                  // fine
            ServeRequest { arch: "warp".into(), ..tpl("tiny", 3) }, // bad arch
        ];
        let err = spec.validate().unwrap_err();
        assert!(err.contains("workload 0"), "{err}");
        assert!(err.contains("workload 2"), "{err}");
        assert!(!err.contains("workload 1"), "{err}");
        // degenerate loop parameters are all reported too
        let mut bad = base_spec();
        bad.deadline_ms = f64::NAN;
        bad.chips = 0;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("deadline_ms") && err.contains("chips"), "{err}");
        // file errors name the file
        let err = OpenLoopSpec::load("/nonexistent/openloop.json").unwrap_err();
        assert!(err.contains("/nonexistent/openloop.json"), "{err}");
    }

    #[test]
    fn example_openloop_spec_parses_and_resolves() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/serve_openloop.json");
        let spec = OpenLoopSpec::load(path).expect("examples/serve_openloop.json must stay valid");
        assert!(matches!(spec.arrivals, ArrivalProcess::Bursty { .. }), "example is bursty");
        assert!(spec.requests > 0 && !spec.workload.is_empty());
        assert!(spec.faults.enabled(), "example exercises the fault model");
        // every workload model resolves in the zoo registry
        let reg = Registry::from_names(&spec.models).unwrap();
        for t in &spec.workload {
            assert!(reg.get(&t.model).is_some(), "undeployed model {}", t.model);
        }
    }

    #[test]
    fn spec_json_roundtrip() {
        let mut spec = base_spec();
        spec.faults = FaultSpec::default_with_seed(3);
        spec.arrivals =
            ArrivalProcess::Bursty { base_rps: 100.0, burst_rps: 5000.0, mean_phase_ms: 10.0 };
        let back = OpenLoopSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // a sharded spec round-trips its scheme; unknown names error
        spec.scheme = Some(ShardScheme::PipelineParallel);
        let back = OpenLoopSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        let mut bad_scheme = spec.to_json();
        if let Value::Obj(fields) = &mut bad_scheme {
            fields.insert("scheme".to_string(), str_("warp"));
        }
        let err = OpenLoopSpec::from_json(&bad_scheme).unwrap_err();
        assert!(err.contains("scheme"), "{err}");
        // defaults: a minimal spec parses with stock parameters
        let v = json::parse(
            r#"{"models": ["small"],
                "workload": [{"model": "small", "seed": 1}],
                "arrivals": {"kind": "poisson", "rate_rps": 100.0}}"#,
        )
        .unwrap();
        let d = OpenLoopSpec::from_json(&v).unwrap();
        assert_eq!(d.queue_cap, 64);
        assert_eq!(d.max_retries, 3);
        assert_eq!(d.timeout_ms, 4.0 * d.deadline_ms);
        assert!(!d.faults.enabled());
        // workload errors accumulate across indices
        let bad = json::parse(
            r#"{"models": [], "workload": [{"seed": 1}, {"model": "m", "seed": 2}, {"seed": 3}],
                "arrivals": {"kind": "poisson", "rate_rps": 100.0}}"#,
        )
        .unwrap();
        let err = OpenLoopSpec::from_json(&bad).unwrap_err();
        assert!(err.contains("request 0") && err.contains("request 2"), "{err}");
    }
}
