//! Seeded open-loop arrival processes (DESIGN.md §11).
//!
//! A closed-loop replay (PR 5's `dbpim serve --replay`) issues the next
//! request only when the previous one is done, so it can never exhibit
//! saturation: offered load adapts to service capacity by construction.
//! The open-loop serve loop instead draws arrival *times* from one of
//! the processes below — requests arrive whether or not the system is
//! keeping up, which is what exposes backpressure, shedding and tail
//! blow-up past the saturation point.
//!
//! Every process is a pure function of `(spec, seed)`: the same seed
//! always produces the same arrival times, which is half of the serve
//! loop's bit-exact replay contract (the other half is the virtual
//! clock in [`super::clock`]).

use crate::json::{self, arr, num, obj, str_, Value};
use crate::util::Rng;

use super::clock::VirtualNs;

/// An open-loop arrival process. Times are virtual nanoseconds from the
/// start of the run.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant mean rate (requests/second).
    Poisson { rate_rps: f64 },
    /// Two-state Markov-modulated Poisson process: a calm phase at
    /// `base_rps` and a burst phase at `burst_rps`, with exponentially
    /// distributed phase dwell times of mean `mean_phase_ms` each.
    Bursty { base_rps: f64, burst_rps: f64, mean_phase_ms: f64 },
    /// Replay of explicit arrival offsets (milliseconds, ascending).
    /// When more arrivals are requested than the trace holds, the
    /// inter-arrival deltas cycle, extending the finite trace into an
    /// open-ended stream with the same shape.
    Trace { times_ms: Vec<f64> },
}

/// Exponential variate with the given rate (events per second),
/// returned in nanoseconds.
fn exp_ns(rng: &mut Rng, rate_rps: f64) -> f64 {
    // 1 - f64() is in (0, 1], so ln is finite and <= 0.
    -(1.0 - rng.f64()).ln() / rate_rps * 1e9
}

impl ArrivalProcess {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Trace { .. } => "trace",
        }
    }

    /// Long-run mean offered rate (requests/second) — the x-axis of a
    /// rate sweep. Bursty phases have equal mean dwell, so the mean
    /// rate is the plain average of the two phase rates.
    pub fn nominal_rps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_rps } => *rate_rps,
            ArrivalProcess::Bursty { base_rps, burst_rps, .. } => 0.5 * (base_rps + burst_rps),
            ArrivalProcess::Trace { times_ms } => {
                if times_ms.len() < 2 {
                    return 0.0;
                }
                let span_ms = times_ms[times_ms.len() - 1] - times_ms[0];
                if span_ms <= 0.0 {
                    return 0.0;
                }
                (times_ms.len() - 1) as f64 / (span_ms / 1e3)
            }
        }
    }

    /// The same process with its offered load scaled by `factor`
    /// (rate-sweep axis): rates multiply, trace gaps divide.
    pub fn scaled(&self, factor: f64) -> ArrivalProcess {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                ArrivalProcess::Poisson { rate_rps: rate_rps * factor }
            }
            ArrivalProcess::Bursty { base_rps, burst_rps, mean_phase_ms } => {
                ArrivalProcess::Bursty {
                    base_rps: base_rps * factor,
                    burst_rps: burst_rps * factor,
                    mean_phase_ms: *mean_phase_ms,
                }
            }
            ArrivalProcess::Trace { times_ms } => ArrivalProcess::Trace {
                times_ms: times_ms.iter().map(|t| t / factor).collect(),
            },
        }
    }

    /// Reject degenerate parameters up front (admission errors, never
    /// worker panics).
    pub fn validate(&self) -> Result<(), String> {
        let finite_pos = |v: f64| v.is_finite() && v > 0.0;
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                if !finite_pos(*rate_rps) {
                    return Err(format!(
                        "poisson arrivals: rate must be finite and > 0, got {rate_rps}"
                    ));
                }
            }
            ArrivalProcess::Bursty { base_rps, burst_rps, mean_phase_ms } => {
                if !finite_pos(*base_rps) || !finite_pos(*burst_rps) {
                    return Err(format!(
                        "bursty arrivals: rates must be finite and > 0, got base {base_rps} / burst {burst_rps}"
                    ));
                }
                if !finite_pos(*mean_phase_ms) {
                    return Err(format!(
                        "bursty arrivals: mean_phase_ms must be finite and > 0, got {mean_phase_ms}"
                    ));
                }
            }
            ArrivalProcess::Trace { times_ms } => {
                if times_ms.is_empty() {
                    return Err("trace arrivals: empty times".to_string());
                }
                for (i, w) in times_ms.windows(2).enumerate() {
                    if w[1] < w[0] {
                        return Err(format!("trace arrivals: times[{}] < times[{}]", i + 1, i));
                    }
                }
                if times_ms.iter().any(|t| !t.is_finite() || *t < 0.0) {
                    return Err("trace arrivals: times must be finite and >= 0".to_string());
                }
            }
        }
        Ok(())
    }

    /// The first `n` arrival times under this process, deterministic in
    /// `seed`. Times are non-decreasing.
    pub fn times(&self, n: usize, seed: u64) -> Vec<VirtualNs> {
        let mut out = Vec::with_capacity(n);
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                let mut rng = Rng::new(seed ^ 0xA881_55C4_11E0_97D3);
                let mut t = 0.0f64;
                for _ in 0..n {
                    t += exp_ns(&mut rng, *rate_rps);
                    out.push(t as VirtualNs);
                }
            }
            ArrivalProcess::Bursty { base_rps, burst_rps, mean_phase_ms } => {
                let mut rng = Rng::new(seed ^ 0xB0B5_7D0C_6A41_29F1);
                let phase_rate = 1e3 / mean_phase_ms; // phase switches per second
                let mut t = 0.0f64;
                let mut burst = false;
                let mut phase_end = exp_ns(&mut rng, phase_rate);
                for _ in 0..n {
                    loop {
                        let rate = if burst { *burst_rps } else { *base_rps };
                        let dt = exp_ns(&mut rng, rate);
                        if t + dt <= phase_end {
                            t += dt;
                            break;
                        }
                        // Exponential inter-arrivals are memoryless, so
                        // restarting the draw at the phase boundary is
                        // statistically exact.
                        t = phase_end;
                        burst = !burst;
                        phase_end = t + exp_ns(&mut rng, phase_rate);
                    }
                    out.push(t as VirtualNs);
                }
            }
            ArrivalProcess::Trace { times_ms } => {
                // Cycle: repeat the trace shifted by one full period per
                // lap. The period is last + mean-gap so the wrap gap
                // matches the trace's own cadence.
                let len = times_ms.len();
                let mean_gap = if len >= 2 {
                    (times_ms[len - 1] - times_ms[0]) / (len - 1) as f64
                } else {
                    1.0
                };
                let period = times_ms[len - 1] + mean_gap.max(1e-6);
                for i in 0..n {
                    let (lap, j) = (i / len, i % len);
                    let t_ms = times_ms[j] + lap as f64 * period;
                    out.push(super::clock::ms_to_ns(t_ms));
                }
            }
        }
        // Belt and braces: the serve loop requires monotone arrivals.
        for i in 1..out.len() {
            if out[i] < out[i - 1] {
                out[i] = out[i - 1];
            }
        }
        out
    }

    /// Parse from a spec object: `{"kind": "poisson", "rate_rps": R}` |
    /// `{"kind": "bursty", "base_rps", "burst_rps", "mean_phase_ms"}` |
    /// `{"kind": "trace", "times_ms": [...]}`.
    pub fn from_json(v: &Value) -> Result<ArrivalProcess, String> {
        let kind = v
            .get("kind")
            .and_then(Value::as_str)
            .ok_or_else(|| "arrivals: missing string \"kind\"".to_string())?;
        let f = |key: &str| -> Result<f64, String> {
            v.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("arrivals ({kind}): missing number \"{key}\""))
        };
        let p = match kind {
            "poisson" => ArrivalProcess::Poisson { rate_rps: f("rate_rps")? },
            "bursty" => ArrivalProcess::Bursty {
                base_rps: f("base_rps")?,
                burst_rps: f("burst_rps")?,
                mean_phase_ms: f("mean_phase_ms")?,
            },
            "trace" => {
                let times = v
                    .get("times_ms")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| "arrivals (trace): missing \"times_ms\" array".to_string())?
                    .iter()
                    .enumerate()
                    .map(|(i, t)| {
                        t.as_f64().ok_or_else(|| {
                            format!("arrivals (trace): times_ms[{i}] must be a number")
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                ArrivalProcess::Trace { times_ms: times }
            }
            other => return Err(format!("arrivals: unknown kind {other:?}")),
        };
        p.validate()?;
        Ok(p)
    }

    pub fn to_json(&self) -> Value {
        match self {
            ArrivalProcess::Poisson { rate_rps } => obj(vec![
                ("kind", str_("poisson")),
                ("rate_rps", num(*rate_rps)),
            ]),
            ArrivalProcess::Bursty { base_rps, burst_rps, mean_phase_ms } => obj(vec![
                ("kind", str_("bursty")),
                ("base_rps", num(*base_rps)),
                ("burst_rps", num(*burst_rps)),
                ("mean_phase_ms", num(*mean_phase_ms)),
            ]),
            ArrivalProcess::Trace { times_ms } => obj(vec![
                ("kind", str_("trace")),
                ("times_ms", arr(times_ms.iter().map(|t| num(*t)).collect())),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_seeded_monotone_and_rate_accurate() {
        let p = ArrivalProcess::Poisson { rate_rps: 1000.0 };
        let a = p.times(4000, 7);
        let b = p.times(4000, 7);
        assert_eq!(a, b, "same seed must replay bit-exactly");
        assert_ne!(a, p.times(4000, 8), "different seeds must differ");
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "times must be non-decreasing");
        // 4000 arrivals at 1000 rps ≈ 4 s of virtual time (loose bound)
        let span_s = *a.last().unwrap() as f64 / 1e9;
        assert!((3.0..5.0).contains(&span_s), "span {span_s}s");
    }

    #[test]
    fn bursty_mixes_two_rates() {
        let p =
            ArrivalProcess::Bursty { base_rps: 100.0, burst_rps: 10_000.0, mean_phase_ms: 20.0 };
        let a = p.times(2000, 42);
        assert_eq!(a, p.times(2000, 42));
        assert!(a.windows(2).all(|w| w[1] >= w[0]));
        // the mean observed rate sits strictly between the two phase
        // rates (loose — both phases must actually contribute)
        let span_s = (*a.last().unwrap() - a[0]) as f64 / 1e9;
        let rate = (a.len() - 1) as f64 / span_s;
        assert!(rate > 150.0 && rate < 9000.0, "observed rate {rate}");
        assert!((p.nominal_rps() - 5050.0).abs() < 1e-9);
    }

    #[test]
    fn trace_replays_and_cycles() {
        let p = ArrivalProcess::Trace { times_ms: vec![0.0, 1.0, 3.0] };
        let a = p.times(6, 0);
        // period = 3.0 + mean gap 1.5 = 4.5 ms
        let ms: Vec<f64> = a.iter().map(|&t| t as f64 / 1e6).collect();
        let want = [0.0, 1.0, 3.0, 4.5, 5.5, 7.5];
        for (got, want) in ms.iter().zip(want) {
            assert!((got - want).abs() < 1e-6, "{ms:?}");
        }
    }

    #[test]
    fn validate_rejects_degenerate_processes() {
        assert!(ArrivalProcess::Poisson { rate_rps: 0.0 }.validate().is_err());
        assert!(ArrivalProcess::Poisson { rate_rps: f64::NAN }.validate().is_err());
        assert!(ArrivalProcess::Bursty { base_rps: 1.0, burst_rps: -1.0, mean_phase_ms: 5.0 }
            .validate()
            .is_err());
        assert!(ArrivalProcess::Bursty { base_rps: 1.0, burst_rps: 2.0, mean_phase_ms: 0.0 }
            .validate()
            .is_err());
        assert!(ArrivalProcess::Trace { times_ms: vec![] }.validate().is_err());
        assert!(ArrivalProcess::Trace { times_ms: vec![2.0, 1.0] }.validate().is_err());
        assert!(ArrivalProcess::Poisson { rate_rps: 10.0 }.validate().is_ok());
    }

    #[test]
    fn json_roundtrip_all_kinds() {
        for p in [
            ArrivalProcess::Poisson { rate_rps: 500.0 },
            ArrivalProcess::Bursty { base_rps: 100.0, burst_rps: 2000.0, mean_phase_ms: 25.0 },
            ArrivalProcess::Trace { times_ms: vec![0.0, 0.5, 2.0] },
        ] {
            let back = ArrivalProcess::from_json(&p.to_json()).unwrap();
            assert_eq!(back, p);
        }
        assert!(ArrivalProcess::from_json(&json::parse("{\"kind\": \"warp\"}").unwrap()).is_err());
        assert!(ArrivalProcess::from_json(&json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn scaling_moves_the_nominal_rate() {
        let p = ArrivalProcess::Poisson { rate_rps: 100.0 };
        assert!((p.scaled(4.0).nominal_rps() - 400.0).abs() < 1e-9);
        let t = ArrivalProcess::Trace { times_ms: vec![0.0, 2.0, 4.0] };
        // halving every gap doubles the rate
        assert!((t.scaled(2.0).nominal_rps() - 2.0 * t.nominal_rps()).abs() < 1e-9);
    }
}
