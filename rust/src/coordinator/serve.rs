//! Batched multi-tenant serving frontend.
//!
//! Everything below the coordinator was built for offline figure
//! sweeps; this module adds the request-serving path of the ROADMAP
//! north star: a deterministic, replayable admission queue over a
//! multi-model [`Registry`], a dynamic batcher that groups compatible
//! requests onto shared compiled state, and a fan-out over the
//! work-stealing pool that returns results in admission order —
//! bit-identical to serial per-request simulation for any batch size
//! and worker count (DESIGN.md §8/§9, pinned by
//! `prop_serve_batched_bit_identical`).
//!
//! Pipeline:
//!
//! 1. **Admission** — a [`ServeSpec`] names the deployed models and a
//!    replayable traffic trace ([`ServeRequest`]: model id, activation
//!    seed, precision/sparsity config, arch preset). Requests are
//!    admitted in trace order; unknown models or arch presets are
//!    admission errors, never panics.
//! 2. **Batching** — the dynamic batcher walks the queue in admission
//!    order and groups requests with equal [`BatchKey`]s into batches
//!    of at most `max_batch`. The key carries exactly the inputs of
//!    `compiler::cache::CompileKey` (model, arch preset, sparsity
//!    config, seed — in perf mode the seed pins both the synthesized
//!    checkpoint and the activations, so it is a compile input), so
//!    the requests of one batch share one compiled `Program` per
//!    layer and one `SimCache` entry. Batches of *different* tenants
//!    still share both caches through the long-lived [`ServeCtx`].
//! 3. **Execution** — batches fan out over `coordinator::pool`; each
//!    batch runs [`sim::simulate_batch`], which flattens its
//!    (request × layer) jobs into the same pool, nesting with the
//!    per-segment parallelism exactly like the sweep drivers.
//! 4. **Completion** — per-request reports scatter back to their
//!    admission slots; [`ServeSpec::run`] returns them in admission
//!    order plus a [`ServeStats`] (simulated latency percentiles,
//!    host throughput, cross-tenant cache counters).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::arch::ArchConfig;
use crate::compiler::{CompileCache, SparsityConfig};
use crate::json::{self, arr, num, obj, str_, Value};
use crate::models::Registry;
use crate::sim::{self, Engine, SimCache, SimReport};
use crate::util;

use super::experiments::SweepStats;
use super::pool;
use super::sharding::{self, ShardSpec};

/// One admitted request: which deployed model to run, under which arch
/// preset and precision/sparsity configuration, on which activation
/// seed. Replay traces are lists of these (see [`ServeSpec::from_json`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRequest {
    /// Model id — must be registered in the spec's `models` list.
    pub model: String,
    /// Arch preset name (`ArchConfig::by_name` spellings).
    pub arch: String,
    /// Precision/sparsity configuration the request runs under.
    pub sparsity: SparsityConfig,
    /// Activation seed. Perf-mode simulation synthesizes the checkpoint
    /// and the activations from this seed (DESIGN.md §3), so it is part
    /// of the batch key.
    pub seed: u64,
}

/// A replayable serving workload: the deployed model set plus the
/// admission-ordered traffic trace.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub models: Vec<String>,
    pub traffic: Vec<ServeRequest>,
}

/// Everything that determines one request's simulation result — the
/// batcher's grouping key. Two requests with equal keys produce equal
/// per-layer `CompileKey`s, so a batch shares one compiled `Program`
/// and one `SimCache` entry per layer.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub(crate) struct BatchKey {
    pub(crate) model: String,
    pub(crate) arch: String,
    /// `SparsityConfig::value_sparsity` as raw bits (f64 is not `Hash`).
    pub(crate) value_bits: u64,
    pub(crate) fta: bool,
    pub(crate) seed: u64,
}

impl BatchKey {
    pub(crate) fn of(r: &ServeRequest) -> BatchKey {
        BatchKey {
            model: r.model.clone(),
            arch: r.arch.clone(),
            value_bits: r.sparsity.value_sparsity.to_bits(),
            fta: r.sparsity.fta,
            seed: r.seed,
        }
    }
}

/// One planned batch: the shared key plus the admission indices of its
/// member requests (ascending — the batcher walks in admission order).
#[derive(Debug)]
struct Batch {
    key: BatchKey,
    members: Vec<usize>,
}

/// Greedy dynamic batcher: walk the trace in admission order, appending
/// each request to the open batch of its key, or opening a new batch
/// when there is none (or the open one is full). Pure function of the
/// trace — replaying a trace always plans the same batches.
fn plan_batches(traffic: &[ServeRequest], max_batch: usize) -> Vec<Batch> {
    let max = max_batch.max(1);
    let mut open: HashMap<BatchKey, usize> = HashMap::new();
    let mut batches: Vec<Batch> = Vec::new();
    for (i, r) in traffic.iter().enumerate() {
        let key = BatchKey::of(r);
        match open.get(&key) {
            Some(&b) if batches[b].members.len() < max => batches[b].members.push(i),
            _ => {
                let b = batches.len();
                batches.push(Batch { key: key.clone(), members: vec![i] });
                open.insert(key, b);
            }
        }
    }
    batches
}

/// Long-lived serving context shared by every batch admitted through
/// it: the model registry plus the cross-tenant compile and simulation
/// caches. Neither cache ever changes a result (DESIGN.md §5/§8) — they
/// only convert repeated work across requests, batches and tenants into
/// hits.
pub struct ServeCtx {
    pub registry: Registry,
    pub compile: CompileCache,
    pub sim: SimCache,
    /// Engine requests simulate under (`DBPIM_ENGINE` override honored,
    /// default parallel; results are bit-identical either way).
    pub engine: Engine,
}

impl ServeCtx {
    pub fn new(registry: Registry) -> ServeCtx {
        ServeCtx {
            registry,
            compile: CompileCache::new(),
            sim: SimCache::new(),
            engine: super::experiments::env_engine().unwrap_or(Engine::Parallel),
        }
    }
}

/// Latency/throughput summary of one replay.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub max_batch: usize,
    /// Simulated on-chip latency per request (ms), admission order.
    pub latencies_ms: Vec<f64>,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Host wall-clock of the whole replay.
    pub wall: Duration,
    /// Host-side serving throughput (requests per wall-clock second).
    pub req_per_s: f64,
    /// Cross-tenant cache counters (compile + sim).
    pub cache: SweepStats,
}

/// Nearest-rank percentile over an ascending-sorted slice; `q` in
/// [0, 100]. Empty input yields 0 (an empty trace has well-defined
/// all-zero stats, not NaN). The computed rank is clamped to
/// `1..=n`, so the boundaries are total: q = 0 (or any q small enough
/// that `ceil` lands on rank 0) returns the minimum, and q = 100 (or
/// out-of-range q) the maximum — never an index panic.
pub(crate) fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

impl ServeRequest {
    pub(crate) fn from_json(i: usize, v: &Value) -> Result<ServeRequest, String> {
        let model = v
            .get("model")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("request {i}: missing string \"model\""))?
            .to_string();
        let arch = match v.get("arch") {
            None => "db-pim".to_string(),
            Some(a) => a
                .as_str()
                .ok_or_else(|| format!("request {i}: \"arch\" must be a string"))?
                .to_string(),
        };
        if ArchConfig::by_name(&arch).is_none() {
            return Err(format!("request {i}: unknown arch preset {arch:?}"));
        }
        let value_sparsity = match v.get("value_sparsity") {
            None => 0.6,
            Some(x) => x
                .as_f64()
                .ok_or_else(|| format!("request {i}: \"value_sparsity\" must be a number"))?,
        };
        // The pruning pipeline's domain is [0, 1); anything else (1.0,
        // negatives, NaN) must be an admission error, not a worker
        // panic deep inside the sweep.
        if !(0.0..1.0).contains(&value_sparsity) {
            return Err(format!("request {i}: \"value_sparsity\" must be in [0.0, 1.0)"));
        }
        let fta = match v.get("fta") {
            None => true,
            Some(x) => {
                x.as_bool().ok_or_else(|| format!("request {i}: \"fta\" must be a boolean"))?
            }
        };
        // Seeds ride JSON numbers (f64), so only non-negative integers
        // up to 2^53 replay exactly; fractional, negative or oversized
        // seeds are rejected rather than silently truncated/wrapped.
        const MAX_EXACT_SEED: f64 = 9_007_199_254_740_992.0; // 2^53
        let seed = match v.get("seed").and_then(Value::as_f64) {
            Some(s) if (0.0..=MAX_EXACT_SEED).contains(&s) && s.fract() == 0.0 => s as u64,
            _ => {
                return Err(format!(
                    "request {i}: \"seed\" must be a non-negative integer (at most 2^53)"
                ))
            }
        };
        Ok(ServeRequest { model, arch, sparsity: SparsityConfig { value_sparsity, fta }, seed })
    }

    pub(crate) fn to_json(&self) -> Value {
        obj(vec![
            ("model", str_(&self.model)),
            ("arch", str_(&self.arch)),
            ("value_sparsity", num(self.sparsity.value_sparsity)),
            ("fta", Value::Bool(self.sparsity.fta)),
            ("seed", num(self.seed as f64)),
        ])
    }
}

impl ServeSpec {
    /// Parse a replay trace (`{"models": [...], "traffic": [...]}`).
    /// Malformed traces are errors with the offending index, never
    /// panics.
    pub fn from_json(v: &Value) -> Result<ServeSpec, String> {
        let models = v
            .get("models")
            .and_then(Value::as_arr)
            .ok_or_else(|| "trace: missing \"models\" array".to_string())?
            .iter()
            .enumerate()
            .map(|(i, m)| {
                m.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| format!("trace: models[{i}] must be a string"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        // Collect every invalid request in one pass: a trace with
        // three bad rows reports all three indices at once instead of
        // making the user fix-and-rerun three times.
        let raw = v
            .get("traffic")
            .and_then(Value::as_arr)
            .ok_or_else(|| "trace: missing \"traffic\" array".to_string())?;
        let mut traffic = Vec::with_capacity(raw.len());
        let mut errs: Vec<String> = Vec::new();
        for (i, r) in raw.iter().enumerate() {
            match ServeRequest::from_json(i, r) {
                Ok(req) => traffic.push(req),
                Err(e) => errs.push(e),
            }
        }
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        Ok(ServeSpec { models, traffic })
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("models", arr(self.models.iter().map(|m| str_(m)).collect())),
            ("traffic", arr(self.traffic.iter().map(ServeRequest::to_json).collect())),
        ])
    }

    /// Load a replayable trace from a JSON file. Every error names the
    /// file, including per-request validation errors.
    pub fn load(path: &str) -> Result<ServeSpec, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let v = json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
        ServeSpec::from_json(&v).map_err(|e| format!("{path}: {e}"))
    }

    /// Replay the trace with a fresh [`ServeCtx`] over the spec's own
    /// model list (zoo lookup). See [`ServeSpec::run_with`].
    pub fn run(&self, max_batch: usize) -> Result<(Vec<SimReport>, ServeStats), String> {
        let ctx = ServeCtx::new(Registry::from_names(&self.models)?);
        self.run_with(&ctx, max_batch)
    }

    /// Replay the trace through an existing serving context: admission →
    /// batching → pooled execution → completion in admission order.
    /// `results[i]` is bit-identical to serially simulating request `i`
    /// alone, for any `max_batch` and worker count.
    pub fn run_with(
        &self,
        ctx: &ServeCtx,
        max_batch: usize,
    ) -> Result<(Vec<SimReport>, ServeStats), String> {
        self.run_with_opts(ctx, max_batch, None)
    }

    /// [`ServeSpec::run_with`] on a chip fleet: every request simulates
    /// through `coordinator::sharding` under `spec`, and per-request
    /// reports are fleet-level (interconnect included in `time_ms`).
    /// A single-chip fleet is bit-identical to [`ServeSpec::run_with`].
    pub fn run_with_fleet(
        &self,
        ctx: &ServeCtx,
        max_batch: usize,
        spec: ShardSpec,
    ) -> Result<(Vec<SimReport>, ServeStats), String> {
        self.run_with_opts(ctx, max_batch, Some(spec))
    }

    /// [`ServeSpec::run_with_fleet`] over a fresh context (CLI entry).
    pub fn run_fleet(
        &self,
        max_batch: usize,
        spec: ShardSpec,
    ) -> Result<(Vec<SimReport>, ServeStats), String> {
        let ctx = ServeCtx::new(Registry::from_names(&self.models)?);
        self.run_with_fleet(&ctx, max_batch, spec)
    }

    fn run_with_opts(
        &self,
        ctx: &ServeCtx,
        max_batch: usize,
        shard: Option<ShardSpec>,
    ) -> Result<(Vec<SimReport>, ServeStats), String> {
        // Admission control: resolve every request before running any
        // (also for programmatically built specs that skipped the JSON
        // validation — an out-of-domain sparsity would otherwise panic
        // deep inside a pool worker). All invalid indices are reported
        // in one error.
        let mut errs: Vec<String> = Vec::new();
        for (i, r) in self.traffic.iter().enumerate() {
            if ctx.registry.get(&r.model).is_none() {
                errs.push(format!("request {i}: model {:?} is not deployed", r.model));
            } else if ArchConfig::by_name(&r.arch).is_none() {
                errs.push(format!("request {i}: unknown arch preset {:?}", r.arch));
            } else if !(0.0..1.0).contains(&r.sparsity.value_sparsity) {
                errs.push(format!("request {i}: value sparsity must be in [0.0, 1.0)"));
            }
        }
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        let t0 = Instant::now();
        let batches = plan_batches(&self.traffic, max_batch);
        let prepared: Vec<_> = batches
            .iter()
            .map(|b| {
                let net = ctx.registry.get(&b.key.model).expect("validated above");
                let arch = ArchConfig::by_name(&b.key.arch).expect("validated above");
                let sp = SparsityConfig {
                    value_sparsity: f64::from_bits(b.key.value_bits),
                    fta: b.key.fta,
                };
                let seeds: Vec<u64> = b.members.iter().map(|&i| self.traffic[i].seed).collect();
                (net, arch, sp, seeds)
            })
            .collect();
        let jobs: Vec<_> = prepared
            .iter()
            .map(|(net, arch, sp, seeds)| {
                move || match shard {
                    // A real fleet: each request simulates through the
                    // sharding layer (its own chip × layer fan-out
                    // nests into the same pool). chips == 1 keeps the
                    // flattened batch path — the delegation makes both
                    // bit-identical.
                    Some(spec) if spec.chips > 1 => seeds
                        .iter()
                        .map(|&seed| {
                            sharding::simulate_sharded(
                                net,
                                *sp,
                                arch,
                                seed,
                                spec,
                                ctx.engine,
                                &ctx.compile,
                                &ctx.sim,
                            )
                            .report
                        })
                        .collect::<Vec<_>>(),
                    _ => sim::simulate_batch(
                        net,
                        *sp,
                        arch,
                        seeds,
                        ctx.engine,
                        &ctx.compile,
                        &ctx.sim,
                    ),
                }
            })
            .collect();
        let per_batch = pool::run_jobs(jobs);

        // Completion: scatter batch results back to admission slots.
        let mut slots: Vec<Option<SimReport>> = (0..self.traffic.len()).map(|_| None).collect();
        for (b, reports) in batches.iter().zip(per_batch) {
            for (&i, report) in b.members.iter().zip(reports) {
                slots[i] = Some(report);
            }
        }
        let results: Vec<SimReport> =
            slots.into_iter().map(|s| s.expect("request not served")).collect();
        let wall = t0.elapsed();

        let latencies_ms: Vec<f64> = results.iter().map(SimReport::time_ms).collect();
        let mut sorted = latencies_ms.clone();
        sorted.sort_by(f64::total_cmp);
        let stats = ServeStats {
            requests: results.len(),
            batches: batches.len(),
            max_batch: max_batch.max(1),
            mean_ms: util::mean(&latencies_ms),
            p50_ms: percentile(&sorted, 50.0),
            p99_ms: percentile(&sorted, 99.0),
            latencies_ms,
            req_per_s: results.len() as f64 / wall.as_secs_f64().max(1e-9),
            wall,
            cache: SweepStats { compile: ctx.compile.stats(), sim: ctx.sim.stats() },
        };
        Ok((results, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fixtures::{small_net, tiny_net};

    fn req(model: &str, arch: &str, v: f64, seed: u64) -> ServeRequest {
        ServeRequest {
            model: model.into(),
            arch: arch.into(),
            sparsity: SparsityConfig::hybrid(v),
            seed,
        }
    }

    #[test]
    fn batcher_groups_compatible_requests_in_admission_order() {
        let traffic = vec![
            req("a", "db-pim", 0.5, 1), // batch 0
            req("b", "db-pim", 0.5, 1), // batch 1 (different model)
            req("a", "db-pim", 0.5, 1), // batch 0
            req("a", "db-pim", 0.5, 2), // batch 2 (different seed)
            req("a", "db-pim", 0.5, 1), // batch 0 — now full (max 3)
            req("a", "db-pim", 0.5, 1), // batch 3 (batch 0 full)
            req("a", "baseline", 0.5, 1), // batch 4 (different arch)
        ];
        let batches = plan_batches(&traffic, 3);
        let members: Vec<Vec<usize>> = batches.iter().map(|b| b.members.clone()).collect();
        assert_eq!(members, vec![vec![0, 2, 4], vec![1], vec![3], vec![5], vec![6]]);
    }

    #[test]
    fn batcher_max_batch_one_serializes() {
        let traffic = vec![req("a", "db-pim", 0.5, 1); 4];
        let batches = plan_batches(&traffic, 1);
        assert_eq!(batches.len(), 4);
        // max_batch 0 is clamped to 1
        assert_eq!(plan_batches(&traffic, 0).len(), 4);
    }

    #[test]
    fn trace_json_roundtrip_and_defaults() {
        let text = r#"{
            "models": ["resnet18"],
            "traffic": [
                {"model": "resnet18", "seed": 7},
                {"model": "resnet18", "arch": "baseline", "value_sparsity": 0.0,
                 "fta": false, "seed": 8}
            ]
        }"#;
        let spec = ServeSpec::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.models, vec!["resnet18"]);
        assert_eq!(spec.traffic.len(), 2);
        // defaults: db-pim hybrid 0.6 with FTA
        assert_eq!(spec.traffic[0].arch, "db-pim");
        assert_eq!(spec.traffic[0].sparsity, SparsityConfig::hybrid(0.6));
        assert_eq!(spec.traffic[1].sparsity, SparsityConfig { value_sparsity: 0.0, fta: false });
        // roundtrip through to_json
        let again = ServeSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(again.traffic[1].seed, 8);
        assert_eq!(again.traffic[1].arch, "baseline");
    }

    #[test]
    fn trace_json_rejects_malformed_requests() {
        for bad in [
            r#"{"traffic": []}"#,
            r#"{"models": ["resnet18"]}"#,
            r#"{"models": [1], "traffic": []}"#,
            r#"{"models": [], "traffic": [{"seed": 1}]}"#,
            r#"{"models": [], "traffic": [{"model": "resnet18"}]}"#,
            r#"{"models": [], "traffic": [{"model": "resnet18", "arch": "warp", "seed": 1}]}"#,
            r#"{"models": [], "traffic": [{"model": "resnet18", "seed": -1}]}"#,
            r#"{"models": [], "traffic": [{"model": "resnet18", "seed": 1.5}]}"#,
            r#"{"models": [], "traffic": [{"model": "resnet18", "value_sparsity": 1.0, "seed": 1}]}"#,
        ] {
            assert!(
                ServeSpec::from_json(&json::parse(bad).unwrap()).is_err(),
                "accepted malformed trace {bad}"
            );
        }
    }

    #[test]
    fn replay_returns_admission_order_and_shares_caches() {
        let spec = ServeSpec {
            models: vec!["small".into(), "tiny".into()],
            traffic: vec![
                req("small", "db-pim", 0.5, 1),
                req("tiny", "db-pim", 0.5, 1),
                req("small", "db-pim", 0.5, 1),
                req("tiny", "baseline", 0.0, 2),
                req("small", "db-pim", 0.5, 1),
            ],
        };
        let ctx = ServeCtx::new(Registry::from_networks(vec![small_net(), tiny_net()]));
        let (results, stats) = spec.run_with(&ctx, 2).unwrap();
        assert_eq!(results.len(), 5);
        // admission order: the result rows name their request's model
        let nets: Vec<&str> = results.iter().map(|r| r.network.as_str()).collect();
        assert_eq!(nets, vec!["small", "tiny", "small", "tiny", "small"]);
        // identical requests produce bit-identical reports
        assert_eq!(results[0].totals, results[2].totals);
        assert_eq!(results[0].totals, results[4].totals);
        assert_eq!(results[0].total_cycles(), results[2].total_cycles());
        // the three identical "small" requests share one SimCache entry
        // per layer: 5 requests × 2 PIM layers = 10 lookups over 6
        // unique keys (deterministic for any schedule)
        assert_eq!(stats.cache.sim.lookups(), 10);
        assert_eq!(stats.cache.sim.misses, 6);
        assert_eq!(stats.cache.sim.hits, 4);
        assert_eq!(stats.requests, 5);
        // batches: small×3 fills one batch of 2 + one of 1
        assert_eq!(stats.batches, 4);
        assert!(stats.p50_ms > 0.0 && stats.p99_ms >= stats.p50_ms);
        assert_eq!(stats.latencies_ms.len(), 5);
    }

    #[test]
    fn replay_rejects_undeployed_models() {
        let spec = ServeSpec {
            models: vec!["small".into()],
            traffic: vec![req("tiny", "db-pim", 0.5, 1)],
        };
        let ctx = ServeCtx::new(Registry::from_networks(vec![small_net()]));
        let err = spec.run_with(&ctx, 4).unwrap_err();
        assert!(err.contains("not deployed"), "{err}");
        // and unknown zoo names fail at registry resolution in run()
        let bad = ServeSpec { models: vec!["warpnet".into()], traffic: vec![] };
        assert!(bad.run(4).is_err());
    }

    #[test]
    fn example_trace_parses_and_plans() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/serve_trace.json");
        let spec = ServeSpec::load(path).expect("examples/serve_trace.json must stay valid");
        assert!(!spec.traffic.is_empty());
        // every trace model resolves in the zoo registry
        let reg = Registry::from_names(&spec.models).unwrap();
        for r in &spec.traffic {
            assert!(reg.get(&r.model).is_some(), "trace names undeployed model {}", r.model);
        }
        // repeats exist by construction, so batching actually groups
        let batches = plan_batches(&spec.traffic, 8);
        assert!(batches.len() < spec.traffic.len(), "example trace should batch");
    }

    #[test]
    fn empty_trace_yields_well_defined_zero_stats() {
        let spec = ServeSpec { models: vec!["small".into()], traffic: vec![] };
        let ctx = ServeCtx::new(Registry::from_networks(vec![small_net()]));
        let (results, stats) = spec.run_with(&ctx, 4).unwrap();
        assert!(results.is_empty());
        assert_eq!(stats.requests, 0);
        assert_eq!(stats.batches, 0);
        assert!(stats.latencies_ms.is_empty());
        // no NaN / division-by-zero artifacts anywhere
        assert_eq!(stats.mean_ms, 0.0);
        assert_eq!(stats.p50_ms, 0.0);
        assert_eq!(stats.p99_ms, 0.0);
        assert!(stats.req_per_s.is_finite() && stats.req_per_s >= 0.0);
    }

    #[test]
    fn trace_validation_reports_all_invalid_indices() {
        let text = r#"{
            "models": [],
            "traffic": [
                {"model": "resnet18", "seed": -1},
                {"model": "resnet18", "seed": 1},
                {"model": "resnet18", "arch": "warp", "seed": 2}
            ]
        }"#;
        let err = ServeSpec::from_json(&json::parse(text).unwrap()).unwrap_err();
        assert!(err.contains("request 0"), "{err}");
        assert!(err.contains("request 2"), "{err}");
        assert!(!err.contains("request 1"), "{err}");

        // run_with does the same for programmatically built specs
        let spec = ServeSpec {
            models: vec!["small".into()],
            traffic: vec![
                req("ghost", "db-pim", 0.5, 1),
                req("small", "db-pim", 0.5, 1),
                req("small", "warp", 0.5, 1),
            ],
        };
        let ctx = ServeCtx::new(Registry::from_networks(vec![small_net()]));
        let err = spec.run_with(&ctx, 4).unwrap_err();
        assert!(err.contains("request 0") && err.contains("request 2"), "{err}");
    }

    #[test]
    fn load_error_names_the_file() {
        let err = ServeSpec::load("/nonexistent/trace.json").unwrap_err();
        assert!(err.contains("/nonexistent/trace.json"), "{err}");
        // validation errors name the file too
        let dir = std::env::temp_dir();
        let path = dir.join("dbpim_bad_trace_test.json");
        std::fs::write(&path, r#"{"models": [], "traffic": [{"seed": 1}]}"#).unwrap();
        let err = ServeSpec::load(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("dbpim_bad_trace_test.json"), "{err}");
        assert!(err.contains("request 0"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 99.0), 4.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs[..1], 50.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_edge_cases_never_panic() {
        // ISSUE 10: q = 0 used to compute rank 0 and underflow the
        // `rank - 1` index; exercise 0/1/2-element inputs across the
        // boundary quantiles (and a tiny-q case that also ceils to 0).
        let quantiles = [0.0, 50.0, 99.0, 100.0];
        for &q in &quantiles {
            assert_eq!(percentile(&[], q), 0.0, "empty, q={q}");
        }
        let one = [7.5];
        for &q in &quantiles {
            assert_eq!(percentile(&one, q), 7.5, "singleton, q={q}");
        }
        let two = [1.0, 9.0];
        assert_eq!(percentile(&two, 0.0), 1.0);
        assert_eq!(percentile(&two, 50.0), 1.0); // nearest-rank: ceil(1.0) = 1
        assert_eq!(percentile(&two, 99.0), 9.0);
        assert_eq!(percentile(&two, 100.0), 9.0);
        // tiny q on a larger input still clamps to the minimum
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.001), 1.0);
        // out-of-range q clamps instead of indexing past the end
        assert_eq!(percentile(&two, 250.0), 9.0);
    }
}
