//! Deterministic fault injection for the open-loop serve loop
//! (DESIGN.md §11).
//!
//! The fault taxonomy has three axes, all derived from one fault seed:
//!
//! * **transient failures** — an individual simulation attempt fails and
//!   must be retried (models flaky chip readout / ECC-uncorrectable
//!   events);
//! * **latency spikes** — an attempt completes but takes `spike_factor`×
//!   its nominal service time (models refresh collisions, thermal
//!   throttling);
//! * **chip down intervals** — a whole chip goes offline for a window,
//!   failing its in-flight work and rejoining later (models brown-outs
//!   and resets).
//!
//! Every decision is a pure hash of `(fault_seed, request id, attempt)`
//! — or, for down windows, a per-chip PRNG stream consumed monotonically
//! by the single-threaded event loop — so a seeded run injects exactly
//! the same faults at exactly the same virtual times on every replay,
//! for any worker count. Faults are *decisions*, not host events: no
//! wall clock, no OS signals, no shared mutable state.

use crate::json::{num, obj, Value};
use crate::util::Rng;

use super::clock::{ms_to_ns, VirtualNs};

/// Fault-model parameters. `off()` (all zeros) disables every axis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Root seed for every fault decision in the run.
    pub seed: u64,
    /// Probability an individual attempt fails transiently, in [0, 1].
    pub transient_rate: f64,
    /// Probability an attempt's service time is multiplied by
    /// `spike_factor`, in [0, 1].
    pub spike_rate: f64,
    /// Latency multiplier applied on a spike (>= 1).
    pub spike_factor: f64,
    /// Mean virtual uptime between chip outages (ms); 0 disables
    /// outages.
    pub down_mean_ms: f64,
    /// Mean duration of one chip outage (ms).
    pub down_duration_ms: f64,
}

impl FaultSpec {
    /// No faults at all — the loop still exercises deadlines, shedding
    /// and continuous batching, just on a perfectly healthy fabric.
    pub fn off() -> FaultSpec {
        FaultSpec {
            seed: 0,
            transient_rate: 0.0,
            spike_rate: 0.0,
            spike_factor: 1.0,
            down_mean_ms: 0.0,
            down_duration_ms: 0.0,
        }
    }

    /// The stock fault mix used by `--faults` and the CI fault leg:
    /// 2% transient attempt failures, 2% latency spikes at 4×, and a
    /// ~20 ms outage roughly every 200 ms of uptime per chip.
    pub fn default_with_seed(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            transient_rate: 0.02,
            spike_rate: 0.02,
            spike_factor: 4.0,
            down_mean_ms: 200.0,
            down_duration_ms: 20.0,
        }
    }

    /// Whether any fault axis is active.
    pub fn enabled(&self) -> bool {
        self.transient_rate > 0.0 || self.spike_rate > 0.0 || self.down_mean_ms > 0.0
    }

    /// `DBPIM_FAULT_SEED=N` turns on the stock fault mix seeded with
    /// `N` (the CI fault-injection leg sets this); unset or
    /// unparsable → `None`.
    pub fn from_env() -> Option<FaultSpec> {
        let raw = std::env::var("DBPIM_FAULT_SEED").ok()?;
        let seed = raw.trim().parse::<u64>().ok()?;
        Some(FaultSpec::default_with_seed(seed))
    }

    pub fn validate(&self) -> Result<(), String> {
        let unit = |v: f64| (0.0..=1.0).contains(&v); // NaN fails both bounds
        if !unit(self.transient_rate) {
            return Err(format!(
                "faults: transient_rate must be in [0, 1], got {}",
                self.transient_rate
            ));
        }
        if !unit(self.spike_rate) {
            return Err(format!("faults: spike_rate must be in [0, 1], got {}", self.spike_rate));
        }
        if !(self.spike_factor >= 1.0 && self.spike_factor.is_finite()) {
            return Err(format!(
                "faults: spike_factor must be finite and >= 1, got {}",
                self.spike_factor
            ));
        }
        if !(self.down_mean_ms >= 0.0 && self.down_mean_ms.is_finite()) {
            return Err(format!(
                "faults: down_mean_ms must be finite and >= 0, got {}",
                self.down_mean_ms
            ));
        }
        if self.down_mean_ms > 0.0
            && !(self.down_duration_ms > 0.0 && self.down_duration_ms.is_finite())
        {
            return Err(format!(
                "faults: down_duration_ms must be finite and > 0 when outages are on, got {}",
                self.down_duration_ms
            ));
        }
        Ok(())
    }

    /// Parse an optional `"faults"` spec object; every field defaults to
    /// its `off()` value except `seed` (default 0), so partial objects
    /// enable only the named axes.
    pub fn from_json(v: &Value) -> Result<FaultSpec, String> {
        let base = FaultSpec::off();
        let f = |key: &str, dflt: f64| -> Result<f64, String> {
            match v.get(key) {
                None => Ok(dflt),
                Some(x) => {
                    x.as_f64().ok_or_else(|| format!("faults: \"{key}\" must be a number"))
                }
            }
        };
        let seed = match v.get("seed") {
            None => 0,
            Some(x) => x
                .as_usize()
                .ok_or_else(|| "faults: \"seed\" must be a non-negative integer".to_string())?
                as u64,
        };
        let spec = FaultSpec {
            seed,
            transient_rate: f("transient_rate", base.transient_rate)?,
            spike_rate: f("spike_rate", base.spike_rate)?,
            spike_factor: f("spike_factor", base.spike_factor)?,
            down_mean_ms: f("down_mean_ms", base.down_mean_ms)?,
            down_duration_ms: f("down_duration_ms", base.down_duration_ms)?,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("seed", num(self.seed as f64)),
            ("transient_rate", num(self.transient_rate)),
            ("spike_rate", num(self.spike_rate)),
            ("spike_factor", num(self.spike_factor)),
            ("down_mean_ms", num(self.down_mean_ms)),
            ("down_duration_ms", num(self.down_duration_ms)),
        ])
    }
}

/// Decision tags keep the per-(request, attempt) hash streams for the
/// three pure decisions independent of each other.
const TAG_TRANSIENT: u64 = 0x7A11_5EED_0000_0001;
const TAG_SPIKE: u64 = 0x7A11_5EED_0000_0002;
const TAG_JITTER: u64 = 0x7A11_5EED_0000_0003;

/// Stateless fault decisions plus the per-chip outage streams. One
/// injector lives inside one serve-loop run; the loop queries it from a
/// single thread in event order, which makes the outage streams (the
/// only stateful part) deterministic too.
pub struct FaultInjector {
    spec: FaultSpec,
    /// Per-chip PRNG streams for outage windows, consumed monotonically.
    chip_rngs: Vec<Rng>,
}

/// One decision hash: a fresh SplitMix64 stream keyed by
/// `(seed, tag, request, attempt)`. One draw, then discarded — there is
/// no sequence to keep in sync across replays.
fn decide(seed: u64, tag: u64, req: u64, attempt: u64) -> u64 {
    Rng::new(
        seed ^ tag
            ^ req.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ attempt.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    )
    .next_u64()
}

/// Map a hash to a uniform f64 in [0, 1).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultInjector {
    pub fn new(spec: FaultSpec, chips: usize) -> FaultInjector {
        let chip_rngs = (0..chips)
            .map(|c| {
                Rng::new(spec.seed ^ 0xC41F_D0D0 ^ (c as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD))
            })
            .collect();
        FaultInjector { spec, chip_rngs }
    }

    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// Does attempt number `attempt` (1-based) of request `req` fail
    /// transiently? Pure in `(spec.seed, req, attempt)`.
    pub fn attempt_fails(&self, req: u64, attempt: u64) -> bool {
        self.spec.transient_rate > 0.0
            && unit(decide(self.spec.seed, TAG_TRANSIENT, req, attempt)) < self.spec.transient_rate
    }

    /// Service-time multiplier for this attempt (1.0 nominally,
    /// `spike_factor` on a latency spike). Pure.
    pub fn latency_factor(&self, req: u64, attempt: u64) -> f64 {
        if self.spec.spike_rate > 0.0
            && unit(decide(self.spec.seed, TAG_SPIKE, req, attempt)) < self.spec.spike_rate
        {
            self.spec.spike_factor
        } else {
            1.0
        }
    }

    /// Deterministic backoff jitter in [1, 2): full exponential backoff
    /// windows double on every retry, and the jitter decorrelates
    /// retry storms without breaking replay. Pure.
    pub fn backoff_jitter(&self, req: u64, attempt: u64) -> f64 {
        1.0 + unit(decide(self.spec.seed, TAG_JITTER, req, attempt))
    }

    /// Next `(down_at, up_at)` outage window for `chip`, strictly after
    /// `after`. Draws exponential uptime/downtime from the chip's own
    /// stream; `None` when outages are disabled. Must be called in
    /// non-decreasing `after` order per chip (the event loop does —
    /// it asks only when scheduling the chip's next outage).
    pub fn next_down_window(
        &mut self,
        chip: usize,
        after: VirtualNs,
    ) -> Option<(VirtualNs, VirtualNs)> {
        if self.spec.down_mean_ms <= 0.0 || chip >= self.chip_rngs.len() {
            return None;
        }
        let rng = &mut self.chip_rngs[chip];
        let up_ms = -(1.0 - rng.f64()).ln() * self.spec.down_mean_ms;
        let down_ms = -(1.0 - rng.f64()).ln() * self.spec.down_duration_ms;
        let down_at = after.saturating_add(ms_to_ns(up_ms).max(1));
        let up_at = down_at.saturating_add(ms_to_ns(down_ms).max(1));
        Some((down_at, up_at))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_and_seeded() {
        let a = FaultInjector::new(FaultSpec::default_with_seed(7), 2);
        let b = FaultInjector::new(FaultSpec::default_with_seed(7), 2);
        for req in 0..200u64 {
            for attempt in 1..4u64 {
                assert_eq!(a.attempt_fails(req, attempt), b.attempt_fails(req, attempt));
                assert_eq!(a.latency_factor(req, attempt), b.latency_factor(req, attempt));
                assert_eq!(a.backoff_jitter(req, attempt), b.backoff_jitter(req, attempt));
                assert!(a.latency_factor(req, attempt) >= 1.0);
                let j = a.backoff_jitter(req, attempt);
                assert!((1.0..2.0).contains(&j));
            }
        }
        // a different seed flips at least some decisions
        let c = FaultInjector::new(FaultSpec::default_with_seed(8), 2);
        let flips = (0..2000u64)
            .filter(|&r| a.attempt_fails(r, 1) != c.attempt_fails(r, 1))
            .count();
        assert!(flips > 0, "seed must matter");
    }

    #[test]
    fn transient_rate_is_roughly_respected() {
        let spec = FaultSpec { transient_rate: 0.25, ..FaultSpec::off() };
        let inj = FaultInjector::new(FaultSpec { seed: 3, ..spec }, 1);
        let n = 20_000u64;
        let fails = (0..n).filter(|&r| inj.attempt_fails(r, 1)).count() as f64 / n as f64;
        assert!((fails - 0.25).abs() < 0.02, "observed transient rate {fails}");
    }

    #[test]
    fn off_spec_injects_nothing() {
        let mut inj = FaultInjector::new(FaultSpec::off(), 4);
        for req in 0..500u64 {
            assert!(!inj.attempt_fails(req, 1));
            assert_eq!(inj.latency_factor(req, 1), 1.0);
        }
        assert!(inj.next_down_window(0, 0).is_none());
        assert!(!FaultSpec::off().enabled());
        assert!(FaultSpec::default_with_seed(1).enabled());
    }

    #[test]
    fn down_windows_are_ordered_and_per_chip_deterministic() {
        let mut a = FaultInjector::new(FaultSpec::default_with_seed(11), 2);
        let mut b = FaultInjector::new(FaultSpec::default_with_seed(11), 2);
        let mut after = 0;
        for _ in 0..50 {
            let (d0, u0) = a.next_down_window(0, after).unwrap();
            assert_eq!((d0, u0), b.next_down_window(0, after).unwrap());
            assert!(d0 > after && u0 > d0, "windows must be ordered");
            after = u0;
        }
        // chip streams are independent: chip 1 differs from chip 0
        let w0 = FaultInjector::new(FaultSpec::default_with_seed(11), 2)
            .next_down_window(0, 0)
            .unwrap();
        let w1 = FaultInjector::new(FaultSpec::default_with_seed(11), 2)
            .next_down_window(1, 0)
            .unwrap();
        assert_ne!(w0, w1);
    }

    #[test]
    fn validate_rejects_garbage() {
        let ok = FaultSpec::default_with_seed(1);
        assert!(ok.validate().is_ok());
        assert!(FaultSpec { transient_rate: 1.5, ..ok }.validate().is_err());
        assert!(FaultSpec { transient_rate: f64::NAN, ..ok }.validate().is_err());
        assert!(FaultSpec { spike_rate: -0.1, ..ok }.validate().is_err());
        assert!(FaultSpec { spike_factor: 0.5, ..ok }.validate().is_err());
        assert!(FaultSpec { down_mean_ms: -1.0, ..ok }.validate().is_err());
        assert!(FaultSpec { down_duration_ms: 0.0, ..ok }.validate().is_err());
        // outages off → duration irrelevant
        assert!(FaultSpec { down_mean_ms: 0.0, down_duration_ms: 0.0, ..ok }.validate().is_ok());
    }

    #[test]
    fn json_roundtrip_and_partial_defaults() {
        let spec = FaultSpec::default_with_seed(9);
        let back = FaultSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // partial: only transients on
        let v = crate::json::parse(r#"{"seed": 3, "transient_rate": 0.1}"#).unwrap();
        let p = FaultSpec::from_json(&v).unwrap();
        assert_eq!(p.seed, 3);
        assert_eq!(p.transient_rate, 0.1);
        assert_eq!(p.spike_rate, 0.0);
        assert_eq!(p.down_mean_ms, 0.0);
        let bad = crate::json::parse(r#"{"transient_rate": 2.0}"#).unwrap();
        assert!(FaultSpec::from_json(&bad).is_err());
        assert!(FaultSpec::from_json(&crate::json::parse(r#"{"seed": -1}"#).unwrap()).is_err());
    }
}
