//! Persistent work-stealing worker pool (std threads only; the offline
//! image has neither rayon nor crossbeam).
//!
//! Every parallel layer of the stack — experiment sweeps, the per-layer
//! fan-out in `sim::simulate_network`, and the per-segment fan-out in
//! `sim::engine::run_layer` — schedules into one lazily-initialized
//! process-wide pool, so *nested* parallelism composes without
//! oversubscription: a [`scope`] opened on a worker thread pushes its
//! child jobs onto that worker's own deque and then *helps* (runs its
//! own children LIFO, steals from siblings, drains the global injector)
//! instead of blocking a thread or spawning new ones. After pool
//! initialization, no code path spawns another OS thread.
//!
//! Structure:
//!
//! * one global **injector** queue (FIFO) fed by non-pool threads;
//! * one **deque** per worker: the owner pushes/pops its own jobs LIFO
//!   (children first — best cache locality, bounded queue depth) while
//!   thieves steal FIFO from the opposite end (oldest = largest work);
//! * a generation-counted condvar so idle workers sleep instead of
//!   spinning, with a short timeout as a lost-wakeup backstop.
//!
//! **Determinism contract:** every spawned job writes its result into
//! its own pre-assigned slot (per-slot handles — there is no shared
//! `Mutex<Vec<…>>` to contend on), and [`scope`] returns results in
//! spawn order. Scheduling and steal order affect wall-clock only; as
//! long as jobs are pure functions of their inputs (every simulation
//! job is — DESIGN.md §3), results are bit-identical for any worker
//! count, including 1.
//!
//! Worker count resolution, at first use: [`configure_workers`] (the
//! CLI's `--workers N`) > `DBPIM_WORKERS` env > [`super::default_workers`].
//! [`Pool::new`] builds a private pool (tests randomize worker counts);
//! dropping an owned pool shuts its threads down. Jobs spawned from a
//! pool's worker (or from a thread helping it) stay on *that* pool.

use std::any::Any;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A type-erased, lifetime-erased unit of work (see the safety note in
/// [`Scope::spawn`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Which pool the current thread executes for: set permanently on pool
/// workers, and temporarily on any thread helping a pool drain a scope.
/// `usize` is the worker's deque index (None for helpers).
type Context = (Arc<Shared>, Option<usize>);

thread_local! {
    static CURRENT: RefCell<Option<Context>> = RefCell::new(None);
}

/// Wake-up channel: a generation counter under the mutex prevents the
/// classic lost-wakeup race (bump + notify happen atomically w.r.t. the
/// sleeper's check), and the wait timeout bounds any residual stall.
struct Sleep {
    gen: Mutex<u64>,
    cv: Condvar,
}

/// State shared by a pool's workers, its queues, and every scope
/// scheduled on it.
struct Shared {
    injector: Mutex<VecDeque<Job>>,
    deques: Vec<Mutex<VecDeque<Job>>>,
    sleep: Sleep,
    /// Threads currently registered in (or entering) `idle_wait`. Lets
    /// `notify` skip the lock + broadcast entirely on the hot path when
    /// nobody is asleep — the common case while all workers are busy.
    sleepers: AtomicUsize,
    /// Queued-job count: incremented *before* a job lands in any queue,
    /// decremented after a successful pop. Lets `has_work` answer the
    /// common idle case ("everything drained") with one atomic load
    /// instead of locking the injector plus every worker deque. A stale
    /// non-zero merely falls through to the locked scan; a zero is
    /// authoritative for the sleep protocol because the increment is
    /// SeqCst-ordered before the push (see `idle_wait`).
    pending: AtomicUsize,
    shutdown: AtomicBool,
}

impl Shared {
    /// Wake sleepers after a push or a job completion. Fast path: no
    /// registered sleepers ⇒ nothing to do. The SeqCst pairing with
    /// `idle_wait`'s registration makes this race-free: if this load
    /// sees 0, the sleeper registered *after* it, so its post-
    /// registration queue re-check observes the already-pushed job.
    fn notify(&self) {
        if self.sleepers.load(Ordering::SeqCst) == 0 {
            return;
        }
        self.notify_locked();
    }

    /// Unconditional bump + broadcast (shutdown, or sleepers present).
    fn notify_locked(&self) {
        let mut gen = self.sleep.gen.lock().unwrap();
        *gen = gen.wrapping_add(1);
        self.sleep.cv.notify_all();
    }

    fn gen(&self) -> u64 {
        *self.sleep.gen.lock().unwrap()
    }

    fn has_work(&self) -> bool {
        // Fast path: nothing queued anywhere — one SeqCst load instead
        // of locking the injector + every deque. This is the case every
        // idle worker hits on every wait cycle. SeqCst pairs with the
        // SeqCst increment in `push`: a sleeper registered in
        // `idle_wait` that reads 0 here is ordered after any pusher
        // that skipped its wakeup (both sides' SeqCst ops form one
        // total order with the `sleepers` registration).
        if self.pending.load(Ordering::SeqCst) == 0 {
            return false;
        }
        // Slow confirmation under the locks: `pending` may be stale-high
        // (a pop between our load and the scan), so verify before
        // claiming there is work.
        if !self.injector.lock().unwrap().is_empty() {
            return true;
        }
        self.deques.iter().any(|d| !d.lock().unwrap().is_empty())
    }

    /// Sleep until the generation moves past `gen0` (or a timeout, as a
    /// backstop). Spurious returns are fine — all callers re-check
    /// their condition in a loop. Registration (`sleepers`) precedes a
    /// re-check of the queues *and* of the caller's own wake condition
    /// (`done`, e.g. "my scope's pending hit 0"), closing the race
    /// against `notify`'s fast path — the SeqCst registration orders
    /// the re-checks after any notifier that skipped us — while the
    /// gen counter closes the classic lost-wakeup race against
    /// notifiers that did take the slow path.
    fn idle_wait(&self, gen0: u64, done: impl Fn() -> bool) {
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        if !self.has_work() && !done() {
            let guard = self.sleep.gen.lock().unwrap();
            if *guard == gen0 && !self.shutdown.load(Ordering::Acquire) {
                drop(self.sleep.cv.wait_timeout(guard, Duration::from_millis(50)).unwrap());
            }
        }
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
    }

    /// Push one job: onto the spawning worker's own deque when called
    /// from a pool thread (LIFO locality), else onto the injector.
    fn push(&self, job: Job, worker: Option<usize>) {
        // Increment before the job is visible in any queue so a sleeper
        // observing pending == 0 can be certain no queued job exists.
        self.pending.fetch_add(1, Ordering::SeqCst);
        match worker {
            Some(i) => self.deques[i].lock().unwrap().push_back(job),
            None => self.injector.lock().unwrap().push_back(job),
        }
        self.notify();
    }

    /// Pop the next runnable job: own deque (LIFO) → injector (FIFO) →
    /// steal from sibling deques (FIFO end).
    fn find_job(&self, worker: Option<usize>) -> Option<Job> {
        // Decrements are Relaxed: a stale-high `pending` only sends
        // `has_work` down the locked scan, never to a wrong answer.
        if let Some(i) = worker {
            if let Some(job) = self.deques[i].lock().unwrap().pop_back() {
                self.pending.fetch_sub(1, Ordering::Relaxed);
                return Some(job);
            }
        }
        if let Some(job) = self.injector.lock().unwrap().pop_front() {
            self.pending.fetch_sub(1, Ordering::Relaxed);
            return Some(job);
        }
        let n = self.deques.len();
        let start = worker.map_or(0, |i| i + 1);
        for off in 0..n {
            let j = (start + off) % n;
            if worker == Some(j) {
                continue;
            }
            if let Some(job) = self.deques[j].lock().unwrap().pop_front() {
                self.pending.fetch_sub(1, Ordering::Relaxed);
                return Some(job);
            }
        }
        None
    }

    /// Drive jobs until `state` has no pending children: the joining
    /// thread *helps* — its own deque holds the scope's children, so
    /// nested scopes execute or steal instead of blocking a thread.
    /// Unrelated jobs picked up while helping are fine: jobs never
    /// block except in nested joins, which themselves help, so progress
    /// is guaranteed.
    fn join(&self, state: &ScopeState, worker: Option<usize>) {
        while state.pending.load(Ordering::SeqCst) != 0 {
            let gen0 = self.gen();
            if let Some(job) = self.find_job(worker) {
                job();
                continue;
            }
            self.idle_wait(gen0, || state.pending.load(Ordering::SeqCst) == 0);
        }
    }
}

/// RAII guard that binds the current thread to a pool context and
/// restores the previous binding on drop.
struct ContextGuard {
    prev: Option<Context>,
}

fn enter_context(shared: &Arc<Shared>, worker: Option<usize>) -> ContextGuard {
    let prev = CURRENT.with(|c| c.borrow_mut().replace((Arc::clone(shared), worker)));
    ContextGuard { prev }
}

impl Drop for ContextGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

fn current_context() -> Option<Context> {
    CURRENT.with(|c| c.borrow().clone())
}

/// The current thread's deque index *on this particular pool* (None for
/// external threads and for workers/helpers of a different pool).
fn current_worker_on(shared: &Arc<Shared>) -> Option<usize> {
    CURRENT.with(|c| match &*c.borrow() {
        Some((s, idx)) if Arc::ptr_eq(s, shared) => *idx,
        _ => None,
    })
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    let _ctx = enter_context(&shared, Some(idx));
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let gen0 = shared.gen();
        match shared.find_job(Some(idx)) {
            Some(job) => job(),
            None => shared.idle_wait(gen0, || false),
        }
    }
    // Release this worker's scratch arena (sim::arena rides worker TLS:
    // each worker owns per-thread free lists of hot-path buffers for
    // its whole lifetime) so private test pools return their retained
    // memory on Drop.
    crate::sim::arena::retire_thread();
}

/// One job's private result cell. Written exactly once, by the one job
/// that owns it; read exactly once, by the scope owner after the join
/// barrier (the `pending` Release/Acquire pair orders the write before
/// the read). No lock, hence no contention between completing jobs.
struct Slot<T> {
    value: std::cell::UnsafeCell<Option<T>>,
}

// SAFETY: access is disciplined by the scope protocol above — a single
// writer (the owning job) before the join barrier, a single reader (the
// scope owner) after it.
unsafe impl<T: Send> Sync for Slot<T> {}

impl<T> Slot<T> {
    fn new() -> Self {
        Slot { value: std::cell::UnsafeCell::new(None) }
    }

    /// SAFETY: called at most once, only by the job owning this slot.
    unsafe fn put(&self, v: T) {
        *self.value.get() = Some(v);
    }

    /// SAFETY: called only after the owning scope joined (`pending`
    /// observed 0 with Acquire).
    unsafe fn take(&self) -> Option<T> {
        (*self.value.get()).take()
    }
}

/// Join state of one scope: outstanding child count + first panic.
struct ScopeState {
    pending: AtomicUsize,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// An in-flight fork-join scope over one pool. Obtained from [`scope`]
/// / [`Pool::scope`]; [`Scope::spawn`] schedules children, and the
/// scope joins (helping, not blocking) before results are returned in
/// spawn order.
pub struct Scope<'env, T: Send> {
    shared: Arc<Shared>,
    state: Arc<ScopeState>,
    slots: Vec<Arc<Slot<T>>>,
    /// Invariant over `'env` so borrowed captures can't be shortened.
    marker: PhantomData<&'env mut &'env ()>,
}

impl<'env, T: Send + 'env> Scope<'env, T> {
    /// Schedule one child job. Its result lands in the slot matching
    /// its spawn position; a panic is captured and re-raised from the
    /// scope owner after all siblings finish.
    pub fn spawn<F>(&mut self, f: F)
    where
        F: FnOnce() -> T + Send + 'env,
    {
        let slot = Arc::new(Slot::new());
        self.slots.push(Arc::clone(&slot));
        self.state.pending.fetch_add(1, Ordering::SeqCst);
        let state = Arc::clone(&self.state);
        let shared = Arc::clone(&self.shared);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            match catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => unsafe { slot.put(v) },
                Err(p) => {
                    let mut first = state.panic.lock().unwrap();
                    if first.is_none() {
                        *first = Some(p);
                    }
                }
            }
            // SeqCst: orders this decrement before `notify`'s sleeper
            // check, so a joiner that registers as a sleeper after
            // being skipped here observes pending == 0 in its own
            // re-check (idle_wait's `done`). SeqCst subsumes the
            // Release the slot-write publication needs.
            state.pending.fetch_sub(1, Ordering::SeqCst);
            shared.notify();
        });
        // SAFETY: lifetime erasure in the rayon/crossbeam mold. The
        // scope unconditionally joins (pending == 0, even when the
        // scope body panics) before `scope_on` returns, so this job —
        // and any `'env` borrow inside it — never outlives the stack
        // frame that owns the borrowed data.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send>>(job)
        };
        let worker = current_worker_on(&self.shared);
        self.shared.push(job, worker);
    }
}

fn scope_on<'env, T, F>(shared: Arc<Shared>, f: F) -> Vec<T>
where
    T: Send + 'env,
    F: FnOnce(&mut Scope<'env, T>) + 'env,
{
    let mut sc = Scope {
        shared: Arc::clone(&shared),
        state: Arc::new(ScopeState { pending: AtomicUsize::new(0), panic: Mutex::new(None) }),
        slots: Vec::new(),
        marker: PhantomData,
    };
    let body = catch_unwind(AssertUnwindSafe(|| f(&mut sc)));
    {
        // Bind this thread to the pool while helping, so jobs executed
        // here route *their* nested spawns back to the same pool.
        let worker = current_worker_on(&shared);
        let _ctx = enter_context(&shared, worker);
        shared.join(&sc.state, worker);
    }
    if let Err(p) = body {
        resume_unwind(p);
    }
    if let Some(p) = sc.state.panic.lock().unwrap().take() {
        resume_unwind(p);
    }
    sc.slots
        .iter()
        .map(|s| unsafe { s.take() }.expect("pool job did not complete"))
        .collect()
}

/// A worker pool. Use [`global`] (or the free [`scope`] / [`run_jobs`])
/// for production paths; `Pool::new` for tests that need a private pool
/// with a specific worker count.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `workers` threads (min 1). The only place the
    /// whole crate creates OS threads.
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            injector: Mutex::new(VecDeque::new()),
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Sleep { gen: Mutex::new(0), cv: Condvar::new() },
            sleepers: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dbpim-pool-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Pool { shared, handles }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.shared.deques.len()
    }

    /// Open a fork-join scope on *this* pool (see the free [`scope`]).
    pub fn scope<'env, T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'env,
        F: FnOnce(&mut Scope<'env, T>) + 'env,
    {
        scope_on(Arc::clone(&self.shared), f)
    }

    /// Run a batch of jobs on this pool; results in input order.
    pub fn run_jobs<'env, T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'env,
        F: FnOnce() -> T + Send + 'env,
    {
        self.scope(move |s| {
            for job in jobs {
                s.spawn(job);
            }
        })
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // bypass the no-sleepers fast path so shutdown is prompt even
        // if a worker is mid-registration
        self.shared.notify_locked();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Global pool
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<Pool> = OnceLock::new();
/// 0 = unset; set by [`configure_workers`] before first pool use.
static CONFIGURED_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Explicitly set the global pool size (the CLI's `--workers N`). Must
/// run before the pool's first use; returns false if the pool was
/// already initialized (the request then has no effect).
pub fn configure_workers(n: usize) -> bool {
    CONFIGURED_WORKERS.store(n.max(1), Ordering::SeqCst);
    GLOBAL.get().is_none()
}

fn env_workers() -> Option<usize> {
    std::env::var("DBPIM_WORKERS").ok()?.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

fn resolved_workers() -> usize {
    let configured = CONFIGURED_WORKERS.load(Ordering::SeqCst);
    let n = if configured > 0 {
        configured
    } else {
        env_workers().unwrap_or_else(super::default_workers)
    };
    n.clamp(1, 256)
}

/// The process-wide pool, created on first use.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| Pool::new(resolved_workers()))
}

/// Worker-thread count the global pool has — or would get, if it has
/// not been initialized yet (read-only paths like `dbpim info` must
/// not spawn the pool as a side effect of printing a number).
pub fn effective_workers() -> usize {
    GLOBAL.get().map(Pool::workers).unwrap_or_else(resolved_workers)
}

/// Open a fork-join scope on the current thread's pool: the pool this
/// thread is a worker of (nested case), else the global pool. Returns
/// the spawned jobs' results in spawn order.
pub fn scope<'env, T, F>(f: F) -> Vec<T>
where
    T: Send + 'env,
    F: FnOnce(&mut Scope<'env, T>) + 'env,
{
    let shared = match current_context() {
        Some((s, _)) => s,
        None => Arc::clone(&global().shared),
    };
    scope_on(shared, f)
}

/// Run a batch of jobs on the current thread's pool (see [`scope`]);
/// results in input order. The direct replacement for the old
/// fork-join `run_parallel` — same ordered-results contract, but jobs
/// land on the persistent pool and may spawn nested work.
pub fn run_jobs<'env, T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'env,
    F: FnOnce() -> T + Send + 'env,
{
    scope(move |s| {
        for job in jobs {
            s.spawn(job);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_jobs_preserves_order() {
        let jobs: Vec<_> = (0..64usize).map(|i| move || i * i).collect();
        assert_eq!(run_jobs(jobs), (0..64usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_pool_completes() {
        let pool = Pool::new(1);
        assert_eq!(pool.workers(), 1);
        let jobs: Vec<_> = (0..8u32).map(|i| move || i + 1).collect();
        assert_eq!(pool.run_jobs(jobs), (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn nested_scopes_execute_on_the_same_pool() {
        let pool = Pool::new(3);
        let outer: Vec<_> = (0..5usize)
            .map(|i| {
                move || {
                    // resolves to `pool` via the worker/helper context
                    let inner: Vec<_> = (0..7usize).map(|j| move || i * 10 + j).collect();
                    run_jobs(inner).iter().sum::<usize>()
                }
            })
            .collect();
        let got = pool.run_jobs(outer);
        let want: Vec<usize> = (0..5).map(|i| (0..7).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn scope_collects_in_spawn_order() {
        let vals = scope(|s| {
            for i in 0..10u64 {
                s.spawn(move || i * 3);
            }
        });
        assert_eq!(vals, (0..10).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn empty_scope_returns_empty() {
        let vals: Vec<u32> = scope(|_| {});
        assert!(vals.is_empty());
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = Pool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_jobs(vec![|| -> usize { panic!("boom") }]);
        }));
        assert!(r.is_err(), "job panic must reach the scope owner");
        // the worker caught the unwind: the pool stays functional
        assert_eq!(pool.run_jobs(vec![|| 41usize + 1]), vec![42]);
    }

    #[test]
    fn jobs_borrow_stack_data() {
        let data: Vec<u64> = (0..100).collect();
        let jobs: Vec<_> = data.chunks(10).map(|c| move || c.iter().sum::<u64>()).collect();
        let sums = run_jobs(jobs);
        assert_eq!(sums.len(), 10);
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn deep_nesting_does_not_deadlock() {
        // 3 levels on a 2-worker pool: joins must help, not block
        let pool = Pool::new(2);
        let outer: Vec<_> = (0..4usize)
            .map(|_| {
                || {
                    let mids: Vec<_> = (0..3usize)
                        .map(|i| move || run_jobs(vec![move || i]).len() + i)
                        .collect();
                    run_jobs(mids).iter().sum::<usize>()
                }
            })
            .collect();
        assert_eq!(pool.run_jobs(outer), vec![6; 4]);
    }

    #[test]
    fn zero_requested_workers_clamps_to_one() {
        assert_eq!(Pool::new(0).workers(), 1);
    }
}
