//! Small shared utilities: a deterministic PRNG (the vendored registry
//! has no `rand`), float summaries, and a tiny property-testing helper
//! used across the crate's unit tests (proptest is unavailable offline —
//! `Cases` provides the same "many random cases + shrink-free minimal
//! reporting" workflow).

/// SplitMix64 PRNG — deterministic, fast, good enough for weight
/// synthesis and randomized tests. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box–Muller output (perf: halves the cos/log cost
    /// of `normal`, the weight-synthesis hot spot — EXPERIMENTS.md §Perf).
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free (bias negligible for our n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (both outputs used).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Random INT8 value.
    #[inline]
    pub fn int8(&mut self) -> i8 {
        self.range_i64(-128, 127) as i8
    }

    /// Clipped-Gaussian INT8 weight (trained-CNN-like distribution).
    pub fn weight_int8(&mut self, sigma: f64) -> i8 {
        (self.normal() * sigma).round().clamp(-127.0, 127.0) as i8
    }
}

/// Minimal randomized-property harness: run `n` seeded cases; on failure
/// report the failing seed so the case is reproducible.
pub fn check_cases(n: u64, mut prop: impl FnMut(&mut Rng) -> std::result::Result<(), String>) {
    for seed in 0..n {
        let mut rng = Rng::new(0xD0E5_0000 ^ seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// ceil(a / b) for positive integers.
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Round `x` up to a multiple of `m`.
#[inline]
pub const fn round_up(x: usize, m: usize) -> usize {
    ceil_div(x, m) * m
}

/// Mean of an f64 slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Geometric mean (ignores non-positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() { 0.0 } else { (logs.iter().sum::<f64>() / logs.len() as f64).exp() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(13) < 13);
        }
    }

    #[test]
    fn range_i64_inclusive_covers_endpoints() {
        let mut rng = Rng::new(3);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = rng.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weight_int8_clips() {
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            let w = rng.weight_int8(100.0);
            assert!((-127..=127).contains(&(w as i32)));
        }
    }

    #[test]
    fn ceil_div_and_round_up() {
        assert_eq!(ceil_div(7, 3), 3);
        assert_eq!(ceil_div(6, 3), 2);
        assert_eq!(round_up(5, 8), 8);
        assert_eq!(round_up(16, 8), 16);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn check_cases_runs_all() {
        let mut count = 0;
        check_cases(16, |_| { count += 1; Ok(()) });
        assert_eq!(count, 16);
    }
}
