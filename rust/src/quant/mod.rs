//! Fixed-point requantization — the integer-exact scheme shared with
//! `python/compile/kernels/ref.py` (INT32 accumulator → INT8 activation):
//!
//! ```text
//! out = clamp( (acc as i64 * mul + (1 << (shift-1))) >> shift, -128, 127 )
//! ```
//!
//! with `shift = 16` and rounding half toward +inf. Both sides of the
//! stack (jnp golden graphs and this simulator) use identical semantics,
//! so e2e comparisons are bit-exact.

/// The shared fixed-point shift.
pub const REQUANT_SHIFT: u32 = 16;

/// Convert a float scale ratio into the fixed-point multiplier.
pub fn requant_mul(scale_ratio: f64) -> i32 {
    let mul = (scale_ratio * f64::from(1u32 << REQUANT_SHIFT)).round();
    assert!(
        (0.0..2147483648.0).contains(&mul),
        "requant ratio {scale_ratio} out of range"
    );
    mul as i32
}

/// Requantize one accumulator value.
#[inline]
pub fn requantize(acc: i32, mul: i32) -> i8 {
    let wide = acc as i64 * mul as i64;
    let rounded = (wide + (1i64 << (REQUANT_SHIFT - 1))) >> REQUANT_SHIFT;
    rounded.clamp(-128, 127) as i8
}

/// Requantize a slice in place into an i8 buffer.
pub fn requantize_slice(acc: &[i32], mul: i32, out: &mut [i8]) {
    assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = requantize(a, mul);
    }
}

/// Symmetric INT8 quantization scale from a float tensor's abs-max.
pub fn amax_scale(values: &[f32]) -> f32 {
    let amax = values.iter().fold(0f32, |m, &v| m.max(v.abs()));
    amax.max(1e-8) / 127.0
}

/// Quantize floats to INT8 with round-half-to-even (matches jnp.round).
pub fn quantize_f32(values: &[f32], scale: f32) -> Vec<i8> {
    values
        .iter()
        .map(|&v| {
            let q = (v / scale).round_ties_even();
            q.clamp(-128.0, 127.0) as i8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_round_trips_simple_ratio() {
        assert_eq!(requant_mul(0.5), 1 << 15);
        assert_eq!(requant_mul(1.0), 1 << 16);
    }

    #[test]
    fn rounding_half_toward_plus_inf() {
        let mul = 1 << 15; // ratio 0.5
        assert_eq!(requantize(1, mul), 1); // 0.5 -> 1
        assert_eq!(requantize(-1, mul), 0); // -0.5 -> 0
        assert_eq!(requantize(3, mul), 2); // 1.5 -> 2
        assert_eq!(requantize(-3, mul), -1); // -1.5 -> -1
    }

    #[test]
    fn clamps_to_int8() {
        let mul = 1 << 16; // ratio 1.0
        assert_eq!(requantize(1000, mul), 127);
        assert_eq!(requantize(-1000, mul), -128);
    }

    #[test]
    fn matches_python_fixture() {
        // Mirrors test_kernel.py::test_requantize_matches_fixed_point:
        // independent evaluation of the same rule on hand values.
        let mul = requant_mul(0.00317);
        for &(acc, expect) in &[(100_000i32, ((100_000i64 * mul as i64 + (1 << 15)) >> 16).clamp(-128, 127) as i8)] {
            assert_eq!(requantize(acc, mul), expect);
        }
    }

    #[test]
    fn no_overflow_at_extremes() {
        // worst-case acc (|acc| <= 2^23-ish in our layers) times max mul
        let mul = requant_mul(32767.99 / 65536.0 * 65536.0 / 65536.0);
        let _ = requantize(i32::MAX, mul);
        let _ = requantize(i32::MIN, mul);
    }

    #[test]
    fn quantize_f32_grid() {
        let xs = [0.0f32, 0.5, -0.5, 1.0, -1.27];
        let q = quantize_f32(&xs, 0.01);
        assert_eq!(q, vec![0, 50, -50, 100, -127]);
    }

    #[test]
    fn amax_scale_guarded() {
        assert!(amax_scale(&[]) > 0.0);
        assert!((amax_scale(&[1.27, -0.3]) - 0.01).abs() < 1e-6);
    }
}
