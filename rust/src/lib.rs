//! # DB-PIM — Dyadic Block Processing-In-Memory
//!
//! Reproduction of *"Efficient SRAM-PIM Co-design by Joint Exploration of
//! Value-Level and Bit-Level Sparsity"* (Duan et al., 2025) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time, python)** — the hybrid-grained pruning
//!   algorithm (coarse block pruning + CSD/FTA bit-level sparsity), the
//!   Pallas dyadic-matmul kernel, and the AOT-lowered golden HLO graphs.
//! * **Layer 3 (this crate)** — the offline compiler that maps pruned
//!   INT8 networks onto the DB-PIM macro grid, a cycle-accurate
//!   simulator of the architecture (sparse allocation network, IPU,
//!   DBMU compartments, CSD adder trees, SIMD core) plus its dense
//!   digital-PIM baseline, the energy model, and a coordinator that
//!   schedules per-layer jobs and verifies numerics against the golden
//!   HLO through the PJRT runtime.
//!
//! The crate is organised bottom-up; see `DESIGN.md` for the full system
//! inventory and the per-experiment index (every paper table/figure maps
//! to a bench target in `rust/benches/`).

pub mod arch;
pub mod benchlib;
pub mod compiler;
pub mod coordinator;
pub mod csd;
pub mod energy;
pub mod fta;
pub mod isa;
pub mod json;
pub mod models;
pub mod pruning;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
