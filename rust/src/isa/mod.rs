//! The DB-PIM instruction set.
//!
//! The offline compiler (Sec. III "offline compilation" → instructions
//! stored in the instruction buffer) emits one stream per layer; the top
//! controller in the simulator fetches, decodes and dispatches them.
//! The encoding is a fixed 12-byte little-endian word so the instruction
//! buffer occupancy (16 KB in the paper) can be checked per layer.

/// SIMD-core opcode (non-PIM operations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SimdOp {
    Relu = 0,
    MaxPool = 1,
    AvgPool = 2,
    Requant = 3,
    ResAdd = 4,
    Mul = 5,
    DwConv = 6,
}

impl SimdOp {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => SimdOp::Relu,
            1 => SimdOp::MaxPool,
            2 => SimdOp::AvgPool,
            3 => SimdOp::Requant,
            4 => SimdOp::ResAdd,
            5 => SimdOp::Mul,
            6 => SimdOp::DwConv,
            _ => return None,
        })
    }
}

/// One instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// Load weight tile `tile` into every macro of `core`
    /// (weight-stationary: done once per tile, reused over all M).
    LoadTile { core: u8, tile: u32 },
    /// Stream input rows `[m_base, m_base + m_count)` through `core`'s
    /// macros against the resident tile and accumulate partial sums.
    Compute { core: u8, tile: u32, m_base: u32, m_count: u16 },
    /// Drain the core's accumulators for those rows to the output buffer.
    Store { core: u8, tile: u32, m_base: u32, m_count: u16 },
    /// SIMD-core operation over `elems` elements.
    Simd { op: SimdOp, elems: u32 },
    /// Barrier: wait for all cores to finish outstanding work.
    Sync,
    /// End of the layer's stream.
    EndLayer,
}

/// Fixed encoding width (bytes).
pub const INSTR_BYTES: usize = 12;

const OP_LOAD: u8 = 1;
const OP_COMPUTE: u8 = 2;
const OP_STORE: u8 = 3;
const OP_SIMD: u8 = 4;
const OP_SYNC: u8 = 5;
const OP_END: u8 = 6;

impl Instr {
    /// Encode into the 12-byte instruction word.
    pub fn encode(&self) -> [u8; INSTR_BYTES] {
        let mut w = [0u8; INSTR_BYTES];
        match *self {
            Instr::LoadTile { core, tile } => {
                w[0] = OP_LOAD;
                w[1] = core;
                w[2..6].copy_from_slice(&tile.to_le_bytes());
            }
            Instr::Compute { core, tile, m_base, m_count } => {
                w[0] = OP_COMPUTE;
                w[1] = core;
                w[2..6].copy_from_slice(&tile.to_le_bytes());
                w[6..10].copy_from_slice(&m_base.to_le_bytes());
                w[10..12].copy_from_slice(&m_count.to_le_bytes());
            }
            Instr::Store { core, tile, m_base, m_count } => {
                w[0] = OP_STORE;
                w[1] = core;
                w[2..6].copy_from_slice(&tile.to_le_bytes());
                w[6..10].copy_from_slice(&m_base.to_le_bytes());
                w[10..12].copy_from_slice(&m_count.to_le_bytes());
            }
            Instr::Simd { op, elems } => {
                w[0] = OP_SIMD;
                w[1] = op as u8;
                w[2..6].copy_from_slice(&elems.to_le_bytes());
            }
            Instr::Sync => w[0] = OP_SYNC,
            Instr::EndLayer => w[0] = OP_END,
        }
        w
    }

    /// Decode one instruction word.
    pub fn decode(w: &[u8]) -> Option<Instr> {
        if w.len() < INSTR_BYTES {
            return None;
        }
        let tile = u32::from_le_bytes([w[2], w[3], w[4], w[5]]);
        let m_base = u32::from_le_bytes([w[6], w[7], w[8], w[9]]);
        let m_count = u16::from_le_bytes([w[10], w[11]]);
        Some(match w[0] {
            OP_LOAD => Instr::LoadTile { core: w[1], tile },
            OP_COMPUTE => Instr::Compute { core: w[1], tile, m_base, m_count },
            OP_STORE => Instr::Store { core: w[1], tile, m_base, m_count },
            OP_SIMD => Instr::Simd { op: SimdOp::from_u8(w[1])?, elems: tile },
            OP_SYNC => Instr::Sync,
            OP_END => Instr::EndLayer,
            _ => return None,
        })
    }
}

/// A barrier-free run of instructions for a single PIM core.
///
/// The segmented `Program` representation (compiler::program) splits a
/// layer's flat stream at `Sync`/`Simd`/`EndLayer` barriers into one
/// `Segment` per core and phase; the parallel engine executes segments
/// of one phase concurrently. A segment never contains a barrier
/// opcode — `decode` enforces this.
///
/// Wire format: one 12-byte header word (opcode `OP_SEG`, core id,
/// instruction count) followed by the instruction words, so segmented
/// programs share the instruction buffer's fixed-width framing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub core: u8,
    pub instrs: Vec<Instr>,
}

const OP_SEG: u8 = 0x10;

impl Segment {
    /// Encoded size in bytes (header + body).
    pub fn encoded_len(&self) -> usize {
        (self.instrs.len() + 1) * INSTR_BYTES
    }

    /// Encode as header word + instruction words.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        let mut h = [0u8; INSTR_BYTES];
        h[0] = OP_SEG;
        h[1] = self.core;
        h[2..6].copy_from_slice(&(self.instrs.len() as u32).to_le_bytes());
        out.extend_from_slice(&h);
        for i in &self.instrs {
            out.extend_from_slice(&i.encode());
        }
        out
    }

    /// Decode one segment from the head of `bytes`; returns the segment
    /// and the number of bytes consumed. Rejects barrier opcodes inside
    /// the body (segments are barrier-free by construction).
    pub fn decode(bytes: &[u8]) -> Option<(Segment, usize)> {
        if bytes.len() < INSTR_BYTES || bytes[0] != OP_SEG {
            return None;
        }
        let core = bytes[1];
        let len = u32::from_le_bytes([bytes[2], bytes[3], bytes[4], bytes[5]]) as usize;
        let total = len.checked_add(1)?.checked_mul(INSTR_BYTES)?;
        if bytes.len() < total {
            return None;
        }
        let mut instrs = Vec::with_capacity(len);
        for i in 0..len {
            let off = (i + 1) * INSTR_BYTES;
            let instr = Instr::decode(&bytes[off..off + INSTR_BYTES])?;
            if matches!(instr, Instr::Sync | Instr::EndLayer | Instr::Simd { .. }) {
                return None;
            }
            instrs.push(instr);
        }
        Some((Segment { core, instrs }, total))
    }
}

/// Encode a sequence of segments back-to-back.
pub fn encode_segments(segs: &[Segment]) -> Vec<u8> {
    let mut out = Vec::with_capacity(segs.iter().map(Segment::encoded_len).sum());
    for s in segs {
        out.extend_from_slice(&s.encode());
    }
    out
}

/// Decode a back-to-back segment stream (must consume all bytes).
pub fn decode_segments(bytes: &[u8]) -> Option<Vec<Segment>> {
    let mut out = Vec::new();
    let mut pos = 0;
    while pos < bytes.len() {
        let (seg, used) = Segment::decode(&bytes[pos..])?;
        out.push(seg);
        pos += used;
    }
    Some(out)
}

/// Encode a full stream.
pub fn encode_stream(instrs: &[Instr]) -> Vec<u8> {
    let mut out = Vec::with_capacity(instrs.len() * INSTR_BYTES);
    for i in instrs {
        out.extend_from_slice(&i.encode());
    }
    out
}

/// Decode a full stream.
pub fn decode_stream(bytes: &[u8]) -> Option<Vec<Instr>> {
    if bytes.len() % INSTR_BYTES != 0 {
        return None;
    }
    bytes.chunks_exact(INSTR_BYTES).map(Instr::decode).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_cases;

    fn sample() -> Vec<Instr> {
        vec![
            Instr::LoadTile { core: 3, tile: 77 },
            Instr::Compute { core: 3, tile: 77, m_base: 1024, m_count: 64 },
            Instr::Store { core: 3, tile: 77, m_base: 1024, m_count: 64 },
            Instr::Simd { op: SimdOp::DwConv, elems: 123_456 },
            Instr::Sync,
            Instr::EndLayer,
        ]
    }

    #[test]
    fn roundtrip_all_opcodes() {
        for i in sample() {
            assert_eq!(Instr::decode(&i.encode()), Some(i));
        }
    }

    #[test]
    fn stream_roundtrip() {
        let s = sample();
        let bytes = encode_stream(&s);
        assert_eq!(bytes.len(), s.len() * INSTR_BYTES);
        assert_eq!(decode_stream(&bytes), Some(s));
    }

    #[test]
    fn rejects_bad_opcode_and_length() {
        let mut w = [0u8; INSTR_BYTES];
        w[0] = 99;
        assert_eq!(Instr::decode(&w), None);
        assert_eq!(Instr::decode(&w[..4]), None);
        assert_eq!(decode_stream(&[0u8; 13]), None);
    }

    #[test]
    fn simd_ops_roundtrip() {
        for v in 0..7u8 {
            let op = SimdOp::from_u8(v).unwrap();
            assert_eq!(op as u8, v);
        }
        assert_eq!(SimdOp::from_u8(7), None);
    }

    #[test]
    fn segment_roundtrip() {
        let seg = Segment {
            core: 5,
            instrs: vec![
                Instr::LoadTile { core: 5, tile: 9 },
                Instr::Compute { core: 5, tile: 9, m_base: 0, m_count: 4 },
                Instr::Store { core: 5, tile: 9, m_base: 0, m_count: 4 },
            ],
        };
        let bytes = seg.encode();
        assert_eq!(bytes.len(), seg.encoded_len());
        let (got, used) = Segment::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(got, seg);
    }

    #[test]
    fn segment_stream_roundtrip() {
        let segs = vec![
            Segment { core: 0, instrs: vec![Instr::LoadTile { core: 0, tile: 1 }] },
            Segment { core: 1, instrs: vec![] },
            Segment {
                core: 7,
                instrs: vec![Instr::Compute { core: 7, tile: 2, m_base: 8, m_count: 2 }],
            },
        ];
        let bytes = encode_segments(&segs);
        assert_eq!(decode_segments(&bytes), Some(segs));
    }

    #[test]
    fn segment_rejects_barriers_and_truncation() {
        // a Sync word smuggled into a segment body must be rejected
        let mut bytes = Segment { core: 0, instrs: vec![] }.encode();
        bytes[2..6].copy_from_slice(&1u32.to_le_bytes()); // claim 1 instr
        bytes.extend_from_slice(&Instr::Sync.encode());
        assert_eq!(Segment::decode(&bytes), None);
        // truncated body
        let seg = Segment { core: 0, instrs: vec![Instr::LoadTile { core: 0, tile: 0 }] };
        let bytes = seg.encode();
        assert_eq!(Segment::decode(&bytes[..bytes.len() - 1]), None);
        // wrong header opcode
        assert_eq!(Segment::decode(&Instr::Sync.encode()), None);
    }

    #[test]
    fn random_segment_roundtrip_property() {
        check_cases(32, |rng| {
            let n = rng.below(20) as usize;
            let core = rng.below(8) as u8;
            let instrs: Vec<Instr> = (0..n)
                .map(|_| match rng.below(3) {
                    0 => Instr::LoadTile { core, tile: rng.next_u64() as u32 },
                    1 => Instr::Compute {
                        core,
                        tile: rng.next_u64() as u32,
                        m_base: rng.next_u64() as u32,
                        m_count: rng.next_u64() as u16,
                    },
                    _ => Instr::Store {
                        core,
                        tile: rng.next_u64() as u32,
                        m_base: rng.next_u64() as u32,
                        m_count: rng.next_u64() as u16,
                    },
                })
                .collect();
            let seg = Segment { core, instrs };
            let bytes = seg.encode();
            match Segment::decode(&bytes) {
                Some((got, used)) if got == seg && used == bytes.len() => Ok(()),
                other => Err(format!("segment roundtrip failed: {other:?}")),
            }
        });
    }

    #[test]
    fn random_instruction_roundtrip_property() {
        check_cases(64, |rng| {
            let i = match rng.below(6) {
                0 => Instr::LoadTile { core: rng.below(8) as u8, tile: rng.next_u64() as u32 },
                1 => Instr::Compute {
                    core: rng.below(8) as u8,
                    tile: rng.next_u64() as u32,
                    m_base: rng.next_u64() as u32,
                    m_count: rng.next_u64() as u16,
                },
                2 => Instr::Store {
                    core: rng.below(8) as u8,
                    tile: rng.next_u64() as u32,
                    m_base: rng.next_u64() as u32,
                    m_count: rng.next_u64() as u16,
                },
                3 => Instr::Simd {
                    op: SimdOp::from_u8(rng.below(7) as u8).unwrap(),
                    elems: rng.next_u64() as u32,
                },
                4 => Instr::Sync,
                _ => Instr::EndLayer,
            };
            if Instr::decode(&i.encode()) != Some(i) {
                return Err(format!("roundtrip failed for {i:?}"));
            }
            Ok(())
        });
    }
}
