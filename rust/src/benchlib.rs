//! Minimal benchmark harness (criterion is not in the offline vendored
//! registry). Provides warmup + repeated timing with median/min/mean
//! reporting, and a table printer used by the paper-figure benches so
//! every bench target prints the same rows/series the paper reports.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

/// Run `f` repeatedly: `warmup` unmeasured runs then `iters` measured.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let s = Sample {
        name: name.to_string(),
        iters: iters.max(1),
        mean,
        median: times[times.len() / 2],
        min: times[0],
    };
    println!(
        "bench {:40} iters={:3} mean={:>12?} median={:>12?} min={:>12?}",
        s.name, s.iters, s.mean, s.median, s.min
    );
    s
}

/// Print a markdown-style table (used for paper-figure regeneration).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
    println!();
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

/// Quick CLI arg: `--fast` trims bench scope (used by CI-style runs).
pub fn fast_mode() -> bool {
    std::env::args().any(|a| a == "--fast") || std::env::var("DBPIM_BENCH_FAST").is_ok()
}

/// One sample as a JSON object (nanosecond timings).
pub fn sample_json(s: &Sample) -> crate::json::Value {
    crate::json::obj(vec![
        ("name", crate::json::str_(&s.name)),
        ("iters", crate::json::num(s.iters as f64)),
        ("mean_ns", crate::json::num(s.mean.as_nanos() as f64)),
        ("median_ns", crate::json::num(s.median.as_nanos() as f64)),
        ("min_ns", crate::json::num(s.min.as_nanos() as f64)),
    ])
}

/// Machine-readable bench output for the perf trajectory (EXPERIMENTS.md
/// §Perf): when `DBPIM_BENCH_JSON` is set, write `BENCH_<bench>.json`
/// into the directory it names ("" or "1" ⇒ current directory). CI
/// uploads the file as the run's perf artifact.
pub fn write_bench_json(bench: &str, samples: &[Sample]) {
    let Ok(dir) = std::env::var("DBPIM_BENCH_JSON") else {
        return;
    };
    let dir = if dir.is_empty() || dir == "1" { ".".to_string() } else { dir };
    let doc = crate::json::obj(vec![
        ("bench", crate::json::str_(bench)),
        ("samples", crate::json::arr(samples.iter().map(sample_json).collect())),
    ]);
    let path = format!("{dir}/BENCH_{bench}.json");
    match std::fs::write(&path, crate::json::to_string(&doc)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let s = bench("noop", 1, 5, || std::hint::black_box(1 + 1));
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 2);
    }

    #[test]
    fn table_formats() {
        print_table("t", &["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(pct(0.5), "50.00%");
    }
}
