//! Canonical Signed Digit (CSD / non-adjacent form) codec and the
//! dyadic-block decomposition — bit-exact mirror of
//! `python/compile/csd.py`.
//!
//! An INT8 value becomes 8 digits in {-1, 0, 1} (LSB first) with no two
//! adjacent non-zeros; the 8 positions split into four *dyadic blocks*
//! (bit pairs). Non-adjacency guarantees each block carries at most one
//! signed digit, so a block is either the Zero pattern or a
//! Complementary pattern that fits the Q/Q̄ pair of one 6T SRAM cell.

/// Number of CSD digit positions for INT8.
pub const NUM_DIGITS: usize = 8;
/// Dyadic blocks per INT8 value.
pub const NUM_BLOCKS: usize = NUM_DIGITS / 2;
/// Maximum non-zero digit count (φ) for INT8.
pub const MAX_PHI: u8 = NUM_BLOCKS as u8;

/// CSD digits of one INT8 value, LSB first.
pub fn to_csd(value: i8) -> [i8; NUM_DIGITS] {
    let mut x = value as i32;
    let mut digits = [0i8; NUM_DIGITS];
    let mut i = 0;
    while x != 0 {
        if x & 1 != 0 {
            let d = 2 - (x & 3); // +1 when x % 4 == 1, -1 when x % 4 == 3
            x -= d;
            digits[i] = d as i8;
        }
        i += 1;
        x >>= 1;
    }
    debug_assert!(i <= NUM_DIGITS);
    digits
}

/// Decode CSD digits back to the integer value.
pub fn from_csd(digits: &[i8; NUM_DIGITS]) -> i32 {
    digits
        .iter()
        .enumerate()
        .map(|(i, &d)| (d as i32) << i)
        .sum()
}

/// Number of non-zero CSD digits (the paper's φ), in 0..=4.
#[inline]
pub fn phi(value: i8) -> u8 {
    PHI_TABLE[(value as u8) as usize]
}

/// Precomputed φ for all 256 INT8 values (index = value as u8).
pub static PHI_TABLE: [u8; 256] = build_phi_table();

const fn build_phi_table() -> [u8; 256] {
    let mut table = [0u8; 256];
    let mut i: i32 = -128;
    while i < 128 {
        let mut x = i;
        let mut count = 0u8;
        while x != 0 {
            if x & 1 != 0 {
                let d = 2 - (x & 3);
                x -= d;
                count += 1;
            }
            x >>= 1;
        }
        table[(i as u8) as usize] = count;
        i += 1;
    }
    table
}

/// Dyadic-block coefficients: block k covers digits (2k, 2k+1) and its
/// coefficient is `d[2k] + 2*d[2k+1]` in {-2..2}, so
/// `value == Σ_k coeff[k] << 2k`.
pub fn dyadic_blocks(value: i8) -> [i8; NUM_BLOCKS] {
    let d = to_csd(value);
    let mut out = [0i8; NUM_BLOCKS];
    let mut k = 0;
    while k < NUM_BLOCKS {
        out[k] = d[2 * k] + 2 * d[2 * k + 1];
        k += 1;
    }
    out
}

/// Inverse of [`dyadic_blocks`].
pub fn from_dyadic_blocks(coeffs: &[i8; NUM_BLOCKS]) -> i32 {
    coeffs
        .iter()
        .enumerate()
        .map(|(k, &c)| (c as i32) << (2 * k))
        .sum()
}

/// One Comp.-pattern block as stored in the DB-PIM meta RF + SRAM cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompBlock {
    /// Dyadic block index 0..=3 (the 2-bit "index" metadata).
    pub index: u8,
    /// True for a negative digit (the "sign" metadata bit).
    pub sign: bool,
    /// True when the digit sits at the odd position of the pair — this
    /// is the Q bit of the 6T cell (patterns `10`/`T0`); Q̄ covers the
    /// even position (patterns `01`/`0T`).
    pub odd: bool,
}

impl CompBlock {
    /// The signed contribution `±2^(2*index + odd)` of this block.
    pub fn contribution(&self) -> i32 {
        let mag = 1i32 << (2 * self.index as i32 + self.odd as i32);
        if self.sign { -mag } else { mag }
    }
}

/// Comp.-pattern metadata for a value — exactly `phi(value)` entries.
pub fn comp_blocks(value: i8) -> Vec<CompBlock> {
    dyadic_blocks(value)
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != 0)
        .map(|(k, &c)| CompBlock { index: k as u8, sign: c < 0, odd: c.abs() == 2 })
        .collect()
}

/// Fraction of non-zero CSD digits over a weight slice (Fig. 3a metric
/// under CSD encoding).
pub fn nonzero_digit_fraction(values: &[i8]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let nz: u64 = values.iter().map(|&v| phi(v) as u64).sum();
    nz as f64 / (values.len() * NUM_DIGITS) as f64
}

/// Fraction of non-zero bits under plain two's-complement encoding.
pub fn nonzero_binary_fraction(values: &[i8]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let nz: u64 = values.iter().map(|&v| (v as u8).count_ones() as u64).sum();
    nz as f64 / (values.len() * NUM_DIGITS) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_cases;

    #[test]
    fn roundtrip_exhaustive() {
        for v in i8::MIN..=i8::MAX {
            assert_eq!(from_csd(&to_csd(v)), v as i32, "value {v}");
            assert_eq!(from_dyadic_blocks(&dyadic_blocks(v)), v as i32);
        }
    }

    #[test]
    fn nonadjacent_property_exhaustive() {
        for v in i8::MIN..=i8::MAX {
            let d = to_csd(v);
            for i in 0..NUM_DIGITS - 1 {
                assert!(!(d[i] != 0 && d[i + 1] != 0), "adjacent digits in {v}");
            }
        }
    }

    #[test]
    fn digits_are_ternary() {
        for v in i8::MIN..=i8::MAX {
            assert!(to_csd(v).iter().all(|d| (-1..=1).contains(d)));
        }
    }

    #[test]
    fn phi_matches_digit_count() {
        for v in i8::MIN..=i8::MAX {
            let count = to_csd(v).iter().filter(|&&d| d != 0).count() as u8;
            assert_eq!(phi(v), count, "value {v}");
            assert!(phi(v) <= MAX_PHI);
        }
    }

    #[test]
    fn paper_example_67() {
        // Tab. I: 67 -> 0100_010T (digits at 6:+1, 2:+1, 0:-1).
        let d = to_csd(67);
        assert_eq!(d[0], -1);
        assert_eq!(d[2], 1);
        assert_eq!(d[6], 1);
        assert_eq!(d.iter().filter(|&&x| x != 0).count(), 3);
        // -67 -> 0T00_0T01
        let d = to_csd(-67);
        assert_eq!(d[0], 1);
        assert_eq!(d[2], -1);
        assert_eq!(d[6], -1);
    }

    #[test]
    fn blocks_hold_at_most_one_digit() {
        for v in i8::MIN..=i8::MAX {
            let d = to_csd(v);
            for k in 0..NUM_BLOCKS {
                assert!(d[2 * k] == 0 || d[2 * k + 1] == 0, "value {v} block {k}");
            }
        }
    }

    #[test]
    fn comp_blocks_count_equals_phi_and_sum_reconstructs() {
        for v in i8::MIN..=i8::MAX {
            let blocks = comp_blocks(v);
            assert_eq!(blocks.len(), phi(v) as usize);
            let sum: i32 = blocks.iter().map(|b| b.contribution()).sum();
            assert_eq!(sum, v as i32, "value {v}");
        }
    }

    #[test]
    fn comp_block_paper_example() {
        // -64 = 0T00_0000: single block at index 3, even position, negative.
        let blocks = comp_blocks(-64);
        assert_eq!(blocks, vec![CompBlock { index: 3, sign: true, odd: false }]);
        // 2: block 0, odd position, positive.
        let blocks = comp_blocks(2);
        assert_eq!(blocks, vec![CompBlock { index: 0, sign: false, odd: true }]);
    }

    #[test]
    fn csd_denser_than_binary_on_random_weights() {
        check_cases(4, |rng| {
            let vals: Vec<i8> = (0..4096).map(|_| rng.int8()).collect();
            let c = nonzero_digit_fraction(&vals);
            let b = nonzero_binary_fraction(&vals);
            if c >= b {
                return Err(format!("csd {c} >= binary {b}"));
            }
            // Reitwiesner asymptotic density is 1/3.
            if (c - 1.0 / 3.0).abs() > 0.04 {
                return Err(format!("csd density {c} far from 1/3"));
            }
            Ok(())
        });
    }

    #[test]
    fn phi_table_spot_checks() {
        assert_eq!(phi(0), 0);
        assert_eq!(phi(64), 1);
        assert_eq!(phi(-64), 1);
        assert_eq!(phi(85), 4); // 01010101 alternating
        assert_eq!(phi(-128), 1);
        assert_eq!(phi(127), 2); // 128 - 1
    }
}
