//! Architecture configuration for DB-PIM and its comparison points.
//!
//! Geometry follows the paper's Sec. V / VI-A: 8 PIM cores × Tm = 4
//! macros; each macro has Tk1 = 16 compartments × Tk2 = 16 SRAM rows ×
//! 16 DBMU columns (one 6T cell per column per row ⇒ 16 KB PIM capacity
//! across 32 macros); 28 nm, 500 MHz. Feature flags select the paper's
//! ablation points (Fig. 12's bit-only / value-only / hybrid) and the
//! DAC'24 predecessor configuration (Tab. III).

pub mod faultmap;

pub use faultmap::{CellFault, CellFaultSpec, DegradePolicy, FaultMap};

/// How assignments are spread over the PIM cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulePolicy {
    /// Greedy longest-processing-time balancing (default).
    Lpt,
    /// Naive round-robin (the paper's plain N-K-M loop order).
    RoundRobin,
}

/// Hardware + feature configuration shared by the compiler and simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    pub name: &'static str,
    /// Number of PIM cores (paper: 8).
    pub n_cores: usize,
    /// Macros per core, all storing identical weights for M-parallelism
    /// (the paper's Tm = 4).
    pub macros_per_core: usize,
    /// Compartments per macro (Tk1 = 16): spatially-parallel input lanes.
    pub compartments: usize,
    /// SRAM cell rows per compartment (Tk2 = 16): sequential (one LPU).
    pub rows_per_compartment: usize,
    /// DBMU columns per compartment (16) — the macro's column budget.
    pub macro_columns: usize,
    /// Input activation bit width (INT8 ⇒ 8 bit-serial cycles max).
    pub input_bits: usize,
    /// Clock (paper: 333–500 MHz; we use the top bin).
    pub freq_mhz: f64,
    /// Coarse pruning granularity α (macro columns / max φ_th).
    pub alpha: usize,
    /// SIMD core lanes for non-PIM ops (dw-conv, pool, ReLU, mul, ...).
    pub simd_lanes: usize,
    /// Cycles to load one full weight tile (weight-stationary, amortized
    /// over all M rows).
    pub tile_load_cycles: u64,

    // ---- sparsity feature flags (the paper's ablation axes) ----
    /// Customized DBMU macro storing only Comp.-pattern blocks
    /// (bit-level weight sparsity). Off ⇒ dense bit-parallel columns
    /// (8 columns per filter, 2 filters per macro).
    pub weight_bit_sparsity: bool,
    /// Sparse allocation network skipping coarse-pruned blocks
    /// (value-level sparsity).
    pub value_sparsity: bool,
    /// IPU dynamic skipping of block-wise all-zero input bit columns.
    pub input_skipping: bool,
    /// SIMD core present (end-to-end models; DAC'24 was conv-only).
    pub has_simd: bool,
    /// Merge column-compatible filter groups into one macro (the
    /// "16 filters at φ_th = 1" packing; ablation knob).
    pub merge_groups: bool,
    /// Core scheduling policy for assignments (ablation knob).
    pub schedule: SchedulePolicy,

    // ---- buffer capacities (KB) for the area/energy report ----
    pub input_buffer_kb: usize,
    pub output_buffer_kb: usize,
    pub inst_buffer_kb: usize,

    // ---- inter-chip interconnect (multi-chip sharding; DESIGN.md §12) ----
    /// Per-hop link latency charged to every chip-boundary crossing
    /// (cycles at the core clock).
    pub link_latency_cycles: u64,
    /// Link bandwidth: activation bytes moved per cycle once a transfer
    /// is streaming (serialization time = ceil(bytes / bw)).
    pub link_bandwidth_bytes_per_cycle: u64,

    // ---- SRAM bit-cell fault model (DESIGN.md §13) ----
    /// Bit-cell fault rates + seed; `CellFaultSpec::off()` (every
    /// preset's default) models a perfect array and compiles the whole
    /// fault subsystem out of the pipeline.
    pub cell_faults: CellFaultSpec,
    /// Spare DBMU columns per macro available to the compile-time
    /// repair pass (`compiler::packing::plan_repair`).
    pub spare_columns_per_macro: usize,
    /// Spare whole macros per core for macro-level sparing.
    pub spare_macros_per_core: usize,
    /// Runtime policy once an ABFT checksum flags a corrupted column.
    pub fault_degrade: DegradePolicy,
}

impl ArchConfig {
    /// The full DB-PIM configuration (this paper).
    pub fn db_pim() -> Self {
        Self {
            name: "db-pim",
            n_cores: 8,
            macros_per_core: 4,
            compartments: 16,
            rows_per_compartment: 16,
            macro_columns: 16,
            input_bits: 8,
            freq_mhz: 500.0,
            alpha: 8,
            simd_lanes: 64,
            tile_load_cycles: 64,
            weight_bit_sparsity: true,
            value_sparsity: true,
            input_skipping: true,
            has_simd: true,
            merge_groups: true,
            schedule: SchedulePolicy::Lpt,
            input_buffer_kb: 128,
            output_buffer_kb: 256,
            inst_buffer_kb: 16,
            link_latency_cycles: 16,
            link_bandwidth_bytes_per_cycle: 64,
            cell_faults: CellFaultSpec::off(),
            spare_columns_per_macro: 2,
            spare_macros_per_core: 1,
            fault_degrade: DegradePolicy::Recompute,
        }
    }

    /// Dense digital PIM baseline: all sparsity support removed
    /// (Sec. VI-A), same buffers/cores/macros.
    pub fn dense_baseline() -> Self {
        Self {
            name: "dense-baseline",
            weight_bit_sparsity: false,
            value_sparsity: false,
            input_skipping: false,
            ..Self::db_pim()
        }
    }

    /// Bit-level sparsity only (weights FTA + input IPU; Fig. 12
    /// "bit-level").
    pub fn bit_only() -> Self {
        Self { name: "bit-only", value_sparsity: false, ..Self::db_pim() }
    }

    /// Value-level sparsity only (allocation network, dense bit columns).
    pub fn value_only() -> Self {
        Self {
            name: "value-only",
            weight_bit_sparsity: false,
            input_skipping: false,
            ..Self::db_pim()
        }
    }

    /// Fig. 11 configuration: weight sparsity only, IPU disabled.
    pub fn weights_only() -> Self {
        Self { name: "weights-only", input_skipping: false, ..Self::db_pim() }
    }

    /// The DAC'24 predecessor (Tab. III): bit-level weight sparsity but
    /// no sparse allocation network, no IPU, no SIMD core, and half the
    /// core count (the journal version "expanded the architecture to
    /// increase computational parallelism").
    pub fn dac24() -> Self {
        Self {
            name: "dac24",
            n_cores: 4,
            value_sparsity: false,
            input_skipping: false,
            has_simd: false,
            ..Self::db_pim()
        }
    }

    /// Preset lookup by CLI/trace name (the `--arch` spellings shared
    /// by `dbpim simulate` and the serving frontend's replay traces).
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "db-pim" | "db_pim" => Self::db_pim(),
            "baseline" | "dense-baseline" => Self::dense_baseline(),
            "bit-only" => Self::bit_only(),
            "value-only" => Self::value_only(),
            "weights-only" => Self::weights_only(),
            "dac24" => Self::dac24(),
            _ => return None,
        })
    }

    /// Total macros (paper: 32).
    pub fn total_macros(&self) -> usize {
        self.n_cores * self.macros_per_core
    }

    /// SRAM cells per macro.
    pub fn cells_per_macro(&self) -> usize {
        self.compartments * self.rows_per_compartment * self.macro_columns
    }

    /// PIM capacity in KB (1 bit per 6T cell pair as in the paper's
    /// 16 KB across 32 macros... each cell stores one weight bit).
    pub fn pim_capacity_kb(&self) -> usize {
        self.total_macros() * self.cells_per_macro() / 8 / 1024
    }

    /// Row-slots (k positions) one macro covers per weight tile.
    pub fn k_slots(&self) -> usize {
        self.compartments * self.rows_per_compartment
    }

    /// Filters per macro in the *dense* mapping (bit-parallel INT8
    /// columns): 16 columns / 8 bits = 2.
    pub fn dense_filters_per_macro(&self) -> usize {
        self.macro_columns / self.input_bits
    }

    /// Clock period in nanoseconds.
    pub fn clock_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }

    /// Deterministic interconnect transfer cost: per-hop latency plus
    /// bandwidth-limited serialization time. Non-decreasing in both
    /// `bytes` and `hops`; zero only for a zero-byte, zero-hop move.
    pub fn link_transfer_cycles(&self, bytes: u64, hops: u64) -> u64 {
        let bw = self.link_bandwidth_bytes_per_cycle.max(1);
        hops * self.link_latency_cycles + bytes.div_ceil(bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let a = ArchConfig::db_pim();
        assert_eq!(a.total_macros(), 32);
        assert_eq!(a.cells_per_macro(), 4096);
        assert_eq!(a.pim_capacity_kb(), 16); // paper: 16 KB PIM
        assert_eq!(a.k_slots(), 256); // Tk = Tk1 * Tk2
        assert_eq!(a.dense_filters_per_macro(), 2);
        assert!((a.clock_ns() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_strips_all_sparsity() {
        let b = ArchConfig::dense_baseline();
        assert!(!b.weight_bit_sparsity && !b.value_sparsity && !b.input_skipping);
        assert_eq!(b.total_macros(), ArchConfig::db_pim().total_macros());
    }

    #[test]
    fn ablation_points_differ_only_in_flags() {
        let full = ArchConfig::db_pim();
        let bit = ArchConfig::bit_only();
        assert_eq!(bit.n_cores, full.n_cores);
        assert!(bit.weight_bit_sparsity && !bit.value_sparsity);
        let val = ArchConfig::value_only();
        assert!(!val.weight_bit_sparsity && val.value_sparsity);
    }

    #[test]
    fn by_name_resolves_every_preset() {
        for arch in [
            ArchConfig::db_pim(),
            ArchConfig::dense_baseline(),
            ArchConfig::bit_only(),
            ArchConfig::value_only(),
            ArchConfig::weights_only(),
            ArchConfig::dac24(),
        ] {
            let resolved = ArchConfig::by_name(arch.name).unwrap();
            assert_eq!(resolved, arch, "preset {} must resolve under its own name", arch.name);
        }
        // CLI alias spelling
        assert_eq!(ArchConfig::by_name("baseline").unwrap().name, "dense-baseline");
        assert!(ArchConfig::by_name("nope").is_none());
    }

    #[test]
    fn link_transfer_cost_monotone_in_bytes_and_hops() {
        let a = ArchConfig::db_pim();
        // non-decreasing in bytes at fixed hops
        for hops in [0u64, 1, 3, 15] {
            let mut prev = 0;
            for bytes in [0u64, 1, 63, 64, 65, 4096, 1 << 20] {
                let c = a.link_transfer_cycles(bytes, hops);
                assert!(c >= prev, "cost fell: {bytes} B / {hops} hops");
                prev = c;
            }
        }
        // non-decreasing in hops at fixed bytes
        for bytes in [0u64, 100, 1 << 16] {
            let mut prev = 0;
            for hops in 0u64..8 {
                let c = a.link_transfer_cycles(bytes, hops);
                assert!(c >= prev, "cost fell: {bytes} B / {hops} hops");
                prev = c;
            }
        }
        // exact shape: hops × latency + ceil(bytes / bw)
        assert_eq!(a.link_transfer_cycles(0, 0), 0);
        assert_eq!(a.link_transfer_cycles(1, 0), 1);
        assert_eq!(
            a.link_transfer_cycles(129, 2),
            2 * a.link_latency_cycles + 3,
            "129 B over a 64 B/cycle link is 3 beats"
        );
        // a zero-bandwidth config must not divide by zero
        let degenerate = ArchConfig { link_bandwidth_bytes_per_cycle: 0, ..a };
        assert_eq!(degenerate.link_transfer_cycles(10, 1), degenerate.link_latency_cycles + 10);
    }

    #[test]
    fn every_preset_ships_a_perfect_array() {
        for arch in [
            ArchConfig::db_pim(),
            ArchConfig::dense_baseline(),
            ArchConfig::bit_only(),
            ArchConfig::value_only(),
            ArchConfig::weights_only(),
            ArchConfig::dac24(),
        ] {
            assert!(!arch.cell_faults.enabled(), "{}: faults must default off", arch.name);
            assert_eq!(arch.fault_degrade, DegradePolicy::Recompute);
            assert!(arch.spare_columns_per_macro > 0, "{}: spare budget", arch.name);
        }
    }

    #[test]
    fn dac24_is_smaller_and_conv_only() {
        let d = ArchConfig::dac24();
        assert_eq!(d.total_macros(), 16);
        assert!(d.weight_bit_sparsity && !d.value_sparsity && !d.has_simd);
    }
}
