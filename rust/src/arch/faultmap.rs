//! Deterministic SRAM bit-cell fault model (DESIGN.md §13).
//!
//! Real SRAM-PIM macros suffer stuck-at cells (manufacturing defects,
//! aging) and transient upsets; a digital PIM array stores weight bits
//! *in* the faulty cells, so one bad cell silently corrupts every MAC
//! that reads it. This module gives the simulator a fault model with
//! the same determinism contract as `coordinator::faults` (serving
//! faults, DESIGN.md §11): every per-cell verdict is a **pure hash** of
//! `(seed, core, macro, compartment, row, col)` — no sequence, no
//! shared state — so fault placement is bit-identical for any engine,
//! worker count, steal order or visit order.
//!
//! Three axes, each its own Bernoulli rate over physical cells:
//!
//! * **stuck-at-0** — the cell always reads an empty payload; the
//!   stored Comp.-pattern block (or dense weight bit) is lost;
//! * **stuck-at-1** — the cell always reads the all-ones payload
//!   (sign = 1, odd = 1 in the CSD mapping; the bit set in the dense
//!   mapping);
//! * **transient** — the cell's sign/bit flips for the duration of the
//!   run (a seeded soft-error pattern; unknown at compile time, so the
//!   repair pass cannot steer around it — only ABFT detection sees it).
//!
//! Stuck faults are *known* at compile time (post-manufacturing test),
//! so `compiler::packing::plan_repair` steers weight columns away from
//! them using the spare column/macro budget. Detection is ABFT-style:
//! position-weighted column checksums over the dyadic-block
//! coefficients of the clean weight block ([`dyadic_checksums`]) are
//! recorded in `Program` metadata and re-verified at tile-load time
//! against the (possibly corrupted) resident block.

use crate::csd;
use crate::json::{num, obj, Value};
use crate::util::Rng;

/// One cell's fault class. Precedence when several rates fire on the
/// same cell: stuck-0 > stuck-1 > transient (a manufacturing defect
/// masks a soft error).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellFault {
    Stuck0,
    Stuck1,
    Transient,
}

/// What the runtime does once an ABFT checksum flags a corrupted
/// column (DESIGN.md §13): surface the corruption (`Fail`), zero the
/// flagged columns' contributions (`Mask`), or restore the exact clean
/// values from the scalar oracle at a deterministic cycle cost
/// (`Recompute`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DegradePolicy {
    /// Keep the corrupted values; detections are recorded and the
    /// orchestrating layer (serve loop, campaign) treats them as a
    /// failed unit of work.
    Fail,
    /// Zero the flagged dyadic-block contributions: bounded output
    /// error, no recompute cost.
    Mask,
    /// Recompute the flagged filters on the scalar oracle — bit-exact
    /// outputs at a per-detection latency charge.
    #[default]
    Recompute,
}

impl DegradePolicy {
    /// CLI/JSON tag (`--degrade fail|mask|recompute`).
    pub fn name(&self) -> &'static str {
        match self {
            DegradePolicy::Fail => "fail",
            DegradePolicy::Mask => "mask",
            DegradePolicy::Recompute => "recompute",
        }
    }

    pub fn parse(tag: &str) -> Option<Self> {
        Some(match tag {
            "fail" => DegradePolicy::Fail,
            "mask" => DegradePolicy::Mask,
            "recompute" | "recompute-on-scalar-oracle" => DegradePolicy::Recompute,
            _ => return None,
        })
    }
}

/// Bit-cell fault rates + the root seed of every cell verdict.
/// `off()` (all-zero rates) models a perfect array and is the default
/// on every `ArchConfig` preset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellFaultSpec {
    /// Per-cell stuck-at-0 probability, in [0, 1].
    pub ber_stuck0: f64,
    /// Per-cell stuck-at-1 probability, in [0, 1].
    pub ber_stuck1: f64,
    /// Per-cell transient-upset probability, in [0, 1].
    pub ber_transient: f64,
    /// Root seed for every cell verdict in the run.
    pub seed: u64,
}

impl CellFaultSpec {
    /// Perfect array — the spec under which the whole fault subsystem
    /// is compiled out of the pipeline (bit-identical to a build that
    /// never heard of faults).
    pub fn off() -> CellFaultSpec {
        CellFaultSpec { ber_stuck0: 0.0, ber_stuck1: 0.0, ber_transient: 0.0, seed: 0 }
    }

    /// All three axes at the same bit-error rate.
    pub fn uniform(ber: f64, seed: u64) -> CellFaultSpec {
        CellFaultSpec { ber_stuck0: ber, ber_stuck1: ber, ber_transient: ber, seed }
    }

    /// The stock mix used by `DBPIM_CELL_FAULT_SEED` and the CI fault
    /// leg: a uniform 1e-4 BER on every axis.
    pub fn default_with_seed(seed: u64) -> CellFaultSpec {
        CellFaultSpec::uniform(1e-4, seed)
    }

    /// Whether any fault axis is active.
    pub fn enabled(&self) -> bool {
        self.ber_stuck0 > 0.0 || self.ber_stuck1 > 0.0 || self.ber_transient > 0.0
    }

    /// `DBPIM_CELL_FAULT_SEED=N` turns on the stock cell-fault mix
    /// seeded with `N`; unset or unparsable → `None`.
    pub fn from_env() -> Option<CellFaultSpec> {
        let raw = std::env::var("DBPIM_CELL_FAULT_SEED").ok()?;
        let seed = raw.trim().parse::<u64>().ok()?;
        Some(CellFaultSpec::default_with_seed(seed))
    }

    pub fn validate(&self) -> Result<(), String> {
        let unit = |v: f64| (0.0..=1.0).contains(&v); // NaN fails both bounds
        for (name, v) in [
            ("ber_stuck0", self.ber_stuck0),
            ("ber_stuck1", self.ber_stuck1),
            ("ber_transient", self.ber_transient),
        ] {
            if !unit(v) {
                return Err(format!("cell faults: {name} must be in [0, 1], got {v}"));
            }
        }
        Ok(())
    }

    /// Derive the fault map of fleet chip `chip`: same rates, chip-mixed
    /// seed, so every chip of a sharded fleet has an independent (but
    /// replayable) defect pattern. Callers use this only for real
    /// fleets (`chips > 1`); the single-chip path keeps the root seed.
    pub fn for_chip(&self, chip: usize) -> CellFaultSpec {
        CellFaultSpec {
            seed: self.seed ^ 0xFA17_C811 ^ (chip as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD),
            ..*self
        }
    }

    /// The spec as cache-key bits: rate bit patterns + seed, normalized
    /// to all-zeros when the spec is off — so a disabled fault model
    /// never perturbs `CompileKey`/`SimKey` (goldens and cache counts
    /// stay bit-identical to a build without the subsystem), while any
    /// enabled spec keys every cached artifact on its exact rates+seed.
    pub fn key_bits(&self) -> [u64; 4] {
        if !self.enabled() {
            return [0; 4];
        }
        [
            self.ber_stuck0.to_bits(),
            self.ber_stuck1.to_bits(),
            self.ber_transient.to_bits(),
            self.seed,
        ]
    }

    /// Parse an optional `"cell_faults"` spec object; every rate
    /// defaults to 0 (off), so partial objects enable only the named
    /// axes.
    pub fn from_json(v: &Value) -> Result<CellFaultSpec, String> {
        let f = |key: &str| -> Result<f64, String> {
            match v.get(key) {
                None => Ok(0.0),
                Some(x) => {
                    x.as_f64().ok_or_else(|| format!("cell faults: \"{key}\" must be a number"))
                }
            }
        };
        let seed = match v.get("seed") {
            None => 0,
            Some(x) => x.as_usize().ok_or_else(|| {
                "cell faults: \"seed\" must be a non-negative integer".to_string()
            })? as u64,
        };
        let spec = CellFaultSpec {
            ber_stuck0: f("ber_stuck0")?,
            ber_stuck1: f("ber_stuck1")?,
            ber_transient: f("ber_transient")?,
            seed,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("seed", num(self.seed as f64)),
            ("ber_stuck0", num(self.ber_stuck0)),
            ("ber_stuck1", num(self.ber_stuck1)),
            ("ber_transient", num(self.ber_transient)),
        ])
    }
}

/// Decision tags keep the three per-cell hash streams independent of
/// each other (same pattern as `coordinator::faults`).
const TAG_STUCK0: u64 = 0xCE11_5EED_0000_0001;
const TAG_STUCK1: u64 = 0xCE11_5EED_0000_0002;
const TAG_TRANSIENT: u64 = 0xCE11_5EED_0000_0003;

/// One cell verdict hash: a fresh SplitMix64 stream keyed by the seed,
/// the axis tag and the full physical cell coordinate. One draw, then
/// discarded — there is no sequence to keep in sync across replays.
fn decide(seed: u64, tag: u64, core: usize, mac: usize, comp: usize, row: usize, col: usize) -> u64 {
    Rng::new(
        seed ^ tag
            ^ (core as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (mac as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
            ^ (comp as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD)
            ^ (row as u64).wrapping_mul(0xC4CE_B9FE_1A85_EC53)
            ^ (col as u64).wrapping_mul(0x2545_F491_4F6C_DD1D),
    )
    .next_u64()
}

/// Map a hash to a uniform f64 in [0, 1).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// The fault map of one chip: a stateless view over the pure per-cell
/// verdicts of a [`CellFaultSpec`]. Cheap to construct (`Copy` spec, no
/// allocation); query order never matters.
#[derive(Debug, Clone, Copy)]
pub struct FaultMap {
    spec: CellFaultSpec,
}

impl FaultMap {
    pub fn new(spec: CellFaultSpec) -> FaultMap {
        FaultMap { spec }
    }

    pub fn spec(&self) -> CellFaultSpec {
        self.spec
    }

    /// Verdict for one physical cell `(core, macro, compartment, row,
    /// col)`. Pure in the spec and the coordinate.
    pub fn cell(&self, core: usize, mac: usize, comp: usize, row: usize, col: usize) -> Option<CellFault> {
        if !self.spec.enabled() {
            return None;
        }
        let s = self.spec;
        if s.ber_stuck0 > 0.0
            && unit(decide(s.seed, TAG_STUCK0, core, mac, comp, row, col)) < s.ber_stuck0
        {
            return Some(CellFault::Stuck0);
        }
        if s.ber_stuck1 > 0.0
            && unit(decide(s.seed, TAG_STUCK1, core, mac, comp, row, col)) < s.ber_stuck1
        {
            return Some(CellFault::Stuck1);
        }
        if s.ber_transient > 0.0
            && unit(decide(s.seed, TAG_TRANSIENT, core, mac, comp, row, col)) < s.ber_transient
        {
            return Some(CellFault::Transient);
        }
        None
    }

    /// Is any cell of physical column `col` of `(core, mac)` *stuck*
    /// (compile-time-known defect)? Transients don't count — the
    /// repair pass cannot see them.
    pub fn column_stuck(&self, core: usize, mac: usize, col: usize, comps: usize, rows: usize) -> bool {
        if !self.spec.enabled() {
            return false;
        }
        for comp in 0..comps {
            for row in 0..rows {
                if matches!(
                    self.cell(core, mac, comp, row, col),
                    Some(CellFault::Stuck0 | CellFault::Stuck1)
                ) {
                    return true;
                }
            }
        }
        false
    }

    /// All faulty cells of physical column `col` of `(core, mac)`, as
    /// `(compartment, row, fault)` triples in fixed scan order.
    pub fn column_faults(
        &self,
        core: usize,
        mac: usize,
        col: usize,
        comps: usize,
        rows: usize,
    ) -> Vec<(usize, usize, CellFault)> {
        let mut out = Vec::new();
        if !self.spec.enabled() {
            return out;
        }
        for comp in 0..comps {
            for row in 0..rows {
                if let Some(f) = self.cell(core, mac, comp, row, col) {
                    out.push((comp, row, f));
                }
            }
        }
        out
    }
}

/// Corrupt one resident weight according to the fault class of the
/// cell holding its `col_in_filter`-th column. Pure value-level model
/// of what the macro would read back:
///
/// * CSD mapping (`bit_sparsity`): column `j` holds the `j`-th
///   Comp.-pattern block of the weight. An *empty* slot (`j ≥ φ(w)`) is
///   never addressed by the allocation network, so faults there are
///   inert. On an occupied slot: stuck-0 loses the block's
///   contribution, stuck-1 reads the all-ones payload
///   (`-2^(2·index+1)` in place of the true contribution), a transient
///   flips the sign.
/// * Dense mapping: column `j` holds two's-complement bit `j`;
///   stuck-0/stuck-1/transient clear/set/flip it.
///
/// The result saturates to i8 (the adder tree's resident operand
/// width).
pub fn corrupt_weight(w: i8, col_in_filter: usize, bit_sparsity: bool, kind: CellFault) -> i8 {
    if bit_sparsity {
        let blocks = csd::comp_blocks(w);
        let Some(b) = blocks.get(col_in_filter) else {
            return w; // empty slot: not addressed
        };
        let c = b.contribution();
        let v = match kind {
            CellFault::Stuck0 => w as i32 - c,
            CellFault::Stuck1 => w as i32 - c - (1 << (2 * b.index as i32 + 1)),
            CellFault::Transient => w as i32 - 2 * c,
        };
        v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
    } else {
        if col_in_filter >= csd::NUM_DIGITS {
            return w;
        }
        let bit = 1u8 << col_in_filter;
        let b = w as u8;
        (match kind {
            CellFault::Stuck0 => b & !bit,
            CellFault::Stuck1 => b | bit,
            CellFault::Transient => b ^ bit,
        }) as i8
    }
}

/// ABFT column checksums over dyadic blocks: for every filter slot `f`
/// of a `[rows × nf]` weight block and every dyadic block index
/// `k ∈ 0..4`, the position-weighted sum
/// `Σ_r mix(r) · coeff_k(w[r, f])` (wrapping u64 arithmetic, odd
/// per-row multipliers). A single changed coefficient in any row
/// changes its `(f, k)` sum (odd multipliers are invertible mod 2^64),
/// and distinct rows carry decorrelated 64-bit weights, so any
/// corruption of the resident block is detected except under a 2^-64
/// class hash collision. Layout: `sums[f * NUM_BLOCKS + k]`.
pub fn dyadic_checksums(wblock: &[i8], nf: usize) -> Vec<u64> {
    if nf == 0 {
        return Vec::new();
    }
    let rows = wblock.len() / nf;
    let mut sums = vec![0u64; nf * csd::NUM_BLOCKS];
    for r in 0..rows {
        let mix = row_mix(r);
        for f in 0..nf {
            let coeffs = csd::dyadic_blocks(wblock[r * nf + f]);
            for (k, &c) in coeffs.iter().enumerate() {
                sums[f * csd::NUM_BLOCKS + k] =
                    sums[f * csd::NUM_BLOCKS + k].wrapping_add((c as i64 as u64).wrapping_mul(mix));
            }
        }
    }
    sums
}

/// Per-row checksum multiplier: a SplitMix64 draw forced odd, so a
/// single-row coefficient change can never sum to zero.
fn row_mix(r: usize) -> u64 {
    Rng::new(0xABF7_C0DE ^ (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64() | 1
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn verdicts_are_pure_and_order_independent() {
        let a = FaultMap::new(CellFaultSpec::uniform(0.01, 7));
        let b = FaultMap::new(CellFaultSpec::uniform(0.01, 7));
        // forward vs reverse visit order: identical verdicts
        let coords: Vec<(usize, usize, usize, usize, usize)> = (0..4)
            .flat_map(|c| (0..2).map(move |m| (c, m)))
            .flat_map(|(c, m)| (0..8).map(move |col| (c, m, col % 4, col / 2, col)))
            .collect();
        let fwd: Vec<_> = coords.iter().map(|&(c, m, k, r, l)| a.cell(c, m, k, r, l)).collect();
        let rev: Vec<_> =
            coords.iter().rev().map(|&(c, m, k, r, l)| b.cell(c, m, k, r, l)).collect();
        let rev: Vec<_> = rev.into_iter().rev().collect();
        assert_eq!(fwd, rev);
        // a different seed flips at least some verdicts at a high rate
        let c = FaultMap::new(CellFaultSpec::uniform(0.5, 8));
        let flips = coords
            .iter()
            .filter(|&&(co, m, k, r, l)| a.cell(co, m, k, r, l) != c.cell(co, m, k, r, l))
            .count();
        assert!(flips > 0, "seed must matter");
    }

    #[test]
    fn off_spec_is_inert() {
        let m = FaultMap::new(CellFaultSpec::off());
        for col in 0..64 {
            assert_eq!(m.cell(0, 0, col % 16, col / 4, col), None);
        }
        assert!(!m.column_stuck(0, 0, 3, 16, 16));
        assert!(m.column_faults(0, 0, 3, 16, 16).is_empty());
        assert_eq!(CellFaultSpec::off().key_bits(), [0; 4]);
        assert!(!CellFaultSpec::off().enabled());
        assert!(CellFaultSpec::default_with_seed(1).enabled());
    }

    #[test]
    fn rates_roughly_respected() {
        let m = FaultMap::new(CellFaultSpec { ber_stuck0: 0.2, ..CellFaultSpec::uniform(0.0, 3) });
        let n = 20_000usize;
        let hits = (0..n).filter(|&i| m.cell(0, 0, 0, 0, i).is_some()).count() as f64 / n as f64;
        assert!((hits - 0.2).abs() < 0.02, "observed stuck0 rate {hits}");
    }

    #[test]
    fn for_chip_streams_differ_and_key_bits_scope() {
        let s = CellFaultSpec::default_with_seed(11);
        assert_ne!(s.for_chip(0).seed, s.for_chip(1).seed);
        assert_eq!(s.for_chip(2).ber_stuck0, s.ber_stuck0);
        assert_ne!(s.key_bits(), [0; 4]);
        // two enabled specs with different seeds key differently
        assert_ne!(s.key_bits(), CellFaultSpec::default_with_seed(12).key_bits());
    }

    #[test]
    fn corrupt_weight_models_each_axis() {
        for v in i8::MIN..=i8::MAX {
            let phi = csd::phi(v) as usize;
            for j in 0..csd::NUM_DIGITS {
                // CSD mapping: empty slots are inert, occupied slots change
                let s0 = corrupt_weight(v, j, true, CellFault::Stuck0);
                let tr = corrupt_weight(v, j, true, CellFault::Transient);
                if j >= phi {
                    assert_eq!(s0, v);
                    assert_eq!(tr, v);
                } else {
                    let c = csd::comp_blocks(v)[j].contribution();
                    assert_eq!(s0 as i32, v as i32 - c, "value {v} col {j}");
                    // transient flips the sign of the block
                    assert_eq!(tr as i32, (v as i32 - 2 * c).clamp(-128, 127));
                }
                // dense mapping: exact bit semantics
                let d0 = corrupt_weight(v, j, false, CellFault::Stuck0);
                let d1 = corrupt_weight(v, j, false, CellFault::Stuck1);
                let dt = corrupt_weight(v, j, false, CellFault::Transient);
                let bit = 1u8 << j;
                assert_eq!(d0 as u8, v as u8 & !bit);
                assert_eq!(d1 as u8, v as u8 | bit);
                assert_eq!(dt as u8, v as u8 ^ bit);
            }
        }
    }

    #[test]
    fn checksums_detect_any_single_value_change() {
        let nf = 3;
        let wblock: Vec<i8> = (0..60).map(|i| (i * 7 % 255) as u8 as i8).collect();
        let clean = dyadic_checksums(&wblock, nf);
        for pos in [0usize, 1, 17, 59] {
            for delta in [1i8, -3, 100] {
                let mut bad = wblock.clone();
                let nv = bad[pos].wrapping_add(delta);
                if nv == bad[pos] {
                    continue;
                }
                bad[pos] = nv;
                assert_ne!(dyadic_checksums(&bad, nf), clean, "pos {pos} delta {delta}");
            }
        }
        // identical block: identical sums
        assert_eq!(dyadic_checksums(&wblock, nf), clean);
    }

    #[test]
    fn spec_json_roundtrip_and_validation() {
        let spec = CellFaultSpec::default_with_seed(9);
        let back = CellFaultSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        let v = crate::json::parse(r#"{"seed": 3, "ber_stuck0": 0.001}"#).unwrap();
        let p = CellFaultSpec::from_json(&v).unwrap();
        assert_eq!(p.seed, 3);
        assert_eq!(p.ber_stuck0, 0.001);
        assert_eq!(p.ber_stuck1, 0.0);
        let bad = crate::json::parse(r#"{"ber_transient": 2.0}"#).unwrap();
        assert!(CellFaultSpec::from_json(&bad).is_err());
        assert!(CellFaultSpec { ber_stuck0: f64::NAN, ..CellFaultSpec::off() }.validate().is_err());
        assert!(DegradePolicy::parse("mask") == Some(DegradePolicy::Mask));
        assert!(DegradePolicy::parse("nope").is_none());
        assert_eq!(DegradePolicy::parse(DegradePolicy::Fail.name()), Some(DegradePolicy::Fail));
    }
}
