//! Network descriptors: the five evaluation CNNs of the paper
//! (CIFAR-100 geometry) plus the MiniNet e2e-verification model loaded
//! from the python-exported artifact manifest.
//!
//! A [`Network`] is a flat list of [`Layer`]s. Conv/pointwise/FC layers
//! run on the PIM array; depthwise conv, pooling, ReLU, residual adds
//! and element-wise multiplies run on the SIMD core (exactly the split
//! the paper uses — Fig. 13's execution-time breakdown falls out of
//! this partition).
//!
//! Transformer workloads (DESIGN.md §14) lower onto the same split:
//! every attention/MLP GEMM is a PIM layer via [`LayerKind::matmul_dims`]
//! (per-head QKV/score/context matmuls parameterized by `heads`,
//! `d_model`, `seq_len`), while LayerNorm runs on the SIMD core like
//! the other element-wise kinds. Anything that answers
//! `matmul_dims() == Some(..)` flows through compile/sim/cache/sharding
//! untouched — that one predicate is the single source of PIM-ness.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod fixtures;
pub mod mininet;
mod zoo;

pub use mininet::{default_artifacts_dir, load_mininet, MiniNet, MiniNetLayer};
pub use zoo::{
    alexnet, bert_base, by_name, default_seq_len, efficientnet_b0, gpt_micro, mobilenet_v2,
    resnet18, tiny_transformer, transformer_seq, transformers, vgg19, zoo, Registry,
};

use crate::util::Rng;

/// One network layer (geometry only; weights are synthesized or loaded
/// separately).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

/// Layer taxonomy. Spatial sizes are single-image (batch handled by M).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Standard or pointwise convolution (PIM). `in_hw` is the input
    /// spatial size; pointwise ⇔ kernel == 1.
    Conv { in_ch: usize, out_ch: usize, kernel: usize, stride: usize, pad: usize, in_hw: usize },
    /// Depthwise convolution (SIMD core).
    DwConv { ch: usize, kernel: usize, stride: usize, pad: usize, in_hw: usize },
    /// Fully-connected layer (PIM).
    Fc { in_features: usize, out_features: usize },
    /// Max/avg pooling over `elems` input elements (SIMD core).
    Pool { elems: usize },
    /// ReLU / activation over `elems` elements (SIMD core).
    Act { elems: usize },
    /// Residual addition over `elems` elements (SIMD core).
    ResAdd { elems: usize },
    /// Element-wise multiply over `elems` elements (SIMD core; SE
    /// blocks and the paper's "Mul" category in Fig. 13).
    Mul { elems: usize },
    /// One multi-head-attention GEMM lowered onto the PIM matmul path
    /// (DESIGN.md §14). The builder emits one layer per head for the
    /// per-head projections; `proj` picks which of the block's matmuls
    /// this layer is and fixes the (M, K, N) derived from `d_model`,
    /// `heads` and `seq_len`. `head_sparsity_pct`, when set, overrides
    /// the run's value-sparsity target for this head's weights (the
    /// per-head pruning config), as an integer percent in [0, 99];
    /// dense runs ignore it so baseline references stay truly dense.
    Attention {
        heads: usize,
        d_model: usize,
        seq_len: usize,
        proj: AttnProj,
        head_sparsity_pct: Option<u8>,
    },
    /// Transformer feed-forward / projection GEMM over a full sequence
    /// (PIM): `seq_len × d_in · d_in × d_out`. `nm`, when set, applies
    /// N:M structured pruning — keep the `n` largest of every `m`
    /// consecutive input-row weights per filter — to the synthesized
    /// weights before value pruning (ignored on dense runs).
    Mlp { seq_len: usize, d_in: usize, d_out: usize, nm: Option<(u8, u8)> },
    /// LayerNorm over `elems` activations (SIMD core; costed as an
    /// element-wise pass like the other SIMD kinds).
    LayerNorm { elems: usize },
}

/// Which GEMM of a multi-head attention block an
/// [`LayerKind::Attention`] layer models. Q/K/V share a shape, so one
/// tag covers all three input projections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnProj {
    /// Per-head Q/K/V input projection:
    /// `seq_len × d_model · d_model × (d_model / heads)`.
    Qkv,
    /// Per-head score matmul Q·Kᵀ:
    /// `seq_len × head_dim · head_dim × seq_len`.
    Score,
    /// Per-head context matmul softmax(S)·V:
    /// `seq_len × seq_len · seq_len × head_dim`.
    Context,
    /// Concat-heads output projection:
    /// `seq_len × d_model · d_model × d_model`.
    Output,
}

impl LayerKind {
    /// Is this layer mapped onto the PIM array (std/pw-conv + FC +
    /// attention/MLP GEMMs)? Equivalent to `matmul_dims().is_some()`.
    pub fn is_pim(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv { .. }
                | LayerKind::Fc { .. }
                | LayerKind::Attention { .. }
                | LayerKind::Mlp { .. }
        )
    }

    /// im2col/GEMM problem size (M, K, N) for PIM layers; None
    /// otherwise. Exhaustive over the taxonomy on purpose: a new kind
    /// must declare here whether it is a GEMM, never fall through a
    /// wildcard into the SIMD path silently.
    pub fn matmul_dims(&self) -> Option<(usize, usize, usize)> {
        match *self {
            LayerKind::Conv { in_ch, out_ch, kernel, stride, pad, in_hw } => {
                let out_hw = (in_hw + 2 * pad - kernel) / stride + 1;
                Some((out_hw * out_hw, in_ch * kernel * kernel, out_ch))
            }
            LayerKind::Fc { in_features, out_features } => Some((1, in_features, out_features)),
            LayerKind::Attention { heads, d_model, seq_len, proj, .. } => {
                let head_dim = d_model / heads.max(1);
                Some(match proj {
                    AttnProj::Qkv => (seq_len, d_model, head_dim),
                    AttnProj::Score => (seq_len, head_dim, seq_len),
                    AttnProj::Context => (seq_len, seq_len, head_dim),
                    AttnProj::Output => (seq_len, d_model, d_model),
                })
            }
            LayerKind::Mlp { seq_len, d_in, d_out, .. } => Some((seq_len, d_in, d_out)),
            LayerKind::DwConv { .. }
            | LayerKind::Pool { .. }
            | LayerKind::Act { .. }
            | LayerKind::ResAdd { .. }
            | LayerKind::Mul { .. }
            | LayerKind::LayerNorm { .. } => None,
        }
    }

    /// MAC count (for OPS accounting; 1 MAC = 2 OPs).
    pub fn macs(&self) -> u64 {
        // Every GEMM-shaped (PIM) kind is covered by its problem size;
        // the match below only prices the SIMD kinds.
        if let Some((m, k, n)) = self.matmul_dims() {
            return (m * k * n) as u64;
        }
        match *self {
            LayerKind::DwConv { ch, kernel, stride, pad, in_hw } => {
                let out_hw = (in_hw + 2 * pad - kernel) / stride + 1;
                (ch * out_hw * out_hw * kernel * kernel) as u64
            }
            LayerKind::Pool { elems }
            | LayerKind::Act { elems }
            | LayerKind::ResAdd { elems }
            | LayerKind::Mul { elems }
            | LayerKind::LayerNorm { elems } => elems as u64,
            // PIM kinds returned above; listed so the match stays
            // exhaustive (and panic-free) if the taxonomy grows.
            LayerKind::Conv { .. }
            | LayerKind::Fc { .. }
            | LayerKind::Attention { .. }
            | LayerKind::Mlp { .. } => 0,
        }
    }
}

/// A whole network.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub input_ch: usize,
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total MACs over PIM layers (std/pw conv + FC).
    pub fn pim_macs(&self) -> u64 {
        self.layers.iter().filter(|l| l.kind.is_pim()).map(|l| l.kind.macs()).sum()
    }

    /// Total MACs/element-ops over SIMD layers.
    pub fn simd_macs(&self) -> u64 {
        self.layers.iter().filter(|l| !l.kind.is_pim()).map(|l| l.kind.macs()).sum()
    }

    pub fn pim_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind.is_pim())
    }
}

/// Synthesized INT8 weights for one PIM layer, im2col layout [K, N]
/// row-major, drawn from a clipped Gaussian (trained-CNN-like; the
/// substitution for the paper's trained CIFAR-100 checkpoints — see
/// DESIGN.md §3).
pub fn synthesize_weights(layer_seed: u64, k: usize, n: usize) -> Vec<i8> {
    let mut rng = Rng::new(layer_seed);
    // Per-filter magnitude spread (log-normal): trained CNNs quantized
    // per-layer have filters of widely varying norms, which is what
    // makes FTA thresholds land on a mix of φ_th ∈ {1, 2} (the paper's
    // "filter thresholds vary between 0 and 2" for redundant models).
    let sigmas: Vec<f64> = (0..n)
        .map(|_| (20.0 * (0.9 * rng.normal()).exp()).clamp(2.5, 60.0))
        .collect();
    let mut out = vec![0i8; k * n];
    for row in 0..k {
        for (col, &sigma) in sigmas.iter().enumerate() {
            out[row * n + col] = rng.weight_int8(sigma);
        }
    }
    out
}

/// Synthesized INT8 activations with ReLU-like statistics (~half zeros,
/// small magnitudes) — used where real activations are not available.
pub fn synthesize_activations(seed: u64, len: usize) -> Vec<i8> {
    let mut rng = Rng::new(seed ^ 0xAC71_1A7E);
    (0..len)
        .map(|_| {
            if rng.f64() < 0.5 {
                0
            } else {
                // heavy-tailed small magnitudes: quantized post-ReLU
                // activations concentrate near zero (bits 4–7 rarely
                // set), which is what makes the IPU's group-wise
                // zero-column skipping pay off (Fig. 3b).
                (1.0 + rng.normal().abs() * 6.0).min(127.0) as i8
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_matmul_dims() {
        let k = LayerKind::Conv { in_ch: 64, out_ch: 128, kernel: 3, stride: 1, pad: 1, in_hw: 16 };
        assert_eq!(k.matmul_dims(), Some((256, 576, 128)));
        assert!(k.is_pim());
        assert_eq!(k.macs(), 256 * 576 * 128);
    }

    #[test]
    fn fc_dims() {
        let k = LayerKind::Fc { in_features: 512, out_features: 100 };
        assert_eq!(k.matmul_dims(), Some((1, 512, 100)));
    }

    #[test]
    fn attention_dims_per_proj() {
        let mk = |proj| LayerKind::Attention {
            heads: 12,
            d_model: 768,
            seq_len: 128,
            proj,
            head_sparsity_pct: Some(60),
        };
        assert_eq!(mk(AttnProj::Qkv).matmul_dims(), Some((128, 768, 64)));
        assert_eq!(mk(AttnProj::Score).matmul_dims(), Some((128, 64, 128)));
        assert_eq!(mk(AttnProj::Context).matmul_dims(), Some((128, 128, 64)));
        assert_eq!(mk(AttnProj::Output).matmul_dims(), Some((128, 768, 768)));
        assert!(mk(AttnProj::Qkv).is_pim());
        assert_eq!(mk(AttnProj::Qkv).macs(), 128 * 768 * 64);
    }

    #[test]
    fn mlp_and_layernorm_split() {
        let m = LayerKind::Mlp { seq_len: 64, d_in: 256, d_out: 1024, nm: Some((2, 4)) };
        assert!(m.is_pim());
        assert_eq!(m.matmul_dims(), Some((64, 256, 1024)));
        assert_eq!(m.macs(), 64 * 256 * 1024);
        let ln = LayerKind::LayerNorm { elems: 64 * 256 };
        assert!(!ln.is_pim());
        assert_eq!(ln.matmul_dims(), None);
        assert_eq!(ln.macs(), 64 * 256);
    }

    #[test]
    fn is_pim_agrees_with_matmul_dims() {
        let kinds = [
            LayerKind::Conv { in_ch: 8, out_ch: 8, kernel: 3, stride: 1, pad: 1, in_hw: 4 },
            LayerKind::DwConv { ch: 8, kernel: 3, stride: 1, pad: 1, in_hw: 4 },
            LayerKind::Fc { in_features: 8, out_features: 8 },
            LayerKind::Pool { elems: 8 },
            LayerKind::Act { elems: 8 },
            LayerKind::ResAdd { elems: 8 },
            LayerKind::Mul { elems: 8 },
            LayerKind::Attention {
                heads: 2,
                d_model: 32,
                seq_len: 16,
                proj: AttnProj::Score,
                head_sparsity_pct: None,
            },
            LayerKind::Mlp { seq_len: 16, d_in: 32, d_out: 64, nm: None },
            LayerKind::LayerNorm { elems: 8 },
        ];
        for k in kinds {
            assert_eq!(k.is_pim(), k.matmul_dims().is_some(), "{k:?}");
        }
    }

    #[test]
    fn dwconv_is_simd() {
        let k = LayerKind::DwConv { ch: 32, kernel: 3, stride: 1, pad: 1, in_hw: 8 };
        assert!(!k.is_pim());
        assert_eq!(k.macs(), 32 * 64 * 9);
    }

    #[test]
    fn synthesized_weights_distribution() {
        let w = synthesize_weights(1, 128, 64);
        assert_eq!(w.len(), 128 * 64);
        let nonzero = w.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > w.len() / 2, "too many zeros: {nonzero}");
        assert!(w.iter().any(|&v| v.abs() > 60), "no tails");
    }

    #[test]
    fn synthesized_activations_relu_like() {
        let a = synthesize_activations(7, 4096);
        assert!(a.iter().all(|&v| v >= 0));
        let zeros = a.iter().filter(|&&v| v == 0).count();
        assert!((0.4..0.6).contains(&(zeros as f64 / a.len() as f64)));
    }

    #[test]
    fn weights_deterministic_per_seed() {
        assert_eq!(synthesize_weights(5, 16, 16), synthesize_weights(5, 16, 16));
        assert_ne!(synthesize_weights(5, 16, 16), synthesize_weights(6, 16, 16));
    }
}
