//! Network descriptors: the five evaluation CNNs of the paper
//! (CIFAR-100 geometry) plus the MiniNet e2e-verification model loaded
//! from the python-exported artifact manifest.
//!
//! A [`Network`] is a flat list of [`Layer`]s. Conv/pointwise/FC layers
//! run on the PIM array; depthwise conv, pooling, ReLU, residual adds
//! and element-wise multiplies run on the SIMD core (exactly the split
//! the paper uses — Fig. 13's execution-time breakdown falls out of
//! this partition).

pub mod fixtures;
pub mod mininet;
mod zoo;

pub use mininet::{default_artifacts_dir, load_mininet, MiniNet, MiniNetLayer};
pub use zoo::{alexnet, by_name, efficientnet_b0, mobilenet_v2, resnet18, vgg19, zoo, Registry};

use crate::util::Rng;

/// One network layer (geometry only; weights are synthesized or loaded
/// separately).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

/// Layer taxonomy. Spatial sizes are single-image (batch handled by M).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Standard or pointwise convolution (PIM). `in_hw` is the input
    /// spatial size; pointwise ⇔ kernel == 1.
    Conv { in_ch: usize, out_ch: usize, kernel: usize, stride: usize, pad: usize, in_hw: usize },
    /// Depthwise convolution (SIMD core).
    DwConv { ch: usize, kernel: usize, stride: usize, pad: usize, in_hw: usize },
    /// Fully-connected layer (PIM).
    Fc { in_features: usize, out_features: usize },
    /// Max/avg pooling over `elems` input elements (SIMD core).
    Pool { elems: usize },
    /// ReLU / activation over `elems` elements (SIMD core).
    Act { elems: usize },
    /// Residual addition over `elems` elements (SIMD core).
    ResAdd { elems: usize },
    /// Element-wise multiply over `elems` elements (SIMD core; SE
    /// blocks and the paper's "Mul" category in Fig. 13).
    Mul { elems: usize },
}

impl LayerKind {
    /// Is this layer mapped onto the PIM array (std/pw-conv + FC)?
    pub fn is_pim(&self) -> bool {
        matches!(self, LayerKind::Conv { .. } | LayerKind::Fc { .. })
    }

    /// im2col problem size (M, K, N) for PIM layers; None otherwise.
    pub fn matmul_dims(&self) -> Option<(usize, usize, usize)> {
        match *self {
            LayerKind::Conv { in_ch, out_ch, kernel, stride, pad, in_hw } => {
                let out_hw = (in_hw + 2 * pad - kernel) / stride + 1;
                Some((out_hw * out_hw, in_ch * kernel * kernel, out_ch))
            }
            LayerKind::Fc { in_features, out_features } => Some((1, in_features, out_features)),
            _ => None,
        }
    }

    /// MAC count (for OPS accounting; 1 MAC = 2 OPs).
    pub fn macs(&self) -> u64 {
        match *self {
            LayerKind::Conv { .. } | LayerKind::Fc { .. } => {
                let (m, k, n) = self.matmul_dims().unwrap();
                (m * k * n) as u64
            }
            LayerKind::DwConv { ch, kernel, stride, pad, in_hw } => {
                let out_hw = (in_hw + 2 * pad - kernel) / stride + 1;
                (ch * out_hw * out_hw * kernel * kernel) as u64
            }
            LayerKind::Pool { elems }
            | LayerKind::Act { elems }
            | LayerKind::ResAdd { elems }
            | LayerKind::Mul { elems } => elems as u64,
        }
    }
}

/// A whole network.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub input_ch: usize,
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total MACs over PIM layers (std/pw conv + FC).
    pub fn pim_macs(&self) -> u64 {
        self.layers.iter().filter(|l| l.kind.is_pim()).map(|l| l.kind.macs()).sum()
    }

    /// Total MACs/element-ops over SIMD layers.
    pub fn simd_macs(&self) -> u64 {
        self.layers.iter().filter(|l| !l.kind.is_pim()).map(|l| l.kind.macs()).sum()
    }

    pub fn pim_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind.is_pim())
    }
}

/// Synthesized INT8 weights for one PIM layer, im2col layout [K, N]
/// row-major, drawn from a clipped Gaussian (trained-CNN-like; the
/// substitution for the paper's trained CIFAR-100 checkpoints — see
/// DESIGN.md §3).
pub fn synthesize_weights(layer_seed: u64, k: usize, n: usize) -> Vec<i8> {
    let mut rng = Rng::new(layer_seed);
    // Per-filter magnitude spread (log-normal): trained CNNs quantized
    // per-layer have filters of widely varying norms, which is what
    // makes FTA thresholds land on a mix of φ_th ∈ {1, 2} (the paper's
    // "filter thresholds vary between 0 and 2" for redundant models).
    let sigmas: Vec<f64> = (0..n)
        .map(|_| (20.0 * (0.9 * rng.normal()).exp()).clamp(2.5, 60.0))
        .collect();
    let mut out = vec![0i8; k * n];
    for row in 0..k {
        for (col, &sigma) in sigmas.iter().enumerate() {
            out[row * n + col] = rng.weight_int8(sigma);
        }
    }
    out
}

/// Synthesized INT8 activations with ReLU-like statistics (~half zeros,
/// small magnitudes) — used where real activations are not available.
pub fn synthesize_activations(seed: u64, len: usize) -> Vec<i8> {
    let mut rng = Rng::new(seed ^ 0xAC71_1A7E);
    (0..len)
        .map(|_| {
            if rng.f64() < 0.5 {
                0
            } else {
                // heavy-tailed small magnitudes: quantized post-ReLU
                // activations concentrate near zero (bits 4–7 rarely
                // set), which is what makes the IPU's group-wise
                // zero-column skipping pay off (Fig. 3b).
                (1.0 + rng.normal().abs() * 6.0).min(127.0) as i8
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_matmul_dims() {
        let k = LayerKind::Conv { in_ch: 64, out_ch: 128, kernel: 3, stride: 1, pad: 1, in_hw: 16 };
        assert_eq!(k.matmul_dims(), Some((256, 576, 128)));
        assert!(k.is_pim());
        assert_eq!(k.macs(), 256 * 576 * 128);
    }

    #[test]
    fn fc_dims() {
        let k = LayerKind::Fc { in_features: 512, out_features: 100 };
        assert_eq!(k.matmul_dims(), Some((1, 512, 100)));
    }

    #[test]
    fn dwconv_is_simd() {
        let k = LayerKind::DwConv { ch: 32, kernel: 3, stride: 1, pad: 1, in_hw: 8 };
        assert!(!k.is_pim());
        assert_eq!(k.macs(), 32 * 64 * 9);
    }

    #[test]
    fn synthesized_weights_distribution() {
        let w = synthesize_weights(1, 128, 64);
        assert_eq!(w.len(), 128 * 64);
        let nonzero = w.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > w.len() / 2, "too many zeros: {nonzero}");
        assert!(w.iter().any(|&v| v.abs() > 60), "no tails");
    }

    #[test]
    fn synthesized_activations_relu_like() {
        let a = synthesize_activations(7, 4096);
        assert!(a.iter().all(|&v| v >= 0));
        let zeros = a.iter().filter(|&&v| v == 0).count();
        assert!((0.4..0.6).contains(&(zeros as f64 / a.len() as f64)));
    }

    #[test]
    fn weights_deterministic_per_seed() {
        assert_eq!(synthesize_weights(5, 16, 16), synthesize_weights(5, 16, 16));
        assert_ne!(synthesize_weights(5, 16, 16), synthesize_weights(6, 16, 16));
    }
}
