//! Shared tiny-network test fixtures.
//!
//! One definition for the small synthetic networks the unit tests
//! (sim, compiler cache), the integration tests and the property tests
//! all exercise — previously each site rebuilt its own copy inline.
//! Not `#[cfg(test)]`: integration tests and benches link the crate
//! from outside, so the fixtures must be ordinary public items.

use super::{Layer, LayerKind, Network};

/// A 3-layer conv → ReLU → FC network, big enough to produce several
/// tiles per assignment and a SIMD layer, small enough for sub-second
/// debug-mode simulation. The workhorse of the engine-equivalence and
/// pooled-execution tests.
pub fn small_net() -> Network {
    Network {
        name: "small".into(),
        input_hw: 8,
        input_ch: 16,
        layers: vec![
            Layer {
                name: "c1".into(),
                kind: LayerKind::Conv {
                    in_ch: 16,
                    out_ch: 32,
                    kernel: 3,
                    stride: 1,
                    pad: 1,
                    in_hw: 8,
                },
            },
            Layer { name: "r1".into(), kind: LayerKind::Act { elems: 32 * 64 } },
            Layer {
                name: "fc".into(),
                kind: LayerKind::Fc { in_features: 2048, out_features: 16 },
            },
        ],
    }
}

/// An even smaller conv → ReLU → FC network for cache-keying tests,
/// where compile cost matters more than simulated shape.
pub fn tiny_net() -> Network {
    Network {
        name: "tiny".into(),
        input_hw: 4,
        input_ch: 8,
        layers: vec![
            Layer {
                name: "c1".into(),
                kind: LayerKind::Conv {
                    in_ch: 8,
                    out_ch: 16,
                    kernel: 3,
                    stride: 1,
                    pad: 1,
                    in_hw: 4,
                },
            },
            Layer { name: "r".into(), kind: LayerKind::Act { elems: 256 } },
            Layer { name: "fc".into(), kind: LayerKind::Fc { in_features: 256, out_features: 8 } },
        ],
    }
}

/// Synthetic stand-in with MiniNet-class geometry (two 3×3 convs on an
/// 8×8 input plus a small FC head). CI's fault-campaign smoke leg and
/// other named-model entry points use it where the python-exported
/// MiniNet artifact bundle is not available; weights are synthesized
/// like every other zoo network.
pub fn mininet_proxy() -> Network {
    Network {
        name: "mininet".into(),
        input_hw: 8,
        input_ch: 8,
        layers: vec![
            Layer {
                name: "c1".into(),
                kind: LayerKind::Conv {
                    in_ch: 8,
                    out_ch: 16,
                    kernel: 3,
                    stride: 1,
                    pad: 1,
                    in_hw: 8,
                },
            },
            Layer { name: "r1".into(), kind: LayerKind::Act { elems: 16 * 64 } },
            Layer {
                name: "c2".into(),
                kind: LayerKind::Conv {
                    in_ch: 16,
                    out_ch: 32,
                    kernel: 3,
                    stride: 1,
                    pad: 1,
                    in_hw: 8,
                },
            },
            Layer { name: "r2".into(), kind: LayerKind::Act { elems: 32 * 64 } },
            Layer {
                name: "fc".into(),
                kind: LayerKind::Fc { in_features: 32 * 64, out_features: 16 },
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_have_pim_and_simd_layers() {
        for net in [small_net(), tiny_net()] {
            assert_eq!(net.layers.len(), 3);
            assert!(net.layers[0].kind.is_pim());
            assert!(!net.layers[1].kind.is_pim());
            assert!(net.layers[2].kind.is_pim());
            assert!(net.pim_macs() > 0);
        }
    }
}
