//! The five evaluation networks of the paper, in their common CIFAR-100
//! adaptations (32×32×3 inputs, 100 classes), plus the transformer
//! workloads of DESIGN.md §14 (a BERT-base-shaped encoder, a small
//! GPT-style decoder stack and a tiny test fixture — sequence length is
//! a constructor parameter, so it can be swept as a first-class axis).
//! Geometry — not trained weights — is what the hardware experiments
//! need; weights are synthesized per layer with trained-like statistics
//! (DESIGN.md §3).

use std::collections::BTreeMap;
use std::sync::Arc;

use super::{AttnProj, Layer, LayerKind, Network};

fn conv(name: &str, in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize, hw: usize) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Conv { in_ch, out_ch, kernel: k, stride: s, pad: p, in_hw: hw },
    }
}

fn dwconv(name: &str, ch: usize, k: usize, s: usize, p: usize, hw: usize) -> Layer {
    Layer { name: name.to_string(), kind: LayerKind::DwConv { ch, kernel: k, stride: s, pad: p, in_hw: hw } }
}

fn fc(name: &str, i: usize, o: usize) -> Layer {
    Layer { name: name.to_string(), kind: LayerKind::Fc { in_features: i, out_features: o } }
}

fn act(name: &str, elems: usize) -> Layer {
    Layer { name: name.to_string(), kind: LayerKind::Act { elems } }
}

fn pool(name: &str, elems: usize) -> Layer {
    Layer { name: name.to_string(), kind: LayerKind::Pool { elems } }
}

fn resadd(name: &str, elems: usize) -> Layer {
    Layer { name: name.to_string(), kind: LayerKind::ResAdd { elems } }
}

fn mul(name: &str, elems: usize) -> Layer {
    Layer { name: name.to_string(), kind: LayerKind::Mul { elems } }
}

fn attn(
    name: String,
    heads: usize,
    d_model: usize,
    seq_len: usize,
    proj: AttnProj,
    head_sparsity_pct: Option<u8>,
) -> Layer {
    Layer {
        name,
        kind: LayerKind::Attention { heads, d_model, seq_len, proj, head_sparsity_pct },
    }
}

fn mlp(name: String, seq_len: usize, d_in: usize, d_out: usize, nm: Option<(u8, u8)>) -> Layer {
    Layer { name, kind: LayerKind::Mlp { seq_len, d_in, d_out, nm } }
}

fn layernorm(name: String, elems: usize) -> Layer {
    Layer { name, kind: LayerKind::LayerNorm { elems } }
}

fn out_hw(hw: usize, k: usize, s: usize, p: usize) -> usize {
    (hw + 2 * p - k) / s + 1
}

/// Per-head value-sparsity schedule (the per-head pruning config of
/// DESIGN.md §14): attention heads are redundant to varying degrees, so
/// later heads get pruned harder, cycling over four targets. Dense runs
/// ignore the override, so baseline references stay dense.
fn head_sparsity(h: usize) -> Option<u8> {
    Some([45u8, 55, 65, 75][h % 4])
}

/// One pre-norm transformer block: LN → per-head {Q,K,V, Q·Kᵀ,
/// softmax·V} → concat/output projection → residual → LN → FFN
/// (up, GELU, down) → residual. Every GEMM is a PIM layer; LN, GELU and
/// the residual adds run on the SIMD core. The FFN GEMMs carry a 2:4
/// N:M structured-pruning config.
fn transformer_block(
    l: &mut Vec<Layer>,
    prefix: &str,
    d_model: usize,
    heads: usize,
    seq_len: usize,
    d_ff: usize,
) {
    let tok = seq_len * d_model;
    l.push(layernorm(format!("{prefix}.ln1"), tok));
    for h in 0..heads {
        let sp = head_sparsity(h);
        for p in ["q", "k", "v"] {
            l.push(attn(format!("{prefix}.h{h}.{p}"), heads, d_model, seq_len, AttnProj::Qkv, sp));
        }
        l.push(attn(format!("{prefix}.h{h}.score"), heads, d_model, seq_len, AttnProj::Score, sp));
        l.push(attn(format!("{prefix}.h{h}.ctx"), heads, d_model, seq_len, AttnProj::Context, sp));
    }
    l.push(attn(format!("{prefix}.out"), heads, d_model, seq_len, AttnProj::Output, None));
    l.push(resadd(&format!("{prefix}.res1"), tok));
    l.push(layernorm(format!("{prefix}.ln2"), tok));
    l.push(mlp(format!("{prefix}.up"), seq_len, d_model, d_ff, Some((2, 4))));
    l.push(act(&format!("{prefix}.gelu"), seq_len * d_ff));
    l.push(mlp(format!("{prefix}.down"), seq_len, d_ff, d_model, Some((2, 4))));
    l.push(resadd(&format!("{prefix}.res2"), tok));
}

/// BERT-base-shaped encoder (12 blocks × d_model 768 × 12 heads, FFN
/// 3072) with a pooled 2-way classifier head. `seq_len` is a sweep
/// axis, so the instance name carries it (`bert_base_s128`); the
/// default registered spelling is `bert_base` at seq_len 128.
pub fn bert_base(seq_len: usize) -> Network {
    let (d_model, heads, d_ff) = (768, 12, 3072);
    let mut l = Vec::new();
    for b in 0..12 {
        transformer_block(&mut l, &format!("enc{b}"), d_model, heads, seq_len, d_ff);
    }
    l.push(fc("cls", d_model, 2));
    Network { name: format!("bert_base_s{seq_len}"), input_hw: seq_len, input_ch: d_model, layers: l }
}

/// Small GPT-style decoder stack (4 blocks × d_model 256 × 8 heads,
/// FFN 1024) with a reduced-vocabulary LM head. Causal masking does
/// not change the GEMM shapes at full sequence length, so the decoder
/// lowers exactly like the encoder; default spelling `gpt_micro` at
/// seq_len 64.
pub fn gpt_micro(seq_len: usize) -> Network {
    let (d_model, heads, d_ff) = (256, 8, 1024);
    let mut l = Vec::new();
    for b in 0..4 {
        transformer_block(&mut l, &format!("dec{b}"), d_model, heads, seq_len, d_ff);
    }
    l.push(mlp("lm_head".to_string(), seq_len, d_model, 512, None));
    Network { name: format!("gpt_micro_s{seq_len}"), input_hw: seq_len, input_ch: d_model, layers: l }
}

/// One-block toy transformer (d_model 32 × 2 heads, FFN 64) for tests
/// and CI smoke legs; default spelling `tiny_transformer` at seq_len
/// 16.
pub fn tiny_transformer(seq_len: usize) -> Network {
    let mut l = Vec::new();
    transformer_block(&mut l, "blk0", 32, 2, seq_len, 64);
    Network { name: format!("tiny_transformer_s{seq_len}"), input_hw: seq_len, input_ch: 32, layers: l }
}

/// The registered transformer workloads at their default sequence
/// lengths (the CNN zoo stays [`zoo`]-only so the paper figures are
/// untouched).
pub fn transformers() -> Vec<Network> {
    vec![bert_base(128), gpt_micro(64), tiny_transformer(16)]
}

/// Build a registered transformer at an explicit sequence length — the
/// design-space explorer's seq-len axis. `None` for CNN/fixture names
/// (their geometry has no sequence dimension).
pub fn transformer_seq(name: &str, seq_len: usize) -> Option<Network> {
    match name {
        "bert_base" | "bert-base" => Some(bert_base(seq_len)),
        "gpt_micro" | "gpt-micro" => Some(gpt_micro(seq_len)),
        "tiny_transformer" => Some(tiny_transformer(seq_len)),
        _ => None,
    }
}

/// Default sequence length of a registered transformer name; `None`
/// for non-transformer models.
pub fn default_seq_len(name: &str) -> Option<usize> {
    match name {
        "bert_base" | "bert-base" => Some(128),
        "gpt_micro" | "gpt-micro" => Some(64),
        "tiny_transformer" => Some(16),
        _ => None,
    }
}

/// AlexNet (CIFAR variant: 5 convs + 3 FCs, pools after 1/2/5).
pub fn alexnet() -> Network {
    let mut l = Vec::new();
    l.push(conv("conv1", 3, 64, 3, 1, 1, 32));
    l.push(act("relu1", 64 * 32 * 32));
    l.push(pool("pool1", 64 * 32 * 32)); // -> 16
    l.push(conv("conv2", 64, 192, 3, 1, 1, 16));
    l.push(act("relu2", 192 * 16 * 16));
    l.push(pool("pool2", 192 * 16 * 16)); // -> 8
    l.push(conv("conv3", 192, 384, 3, 1, 1, 8));
    l.push(act("relu3", 384 * 8 * 8));
    l.push(conv("conv4", 384, 256, 3, 1, 1, 8));
    l.push(act("relu4", 256 * 8 * 8));
    l.push(conv("conv5", 256, 256, 3, 1, 1, 8));
    l.push(act("relu5", 256 * 8 * 8));
    l.push(pool("pool5", 256 * 8 * 8)); // -> 4
    l.push(fc("fc6", 256 * 4 * 4, 4096));
    l.push(act("relu6", 4096));
    l.push(fc("fc7", 4096, 4096));
    l.push(act("relu7", 4096));
    l.push(fc("fc8", 4096, 100));
    Network { name: "alexnet".into(), input_hw: 32, input_ch: 3, layers: l }
}

/// VGG19 (CIFAR variant: 16 convs, pool after each block, one FC).
pub fn vgg19() -> Network {
    let cfg: &[&[usize]] = &[&[64, 64], &[128, 128], &[256, 256, 256, 256], &[512, 512, 512, 512], &[512, 512, 512, 512]];
    let mut l = Vec::new();
    let mut hw = 32;
    let mut in_ch = 3;
    let mut idx = 0;
    for (b, block) in cfg.iter().enumerate() {
        for &out_ch in *block {
            idx += 1;
            l.push(conv(&format!("conv{}_{idx}", b + 1), in_ch, out_ch, 3, 1, 1, hw));
            l.push(act(&format!("relu{idx}"), out_ch * hw * hw));
            in_ch = out_ch;
        }
        l.push(pool(&format!("pool{}", b + 1), in_ch * hw * hw));
        hw /= 2;
    }
    // hw == 1 after five pools
    l.push(fc("fc", 512, 100));
    Network { name: "vgg19".into(), input_hw: 32, input_ch: 3, layers: l }
}

/// ResNet18 (CIFAR variant: 3×3 stem, 4 stages of 2 basic blocks).
pub fn resnet18() -> Network {
    let mut l = Vec::new();
    l.push(conv("conv1", 3, 64, 3, 1, 1, 32));
    l.push(act("relu1", 64 * 32 * 32));
    let stages: &[(usize, usize)] = &[(64, 1), (128, 2), (256, 2), (512, 2)];
    let mut in_ch = 64;
    let mut hw = 32;
    for (s, &(ch, first_stride)) in stages.iter().enumerate() {
        for b in 0..2 {
            let stride = if b == 0 { first_stride } else { 1 };
            let name = |p: &str| format!("layer{}_{b}_{p}", s + 1);
            let hw_out = out_hw(hw, 3, stride, 1);
            l.push(conv(&name("conv1"), in_ch, ch, 3, stride, 1, hw));
            l.push(act(&name("relu1"), ch * hw_out * hw_out));
            l.push(conv(&name("conv2"), ch, ch, 3, 1, 1, hw_out));
            if stride != 1 || in_ch != ch {
                l.push(conv(&name("down"), in_ch, ch, 1, stride, 0, hw));
            }
            l.push(resadd(&name("add"), ch * hw_out * hw_out));
            l.push(act(&name("relu2"), ch * hw_out * hw_out));
            in_ch = ch;
            hw = hw_out;
        }
    }
    l.push(pool("avgpool", 512 * hw * hw)); // hw == 4
    l.push(fc("fc", 512, 100));
    Network { name: "resnet18".into(), input_hw: 32, input_ch: 3, layers: l }
}

/// One MobileNetV2 inverted-residual block. Returns (layers, out_hw).
fn inverted_residual(
    name: &str,
    in_ch: usize,
    out_ch: usize,
    hw: usize,
    stride: usize,
    expand: usize,
) -> (Vec<Layer>, usize) {
    let mut l = Vec::new();
    let mid = in_ch * expand;
    let hw_out = out_hw(hw, 3, stride, 1);
    if expand != 1 {
        l.push(conv(&format!("{name}_pw1"), in_ch, mid, 1, 1, 0, hw));
        l.push(act(&format!("{name}_relu1"), mid * hw * hw));
    }
    l.push(dwconv(&format!("{name}_dw"), mid, 3, stride, 1, hw));
    l.push(act(&format!("{name}_relu2"), mid * hw_out * hw_out));
    l.push(conv(&format!("{name}_pw2"), mid, out_ch, 1, 1, 0, hw_out));
    if stride == 1 && in_ch == out_ch {
        l.push(resadd(&format!("{name}_add"), out_ch * hw_out * hw_out));
    }
    (l, hw_out)
}

/// MobileNetV2 (CIFAR variant: stride-1 stem, first downsamples moved).
pub fn mobilenet_v2() -> Network {
    // (expand t, out channels c, repeats n, first stride s)
    let cfg: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 1),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut l = Vec::new();
    l.push(conv("stem", 3, 32, 3, 1, 1, 32));
    l.push(act("stem_relu", 32 * 32 * 32));
    let mut in_ch = 32;
    let mut hw = 32;
    for (bi, &(t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let (layers, hw_out) =
                inverted_residual(&format!("b{bi}_{r}"), in_ch, c, hw, stride, t);
            l.extend(layers);
            in_ch = c;
            hw = hw_out;
        }
    }
    l.push(conv("head", 320, 1280, 1, 1, 0, hw));
    l.push(act("head_relu", 1280 * hw * hw));
    l.push(pool("avgpool", 1280 * hw * hw));
    l.push(fc("fc", 1280, 100));
    Network { name: "mobilenet_v2".into(), input_hw: 32, input_ch: 3, layers: l }
}

/// One EfficientNet MBConv block with squeeze-and-excitation.
fn mbconv(
    name: &str,
    in_ch: usize,
    out_ch: usize,
    hw: usize,
    stride: usize,
    expand: usize,
    kernel: usize,
) -> (Vec<Layer>, usize) {
    let mut l = Vec::new();
    let mid = in_ch * expand;
    let pad = kernel / 2;
    let hw_out = out_hw(hw, kernel, stride, pad);
    if expand != 1 {
        l.push(conv(&format!("{name}_pw1"), in_ch, mid, 1, 1, 0, hw));
        l.push(act(&format!("{name}_swish1"), mid * hw * hw));
    }
    l.push(dwconv(&format!("{name}_dw"), mid, kernel, stride, pad, hw));
    l.push(act(&format!("{name}_swish2"), mid * hw_out * hw_out));
    // Squeeze-and-excitation: global pool + 2 tiny FCs + channel mul.
    let se = (in_ch / 4).max(8);
    l.push(pool(&format!("{name}_se_pool"), mid * hw_out * hw_out));
    l.push(fc(&format!("{name}_se_fc1"), mid, se));
    l.push(fc(&format!("{name}_se_fc2"), se, mid));
    l.push(mul(&format!("{name}_se_mul"), mid * hw_out * hw_out));
    l.push(conv(&format!("{name}_pw2"), mid, out_ch, 1, 1, 0, hw_out));
    if stride == 1 && in_ch == out_ch {
        l.push(resadd(&format!("{name}_add"), out_ch * hw_out * hw_out));
    }
    (l, hw_out)
}

/// EfficientNet-B0 (CIFAR variant: reduced downsampling).
pub fn efficientnet_b0() -> Network {
    // (expand, out_ch, repeats, first stride, kernel)
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (1, 16, 1, 1, 3),
        (6, 24, 2, 1, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    let mut l = Vec::new();
    l.push(conv("stem", 3, 32, 3, 1, 1, 32));
    l.push(act("stem_swish", 32 * 32 * 32));
    let mut in_ch = 32;
    let mut hw = 32;
    for (bi, &(t, c, n, s, k)) in cfg.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let (layers, hw_out) = mbconv(&format!("mb{bi}_{r}"), in_ch, c, hw, stride, t, k);
            l.extend(layers);
            in_ch = c;
            hw = hw_out;
        }
    }
    l.push(conv("head", 320, 1280, 1, 1, 0, hw));
    l.push(act("head_swish", 1280 * hw * hw));
    l.push(pool("avgpool", 1280 * hw * hw));
    l.push(fc("fc", 1280, 100));
    Network { name: "efficientnet_b0".into(), input_hw: 32, input_ch: 3, layers: l }
}

/// All five paper networks.
pub fn zoo() -> Vec<Network> {
    vec![alexnet(), vgg19(), resnet18(), mobilenet_v2(), efficientnet_b0()]
}

/// Lookup by name (CLI entry point). Besides the five paper networks,
/// the transformer workloads are addressable at their default sequence
/// lengths (`bert_base`, `gpt_micro`, `tiny_transformer` — see
/// [`transformer_seq`] for explicit seq-len instances) and the small
/// synthetic fixtures for CI smoke legs (`mininet`, `tiny`, `small`)
/// so fast sweeps don't need the zoo.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "alexnet" => Some(alexnet()),
        "vgg19" => Some(vgg19()),
        "resnet18" => Some(resnet18()),
        "mobilenet_v2" | "mobilenetv2" => Some(mobilenet_v2()),
        "efficientnet_b0" | "efficientnetb0" => Some(efficientnet_b0()),
        "bert_base" | "bert-base" => Some(bert_base(128)),
        "gpt_micro" | "gpt-micro" => Some(gpt_micro(64)),
        "tiny_transformer" => Some(tiny_transformer(16)),
        "mininet" => Some(super::fixtures::mininet_proxy()),
        "tiny" => Some(super::fixtures::tiny_net()),
        "small" => Some(super::fixtures::small_net()),
        _ => None,
    }
}

/// Multi-tenant model registry: each deployed model's descriptor is
/// constructed once and shared (`Arc`) by every request that names it —
/// the serving frontend's lookup table (`coordinator::serve`). Keys are
/// the names models were registered under, so a replay trace and its
/// `models` list must agree on spelling.
#[derive(Debug, Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<Network>>,
}

impl Registry {
    /// Resolve zoo names through [`by_name`]. An unknown name is an
    /// admission error, reported with the offending spelling.
    pub fn from_names<S: AsRef<str>>(names: &[S]) -> Result<Registry, String> {
        let mut models = BTreeMap::new();
        for name in names {
            let name = name.as_ref();
            let net = by_name(name)
                .ok_or_else(|| format!("unknown model {name:?} (not in the zoo)"))?;
            models.insert(name.to_string(), Arc::new(net));
        }
        Ok(Registry { models })
    }

    /// Register explicit networks under their own names (tests serve
    /// the `models::fixtures` networks this way).
    pub fn from_networks(nets: Vec<Network>) -> Registry {
        Registry { models: nets.into_iter().map(|n| (n.name.clone(), Arc::new(n))).collect() }
    }

    /// The shared descriptor registered under `name`.
    pub fn get(&self, name: &str) -> Option<Arc<Network>> {
        self.models.get(name).map(Arc::clone)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    // Tests assert on infallible fixtures; the module-wide
    // unwrap/expect lint is for production model-construction paths.
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::*;

    #[test]
    fn zoo_has_five_networks() {
        let z = zoo();
        assert_eq!(z.len(), 5);
        for n in &z {
            assert!(!n.layers.is_empty());
            assert!(n.pim_macs() > 0);
        }
    }

    #[test]
    fn vgg19_has_16_convs_and_1_fc() {
        let n = vgg19();
        let convs = n.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv { .. })).count();
        let fcs = n.layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc { .. })).count();
        assert_eq!(convs, 16);
        assert_eq!(fcs, 1);
    }

    #[test]
    fn resnet18_shapes_consistent() {
        let n = resnet18();
        // final FC must be 512 -> 100
        let fc = n.layers.iter().rev().find(|l| matches!(l.kind, LayerKind::Fc { .. })).unwrap();
        assert_eq!(fc.kind.matmul_dims(), Some((1, 512, 100)));
        // 20 convs total (16 block + stem + 3 downsamples)
        let convs = n.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv { .. })).count();
        assert_eq!(convs, 20);
    }

    #[test]
    fn compact_models_have_dwconv_and_mul() {
        let m = mobilenet_v2();
        assert!(m.layers.iter().any(|l| matches!(l.kind, LayerKind::DwConv { .. })));
        let e = efficientnet_b0();
        assert!(e.layers.iter().any(|l| matches!(l.kind, LayerKind::Mul { .. })));
        // dw-conv MACs are a visible fraction of compact models (Fig. 13)
        assert!(m.simd_macs() > 0);
    }

    #[test]
    fn vgg_dominates_mac_count() {
        // VGG19 ~ 400M MACs at CIFAR scale; MobileNetV2 much smaller.
        let v = vgg19().pim_macs();
        let m = mobilenet_v2().pim_macs();
        assert!(v > 300_000_000, "vgg19 {v}");
        assert!(m < v / 3, "mobilenet {m} vs vgg {v}");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in zoo() {
            assert_eq!(by_name(&n.name).unwrap().name, n.name);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn registry_resolves_and_shares_descriptors() {
        let reg = Registry::from_names(&["resnet18", "mobilenet_v2"]).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["mobilenet_v2", "resnet18"]);
        let a = reg.get("resnet18").unwrap();
        let b = reg.get("resnet18").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "lookups must share one descriptor");
        assert!(reg.get("alexnet").is_none(), "unregistered models are not served");
        assert!(Registry::from_names(&["resnet18", "nope"]).is_err());
    }

    #[test]
    fn registry_from_networks_uses_network_names() {
        let reg = Registry::from_networks(vec![alexnet(), vgg19()]);
        assert!(!reg.is_empty());
        assert_eq!(reg.get("alexnet").unwrap().name, "alexnet");
        assert!(reg.get("resnet18").is_none());
    }

    #[test]
    fn mobilenet_spatial_chain_valid() {
        // all conv in_hw values must be consistent: recompute by walking
        let n = mobilenet_v2();
        let mut hw = 32usize;
        for l in &n.layers {
            match l.kind {
                LayerKind::Conv { in_hw, kernel, stride, pad, .. } => {
                    assert_eq!(in_hw, hw, "layer {} expected hw {hw}", l.name);
                    hw = (hw + 2 * pad - kernel) / stride + 1;
                }
                LayerKind::DwConv { in_hw, kernel, stride, pad, .. } => {
                    assert_eq!(in_hw, hw, "layer {} expected hw {hw}", l.name);
                    hw = (hw + 2 * pad - kernel) / stride + 1;
                }
                // spatially inert kinds — listed so a new spatial kind
                // can't slip past this walk through a wildcard
                LayerKind::Fc { .. }
                | LayerKind::Pool { .. }
                | LayerKind::Act { .. }
                | LayerKind::ResAdd { .. }
                | LayerKind::Mul { .. }
                | LayerKind::Attention { .. }
                | LayerKind::Mlp { .. }
                | LayerKind::LayerNorm { .. } => {}
            }
        }
    }

    /// Count a model's PIM layers per GEMM kind.
    fn pim_kind_counts(n: &Network) -> (usize, usize, usize, usize) {
        let (mut conv, mut fc, mut attn, mut mlp) = (0, 0, 0, 0);
        for l in n.pim_layers() {
            match l.kind {
                LayerKind::Conv { .. } => conv += 1,
                LayerKind::Fc { .. } => fc += 1,
                LayerKind::Attention { .. } => attn += 1,
                LayerKind::Mlp { .. } => mlp += 1,
                LayerKind::DwConv { .. }
                | LayerKind::Pool { .. }
                | LayerKind::Act { .. }
                | LayerKind::ResAdd { .. }
                | LayerKind::Mul { .. }
                | LayerKind::LayerNorm { .. } => {
                    panic!("{}: non-PIM kind {:?} in pim_layers()", n.name, l.kind)
                }
            }
        }
        (conv, fc, attn, mlp)
    }

    #[test]
    fn every_model_has_nonzero_pim_layers_per_kind() {
        // The ISSUE-10 audit gate: no model's GEMMs may be silently
        // swallowed as non-PIM by a wildcard match. CNNs must count
        // convs, transformers must count attention + MLP GEMMs.
        for n in zoo() {
            let (conv, fc, _, _) = pim_kind_counts(&n);
            assert!(conv > 0, "{}: no conv PIM layers", n.name);
            assert!(fc > 0, "{}: no FC PIM layers", n.name);
        }
        for n in transformers() {
            let (_, _, attn, mlp) = pim_kind_counts(&n);
            assert!(attn > 0, "{}: no attention PIM layers", n.name);
            assert!(mlp > 0, "{}: no MLP PIM layers", n.name);
            assert!(n.pim_macs() > 0, "{}: zero PIM MACs", n.name);
        }
    }

    #[test]
    fn transformer_structure() {
        let t = tiny_transformer(16);
        // 1 block: ln1 + 2 heads × (q,k,v,score,ctx) + out + res1 +
        // ln2 + up + gelu + down + res2 = 17 layers, 13 of them PIM.
        assert_eq!(t.layers.len(), 17);
        assert_eq!(t.pim_layers().count(), 13);
        let b = bert_base(128);
        // 12 blocks × (12 heads × 5 + 6 GEMM/SIMD wrap layers) + cls
        assert_eq!(b.layers.len(), 12 * (12 * 5 + 8) + 1);
        let (_, fc, attn, mlp) = pim_kind_counts(&b);
        assert_eq!(attn, 12 * (12 * 5 + 1));
        assert_eq!(mlp, 24);
        assert_eq!(fc, 1);
        // per-head sparsity configs present on per-head projections,
        // absent on the concat/output projection
        assert!(t.layers.iter().any(|l| matches!(
            l.kind,
            LayerKind::Attention { head_sparsity_pct: Some(_), proj: AttnProj::Qkv, .. }
        )));
        assert!(t.layers.iter().any(|l| matches!(
            l.kind,
            LayerKind::Attention { head_sparsity_pct: None, proj: AttnProj::Output, .. }
        )));
        // N:M config on the FFN GEMMs
        assert!(t
            .layers
            .iter()
            .any(|l| matches!(l.kind, LayerKind::Mlp { nm: Some((2, 4)), .. })));
    }

    #[test]
    fn seq_len_is_a_first_class_axis() {
        let a = gpt_micro(32);
        let b = gpt_micro(64);
        assert_ne!(a.name, b.name, "instances must key caches separately");
        assert!(b.pim_macs() > a.pim_macs());
        assert_eq!(transformer_seq("gpt_micro", 32).unwrap().name, a.name);
        assert!(transformer_seq("resnet18", 32).is_none());
        assert_eq!(default_seq_len("bert_base"), Some(128));
        assert_eq!(default_seq_len("alexnet"), None);
        // by_name serves the default-seq instances
        assert_eq!(by_name("tiny_transformer").unwrap().name, "tiny_transformer_s16");
    }
}
