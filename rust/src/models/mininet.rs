//! MiniNet: the e2e verification model exported by `python/compile/aot.py`.
//!
//! The manifest + binary pack carry the exact FTA-projected INT8 weights
//! baked into the golden HLO graph, so the rust compiler/simulator can
//! run the same network and compare logits bit-for-bit against PJRT.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::json;
use crate::pruning::BlockMask;
use crate::tensor::ConvGeom;

/// One PIM layer of MiniNet with its loaded weights and metadata.
#[derive(Debug, Clone)]
pub struct MiniNetLayer {
    pub name: String,
    /// im2col weight matrix [K, N], row-major (column n = filter n).
    pub weights: Vec<i8>,
    pub k: usize,
    pub n: usize,
    /// Coarse-pruning block mask (1×α blocks along filters).
    pub mask: BlockMask,
    /// FTA thresholds per filter.
    pub thresholds: Vec<u8>,
    /// Fixed-point requantization multiplier (shift = 16).
    pub requant_mul: i32,
    /// Conv geometry; `None` for the FC layer.
    pub conv: Option<ConvInfo>,
}

/// Conv attributes from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct ConvInfo {
    pub out_ch: usize,
    pub in_ch: usize,
    pub geom: ConvGeom,
    pub pool: bool,
}

/// The full loaded model + verification fixtures.
#[derive(Debug, Clone)]
pub struct MiniNet {
    pub alpha: usize,
    pub batch: usize,
    pub input_ch: usize,
    pub input_hw: usize,
    pub num_classes: usize,
    pub layers: Vec<MiniNetLayer>,
    /// Fixed input batch (NCHW int8) used by the golden run.
    pub input: Vec<i8>,
    /// Golden logits [batch, num_classes] int32 from the jnp oracle.
    pub golden: Vec<i32>,
    /// Path to the golden HLO text (for the PJRT runtime).
    pub hlo_path: PathBuf,
    /// Path to the golden tile-matmul HLO text.
    pub tile_hlo_path: PathBuf,
}

/// Fallible manifest lookup: the manifest comes off disk, so a missing
/// key is a typed load error, never a panic.
fn req<'a>(v: &'a json::Value, key: &str) -> crate::Result<&'a json::Value> {
    v.try_req(key).map_err(anyhow::Error::msg)
}

/// Load MiniNet from an artifacts directory (`make artifacts` output).
pub fn load_mininet(artifacts_dir: &Path) -> crate::Result<MiniNet> {
    let manifest_path = artifacts_dir.join("mininet_manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
    let m = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

    let alpha = req(&m, "alpha")?.as_usize().context("alpha")?;
    let input_obj = req(&m, "input")?;
    let batch = req(input_obj, "batch")?.as_usize().context("batch")?;
    let input_ch = req(input_obj, "ch")?.as_usize().context("ch")?;
    let input_hw = req(input_obj, "hw")?.as_usize().context("hw")?;
    let num_classes = req(&m, "num_classes")?.as_usize().context("classes")?;

    let files = req(&m, "files")?;
    let read_bin = |key: &str| -> crate::Result<Vec<u8>> {
        let name = req(files, key)?.as_str().context("file name")?;
        std::fs::read(artifacts_dir.join(name)).with_context(|| format!("reading {name}"))
    };
    let weights_bin = read_bin("weights")?;
    let masks_bin = read_bin("masks")?;
    let input_bin = read_bin("input")?;
    let golden_bin = read_bin("golden")?;

    let mut layers = Vec::new();
    for layer in req(&m, "layers")?.as_arr().context("layers")? {
        let name = req(layer, "name")?.as_str().context("name")?.to_string();
        let k = req(layer, "k")?.as_usize().context("k")?;
        let n = req(layer, "n")?.as_usize().context("n")?;
        let woff = req(layer, "weight_offset")?.as_usize().context("woff")?;
        let moff = req(layer, "mask_offset")?.as_usize().context("moff")?;
        if woff + k * n > weights_bin.len() {
            bail!("weight pack too short for layer {name}");
        }
        let weights: Vec<i8> =
            weights_bin[woff..woff + k * n].iter().map(|&b| b as i8).collect();
        if alpha == 0 || n % alpha != 0 {
            bail!("layer {name}: n={n} not a multiple of alpha={alpha}");
        }
        let groups = n / alpha;
        if moff + k * groups > masks_bin.len() {
            bail!("mask pack too short for layer {name}");
        }
        let mask = BlockMask::from_bytes(k, groups, alpha, &masks_bin[moff..moff + k * groups]);
        let thresholds: Vec<u8> = req(layer, "thresholds")?
            .as_arr()
            .context("thresholds")?
            .iter()
            .map(|v| v.as_i64().unwrap_or(0) as u8)
            .collect();
        if thresholds.len() != n {
            bail!("layer {name}: {} thresholds for n={n}", thresholds.len());
        }
        let requant_mul = req(layer, "requant_mul")?.as_i64().context("mul")? as i32;
        let conv = match layer.get("conv") {
            Some(c) if *c != json::Value::Null => Some(ConvInfo {
                out_ch: req(c, "out_ch")?.as_usize().context("out_ch")?,
                in_ch: req(c, "in_ch")?.as_usize().context("in_ch")?,
                geom: ConvGeom {
                    kh: req(c, "kernel")?.as_usize().context("kernel")?,
                    kw: req(c, "kernel")?.as_usize().context("kernel")?,
                    stride: req(c, "stride")?.as_usize().context("stride")?,
                    pad: req(c, "pad")?.as_usize().context("pad")?,
                },
                pool: req(c, "pool")?.as_bool().context("pool")?,
            }),
            _ => None,
        };
        layers.push(MiniNetLayer { name, weights, k, n, mask, thresholds, requant_mul, conv });
    }

    let input: Vec<i8> = input_bin.iter().map(|&b| b as i8).collect();
    if input.len() != batch * input_ch * input_hw * input_hw {
        bail!("input pack size mismatch");
    }
    let golden: Vec<i32> = golden_bin
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    if golden.len() != batch * num_classes {
        bail!("golden pack size mismatch");
    }

    let hlo_path = artifacts_dir.join(req(files, "hlo")?.as_str().context("hlo")?);
    let tile_hlo_path =
        artifacts_dir.join(req(files, "tile_hlo")?.as_str().context("tile_hlo")?);
    Ok(MiniNet {
        alpha,
        batch,
        input_ch,
        input_hw,
        num_classes,
        layers,
        input,
        golden,
        hlo_path,
        tile_hlo_path,
    })
}

/// Default artifacts directory (repo-root/artifacts), overridable via
/// the `DBPIM_ARTIFACTS` environment variable.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DBPIM_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{csd, fta};

    fn artifacts() -> Option<MiniNet> {
        let dir = default_artifacts_dir();
        load_mininet(&dir).ok()
    }

    #[test]
    fn loads_manifest_and_shapes_line_up() {
        let Some(net) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(net.alpha, 8);
        assert_eq!(net.layers.len(), 4);
        assert_eq!(net.golden.len(), net.batch * net.num_classes);
        for l in &net.layers {
            assert_eq!(l.weights.len(), l.k * l.n);
            assert_eq!(l.thresholds.len(), l.n);
            assert_eq!(l.mask.k, l.k);
            assert_eq!(l.mask.groups * l.mask.alpha, l.n);
        }
    }

    #[test]
    fn loaded_weights_are_fta_compliant() {
        let Some(net) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for l in &net.layers {
            let expand = l.mask.expand();
            for col in 0..l.n {
                let th = l.thresholds[col];
                for row in 0..l.k {
                    let w = l.weights[row * l.n + col];
                    if !expand[row * l.n + col] {
                        assert_eq!(w, 0, "{}: pruned weight nonzero", l.name);
                    } else if th > 0 {
                        assert_eq!(csd::phi(w), th, "{}: phi mismatch at ({row},{col})", l.name);
                    } else {
                        assert_eq!(w, 0);
                    }
                }
            }
        }
    }

    #[test]
    fn loaded_weights_match_rust_fta_projection() {
        // FTA is idempotent, so re-projecting loaded weights must be a
        // no-op — this pins the python and rust implementations together.
        let Some(net) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for l in &net.layers {
            let mask = l.mask.expand();
            let (reproj, ths) = fta::fta_layer(&l.weights, l.k, l.n, Some(&mask));
            assert_eq!(reproj, l.weights, "{} not FTA-stable", l.name);
            // thresholds match wherever the filter is non-empty
            for (col, (&a, &b)) in ths.iter().zip(&l.thresholds).enumerate() {
                assert_eq!(a, b, "{} threshold mismatch at filter {col}", l.name);
            }
        }
    }
}
