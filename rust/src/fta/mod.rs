//! Fixed-Threshold Approximation (Alg. 1) — rust mirror of
//! `python/compile/fta.py`, bit-exact (same mode rule, same tie-breaks).
//!
//! FTA gives every filter a uniform non-zero CSD digit count
//! φ_th ∈ {0, 1, 2}: the mode of the filter's digit counts over weights
//! that survived coarse pruning, clamped to 2. Every kept weight is then
//! re-projected to the nearest INT8 value with exactly φ_th digits, so a
//! filter occupies exactly φ_th SRAM columns per weight row and the
//! crossbar stays regular with all Zero-pattern blocks removed.

use crate::csd;

/// Query table T(φ): all INT8 values with exactly φ non-zero CSD digits,
/// ascending. The five tables partition the 256 INT8 values
/// (|T(1)| = 15: ±2^0..2^6 plus -2^7; +128 is out of range).
pub fn query_table(phi_th: u8) -> &'static [i8] {
    assert!(phi_th <= csd::MAX_PHI, "phi {phi_th} out of range");
    &TABLES[phi_th as usize]
}

static TABLES: std::sync::LazyLock<[Vec<i8>; 5]> = std::sync::LazyLock::new(|| {
    let mut tables: [Vec<i8>; 5] = Default::default();
    for v in i8::MIN..=i8::MAX {
        tables[csd::phi(v) as usize].push(v);
    }
    for t in &mut tables {
        t.sort_unstable();
    }
    tables
});

/// Project `value` to the closest element of T(φ_th); ties resolve to
/// the larger candidate (paper's worked example projects 0 → +1).
/// O(1): precomputed 256-entry projection LUT per φ (perf §Perf: this
/// is the FTA hot spot — one lookup per weight per projection).
#[inline]
pub fn nearest_in_table(value: i8, phi_th: u8) -> i8 {
    assert!(phi_th <= csd::MAX_PHI, "phi {phi_th} out of range");
    NEAREST[phi_th as usize][(value as u8) as usize]
}

static NEAREST: std::sync::LazyLock<[[i8; 256]; 5]> = std::sync::LazyLock::new(|| {
    let mut out = [[0i8; 256]; 5];
    for phi_th in 0..=csd::MAX_PHI {
        let table = query_table(phi_th);
        for v in i8::MIN..=i8::MAX {
            out[phi_th as usize][(v as u8) as usize] = nearest_search(v, table);
        }
    }
    out
});

fn nearest_search(value: i8, table: &[i8]) -> i8 {
    let v = value as i32;
    match table.binary_search(&value) {
        Ok(_) => value,
        Err(idx) => {
            let lo = idx.saturating_sub(1).min(table.len() - 1);
            let hi = idx.min(table.len() - 1);
            let (tl, th) = (table[lo] as i32, table[hi] as i32);
            // strict '<' keeps hi on ties => prefer the larger value
            if (v - tl).abs() < (th - v).abs() {
                table[lo]
            } else {
                table[hi]
            }
        }
    }
}

/// Threshold rule: mode of kept weights' φ, with the paper's clamps.
pub fn filter_threshold(phis: &[u8], mask: &[bool]) -> u8 {
    debug_assert_eq!(phis.len(), mask.len());
    let mut counts = [0u32; csd::MAX_PHI as usize + 1];
    let mut any_nonzero_phi = false;
    let mut any_kept = false;
    for (&p, &m) in phis.iter().zip(mask) {
        any_nonzero_phi |= p != 0;
        if m {
            counts[p as usize] += 1;
            any_kept = true;
        }
    }
    if !any_kept || !any_nonzero_phi {
        return 0; // all-zero (or fully pruned) filter
    }
    // Mode; ties resolve to the smaller φ (first max), matching numpy argmax.
    let mode = counts
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i as u8)
        .unwrap();
    match mode {
        0 => 1,
        1 | 2 => mode,
        _ => 2,
    }
}

/// Apply FTA to one filter. Masked weights stay exactly zero; every kept
/// weight (including natural zeros) is projected into T(φ_th).
/// Returns (projected weights, φ_th).
pub fn fta_filter(weights: &[i8], mask: &[bool]) -> (Vec<i8>, u8) {
    let phis: Vec<u8> = weights.iter().map(|&w| csd::phi(w)).collect();
    let th = filter_threshold(&phis, mask);
    if th == 0 {
        return (vec![0; weights.len()], 0);
    }
    let out = weights
        .iter()
        .zip(mask)
        .map(|(&w, &m)| if m { nearest_in_table(w, th) } else { 0 })
        .collect();
    (out, th)
}

/// Apply FTA to a layer's [K, N] weight matrix (row-major). `mask` is a
/// per-weight keep mask of the same shape (all-true when absent).
/// Returns (projected [K, N], thresholds [N]).
pub fn fta_layer(weights: &[i8], k: usize, n: usize, mask: Option<&[bool]>) -> (Vec<i8>, Vec<u8>) {
    assert_eq!(weights.len(), k * n);
    // Transpose once so each filter is contiguous (perf §Perf: the
    // column-strided walk dominated the offline pipeline profile).
    let mut wt = vec![0i8; k * n];
    let mut mt = vec![true; k * n];
    for row in 0..k {
        let wrow = &weights[row * n..(row + 1) * n];
        for col in 0..n {
            wt[col * k + row] = wrow[col];
        }
        if let Some(m) = mask {
            let mrow = &m[row * n..(row + 1) * n];
            for col in 0..n {
                mt[col * k + row] = mrow[col];
            }
        }
    }
    let mut out = vec![0i8; k * n];
    let mut ths = vec![0u8; n];
    for col in 0..n {
        let (proj, th) = fta_filter(&wt[col * k..(col + 1) * k], &mt[col * k..(col + 1) * k]);
        ths[col] = th;
        for row in 0..k {
            out[row * n + col] = proj[row];
        }
    }
    (out, ths)
}

/// Bit-level sparsity (fraction of zero CSD digits).
pub fn bit_sparsity(weights: &[i8]) -> f64 {
    1.0 - csd::nonzero_digit_fraction(weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_cases;

    #[test]
    fn tables_partition_int8() {
        let total: usize = (0..=4).map(|p| query_table(p).len()).sum();
        assert_eq!(total, 256);
        assert_eq!(query_table(0), &[0]);
        assert_eq!(query_table(1).len(), 15);
    }

    #[test]
    fn table_one_is_signed_powers_of_two() {
        let t: Vec<i32> = query_table(1).iter().map(|&v| v as i32).collect();
        let mut expect: Vec<i32> = (0..8)
            .flat_map(|k| [1i32 << k, -(1i32 << k)])
            .filter(|v| (-128..=127).contains(v))
            .collect();
        expect.sort_unstable();
        assert_eq!(t, expect);
    }

    #[test]
    fn nearest_tie_prefers_larger() {
        assert_eq!(nearest_in_table(0, 1), 1);
    }

    #[test]
    fn nearest_is_optimal_exhaustive() {
        for th in 1..=2u8 {
            let table = query_table(th);
            for v in i8::MIN..=i8::MAX {
                let chosen = nearest_in_table(v, th);
                let best = table
                    .iter()
                    .map(|&t| (t as i32 - v as i32).abs())
                    .min()
                    .unwrap();
                assert_eq!((chosen as i32 - v as i32).abs(), best, "v={v} th={th}");
            }
        }
    }

    #[test]
    fn paper_worked_example() {
        // Sec. IV-C: f0 = {-63,0,64,0,0,-8,13}, mask = {1,0,1,1,0,1,1}.
        let f0: [i8; 7] = [-63, 0, 64, 0, 0, -8, 13];
        let mask = [true, false, true, true, false, true, true];
        let phis: Vec<u8> = f0.iter().map(|&w| csd::phi(w)).collect();
        assert_eq!(phis, vec![2, 0, 1, 0, 0, 1, 3]);
        assert_eq!(filter_threshold(&phis, &mask), 1);
        let (out, th) = fta_filter(&f0, &mask);
        assert_eq!(th, 1);
        assert_eq!(out, vec![-64, 0, 64, 1, 0, -8, 16]);
    }

    #[test]
    fn threshold_rules() {
        let ones = [true; 4];
        assert_eq!(filter_threshold(&[0, 0, 0, 0], &ones), 0);
        assert_eq!(filter_threshold(&[0, 0, 0, 1], &ones), 1);
        assert_eq!(filter_threshold(&[1, 1, 2, 3], &ones), 1);
        assert_eq!(filter_threshold(&[2, 2, 1, 3], &ones), 2);
        assert_eq!(filter_threshold(&[3, 3, 4, 1], &ones), 2);
        assert_eq!(filter_threshold(&[1, 2, 3], &[false; 3]), 0);
    }

    #[test]
    fn projection_uniform_phi_property() {
        check_cases(32, |rng| {
            let k = 8 + rng.below(64) as usize;
            let w: Vec<i8> = (0..k).map(|_| rng.int8()).collect();
            let mask: Vec<bool> = (0..k).map(|_| rng.f64() > 0.3).collect();
            let (out, th) = fta_filter(&w, &mask);
            for (i, (&o, &m)) in out.iter().zip(&mask).enumerate() {
                if !m && o != 0 {
                    return Err(format!("pruned weight {i} nonzero"));
                }
                if m && th > 0 && csd::phi(o) != th {
                    return Err(format!("weight {i}: phi {} != th {th}", csd::phi(o)));
                }
                if th == 0 && o != 0 {
                    return Err(format!("all-zero filter has nonzero at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn projection_idempotent() {
        check_cases(16, |rng| {
            let w: Vec<i8> = (0..32).map(|_| rng.int8()).collect();
            let (once, th1) = fta_filter(&w, &vec![true; 32]);
            let (twice, th2) = fta_filter(&once, &vec![true; 32]);
            if once != twice || th1 != th2 {
                return Err("not idempotent".into());
            }
            Ok(())
        });
    }

    #[test]
    fn layer_matches_per_filter() {
        let (k, n) = (16, 4);
        let mut rng = crate::util::Rng::new(9);
        let w: Vec<i8> = (0..k * n).map(|_| rng.int8()).collect();
        let (out, ths) = fta_layer(&w, k, n, None);
        for col in 0..n {
            let colw: Vec<i8> = (0..k).map(|r| w[r * n + col]).collect();
            let (proj, th) = fta_filter(&colw, &vec![true; k]);
            assert_eq!(th, ths[col]);
            for r in 0..k {
                assert_eq!(out[r * n + col], proj[r]);
            }
        }
    }

    #[test]
    fn fta_guarantees_75_percent_sparsity() {
        let mut rng = crate::util::Rng::new(3);
        let w: Vec<i8> = (0..4096).map(|_| rng.int8()).collect();
        let (out, _) = fta_layer(&w, 256, 16, None);
        assert!(bit_sparsity(&out) >= 0.75);
    }
}
