//! Bench + regeneration of **Fig. 13** — execution-time breakdown of
//! MobileNetV2 and EfficientNetB0 on DB-PIM: pw/std-conv+FC vs dw-conv
//! vs multiplications vs everything else (pool/ReLU/resadd).
//!
//! ```bash
//! cargo bench --bench fig13_optime
//! ```

use dbpim::benchlib::{bench, pct, print_table};
use dbpim::coordinator::experiments;

fn main() {
    let rows = experiments::fig13(42);
    print_table(
        "Fig. 13 — execution-time breakdown (DB-PIM, hybrid sparsity)",
        &["network", "pw/std-Conv/FC", "dw-Conv", "Mul", "Etc."],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    pct(r.pw_std_conv_fc),
                    pct(r.dw_conv),
                    pct(r.mul),
                    pct(r.etc),
                ]
            })
            .collect::<Vec<_>>(),
    );

    // paper shape: conv+FC only ~51-61% of time; dw-conv is the big
    // non-acceleratable chunk (48.3% MobileNetV2 / 35.9% EfficientNet)
    for r in &rows {
        let sum = r.pw_std_conv_fc + r.dw_conv + r.mul + r.etc;
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(r.pw_std_conv_fc < 0.75, "conv share too high: {r:?}");
        assert!(r.dw_conv > 0.2, "dw-conv share too low: {r:?}");
    }
    let eff = rows.iter().find(|r| r.network == "efficientnet_b0").unwrap();
    assert!(eff.mul > 0.005, "SE multiplies must be visible: {eff:?}");

    bench("fig13_both_networks", 0, 3, || experiments::fig13(42));
}
