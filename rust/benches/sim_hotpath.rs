//! L3 hot-path microbenchmarks (the perf-pass instrument, EXPERIMENTS.md
//! §Perf): isolates the simulator inner loops so optimization deltas are
//! measurable in isolation from experiment orchestration.
//!
//! * `row_loop_ipu_on` — the per-(m, tile) occupancy + B_eff loop on the
//!   parallel segmented engine (dominant cost with input skipping)
//! * `row_loop_ipu_on_sequential` — same work on the sequential engine
//! * `row_loop_ipu_on_legacy_interp` — same work on the legacy
//!   flat-stream interpreter (the pre-refactor baseline)
//! * `analytic` — the data-independent fast path
//! * `functional` — accumulate path (MiniNet-style verification runs)
//! * `step_major_occ_scan` — the batched step-major occupancy kernel in
//!   isolation (sim::kernels::scan_tile_occupancy)
//! * `gemm_accumulate` — the gathered-weight micro-GEMM in isolation
//! * `kernel_backend_scan` / `kernel_backend_gemm` — the same two
//!   kernels routed through the layer's *selected* `KernelBackend`
//!   (sim::backend), each printed against a `ScalarRef` oracle run on
//!   identical inputs — the selected backend must not lose to the
//!   oracle
//! * `requant_relu_arena` — requant/ReLU through the backend trait
//!   into an arena-recycled i8 buffer (asserts zero arena misses —
//!   the ISSUE 6 satellite-1 acceptance gate)
//! * `arena_reuse_row_loop` — the IPU row loop in steady state on an
//!   arena-warm thread (sequential engine; asserts zero arena misses —
//!   the allocation-free hot path)
//! * `dense_eff_prefix` — the dense-baseline analytic path, whose
//!   effective-cell accounting is an O(1) compile-time prefix
//!   subtraction per Compute chunk (previously an O(rows × filters)
//!   popcount walk)
//! * `compile`  — prune + FTA + pack + codegen for a VGG-sized layer
//! * `compile_cached_sweep` — a fig11-shaped repeated compile through
//!   the sweep-wide CompileCache (1 miss + 3 hits per layer)
//! * `sim_cached_sweep` — a fig11-shaped repeated *simulation* through
//!   the sweep-wide SimCache (1 miss + 3 hits per layer; hits skip
//!   compile + simulate entirely)
//! * `serve_throughput` — a 48-request multi-tenant replay through the
//!   batched serving frontend (dynamic batching + cross-tenant cache
//!   sharing + pooled batch fan-out; admission-order results)
//! * `serve_loop_saturation` — the open-loop continuous-batching serve
//!   loop driven far past saturation (Poisson arrivals at ~1M rps into
//!   a bounded queue): measures the admission/shed/EDF-dispatch event
//!   loop itself, and asserts load shedding stays a typed outcome
//! * `shard_sweep` — resnet18 across 1/4/16-chip fleets under tensor
//!   and pipeline parallelism (partition → per-chip fan-out →
//!   deterministic merge + interconnect); asserts the chips=1
//!   delegation stays bit-identical to the single-chip report
//! * `fault_campaign` — one mininet cell of the hardware-fault campaign
//!   (BER 1e-4, repair off vs spares): repair planning, fault-aware
//!   lowering, ABFT verification and the clean-vs-faulty functional
//!   comparison; asserts zero undetected corrupted layers
//! * `explore_sweep` — the tiny-transformer design-space exploration
//!   (seq-len × arch-variant × fleet grid through the shared sweep
//!   caches + the Pareto post-pass); asserts a non-empty frontier
//! * `pool_spawn_overhead` — scheduling cost of the persistent
//!   work-stealing pool: 256 trivial jobs through `pool::run_jobs`
//! * `pool_nested_sweep` — a miniature sweep × layer × segment nested
//!   run on the shared pool (the composition `run_parallel` forbade)
//! * `e2e`      — one full ResNet18 perf simulation (layer-parallel)
//!
//! ```bash
//! cargo bench --bench sim_hotpath            # full run
//! DBPIM_BENCH_FAST=1 cargo bench --bench sim_hotpath   # CI smoke
//! DBPIM_BENCH_JSON=. cargo bench --bench sim_hotpath   # + BENCH_sim_hotpath.json
//! ```

use dbpim::arch::ArchConfig;
use dbpim::benchlib::{bench, fast_mode, write_bench_json, Sample};
use dbpim::compiler::{compile_layer, prepare_layer, SparsityConfig};
use dbpim::models::{synthesize_activations, synthesize_weights};
use dbpim::quant;
use dbpim::sim::{Engine, Machine};
use dbpim::tensor::MatI8;

fn main() {
    let fast = fast_mode();
    let iters = |full: u32, quick: u32| if fast { quick } else { full };
    let mut samples: Vec<Sample> = Vec::new();

    let (m, k, n) = (256, 1152, 128); // VGG-like conv layer
    let w = synthesize_weights(1, k, n);
    let x = MatI8::from_vec(m, k, synthesize_activations(2, m * k));

    // --- row-loop path (IPU on): parallel vs sequential vs legacy ---
    let arch = ArchConfig::db_pim();
    let prep = prepare_layer(
        "hot", m, k, n,
        w.clone(), SparsityConfig::hybrid(0.6), &arch,
        quant::requant_mul(0.01), true, None,
    );
    let layer = compile_layer(prep, &arch);
    let machine = Machine::new(arch.clone());
    let machine_seq = Machine::with_engine(arch.clone(), Engine::Sequential);
    let s_par = bench("row_loop_ipu_on", 1, iters(10, 3), || {
        machine.run_pim_layer(&layer, Some(&x), false)
    });
    let s_seq = bench("row_loop_ipu_on_sequential", 1, iters(10, 3), || {
        machine_seq.run_pim_layer(&layer, Some(&x), false)
    });
    let s_legacy = bench("row_loop_ipu_on_legacy_interp", 1, iters(10, 3), || {
        machine.run_pim_layer_interp(&layer, Some(&x), false)
    });
    println!(
        "  parallel speedup: {:.2}x vs sequential engine, {:.2}x vs legacy interp",
        s_seq.median.as_secs_f64() / s_par.median.as_secs_f64().max(1e-12),
        s_legacy.median.as_secs_f64() / s_par.median.as_secs_f64().max(1e-12),
    );
    // report simulated-events-per-second for the perf log
    let (stats, _) = machine.run_pim_layer(&layer, Some(&x), false);
    let steps = stats.events.input_buf_reads; // one per row-step
    println!(
        "  row-steps {} -> {:.1} M row-steps/s",
        steps,
        steps as f64 / s_par.median.as_secs_f64() / 1e6
    );
    samples.push(s_par);
    samples.push(s_seq);
    samples.push(s_legacy);

    // --- analytic path (IPU off) ---
    let arch2 = ArchConfig::weights_only();
    let prep2 = prepare_layer(
        "hot2", m, k, n,
        w.clone(), SparsityConfig::hybrid(0.6), &arch2,
        quant::requant_mul(0.01), true, None,
    );
    let layer2 = compile_layer(prep2, &arch2);
    let machine2 = Machine::new(arch2);
    samples.push(bench("analytic_ipu_off", 1, iters(50, 5), || {
        machine2.run_pim_layer(&layer2, None, false)
    }));

    // --- functional path ---
    samples.push(bench("functional_accumulate", 1, iters(5, 2), || {
        machine.run_pim_layer(&layer, Some(&x), true)
    }));

    // --- batched kernels in isolation ---
    {
        use dbpim::sim::{kernels, occupancy::OccupancyTable};
        use dbpim::util::ceil_div;
        let comp = arch.compartments;
        let a0 = &layer.assignments[0];
        // perf-mode table (occ only) + the per-tile scan inputs, hoisted
        // so the bench times nothing but the kernel walk
        let table = OccupancyTable::build(0, &x, &a0.kept_rows, comp, m, true, false);
        let scans: Vec<(u32, usize, Vec<u64>)> = layer
            .tiles
            .iter()
            .filter(|t| t.assignment == 0)
            .map(|t| {
                let steps = ceil_div(t.rows(), comp);
                let demand = a0.active_cols() as u64;
                let step_eff: Vec<u64> = (0..steps)
                    .map(|s| demand * (t.rows() - s * comp).min(comp) as u64)
                    .collect();
                (t.id, t.row_start / comp, step_eff)
            })
            .collect();
        samples.push(bench("step_major_occ_scan", 2, iters(300, 20), || {
            let mut acc = 0u64;
            for (id, base_step, step_eff) in &scans {
                let scan = kernels::scan_tile_occupancy(&table, *id, *base_step, step_eff);
                acc = acc.wrapping_add(scan.eff_total);
            }
            acc
        }));

        // functional-mode table (gathered rows) + the dense micro-GEMM
        // over one assignment's weight block, all M rows
        let table_f = OccupancyTable::build(0, &x, &a0.kept_rows, comp, m, false, true);
        let nf = a0.filters.len();
        let mut out = vec![0i32; m * nf];
        samples.push(bench("gemm_accumulate", 1, iters(50, 5), || {
            out.fill(0);
            for mi in 0..m {
                kernels::gemm_accumulate(
                    &mut out[mi * nf..(mi + 1) * nf],
                    table_f.gathered_row(mi),
                    &a0.wblock,
                );
            }
            out[0]
        }));

        // --- the same kernels through the layer's selected backend,
        // raced against the ScalarRef oracle on identical inputs ---
        use dbpim::sim::backend::{self, KernelBackend};
        use dbpim::sim::kernels::TileScan;
        let sel = backend::backend_for(layer.program.kernel);
        println!("  selected kernel backend: {}", layer.program.kernel.name());
        let mut scan_buf = TileScan::empty();
        let mut lanes = Vec::new();
        let s_sel_scan = bench("kernel_backend_scan", 2, iters(300, 20), || {
            let mut acc = 0u64;
            for (id, base_step, step_eff) in &scans {
                sel.scan_tile_occupancy_into(
                    &mut scan_buf,
                    &table,
                    *id,
                    *base_step,
                    step_eff,
                    &mut lanes,
                );
                acc = acc.wrapping_add(scan_buf.eff_total);
            }
            acc
        });
        let s_ref_scan = bench("kernel_backend_scan_scalar_ref", 1, iters(50, 5), || {
            let mut acc = 0u64;
            for (id, base_step, step_eff) in &scans {
                backend::SCALAR_REF.scan_tile_occupancy_into(
                    &mut scan_buf,
                    &table,
                    *id,
                    *base_step,
                    step_eff,
                    &mut lanes,
                );
                acc = acc.wrapping_add(scan_buf.eff_total);
            }
            acc
        });
        let s_sel_gemm = bench("kernel_backend_gemm", 1, iters(50, 5), || {
            out.fill(0);
            for mi in 0..m {
                sel.gemm_accumulate(
                    &mut out[mi * nf..(mi + 1) * nf],
                    table_f.gathered_row(mi),
                    &a0.wblock,
                );
            }
            out[0]
        });
        let s_ref_gemm = bench("kernel_backend_gemm_scalar_ref", 1, iters(20, 2), || {
            out.fill(0);
            for mi in 0..m {
                backend::SCALAR_REF.gemm_accumulate(
                    &mut out[mi * nf..(mi + 1) * nf],
                    table_f.gathered_row(mi),
                    &a0.wblock,
                );
            }
            out[0]
        });
        println!(
            "  selected backend vs scalar oracle: scan {:.2}x, gemm {:.2}x",
            s_ref_scan.median.as_secs_f64() / s_sel_scan.median.as_secs_f64().max(1e-12),
            s_ref_gemm.median.as_secs_f64() / s_sel_gemm.median.as_secs_f64().max(1e-12),
        );
        samples.push(s_sel_scan);
        samples.push(s_sel_gemm);

        // --- requant/ReLU through the arena-recycled i8 path (the
        // satellite-1 allocation fix: caller-provided, recycled buffer;
        // steady state must be allocation-free) ---
        {
            use dbpim::sim::arena;
            let mul = quant::requant_mul(0.01);
            let warm = arena::take_i8(out.len());
            arena::give_i8(warm);
            arena::reset_stats();
            samples.push(bench("requant_relu_arena", 0, iters(200, 10), || {
                let mut q = arena::take_i8(out.len());
                sel.requant_relu_into(&mut q, &out, mul, true);
                let r = q[0];
                arena::give_i8(q);
                r
            }));
            let s = arena::stats();
            assert_eq!(s.misses, 0, "requant arena path still allocating: {s:?}");
            assert!(s.hits > 0, "requant arena path saw no takes");
        }
    }

    // --- steady-state row loop on an arena-warm thread ---
    // Sequential engine: every executor runs on this thread, so this
    // thread's arena sees every take/give. One warm-up run fills the
    // free lists; the measured runs must then be allocation-free
    // (zero arena misses — the ISSUE 4 acceptance gate, also pinned
    // by sim::arena's unit test and the recycling property test).
    {
        use dbpim::sim::arena;
        machine_seq.run_pim_layer(&layer, Some(&x), false);
        arena::reset_stats();
        samples.push(bench("arena_reuse_row_loop", 0, iters(10, 3), || {
            machine_seq.run_pim_layer(&layer, Some(&x), false)
        }));
        let s = arena::stats();
        assert_eq!(s.misses, 0, "steady-state row loop still allocating: {s:?}");
        assert!(s.hits > 0, "arena saw no takes");
    }

    // --- dense analytic path: O(1) prefix-sum effective cells ---
    let arch_d = ArchConfig::dense_baseline();
    let prep_d = prepare_layer(
        "hotd", m, k, n,
        w.clone(), SparsityConfig::dense(), &arch_d,
        quant::requant_mul(0.01), true, None,
    );
    let layer_d = compile_layer(prep_d, &arch_d);
    let machine_d = Machine::new(arch_d);
    samples.push(bench("dense_eff_prefix", 1, iters(50, 5), || {
        machine_d.run_pim_layer(&layer_d, None, false)
    }));

    // --- compiler ---
    let arch3 = ArchConfig::db_pim();
    samples.push(bench("compile_layer_vgg_sized", 1, iters(10, 2), || {
        let prep = prepare_layer(
            "c", m, k, n,
            w.clone(), SparsityConfig::hybrid(0.6), &arch3,
            quant::requant_mul(0.01), true, None,
        );
        compile_layer(prep, &arch3)
    }));

    // --- sweep-wide compile cache: fig11-shaped repetition (the dense
    // baseline recurs at every sweep point → 1 miss + 3 hits/layer) ---
    samples.push(bench("compile_cached_sweep", 0, iters(5, 2), || {
        let cache = dbpim::compiler::CompileCache::new();
        let net = dbpim::models::resnet18();
        let arch = ArchConfig::dense_baseline();
        for _ in 0..4 {
            for idx in 0..net.layers.len() {
                let _ = cache.get_or_compile(&net, idx, SparsityConfig::dense(), &arch, 42);
            }
        }
        let stats = cache.stats();
        assert!(stats.hits == 3 * stats.misses, "unexpected hit pattern: {stats:?}");
        stats.hits
    }));

    // --- sweep-wide sim cache: fig11-shaped repeated cells (the dense
    // baseline recurs at every sweep point → 1 miss + 3 hits/layer,
    // and every hit skips compile + activation synthesis + simulate) ---
    samples.push(bench("sim_cached_sweep", 0, iters(5, 2), || {
        let compile_cache = dbpim::compiler::CompileCache::new();
        let sim_cache = dbpim::sim::SimCache::new();
        let net = dbpim::models::fixtures::small_net();
        let arch = ArchConfig::dense_baseline();
        let mut acc = 0u64;
        for _ in 0..4 {
            let r = dbpim::sim::simulate_network_memo(
                &net,
                SparsityConfig::dense(),
                &arch,
                42,
                Engine::Parallel,
                &compile_cache,
                &sim_cache,
            );
            acc = acc.wrapping_add(r.total_cycles());
        }
        let stats = sim_cache.stats();
        assert!(stats.hits == 3 * stats.misses, "unexpected sim hit pattern: {stats:?}");
        // hits skipped compilation entirely (one compile lookup per
        // sim computation — misses plus racing duplicates)
        assert!(compile_cache.stats().lookups() == stats.misses + stats.dup_computes);
        acc
    }));

    // --- batched multi-tenant serving frontend: trace replay ---
    // 48 requests over two tenants' models at mixed arch/sparsity
    // points with repeats by construction, so the dynamic batcher
    // groups compatible requests and the shared SimCache converts the
    // repeats into hits; results return in admission order.
    {
        use dbpim::coordinator::serve::{ServeCtx, ServeRequest, ServeSpec};
        use dbpim::models::Registry;
        let traffic: Vec<ServeRequest> = (0..48)
            .map(|i| ServeRequest {
                model: (if i % 3 == 0 { "tiny" } else { "small" }).into(),
                arch: "db-pim".into(),
                sparsity: SparsityConfig::hybrid(0.2 * (i % 4) as f64),
                seed: (i % 4) as u64,
            })
            .collect();
        let spec = ServeSpec { models: vec!["small".into(), "tiny".into()], traffic };
        samples.push(bench("serve_throughput", 0, iters(5, 2), || {
            // fresh context per replay: the measured work is one cold
            // replay (intra-replay sharing included), not cache decay
            let ctx = ServeCtx::new(Registry::from_networks(vec![
                dbpim::models::fixtures::small_net(),
                dbpim::models::fixtures::tiny_net(),
            ]));
            let (results, stats) = spec.run_with(&ctx, 8).unwrap();
            assert_eq!(results.len(), 48);
            assert!(stats.batches < 48, "replay must actually batch");
            assert!(
                stats.cache.sim.hits > 0,
                "replay must share sim-cache entries across requests"
            );
            results.len()
        }));
    }

    // --- open-loop serve loop at saturation ---
    // 96 Poisson arrivals at ~1M rps into a 16-deep queue on 2×4-lane
    // chips with a tight deadline: far past capacity, so the measured
    // work is the admission / shed / EDF-dispatch / continuous-batching
    // event loop under stress. Shedding must stay a typed outcome (the
    // loop never panics under overload), and the books must balance.
    {
        use dbpim::coordinator::arrivals::ArrivalProcess;
        use dbpim::coordinator::faults::FaultSpec;
        use dbpim::coordinator::serve::{ServeCtx, ServeRequest};
        use dbpim::coordinator::serve_loop::OpenLoopSpec;
        use dbpim::models::Registry;
        let spec = OpenLoopSpec {
            models: vec!["small".into(), "tiny".into()],
            workload: vec![
                ServeRequest {
                    model: "small".into(),
                    arch: "db-pim".into(),
                    sparsity: SparsityConfig::hybrid(0.6),
                    seed: 1,
                },
                ServeRequest {
                    model: "tiny".into(),
                    arch: "db-pim".into(),
                    sparsity: SparsityConfig::hybrid(0.4),
                    seed: 2,
                },
            ],
            arrivals: ArrivalProcess::Poisson { rate_rps: 1.0e6 },
            requests: 96,
            queue_cap: 16,
            deadline_ms: 0.2,
            timeout_ms: 50.0,
            max_batch: 4,
            chips: 2,
            scheme: None,
            max_retries: 1,
            backoff_ms: 0.05,
            seed: 42,
            faults: FaultSpec::off(),
            trace_events: false,
        };
        samples.push(bench("serve_loop_saturation", 0, iters(5, 2), || {
            // fresh context per run: one cold open-loop episode, not
            // cache decay across iterations
            let ctx = ServeCtx::new(Registry::from_networks(vec![
                dbpim::models::fixtures::small_net(),
                dbpim::models::fixtures::tiny_net(),
            ]));
            let (outcomes, stats) = spec.run_with(&ctx).unwrap();
            assert_eq!(outcomes.len(), 96);
            assert!(stats.shed > 0, "saturation run must shed load");
            assert!(stats.done > 0, "saturation run must still serve");
            assert_eq!(
                stats.done + stats.shed + stats.failed + stats.timed_out,
                96,
                "outcome conservation"
            );
            stats.done
        }));
    }

    // --- the worker pool itself ---
    {
        use dbpim::coordinator::pool;
        // per-spawn overhead: trivial jobs, so the measured time is
        // queue/steal/wake bookkeeping rather than payload
        samples.push(bench("pool_spawn_overhead", 1, iters(50, 5), || {
            let jobs: Vec<_> = (0..256usize).map(|i| move || i.wrapping_mul(i)).collect();
            pool::run_jobs(jobs).iter().sum::<usize>()
        }));
        // nested composition: 4 sweep cells fan out, each cell fans its
        // layers out, each layer its core segments — all one pool
        samples.push(bench("pool_nested_sweep", 0, iters(5, 2), || {
            let net = dbpim::models::fixtures::small_net();
            let cells: Vec<_> = (0..4u64)
                .map(|i| {
                    let net = net.clone();
                    move || {
                        dbpim::sim::simulate_network(
                            &net,
                            SparsityConfig::hybrid(0.2 * i as f64),
                            &ArchConfig::db_pim(),
                            i,
                        )
                        .total_cycles()
                    }
                })
                .collect();
            pool::run_jobs(cells).iter().sum::<u64>()
        }));
    }

    // --- sharded multi-chip fleet: resnet18 on 1/4/16 chips, TP vs PP ---
    // The measured work is the full shard pipeline: capacity-aware
    // partition → per-chip subset compile + simulate fan-out over the
    // shared pool → order-fixed merge with the interconnect charge.
    // chips=1 must stay bit-identical to the plain single-chip report
    // (the DESIGN.md §12 delegation contract); the Arc<ArchConfig>
    // threading (ISSUE 8 satellite 1) keeps the per-chip fan-out free
    // of deep config clones.
    {
        use dbpim::coordinator::sharding::{self, ShardSpec};
        let net = dbpim::models::resnet18();
        let sp = SparsityConfig::hybrid(0.6);
        let arch_s = ArchConfig::db_pim();
        samples.push(bench("shard_sweep", 0, iters(3, 1), || {
            let cc = dbpim::compiler::CompileCache::new();
            let sc = dbpim::sim::SimCache::new();
            let base = dbpim::sim::simulate_network_memo(
                &net,
                sp,
                &arch_s,
                42,
                Engine::Parallel,
                &cc,
                &sc,
            )
            .total_cycles();
            let mut acc = 0u64;
            for scheme in ["tp", "pp"] {
                for chips in [1usize, 4, 16] {
                    let spec = ShardSpec::parse(chips, scheme).unwrap();
                    let r = sharding::simulate_sharded(
                        &net,
                        sp,
                        &arch_s,
                        42,
                        spec,
                        Engine::Parallel,
                        &cc,
                        &sc,
                    );
                    if chips == 1 {
                        assert_eq!(
                            r.fleet_cycles(),
                            base,
                            "chips=1 {scheme} must be bit-identical to single-chip"
                        );
                    }
                    acc = acc.wrapping_add(r.fleet_cycles());
                }
            }
            acc
        }));
    }

    // --- hardware-fault campaign: repair + ABFT detection pipeline ---
    // One mininet cell at BER 1e-4 under both repair strategies. The
    // measured work is the full campaign unit: repair planning,
    // fault-aware lowering, perf overhead sims and the per-layer
    // clean-vs-faulty functional comparison. ABFT must leave no
    // corrupted layer undetected (the ISSUE 9 acceptance gate).
    {
        use dbpim::coordinator::experiments as exp;
        let nets = vec!["mininet".to_string()];
        samples.push(bench("fault_campaign", 0, iters(5, 2), || {
            let (rows, _) =
                exp::fault_campaign_with_stats(&nets, &[1e-4], &["none", "spares"], 42, 42);
            assert_eq!(rows.len(), 2);
            assert!(
                rows.iter().all(|r| r.undetected_layers == 0),
                "campaign left corrupted layers undetected"
            );
            rows.iter().map(|r| r.detections).sum::<u64>()
        }));
    }

    // --- design-space explorer: transformer grid + Pareto post-pass ---
    // The full `dbpim explore` unit on the cheapest fixture: 2 seq-len
    // instances × 5 arch variants × 2 fleet points, each cell a fleet
    // simulation through the shared caches, then the per-model
    // frontier marking. The frontier must be non-empty (the ISSUE 10
    // acceptance gate).
    {
        use dbpim::coordinator::experiments as exp;
        let nets = vec!["tiny_transformer".to_string()];
        samples.push(bench("explore_sweep", 0, iters(5, 2), || {
            let (rows, _) = exp::explore_with_stats(&nets, 42);
            assert_eq!(rows.len(), 20);
            assert!(rows.iter().any(|r| r.on_frontier), "empty Pareto frontier");
            rows.iter().filter(|r| r.on_frontier).count()
        }));
    }

    // --- end-to-end perf sim (layer-parallel by default) ---
    samples.push(bench("e2e_resnet18_hybrid", 0, iters(3, 1), || {
        let net = dbpim::models::resnet18();
        dbpim::sim::simulate_network(&net, SparsityConfig::hybrid(0.6), &ArchConfig::db_pim(), 42)
    }));
    if !fast {
        samples.push(bench("e2e_resnet18_hybrid_sequential", 0, iters(3, 1), || {
            let net = dbpim::models::resnet18();
            dbpim::sim::simulate_network_with_engine(
                &net,
                SparsityConfig::hybrid(0.6),
                &ArchConfig::db_pim(),
                42,
                Engine::Sequential,
            )
        }));
    }

    write_bench_json("sim_hotpath", &samples);
}
