//! L3 hot-path microbenchmarks (the perf-pass instrument, EXPERIMENTS.md
//! §Perf): isolates the simulator inner loops so optimization deltas are
//! measurable in isolation from experiment orchestration.
//!
//! * `row_loop` — the per-(m, tile) IPU gather + B_eff loop (dominant
//!   cost with input skipping enabled)
//! * `analytic` — the data-independent fast path
//! * `functional` — accumulate path (MiniNet-style verification runs)
//! * `compile`  — prune + FTA + pack + codegen for a VGG-sized layer
//! * `e2e`      — one full ResNet18 perf simulation
//!
//! ```bash
//! cargo bench --bench sim_hotpath
//! ```

use dbpim::arch::ArchConfig;
use dbpim::benchlib::bench;
use dbpim::compiler::{compile_layer, prepare_layer, SparsityConfig};
use dbpim::models::{synthesize_activations, synthesize_weights};
use dbpim::quant;
use dbpim::sim::Machine;
use dbpim::tensor::MatI8;

fn main() {
    let (m, k, n) = (256, 1152, 128); // VGG-like conv layer
    let w = synthesize_weights(1, k, n);
    let x = MatI8::from_vec(m, k, synthesize_activations(2, m * k));

    // --- row-loop path (IPU on) ---
    let arch = ArchConfig::db_pim();
    let prep = prepare_layer(
        "hot", m, k, n,
        w.clone(), SparsityConfig::hybrid(0.6), &arch,
        quant::requant_mul(0.01), true, None,
    );
    let layer = compile_layer(prep, &arch);
    let machine = Machine::new(arch.clone());
    let s = bench("row_loop_ipu_on", 1, 10, || {
        machine.run_pim_layer(&layer, Some(&x), false)
    });
    // report simulated-events-per-second for the perf log
    let (stats, _) = machine.run_pim_layer(&layer, Some(&x), false);
    let steps = stats.events.input_buf_reads; // one per row-step
    println!(
        "  row-steps {} -> {:.1} M row-steps/s",
        steps,
        steps as f64 / s.median.as_secs_f64() / 1e6
    );

    // --- analytic path (IPU off) ---
    let arch2 = ArchConfig::weights_only();
    let prep2 = prepare_layer(
        "hot2", m, k, n,
        w.clone(), SparsityConfig::hybrid(0.6), &arch2,
        quant::requant_mul(0.01), true, None,
    );
    let layer2 = compile_layer(prep2, &arch2);
    let machine2 = Machine::new(arch2);
    bench("analytic_ipu_off", 1, 50, || machine2.run_pim_layer(&layer2, None, false));

    // --- functional path ---
    bench("functional_accumulate", 1, 5, || machine.run_pim_layer(&layer, Some(&x), true));

    // --- compiler ---
    let arch3 = ArchConfig::db_pim();
    bench("compile_layer_vgg_sized", 1, 10, || {
        let prep = prepare_layer(
            "c", m, k, n,
            w.clone(), SparsityConfig::hybrid(0.6), &arch3,
            quant::requant_mul(0.01), true, None,
        );
        compile_layer(prep, &arch3)
    });

    // --- end-to-end perf sim ---
    bench("e2e_resnet18_hybrid", 0, 3, || {
        let net = dbpim::models::resnet18();
        dbpim::sim::simulate_network(&net, SparsityConfig::hybrid(0.6), &ArchConfig::db_pim(), 42)
    });
}
