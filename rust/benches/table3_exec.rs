//! Bench + regeneration of **Table III** — on-chip execution time
//! (std/pw-conv + FC layers only) of the DAC'24 predecessor
//! configuration vs this work's bit-level and hybrid modes, across the
//! five networks, plus the headline "up to N× vs DAC'24" number.
//!
//! ```bash
//! cargo bench --bench table3_exec
//! ```

use dbpim::benchlib::{bench, f2, print_table};
use dbpim::coordinator::experiments;

fn main() {
    let rows = experiments::table3(42);
    print_table(
        "Table III — on-chip execution time (ms, conv+FC only)",
        &["network", "DAC'24 [16]", "bit-level", "hybrid", "hybrid vs DAC'24"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    f2(r.dac24_ms),
                    f2(r.bit_level_ms),
                    f2(r.hybrid_ms),
                    format!("{}x", f2(r.dac24_ms / r.hybrid_ms)),
                ]
            })
            .collect::<Vec<_>>(),
    );

    let max_speedup = rows
        .iter()
        .map(|r| r.dac24_ms / r.hybrid_ms)
        .fold(0.0f64, f64::max);
    println!("max speedup vs DAC'24: {max_speedup:.2}x (paper: up to 11.10x)");

    // paper shape: hybrid < bit-level < DAC'24 for every network, and a
    // several-fold best case
    for r in &rows {
        assert!(r.hybrid_ms < r.bit_level_ms, "{r:?}");
        assert!(r.bit_level_ms < r.dac24_ms, "{r:?}");
    }
    assert!(max_speedup > 3.0, "max speedup {max_speedup}");

    bench("table3_one_network_alexnet", 0, 3, || {
        let net = dbpim::models::alexnet();
        dbpim::sim::simulate_network(
            &net,
            dbpim::compiler::SparsityConfig::hybrid(0.6),
            &dbpim::arch::ArchConfig::db_pim(),
            42,
        )
    });
}
