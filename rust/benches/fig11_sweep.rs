//! Bench + regeneration of **Fig. 11** — speedup and normalized energy
//! over the dense PIM baseline on VGG19 / ResNet18 / MobileNetV2 at
//! 75–90% weight sparsity (hybrid pruning, input-column skipping OFF,
//! std/pw-conv + FC layers only — the paper's stated scope).
//!
//! ```bash
//! cargo bench --bench fig11_sweep
//! ```

use dbpim::benchlib::{bench, f2, pct, print_table};
use dbpim::coordinator::experiments;

fn main() {
    let (rows, stats) = experiments::fig11_with_stats(42);
    print_table(
        "Fig. 11 — speedup & energy vs dense digital PIM baseline",
        &["network", "weight sparsity", "speedup", "energy saving"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    pct(r.total_sparsity),
                    format!("{}x", f2(r.speedup)),
                    pct(r.energy_saving),
                ]
            })
            .collect::<Vec<_>>(),
    );

    // paper-shape assertions: monotone, VGG ≥ ResNet ≥ MobileNet at 90%,
    // several-fold speedup band, energy saving in the 70–95% band
    let get = |n: &str, t: f64| {
        rows.iter().find(|r| r.network == n && (r.total_sparsity - t).abs() < 1e-9).unwrap()
    };
    assert!(get("vgg19", 0.90).speedup > get("resnet18", 0.90).speedup);
    assert!(get("resnet18", 0.90).speedup > get("mobilenet_v2", 0.90).speedup);
    assert!(get("vgg19", 0.90).speedup > 6.0);
    for r in &rows {
        assert!(r.energy_saving > 0.6 && r.energy_saving < 0.95, "{r:?}");
    }

    // the dense baseline is shared by all four sparsity points of each
    // network — the sweep-wide sim cache must convert those repeats
    // into hits (3 of its 4 simulations per network-layer), and a sim
    // hit skips compilation entirely, so the compile cache sees
    // exactly the sim misses
    println!("compile cache: {}", stats.compile.summary());
    println!("sim cache: {}", stats.sim.summary());
    assert!(stats.sim.hits > 0, "fig11 sweep produced no sim-cache hits");
    assert_eq!(
        stats.compile.lookups(),
        stats.sim.misses + stats.sim.dup_computes,
        "sim-cache hits must skip compilation entirely"
    );

    bench("fig11_one_point_vgg19_90", 0, 3, || {
        let net = dbpim::models::vgg19();
        dbpim::sim::simulate_network(
            &net,
            dbpim::compiler::SparsityConfig::hybrid(0.6),
            &dbpim::arch::ArchConfig::weights_only(),
            42,
        )
    });
}
