//! Ablation bench for the compiler design choices DESIGN.md calls out:
//!
//! * **group merging** — packing column-compatible filter groups into
//!   one macro (how the architecture reaches 16 filters/macro at φ=1)
//!   vs strictly one α-group per macro;
//! * **core scheduling** — greedy LPT balancing vs naive round-robin
//!   (the paper's plain N-K-M order).
//!
//! ```bash
//! cargo bench --bench ablation
//! ```

use dbpim::arch::{ArchConfig, SchedulePolicy};
use dbpim::benchlib::{f2, print_table};
use dbpim::compiler::SparsityConfig;
use dbpim::models;
use dbpim::sim;

fn run(net: &models::Network, arch: &ArchConfig) -> (u64, f64) {
    let r = sim::simulate_network(net, SparsityConfig::hybrid(0.6), arch, 42);
    (r.pim_cycles(), r.u_act())
}

fn main() {
    let nets = ["vgg19", "resnet18", "mobilenet_v2"];
    let mut rows = Vec::new();
    for name in nets {
        let net = models::by_name(name).unwrap();
        let full = ArchConfig::db_pim();
        let no_merge = ArchConfig { merge_groups: false, ..ArchConfig::db_pim() };
        let rr = ArchConfig { schedule: SchedulePolicy::RoundRobin, ..ArchConfig::db_pim() };

        let (c_full, u_full) = run(&net, &full);
        let (c_nm, u_nm) = run(&net, &no_merge);
        let (c_rr, _) = run(&net, &rr);

        rows.push(vec![
            name.to_string(),
            format!("{c_full}"),
            format!("{} ({}x)", c_nm, f2(c_nm as f64 / c_full as f64)),
            format!("{} ({}x)", c_rr, f2(c_rr as f64 / c_full as f64)),
            format!("{} -> {}", f2(100.0 * u_nm), f2(100.0 * u_full)),
        ]);

        // Neither heuristic is globally optimal (merging coarsens the
        // load-balancing granularity; LPT is a 4/3-approximation), so
        // allow small inversions but catch real regressions.
        assert!(c_nm as f64 >= 0.92 * c_full as f64, "{name}: merging regressed badly");
        assert!(c_rr as f64 >= 0.92 * c_full as f64, "{name}: LPT lost badly to round-robin");
    }
    print_table(
        "Ablation — PIM cycles under compiler variants (hybrid 60%)",
        &["network", "full", "no group merge", "round-robin sched", "U_act% nm->full"],
        &rows,
    );
}
