//! Bench + regeneration of **Fig. 3** — sparsity analysis.
//!
//! (a) zero-bit proportion in weights: original / 60% value-pruned /
//!     hybrid, per network;
//! (b) all-zero input bit-column proportion for groups N = 1, 8, 16.
//!
//! ```bash
//! cargo bench --bench fig03_sparsity
//! ```

use dbpim::benchlib::{bench, pct, print_table};
use dbpim::coordinator::experiments;

fn main() {
    let (bits, cols) = experiments::fig3(42);

    print_table(
        "Fig. 3(a) — proportion of zero bits in weights (CSD encoding)",
        &["network", "Ori.", "Val. (60%)", "Our (hybrid)"],
        &bits
            .iter()
            .map(|r| vec![r.network.clone(), pct(r.original), pct(r.value_pruned), pct(r.hybrid)])
            .collect::<Vec<_>>(),
    );
    print_table(
        "Fig. 3(b) — all-zero bit columns in input groups",
        &["network", "N=1", "N=8", "N=16"],
        &cols
            .iter()
            .map(|r| vec![r.network.clone(), pct(r.group1), pct(r.group8), pct(r.group16)])
            .collect::<Vec<_>>(),
    );

    // paper-shape assertions
    for r in &bits {
        assert!(r.original < r.value_pruned && r.value_pruned < r.hybrid, "{r:?}");
        assert!(r.value_pruned > 0.75, "Val. should exceed 80%-ish: {r:?}");
    }
    for r in &cols {
        assert!(r.group1 >= r.group8 && r.group8 >= r.group16, "{r:?}");
    }

    // timing: the analysis pass itself
    bench("fig3_full_analysis", 0, 3, || experiments::fig3(42));
}
