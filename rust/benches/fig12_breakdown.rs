//! Bench + regeneration of **Fig. 12** — end-to-end speedup (a) and
//! normalized energy (b) for bit-level / value-level / hybrid sparsity
//! across all five networks, relative to the dense PIM baseline.
//!
//! ```bash
//! cargo bench --bench fig12_breakdown
//! ```

use dbpim::benchlib::{bench, f2, print_table};
use dbpim::coordinator::experiments;

fn main() {
    let rows = experiments::fig12(42);
    print_table(
        "Fig. 12(a/b) — end-to-end speedup and normalized energy",
        &["network", "approach", "speedup", "normalized energy"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    r.approach.to_string(),
                    format!("{}x", f2(r.speedup)),
                    f2(r.energy_norm),
                ]
            })
            .collect::<Vec<_>>(),
    );

    // paper shape: hybrid dominates both single-axis approaches; compact
    // models gain less than the big CNNs
    for net in ["alexnet", "vgg19", "resnet18", "mobilenet_v2", "efficientnet_b0"] {
        let get = |ap: &str| rows.iter().find(|r| r.network == net && r.approach == ap).unwrap();
        assert!(get("hybrid").speedup >= get("bit").speedup, "{net}");
        assert!(get("hybrid").speedup >= get("value").speedup, "{net}");
        assert!(get("hybrid").energy_norm < 1.0, "{net}");
    }
    let hy = |n: &str| rows.iter().find(|r| r.network == n && r.approach == "hybrid").unwrap();
    assert!(hy("mobilenet_v2").speedup < hy("vgg19").speedup);

    bench("fig12_one_network_resnet18", 0, 3, || {
        let net = dbpim::models::resnet18();
        dbpim::sim::simulate_network(
            &net,
            dbpim::compiler::SparsityConfig::hybrid(0.6),
            &dbpim::arch::ArchConfig::db_pim(),
            42,
        )
    });
}
