//! Bench + regeneration of **Table II** — comparison with prior works:
//! measured actual utilization U_act per network, peak throughput, and
//! peak throughput per macro, alongside the prior-work numbers the
//! paper tabulates (quoted from Table II for context).
//!
//! ```bash
//! cargo bench --bench table2_throughput
//! ```

use dbpim::benchlib::{bench, f2, pct, print_table};
use dbpim::coordinator::experiments;

/// Prior-work rows quoted from the paper's Table II (for the printed
/// comparison only; our measured row is computed).
const PRIOR: &[(&str, &str, &str, f64)] = &[
    // (work, type, utilization bound, peak GOPS/macro)
    ("ISSCC'20 [21]", "analog", "<32.04%", 62.5),
    ("ISSCC'21 [22]", "analog", "32.04%", 24.69),
    ("Z-PIM [36]", "digital", "16%", 7.95),
    ("SDP [23]", "digital", "48.64%", 51.19),
    ("TT@CIM [26]", "analog", "<50%", 25.1),
];

fn main() {
    let t = experiments::table2(42);

    println!("\nDB-PIM (this work): {} macros, {} KB PIM capacity", t.total_macros, t.pim_kb);
    println!(
        "peak throughput: {:.2} TOPS (8b/8b) | per macro: {:.1} GOPS (φ=1) / {:.1} GOPS (φ=2) / {:.1} GOPS (dense INT8 mapping)",
        t.peak_tops_phi1, t.peak_gops_per_macro_phi1, t.peak_gops_per_macro_phi2, t.dense_gops_per_macro
    );

    let mut rows: Vec<Vec<String>> = PRIOR
        .iter()
        .map(|(w, ty, u, g)| vec![w.to_string(), ty.to_string(), u.to_string(), f2(*g)])
        .collect();
    for (net, u) in &t.u_act {
        rows.push(vec![
            format!("this work ({net})"),
            "digital".into(),
            pct(*u),
            f2(t.peak_gops_per_macro_phi2),
        ]);
    }
    print_table(
        "Table II — utilization & peak throughput per macro",
        &["work", "type", "U_act", "GOPS/macro"],
        &rows,
    );

    // paper shape: our U_act beats every prior bound (~78–87% measured)
    for (net, u) in &t.u_act {
        assert!(*u > 0.55, "{net} utilization {u} below prior work band");
    }
    // φ=1 peak = 8x dense mapping, φ=2 = 4x (paper: 16/8 filters vs 2)
    assert!((t.peak_gops_per_macro_phi1 / t.dense_gops_per_macro - 8.0).abs() < 1e-6);
    assert!((t.peak_gops_per_macro_phi2 / t.dense_gops_per_macro - 4.0).abs() < 1e-6);

    bench("table2_utilization_measurement", 0, 1, || experiments::table2(42));
}
