//! Golden-row snapshot regression suite: every sweep driver's rows,
//! serialized to committed JSON goldens in `tests/goldens/` and compared
//! **bit-exactly** on every test run.
//!
//! Bit-exactness: both the fresh rows and the committed file go through
//! the same canonical writer (`json::to_string`, shortest-roundtrip
//! float formatting), so string equality is f64-bit equality. Any change
//! to the compiler, simulator, energy table, weight synthesis or driver
//! axes that moves a single output bit fails here with a pointer to the
//! first divergence.
//!
//! Regeneration (deliberate changes):
//!
//! ```bash
//! DBPIM_UPDATE_GOLDENS=1 cargo test -q --test integration_goldens
//! ```
//!
//! Bootstrap: when a golden file is missing (fresh checkout before the
//! goldens were ever committed), the test writes it and passes with a
//! notice — commit the generated `rust/tests/goldens/*.json` (CI uploads
//! them as the `goldens` artifact). See EXPERIMENTS.md §Goldens.

use dbpim::coordinator::experiments as exp;
use dbpim::json;
use std::path::PathBuf;

/// The seed every CLI driver uses (`dbpim fig11` etc.), so goldens match
/// the `artifacts/<exp>.json` reports bit for bit.
const SEED: u64 = 42;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens").join(format!("{name}.json"))
}

/// Compare `fresh` against the committed golden (canonical-string,
/// bit-exact); regenerate under `DBPIM_UPDATE_GOLDENS=1`; bootstrap the
/// file when missing.
fn check_golden(name: &str, fresh: &json::Value) {
    let path = golden_path(name);
    let fresh_text = json::to_string(fresh);
    if std::env::var("DBPIM_UPDATE_GOLDENS").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &fresh_text).unwrap();
        println!("updated golden {}", path.display());
        return;
    }
    let committed = match std::fs::read_to_string(&path) {
        Ok(text) => text,
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &fresh_text).unwrap();
            println!("bootstrapped golden {} — commit this file", path.display());
            return;
        }
    };
    let committed_value = json::parse(&committed)
        .unwrap_or_else(|e| panic!("golden {name} is unparseable ({e}); regenerate it"));
    let committed_text = json::to_string(&committed_value);
    if committed_text != fresh_text {
        let at = committed_text
            .bytes()
            .zip(fresh_text.bytes())
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| committed_text.len().min(fresh_text.len()));
        let ctx = |s: &str| s[at.saturating_sub(40)..(at + 40).min(s.len())].to_string();
        panic!(
            "golden {name} diverged at byte {at}:\n  committed: …{}…\n  fresh:     …{}…\n\
             If the change is deliberate, regenerate with\n  \
             DBPIM_UPDATE_GOLDENS=1 cargo test -q --test integration_goldens",
            ctx(&committed_text),
            ctx(&fresh_text),
        );
    }
}

#[test]
fn golden_fig3() {
    let (bits, cols) = exp::fig3(SEED);
    check_golden("fig3", &exp::fig3_json(&bits, &cols));
}

#[test]
fn golden_fig11() {
    check_golden("fig11", &exp::fig11_json(&exp::fig11(SEED)));
}

#[test]
fn golden_fig12() {
    check_golden("fig12", &exp::fig12_json(&exp::fig12(SEED)));
}

#[test]
fn golden_fig13() {
    check_golden("fig13", &exp::fig13_json(&exp::fig13(SEED)));
}

#[test]
fn golden_table2() {
    check_golden("table2", &exp::table2_json(&exp::table2(SEED)));
}

#[test]
fn golden_table3() {
    check_golden("table3", &exp::table3_json(&exp::table3(SEED)));
}

#[test]
fn golden_fault_campaign() {
    // Same rows as the `dbpim fault-campaign` defaults (resnet18 ×
    // BER {1e-5, 1e-4, 1e-3} × repair {none, spares}): pins repair
    // coverage, injected-cell and detection counts, per-layer
    // corruption accounting and cycle/energy overheads bit-exactly.
    // Cell-fault verdicts are pure hashes of (seed, coordinate), so
    // these rows are identical for any engine or worker count.
    let rows = exp::fault_campaign(SEED);
    // ISSUE 9 acceptance, pinned independently of the snapshot: with
    // spare repair at BER <= 1e-4, no corrupted layer goes undetected,
    // and the spare budget repairs real columns somewhere in the sweep.
    for r in rows.iter().filter(|r| r.repair == "spares" && r.ber <= 1e-4) {
        assert_eq!(
            r.undetected_layers, 0,
            "undetected corruption under spares at ber={}",
            r.ber
        );
    }
    assert!(
        rows.iter().any(|r| r.repair == "spares" && r.repaired_columns > 0),
        "spare repair never fired across the sweep"
    );
    check_golden("fault_campaign", &exp::fault_campaign_json(&rows));
}

#[test]
fn golden_explore() {
    // Same rows as the `dbpim explore` defaults (tiny_transformer +
    // gpt_micro over seq-len × arch-variant × fleet axes): pins the
    // transformer GEMM lowering, per-head/N:M sparsity configs, the
    // arch-variant cost deltas and the Pareto-frontier marking
    // bit-exactly. Rows are identical for any worker count or engine.
    let rows = exp::explore(SEED);
    // ISSUE 10 acceptance, pinned independently of the snapshot: every
    // swept model reports a non-empty frontier.
    for model in ["tiny_transformer", "gpt_micro"] {
        assert!(
            rows.iter().any(|r| r.model == model && r.on_frontier),
            "{model}: empty Pareto frontier"
        );
    }
    check_golden("explore", &exp::explore_json(&rows));
}

#[test]
fn golden_shard_sweep() {
    // The multi-chip driver builds its fleet specs explicitly, so these
    // rows are identical with or without the DBPIM_CHIPS/DBPIM_SCHEME
    // env overrides the equivalence CI leg sets.
    check_golden("shard_sweep", &exp::shard_sweep_json(&exp::shard_sweep(SEED)));
}
