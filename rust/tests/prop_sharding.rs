//! Property and integration tests for the multi-chip sharding layer
//! (DESIGN.md §12; `coordinator::sharding`).
//!
//! Invariants covered:
//! * placement: `partition_assignments` always returns a per-chip
//!   partition (ascending lists, concatenation a permutation of the
//!   assignment indices), and whenever the guaranteed-fit condition
//!   `total ≤ chips·cap − (chips−1)·max` holds, no chip exceeds its
//!   weight capacity — the contract the sharding module doc pins as
//!   `tp_placement_respects_capacity`
//! * delegation: a `chips == 1` fleet under *every* scheme is
//!   bit-identical to the plain single-chip path — same layer stats,
//!   same totals, same `total_cycles`/`time_ns`, zero interconnect
//! * conservation: for `chips > 1` under every scheme, the physical
//!   projection of the merged totals (timing fields set aside, barrier
//!   bookkeeping corrected by the merge) equals the single-chip
//!   physical totals exactly, and the interconnect pseudo-layer is
//!   physically zero
//! * determinism: sharded runs are bit-identical across the parallel
//!   and sequential engines (fresh caches on each side, so the
//!   comparison is not served from the memo)
//! * speedup: resnet18 under tensor parallelism is monotone
//!   non-degrading over 1 → 4 → 16 chips (the ISSUE 8 acceptance
//!   criterion), with a small slack at 16 where LPT balance is not
//!   provably monotone

use dbpim::arch::ArchConfig;
use dbpim::compiler::{compile_layer, prepare_layer, CompileCache, SparsityConfig};
use dbpim::coordinator::sharding::{
    self, assignment_footprint_bytes, partition_assignments, physical_projection, ShardSpec,
};
use dbpim::models::{fixtures, resnet18, synthesize_weights, Network};
use dbpim::quant;
use dbpim::sim::{self, Engine, SimCache, SimReport};
use dbpim::util::{check_cases, Rng};

fn random_arch(rng: &mut Rng) -> ArchConfig {
    match rng.below(6) {
        0 => ArchConfig::db_pim(),
        1 => ArchConfig::dense_baseline(),
        2 => ArchConfig::bit_only(),
        3 => ArchConfig::value_only(),
        4 => ArchConfig::weights_only(),
        _ => ArchConfig::dac24(),
    }
}

fn random_sparsity(rng: &mut Rng) -> SparsityConfig {
    SparsityConfig { value_sparsity: rng.f64() * 0.8, fta: rng.f64() < 0.7 }
}

fn random_fixture(rng: &mut Rng) -> Network {
    if rng.below(2) == 0 {
        fixtures::small_net()
    } else {
        fixtures::tiny_net()
    }
}

/// Bit-exact report comparison, field by field (`SimReport` carries no
/// `PartialEq` because of the shared `Arc<ArchConfig>`).
fn same_report(want: &SimReport, got: &SimReport) -> Result<(), String> {
    if want.network != got.network {
        return Err(format!("network name: {} vs {}", want.network, got.network));
    }
    if want.layers.len() != got.layers.len() {
        return Err(format!("layer count: {} vs {}", want.layers.len(), got.layers.len()));
    }
    for (w, g) in want.layers.iter().zip(&got.layers) {
        if w.name != g.name {
            return Err(format!("layer name: {} vs {}", w.name, g.name));
        }
        if w.elapsed != g.elapsed || w.core_cycles != g.core_cycles || w.events != g.events {
            return Err(format!("layer {} stats diverge", w.name));
        }
    }
    if want.totals != got.totals {
        return Err("totals diverge".into());
    }
    if want.total_cycles() != got.total_cycles() || want.time_ns() != got.time_ns() {
        return Err(format!(
            "timing: {} cy / {} ns vs {} cy / {} ns",
            want.total_cycles(),
            want.time_ns(),
            got.total_cycles(),
            got.time_ns()
        ));
    }
    Ok(())
}

/// Placement is a partition, lists are ascending, and the
/// guaranteed-fit condition implies every chip stays within its weight
/// capacity. (Proof the LPT fallback never fires under the condition:
/// if some footprint `fp` fit nowhere, every chip would already hold
/// more than `cap − fp`, so `total > chips·cap − chips·fp + fp
/// ≥ chips·cap − (chips−1)·max` — contradiction.)
#[test]
fn tp_placement_respects_capacity() {
    check_cases(30, |rng| {
        let arch = random_arch(rng);
        let sp = random_sparsity(rng);
        let m = 1 + rng.below(16) as usize;
        let k = 1 + rng.below(512) as usize;
        let n = 8 * (1 + rng.below(12) as usize);
        let w = synthesize_weights(rng.next_u64(), k, n);
        let prep = prepare_layer("p", m, k, n, w, sp, &arch, quant::requant_mul(0.01), true, None);
        let layer = compile_layer(prep, &arch);
        let chips = 1 + rng.below(8) as usize;

        let parts = partition_assignments(&layer.assignments, &arch, chips);
        if parts.len() != chips {
            return Err(format!("{} chip lists for {chips} chips", parts.len()));
        }
        let mut seen = vec![false; layer.assignments.len()];
        for (c, p) in parts.iter().enumerate() {
            for win in p.windows(2) {
                if win[0] >= win[1] {
                    return Err(format!("chip {c} list not ascending"));
                }
            }
            for &i in p {
                if *seen.get(i).ok_or_else(|| format!("chip {c} got bogus index {i}"))? {
                    return Err(format!("assignment {i} placed twice"));
                }
                seen[i] = true;
            }
        }
        if let Some(i) = seen.iter().position(|&s| !s) {
            return Err(format!("assignment {i} dropped"));
        }

        let cap = (arch.pim_capacity_kb() as u64) * 1024;
        let foot: Vec<u64> = layer.assignments.iter().map(assignment_footprint_bytes).collect();
        let total: u64 = foot.iter().sum();
        let max = foot.iter().copied().max().unwrap_or(0);
        if total + (chips as u64 - 1) * max <= chips as u64 * cap {
            for (c, p) in parts.iter().enumerate() {
                let used: u64 = p.iter().map(|&i| foot[i]).sum();
                if used > cap {
                    return Err(format!(
                        "chip {c} over capacity under the fit condition: {used} > {cap} \
                         (total {total}, max {max}, chips {chips})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// `chips == 1` under every scheme delegates to the single-chip path
/// bit for bit — the DESIGN.md §12 contract the goldens and the CI
/// `DBPIM_CHIPS=1` equivalence leg rely on.
#[test]
fn prop_single_chip_fleet_is_bit_identical_under_every_scheme() {
    check_cases(12, |rng| {
        let arch = random_arch(rng);
        let sp = random_sparsity(rng);
        let net = random_fixture(rng);
        let engine = if rng.below(2) == 0 { Engine::Parallel } else { Engine::Sequential };
        let seed = rng.next_u64();
        let cache = CompileCache::new();
        let simc = SimCache::new();
        let want = sim::simulate_network_memo(&net, sp, &arch, seed, engine, &cache, &simc);
        for scheme in ["tp", "pp", "hybrid"] {
            let spec = ShardSpec::parse(1, scheme).unwrap();
            let got =
                sharding::simulate_sharded(&net, sp, &arch, seed, spec, engine, &cache, &simc);
            same_report(&want, &got.report)
                .map_err(|e| format!("chips=1 {scheme} on {}: {e}", arch.name))?;
            if got.interconnect_cycles != 0 || got.interconnect_bytes != 0 {
                return Err(format!("chips=1 {scheme} charged interconnect"));
            }
            if got.chip_cycles != vec![want.total_cycles()]
                || got.pipeline_interval_cycles != want.total_cycles()
            {
                return Err(format!("chips=1 {scheme} fleet decomposition off"));
            }
        }
        Ok(())
    });
}

/// Sharding moves work, it must not create or destroy it: for any
/// fleet the physical projection of the merged totals equals the
/// single-chip totals exactly, the interconnect pseudo-layer is
/// physically zero, and the whole run is bit-identical across engines.
#[test]
fn prop_sharded_physical_totals_are_conserved() {
    check_cases(8, |rng| {
        let arch = random_arch(rng);
        let sp = random_sparsity(rng);
        let net = random_fixture(rng);
        let seed = rng.next_u64();
        let chips = 2 + rng.below(3) as usize;
        let cache = CompileCache::new();
        let simc = SimCache::new();
        let want =
            sim::simulate_network_memo(&net, sp, &arch, seed, Engine::Parallel, &cache, &simc);
        for scheme in ["tp", "pp", "hybrid"] {
            let spec = ShardSpec::parse(chips, scheme).unwrap();
            let got = sharding::simulate_sharded(
                &net,
                sp,
                &arch,
                seed,
                spec,
                Engine::Parallel,
                &cache,
                &simc,
            );
            if physical_projection(&got.report.totals) != physical_projection(&want.totals) {
                return Err(format!(
                    "physical totals not conserved: {chips} chips, {scheme}, {}",
                    arch.name
                ));
            }
            if let Some(comm) = got.report.layers.iter().find(|l| l.name == "interconnect") {
                let phys = physical_projection(&comm.events);
                if phys != dbpim::energy::EventCounts::default() {
                    return Err(format!("interconnect pseudo-layer has physical events: {phys:?}"));
                }
            }
            if got.chip_cycles.len() != chips {
                return Err(format!(
                    "{} chip_cycles entries for {chips} chips",
                    got.chip_cycles.len()
                ));
            }
            // Determinism across engines, served from fresh caches so
            // the memo cannot mask a divergence.
            let seq_cache = CompileCache::new();
            let seq_simc = SimCache::new();
            let seq = sharding::simulate_sharded(
                &net,
                sp,
                &arch,
                seed,
                spec,
                Engine::Sequential,
                &seq_cache,
                &seq_simc,
            );
            same_report(&got.report, &seq.report)
                .map_err(|e| format!("engines diverge: {chips} chips, {scheme}: {e}"))?;
            if got.chip_cycles != seq.chip_cycles
                || got.interconnect_cycles != seq.interconnect_cycles
                || got.interconnect_bytes != seq.interconnect_bytes
                || got.pipeline_interval_cycles != seq.pipeline_interval_cycles
            {
                return Err(format!("fleet decomposition diverges: {chips} chips, {scheme}"));
            }
        }
        Ok(())
    });
}

/// The ISSUE 8 acceptance criterion: resnet18 under tensor parallelism
/// speeds up monotonically (non-degrading) over 1 → 4 → 16 chips. The
/// 4-vs-1 comparison is strict; 16-vs-4 allows 2% slack because LPT
/// balance plus a growing all-gather is not provably monotone.
#[test]
fn tp_speedup_is_monotone_on_resnet18() {
    let net = resnet18();
    let sp = SparsityConfig::hybrid(0.6);
    let arch = ArchConfig::db_pim();
    let cache = CompileCache::new();
    let simc = SimCache::new();
    let fleet = |chips: usize| {
        let spec = ShardSpec::parse(chips, "tp").unwrap();
        sharding::simulate_sharded(&net, sp, &arch, 42, spec, Engine::Parallel, &cache, &simc)
            .fleet_cycles()
    };
    let c1 = fleet(1);
    let c4 = fleet(4);
    let c16 = fleet(16);
    assert!(c4 < c1, "4-chip TP must beat a single chip: {c4} vs {c1} cycles");
    assert!(
        c16 as f64 <= c4 as f64 * 1.02,
        "16-chip TP degrades past the slack vs 4 chips: {c16} vs {c4} cycles"
    );
}
