//! Integration: compiler → simulator across architectures, sparsity
//! levels and layer geometries. Functional outputs must always equal
//! the exact matmul reference; timing must respect the paper's ordering
//! relations (more sparsity ⇒ fewer cycles, DB-PIM ⇒ higher U_act).

use dbpim::arch::ArchConfig;
use dbpim::compiler::{compile_layer, prepare_layer, SparsityConfig};
use dbpim::models::{synthesize_activations, synthesize_weights};
use dbpim::quant;
use dbpim::sim::Machine;
use dbpim::tensor::{matmul_i8, MatI8};

fn build(
    m: usize,
    k: usize,
    n: usize,
    sp: SparsityConfig,
    arch: &ArchConfig,
    seed: u64,
) -> dbpim::compiler::CompiledLayer {
    let w = synthesize_weights(seed, k, n);
    let prep = prepare_layer("t", m, k, n, w, sp, arch, quant::requant_mul(0.01), true, None);
    compile_layer(prep, arch)
}

fn acts(m: usize, k: usize, seed: u64) -> MatI8 {
    MatI8::from_vec(m, k, synthesize_activations(seed, m * k))
}

#[test]
fn functional_equivalence_matrix_of_configs() {
    // all architectures × several geometries × sparsity levels
    let archs = [
        ArchConfig::db_pim(),
        ArchConfig::dense_baseline(),
        ArchConfig::bit_only(),
        ArchConfig::value_only(),
        ArchConfig::weights_only(),
        ArchConfig::dac24(),
    ];
    let geoms = [(3, 17, 8), (16, 256, 32), (5, 700, 24), (1, 512, 16)];
    let sparsities =
        [SparsityConfig::dense(), SparsityConfig::hybrid(0.3), SparsityConfig::hybrid(0.7)];
    for arch in &archs {
        let machine = Machine::new(arch.clone());
        for &(m, k, n) in &geoms {
            for (si, &sp) in sparsities.iter().enumerate() {
                let layer = build(m, k, n, sp, arch, 1000 + si as u64);
                let x = acts(m, k, 77 + si as u64);
                let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
                let want = matmul_i8(&x, &layer.prep.weights);
                assert_eq!(
                    acc.unwrap(),
                    want,
                    "functional mismatch: {} m{m} k{k} n{n} sp{si}",
                    arch.name
                );
            }
        }
    }
}

#[test]
fn cycles_monotone_in_value_sparsity() {
    let arch = ArchConfig::db_pim();
    let machine = Machine::new(arch.clone());
    let mut last = u64::MAX;
    for v in [0.0, 0.25, 0.5, 0.75] {
        let layer = build(32, 512, 64, SparsityConfig::hybrid(v), &arch, 5);
        let x = acts(32, 512, 9);
        let (stats, _) = machine.run_pim_layer(&layer, Some(&x), false);
        assert!(
            stats.elapsed <= last,
            "cycles went UP with sparsity: v={v} {} > {last}",
            stats.elapsed
        );
        last = stats.elapsed;
    }
}

#[test]
fn all_filters_covered_exactly_once() {
    for arch in [ArchConfig::db_pim(), ArchConfig::dense_baseline()] {
        let layer = build(4, 128, 104, SparsityConfig::hybrid(0.4), &arch, 11);
        let mut seen = vec![0u32; layer.prep.n];
        for a in &layer.assignments {
            for &f in &a.filters {
                seen[f] += 1;
            }
        }
        // every filter with non-zero threshold is assigned exactly once
        for (f, &count) in seen.iter().enumerate() {
            let th = layer.prep.thresholds[f];
            if arch.weight_bit_sparsity && th == 0 {
                assert_eq!(count, 0, "empty filter {f} assigned");
            } else {
                assert_eq!(count, 1, "filter {f} count {count} on {}", arch.name);
            }
        }
    }
}

#[test]
fn empty_and_degenerate_layers() {
    let arch = ArchConfig::db_pim();
    let machine = Machine::new(arch.clone());
    // all-zero weights: everything removed by FTA (φ_th = 0 everywhere)
    let prep = prepare_layer(
        "zero",
        4,
        32,
        16,
        vec![0i8; 32 * 16],
        SparsityConfig::hybrid(0.0),
        &arch,
        quant::requant_mul(0.01),
        true,
        None,
    );
    let layer = compile_layer(prep, &arch);
    assert!(layer.assignments.is_empty(), "all-zero layer must map to nothing");
    let x = acts(4, 32, 1);
    let (stats, acc) = machine.run_pim_layer(&layer, Some(&x), true);
    assert!(acc.unwrap().data.iter().all(|&v| v == 0));
    assert_eq!(stats.events.macro_cycles, 0);
}

#[test]
fn single_row_and_single_filter_group() {
    let arch = ArchConfig::db_pim();
    let machine = Machine::new(arch.clone());
    let layer = build(1, 8, 8, SparsityConfig::hybrid(0.0), &arch, 3);
    let x = acts(1, 8, 2);
    let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
    let want = matmul_i8(&x, &layer.prep.weights);
    assert_eq!(acc.unwrap(), want);
}

#[test]
fn instruction_stream_fits_paper_instruction_buffer_per_tile() {
    // the 16 KB instruction buffer must hold one tile's worth of
    // instructions; check the per-tile instruction density is sane.
    let arch = ArchConfig::db_pim();
    let layer = build(64, 1024, 64, SparsityConfig::hybrid(0.6), &arch, 4);
    let per_tile = dbpim::compiler::instr_bytes(&layer) / layer.tiles.len().max(1);
    assert!(per_tile < 16 * 1024, "per-tile instruction footprint {per_tile}B exceeds buffer");
}

#[test]
fn utilization_ordering_dbpim_vs_dense_on_network_layers() {
    // conv-like geometry: DB-PIM mapping must waste far fewer engaged
    // cells than the dense mapping (which stores FTA zeros).
    let sp = SparsityConfig::hybrid(0.6);
    let arch_d = ArchConfig::db_pim();
    let arch_b = ArchConfig::dense_baseline();
    let ld = build(64, 576, 64, sp, &arch_d, 21);
    let lb = build(64, 576, 64, sp, &arch_b, 21);
    let x = acts(64, 576, 5);
    let (sd, _) = Machine::new(arch_d.clone()).run_pim_layer(&ld, Some(&x), false);
    let (sb, _) = Machine::new(arch_b.clone()).run_pim_layer(&lb, None, false);
    let cells = arch_d.macro_columns * arch_d.compartments;
    let ud = sd.events.u_act(cells);
    let ub = sb.events.u_act(cells);
    assert!(ud > 0.75, "DB-PIM U_act {ud}");
    assert!(ub < 0.45, "dense U_act {ub} (stores FTA zeros)");
}

#[test]
fn dbmu_bit_level_path_cross_checks_fast_functional_path() {
    // The machine's fast dot-product accumulate must agree with the
    // bit-level DBMU datapath on the packed tile image.
    use dbpim::sim::dbmu::{row_step_mac, TileImage};
    let arch = ArchConfig::db_pim();
    let layer = build(1, 64, 8, SparsityConfig::hybrid(0.5), &arch, 33);
    let x = acts(1, 64, 6);
    let machine = Machine::new(arch);
    let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
    let acc = acc.unwrap();

    // recompute through the DBMU path
    let mut got = vec![0i32; layer.prep.n];
    for a in &layer.assignments {
        let image = TileImage::pack(&layer.prep.weights, &a.kept_rows, &a.filters);
        let gathered: Vec<i8> = a.kept_rows.iter().map(|&k| x.get(0, k as usize)).collect();
        let mut local = vec![0i32; a.filters.len()];
        for base in (0..gathered.len()).step_by(16) {
            let hi = (base + 16).min(gathered.len());
            row_step_mac(&gathered[base..hi], &image, base, &mut local);
        }
        for (slot, &f) in a.filters.iter().enumerate() {
            got[f] += local[slot];
        }
    }
    for f in 0..layer.prep.n {
        assert_eq!(got[f], acc.get(0, f), "DBMU path disagrees at filter {f}");
    }
}

#[test]
fn dense_mapping_timing_is_shape_only() {
    // the baseline's cycle count must not depend on weight values
    let arch = ArchConfig::dense_baseline();
    let machine = Machine::new(arch.clone());
    let a = build(8, 256, 16, SparsityConfig::dense(), &arch, 1);
    let b = build(8, 256, 16, SparsityConfig::hybrid(0.7), &arch, 2);
    let (sa, _) = machine.run_pim_layer(&a, None, false);
    let (sb, _) = machine.run_pim_layer(&b, None, false);
    assert_eq!(sa.elapsed, sb.elapsed, "baseline timing must be data-independent");
}

#[test]
fn network_level_pooled_engines_agree_on_shared_fixture() {
    // whole-network run on the shared `models::fixtures` network: the
    // pool-backed parallel walk (layer jobs + nested segment jobs) must
    // be bit-identical to the fully sequential walk.
    use dbpim::models::fixtures::small_net;
    use dbpim::sim::Engine;
    let net = small_net();
    let sp = SparsityConfig::hybrid(0.4);
    let arch = ArchConfig::db_pim();
    let p = dbpim::sim::simulate_network_with_engine(&net, sp, &arch, 11, Engine::Parallel);
    let s = dbpim::sim::simulate_network_with_engine(&net, sp, &arch, 11, Engine::Sequential);
    assert_eq!(p.totals, s.totals);
    assert_eq!(p.total_cycles(), s.total_cycles());
    for (a, b) in p.layers.iter().zip(&s.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.events, b.events);
        assert_eq!(a.core_cycles, b.core_cycles);
        assert_eq!(a.elapsed, b.elapsed);
    }
}
