//! Randomized property tests over coordinator/compiler/simulator
//! invariants (proptest is not in the offline registry; the in-tree
//! `util::check_cases` harness provides seeded-case reporting).
//!
//! Invariants covered:
//! * routing: every kept filter reaches exactly one assignment; every
//!   kept K row of a group is covered by exactly one tile
//! * batching: Compute instructions partition [0, M) per tile
//! * state: functional accumulators equal the exact matmul for random
//!   shapes/sparsities/architectures
//! * conservation: IPU can only reduce cycles; value pruning can only
//!   reduce stored rows; energy is monotone in event counts
//! * equivalence: the parallel segmented engine is bit-identical
//!   (cycles, events, accumulators) to the sequential segmented engine
//!   and to the legacy flat-stream interpreter
//! * kernels: the step-major batched occupancy scan reproduces a
//!   scalar first-principles walk straight off the input matrix, and
//!   the compile-time gathered weight block (the micro-GEMM operand)
//!   matches the prepared weight matrix
//! * backends: every kernel backend (`Swar64`, `Wide`) is bit-identical
//!   to the `ScalarRef` oracle on the isolated scan/GEMM/requant
//!   routines and at whole-layer granularity for forced kernel tags,
//!   both engines and random core counts
//! * caching: simulating through a CompileCache is bit-identical to
//!   fresh compilation, and repeated sweep points hit; simulating
//!   through a SimCache is bit-identical to the uncached path, repeated
//!   cells hit, and hits skip compilation entirely
//! * recycling: executors running on an arena-warm thread (recycled
//!   occupancy tables, tile scans and accumulator blocks) are
//!   bit-identical to fresh-allocation executors across random reuse
//!   sequences
//! * pooling: nested sweep × layer × segment execution on a private
//!   work-stealing pool (random worker counts 1–16) is bit-identical
//!   to the fully sequential walk, and the SweepSpec executor
//!   reproduces the pre-refactor (serial, per-cell) driver rows exactly
//! * decoding: arbitrary and truncated byte streams through every ISA
//!   decoder (`Instr`/`Segment`/segment stream/`Program`) return a
//!   clean `None` — never a panic, never an over-read — complementing
//!   the encode round-trip properties
//! * cache accounting: `lookups == hits + misses` for both caches, and
//!   the hit/miss counters are identical for any worker count —
//!   scheduling only ever moves work into `dup_computes`
//! * serving: a batched multi-tenant replay (random traces, batch
//!   sizes and worker counts) returns admission-ordered results
//!   bitwise identical to serial per-request `simulate_network`
//! * open-loop serving: a seeded open-loop run (random Poisson/bursty
//!   arrivals, fault injection on) replays bit-exactly — same
//!   per-request outcomes, same deterministic stats, same event order —
//!   across 1, 2 and N workers
//! * fault surfacing: an injected fault that exhausts its retry budget
//!   produces a typed per-request `Failed` outcome with exact retry
//!   counters, never a pool poisoning or a panic, for every worker
//!   count
//! * cell faults: per-cell fault verdicts are pure hashes — identical
//!   across map instances and visit orders — and a faulty layer's
//!   stats/accumulators are bit-identical across engines and reruns; a
//!   zero-BER spec (any seed, any spare/degrade knobs) is bit-identical
//!   to the plain pipeline and shares its compile-cache entries; the
//!   repair pass never exceeds the spare column/macro budget and its
//!   column maps are injective, clean-unless-reported, and consistent
//!   with the aggregate report
//! * transformers: attention/MLP layers lowered to GEMM simulate
//!   bit-identically across Sequential/Parallel engines and through
//!   the compile/sim caches, for random seq lengths and sparsity
//!   points
//! * exploration: every `on_frontier` explorer row is non-dominated
//!   within its model, and the whole row set reproduces bit-exactly
//!   from a fresh `SweepCtx`

use dbpim::arch::ArchConfig;
use dbpim::compiler::{compile_layer, prepare_layer, SparsityConfig};
use dbpim::isa::Instr;
use dbpim::models::synthesize_weights;
use dbpim::quant;
use dbpim::sim::{Engine, Machine};
use dbpim::tensor::{matmul_i8, MatI8};
use dbpim::util::{check_cases, Rng};

fn random_arch(rng: &mut Rng) -> ArchConfig {
    match rng.below(6) {
        0 => ArchConfig::db_pim(),
        1 => ArchConfig::dense_baseline(),
        2 => ArchConfig::bit_only(),
        3 => ArchConfig::value_only(),
        4 => ArchConfig::weights_only(),
        _ => ArchConfig::dac24(),
    }
}

fn random_layer(
    rng: &mut Rng,
    arch: &ArchConfig,
) -> (dbpim::compiler::CompiledLayer, MatI8) {
    let m = 1 + rng.below(24) as usize;
    let k = 1 + rng.below(512) as usize;
    let n = 8 * (1 + rng.below(12) as usize);
    let v = rng.f64() * 0.8;
    let fta = rng.f64() < 0.7;
    let w = synthesize_weights(rng.next_u64(), k, n);
    let prep = prepare_layer(
        "p",
        m,
        k,
        n,
        w,
        SparsityConfig { value_sparsity: v, fta },
        arch,
        quant::requant_mul(0.01),
        true,
        None,
    );
    let layer = compile_layer(prep, arch);
    let x = MatI8::from_vec(m, k, (0..m * k).map(|_| rng.int8()).collect());
    (layer, x)
}

#[test]
fn prop_functional_equals_reference() {
    check_cases(40, |rng| {
        let arch = random_arch(rng);
        let (layer, x) = random_layer(rng, &arch);
        let machine = Machine::new(arch.clone());
        let (_, acc) = machine.run_pim_layer(&layer, Some(&x), true);
        let want = matmul_i8(&x, &layer.prep.weights);
        if acc.unwrap() != want {
            return Err(format!(
                "mismatch on {} m{} k{} n{}",
                arch.name, layer.prep.m, layer.prep.k, layer.prep.n
            ));
        }
        Ok(())
    });
}

/// Scalar first-principles cross-check of the batched kernels: rebuild
/// each tile's IPU timing per (row, step) straight off `x` (gather +
/// OR-fold + popcount, no OccupancyTable involved) and compare against
/// `sim::kernels::scan_tile_occupancy` over a freshly built table; also
/// verify the compile-time gathered weight block against the prepared
/// weight matrix. Covers the step-major storage + word-batched walk and
/// the micro-GEMM operand end-to-end.
fn check_batched_kernels(
    layer: &dbpim::compiler::CompiledLayer,
    x: &MatI8,
    arch: &ArchConfig,
) -> Result<(), String> {
    use dbpim::sim::{kernels, occupancy::OccupancyTable};
    let comp = arch.compartments;
    let m_total = layer.prep.m.max(1);
    for (ai, a) in layer.assignments.iter().enumerate().take(3) {
        let nf = a.filters.len();
        if a.wblock.len() != a.kept_rows.len() * nf {
            return Err(format!("wblock shape off for assignment {ai}"));
        }
        for (ri, &k) in a.kept_rows.iter().enumerate() {
            for (fi, &f) in a.filters.iter().enumerate() {
                if a.wblock[ri * nf + fi] != layer.prep.weights.get(k as usize, f) {
                    return Err(format!("wblock[{ri},{fi}] diverges in assignment {ai}"));
                }
            }
        }
        let table = OccupancyTable::build(ai, x, &a.kept_rows, comp, m_total, true, false);
        for t in layer.tiles.iter().filter(|t| t.assignment == ai) {
            let rows = t.rows();
            let steps = dbpim::util::ceil_div(rows, comp);
            if t.row_start % comp != 0 {
                return Err(format!("step-unaligned tile at row {}", t.row_start));
            }
            // varied per-step weights exercise the eff-total fold too
            let step_eff: Vec<u64> = (0..steps).map(|s| 1 + s as u64).collect();
            let scan =
                kernels::scan_tile_occupancy(&table, t.id, t.row_start / comp, &step_eff);
            let mut eff_ref = 0u64;
            for m in 0..m_total {
                let mut rc = 0u64;
                for (s, &eff) in step_eff.iter().enumerate() {
                    let start = t.row_start + s * comp;
                    let lanes = (rows - s * comp).min(comp);
                    let or = a.kept_rows[start..start + lanes]
                        .iter()
                        .fold(0u8, |o, &k| o | (x.get(m, k as usize) as u8));
                    let beff = u64::from(or.count_ones());
                    rc += beff;
                    eff_ref += eff * beff;
                }
                if scan.row_cycles[m] != rc {
                    return Err(format!(
                        "occ scan row {m} of tile {} diverges: {} vs scalar {rc}",
                        t.id, scan.row_cycles[m]
                    ));
                }
            }
            if scan.eff_total != eff_ref {
                return Err(format!("occ scan eff_total diverges on tile {}", t.id));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_engines_bit_identical_to_legacy_interp() {
    // The acceptance invariant of the segmented-program refactor: for
    // random architectures (IPU on/off, dense baseline, 1–8 cores),
    // sparsity configs and shapes, in both perf and functional mode,
    // the parallel engine, the sequential engine and the legacy flat
    // interpreter agree on every LayerStats field and on the exact
    // accumulators — all three paths running the batched step-major
    // occupancy kernel and the gathered-weight GEMM accumulate, which
    // are additionally cross-checked against scalar first-principles
    // references per case.
    check_cases(30, |rng| {
        let mut arch = random_arch(rng);
        arch.n_cores = 1 + rng.below(8) as usize;
        if rng.below(4) == 0 {
            // exercise IPU-flag combinations the presets don't cover
            arch.input_skipping = !arch.input_skipping;
        }
        let functional = rng.below(2) == 0;
        let (layer, x) = random_layer(rng, &arch);
        let seq = Machine::with_engine(arch.clone(), Engine::Sequential);
        let par = Machine::with_engine(arch.clone(), Engine::Parallel);
        let (s_int, a_int) = seq.run_pim_layer_interp(&layer, Some(&x), functional);
        let (s_seq, a_seq) = seq.run_pim_layer(&layer, Some(&x), functional);
        let (s_par, a_par) = par.run_pim_layer(&layer, Some(&x), functional);
        for (label, s, a) in [("sequential", &s_seq, &a_seq), ("parallel", &s_par, &a_par)] {
            if s.events != s_int.events {
                return Err(format!(
                    "{label} events diverge on {} cores={} fn={functional}:\n{:?}\nvs\n{:?}",
                    arch.name, arch.n_cores, s.events, s_int.events
                ));
            }
            if s.core_cycles != s_int.core_cycles {
                return Err(format!(
                    "{label} core clocks diverge on {} cores={}: {:?} vs {:?}",
                    arch.name, arch.n_cores, s.core_cycles, s_int.core_cycles
                ));
            }
            if s.elapsed != s_int.elapsed {
                return Err(format!("{label} makespan diverges on {}", arch.name));
            }
            if *a != a_int {
                return Err(format!("{label} accumulators diverge on {}", arch.name));
            }
        }
        if functional {
            // and all of them equal the exact reference matmul
            let want = matmul_i8(&x, &layer.prep.weights);
            if a_int.as_ref() != Some(&want) {
                return Err(format!("legacy interp != reference matmul on {}", arch.name));
            }
        }
        // the batched kernels themselves vs scalar first principles
        check_batched_kernels(&layer, &x, &arch)?;
        Ok(())
    });
}

#[test]
fn prop_kernel_backends_bit_identical() {
    // The KernelBackend oracle rule: every fast backend — Swar64 and
    // Wide (AVX2 where the host has it, portable chunked elsewhere) —
    // must be bit-identical to the ScalarRef oracle on every input.
    // Checked two ways per case: the three isolated routines on random
    // occupancy tables / weight blocks / accumulator states (including
    // the requant clamp and ReLU edge values), and a whole layer run
    // with `Program::kernel` forced to each backend, which must
    // reproduce the scalar-forced run exactly under both engines and
    // random core counts.
    use dbpim::sim::backend::{self, BackendKind, KernelBackend};
    use dbpim::sim::kernels::TileScan;
    use dbpim::sim::occupancy::OccupancyTable;
    use dbpim::util::ceil_div;
    check_cases(12, |rng| {
        // --- isolated occupancy scan on a random table ---
        let m_total = 1 + rng.below(40) as usize;
        let k = 8 + rng.below(300) as usize;
        let comp = [1usize, 4, 16][rng.below(3) as usize];
        let x = MatI8::from_vec(
            m_total,
            k,
            (0..m_total * k)
                .map(|_| if rng.below(2) == 0 { 0 } else { rng.int8() })
                .collect(),
        );
        let kept: Vec<u32> = (0..k as u32).filter(|_| rng.below(4) > 0).collect();
        if !kept.is_empty() {
            let table = OccupancyTable::build(0, &x, &kept, comp, m_total, true, false);
            let steps = ceil_div(kept.len(), comp);
            let step_eff: Vec<u64> = (0..steps).map(|_| rng.below(512)).collect();
            let mut want = TileScan::empty();
            let mut scratch = Vec::new();
            backend::SCALAR_REF
                .scan_tile_occupancy_into(&mut want, &table, 3, 0, &step_eff, &mut scratch);
            for b in backend::all_backends() {
                let mut got = TileScan::empty();
                let mut scratch = Vec::new();
                b.scan_tile_occupancy_into(&mut got, &table, 3, 0, &step_eff, &mut scratch);
                if got.tile != want.tile
                    || got.row_cycles != want.row_cycles
                    || got.eff_total != want.eff_total
                {
                    return Err(format!(
                        "{:?} scan diverges from oracle (m {m_total} kept {})",
                        b.kind(),
                        kept.len()
                    ));
                }
            }
        }
        // --- isolated GEMM over non-zero base accumulators, with zero
        // and 0x80 (-128) activation bytes salted in ---
        let rows = rng.below(48) as usize;
        let nf = 1 + rng.below(40) as usize;
        let gathered: Vec<u8> = (0..rows)
            .map(|_| match rng.below(4) {
                0 => 0,
                1 => 0x80,
                _ => rng.int8() as u8,
            })
            .collect();
        let wblock: Vec<i8> = (0..rows * nf).map(|_| rng.int8()).collect();
        let base: Vec<i32> = (0..nf).map(|_| (rng.next_u64() as i32) >> 8).collect();
        let mut want = base.clone();
        backend::SCALAR_REF.gemm_accumulate(&mut want, &gathered, &wblock);
        for b in backend::all_backends() {
            let mut got = base.clone();
            b.gemm_accumulate(&mut got, &gathered, &wblock);
            if got != want {
                return Err(format!(
                    "{:?} gemm diverges from oracle (rows {rows} nf {nf})",
                    b.kind()
                ));
            }
        }
        // --- isolated requant/ReLU with clamp edge values ---
        let mut accs: Vec<i32> =
            (0..rng.below(64) as usize).map(|_| rng.next_u64() as i32).collect();
        accs.extend([0, 1, -1, i32::MAX, i32::MIN, 100_000, -100_000, 6553, 65_536]);
        let mul = quant::requant_mul(0.001 + rng.f64() * 0.1);
        for relu in [false, true] {
            let mut want = vec![0i8; accs.len()];
            backend::SCALAR_REF.requant_relu_into(&mut want, &accs, mul, relu);
            for b in backend::all_backends() {
                let mut got = vec![0i8; accs.len()];
                b.requant_relu_into(&mut got, &accs, mul, relu);
                if got != want {
                    return Err(format!(
                        "{:?} requant diverges from oracle (relu={relu})",
                        b.kind()
                    ));
                }
            }
        }
        // --- whole layer with the kernel tag forced per backend ---
        let mut arch = random_arch(rng);
        arch.n_cores = 1 + rng.below(8) as usize;
        let functional = rng.below(2) == 0;
        let (layer, x) = random_layer(rng, &arch);
        let mut oracle_layer = layer.clone();
        oracle_layer.program.kernel = BackendKind::Scalar;
        let seq = Machine::with_engine(arch.clone(), Engine::Sequential);
        let par = Machine::with_engine(arch.clone(), Engine::Parallel);
        let want = seq.run_pim_layer(&oracle_layer, Some(&x), functional);
        for kind in BackendKind::ALL {
            let mut forced = layer.clone();
            forced.program.kernel = kind;
            for (label, machine) in [("sequential", &seq), ("parallel", &par)] {
                let (stats, acc) = machine.run_pim_layer(&forced, Some(&x), functional);
                if stats.events != want.0.events
                    || stats.core_cycles != want.0.core_cycles
                    || stats.elapsed != want.0.elapsed
                {
                    return Err(format!(
                        "{kind:?} {label} stats diverge from scalar oracle on {} cores={}",
                        arch.name, arch.n_cores
                    ));
                }
                if acc != want.1 {
                    return Err(format!(
                        "{kind:?} {label} accumulators diverge on {}",
                        arch.name
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_compile_cache_is_bit_identical_and_hits() {
    use dbpim::compiler::CompileCache;
    use dbpim::models::fixtures::small_net;
    check_cases(12, |rng| {
        let arch = random_arch(rng);
        let net = small_net();
        let sp = SparsityConfig { value_sparsity: rng.f64() * 0.7, fta: rng.below(2) == 0 };
        let seed = rng.next_u64();
        let cache = CompileCache::new();
        let plain = dbpim::sim::simulate_network_with_engine(
            &net, sp, &arch, seed, Engine::Sequential,
        );
        let cached = dbpim::sim::simulate_network_cached(
            &net, sp, &arch, seed, Engine::Sequential, &cache,
        );
        if cached.totals != plain.totals || cached.total_cycles() != plain.total_cycles() {
            return Err(format!("cached simulation diverges on {}", arch.name));
        }
        let first = cache.stats();
        if first.hits != 0 || first.misses == 0 {
            return Err(format!("unexpected first-pass stats {first:?}"));
        }
        // a repeated sweep point must be served entirely from the cache
        let again = dbpim::sim::simulate_network_cached(
            &net, sp, &arch, seed, Engine::Sequential, &cache,
        );
        if again.totals != plain.totals {
            return Err(format!("cache-hit simulation diverges on {}", arch.name));
        }
        let second = cache.stats();
        if second.misses != first.misses || second.hits != first.misses {
            return Err(format!("repeat pass did not hit: {second:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_simcache_is_bit_identical_and_hits() {
    // Mirror of the compile-cache property one level up: simulating
    // through a SimCache must be bit-identical to the uncached path, a
    // repeated sweep cell must be served entirely from the cache, and
    // sim-cache hits must skip compilation entirely (the compile cache
    // sees exactly one lookup per sim miss and none on the hit pass).
    use dbpim::compiler::CompileCache;
    use dbpim::models::fixtures::small_net;
    use dbpim::sim::SimCache;
    check_cases(8, |rng| {
        let arch = random_arch(rng);
        let net = small_net();
        let sp = SparsityConfig { value_sparsity: rng.f64() * 0.7, fta: rng.below(2) == 0 };
        let seed = rng.next_u64();
        let cc = CompileCache::new();
        let sc = SimCache::new();
        let plain =
            dbpim::sim::simulate_network_with_engine(&net, sp, &arch, seed, Engine::Sequential);
        let memo = dbpim::sim::simulate_network_memo(
            &net,
            sp,
            &arch,
            seed,
            Engine::Sequential,
            &cc,
            &sc,
        );
        if memo.totals != plain.totals || memo.total_cycles() != plain.total_cycles() {
            return Err(format!("memoized simulation diverges on {}", arch.name));
        }
        let first = sc.stats();
        if first.hits != 0 || first.misses == 0 {
            return Err(format!("unexpected first-pass sim stats {first:?}"));
        }
        if cc.stats().lookups() != first.misses {
            return Err(format!(
                "compile lookups {} != sim misses {} on {}",
                cc.stats().lookups(),
                first.misses,
                arch.name
            ));
        }
        // a repeated sweep cell must be served entirely from the cache
        let again = dbpim::sim::simulate_network_memo(
            &net,
            sp,
            &arch,
            seed,
            Engine::Sequential,
            &cc,
            &sc,
        );
        if again.totals != plain.totals {
            return Err(format!("sim-cache-hit report diverges on {}", arch.name));
        }
        for (a, b) in again.layers.iter().zip(&plain.layers) {
            if a.name != b.name
                || a.events != b.events
                || a.core_cycles != b.core_cycles
                || a.elapsed != b.elapsed
            {
                return Err(format!("cached layer {} diverges on {}", a.name, arch.name));
            }
        }
        let second = sc.stats();
        if second.misses != first.misses || second.hits != first.misses {
            return Err(format!("repeat pass did not hit: {second:?}"));
        }
        // the hit pass never touched the compiler
        if cc.stats().lookups() != first.misses {
            return Err("sim-cache hits must skip compilation entirely".into());
        }
        Ok(())
    });
}

#[test]
fn prop_arena_recycled_executors_bit_identical() {
    // The acceptance invariant of the scratch-arena refactor: arena
    // recycling must never leak state between executors. Run random
    // layers repeatedly in random interleavings on this thread
    // (sequential engine — every executor recycles through this
    // thread's arena, which is warm after the first pass) and require
    // each rerun to reproduce the first run's stats and accumulators
    // bit for bit.
    check_cases(6, |rng| {
        let mut cases = Vec::new();
        for _ in 0..3 {
            let arch = random_arch(rng);
            let (layer, x) = random_layer(rng, &arch);
            let functional = rng.below(2) == 0;
            let machine = Machine::with_engine(arch, Engine::Sequential);
            let want = machine.run_pim_layer(&layer, Some(&x), functional);
            cases.push((machine, layer, x, functional, want));
        }
        for round in 0..6 {
            let i = rng.below(cases.len() as u64) as usize;
            let (machine, layer, x, functional, want) = &cases[i];
            let (stats, acc) = machine.run_pim_layer(layer, Some(x), *functional);
            if stats.events != want.0.events
                || stats.core_cycles != want.0.core_cycles
                || stats.elapsed != want.0.elapsed
            {
                return Err(format!("recycled rerun {round} of case {i} diverges"));
            }
            if acc != want.1 {
                return Err(format!("recycled accumulators diverge on rerun {round}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pooled_nested_execution_bit_identical() {
    // The acceptance invariant of the worker-pool refactor: a sweep
    // fanned out on a private pool of random size (1–16 workers), with
    // each cell's layer jobs and per-segment jobs nesting into the
    // *same* pool, produces reports bit-identical to the fully
    // sequential walk — worker count and steal order never leak into
    // results.
    use dbpim::coordinator::pool::Pool;
    use dbpim::models::fixtures::small_net;
    check_cases(6, |rng| {
        let workers = 1 + rng.below(16) as usize;
        let pool = Pool::new(workers);
        let net = small_net();
        let arch = ArchConfig::db_pim();
        let cells: Vec<(f64, u64)> = (0..4).map(|_| (rng.f64() * 0.7, rng.next_u64())).collect();
        // serial reference: every level sequential, no pool involved
        let want: Vec<_> = cells
            .iter()
            .map(|&(v, seed)| {
                dbpim::sim::simulate_network_with_engine(
                    &net,
                    SparsityConfig::hybrid(v),
                    &arch,
                    seed,
                    Engine::Sequential,
                )
            })
            .collect();
        // pooled: sweep cells fan out on the private pool; nested
        // layer/segment scopes route back onto it via the worker TLS
        let jobs: Vec<_> = cells
            .iter()
            .map(|&(v, seed)| {
                let (net, arch) = (&net, &arch);
                move || {
                    dbpim::sim::simulate_network_with_engine(
                        net,
                        SparsityConfig::hybrid(v),
                        arch,
                        seed,
                        Engine::Parallel,
                    )
                }
            })
            .collect();
        let got = pool.run_jobs(jobs);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            if g.totals != w.totals {
                return Err(format!("totals diverge at cell {i} with {workers} workers"));
            }
            if g.layers.len() != w.layers.len() {
                return Err(format!("layer count diverges at cell {i}"));
            }
            for (a, b) in g.layers.iter().zip(&w.layers) {
                if a.events != b.events
                    || a.core_cycles != b.core_cycles
                    || a.elapsed != b.elapsed
                {
                    return Err(format!("layer {} diverges at {workers} workers", a.name));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sweepspec_reproduces_serial_fig11_rows() {
    // The SweepSpec executor must reproduce the pre-refactor driver
    // rows exactly: recompute every fig11 cell serially (sequential
    // engine, plain cached simulation calls — what the old driver ran
    // per job) and require bitwise-equal speedup/energy columns.
    use dbpim::compiler::CompileCache;
    use dbpim::coordinator::experiments;
    use dbpim::energy::EnergyTable;
    use dbpim::sim::OpCategory;

    let seed = 7;
    let (rows, stats) = experiments::fig11_with_stats(seed);
    assert_eq!(rows.len(), 12);
    assert!(stats.sim.hits > 0, "fig11's repeated dense baseline must hit the sweep sim cache");
    // a sim-cache hit skips compilation entirely: the compile cache
    // sees exactly one lookup per sim computation (misses plus any
    // racing duplicates, which re-drive the compile lookup)
    assert_eq!(stats.compile.lookups(), stats.sim.misses + stats.sim.dup_computes);

    let cache = CompileCache::new();
    let arch = ArchConfig::weights_only();
    let base_arch = ArchConfig::dense_baseline();
    let table = EnergyTable::default28nm();
    let pim_energy = |r: &dbpim::sim::SimReport| -> f64 {
        r.layers
            .iter()
            .filter(|l| l.category == OpCategory::PimConvFc)
            .map(|l| l.events.energy_pj(&table))
            .sum()
    };
    let mut i = 0;
    for name in ["vgg19", "resnet18", "mobilenet_v2"] {
        for &v in &[0.0, 0.2, 0.4, 0.6] {
            let net = dbpim::models::by_name(name).unwrap();
            let r = dbpim::sim::simulate_network_cached(
                &net,
                SparsityConfig::hybrid(v),
                &arch,
                seed,
                Engine::Sequential,
                &cache,
            );
            let b = dbpim::sim::simulate_network_cached(
                &net,
                SparsityConfig::dense(),
                &base_arch,
                seed,
                Engine::Sequential,
                &cache,
            );
            let row = &rows[i];
            assert_eq!(row.network, name, "row order diverges at {i}");
            let speedup = b.pim_cycles() as f64 / r.pim_cycles().max(1) as f64;
            let saving = 1.0 - pim_energy(&r) / pim_energy(&b).max(1e-12);
            assert_eq!(row.speedup.to_bits(), speedup.to_bits(), "{name} v={v}");
            assert_eq!(row.energy_saving.to_bits(), saving.to_bits(), "{name} v={v}");
            i += 1;
        }
    }
}

#[test]
fn prop_routing_tiles_partition_kept_rows() {
    check_cases(60, |rng| {
        let arch = random_arch(rng);
        let (layer, _) = random_layer(rng, &arch);
        for (ai, a) in layer.assignments.iter().enumerate() {
            let mut covered = 0usize;
            let mut last_end = 0usize;
            for t in layer.tiles.iter().filter(|t| t.assignment == ai) {
                if t.row_start != last_end {
                    return Err(format!("tile gap at {}", t.row_start));
                }
                if t.rows() > arch.k_slots() {
                    return Err("tile exceeds macro capacity".into());
                }
                covered += t.rows();
                last_end = t.row_end;
            }
            if covered != a.kept_rows.len() {
                return Err(format!("covered {covered} != kept {}", a.kept_rows.len()));
            }
            if a.active_cols() > arch.macro_columns {
                return Err("column overflow".into());
            }
            // kept rows strictly ascending (gather order == row order)
            if !a.kept_rows.windows(2).all(|w| w[0] < w[1]) {
                return Err("kept rows not sorted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batching_compute_instrs_partition_m() {
    check_cases(40, |rng| {
        let arch = random_arch(rng);
        let (layer, _) = random_layer(rng, &arch);
        let m_total = layer.prep.m.max(1) as u32;
        for (ti, _) in layer.tiles.iter().enumerate() {
            let mut next = 0u32;
            for instr in &layer.instrs {
                if let Instr::Compute { tile, m_base, m_count, .. } = *instr {
                    if tile as usize == ti {
                        if m_base != next {
                            return Err(format!("m gap: {m_base} != {next}"));
                        }
                        if m_count as usize > arch.macros_per_core {
                            return Err("chunk exceeds Tm".into());
                        }
                        next = m_base + m_count as u32;
                    }
                }
            }
            if next != m_total {
                return Err(format!("tile {ti} covered {next} of {m_total} rows"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ipu_only_reduces_cycles() {
    check_cases(25, |rng| {
        // identical configs except for the IPU flag
        let on = ArchConfig::bit_only();
        let off = ArchConfig { input_skipping: false, ..ArchConfig::bit_only() };
        let seed = rng.next_u64();
        let mut r1 = Rng::new(seed);
        let (l_on, x) = random_layer(&mut r1, &on);
        let mut r2 = Rng::new(seed);
        let (l_off, _) = random_layer(&mut r2, &off);
        let (s_on, _) = Machine::new(on).run_pim_layer(&l_on, Some(&x), false);
        let (s_off, _) = Machine::new(off).run_pim_layer(&l_off, Some(&x), false);
        if s_on.elapsed > s_off.elapsed {
            return Err(format!("IPU increased cycles: {} > {}", s_on.elapsed, s_off.elapsed));
        }
        Ok(())
    });
}

#[test]
fn prop_value_pruning_only_reduces_stored_rows() {
    check_cases(30, |rng| {
        let arch = ArchConfig::db_pim();
        let k = 16 + rng.below(256) as usize;
        let n = 16;
        let w = synthesize_weights(rng.next_u64(), k, n);
        let lo = prepare_layer("a", 2, k, n, w.clone(), SparsityConfig::hybrid(0.2), &arch,
                               quant::requant_mul(0.01), true, None);
        let hi = prepare_layer("b", 2, k, n, w, SparsityConfig::hybrid(0.8), &arch,
                               quant::requant_mul(0.01), true, None);
        let rows = |p: &dbpim::compiler::PreparedLayer| -> usize {
            (0..p.mask.groups).map(|g| p.mask.kept_rows(g)).sum()
        };
        if rows(&hi) > rows(&lo) {
            return Err("more pruning kept more rows".into());
        }
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_events() {
    use dbpim::energy::{EnergyTable, EventCounts};
    check_cases(50, |rng| {
        let t = EnergyTable::default28nm();
        let mut a = EventCounts::default();
        a.macro_cycles = rng.below(1000);
        a.macro_col_cycles = a.macro_cycles * 16;
        a.input_buf_reads = rng.below(500);
        a.simd_lane_ops = rng.below(500);
        let mut b = a.clone();
        b.macro_cycles += 1 + rng.below(100);
        b.macro_col_cycles = b.macro_cycles * 16;
        if b.energy_pj(&t) <= a.energy_pj(&t) {
            return Err("energy not monotone".into());
        }
        Ok(())
    });
}

#[test]
fn prop_decoders_never_panic_or_overread_on_bad_bytes() {
    // Satellite of the serving PR: decoders face untrusted bytes
    // (foreign instruction buffers, corrupted traces), so arbitrary and
    // truncated streams must come back as a clean `None` — never a
    // panic, never a read past the buffer. Complements the encode
    // round-trip properties above.
    use dbpim::compiler::Program;
    use dbpim::isa::{self, Segment};
    check_cases(80, |rng| {
        // 1) arbitrary bytes through every decoder
        let len = rng.below(240) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        if let Some(instrs) = isa::decode_stream(&bytes) {
            if instrs.len() * isa::INSTR_BYTES != bytes.len() {
                return Err("decode_stream consumed a partial word".into());
            }
        }
        // (a decoded segment need not re-encode byte-identically —
        // decode ignores padding bytes that encode zeroes — but it must
        // never claim to have consumed more than it was given)
        if let Some((seg, used)) = Segment::decode(&bytes) {
            if used > bytes.len() {
                return Err(format!("Segment::decode over-read: {used} > {}", bytes.len()));
            }
            if used != (seg.instrs.len() + 1) * isa::INSTR_BYTES {
                return Err("Segment::decode consumed a size inconsistent with its result".into());
            }
        }
        let _ = isa::decode_segments(&bytes);
        let _ = Program::decode(&bytes, 1 + rng.below(8) as usize);

        // 2) every proper truncation of a valid segment encoding is
        //    rejected (the header's length claim can no longer be met)
        let seg = Segment {
            core: rng.below(8) as u8,
            instrs: (0..1 + rng.below(6) as usize)
                .map(|_| isa::Instr::LoadTile { core: 0, tile: rng.next_u64() as u32 })
                .collect(),
        };
        let enc = seg.encode();
        for _ in 0..4 {
            let cut = rng.below(enc.len() as u64) as usize;
            if Segment::decode(&enc[..cut]).is_some() {
                return Err(format!("truncated segment accepted at {cut}/{}", enc.len()));
            }
        }
        // 3) flat streams: non-word-aligned truncations are rejected;
        //    single-byte corruption decodes cleanly or not at all
        let stream = isa::encode_stream(&[
            isa::Instr::LoadTile { core: 1, tile: 7 },
            isa::Instr::Compute { core: 1, tile: 7, m_base: 0, m_count: 4 },
            isa::Instr::Sync,
            isa::Instr::EndLayer,
        ]);
        let cut = rng.below(stream.len() as u64) as usize;
        if cut % isa::INSTR_BYTES != 0 && isa::decode_stream(&stream[..cut]).is_some() {
            return Err(format!("mid-word truncation accepted at {cut}"));
        }
        let mut corrupt = stream.clone();
        let at = rng.below(corrupt.len() as u64) as usize;
        corrupt[at] ^= 1u8 << rng.below(8);
        let _ = isa::decode_stream(&corrupt);
        let _ = Program::decode(&corrupt, 8);
        Ok(())
    });
}

#[test]
fn prop_cache_stats_deterministic_across_worker_counts() {
    // Satellite of the serving PR: for one sweep replayed under
    // private pools of different sizes, both caches must report
    // `lookups == hits + misses` and the SAME hit/miss counters for
    // every worker count — scheduling only ever moves work into
    // `dup_computes` (racing duplicate computations), never into the
    // deterministic counters the drivers and tests pin.
    use dbpim::compiler::CompileCache;
    use dbpim::coordinator::pool::Pool;
    use dbpim::models::fixtures::tiny_net;
    use dbpim::sim::SimCache;
    check_cases(5, |rng| {
        let net = tiny_net();
        let arch = random_arch(rng);
        let cells: Vec<(f64, u64)> =
            (0..6).map(|_| (0.2 * rng.below(3) as f64, rng.below(3))).collect();
        let run_under = |workers: usize| {
            let pool = Pool::new(workers);
            let cc = CompileCache::new();
            let sc = SimCache::new();
            let jobs: Vec<_> = cells
                .iter()
                .map(|&(v, seed)| {
                    let (net, arch, cc, sc) = (&net, &arch, &cc, &sc);
                    move || {
                        dbpim::sim::simulate_network_memo(
                            net,
                            SparsityConfig::hybrid(v),
                            arch,
                            seed,
                            Engine::Parallel,
                            cc,
                            sc,
                        )
                        .total_cycles()
                    }
                })
                .collect();
            let rows = pool.run_jobs(jobs);
            (rows, cc.stats(), sc.stats())
        };
        let (rows1, cc1, sc1) = run_under(1);
        let w = 2 + rng.below(15) as usize;
        let (rows2, cc2, sc2) = run_under(w);
        if rows1 != rows2 {
            return Err(format!("rows diverge between 1 and {w} workers"));
        }
        for (label, s) in
            [("compile@1", cc1), ("sim@1", sc1), ("compile@w", cc2), ("sim@w", sc2)]
        {
            if s.lookups() != s.hits + s.misses {
                return Err(format!("{label}: lookups != hits + misses: {s:?}"));
            }
        }
        if (cc1.hits, cc1.misses) != (cc2.hits, cc2.misses) {
            return Err(format!(
                "compile stats schedule-dependent: {cc1:?} vs {cc2:?} ({w} workers)"
            ));
        }
        if (sc1.hits, sc1.misses) != (sc2.hits, sc2.misses) {
            return Err(format!(
                "sim stats schedule-dependent: {sc1:?} vs {sc2:?} ({w} workers)"
            ));
        }
        // every cell reaches the sim cache once per PIM layer, and the
        // compile cache sees exactly one lookup per sim computation
        if sc1.lookups() != (cells.len() * 2) as u64 {
            return Err(format!("unexpected sim lookup count {sc1:?}"));
        }
        for (cc, sc) in [(cc1, sc1), (cc2, sc2)] {
            if cc.lookups() != sc.misses + sc.dup_computes {
                return Err(format!("compile lookups {cc:?} != sim computations {sc:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_serve_batched_bit_identical() {
    // The serving frontend's acceptance invariant: for random traffic
    // traces, random batch sizes and random worker counts, replayed
    // results are bitwise identical to serial per-request
    // `simulate_network` — batch boundaries, cross-tenant cache
    // sharing and pool scheduling never leak into results, and results
    // come back in admission order.
    use dbpim::coordinator::pool::Pool;
    use dbpim::coordinator::serve::{ServeCtx, ServeRequest, ServeSpec};
    use dbpim::models::fixtures::{small_net, tiny_net};
    use dbpim::models::Registry;
    check_cases(5, |rng| {
        let workers = 1 + rng.below(8) as usize;
        let max_batch = 1 + rng.below(5) as usize;
        let models = ["small", "tiny"];
        let archs = ["db-pim", "weights-only", "baseline"];
        let n = 3 + rng.below(8) as usize;
        let traffic: Vec<ServeRequest> = (0..n)
            .map(|_| ServeRequest {
                model: models[rng.below(2) as usize].to_string(),
                arch: archs[rng.below(3) as usize].to_string(),
                sparsity: SparsityConfig {
                    value_sparsity: 0.1 * rng.below(6) as f64,
                    fta: rng.below(2) == 0,
                },
                seed: rng.below(3),
            })
            .collect();
        let spec = ServeSpec { models: models.iter().map(|m| m.to_string()).collect(), traffic };
        // serial reference: each request alone, fully sequential, no
        // caches involved
        let registry = Registry::from_networks(vec![small_net(), tiny_net()]);
        let want: Vec<_> = spec
            .traffic
            .iter()
            .map(|r| {
                dbpim::sim::simulate_network_with_engine(
                    &registry.get(&r.model).unwrap(),
                    r.sparsity,
                    &ArchConfig::by_name(&r.arch).unwrap(),
                    r.seed,
                    Engine::Sequential,
                )
            })
            .collect();
        // batched replay on a private pool of random size
        let pool = Pool::new(workers);
        let ctx = ServeCtx::new(Registry::from_networks(vec![small_net(), tiny_net()]));
        let (spec_ref, ctx_ref) = (&spec, &ctx);
        let (got, stats) = pool
            .run_jobs(vec![move || spec_ref.run_with(ctx_ref, max_batch).unwrap()])
            .pop()
            .unwrap();
        if got.len() != want.len() {
            return Err("result count diverges".into());
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            if g.network != spec.traffic[i].model {
                return Err(format!("admission order broken at request {i}"));
            }
            if g.totals != w.totals {
                return Err(format!(
                    "totals diverge at request {i} (batch {max_batch}, {workers} workers)"
                ));
            }
            if g.layers.len() != w.layers.len() {
                return Err(format!("layer count diverges at request {i}"));
            }
            for (a, b) in g.layers.iter().zip(&w.layers) {
                if a.name != b.name
                    || a.events != b.events
                    || a.core_cycles != b.core_cycles
                    || a.elapsed != b.elapsed
                {
                    return Err(format!("layer {} diverges at request {i}", a.name));
                }
            }
        }
        if stats.requests != n || stats.latencies_ms.len() != n {
            return Err("serve stats inconsistent with trace length".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fault_map_pure_and_schedule_independent() {
    // ISSUE 9 acceptance: every cell-fault verdict is a pure hash of
    // (seed, coordinate) — no sequence, no shared state — so fault
    // placement and everything downstream of it is bit-identical for
    // any engine, worker count or visit order. Checked at both levels:
    // raw verdicts across map instances and visit orders, and a whole
    // faulty layer (compile-time corruption + ABFT detection +
    // degrade) across sequential/parallel engines and reruns.
    use dbpim::arch::{CellFaultSpec, FaultMap};
    check_cases(10, |rng| {
        let spec = CellFaultSpec {
            ber_stuck0: rng.f64() * 0.01,
            ber_stuck1: rng.f64() * 0.01,
            ber_transient: rng.f64() * 0.01,
            seed: rng.next_u64(),
        };
        let a = FaultMap::new(spec);
        let b = FaultMap::new(spec);
        let coords: Vec<(usize, usize, usize, usize, usize)> = (0..64)
            .map(|_| {
                (
                    rng.below(8) as usize,
                    rng.below(6) as usize,
                    rng.below(16) as usize,
                    rng.below(16) as usize,
                    rng.below(24) as usize,
                )
            })
            .collect();
        let fwd: Vec<_> = coords.iter().map(|&(c, m, k, r, l)| a.cell(c, m, k, r, l)).collect();
        for (i, &(c, m, k, r, l)) in coords.iter().enumerate().rev() {
            if b.cell(c, m, k, r, l) != fwd[i] {
                return Err(format!("verdict at coord {i} depends on instance/visit order"));
            }
        }
        // end-to-end: same faulty layer under both engines, run twice
        let mut arch = random_arch(rng);
        arch.n_cores = 1 + rng.below(8) as usize;
        arch.cell_faults = CellFaultSpec::uniform(1e-3 + rng.f64() * 5e-3, rng.next_u64());
        let functional = rng.below(2) == 0;
        let (layer, x) = random_layer(rng, &arch);
        if layer.faults.is_none() {
            return Err(format!("enabled spec compiled without fault metadata on {}", arch.name));
        }
        let seq = Machine::with_engine(arch.clone(), Engine::Sequential);
        let par = Machine::with_engine(arch.clone(), Engine::Parallel);
        let want = seq.run_pim_layer(&layer, Some(&x), functional);
        for (label, m) in
            [("sequential rerun", &seq), ("parallel", &par), ("parallel rerun", &par)]
        {
            let (s, acc) = m.run_pim_layer(&layer, Some(&x), functional);
            if s.events != want.0.events
                || s.core_cycles != want.0.core_cycles
                || s.elapsed != want.0.elapsed
            {
                return Err(format!(
                    "{label} stats diverge under faults on {} cores={}",
                    arch.name, arch.n_cores
                ));
            }
            if acc != want.1 {
                return Err(format!("{label} accumulators diverge under faults on {}", arch.name));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_zero_ber_bit_identical() {
    // ISSUE 9 acceptance: `CellFaultSpec::off()` must be bit-identical
    // to a build that never heard of the fault subsystem — regardless
    // of the seed riding along in the off spec or how the spare/degrade
    // knobs are set. Stronger than report equality: the off spec must
    // not perturb the CompileKey either, so the second network shares
    // every compile-cache entry with the first (all hits, no misses).
    use dbpim::arch::{CellFaultSpec, DegradePolicy};
    use dbpim::compiler::CompileCache;
    use dbpim::models::fixtures::tiny_net;
    check_cases(8, |rng| {
        let base = random_arch(rng);
        let mut decorated = base.clone();
        decorated.cell_faults = CellFaultSpec { seed: rng.next_u64(), ..CellFaultSpec::off() };
        decorated.spare_columns_per_macro = rng.below(5) as usize;
        decorated.spare_macros_per_core = rng.below(3) as usize;
        decorated.fault_degrade =
            [DegradePolicy::Fail, DegradePolicy::Mask, DegradePolicy::Recompute]
                [rng.below(3) as usize];
        let net = tiny_net();
        let sp = SparsityConfig { value_sparsity: rng.f64() * 0.7, fta: rng.below(2) == 0 };
        let seed = rng.next_u64();
        let cache = CompileCache::new();
        let a = dbpim::sim::simulate_network_cached(
            &net, sp, &base, seed, Engine::Sequential, &cache,
        );
        let first = cache.stats();
        let b = dbpim::sim::simulate_network_cached(
            &net, sp, &decorated, seed, Engine::Sequential, &cache,
        );
        if a.totals != b.totals || a.total_cycles() != b.total_cycles() {
            return Err(format!("zero-BER totals diverge on {}", base.name));
        }
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            if la.events != lb.events
                || la.core_cycles != lb.core_cycles
                || la.elapsed != lb.elapsed
            {
                return Err(format!("zero-BER layer {} diverges on {}", la.name, base.name));
            }
        }
        let second = cache.stats();
        if second.misses != first.misses || second.hits != first.misses {
            return Err(format!(
                "off fault spec perturbed the compile key on {}: {first:?} then {second:?}",
                base.name
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_repair_respects_spare_budget() {
    // The repair pass may only spend what the arch grants: at most
    // `spare_macros_per_core` replica slots per core served by spares,
    // column maps injective into the physical column space, every
    // logical column on a clean physical column unless reported in
    // `stuck_logical`, and the aggregate report self-consistent
    // (`stuck == repaired + unrepairable`). With a zero spare budget
    // nothing may be repaired or spared.
    use dbpim::arch::FaultMap;
    use dbpim::compiler::packing;
    check_cases(12, |rng| {
        let mut arch = ArchConfig::db_pim();
        arch.n_cores = 1 + rng.below(4) as usize;
        arch.spare_columns_per_macro = rng.below(5) as usize;
        arch.spare_macros_per_core = rng.below(3) as usize;
        arch.cell_faults = dbpim::arch::CellFaultSpec::uniform(
            [1e-5, 1e-4, 1e-3, 5e-3][rng.below(4) as usize],
            rng.next_u64(),
        );
        let plan = packing::plan_repair(&arch).ok_or("enabled spec must yield a plan")?;
        let fm = FaultMap::new(arch.cell_faults);
        let phys_cols = arch.macro_columns + arch.spare_columns_per_macro;
        let phys_macros = arch.macros_per_core + arch.spare_macros_per_core;
        let rep = plan.report;
        if rep.stuck_columns != rep.repaired_columns + rep.unrepairable_columns {
            return Err(format!("report not self-consistent: {rep:?}"));
        }
        if rep.spared_macros > (arch.n_cores * arch.spare_macros_per_core) as u64 {
            return Err(format!("spared {} macros over budget", rep.spared_macros));
        }
        if plan.slots.len() != arch.n_cores {
            return Err("one slot list per core".into());
        }
        for (core, slots) in plan.slots.iter().enumerate() {
            if slots.len() != arch.macros_per_core {
                return Err(format!("core {core}: {} replica slots", slots.len()));
            }
            let mut macros_seen = std::collections::HashSet::new();
            for mr in slots {
                if mr.phys_macro >= phys_macros || !macros_seen.insert(mr.phys_macro) {
                    return Err(format!("core {core}: bad physical macro {}", mr.phys_macro));
                }
                if mr.col_map.len() != arch.macro_columns {
                    return Err(format!("core {core}: col_map length {}", mr.col_map.len()));
                }
                let mut cols_seen = std::collections::HashSet::new();
                for (lc, &pc) in mr.col_map.iter().enumerate() {
                    if pc as usize >= phys_cols || !cols_seen.insert(pc) {
                        return Err(format!("core {core}: col_map not injective at {lc}"));
                    }
                    let stuck = fm.column_stuck(
                        core,
                        mr.phys_macro,
                        pc as usize,
                        arch.compartments,
                        arch.rows_per_compartment,
                    );
                    let reported = mr.stuck_logical.binary_search(&(lc as u16)).is_ok();
                    if stuck != reported {
                        return Err(format!(
                            "core {core} macro {}: logical {lc} stuck={stuck} reported={reported}",
                            mr.phys_macro
                        ));
                    }
                }
            }
        }
        if arch.spare_columns_per_macro == 0 && arch.spare_macros_per_core == 0 {
            // no budget: every stuck column stays, nothing is spared.
            // (spare macros alone can still "repair" by swapping whole
            // macros, so only the fully-zero budget pins zero repairs)
            if rep.repaired_columns != 0 || rep.spared_macros != 0 {
                return Err(format!("zero budget but repairs reported: {rep:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_isa_roundtrip_random_streams() {
    check_cases(50, |rng| {
        let n = rng.below(64) as usize;
        let instrs: Vec<Instr> = (0..n)
            .map(|_| match rng.below(4) {
                0 => Instr::LoadTile { core: rng.below(8) as u8, tile: rng.next_u64() as u32 },
                1 => Instr::Compute {
                    core: rng.below(8) as u8,
                    tile: rng.next_u64() as u32,
                    m_base: rng.next_u64() as u32,
                    m_count: rng.next_u64() as u16,
                },
                2 => Instr::Sync,
                _ => Instr::EndLayer,
            })
            .collect();
        let bytes = dbpim::isa::encode_stream(&instrs);
        if dbpim::isa::decode_stream(&bytes) != Some(instrs) {
            return Err("stream roundtrip failed".into());
        }
        Ok(())
    });
}

#[test]
fn prop_open_loop_deterministic_across_worker_counts() {
    // ISSUE 7 acceptance: a seeded open-loop run — per-request
    // outcomes, goodput/SLO/shed/retry stats, and event order — is
    // bit-identical across 1, 2 and N workers, with fault injection
    // on. The loop itself is single-threaded discrete-event simulation
    // in virtual time; the pool only parallelizes the simulations
    // inside one event, which are schedule-independent (DESIGN.md
    // §8/§11). Host wall time and `dup_computes` are the only fields
    // allowed to vary.
    use dbpim::coordinator::arrivals::ArrivalProcess;
    use dbpim::coordinator::faults::FaultSpec;
    use dbpim::coordinator::pool::Pool;
    use dbpim::coordinator::serve::{ServeCtx, ServeRequest};
    use dbpim::coordinator::serve_loop::OpenLoopSpec;
    use dbpim::models::fixtures::{small_net, tiny_net};
    use dbpim::models::Registry;
    check_cases(4, |rng| {
        let arrivals = if rng.below(2) == 0 {
            ArrivalProcess::Poisson { rate_rps: 500.0 + rng.below(4000) as f64 }
        } else {
            ArrivalProcess::Bursty {
                base_rps: 200.0 + rng.below(500) as f64,
                burst_rps: 2000.0 + rng.below(8000) as f64,
                mean_phase_ms: 5.0 + rng.below(20) as f64,
            }
        };
        let tpl = |model: &str, seed: u64| ServeRequest {
            model: model.into(),
            arch: "db-pim".into(),
            sparsity: SparsityConfig::hybrid(0.5),
            seed,
        };
        let spec = OpenLoopSpec {
            models: vec!["small".into(), "tiny".into()],
            workload: vec![tpl("small", 1 + rng.below(2)), tpl("tiny", rng.below(2))],
            arrivals,
            requests: 6 + rng.below(10) as usize,
            queue_cap: 4 + rng.below(8) as usize,
            deadline_ms: 0.5 + 0.5 * rng.below(4) as f64,
            timeout_ms: 8.0,
            max_batch: 1 + rng.below(4) as usize,
            chips: 1 + rng.below(3) as usize,
            scheme: None,
            max_retries: 1 + rng.below(3) as u32,
            backoff_ms: 0.25,
            seed: rng.next_u64(),
            faults: FaultSpec::default_with_seed(rng.next_u64()),
            trace_events: true,
        };
        let run_under = |workers: usize| {
            let pool = Pool::new(workers);
            let ctx = ServeCtx::new(Registry::from_networks(vec![small_net(), tiny_net()]));
            let (spec_ref, ctx_ref) = (&spec, &ctx);
            pool.run_jobs(vec![move || spec_ref.run_with(ctx_ref).unwrap()]).pop().unwrap()
        };
        let (o1, s1) = run_under(1);
        let (o2, s2) = run_under(2);
        let w = 3 + rng.below(10) as usize;
        let (ow, sw) = run_under(w);
        if o1 != o2 || o1 != ow {
            return Err(format!("outcomes diverge across 1/2/{w} workers"));
        }
        if s1.events != s2.events || s1.events != sw.events {
            return Err(format!("event order diverges across 1/2/{w} workers"));
        }
        for (label, s) in [("2", &s2), ("N", &sw)] {
            let a = (s1.done, s1.shed, s1.failed, s1.timed_out, s1.deadline_met, s1.retries);
            let b = (s.done, s.shed, s.failed, s.timed_out, s.deadline_met, s.retries);
            if a != b {
                return Err(format!("outcome counters diverge at {label} workers: {a:?} vs {b:?}"));
            }
            let a = (s1.admitted, s1.batches, s1.peak_queue);
            let b = (s.admitted, s.batches, s.peak_queue);
            if a != b {
                return Err(format!("loop counters diverge at {label} workers: {a:?} vs {b:?}"));
            }
            if s1.makespan_ms != s.makespan_ms
                || s1.slo_attainment != s.slo_attainment
                || s1.goodput_rps != s.goodput_rps
                || s1.p99_ms != s.p99_ms
            {
                return Err(format!("derived stats diverge at {label} workers"));
            }
            if (s1.cache.sim.hits, s1.cache.sim.misses) != (s.cache.sim.hits, s.cache.sim.misses)
            {
                return Err(format!("sim cache stats schedule-dependent at {label} workers"));
            }
        }
        if s1.done + s1.shed + s1.failed + s1.timed_out != spec.requests {
            return Err(format!("outcome conservation broken: {s1:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_open_loop_fault_exhaustion_typed_outcomes() {
    // ISSUE 7 satellite: an injected fault that exhausts its retry
    // budget must surface as a typed per-request `Failed` outcome with
    // exact shed/retry counters — never a pool poisoning, never a
    // panic — for every worker count. transient_rate = 1.0 makes every
    // attempt fail deterministically.
    use dbpim::coordinator::arrivals::ArrivalProcess;
    use dbpim::coordinator::faults::FaultSpec;
    use dbpim::coordinator::pool::Pool;
    use dbpim::coordinator::serve::{ServeCtx, ServeRequest};
    use dbpim::coordinator::serve_loop::{OpenLoopSpec, Outcome};
    use dbpim::models::fixtures::small_net;
    use dbpim::models::Registry;
    check_cases(4, |rng| {
        let workers = 1 + rng.below(8) as usize;
        let n = 3 + rng.below(6) as usize;
        let max_retries = rng.below(3) as u32;
        let spec = OpenLoopSpec {
            models: vec!["small".into()],
            workload: vec![ServeRequest {
                model: "small".into(),
                arch: "db-pim".into(),
                sparsity: SparsityConfig::hybrid(0.5),
                seed: rng.below(3),
            }],
            arrivals: ArrivalProcess::Poisson { rate_rps: 1000.0 },
            requests: n,
            queue_cap: 64,
            deadline_ms: 1e6,
            timeout_ms: 4e6,
            max_batch: 1 + rng.below(4) as usize,
            chips: 1 + rng.below(2) as usize,
            scheme: None,
            max_retries,
            backoff_ms: 0.5,
            seed: rng.next_u64(),
            faults: FaultSpec { seed: rng.next_u64(), transient_rate: 1.0, ..FaultSpec::off() },
            trace_events: false,
        };
        let pool = Pool::new(workers);
        let ctx = ServeCtx::new(Registry::from_networks(vec![small_net()]));
        let (spec_ref, ctx_ref) = (&spec, &ctx);
        let (outcomes, stats) =
            pool.run_jobs(vec![move || spec_ref.run_with(ctx_ref).unwrap()]).pop().unwrap();
        for o in &outcomes {
            let want = Outcome::Failed { attempts: max_retries + 1 };
            if o.outcome != want {
                return Err(format!(
                    "request {} not a typed failure: {:?} (want {want:?}, {workers} workers)",
                    o.id, o.outcome
                ));
            }
        }
        if stats.failed != n || stats.done != 0 || stats.shed != 0 || stats.timed_out != 0 {
            return Err(format!("counters wrong under total fault load: {stats:?}"));
        }
        if stats.retries != n as u64 * max_retries as u64 {
            return Err(format!(
                "retry counter wrong: {} (want {} = {n} x {max_retries})",
                stats.retries,
                n as u64 * max_retries as u64
            ));
        }
        // the pool and caches are not poisoned: a healthy follow-up run
        // through the same pool and context completes everything
        let mut healthy = spec.clone();
        healthy.faults = FaultSpec::off();
        let (h_ref, c_ref) = (&healthy, &ctx);
        let (_, hs) = pool.run_jobs(vec![move || h_ref.run_with(c_ref).unwrap()]).pop().unwrap();
        if hs.done != n {
            return Err(format!("pool poisoned after fault exhaustion: {hs:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_attention_gemm_engine_and_cache_bit_identical() {
    // Transformer layers are PIM layers purely through `matmul_dims`,
    // so they must inherit every determinism contract the CNN path
    // has: Sequential == Parallel, and the memoized path == the
    // uncached path, bit for bit, at random seq lengths and sparsity
    // points (which exercise the per-head overrides and 2:4 pruning).
    use dbpim::compiler::CompileCache;
    use dbpim::sim::SimCache;
    check_cases(6, |rng| {
        let seq = 2 + 2 * rng.below(8) as usize; // 2..=16
        let net = dbpim::models::transformer_seq("tiny_transformer", seq)
            .ok_or("tiny_transformer not registered")?;
        let sp = SparsityConfig { value_sparsity: rng.f64() * 0.7, fta: rng.below(2) == 0 };
        let arch = ArchConfig::db_pim();
        let seed = rng.next_u64();
        let seq_r =
            dbpim::sim::simulate_network_with_engine(&net, sp, &arch, seed, Engine::Sequential);
        let par_r =
            dbpim::sim::simulate_network_with_engine(&net, sp, &arch, seed, Engine::Parallel);
        if par_r.totals != seq_r.totals || par_r.total_cycles() != seq_r.total_cycles() {
            return Err(format!("engines diverge on {} (seq={seq})", net.name));
        }
        let cc = CompileCache::new();
        let sc = SimCache::new();
        let memo = dbpim::sim::simulate_network_memo(
            &net,
            sp,
            &arch,
            seed,
            Engine::Sequential,
            &cc,
            &sc,
        );
        if memo.totals != seq_r.totals || memo.total_cycles() != seq_r.total_cycles() {
            return Err(format!("memoized run diverges on {} (seq={seq})", net.name));
        }
        for (a, b) in memo.layers.iter().zip(&seq_r.layers) {
            if a.name != b.name || a.events != b.events || a.elapsed != b.elapsed {
                return Err(format!("layer {} diverges under caches (seq={seq})", a.name));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_explore_pareto() {
    // Every reported frontier row is actually non-dominated within its
    // model, and the whole sweep reproduces bit-exactly from a fresh
    // `SweepCtx` (each `explore_with_stats` call builds its own).
    use dbpim::coordinator::experiments as exp;
    check_cases(3, |rng| {
        let names = vec!["tiny_transformer".to_string()];
        let seed = rng.below(1000);
        let (rows, _) = exp::explore_with_stats(&names, seed);
        if rows.is_empty() || !rows.iter().any(|r| r.on_frontier) {
            return Err(format!("empty sweep or frontier at seed {seed}"));
        }
        for r in rows.iter().filter(|r| r.on_frontier) {
            let dominated = rows.iter().any(|o| {
                o.model == r.model
                    && o.speedup >= r.speedup
                    && o.energy_uj <= r.energy_uj
                    && (o.speedup > r.speedup || o.energy_uj < r.energy_uj)
            });
            if dominated {
                return Err(format!("dominated frontier row {} / {}", r.network, r.arch));
            }
        }
        // frontier marks agree with the standalone helper
        let pts: Vec<(f64, f64)> = rows.iter().map(|r| (r.speedup, r.energy_uj)).collect();
        let mask = exp::pareto_frontier(&pts);
        for (r, m) in rows.iter().zip(&mask) {
            if r.on_frontier != *m {
                return Err(format!("frontier mark disagrees on {} / {}", r.network, r.arch));
            }
        }
        let (again, _) = exp::explore_with_stats(&names, seed);
        if again != rows {
            return Err(format!("explore rows not reproducible at seed {seed}"));
        }
        Ok(())
    });
}
