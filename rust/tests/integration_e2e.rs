//! End-to-end integration: the exported MiniNet artifact through the
//! full rust stack (load → compile → cycle-accurate functional sim) on
//! every architecture, checked bit-for-bit against the exported golden
//! logits, plus experiment-level shape checks on the zoo.
//!
//! Skips gracefully when `make artifacts` has not run.

use dbpim::arch::ArchConfig;
use dbpim::compiler::SparsityConfig;
use dbpim::models::{self, MiniNet};
use dbpim::sim::{self, pipeline::run_mininet};

fn load() -> Option<MiniNet> {
    models::load_mininet(&models::default_artifacts_dir()).ok()
}

#[test]
fn mininet_all_archs_bit_exact() {
    let Some(net) = load() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    for arch in [
        ArchConfig::db_pim(),
        ArchConfig::dense_baseline(),
        ArchConfig::bit_only(),
        ArchConfig::value_only(),
        ArchConfig::weights_only(),
        ArchConfig::dac24(),
    ] {
        let run = run_mininet(&net, &arch).unwrap();
        assert_eq!(run.logits, net.golden, "{} diverges from golden", arch.name);
    }
}

#[test]
fn mininet_speedup_and_energy_ordering() {
    let Some(net) = load() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let d = run_mininet(&net, &ArchConfig::db_pim()).unwrap();
    let bit = run_mininet(&net, &ArchConfig::bit_only()).unwrap();
    let base = run_mininet(&net, &ArchConfig::dense_baseline()).unwrap();
    // hybrid ≤ bit-only ≤ baseline in cycles (hybrid exploits strictly
    // more sparsity than bit-only on this 60%-value-pruned model)
    assert!(d.total_cycles() <= bit.total_cycles());
    assert!(bit.total_cycles() < base.total_cycles());
    assert!(d.energy_uj() < base.energy_uj());
}

#[test]
fn mininet_utilization_beats_baseline() {
    let Some(net) = load() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let arch = ArchConfig::db_pim();
    let d = run_mininet(&net, &arch).unwrap();
    let b = run_mininet(&net, &ArchConfig::dense_baseline()).unwrap();
    let cells = arch.macro_columns * arch.compartments;
    assert!(d.totals.u_act(cells) > 2.0 * b.totals.u_act(cells));
}

// ---------------------------------------------------------------------------
// zoo-level experiment shape checks (the paper's qualitative claims)
// ---------------------------------------------------------------------------

#[test]
fn fig11_shape_vgg_beats_resnet_beats_mobilenet() {
    let rows = dbpim::coordinator::experiments::fig11(7);
    let speedup = |net: &str, total: f64| {
        rows.iter()
            .find(|r| r.network == net && (r.total_sparsity - total).abs() < 1e-9)
            .map(|r| r.speedup)
            .unwrap()
    };
    // at 90% compound sparsity: vgg > resnet > mobilenet (Fig. 11)
    let v = speedup("vgg19", 0.90);
    let r = speedup("resnet18", 0.90);
    let m = speedup("mobilenet_v2", 0.90);
    assert!(v > r && r > m, "ordering broke: vgg {v} resnet {r} mobilenet {m}");
    // headline band: up to ~8x speedup at 90%
    assert!(v > 6.0 && v < 14.0, "vgg 90% speedup {v} out of band");
    // 75% point: roughly 4x or higher is NOT guaranteed for mobilenet,
    // but vgg/resnet sit near 4x
    assert!(speedup("vgg19", 0.75) > 3.0);
    // energy savings in the paper's band (73–90%)
    for row in &rows {
        assert!(
            row.energy_saving > 0.5 && row.energy_saving < 0.97,
            "energy saving out of band: {row:?}"
        );
    }
    // monotone in sparsity per network
    for net in ["vgg19", "resnet18", "mobilenet_v2"] {
        let mut pts: Vec<_> = rows.iter().filter(|r| r.network == net).collect();
        pts.sort_by(|a, b| a.total_sparsity.partial_cmp(&b.total_sparsity).unwrap());
        for w in pts.windows(2) {
            assert!(w[1].speedup >= w[0].speedup * 0.98, "{net} not monotone");
        }
    }
}

#[test]
fn fig12_hybrid_dominates_single_axis_approaches() {
    let rows = dbpim::coordinator::experiments::fig12(7);
    for net in ["alexnet", "vgg19", "resnet18", "mobilenet_v2", "efficientnet_b0"] {
        let get = |ap: &str| rows.iter().find(|r| r.network == net && r.approach == ap).unwrap();
        let hybrid = get("hybrid");
        let bit = get("bit");
        let value = get("value");
        assert!(
            hybrid.speedup >= bit.speedup && hybrid.speedup >= value.speedup,
            "{net}: hybrid {} vs bit {} value {}",
            hybrid.speedup,
            bit.speedup,
            value.speedup
        );
        assert!(hybrid.energy_norm <= bit.energy_norm * 1.02);
        assert!(hybrid.energy_norm < 1.0 && hybrid.speedup > 1.0);
    }
    // compact models trail the big CNNs end-to-end (Fig. 12 discussion)
    let hy = |net: &str| rows.iter().find(|r| r.network == net && r.approach == "hybrid").unwrap();
    assert!(hy("mobilenet_v2").speedup < hy("vgg19").speedup);
    assert!(hy("efficientnet_b0").speedup < hy("vgg19").speedup);
}

#[test]
fn table3_hybrid_fastest_dac24_slowest() {
    let rows = dbpim::coordinator::experiments::table3(7);
    assert_eq!(rows.len(), 5);
    for r in &rows {
        assert!(
            r.hybrid_ms < r.bit_level_ms && r.bit_level_ms < r.dac24_ms,
            "{r:?}"
        );
        let total_speedup = r.dac24_ms / r.hybrid_ms;
        assert!(total_speedup > 2.0 && total_speedup < 20.0, "{r:?}");
    }
}

#[test]
fn simd_bound_networks_keep_simd_time_constant_across_archs() {
    // dw-conv time must be identical on DB-PIM and baseline — only PIM
    // layers accelerate (this produces the Fig. 13 Amdahl floor).
    let net = models::mobilenet_v2();
    let a = sim::simulate_network(&net, SparsityConfig::hybrid(0.6), &ArchConfig::db_pim(), 3);
    let b = sim::simulate_network(&net, SparsityConfig::dense(), &ArchConfig::dense_baseline(), 3);
    let dw = |r: &sim::SimReport| {
        r.layers
            .iter()
            .filter(|l| l.category == sim::OpCategory::DwConv)
            .map(|l| l.elapsed)
            .sum::<u64>()
    };
    assert_eq!(dw(&a), dw(&b), "dw-conv time should not depend on PIM config");
}
