//! Integration: the PJRT runtime path. Loads the AOT HLO artifacts,
//! executes them on the CPU PJRT client, and cross-checks against both
//! the exported golden logits and the rust simulator.
//!
//! These tests exercise the xla crate and require the artifacts; they
//! skip gracefully when `make artifacts` has not run.

use dbpim::arch::ArchConfig;
use dbpim::csd;
use dbpim::models::{self, MiniNet};
use dbpim::runtime;
use dbpim::sim::pipeline::run_mininet;
use dbpim::tensor::{matmul_i8, MatI8};
use dbpim::util::Rng;

fn load() -> Option<MiniNet> {
    models::load_mininet(&models::default_artifacts_dir()).ok()
}

#[test]
fn golden_hlo_executes_and_matches_export() {
    let Some(net) = load() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let logits = runtime::run_golden_mininet(&net).expect("PJRT run failed");
    assert_eq!(logits, net.golden, "PJRT output != exported golden logits");
}

#[test]
fn simulator_matches_pjrt_bit_for_bit() {
    let Some(net) = load() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let logits = runtime::run_golden_mininet(&net).expect("PJRT run failed");
    let run = run_mininet(&net, &ArchConfig::db_pim()).unwrap();
    assert_eq!(run.logits, logits, "three-layer stack round-trip broke");
}

#[test]
fn tile_matmul_hlo_matches_rust_reference() {
    // the Pallas dyadic-kernel tile graph vs the rust exact matmul, on
    // random tiles of the exported geometry (64 x 128 x 64)
    let Some(net) = load() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let (m, k, n) = (64, 128, 64);
    let mut rng = Rng::new(99);
    let x: Vec<i8> = (0..m * k).map(|_| rng.int8()).collect();
    let w: Vec<i8> = (0..k * n).map(|_| rng.int8()).collect();
    // dyadic digit planes [4, K, N] (same decomposition as python csd)
    let mut planes = vec![0i8; 4 * k * n];
    for r in 0..k {
        for c in 0..n {
            let blocks = csd::dyadic_blocks(w[r * n + c]);
            for (d, &coef) in blocks.iter().enumerate() {
                planes[(d * k + r) * n + c] = coef;
            }
        }
    }
    let got = runtime::run_golden_tile(&net, &x, m, k, &planes, n).expect("tile run failed");
    let want = matmul_i8(&MatI8::from_vec(m, k, x), &MatI8::from_vec(k, n, w));
    let want32: Vec<i32> = want.data;
    assert_eq!(got, want32, "Pallas tile kernel != rust reference");
}

#[test]
fn literal_shape_mismatch_is_rejected() {
    let err = runtime::literal_i8(&[1, 2, 3], &[2, 2]);
    assert!(err.is_err());
}
